//! PJRT runtime: loads the AOT-compiled HLO artifacts and executes
//! them on the request path. Python is **never** on this path — the
//! Rust binary is self-contained once `make artifacts` has run.
//!
//! Two layers:
//!
//! * [`Engine`] — owns the `xla` PJRT CPU client and a compile-once
//!   executable cache. PJRT handles are raw pointers (`!Send`), so an
//!   `Engine` lives on one thread.
//! * [`EngineHandle`] — the `Send + Clone` face the coordinator uses: a
//!   dedicated executor thread owns the `Engine` and serves execution
//!   requests over a channel (single execution stream, like a device
//!   queue).

pub mod artifact;

pub use artifact::{ArtifactKind, ArtifactMeta, DType, Registry};

use crate::Result;
use std::collections::HashMap;
use std::path::Path;
use std::sync::mpsc;

/// A matrix (or vector) of i32 operands, row-major.
#[derive(Debug, Clone)]
pub struct IntMat {
    pub data: Vec<i32>,
    pub rows: usize,
    pub cols: usize,
    /// Emit a rank-1 literal of length `cols` (bias vectors etc.).
    rank1: bool,
}

impl IntMat {
    pub fn new(data: Vec<i32>, rows: usize, cols: usize) -> Result<Self> {
        anyhow::ensure!(data.len() == rows * cols, "shape mismatch");
        Ok(IntMat {
            data,
            rows,
            cols,
            rank1: false,
        })
    }

    /// A rank-1 operand (e.g. a bias vector).
    pub fn vec(data: Vec<i32>) -> Self {
        let cols = data.len();
        IntMat {
            data,
            rows: 1,
            cols,
            rank1: true,
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        if self.rank1 {
            Ok(lit)
        } else {
            Ok(lit.reshape(&[self.rows as i64, self.cols as i64])?)
        }
    }
}

/// The single-threaded PJRT engine.
pub struct Engine {
    client: xla::PjRtClient,
    registry: Registry,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// Create a CPU PJRT client and load the artifact manifest.
    pub fn new(artifact_dir: &Path) -> Result<Engine> {
        let registry = Registry::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine {
            client,
            registry,
            cache: HashMap::new(),
        })
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (once) and return the executable for an artifact.
    fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let meta = self
                .registry
                .get(name)
                .ok_or_else(|| anyhow::anyhow!("unknown artifact '{name}'"))?
                .clone();
            let proto = xla::HloModuleProto::from_text_file(&meta.path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Eagerly compile every artifact (server warm-up).
    pub fn warm_up(&mut self) -> Result<usize> {
        let names: Vec<String> = self.registry.iter().map(|m| m.name.clone()).collect();
        for n in &names {
            self.executable(n)?;
        }
        Ok(names.len())
    }

    /// Execute an artifact with i32 matrix inputs; returns the first
    /// tuple element flattened to f64 (f32 artifacts are upcast).
    pub fn execute(&mut self, name: &str, inputs: &[IntMat]) -> Result<Vec<f64>> {
        let dtype = self
            .registry
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact '{name}'"))?
            .dtype;
        let exe = self.executable(name)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|m| m.to_literal())
            .collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?
            .to_tuple1()?;
        Ok(match dtype {
            DType::F32 => result.to_vec::<f32>()?.into_iter().map(f64::from).collect(),
            DType::F64 => result.to_vec::<f64>()?,
        })
    }

    /// Execute the registered matmul artifact for `(m,k,n,bits,variant)`.
    /// Returns `None` (without executing) when no artifact matches —
    /// the caller falls back to the native plane-matmul path.
    pub fn execute_matmul(
        &mut self,
        a: &IntMat,
        b: &IntMat,
        bits: u32,
        variant: crate::sim::mac_common::MacVariant,
    ) -> Result<Option<Vec<f64>>> {
        let key = self
            .registry
            .find_matmul(a.rows, a.cols, b.cols, bits, variant)
            .map(|meta| meta.name.clone());
        match key {
            Some(name) => Ok(Some(self.execute(&name, &[a.clone(), b.clone()])?)),
            None => Ok(None),
        }
    }
}

/// A request processed by the executor thread.
enum Req {
    Execute {
        name: String,
        inputs: Vec<IntMat>,
        reply: mpsc::Sender<Result<Vec<f64>>>,
    },
    Matmul {
        a: IntMat,
        b: IntMat,
        bits: u32,
        variant: crate::sim::mac_common::MacVariant,
        reply: mpsc::Sender<Result<Option<Vec<f64>>>>,
    },
    WarmUp {
        reply: mpsc::Sender<Result<usize>>,
    },
    Shutdown,
}

/// `Send + Clone` handle to an engine running on its own thread.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<Req>,
}

impl EngineHandle {
    /// Spawn the executor thread. Fails fast if the engine cannot be
    /// constructed (missing artifacts, PJRT init failure).
    pub fn spawn(artifact_dir: &Path) -> Result<(EngineHandle, std::thread::JoinHandle<()>)> {
        let (tx, rx) = mpsc::channel::<Req>();
        let (init_tx, init_rx) = mpsc::channel::<Result<()>>();
        let dir = artifact_dir.to_path_buf();
        let join = std::thread::Builder::new()
            .name("pjrt-engine".into())
            .spawn(move || {
                let mut engine = match Engine::new(&dir) {
                    Ok(e) => {
                        let _ = init_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = init_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Req::Execute { name, inputs, reply } => {
                            let _ = reply.send(engine.execute(&name, &inputs));
                        }
                        Req::Matmul { a, b, bits, variant, reply } => {
                            let _ = reply.send(engine.execute_matmul(&a, &b, bits, variant));
                        }
                        Req::WarmUp { reply } => {
                            let _ = reply.send(engine.warm_up());
                        }
                        Req::Shutdown => break,
                    }
                }
            })?;
        init_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("engine thread died during init"))??;
        Ok((EngineHandle { tx }, join))
    }

    pub fn execute(&self, name: &str, inputs: Vec<IntMat>) -> Result<Vec<f64>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Req::Execute {
                name: name.to_string(),
                inputs,
                reply,
            })
            .map_err(|_| anyhow::anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("engine thread gone"))?
    }

    pub fn execute_matmul(
        &self,
        a: IntMat,
        b: IntMat,
        bits: u32,
        variant: crate::sim::mac_common::MacVariant,
    ) -> Result<Option<Vec<f64>>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Req::Matmul {
                a,
                b,
                bits,
                variant,
                reply,
            })
            .map_err(|_| anyhow::anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("engine thread gone"))?
    }

    pub fn warm_up(&self) -> Result<usize> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Req::WarmUp { reply })
            .map_err(|_| anyhow::anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("engine thread gone"))?
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Req::Shutdown);
    }
}

/// Default artifact directory: `$BITSMM_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> std::path::PathBuf {
    std::env::var_os("BITSMM_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|| "artifacts".into())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intmat_checks_shape() {
        assert!(IntMat::new(vec![1, 2, 3], 2, 2).is_err());
        assert!(IntMat::new(vec![1, 2, 3, 4], 2, 2).is_ok());
    }

    #[test]
    fn missing_artifact_dir_errors_cleanly() {
        let err = Engine::new(Path::new("/nonexistent-dir-xyz")).map(|_| ()).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
