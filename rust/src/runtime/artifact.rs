//! Artifact registry: the manifest of AOT-compiled HLO executables
//! produced by `python/compile/aot.py` (`make artifacts`).
//!
//! Manifest line format (one artifact per line):
//! `name kind variant bits m k n dtype path`

use crate::sim::mac_common::MacVariant;
use crate::Result;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// What an artifact computes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// Bare bit-serial matmul `(a m×k, b k×n) → (m×n,)`.
    Matmul,
    /// Quantized MLP forward (weights/biases as parameters).
    Mlp,
    /// Attention block forward.
    Attention,
}

impl std::str::FromStr for ArtifactKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "matmul" => Ok(ArtifactKind::Matmul),
            "mlp" => Ok(ArtifactKind::Mlp),
            "attention" => Ok(ArtifactKind::Attention),
            other => anyhow::bail!("unknown artifact kind '{other}'"),
        }
    }
}

/// Output element type of an artifact.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    F64,
}

impl std::str::FromStr for DType {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "f64" => Ok(DType::F64),
            other => anyhow::bail!("unknown dtype '{other}'"),
        }
    }
}

/// One manifest entry.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub kind: ArtifactKind,
    pub variant: MacVariant,
    pub bits: u32,
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub dtype: DType,
    /// Absolute path to the HLO text file.
    pub path: PathBuf,
}

/// Shape key used to look up matmul executables.
pub type MatmulKey = (usize, usize, usize, u32, MacVariant);

/// Parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Registry {
    by_name: HashMap<String, ArtifactMeta>,
    matmuls: HashMap<MatmulKey, String>,
}

impl Registry {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Registry> {
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {} ({e}); run `make artifacts` first",
                manifest.display()
            )
        })?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (separated out for tests).
    pub fn parse(text: &str, dir: &Path) -> Result<Registry> {
        let mut reg = Registry::default();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let f: Vec<&str> = line.split_whitespace().collect();
            anyhow::ensure!(
                f.len() == 9,
                "manifest line {} malformed ({} fields)",
                lineno + 1,
                f.len()
            );
            let meta = ArtifactMeta {
                name: f[0].to_string(),
                kind: f[1].parse()?,
                variant: f[2].parse()?,
                bits: f[3].parse()?,
                m: f[4].parse()?,
                k: f[5].parse()?,
                n: f[6].parse()?,
                dtype: f[7].parse()?,
                path: dir.join(f[8]),
            };
            if meta.kind == ArtifactKind::Matmul && meta.dtype == DType::F32 {
                reg.matmuls.insert(
                    (meta.m, meta.k, meta.n, meta.bits, meta.variant),
                    meta.name.clone(),
                );
            }
            anyhow::ensure!(
                reg.by_name.insert(meta.name.clone(), meta).is_none(),
                "duplicate artifact name on line {}",
                lineno + 1
            );
        }
        Ok(reg)
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.by_name.get(name)
    }

    /// Find the f32 matmul executable matching a shape/precision, if
    /// one was exported.
    pub fn find_matmul(&self, m: usize, k: usize, n: usize, bits: u32, variant: MacVariant) -> Option<&ArtifactMeta> {
        self.matmuls
            .get(&(m, k, n, bits, variant))
            .and_then(|n2| self.by_name.get(n2))
    }

    pub fn len(&self) -> usize {
        self.by_name.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_name.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &ArtifactMeta> {
        self.by_name.values()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
mm_booth_b8_8x64x64 matmul booth 8 8 64 64 f32 mm_booth_b8_8x64x64.hlo.txt
mlp_8 mlp booth 8 8 64 10 f32 mlp_8.hlo.txt
# a comment

mm_booth_b16_8x64x64_exact matmul booth 16 8 64 64 f64 exact.hlo.txt
";

    #[test]
    fn parses_manifest() {
        let reg = Registry::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(reg.len(), 3);
        let m = reg.get("mlp_8").unwrap();
        assert_eq!(m.kind, ArtifactKind::Mlp);
        assert_eq!(m.path, Path::new("/tmp/a/mlp_8.hlo.txt"));
    }

    #[test]
    fn matmul_lookup_by_shape() {
        let reg = Registry::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        let hit = reg.find_matmul(8, 64, 64, 8, MacVariant::Booth);
        assert_eq!(hit.unwrap().name, "mm_booth_b8_8x64x64");
        assert!(reg.find_matmul(8, 64, 64, 4, MacVariant::Booth).is_none());
        // f64 artifacts are not offered for the fast path
        assert!(reg.find_matmul(8, 64, 64, 16, MacVariant::Booth).is_none());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Registry::parse("too few fields", Path::new("/")).is_err());
        let dup = "a matmul booth 8 1 1 1 f32 p\na matmul booth 8 1 1 1 f32 p\n";
        assert!(Registry::parse(dup, Path::new("/")).is_err());
    }
}
