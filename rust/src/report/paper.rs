//! Renderers that regenerate the paper's tables and figures from the
//! models — shared by the launcher (`bitsmm tables|fig6`) and the
//! bench targets.

use crate::arch::asic::AsicModel;
use crate::arch::fpga::FpgaModel;
use crate::arch::pdk::PdkKind;
use crate::arch::throughput::fig6_series;
use crate::baselines::table4_published;
use crate::report::{ascii_plot, f, Table};
use crate::sim::array::SaConfig;
use crate::sim::mac_common::MacVariant;

/// Table II: FPGA implementation results at 300 MHz.
pub fn render_table2() -> String {
    let model = FpgaModel::default();
    let mut t = Table::new(
        "Table II — AMD ZCU104 FPGA @ 300 MHz (modelled; paper values in brackets)",
        &["Design", "LUTs", "FFs", "Power (W)", "GOPS", "GOPS/W"],
    );
    let paper: [(&str, u64, u64, f64, f64, f64); 4] = [
        ("16x4", 5630, 8762, 1.13, 1.2, 1.062),
        ("16x4 SBMwC", 11418, 10807, 1.657, 1.2, 0.724),
        ("32x8", 29355, 35490, 2.125, 4.8, 2.259),
        ("64x16", 117836, 155586, 6.459, 19.2, 2.973),
    ];
    for (row, p) in model.table2_rows().iter().zip(paper) {
        t.row(&[
            p.0.to_string(),
            format!("{} [{}]", row.luts, p.1),
            format!("{} [{}]", row.ffs, p.2),
            format!("{} [{}]", f(row.power_w), p.3),
            format!("{} [{}]", f(row.gops), p.4),
            format!("{} [{}]", f(row.gops_per_w), p.5),
        ]);
    }
    t.render()
}

/// Table III: ASIC physical implementation results.
pub fn render_table3() -> String {
    let mut out = String::new();
    for kind in [PdkKind::Asap7, PdkKind::Nangate45] {
        let model = AsicModel::new(kind);
        let mut t = Table::new(
            &format!("Table III — {} (modelled)", kind.name()),
            &[
                "Design",
                "MaxF (MHz)",
                "Area (mm2)",
                "Power (W)",
                "Peak GOPS",
                "GOPS@tgt",
                "GOPS/mm2",
                "GOPS/W",
            ],
        );
        for row in model.table3_rows() {
            let label = match row.config.variant {
                MacVariant::Booth => row.config.label(),
                MacVariant::Sbmwc => format!("{} SBMwC", row.config.label()),
            };
            t.row(&[
                label,
                f(row.max_freq_mhz),
                format!("{:.3}", row.area_mm2),
                f(row.power_w),
                f(row.peak_gops_at_fmax),
                f(row.gops_at_target),
                f(row.gops_per_mm2),
                f(row.gops_per_w),
            ]);
        }
        out.push_str(&t.render());
    }
    out
}

/// Table IV: comparison with published SOTA numbers.
pub fn render_table4() -> String {
    let fpga = FpgaModel::default();
    let ours_fpga = fpga.implement(SaConfig::new(16, 64, MacVariant::Booth), 16);
    let asic = AsicModel::new(PdkKind::Asap7);
    let ours_asic = asic.implement(SaConfig::new(16, 64, MacVariant::Booth), 16);
    let published = table4_published();

    let mut t = Table::new(
        "Table IV — comparison with SOTA (16-bit-equivalent)",
        &["Design", "Platform", "GOPS", "GOPS/W"],
    );
    t.row(&[
        published[0].design.into(),
        published[0].platform.into(),
        f(published[0].gops_16b),
        f(published[0].gops_per_w),
    ]);
    t.row(&[
        "Ours (64x16)".into(),
        "ZU7EV on ZCU104".into(),
        f(ours_fpga.gops),
        f(ours_fpga.gops_per_w),
    ]);
    t.row(&[
        published[1].design.into(),
        published[1].platform.into(),
        f(published[1].gops_16b),
        f(published[1].gops_per_w),
    ]);
    t.row(&[
        "Ours (64x16)".into(),
        "asap7 (7nm)".into(),
        f(ours_asic.peak_gops_at_fmax),
        f(ours_asic.gops_per_w),
    ]);
    let mut s = t.render();
    s.push_str(&format!(
        "area efficiency: FSSA {} GOPS/mm2 vs ours {} GOPS/mm2 (asap7)\n",
        f(published[1].gops_per_mm2.unwrap()),
        f(ours_asic.gops_per_mm2)
    ));
    s
}

/// Fig. 6: peak OP/cycle vs operand bit width for the three topologies.
pub fn render_fig6() -> String {
    let topologies = [(16u64, 4u64), (32, 8), (64, 16)];
    let series: Vec<(String, Vec<(f64, f64)>)> = topologies
        .iter()
        .map(|&(c, r)| {
            (
                format!("{c}x{r}"),
                fig6_series(c, r, 1..=16)
                    .into_iter()
                    .map(|(b, v)| (b as f64, v))
                    .collect(),
            )
        })
        .collect();
    let refs: Vec<(&str, &[(f64, f64)])> = series
        .iter()
        .map(|(n, s)| (n.as_str(), s.as_slice()))
        .collect();
    let mut out = ascii_plot(
        "Fig. 6 — peak throughput (OP/cycle) vs operand bit width (eq. 10)",
        &refs,
        16,
    );
    // also emit the exact series, paper-style
    let mut t = Table::new("Fig. 6 data", &["bits", "16x4", "32x8", "64x16"]);
    for b in 1..=16u32 {
        t.row(&[
            b.to_string(),
            f(64.0 / b as f64),
            f(256.0 / b as f64),
            f(1024.0 / b as f64),
        ]);
    }
    out.push_str(&t.render());
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn renders_contain_headline_numbers() {
        let t2 = super::render_table2();
        assert!(t2.contains("19.20"));
        let t3 = super::render_table3();
        assert!(t3.contains("asap7"));
        assert!(t3.contains("nangate45"));
        let t4 = super::render_table4();
        assert!(t4.contains("BISMO"));
        assert!(t4.contains("FSSA"));
        let f6 = super::render_fig6();
        assert!(f6.contains("1024"));
    }
}
