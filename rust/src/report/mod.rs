//! Paper-style table/figure rendering for the bench harness: plain
//! monospace tables matching the paper's rows, and ASCII series plots
//! for the figures.

/// A simple text table with column alignment.
#[derive(Debug, Clone, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!(" {:<width$} ", c, width = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = format!("== {} ==\n", self.title);
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }
}

/// Format a float with engineering-style compactness.
pub fn f(v: f64) -> String {
    if v == 0.0 {
        return "0".into();
    }
    let a = v.abs();
    if a >= 100.0 {
        format!("{v:.1}")
    } else if a >= 1.0 {
        format!("{v:.2}")
    } else {
        format!("{v:.3}")
    }
}

/// ASCII plot of one or more (x, y) series — stands in for the paper's
/// figures in terminal output. Log-y is used when the dynamic range is
/// wide (Fig. 6 spans 4→1024 OP/cycle).
pub fn ascii_plot(title: &str, series: &[(&str, &[(f64, f64)])], height: usize) -> String {
    assert!(height >= 4);
    let all: Vec<(f64, f64)> = series.iter().flat_map(|(_, s)| s.iter().copied()).collect();
    if all.is_empty() {
        return format!("== {title} == (no data)\n");
    }
    let (xmin, xmax) = all
        .iter()
        .fold((f64::MAX, f64::MIN), |(lo, hi), p| (lo.min(p.0), hi.max(p.0)));
    let (ymin, ymax) = all
        .iter()
        .fold((f64::MAX, f64::MIN), |(lo, hi), p| (lo.min(p.1), hi.max(p.1)));
    let log_y = ymin > 0.0 && ymax / ymin > 50.0;
    let (ty_min, ty_max) = if log_y {
        (ymin.ln(), ymax.ln())
    } else {
        (ymin, ymax)
    };
    let width = 64usize;
    let mut grid = vec![vec![' '; width]; height];
    let marks = ['*', '+', 'o', 'x', '#'];
    for (si, (_, pts)) in series.iter().enumerate() {
        for &(x, y) in pts.iter() {
            let tx = if xmax > xmin {
                (x - xmin) / (xmax - xmin)
            } else {
                0.5
            };
            let ty_val = if log_y { y.ln() } else { y };
            let ty = if ty_max > ty_min {
                (ty_val - ty_min) / (ty_max - ty_min)
            } else {
                0.5
            };
            let col = (tx * (width - 1) as f64).round() as usize;
            let row = height - 1 - (ty * (height - 1) as f64).round() as usize;
            grid[row][col] = marks[si % marks.len()];
        }
    }
    let mut out = format!(
        "== {title} ==  (y: {}..{}{}; x: {}..{})\n",
        f(ymin),
        f(ymax),
        if log_y { ", log scale" } else { "" },
        f(xmin),
        f(xmax)
    );
    for row in grid {
        out.push('|');
        out.extend(row);
        out.push('\n');
    }
    out.push('+');
    out.push_str(&"-".repeat(width));
    out.push('\n');
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!("  {} {}\n", marks[si % marks.len()], name));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["name", "value"]);
        t.row(&["short".into(), "1".into()]);
        t.row(&["a-much-longer-name".into(), "23456".into()]);
        let s = t.render();
        assert!(s.contains("== Demo =="));
        let lines: Vec<&str> = s.lines().collect();
        // all data lines equal width
        assert_eq!(lines[1].len(), lines[3].len());
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn float_formatting() {
        assert_eq!(f(0.0), "0");
        assert_eq!(f(1234.5), "1234.5");
        assert_eq!(f(19.2), "19.20");
        assert_eq!(f(0.724), "0.724");
    }

    #[test]
    fn plot_contains_series_marks() {
        let s1: Vec<(f64, f64)> = (1..=16).map(|b| (b as f64, 1024.0 / b as f64)).collect();
        let s2: Vec<(f64, f64)> = (1..=16).map(|b| (b as f64, 64.0 / b as f64)).collect();
        let p = ascii_plot("Fig6", &[("64x16", &s1), ("16x4", &s2)], 12);
        assert!(p.contains('*') && p.contains('+'));
        assert!(p.contains("log scale"));
    }
}

pub mod paper;
