//! Minimal property-testing framework with shrinking.
//!
//! Substrate built in-repo (offline environment — `proptest` is not
//! available; see DESIGN.md substitution table). Provides the pieces the
//! test suites need: value generators, a `forall` runner that reports
//! the failing case, and greedy shrinking toward structurally smaller
//! counterexamples.
//!
//! ```no_run
//! // (no_run: the doctest runner lacks the xla rpath of regular test
//! // binaries; the same behaviour is covered by unit tests below)
//! use bitsmm::proptest_lite::{forall, Gen};
//! forall("add commutes", 256, Gen::pair(Gen::i32s(-100, 100), Gen::i32s(-100, 100)),
//!        |&(a, b)| a + b == b + a);
//! ```

use crate::prng::Pcg32;

/// A generator of values of type `T` plus a shrinker.
pub struct Gen<T> {
    gen: Box<dyn Fn(&mut Pcg32) -> T>,
    shrink: Box<dyn Fn(&T) -> Vec<T>>,
}

impl<T: Clone + 'static> Gen<T> {
    pub fn new(
        gen: impl Fn(&mut Pcg32) -> T + 'static,
        shrink: impl Fn(&T) -> Vec<T> + 'static,
    ) -> Self {
        Gen {
            gen: Box::new(gen),
            shrink: Box::new(shrink),
        }
    }

    pub fn sample(&self, rng: &mut Pcg32) -> T {
        (self.gen)(rng)
    }

    pub fn shrinks(&self, v: &T) -> Vec<T> {
        (self.shrink)(v)
    }

    /// Map the generated value (loses shrinking through the map unless
    /// the mapping is monotone in the shrink order, which is typical).
    pub fn map<U: Clone + 'static>(self, f: impl Fn(T) -> U + Clone + 'static) -> Gen<U> {
        let f2 = f.clone();
        let inner_shrink = self.shrink;
        let inner_gen = self.gen;
        // keep shrinking by regenerating from shrunk inputs is not
        // possible generically; shrink through the original domain.
        let _ = &inner_shrink;
        Gen {
            gen: Box::new(move |rng| f(inner_gen(rng))),
            shrink: Box::new(move |_v| {
                let _ = &f2;
                Vec::new()
            }),
        }
    }
}

impl Gen<i32> {
    /// Uniform i32 in `[lo, hi]`, shrinking toward 0 (or the bound
    /// nearest 0).
    pub fn i32s(lo: i32, hi: i32) -> Gen<i32> {
        let target = 0i32.clamp(lo, hi);
        Gen::new(
            move |rng| rng.range_i32(lo, hi),
            move |&v| {
                let mut out = Vec::new();
                if v != target {
                    out.push(target);
                    let mid = target + (v - target) / 2;
                    if mid != v && mid != target {
                        out.push(mid);
                    }
                    if (v - target).abs() > 1 {
                        out.push(v - (v - target).signum());
                    }
                }
                out
            },
        )
    }
}

impl Gen<u32> {
    /// Uniform u32 in `[lo, hi]`, shrinking toward `lo`.
    pub fn u32s(lo: u32, hi: u32) -> Gen<u32> {
        Gen::new(
            move |rng| {
                let span = hi - lo; // inclusive; handle the full range
                if span == u32::MAX {
                    rng.next_u32()
                } else {
                    lo + rng.below(span + 1)
                }
            },
            move |&v| {
                let mut out = Vec::new();
                if v > lo {
                    out.push(lo);
                    let mid = lo + (v - lo) / 2;
                    if mid != v {
                        out.push(mid);
                    }
                    out.push(v - 1);
                }
                out.dedup();
                out
            },
        )
    }
}

impl<T: Clone + 'static> Gen<Vec<T>> {
    /// Vector with length in `[min_len, max_len]`, elements from `elem`.
    /// Shrinks by halving length, dropping single elements, and
    /// shrinking individual elements.
    pub fn vecs(elem: Gen<T>, min_len: usize, max_len: usize) -> Gen<Vec<T>> {
        let elem = std::rc::Rc::new(elem);
        let e1 = elem.clone();
        Gen::new(
            move |rng| {
                let len = min_len + rng.below_usize(max_len - min_len + 1);
                (0..len).map(|_| e1.sample(rng)).collect()
            },
            move |v: &Vec<T>| {
                let mut out: Vec<Vec<T>> = Vec::new();
                if v.len() > min_len {
                    // halve
                    out.push(v[..(v.len() / 2).max(min_len)].to_vec());
                    // drop last
                    out.push(v[..v.len() - 1].to_vec());
                }
                // shrink one element (first few positions only, for speed)
                for i in 0..v.len().min(4) {
                    for s in elem.shrinks(&v[i]) {
                        let mut w = v.clone();
                        w[i] = s;
                        out.push(w);
                    }
                }
                out
            },
        )
    }
}

/// Pair generator combinator.
impl<A: Clone + 'static, B: Clone + 'static> Gen<(A, B)> {
    pub fn pair(a: Gen<A>, b: Gen<B>) -> Gen<(A, B)> {
        let (a, b) = (std::rc::Rc::new(a), std::rc::Rc::new(b));
        let (a1, b1) = (a.clone(), b.clone());
        Gen::new(
            move |rng| (a1.sample(rng), b1.sample(rng)),
            move |(x, y)| {
                let mut out: Vec<(A, B)> = a.shrinks(x).into_iter().map(|x2| (x2, y.clone())).collect();
                out.extend(b.shrinks(y).into_iter().map(|y2| (x.clone(), y2)));
                out
            },
        )
    }
}

/// Run `prop` on `cases` random samples from `gen`; on failure, shrink
/// greedily and panic with the minimal counterexample found.
///
/// The seed is derived from the property name so failures are
/// reproducible run-to-run but distinct across properties.
pub fn forall<T: Clone + std::fmt::Debug + 'static>(
    name: &str,
    cases: u32,
    gen: Gen<T>,
    prop: impl Fn(&T) -> bool,
) {
    let seed = name
        .bytes()
        .fold(0xcbf29ce484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3));
    let mut rng = Pcg32::new(seed);
    for case in 0..cases {
        let v = gen.sample(&mut rng);
        if !prop(&v) {
            let minimal = shrink_loop(&gen, v, &prop);
            panic!("property '{name}' failed at case {case}: minimal counterexample = {minimal:?}");
        }
    }
}

fn shrink_loop<T: Clone + 'static>(gen: &Gen<T>, mut v: T, prop: &impl Fn(&T) -> bool) -> T {
    // Greedy descent: take the first shrink that still fails, up to a
    // bounded number of rounds.
    for _ in 0..200 {
        let mut advanced = false;
        for cand in gen.shrinks(&v) {
            if !prop(&cand) {
                v = cand;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("i32 add commutes", 200, Gen::pair(Gen::i32s(-50, 50), Gen::i32s(-50, 50)), |&(a, b)| {
            a + b == b + a
        });
    }

    #[test]
    fn failing_property_shrinks() {
        let r = std::panic::catch_unwind(|| {
            forall("all i32 below 10", 500, Gen::i32s(0, 100), |&v| v < 10)
        });
        let msg = match r {
            Err(e) => *e.downcast::<String>().unwrap(),
            Ok(_) => panic!("property should have failed"),
        };
        // greedy shrink should land on exactly the boundary value 10
        assert!(msg.contains("= 10"), "unexpected shrink result: {msg}");
    }

    #[test]
    fn vec_generator_respects_bounds() {
        forall(
            "vec len bounds",
            200,
            Gen::vecs(Gen::i32s(-5, 5), 1, 17),
            |v| (1..=17).contains(&v.len()) && v.iter().all(|x| (-5..=5).contains(x)),
        );
    }

    #[test]
    fn u32_shrinks_toward_lo() {
        let g = Gen::u32s(3, 100);
        let s = g.shrinks(&50);
        assert!(s.contains(&3));
    }
}
