//! BISMO computation model (Umuroglu et al. [33], [34]; §II-D).
//!
//! BISMO decomposes multiplication into bitwise products between
//! multiplicand and multiplier bits: each pair `(mc[i], ml[j])` is
//! ANDed and shifted by `i + j`. Without parallelism this needs
//! `b_mc × b_ml × n` cycles per dot product (the paper's eq. 6). BISMO
//! recovers throughput with *intra-MAC* parallelism: `dk` operand pairs
//! are processed simultaneously and a population counter accumulates
//! the AND results, so effective cycles divide by `dk`.
//!
//! Key contrast the paper draws: BISMO supports *asymmetric* operand
//! widths natively (cycles scale with the product `b_mc·b_ml`), while
//! bitSMM extends both operands to `b_max` but scales linearly.

use super::SerialDotModel;
use crate::arch::throughput::bismo_cycles;

/// BISMO model with configurable intra-MAC parallelism.
#[derive(Debug, Clone)]
pub struct Bismo {
    /// Operand pairs processed per MAC per cycle (population-counter
    /// width). 1 = the pure serial model of eq. 6.
    pub dk: u64,
}

impl Bismo {
    pub fn serial() -> Self {
        Bismo { dk: 1 }
    }

    /// The FPGA-optimized configuration of [34] processes whole 64-bit
    /// words of packed bits per cycle.
    pub fn optimized() -> Self {
        Bismo { dk: 64 }
    }

    /// Cycles for an m×k×n matmul on a `pe` processing-element overlay
    /// (each PE handles one output dot product at a time).
    pub fn matmul_cycles(&self, m: u64, k: u64, n: u64, b_mc: u32, b_ml: u32, pe: u64) -> u64 {
        let dots = m * n;
        let per_dot = self.dot_cycles(b_mc, b_ml, k);
        (dots * per_dot).div_ceil(pe)
    }
}

impl SerialDotModel for Bismo {
    fn name(&self) -> &'static str {
        "bismo"
    }

    fn dot_cycles(&self, b_mc: u32, b_ml: u32, n_values: u64) -> u64 {
        bismo_cycles(b_mc as u64, b_ml as u64, n_values).div_ceil(self.dk)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq6_serial_case() {
        // 2-bit × 2-bit over 10 values: 2·2·10 = 40 cycles
        assert_eq!(Bismo::serial().dot_cycles(2, 2, 10), 40);
    }

    #[test]
    fn asymmetric_widths_scale_with_product() {
        let b = Bismo::serial();
        assert_eq!(b.dot_cycles(1, 16, 100), 1600);
        assert_eq!(b.dot_cycles(16, 16, 100), 25600);
    }

    #[test]
    fn intra_mac_parallelism_divides() {
        assert_eq!(Bismo::optimized().dot_cycles(16, 16, 100), 400);
    }

    #[test]
    fn matmul_distributes_over_pes() {
        let b = Bismo::serial();
        // 4×10×4 at 2 bits on 16 PEs: 16 dots × 40 cycles / 16
        assert_eq!(b.matmul_cycles(4, 10, 4, 2, 2, 16), 40);
    }
}
