//! Loom computation model (Sharify et al. [31]; §II-D).
//!
//! Fully bit-serial on both operands, like BISMO's decomposition
//! (eq. 6), but with *spatial* parallelism: one bit from each of 16
//! activations and one bit from each of 16 weights stream into each MAC
//! concurrently, so a MAC covers a 16-element slice of the dot product
//! per `b_mc × b_ml` bit-pair sweep.

use super::SerialDotModel;

/// Loom model.
#[derive(Debug, Clone)]
pub struct Loom {
    /// Operand-pair group size streamed concurrently per MAC (16 in
    /// the paper).
    pub group: u64,
}

impl Default for Loom {
    fn default() -> Self {
        Loom { group: 16 }
    }
}

impl SerialDotModel for Loom {
    fn name(&self) -> &'static str {
        "loom"
    }

    fn dot_cycles(&self, b_mc: u32, b_ml: u32, n_values: u64) -> u64 {
        // groups of `group` values, each needing a full bit-pair sweep
        n_values.div_ceil(self.group) * (b_mc as u64) * (b_ml as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn group_parallelism() {
        let l = Loom::default();
        // 16 values, 8×8 bits: one sweep = 64 cycles
        assert_eq!(l.dot_cycles(8, 8, 16), 64);
        // 17 values: two sweeps
        assert_eq!(l.dot_cycles(8, 8, 17), 128);
    }

    #[test]
    fn degenerates_to_eq6_with_group_1() {
        let l = Loom { group: 1 };
        assert_eq!(
            l.dot_cycles(5, 7, 100),
            crate::arch::throughput::bismo_cycles(5, 7, 100)
        );
    }
}
