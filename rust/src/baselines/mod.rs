//! Cycle/throughput models of the comparator designs (paper §II-D and
//! Table IV).
//!
//! The paper compares bitSMM against prior bit-serial accelerators by
//! converting their published numbers to a common 16-bit-operand
//! convention ("a single 16-bit-by-16-bit multiplication requires
//! 16 × 16 = 256 binary operations in these models"). This module
//! implements (a) the *computation models* of each prior design —
//! their cycle formulas, so the eq.6-vs-eq.8 crossover and scaling
//! benches can sweep them — and (b) the *published datapoints* Table IV
//! quotes, as constants with provenance.

pub mod bismo;
pub mod fssa;
pub mod loom;
pub mod stripes;

pub use bismo::Bismo;
pub use fssa::Fssa;
pub use loom::Loom;
pub use stripes::Stripes;

/// A published comparison point as quoted in Table IV.
#[derive(Debug, Clone)]
pub struct SotaPoint {
    pub design: &'static str,
    pub platform: &'static str,
    /// 16-bit-equivalent GOPS.
    pub gops_16b: f64,
    pub gops_per_w: f64,
    /// GOPS/mm² where reported (§IV-B prose, FSSA vs ours).
    pub gops_per_mm2: Option<f64>,
}

/// The rows of Table IV that quote *other* papers (our own rows are
/// produced live by the arch models / simulator).
pub fn table4_published() -> Vec<SotaPoint> {
    vec![
        SotaPoint {
            design: "Opt. BISMO [34]",
            platform: "ZU3EG on Ultra96",
            gops_16b: 60.0,
            gops_per_w: 8.33,
            gops_per_mm2: None,
        },
        SotaPoint {
            design: "FSSA [37]",
            platform: "28nm technology",
            gops_16b: 25.75,
            gops_per_w: 258.0,
            gops_per_mm2: Some(40.86),
        },
    ]
}

/// Convert a binary-operations-per-second figure (the BISMO/FSSA
/// reporting convention) to 16-bit-equivalent OPS: one 16×16-bit
/// multiply = 256 binary ops.
pub fn binary_ops_to_16b(binary_ops: f64) -> f64 {
    binary_ops / 256.0
}

/// Common interface: cycles to compute a vector dot product of
/// `n_values` elements at the given operand widths, *without* intra-MAC
/// parallelism — the apples-to-apples latency comparison of §III-A.
pub trait SerialDotModel {
    fn name(&self) -> &'static str;
    fn dot_cycles(&self, b_mc: u32, b_ml: u32, n_values: u64) -> u64;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversion_convention() {
        // 256 binary GOPS ≡ 1 GOPS at 16 bit
        assert_eq!(binary_ops_to_16b(256e9), 1e9);
    }

    #[test]
    fn table4_rows_present() {
        let rows = table4_published();
        assert_eq!(rows.len(), 2);
        assert!(rows[0].design.contains("BISMO"));
        assert!(rows[1].gops_per_mm2.is_some());
    }
}
