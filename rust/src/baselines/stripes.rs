//! Stripes computation model (Judd et al. [28]; §II-D).
//!
//! Serial–parallel multiplication: the multiplier (activation) streams
//! bit-serially while the multiplicand (weight) is stored and supplied
//! in 16-bit parallel form. A dot product therefore takes
//! `b_ml × n` cycles — independent of the weight precision, which is
//! fixed at the parallel width. Dynamic Stripes [29] adapts `b_ml` at
//! runtime to the activations' actual precision needs; we model that as
//! a per-call effective width.

use super::SerialDotModel;

/// Stripes model.
#[derive(Debug, Clone)]
pub struct Stripes {
    /// Parallel weight width (16 in the paper).
    pub weight_bits: u32,
}

impl Default for Stripes {
    fn default() -> Self {
        Stripes { weight_bits: 16 }
    }
}

impl Stripes {
    /// Dynamic-Stripes effective activation width: the minimum width
    /// that covers the largest-magnitude activation in the group.
    pub fn dynamic_effective_bits(activations: &[i32]) -> u32 {
        activations
            .iter()
            .map(|&a| {
                // smallest two's-complement width that holds `a`
                let mut w = 1u32;
                while !(crate::bits::twos::min_value(w) <= a && a <= crate::bits::twos::max_value(w)) {
                    w += 1;
                }
                w
            })
            .max()
            .unwrap_or(1)
    }
}

impl SerialDotModel for Stripes {
    fn name(&self) -> &'static str {
        "stripes"
    }

    /// `b_mc` is ignored: weights are bit-parallel at `weight_bits`.
    fn dot_cycles(&self, _b_mc: u32, b_ml: u32, n_values: u64) -> u64 {
        b_ml as u64 * n_values
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycles_independent_of_weight_precision() {
        let s = Stripes::default();
        assert_eq!(s.dot_cycles(1, 8, 100), s.dot_cycles(16, 8, 100));
        assert_eq!(s.dot_cycles(16, 8, 100), 800);
    }

    #[test]
    fn dynamic_width_tracks_magnitudes() {
        assert_eq!(Stripes::dynamic_effective_bits(&[0]), 1);
        assert_eq!(Stripes::dynamic_effective_bits(&[-1, 0]), 1);
        assert_eq!(Stripes::dynamic_effective_bits(&[1]), 2); // +1 needs 2 bits
        assert_eq!(Stripes::dynamic_effective_bits(&[7, -8]), 4);
        assert_eq!(Stripes::dynamic_effective_bits(&[127]), 8);
        assert_eq!(Stripes::dynamic_effective_bits(&[-32768]), 16);
    }
}
