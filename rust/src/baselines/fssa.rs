//! FSSA computation model (Moghaddasi & Nam [37]; §II-D).
//!
//! A fully serial, mixed-precision systolic array for vision
//! transformers: weights are preloaded onto the SA (weight-stationary),
//! each PE multiplies one activation bit with one weight bit, and an
//! accumulation unit reconstructs outputs. Cycle behaviour follows the
//! eq.6 family (bit-pair sweeps) with the array providing spatial
//! parallelism over output elements; the published efficiency figures
//! quoted in Table IV come from their 28 nm implementation.

use super::SerialDotModel;

/// FSSA model.
#[derive(Debug, Clone)]
pub struct Fssa {
    /// PE array extent (output elements computed concurrently).
    pub array_rows: u64,
    pub array_cols: u64,
}

impl Default for Fssa {
    fn default() -> Self {
        // representative edge configuration from [37]
        Fssa {
            array_rows: 16,
            array_cols: 16,
        }
    }
}

impl Fssa {
    /// Published 28 nm implementation numbers quoted by Table IV.
    pub const PUBLISHED_GOPS: f64 = 25.75;
    pub const PUBLISHED_GOPS_PER_W: f64 = 258.0;
    pub const PUBLISHED_GOPS_PER_MM2: f64 = 40.86;

    /// Cycles for an m×k×n matmul: weights preloaded, bit-pair sweep
    /// per k-slice, tiles of `array_rows × array_cols` outputs.
    pub fn matmul_cycles(&self, m: u64, k: u64, n: u64, b_act: u32, b_w: u32) -> u64 {
        let tiles = m.div_ceil(self.array_rows) * n.div_ceil(self.array_cols);
        let preload = b_w as u64; // weight bits shifted in serially
        tiles * (preload + self.dot_cycles(b_w, b_act, k))
    }
}

impl SerialDotModel for Fssa {
    fn name(&self) -> &'static str {
        "fssa"
    }

    fn dot_cycles(&self, b_mc: u32, b_ml: u32, n_values: u64) -> u64 {
        // one activation bit × one weight bit per PE per cycle
        (b_mc as u64) * (b_ml as u64) * n_values
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mixed_precision_scales_with_bit_product() {
        let f = Fssa::default();
        assert_eq!(f.dot_cycles(8, 4, 10), 320);
        assert_eq!(f.dot_cycles(4, 4, 10), 160);
    }

    #[test]
    fn matmul_tiles_over_array() {
        let f = Fssa::default();
        let one_tile = f.matmul_cycles(16, 32, 16, 8, 8);
        let four_tiles = f.matmul_cycles(32, 32, 32, 8, 8);
        assert_eq!(four_tiles, 4 * one_tile);
    }

    #[test]
    fn published_numbers_match_table4() {
        assert_eq!(Fssa::PUBLISHED_GOPS, 25.75);
        assert_eq!(Fssa::PUBLISHED_GOPS_PER_W, 258.0);
    }
}
