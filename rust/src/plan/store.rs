//! The persistent plan cache file: `configs/plans.json` (DESIGN.md
//! §Planner).
//!
//! The file is versioned and **host-fingerprinted**: a plan tuned on an
//! AVX2 x86 box encodes reducer and threading choices that are wrong on
//! a NEON or narrow machine, so a loader on a different host rejects
//! the whole file and falls back to the cost model instead of applying
//! foreign plans. Rejection is loud but non-fatal — the planner still
//! works, it just re-derives (or re-calibrates) plans locally.
//!
//! Offline environment: no `serde`/`serde_json` (DESIGN.md
//! substitutions), so this module carries a writer and a minimal JSON
//! reader for the subset the plan file uses (objects, arrays, strings,
//! integers, booleans).

use super::exec::{ExecPlan, Partition, PlanBackend};
use super::key::PlanKey;
use crate::bits::packed::{KernelFamily, PopcountKernel, TilePolicy};
use crate::bits::plane::PlaneKind;
use crate::Result;

/// Identify the plan-relevant host: architecture, the SIMD popcount
/// actually available at runtime, and the core count (thread choices
/// tuned for one width are wrong on another).
pub fn host_fingerprint() -> String {
    let simd = if PopcountKernel::Avx2.available() {
        "avx2"
    } else if PopcountKernel::Neon.available() {
        "neon"
    } else {
        "scalar"
    };
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    format!("{}/{simd}/c{cores}", std::env::consts::ARCH)
}

/// A versioned, fingerprinted set of `(PlanKey, ExecPlan)` entries.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanFile {
    pub version: u32,
    pub fingerprint: String,
    pub entries: Vec<(PlanKey, ExecPlan)>,
}

impl PlanFile {
    pub const VERSION: u32 = 1;

    /// A file stamped for *this* host.
    pub fn new(entries: Vec<(PlanKey, ExecPlan)>) -> PlanFile {
        PlanFile {
            version: Self::VERSION,
            fingerprint: host_fingerprint(),
            entries,
        }
    }

    /// Reject files another host (or another format version) wrote —
    /// the caller falls back to the cost model.
    pub fn check_host(&self) -> Result<()> {
        anyhow::ensure!(
            self.version == Self::VERSION,
            "plan file version {} (this build reads {})",
            self.version,
            Self::VERSION
        );
        let here = host_fingerprint();
        anyhow::ensure!(
            self.fingerprint == here,
            "plan file was tuned on '{}' but this host is '{here}' — refusing foreign plans",
            self.fingerprint
        );
        Ok(())
    }

    /// Render as JSON, one plan entry per line (diff-friendly).
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"version\": {},\n", self.version));
        s.push_str(&format!("  \"fingerprint\": \"{}\",\n", self.fingerprint));
        s.push_str("  \"plans\": [\n");
        let lines: Vec<String> = self
            .entries
            .iter()
            .map(|(k, p)| {
                let seg_words = match p.family {
                    KernelFamily::Rsr { seg_words } => seg_words,
                    KernelFamily::Popcount => 0,
                };
                format!(
                    "    {{\"mb\":{},\"kb\":{},\"nb\":{},\"ba\":{},\"bb\":{},\"kind\":\"{}\",\
\"backend\":\"{}\",\"kernel\":\"{}\",\"threads\":{},\"partition\":\"{}\",\
\"tile_rows\":{},\"tile_cols\":{},\"k_chunks\":{},\"family\":\"{}\",\"seg_words\":{}}}",
                    k.mb,
                    k.kb,
                    k.nb,
                    k.bits_a,
                    k.bits_b,
                    k.kind.name(),
                    p.backend.name(),
                    p.kernel.name(),
                    p.threads,
                    p.partition.name(),
                    p.tile.tile_rows,
                    p.tile.tile_cols,
                    p.tile.k_chunks,
                    p.family.name(),
                    seg_words
                )
            })
            .collect();
        s.push_str(&lines.join(",\n"));
        s.push_str("\n  ]\n}\n");
        s
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.render())?;
        Ok(())
    }

    /// Structural parse (no host check — `check_host` is separate so
    /// tests and tools can inspect foreign files).
    pub fn parse(text: &str) -> Result<PlanFile> {
        let root = Json::parse(text)?;
        let version = root.field("version")?.as_int()? as u32;
        let fingerprint = root.field("fingerprint")?.as_str()?.to_string();
        let mut entries = Vec::new();
        for (i, e) in root.field("plans")?.as_arr()?.iter().enumerate() {
            entries.push(
                parse_entry(e).map_err(|err| anyhow::anyhow!("plan entry {i}: {err}"))?,
            );
        }
        Ok(PlanFile {
            version,
            fingerprint,
            entries,
        })
    }

    pub fn load(path: &std::path::Path) -> Result<PlanFile> {
        PlanFile::parse(&std::fs::read_to_string(path)?)
    }
}

fn parse_kind(s: &str) -> Result<PlaneKind> {
    match s {
        "sbmwc" => Ok(PlaneKind::Sbmwc),
        "booth" => Ok(PlaneKind::Booth),
        other => anyhow::bail!("unknown plane kind '{other}' (sbmwc|booth)"),
    }
}

fn parse_entry(e: &Json) -> Result<(PlanKey, ExecPlan)> {
    let int = |name: &str| -> Result<i64> { e.field(name)?.as_int() };
    // Fields PR 6 added are optional with pre-PR-6 defaults, so plan
    // files written by older builds (same format version) still load.
    let int_or = |name: &str, default: i64| -> Result<i64> {
        match e.field(name) {
            Ok(v) => v.as_int(),
            Err(_) => Ok(default),
        }
    };
    let key = PlanKey {
        mb: u8::try_from(int("mb")?)?,
        kb: u8::try_from(int("kb")?)?,
        nb: u8::try_from(int("nb")?)?,
        bits_a: u8::try_from(int("ba")?)?,
        bits_b: u8::try_from(int("bb")?)?,
        kind: parse_kind(e.field("kind")?.as_str()?)?,
    };
    let backend: PlanBackend = e.field("backend")?.as_str()?.parse()?;
    let kernel: PopcountKernel = e.field("kernel")?.as_str()?.parse()?;
    let partition: Partition = e.field("partition")?.as_str()?.parse()?;
    let threads = u32::try_from(int("threads")?)?;
    let tile = TilePolicy {
        tile_rows: usize::try_from(int("tile_rows")?)?,
        tile_cols: usize::try_from(int("tile_cols")?)?,
        k_chunks: usize::try_from(int_or("k_chunks", 0)?)?,
    };
    let family = match e.field("family") {
        Ok(v) => v.as_str()?,
        Err(_) => "popcount",
    };
    let plan = match backend {
        PlanBackend::Native => ExecPlan::native(),
        PlanBackend::Device => ExecPlan::device(),
        PlanBackend::Packed => {
            let p = ExecPlan::packed(kernel, threads, partition, tile);
            match family {
                "popcount" => p,
                "rsr" => p.rsr(u32::try_from(int_or("seg_words", 0)?)?),
                other => anyhow::bail!("unknown kernel family '{other}' (popcount|rsr)"),
            }
        }
    };
    Ok((key, plan))
}

// ---------------------------------------------------------------------------
// Minimal JSON reader (offline: no serde_json)
// ---------------------------------------------------------------------------

/// The JSON subset the plan file, the bench logs, and the telemetry
/// snapshots use: objects, arrays, strings with basic escapes, i64
/// integers, finite f64 floats, booleans, and null. Plan files remain
/// integer-strict at the access layer: `as_int` rejects `Float`.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Obj(Vec<(String, Json)>),
    Arr(Vec<Json>),
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Null,
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            s: text.as_bytes(),
            i: 0,
        };
        let v = p.value()?;
        p.skip_ws();
        anyhow::ensure!(p.i == p.s.len(), "trailing garbage at byte {}", p.i);
        Ok(v)
    }

    pub fn field(&self, name: &str) -> Result<&Json> {
        match self {
            Json::Obj(fields) => fields
                .iter()
                .find(|(k, _)| k == name)
                .map(|(_, v)| v)
                .ok_or_else(|| anyhow::anyhow!("missing field '{name}'")),
            _ => anyhow::bail!("expected an object looking up '{name}'"),
        }
    }

    pub fn as_int(&self) -> Result<i64> {
        match self {
            Json::Int(i) => Ok(*i),
            other => anyhow::bail!("expected an integer, got {other:?}"),
        }
    }

    /// Numeric view: integers widen to f64, floats pass through.
    /// `Null` is *not* a number — callers that accept "finite or
    /// null" (telemetry snapshots) should check `is_null` first.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Int(i) => Ok(*i as f64),
            Json::Float(x) => Ok(*x),
            other => anyhow::bail!("expected a number, got {other:?}"),
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Render a float as a JSON value: non-finite values (which JSON
    /// cannot represent) become `null`; finite values use Rust's
    /// shortest round-trip representation, which always carries a
    /// '.' or 'e' so the reader keeps Int/Float apart.
    pub fn render_f64(v: f64) -> String {
        if v.is_finite() {
            format!("{v:?}")
        } else {
            "null".to_string()
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => anyhow::bail!("expected a string, got {other:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => anyhow::bail!("expected an array, got {other:?}"),
        }
    }
}

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while self.i < self.s.len() && self.s[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&mut self) -> Result<u8> {
        self.skip_ws();
        self.s
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, ch: u8) -> Result<()> {
        let got = self.peek()?;
        anyhow::ensure!(
            got == ch,
            "expected '{}' at byte {}, got '{}'",
            ch as char,
            self.i,
            got as char
        );
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' | b'f' => self.boolean(),
            b'n' => self.null(),
            b'-' | b'0'..=b'9' => self.number(),
            other => anyhow::bail!("unexpected '{}' at byte {}", other as char, self.i),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            let key = {
                self.skip_ws();
                self.string()?
            };
            self.expect(b':')?;
            fields.push((key, self.value()?));
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(fields));
                }
                other => anyhow::bail!("expected ',' or '}}', got '{}'", other as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                other => anyhow::bail!("expected ',' or ']', got '{}'", other as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        // collect raw bytes and decode once at the closing quote, so
        // multi-byte UTF-8 content round-trips instead of being
        // reassembled byte-by-byte into mojibake
        let mut out: Vec<u8> = Vec::new();
        loop {
            let ch = *self
                .s
                .get(self.i)
                .ok_or_else(|| anyhow::anyhow!("unterminated string"))?;
            self.i += 1;
            match ch {
                b'"' => return Ok(String::from_utf8(out)?),
                b'\\' => {
                    let esc = *self
                        .s
                        .get(self.i)
                        .ok_or_else(|| anyhow::anyhow!("unterminated escape"))?;
                    self.i += 1;
                    out.push(match esc {
                        b'"' => b'"',
                        b'\\' => b'\\',
                        b'/' => b'/',
                        b'n' => b'\n',
                        b't' => b'\t',
                        other => anyhow::bail!("unsupported escape '\\{}'", other as char),
                    });
                }
                other => out.push(other),
            }
        }
    }

    fn boolean(&mut self) -> Result<Json> {
        self.skip_ws();
        if self.s[self.i..].starts_with(b"true") {
            self.i += 4;
            Ok(Json::Bool(true))
        } else if self.s[self.i..].starts_with(b"false") {
            self.i += 5;
            Ok(Json::Bool(false))
        } else {
            anyhow::bail!("bad literal at byte {}", self.i)
        }
    }

    fn null(&mut self) -> Result<Json> {
        self.skip_ws();
        anyhow::ensure!(
            self.s[self.i..].starts_with(b"null"),
            "bad literal at byte {}",
            self.i
        );
        self.i += 4;
        Ok(Json::Null)
    }

    /// Parse a number. A bare integer stays `Json::Int`; the presence
    /// of a fraction or exponent makes it `Json::Float`, so plan-file
    /// entries (read back through `as_int`) stay integer-strict.
    fn number(&mut self) -> Result<Json> {
        self.skip_ws();
        let start = self.i;
        if self.s.get(self.i) == Some(&b'-') {
            self.i += 1;
        }
        while self.i < self.s.len() && self.s[self.i].is_ascii_digit() {
            self.i += 1;
        }
        let mut float = false;
        if self.s.get(self.i) == Some(&b'.') {
            float = true;
            self.i += 1;
            while self.i < self.s.len() && self.s[self.i].is_ascii_digit() {
                self.i += 1;
            }
        }
        if matches!(self.s.get(self.i), Some(&b'e') | Some(&b'E')) {
            float = true;
            self.i += 1;
            if matches!(self.s.get(self.i), Some(&b'+') | Some(&b'-')) {
                self.i += 1;
            }
            while self.i < self.s.len() && self.s[self.i].is_ascii_digit() {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.s[start..self.i])?;
        if float {
            let x = text
                .parse::<f64>()
                .map_err(|e| anyhow::anyhow!("bad float '{text}': {e}"))?;
            anyhow::ensure!(x.is_finite(), "non-finite float '{text}'");
            Ok(Json::Float(x))
        } else {
            Ok(Json::Int(text.parse::<i64>()?))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_entries() -> Vec<(PlanKey, ExecPlan)> {
        vec![
            (
                PlanKey::for_matmul(1, 512, 4096, 8, 8, PlaneKind::Sbmwc),
                ExecPlan::packed(
                    PopcountKernel::Unroll8,
                    9,
                    Partition::Stolen,
                    TilePolicy { tile_rows: 1, tile_cols: 0, ..TilePolicy::AUTO },
                ),
            ),
            (
                PlanKey::for_matmul(256, 256, 256, 16, 16, PlaneKind::Booth),
                ExecPlan::native(),
            ),
            (
                PlanKey::for_matmul(8, 64, 64, 4, 4, PlaneKind::Sbmwc),
                ExecPlan::packed(PopcountKernel::Scalar, 1, Partition::Serial, TilePolicy::AUTO),
            ),
            (
                PlanKey::for_matmul(64, 512, 64, 1, 1, PlaneKind::Sbmwc),
                ExecPlan::packed(PopcountKernel::Scalar, 1, Partition::Serial, TilePolicy::AUTO)
                    .rsr(2),
            ),
            (
                PlanKey::for_matmul(1, 8192, 512, 8, 8, PlaneKind::Booth),
                ExecPlan::packed(
                    PopcountKernel::Unroll8,
                    8,
                    Partition::Stolen,
                    TilePolicy { k_chunks: 4, ..TilePolicy::AUTO },
                ),
            ),
        ]
    }

    #[test]
    fn render_parse_roundtrip_is_exact() {
        let f = PlanFile::new(sample_entries());
        let g = PlanFile::parse(&f.render()).unwrap();
        assert_eq!(f, g);
        assert!(g.check_host().is_ok(), "same host accepts its own file");
    }

    #[test]
    fn save_load_roundtrip_on_disk() {
        let dir = std::env::temp_dir().join("bitsmm_plan_store_test");
        let path = dir.join("plans.json");
        let f = PlanFile::new(sample_entries());
        f.save(&path).unwrap();
        let g = PlanFile::load(&path).unwrap();
        assert_eq!(f, g);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn foreign_fingerprint_is_rejected() {
        let mut f = PlanFile::new(sample_entries());
        f.fingerprint = "alien-arch/avx512/c999".into();
        // still *parses* (tools can inspect it) …
        let g = PlanFile::parse(&f.render()).unwrap();
        assert_eq!(g.fingerprint, f.fingerprint);
        // … but the host check refuses to apply it
        let err = g.check_host().unwrap_err().to_string();
        assert!(err.contains("foreign"), "{err}");
    }

    #[test]
    fn version_mismatch_is_rejected() {
        let mut f = PlanFile::new(vec![]);
        f.version = 999;
        assert!(PlanFile::parse(&f.render()).unwrap().check_host().is_err());
    }

    #[test]
    fn malformed_files_error_with_context() {
        assert!(PlanFile::parse("").is_err());
        assert!(PlanFile::parse("{\"version\": 1}").is_err(), "missing fields");
        assert!(PlanFile::parse("{\"version\": 1, \"fingerprint\": \"x\", \"plans\": [{}]}")
            .unwrap_err()
            .to_string()
            .contains("plan entry 0"));
        // bad kernel name inside an entry
        let bad = PlanFile::new(sample_entries())
            .render()
            .replace("\"kernel\":\"scalar\"", "\"kernel\":\"simd9000\"");
        assert!(PlanFile::parse(&bad).is_err());
        // bad family name inside an entry
        let bad = PlanFile::new(sample_entries())
            .render()
            .replace("\"family\":\"rsr\"", "\"family\":\"oracle\"");
        assert!(PlanFile::parse(&bad).is_err());
    }

    #[test]
    fn pre_pr6_entries_parse_with_default_family_and_ksplit() {
        // An entry written before family/seg_words/k_chunks existed
        // (same format version) loads as popcount with no k-split.
        let old = format!(
            "{{\n  \"version\": 1,\n  \"fingerprint\": \"{}\",\n  \"plans\": [\n    \
{{\"mb\":0,\"kb\":9,\"nb\":12,\"ba\":8,\"bb\":8,\"kind\":\"sbmwc\",\"backend\":\"packed\",\
\"kernel\":\"scalar\",\"threads\":9,\"partition\":\"stolen\",\"tile_rows\":1,\"tile_cols\":0}}\n  ]\n}}\n",
            host_fingerprint()
        );
        let f = PlanFile::parse(&old).unwrap();
        assert!(f.check_host().is_ok());
        let (_, p) = &f.entries[0];
        assert_eq!(p.family, KernelFamily::Popcount);
        assert_eq!(p.tile.k_chunks, 0);
        assert_eq!(p.tile.tile_rows, 1);
    }

    #[test]
    fn rsr_and_ksplit_fields_roundtrip() {
        let f = PlanFile::new(sample_entries());
        let text = f.render();
        assert!(text.contains("\"family\":\"rsr\""), "{text}");
        assert!(text.contains("\"seg_words\":2"), "{text}");
        assert!(text.contains("\"k_chunks\":4"), "{text}");
        let g = PlanFile::parse(&text).unwrap();
        let rsr = g
            .entries
            .iter()
            .find(|(k, _)| k.bits_a == 1)
            .map(|(_, p)| p)
            .unwrap();
        assert_eq!(rsr.family, KernelFamily::Rsr { seg_words: 2 });
        let split = g
            .entries
            .iter()
            .find(|(_, p)| p.tile.k_chunks != 0)
            .map(|(_, p)| p)
            .unwrap();
        assert_eq!(split.tile.k_chunks, 4);
        assert_eq!(split.family, KernelFamily::Popcount);
    }

    #[test]
    fn json_reader_handles_the_subset() {
        let v = Json::parse(" {\"a\": [1, -2, true], \"s\": \"x\\\"y\"} ").unwrap();
        assert_eq!(v.field("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.field("a").unwrap().as_arr().unwrap()[1].as_int().unwrap(), -2);
        assert_eq!(v.field("s").unwrap().as_str().unwrap(), "x\"y");
        // multi-byte UTF-8 content round-trips, byte-exact
        let u = Json::parse("{\"fp\": \"café-box/neon/c2\"}").unwrap();
        assert_eq!(u.field("fp").unwrap().as_str().unwrap(), "café-box/neon/c2");
        assert!(Json::parse("{\"a\": 1,}").is_err(), "trailing comma");
        assert!(Json::parse("{\"a\": 1} garbage").is_err());
        assert!(Json::parse("[1, 2").is_err(), "unterminated array");
        // Floats and null (telemetry snapshots): a fraction or
        // exponent makes a Float; bare digits stay Int; as_int stays
        // integer-strict so plan files cannot silently carry floats.
        let w = Json::parse("{\"a\": 1.5, \"b\": -2.25e2, \"c\": 3, \"d\": null}").unwrap();
        assert_eq!(w.field("a").unwrap().as_f64().unwrap(), 1.5);
        assert_eq!(w.field("b").unwrap().as_f64().unwrap(), -225.0);
        assert_eq!(w.field("c").unwrap().as_int().unwrap(), 3);
        assert_eq!(w.field("c").unwrap().as_f64().unwrap(), 3.0);
        assert!(w.field("d").unwrap().is_null());
        assert!(w.field("a").unwrap().as_int().is_err(), "as_int rejects Float");
        assert!(Json::parse("1.").is_ok(), "trailing-dot float parses as 1.0");
        // The float writer round-trips through the reader, and maps
        // non-finite values to null (JSON has no inf/nan).
        assert_eq!(Json::render_f64(1.5), "1.5");
        assert_eq!(Json::parse(&Json::render_f64(0.1)).unwrap().as_f64().unwrap(), 0.1);
        assert_eq!(Json::render_f64(f64::INFINITY), "null");
        assert_eq!(Json::render_f64(f64::NAN), "null");
    }

    #[test]
    fn fingerprint_names_this_host() {
        let fp = host_fingerprint();
        assert!(fp.contains(std::env::consts::ARCH));
        assert!(fp.contains("/c"), "core count present: {fp}");
    }
}
