//! `bitsmm tune` — offline plan-cache tuning over the zoo-model shape
//! census (DESIGN.md §Planner).
//!
//! The tuner enumerates the matmul shapes the serving stack actually
//! submits (every zoo model at solo and fused batch sizes, under its
//! native per-layer precisions and under precision-policy overrides)
//! plus the skewed stress shapes `perf_hotpath` sweeps, calibrates the
//! candidate plans on each, and writes the winners to
//! `configs/plans.json` — a server started with `--planner static`
//! then serves every census shape from an exact plan hit without ever
//! benchmarking on the request path. `--smoke` shrinks shapes and
//! skips the precision-override sweep so CI finishes in seconds while
//! still exercising the full tune → save → load round trip.

use super::exec::ShapeRun;
use super::key::PlanKey;
use super::planner::{Planner, PlannerMode};
use super::ExecPlan;
use crate::bits::packed::{PackedPlanes, PackedPool};
use crate::bits::plane::PlaneKind;
use crate::coordinator::PrecisionPolicy;
use crate::nn::model::zoo_model;
use crate::prng::Pcg32;
use crate::report::Table;
use crate::Result;
use std::sync::Arc;

/// `bitsmm tune` options (parsed in `main.rs`).
#[derive(Debug, Clone)]
pub struct TuneOpts {
    /// Plan file to write.
    pub out: std::path::PathBuf,
    /// Packed-kernel pool threads for tuning (0 = all cores).
    pub threads: usize,
    /// CI budget: smaller shapes, no precision-override sweep.
    pub smoke: bool,
    /// Zoo models whose shape census to tune.
    pub models: Vec<String>,
    /// Operand seed for the synthetic calibration matrices.
    pub seed: u64,
}

impl Default for TuneOpts {
    fn default() -> TuneOpts {
        TuneOpts {
            out: std::path::PathBuf::from("configs/plans.json"),
            threads: 0,
            smoke: false,
            models: vec!["mlp".into(), "cnn".into(), "attn".into()],
            seed: 42,
        }
    }
}

/// Calibrate one shape class on synthetic operands and install the
/// winner: the shared path for `bitsmm tune` and the server's
/// warm-start pre-resolution (`PlannerMode::Online`). The stationary
/// operand is pre-packed outside the timed region — the layer-cache
/// steady state calibration should reflect. A class already cached is
/// returned as-is (no re-benchmark).
pub fn calibrate_shape(
    planner: &Planner,
    pool: Option<&Arc<PackedPool>>,
    m: usize,
    k: usize,
    n: usize,
    bits: u32,
    kind: PlaneKind,
    seed: u64,
) -> Result<ExecPlan> {
    let key = PlanKey::for_matmul(m, k, n, bits, bits, kind);
    if let Some(p) = planner.peek(&key) {
        return Ok(p);
    }
    let lo = crate::bits::twos::min_value(bits);
    let hi = crate::bits::twos::max_value(bits);
    let mut rng = Pcg32::new(seed ^ ((m as u64) << 40) ^ ((k as u64) << 20) ^ n as u64 ^ bits as u64);
    let a: Vec<i32> = (0..m * k).map(|_| rng.range_i32(lo, hi)).collect();
    // 1–2 bit stationary operands calibrate on codebook-redundant
    // columns — the repetition profile of real quantized weights that
    // the RSR family exploits. Uniform random columns are the RSR
    // worst case and would veto in calibration a kernel that wins in
    // production (DESIGN.md §Sub-popcount-Kernels).
    let b: Vec<i32> = if bits <= 2 {
        codebook_cols(&mut rng, k, n, lo, hi, 16)
    } else {
        (0..k * n).map(|_| rng.range_i32(lo, hi)).collect()
    };
    let pb = Arc::new(PackedPlanes::pack_cols(&b, k, n, bits, kind)?);
    let run = ShapeRun {
        a: &a,
        b: &b,
        m,
        k,
        n,
        bits,
        stream_kind: PlaneKind::Sbmwc,
        packed_b: Some(&pb),
        pool,
    };
    let (plan, _out) = planner.calibrate(key, &run)?;
    Ok(plan)
}

/// A row-major `k × n` stationary operand whose columns are drawn
/// from a codebook of at most `distinct` column patterns — the
/// redundancy real low-precision quantized weights exhibit.
pub fn codebook_cols(
    rng: &mut Pcg32,
    k: usize,
    n: usize,
    lo: i32,
    hi: i32,
    distinct: usize,
) -> Vec<i32> {
    let distinct = distinct.max(1);
    let book: Vec<Vec<i32>> = (0..distinct)
        .map(|_| (0..k).map(|_| rng.range_i32(lo, hi)).collect())
        .collect();
    let mut b = vec![0i32; k * n];
    for j in 0..n {
        let col = &book[rng.range_i32(0, distinct as i32 - 1) as usize];
        for (r, &v) in col.iter().enumerate() {
            b[r * n + j] = v;
        }
    }
    b
}

/// The matmul shape census of the named zoo models: solo and fused
/// batch sizes under native layer precisions, plus (full mode)
/// precision-policy overrides so precision re-planning has plans
/// ready before the first re-quantized request arrives.
pub fn zoo_shape_census(models: &[String], smoke: bool) -> Result<Vec<(usize, usize, usize, u32)>> {
    let batches: &[usize] = if smoke { &[1, 4] } else { &[1, 8] };
    let mut shapes = Vec::new();
    for name in models {
        let model = zoo_model(name, 1)?;
        for &b in batches {
            shapes.extend(model.matmul_shapes(b));
            if !smoke {
                for bits in [4u32, 12] {
                    shapes.extend(
                        PrecisionPolicy::Uniform(bits).shape_census(&model, b)?,
                    );
                }
            }
        }
    }
    shapes.sort_unstable();
    shapes.dedup();
    Ok(shapes)
}

/// The skewed stress shapes (the `perf_hotpath` §5c' set) at two
/// precisions straddling the native/packed crossover.
pub fn skewed_shape_census(smoke: bool) -> Vec<(usize, usize, usize, u32)> {
    let dims: &[(usize, usize, usize)] = if smoke {
        &[(1, 128, 512), (512, 128, 1), (32, 512, 32), (64, 64, 64)]
    } else {
        &[(1, 512, 4096), (4096, 512, 1), (64, 4096, 64), (256, 256, 256)]
    };
    let mut shapes = Vec::new();
    for &(m, k, n) in dims {
        for bits in [3u32, 8] {
            shapes.push((m, k, n, bits));
        }
    }
    // PR 6 regimes (perf_hotpath §5d/§5e): 1–2 bit classes where the
    // RSR family competes, and huge-k classes where the deterministic
    // k-split fans out across the pool.
    let low: &[(usize, usize, usize)] = if smoke {
        &[(64, 512, 64)]
    } else {
        &[(256, 256, 256), (64, 4096, 64)]
    };
    for &(m, k, n) in low {
        for bits in [1u32, 2] {
            shapes.push((m, k, n, bits));
        }
    }
    let hugek: &[(usize, usize, usize)] = if smoke {
        &[(1, 16384, 64)]
    } else {
        &[(1, 8192, 512), (16, 16384, 64)]
    };
    for &(m, k, n) in hugek {
        shapes.push((m, k, n, 8));
    }
    shapes
}

/// Run the tune sweep and write the plan file. Returns the number of
/// plans written.
pub fn run_tune(opts: &TuneOpts) -> Result<usize> {
    let threads = if opts.threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        opts.threads
    };
    let pool = if threads > 1 {
        Some(Arc::new(PackedPool::new(threads)?))
    } else {
        None
    };
    let slots = pool.as_ref().map_or(1, |p| p.threads() + 1);
    let planner = Planner::new(PlannerMode::Online, slots);

    let mut shapes = zoo_shape_census(&opts.models, opts.smoke)?;
    shapes.extend(skewed_shape_census(opts.smoke));
    shapes.sort_unstable();
    shapes.dedup();

    let mut t = Table::new(
        &format!(
            "tune: {} shapes, {slots} kernel slots{}",
            shapes.len(),
            if opts.smoke { " (smoke)" } else { "" }
        ),
        &["shape @bits", "shape class", "chosen plan"],
    );
    for &(m, k, n, bits) in &shapes {
        let plan = calibrate_shape(&planner, pool.as_ref(), m, k, n, bits, PlaneKind::Sbmwc, opts.seed)?;
        let key = PlanKey::for_matmul(m, k, n, bits, bits, PlaneKind::Sbmwc);
        t.row(&[format!("{m}x{k}x{n} @{bits}b"), format!("{key}"), plan.label()]);
    }
    print!("{}", t.render());

    let written = planner.save_file(&opts.out)?;
    let stats = planner.stats();
    println!(
        "wrote {written} plans to {} (fingerprint '{}', {} calibrations)",
        opts.out.display(),
        super::host_fingerprint(),
        stats.calibrations
    );
    Ok(written)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::driver::ref_matmul_i64;

    #[test]
    fn census_covers_every_zoo_model_and_dedups() {
        let models: Vec<String> = ["mlp", "cnn", "attn"].iter().map(|s| s.to_string()).collect();
        let shapes = zoo_shape_census(&models, true).unwrap();
        assert!(!shapes.is_empty());
        // mlp solo rows: 1x64x64 @8b; fused: 4x64x64 @8b
        assert!(shapes.contains(&(1, 64, 64, 8)), "{shapes:?}");
        assert!(shapes.contains(&(4, 64, 64, 8)));
        // cnn conv1 fused at batch 4: tall-thin 1024x9x8 @8b
        assert!(shapes.contains(&(4 * 256, 9, 8, 8)));
        // attention projections: 16x32x32 @8b (batch-independent)
        assert!(shapes.contains(&(16, 32, 32, 8)));
        // dedup
        let mut copy = shapes.clone();
        copy.dedup();
        assert_eq!(copy.len(), shapes.len());
        // the full census adds precision-override widths
        let full = zoo_shape_census(&models[..1], false).unwrap();
        assert!(full.contains(&(1, 64, 64, 4)), "uniform-4 override present");
        assert!(full.contains(&(1, 64, 64, 12)), "uniform-12 override present");
    }

    #[test]
    fn skewed_census_straddles_the_crossover() {
        let s = skewed_shape_census(true);
        assert!(s.contains(&(1, 128, 512, 8)) && s.contains(&(1, 128, 512, 3)));
        // PR 6: the RSR regime at 1–2 bits and one huge-k class ride
        // the smoke census, so `tune --smoke` calibrates (and the CI
        // grep can find) both new plan axes
        assert!(s.contains(&(64, 512, 64, 1)) && s.contains(&(64, 512, 64, 2)));
        assert!(s.contains(&(1, 16384, 64, 8)));
        assert_eq!(s.len(), 11);
        let f = skewed_shape_census(false);
        assert!(f.contains(&(256, 256, 256, 1)) && f.contains(&(64, 4096, 64, 2)));
        assert!(f.contains(&(1, 8192, 512, 8)) && f.contains(&(16, 16384, 64, 8)));
        assert_eq!(f.len(), 14);
    }

    #[test]
    fn codebook_cols_bound_distinct_columns() {
        let mut rng = Pcg32::new(0xc0de);
        let (k, n) = (64usize, 48usize);
        let b = codebook_cols(&mut rng, k, n, -1, 1, 4);
        assert_eq!(b.len(), k * n);
        assert!(b.iter().all(|&v| (-1..=1).contains(&v)));
        let mut cols: Vec<Vec<i32>> = (0..n)
            .map(|j| (0..k).map(|r| b[r * n + j]).collect())
            .collect();
        cols.sort();
        cols.dedup();
        assert!(cols.len() <= 4, "{} distinct columns from a 4-codebook", cols.len());
    }

    #[test]
    fn calibrate_shape_installs_and_is_idempotent() {
        let planner = Planner::new(PlannerMode::Online, 1);
        let p1 = calibrate_shape(&planner, None, 4, 64, 8, 6, PlaneKind::Sbmwc, 7).unwrap();
        assert_eq!(planner.stats().calibrations, 1);
        let p2 = calibrate_shape(&planner, None, 4, 64, 8, 6, PlaneKind::Sbmwc, 7).unwrap();
        assert_eq!(p1, p2);
        assert_eq!(planner.stats().calibrations, 1, "cached class never re-benchmarks");
        // and the installed plan is bit-transparent on a fresh shape
        let mut rng = Pcg32::new(0x7e57);
        let a: Vec<i32> = (0..4 * 64).map(|_| rng.range_i32(-32, 31)).collect();
        let b: Vec<i32> = (0..64 * 8).map(|_| rng.range_i32(-32, 31)).collect();
        let run = ShapeRun {
            a: &a,
            b: &b,
            m: 4,
            k: 64,
            n: 8,
            bits: 6,
            stream_kind: PlaneKind::Sbmwc,
            packed_b: None,
            pool: None,
        };
        let (out, _, _) = run.run(&p1).unwrap();
        assert_eq!(out, ref_matmul_i64(&a, &b, 4, 64, 8));
    }

    #[test]
    fn run_tune_smoke_writes_a_loadable_plan_file() {
        let dir = std::env::temp_dir().join("bitsmm_tune_smoke");
        let out = dir.join("plans.json");
        let opts = TuneOpts {
            out: out.clone(),
            threads: 2,
            smoke: true,
            models: vec!["mlp".into()],
            seed: 1,
        };
        let written = run_tune(&opts).unwrap();
        assert!(written > 0);
        // the emitted file round-trips into a fresh planner on this host
        let q = Planner::new(PlannerMode::Static, 3);
        assert_eq!(q.load_file(&out).unwrap(), written);
        std::fs::remove_file(&out).unwrap();
    }
}
