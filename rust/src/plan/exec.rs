//! Executable plans: what the planner chooses between, and the one
//! function that runs a plan (DESIGN.md §Planner).
//!
//! An [`ExecPlan`] is a point in the discrete configuration space the
//! serving stack accumulated across PRs 1–4: native-vs-packed backend,
//! popcount reducer, kernel thread intent, equal-slice vs work-stolen
//! partitioning, and 2-D tile policy. Every plan is **bit-transparent**
//! — all candidates compute the same integers (each leg is pinned to
//! the serial packed oracle and the native reference by the property
//! suite), so the planner is free to pick any of them purely on
//! measured or modelled speed.
//!
//! [`ShapeRun::run`] is the single execution path for a plan, shared by
//! the scheduler's request path, the planner's on-line calibration, the
//! `bitsmm tune` sweep, benches, and the property tests — so what gets
//! timed is exactly what gets served.

use super::cost;
use super::key::PlanKey;
use crate::bits::packed::{
    matmul_packed_rsr, matmul_packed_tile_rowslice, matmul_packed_tile_stolen,
    matmul_packed_tile_stolen_with, matmul_packed_tile_with, KernelFamily, PackedPlanes,
    PackedPool, PopcountKernel, StealStats, TilePolicy,
};
use crate::bits::plane::PlaneKind;
use crate::nn::matmul_native;
use crate::Result;
use std::sync::Arc;

/// Which functional engine a plan routes the matmul to. (The PJRT and
/// cycle-accurate backends are fidelity choices, not speed choices —
/// the planner only arbitrates the two host-speed engines.)
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PlanBackend {
    /// The dense i-k-j integer loop (`matmul_native`).
    Native,
    /// The word-packed plane-pair engine (`bits::packed`).
    Packed,
    /// The instruction-driven cycle-accurate device
    /// ([`crate::device::device_matmul`] on the paper's default 4×16
    /// Booth array). A fidelity choice, not a speed choice: nameable in
    /// plan files and runnable through the shared executor, but never
    /// offered by [`ExecPlan::candidates`] — the planner only
    /// arbitrates the host-speed engines.
    Device,
}

impl PlanBackend {
    pub fn name(self) -> &'static str {
        match self {
            PlanBackend::Native => "native",
            PlanBackend::Packed => "packed",
            PlanBackend::Device => "device",
        }
    }
}

impl std::str::FromStr for PlanBackend {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<PlanBackend> {
        match s {
            "native" => Ok(PlanBackend::Native),
            "packed" => Ok(PlanBackend::Packed),
            "device" => Ok(PlanBackend::Device),
            other => anyhow::bail!("unknown plan backend '{other}' (native|packed|device)"),
        }
    }
}

/// How a packed matmul is spread over the kernel pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Partition {
    /// Single-thread kernel — no pool dispatch at all.
    Serial,
    /// PR 2 equal row slices (`matmul_packed_tile_rowslice`).
    Rowslice,
    /// Work-stealing 2-D tiles (`matmul_packed_tile_stolen`).
    Stolen,
}

impl Partition {
    pub fn name(self) -> &'static str {
        match self {
            Partition::Serial => "serial",
            Partition::Rowslice => "rowslice",
            Partition::Stolen => "stolen",
        }
    }
}

impl std::str::FromStr for Partition {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Partition> {
        match s {
            "serial" => Ok(Partition::Serial),
            "rowslice" => Ok(Partition::Rowslice),
            "stolen" => Ok(Partition::Stolen),
            other => anyhow::bail!("unknown partition '{other}' (serial|rowslice|stolen)"),
        }
    }
}

/// One executable configuration of the serving hot path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExecPlan {
    pub backend: PlanBackend,
    /// Popcount reducer (packed backend only).
    pub kernel: PopcountKernel,
    /// Kernel slots the plan was chosen for (1 = serial; informational
    /// when the executing pool is a different size — the partition is
    /// what actually dispatches).
    pub threads: u32,
    pub partition: Partition,
    /// Tile policy: 2-D output tiles plus the contracted-dimension
    /// chunk count (stolen partition only).
    pub tile: TilePolicy,
    /// Plane-pair kernel family: direct popcount or RSR segment reuse
    /// (packed backend only).
    pub family: KernelFamily,
}

impl ExecPlan {
    pub fn native() -> ExecPlan {
        ExecPlan {
            backend: PlanBackend::Native,
            kernel: PopcountKernel::Scalar,
            threads: 1,
            partition: Partition::Serial,
            tile: TilePolicy::AUTO,
            family: KernelFamily::Popcount,
        }
    }

    /// The instruction-driven device plan: every other knob is inert
    /// (the streamed array has no reducer, pool, or tile policy).
    pub fn device() -> ExecPlan {
        ExecPlan {
            backend: PlanBackend::Device,
            ..ExecPlan::native()
        }
    }

    pub fn packed(
        kernel: PopcountKernel,
        threads: u32,
        partition: Partition,
        tile: TilePolicy,
    ) -> ExecPlan {
        ExecPlan {
            backend: PlanBackend::Packed,
            kernel,
            threads: threads.max(1),
            partition,
            tile,
            family: KernelFamily::Popcount,
        }
    }

    /// This plan with the RSR segment-kernel family (`seg_words = 0`
    /// for auto segment length).
    pub fn rsr(mut self, seg_words: u32) -> ExecPlan {
        self.family = KernelFamily::Rsr { seg_words };
        self
    }

    /// The plan the pre-planner scheduler always ran: packed, the
    /// configured reducer and tile policy, stolen across the pool when
    /// one is attached. Keeping it as an explicit plan means the
    /// planner-off path and the planned path share one executor.
    pub fn static_default(
        kernel: PopcountKernel,
        tile: TilePolicy,
        pool_slots: usize,
    ) -> ExecPlan {
        if pool_slots > 1 {
            ExecPlan::packed(kernel, pool_slots as u32, Partition::Stolen, tile)
        } else {
            ExecPlan::packed(kernel, 1, Partition::Serial, tile)
        }
    }

    /// Human/plan-file label, e.g. `packed/avx2/t9/stolen/auto`;
    /// non-default k-chunk counts and the RSR family append suffixes
    /// (`.../auto/k4`, `.../auto/rsr2`) so default labels are
    /// unchanged across plan-file generations.
    pub fn label(&self) -> String {
        match self.backend {
            PlanBackend::Native => "native".to_string(),
            PlanBackend::Device => "device".to_string(),
            PlanBackend::Packed => {
                let mut tile = if self.tile.tile_rows == 0 && self.tile.tile_cols == 0 {
                    "auto".to_string()
                } else {
                    format!("{}x{}", self.tile.tile_rows, self.tile.tile_cols)
                };
                if self.tile.k_chunks != 0 {
                    tile.push_str(&format!("/k{}", self.tile.k_chunks));
                }
                if let KernelFamily::Rsr { seg_words } = self.family {
                    tile.push_str("/rsr");
                    if seg_words != 0 {
                        tile.push_str(&seg_words.to_string());
                    }
                }
                format!(
                    "packed/{}/t{}/{}/{tile}",
                    self.kernel.name(),
                    self.threads,
                    self.partition.name()
                )
            }
        }
    }

    /// The full candidate space for `pool_slots` kernel slots: native,
    /// every available reducer serially, and (when a pool exists) every
    /// available reducer under rowslice and under stolen with a small
    /// spread of tile policies. This is the sweep `bitsmm tune` times
    /// and the set the bit-transparency property test pins — every
    /// member computes identical integers.
    pub fn candidates(pool_slots: usize) -> Vec<ExecPlan> {
        let mut v = vec![ExecPlan::native()];
        let kernels = PopcountKernel::available_concrete();
        for &kern in &kernels {
            v.push(ExecPlan::packed(kern, 1, Partition::Serial, TilePolicy::AUTO));
        }
        if pool_slots > 1 {
            let t = pool_slots as u32;
            for &kern in &kernels {
                v.push(ExecPlan::packed(kern, t, Partition::Rowslice, TilePolicy::AUTO));
                for tile in [
                    TilePolicy::AUTO,
                    TilePolicy { tile_rows: 1, tile_cols: 0, ..TilePolicy::AUTO },
                    TilePolicy { tile_rows: 0, tile_cols: 1, ..TilePolicy::AUTO },
                ] {
                    v.push(ExecPlan::packed(kern, t, Partition::Stolen, tile));
                }
            }
        }
        // the sub-popcount family and the k-split axis: serial RSR at
        // two segment lengths, and — pooled — stolen RSR, an explicit
        // no-split baseline, and a forced 2-chunk split. All enter the
        // same bit-transparency sweep as the popcount plans.
        let auto = PopcountKernel::Auto.resolve();
        v.push(ExecPlan::packed(auto, 1, Partition::Serial, TilePolicy::AUTO).rsr(1));
        v.push(ExecPlan::packed(auto, 1, Partition::Serial, TilePolicy::AUTO).rsr(2));
        if pool_slots > 1 {
            let t = pool_slots as u32;
            v.push(ExecPlan::packed(auto, t, Partition::Stolen, TilePolicy::AUTO).rsr(0));
            v.push(ExecPlan::packed(auto, t, Partition::Stolen, TilePolicy::NO_KSPLIT));
            v.push(ExecPlan::packed(
                auto,
                t,
                Partition::Stolen,
                TilePolicy { tile_rows: 0, tile_cols: 0, k_chunks: 2 },
            ));
        }
        v
    }

    /// The short list on-line calibration times on a *live* request:
    /// the cost-model seed plus the structurally distinct alternatives
    /// (native, serial packed, pooled stolen/rowslice with the best
    /// reducer), deduplicated, capped at `limit`. Small on purpose —
    /// calibration runs on the request path.
    pub fn top_candidates(key: &PlanKey, pool_slots: usize, limit: usize) -> Vec<ExecPlan> {
        let auto = PopcountKernel::Auto.resolve();
        let mut v = vec![
            cost::seed_plan(key, pool_slots),
            ExecPlan::native(),
            ExecPlan::packed(auto, 1, Partition::Serial, TilePolicy::AUTO),
        ];
        let low_prec = key.bits_a <= 2 && key.bits_b <= 2;
        if low_prec {
            v.push(ExecPlan::packed(auto, 1, Partition::Serial, TilePolicy::AUTO).rsr(0));
        }
        if pool_slots > 1 {
            let t = pool_slots as u32;
            // huge-k classes calibrate the stolen candidate with the
            // cost model's concrete chunk count, so a winning k-split
            // plan is visible (and persistable) as one
            let stolen_tile = if cost::prefers_ksplit(key, pool_slots) {
                TilePolicy {
                    tile_rows: 0,
                    tile_cols: 0,
                    k_chunks: cost::seed_k_chunks(key, pool_slots),
                }
            } else {
                TilePolicy::AUTO
            };
            v.push(ExecPlan::packed(auto, t, Partition::Stolen, stolen_tile));
            if low_prec {
                v.push(ExecPlan::packed(auto, t, Partition::Stolen, TilePolicy::AUTO).rsr(0));
            }
            v.push(ExecPlan::packed(auto, t, Partition::Rowslice, TilePolicy::AUTO));
        }
        let mut out: Vec<ExecPlan> = Vec::with_capacity(v.len());
        for p in v {
            if !out.contains(&p) {
                out.push(p);
            }
        }
        out.truncate(limit.max(1));
        out
    }
}

/// The result of running one plan: the exact i64 accumulators, the
/// stolen-scheduler telemetry (zero unless the stolen partition ran),
/// and whether the packed engine (vs the native loop) produced it.
pub type RunOut = (Vec<i64>, StealStats, bool);

/// One matmul's operands and execution context, bundled so the
/// scheduler, the calibrator, and the tuner all run plans through the
/// same code.
pub struct ShapeRun<'r> {
    /// Streamed operand, row-major `m × k`.
    pub a: &'r [i32],
    /// Stationary operand, row-major `k × n` (dense — used by the
    /// native backend and to pack ad-hoc when `packed_b` is absent).
    pub b: &'r [i32],
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub bits: u32,
    /// Plane kind used to pack the streamed operand (and the
    /// stationary one when no cached planes are supplied).
    pub stream_kind: PlaneKind,
    /// Pre-packed stationary planes at exactly `bits` (the layer-cache
    /// steady state); `None` means pack per call, which the timing then
    /// honestly includes.
    pub packed_b: Option<&'r Arc<PackedPlanes>>,
    /// Kernel worker pool for pooled partitions; plans wanting a pool
    /// degrade to the serial kernel without one.
    pub pool: Option<&'r Arc<PackedPool>>,
}

impl ShapeRun<'_> {
    /// Execute `plan` on these operands. Bit-identical across every
    /// plan by construction: the native leg is the reference loop, and
    /// every packed leg is pinned to it by the property suite.
    pub fn run(&self, plan: &ExecPlan) -> Result<RunOut> {
        let (m, k, n, bits) = (self.m, self.k, self.n, self.bits);
        match plan.backend {
            PlanBackend::Native => Ok((
                matmul_native(self.a, self.b, m, k, n, bits)?,
                StealStats::default(),
                false,
            )),
            // fidelity leg: the cycle-accurate array behind the
            // instruction-driven driver, on the paper's default 4×16
            // Booth configuration (per-stage telemetry is dropped here;
            // the scheduler's Simulate backend reports it)
            PlanBackend::Device => {
                let sa = crate::sim::array::SaConfig::new(
                    4,
                    16,
                    crate::sim::mac_common::MacVariant::Booth,
                );
                let (out, _stats) =
                    crate::device::device_matmul(sa, self.a, self.b, m, k, n, bits)?;
                Ok((out, StealStats::default(), false))
            }
            PlanBackend::Packed => {
                let pa = Arc::new(PackedPlanes::pack_rows(self.a, m, k, bits, self.stream_kind)?);
                let pb = match self.packed_b {
                    Some(p) => {
                        anyhow::ensure!(
                            p.len == k && p.vectors == n && p.bits == bits,
                            "supplied planes ({}x{} @{}b) do not match the run ({k}x{n} @{bits}b)",
                            p.len,
                            p.vectors,
                            p.bits
                        );
                        p.clone()
                    }
                    None => Arc::new(PackedPlanes::pack_cols(self.b, k, n, bits, self.stream_kind)?),
                };
                match plan.family {
                    KernelFamily::Rsr { seg_words } => match (plan.partition, self.pool) {
                        (Partition::Stolen, Some(pool)) => {
                            let (out, stats) = matmul_packed_tile_stolen_with(
                                pool, &pa, &pb, 0, m, 0, n, plan.kernel, plan.tile, plan.family,
                            )?;
                            Ok((out, stats, true))
                        }
                        // serial (or pool-less degrade): one segment
                        // table spanning the whole output
                        _ => Ok((
                            matmul_packed_rsr(
                                &pa, &pb, 0, m, 0, n, plan.kernel, seg_words as usize,
                            )?,
                            StealStats::default(),
                            true,
                        )),
                    },
                    KernelFamily::Popcount => match (plan.partition, self.pool) {
                        (Partition::Serial, _) | (_, None) => Ok((
                            matmul_packed_tile_with(&pa, &pb, 0, m, 0, n, plan.kernel)?,
                            StealStats::default(),
                            true,
                        )),
                        (Partition::Rowslice, Some(pool)) => Ok((
                            matmul_packed_tile_rowslice(pool, &pa, &pb, 0, m, 0, n, plan.kernel)?,
                            StealStats::default(),
                            true,
                        )),
                        (Partition::Stolen, Some(pool)) => {
                            let (out, stats) = matmul_packed_tile_stolen(
                                pool, &pa, &pb, 0, m, 0, n, plan.kernel, plan.tile,
                            )?;
                            Ok((out, stats, true))
                        }
                    },
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::twos::{max_value, min_value};
    use crate::prng::Pcg32;
    use crate::sim::driver::ref_matmul_i64;

    fn rand_mat(rng: &mut Pcg32, len: usize, bits: u32) -> Vec<i32> {
        let (lo, hi) = (min_value(bits), max_value(bits));
        (0..len).map(|_| rng.range_i32(lo, hi)).collect()
    }

    #[test]
    fn candidate_space_covers_the_knobs() {
        let pooled = ExecPlan::candidates(4);
        assert!(pooled.contains(&ExecPlan::native()));
        assert!(pooled.iter().any(|p| p.partition == Partition::Serial
            && p.backend == PlanBackend::Packed));
        assert!(pooled.iter().any(|p| p.partition == Partition::Rowslice));
        assert!(pooled.iter().any(|p| p.partition == Partition::Stolen
            && p.tile != TilePolicy::AUTO));
        // the PR 6 axes: RSR family serial and stolen, a forced k-split
        // and an explicit no-split stolen baseline
        assert!(pooled.iter().any(
            |p| matches!(p.family, KernelFamily::Rsr { .. }) && p.partition == Partition::Serial
        ));
        assert!(pooled.iter().any(
            |p| matches!(p.family, KernelFamily::Rsr { .. }) && p.partition == Partition::Stolen
        ));
        assert!(pooled.iter().any(|p| p.tile.k_chunks >= 2));
        assert!(pooled.iter().any(|p| p.tile == TilePolicy::NO_KSPLIT));
        // no duplicates
        for (i, p) in pooled.iter().enumerate() {
            assert!(!pooled[i + 1..].contains(p), "duplicate candidate {p:?}");
        }
        // without a pool, nothing pooled is offered
        let serial = ExecPlan::candidates(1);
        assert!(serial.iter().all(|p| p.partition == Partition::Serial));
        assert!(serial.len() >= 2, "native + at least the scalar reducer");
    }

    #[test]
    fn top_candidates_cover_the_new_regimes() {
        // 1–2 bit classes offer RSR…
        let low = crate::plan::PlanKey::for_matmul(64, 512, 64, 1, 1, PlaneKind::Sbmwc);
        let top = ExecPlan::top_candidates(&low, 5, 6);
        assert!(
            top.iter().any(|p| matches!(p.family, KernelFamily::Rsr { .. })),
            "no RSR candidate for a 1-bit class: {top:?}"
        );
        // …huge-k classes offer a concrete k-split…
        let hugek = crate::plan::PlanKey::for_matmul(1, 8192, 512, 8, 8, PlaneKind::Sbmwc);
        let top = ExecPlan::top_candidates(&hugek, 5, 6);
        assert!(
            top.iter().any(|p| p.partition == Partition::Stolen && p.tile.k_chunks >= 2),
            "no k-split candidate for a huge-k class: {top:?}"
        );
        // …and mid shapes at high precision offer neither
        let mid = crate::plan::PlanKey::for_matmul(64, 512, 64, 8, 8, PlaneKind::Sbmwc);
        let top = ExecPlan::top_candidates(&mid, 5, 6);
        assert!(top.iter().all(|p| p.family == KernelFamily::Popcount));
        assert!(top.iter().all(|p| p.tile.k_chunks == 0));
    }

    #[test]
    fn top_candidates_are_small_and_lead_with_the_seed() {
        let key = crate::plan::PlanKey::for_matmul(64, 512, 64, 4, 4, PlaneKind::Sbmwc);
        let top = ExecPlan::top_candidates(&key, 5, 5);
        assert!(top.len() <= 5 && !top.is_empty());
        assert_eq!(top[0], super::cost::seed_plan(&key, 5));
        assert!(top.contains(&ExecPlan::native()));
        for (i, p) in top.iter().enumerate() {
            assert!(!top[i + 1..].contains(p), "duplicate top candidate {p:?}");
        }
    }

    #[test]
    fn every_plan_runs_bit_identical_on_a_spot_shape() {
        let pool = Arc::new(PackedPool::new(2).unwrap());
        let mut rng = Pcg32::new(0x9147);
        let (m, k, n, bits) = (5usize, 70usize, 9usize, 6u32);
        let a = rand_mat(&mut rng, m * k, bits);
        let b = rand_mat(&mut rng, k * n, bits);
        let want = ref_matmul_i64(&a, &b, m, k, n);
        let pb = Arc::new(
            PackedPlanes::pack_cols(&b, k, n, bits, PlaneKind::Sbmwc).unwrap(),
        );
        for packed_b in [None, Some(&pb)] {
            let run = ShapeRun {
                a: &a,
                b: &b,
                m,
                k,
                n,
                bits,
                stream_kind: PlaneKind::Sbmwc,
                packed_b,
                pool: Some(&pool),
            };
            for plan in ExecPlan::candidates(pool.threads() + 1) {
                let (out, stats, ran_packed) = run.run(&plan).unwrap();
                assert_eq!(out, want, "{} diverged", plan.label());
                assert_eq!(ran_packed, plan.backend == PlanBackend::Packed);
                if plan.partition != Partition::Stolen {
                    assert_eq!(stats, StealStats::default());
                }
            }
        }
    }

    #[test]
    fn pooled_plans_degrade_serially_without_a_pool() {
        let mut rng = Pcg32::new(0x9148);
        let (m, k, n, bits) = (3usize, 64usize, 4usize, 4u32);
        let a = rand_mat(&mut rng, m * k, bits);
        let b = rand_mat(&mut rng, k * n, bits);
        let run = ShapeRun {
            a: &a,
            b: &b,
            m,
            k,
            n,
            bits,
            stream_kind: PlaneKind::Sbmwc,
            packed_b: None,
            pool: None,
        };
        let plan = ExecPlan::packed(
            PopcountKernel::Auto,
            8,
            Partition::Stolen,
            TilePolicy::AUTO,
        );
        let (out, _, ran_packed) = run.run(&plan).unwrap();
        assert_eq!(out, ref_matmul_i64(&a, &b, m, k, n));
        assert!(ran_packed);
    }

    #[test]
    fn mismatched_supplied_planes_are_rejected() {
        let a = [1i32, 2, 3];
        let b = [1i32, 2, 3, 4, 5, 6];
        let pb = Arc::new(PackedPlanes::pack_cols(&b, 3, 2, 8, PlaneKind::Sbmwc).unwrap());
        let run = ShapeRun {
            a: &a,
            b: &b,
            m: 1,
            k: 3,
            n: 2,
            bits: 4, // planes above are 8-bit: the run must reject them
            stream_kind: PlaneKind::Sbmwc,
            packed_b: Some(&pb),
            pool: None,
        };
        let plan = ExecPlan::packed(PopcountKernel::Scalar, 1, Partition::Serial, TilePolicy::AUTO);
        assert!(run.run(&plan).is_err());
    }

    #[test]
    fn labels_and_parses() {
        assert_eq!(ExecPlan::native().label(), "native");
        let p = ExecPlan::packed(
            PopcountKernel::Scalar,
            9,
            Partition::Stolen,
            TilePolicy { tile_rows: 2, tile_cols: 8, ..TilePolicy::AUTO },
        );
        assert_eq!(p.label(), "packed/scalar/t9/stolen/2x8");
        // the PR 6 axes only append when non-default
        let ks = ExecPlan::packed(
            PopcountKernel::Scalar,
            9,
            Partition::Stolen,
            TilePolicy { tile_rows: 0, tile_cols: 0, k_chunks: 4 },
        );
        assert_eq!(ks.label(), "packed/scalar/t9/stolen/auto/k4");
        let rsr = ExecPlan::packed(PopcountKernel::Scalar, 1, Partition::Serial, TilePolicy::AUTO);
        assert_eq!(rsr.rsr(0).label(), "packed/scalar/t1/serial/auto/rsr");
        assert_eq!(rsr.rsr(2).label(), "packed/scalar/t1/serial/auto/rsr2");
        assert_eq!("native".parse::<PlanBackend>().unwrap(), PlanBackend::Native);
        assert_eq!("device".parse::<PlanBackend>().unwrap(), PlanBackend::Device);
        assert_eq!(ExecPlan::device().label(), "device");
        assert_eq!("stolen".parse::<Partition>().unwrap(), Partition::Stolen);
        assert!("gpu".parse::<PlanBackend>().is_err());
        assert!("diagonal".parse::<Partition>().is_err());
    }

    #[test]
    fn device_plan_is_runnable_but_never_a_candidate() {
        let mut rng = Pcg32::new(0xdead);
        let (m, k, n, bits) = (5usize, 70usize, 9usize, 6u32);
        let a = rand_mat(&mut rng, m * k, bits);
        let b = rand_mat(&mut rng, k * n, bits);
        let run = ShapeRun {
            a: &a,
            b: &b,
            m,
            k,
            n,
            bits,
            stream_kind: PlaneKind::Sbmwc,
            packed_b: None,
            pool: None,
        };
        let (out, stats, ran_packed) = run.run(&ExecPlan::device()).unwrap();
        assert_eq!(out, ref_matmul_i64(&a, &b, m, k, n), "device leg diverged");
        assert!(!ran_packed);
        assert_eq!(stats, StealStats::default());
        // the planner never offers the fidelity leg on speed grounds
        for plan in ExecPlan::candidates(8) {
            assert_ne!(plan.backend, PlanBackend::Device);
        }
    }
}
