//! The shape-keyed execution planner — tier resolution and the shared
//! concurrent plan cache (DESIGN.md §Planner).
//!
//! One `Arc<Planner>` is shared by every request worker's scheduler.
//! A lookup resolves through three tiers:
//!
//! 1. **Exact hit** — the bucketed key is in the cache (loaded from
//!    `configs/plans.json`, pre-resolved at warm start, or installed by
//!    an earlier miss).
//! 2. **Nearest bucket** — a cached *tuned* plan (calibrated, loaded
//!    from the plan file, or deliberately installed — never a
//!    cost-model seed or a nearest-tier copy, so reuse cannot chain
//!    past the distance cap) for the same precisions and plane kind
//!    in a nearby shape bucket is reused (tuned classes a few powers
//!    of two apart almost always want the same plan); with no such
//!    neighbour, the built-in cost model seeds the plan
//!    ([`crate::plan::cost`]).
//! 3. **On-line calibration** (`PlannerMode::Online` only, replacing
//!    the cost-model fallback when no neighbour exists) — the top
//!    candidate plans are *run* on the live operands, the fastest one
//!    is installed, and its (bit-identical) output is returned so the
//!    request pays for at most a handful of extra matmuls, once per
//!    shape class.
//!
//! Whatever the tier, the resolved plan is installed under the exact
//! key, so every class converges to hit-steady-state. Plans may change
//! speed, never integers: every candidate is pinned bit-identical by
//! the property suite, which is what makes the planner safe to drop
//! into the serving path.

use super::exec::{ExecPlan, RunOut, ShapeRun};
use super::key::PlanKey;
use super::store::PlanFile;
use super::cost;
use crate::Result;
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// How much planning the server does (`server.planner` /
/// `--planner`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlannerMode {
    /// No planner: the static server-wide config runs everything.
    Off,
    /// Cache + nearest-bucket + cost model; never benchmarks on the
    /// request path.
    Static,
    /// `Static`, plus first-touch micro-calibration of unseen shape
    /// classes on the live operands.
    Online,
}

impl PlannerMode {
    pub fn name(self) -> &'static str {
        match self {
            PlannerMode::Off => "off",
            PlannerMode::Static => "static",
            PlannerMode::Online => "online",
        }
    }
}

impl std::str::FromStr for PlannerMode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<PlannerMode> {
        match s {
            "off" => Ok(PlannerMode::Off),
            "static" => Ok(PlannerMode::Static),
            "online" => Ok(PlannerMode::Online),
            other => anyhow::bail!("unknown planner mode '{other}' (off|static|online)"),
        }
    }
}

/// Which tier resolved a lookup (reported per-scheduler via
/// `ExecutionReport.plan`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanTier {
    /// Cache hit on the exact key.
    Exact,
    /// Reused a nearby bucket's plan (same precisions and kind).
    Nearest,
    /// Seeded from the built-in cost model.
    CostModel,
    /// Micro-benchmarked on the live shape (Online mode).
    Calibrated,
}

/// Plan-cache telemetry, merged like the steal stats: per-scheduler in
/// `ExecutionReport`, mirrored into the server `Metrics`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PlanStats {
    /// Exact-key cache hits.
    pub hits: u64,
    /// Lookups resolved below tier 1 (nearest bucket, cost model, or
    /// calibration).
    pub misses: u64,
    /// Misses that ran an on-line micro-benchmark.
    pub calibrations: u64,
}

impl PlanStats {
    pub fn merge(&mut self, o: &PlanStats) {
        self.hits += o.hits;
        self.misses += o.misses;
        self.calibrations += o.calibrations;
    }

    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            return 0.0;
        }
        self.hits as f64 / self.lookups() as f64
    }

    /// JSON object for the telemetry snapshot.
    pub fn json(&self) -> String {
        format!(
            "{{\"hits\":{},\"misses\":{},\"calibrations\":{}}}",
            self.hits, self.misses, self.calibrations
        )
    }
}

/// Neighbour reuse gives up beyond this bucket distance — classes that
/// far apart (≥ ~2⁴× in some dimension product) genuinely may want
/// different plans, so the cost model takes over.
const NEAREST_MAX_DISTANCE: u32 = 4;

/// Candidate plans an on-line calibration times (kept small: it runs
/// on the request path, once per shape class). Six covers the PR 6
/// axes: the regime-ranked head always includes the RSR candidate at
/// 1–2 bits and the forced k-split candidate at huge k.
const CALIBRATION_CANDIDATES: usize = 6;

/// One cached resolution. `donor` marks *tuned* entries — calibrated,
/// loaded from a plan file, or deliberately [`Planner::insert`]ed —
/// the only ones that may seed neighbouring buckets. Cost-model seeds
/// are not donors (the cost model is free to re-evaluate at the
/// neighbour's own representative shape, where e.g. the pooling work
/// floor may cut the other way), and nearest-tier copies are not
/// donors either, so reuse cannot chain transitively past
/// [`NEAREST_MAX_DISTANCE`] (a plan copied to distance 4 copied again
/// to distance 4 would otherwise govern a class 8 buckets away).
#[derive(Debug, Clone, Copy)]
struct Cached {
    plan: ExecPlan,
    donor: bool,
}

/// The shape-keyed planner: mode + shared plan cache + counters.
pub struct Planner {
    mode: PlannerMode,
    /// Kernel slots plans are sized for (pool threads + the caller's
    /// inline slot; 1 = no pool).
    pool_slots: usize,
    cache: Mutex<HashMap<PlanKey, Cached>>,
    /// Shape classes currently being calibrated by some worker —
    /// concurrent first-touch misses on the same class run the
    /// cost-model seed once instead of duplicating the benchmark.
    calibrating: Mutex<HashSet<PlanKey>>,
    hits: AtomicU64,
    misses: AtomicU64,
    calibrations: AtomicU64,
}

impl Planner {
    pub fn new(mode: PlannerMode, pool_slots: usize) -> Planner {
        Planner {
            mode,
            pool_slots: pool_slots.max(1),
            cache: Mutex::new(HashMap::new()),
            calibrating: Mutex::new(HashSet::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            calibrations: AtomicU64::new(0),
        }
    }

    pub fn mode(&self) -> PlannerMode {
        self.mode
    }

    pub fn is_on(&self) -> bool {
        self.mode != PlannerMode::Off
    }

    pub fn pool_slots(&self) -> usize {
        self.pool_slots
    }

    /// Cached plan count.
    pub fn len(&self) -> usize {
        self.cache.lock().expect("plan cache poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Counter-free cache probe (tools and warm start; request-path
    /// lookups go through [`Planner::resolve`] / [`Planner::plan_run`]).
    pub fn peek(&self, key: &PlanKey) -> Option<ExecPlan> {
        self.cache
            .lock()
            .expect("plan cache poisoned")
            .get(key)
            .map(|c| c.plan)
    }

    /// Deliberately install a plan (tools, tests, plan files): a donor
    /// entry, eligible to seed neighbouring buckets.
    pub fn insert(&self, key: PlanKey, plan: ExecPlan) {
        self.cache
            .lock()
            .expect("plan cache poisoned")
            .insert(key, Cached { plan, donor: true });
    }

    /// Nearest *donor* neighbour of `key` (same precisions and plane
    /// kind, within the bucket-distance cap) — the shared tier-2 step
    /// of both resolution paths. Nearest-tier copies never donate, so
    /// the distance cap is a true bound, not a per-hop one.
    fn nearest_in(cache: &HashMap<PlanKey, Cached>, key: &PlanKey) -> Option<ExecPlan> {
        cache
            .iter()
            .filter(|(_, c)| c.donor)
            .filter_map(|(k, c)| key.distance(k).map(|d| (d, c.plan)))
            .filter(|&(d, _)| d <= NEAREST_MAX_DISTANCE)
            .min_by_key(|&(d, _)| d)
            .map(|(_, p)| p)
    }

    /// Tier-resolve without touching operands (Static mode, warm
    /// start): exact hit → nearest bucket → cost model. The result is
    /// installed under the exact key, so repeats are hits.
    pub fn resolve(&self, key: PlanKey) -> (ExecPlan, PlanTier) {
        let mut cache = self.cache.lock().expect("plan cache poisoned");
        if let Some(c) = cache.get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return (c.plan, PlanTier::Exact);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let (plan, tier) = match Self::nearest_in(&cache, &key) {
            Some(p) => (p, PlanTier::Nearest),
            None => (cost::seed_plan(&key, self.pool_slots), PlanTier::CostModel),
        };
        // neither tier installs a donor: copies must not chain, and
        // cost-model seeds are better re-derived per class (see Cached)
        cache.insert(key, Cached { plan, donor: false });
        (plan, tier)
    }

    /// Request-path resolution, honouring the tier order in every
    /// mode: exact hit, then nearest-bucket reuse (a tuned neighbour —
    /// e.g. loaded from the plan file — beats re-measuring), and only
    /// then, in `Online` mode, first-touch calibration on the live
    /// operands — which hands back the winning run's output
    /// (`Some(RunOut)`) so the caller skips re-running. Static mode
    /// falls to the cost model where Online would calibrate.
    pub fn plan_run(
        &self,
        key: PlanKey,
        run: &ShapeRun<'_>,
    ) -> Result<(ExecPlan, PlanTier, Option<RunOut>)> {
        if self.mode != PlannerMode::Online {
            let (plan, tier) = self.resolve(key);
            return Ok((plan, tier, None));
        }
        {
            let mut cache = self.cache.lock().expect("plan cache poisoned");
            if let Some(c) = cache.get(&key) {
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Ok((c.plan, PlanTier::Exact, None));
            }
            self.misses.fetch_add(1, Ordering::Relaxed);
            if let Some(p) = Self::nearest_in(&cache, &key) {
                cache.insert(key, Cached { plan: p, donor: false });
                return Ok((p, PlanTier::Nearest, None));
            }
        } // drop the lock before the (potentially long) calibration
        // claim the class: a concurrent worker that misses the same
        // uncached class while we benchmark runs the cost-model seed
        // once (without installing it) instead of duplicating the
        // calibration — the winner lands exactly once
        if !self
            .calibrating
            .lock()
            .expect("calibration set poisoned")
            .insert(key)
        {
            return Ok((cost::seed_plan(&key, self.pool_slots), PlanTier::CostModel, None));
        }
        // re-peek after claiming: a racer that missed alongside us may
        // have calibrated and released between our miss and our claim —
        // serve its installed winner instead of re-benchmarking
        if let Some(p) = self.peek(&key) {
            self.calibrating
                .lock()
                .expect("calibration set poisoned")
                .remove(&key);
            return Ok((p, PlanTier::Exact, None));
        }
        let result = self.calibrate(key, run);
        self.calibrating
            .lock()
            .expect("calibration set poisoned")
            .remove(&key);
        let (plan, out) = result?;
        Ok((plan, PlanTier::Calibrated, Some(out)))
    }

    /// Micro-benchmark the top candidate plans on `run`, install the
    /// fastest under `key`, and return it with its output. Each
    /// candidate runs twice — an untimed warm-up absorbing one-time
    /// cold-start costs (pool worker wake-up, cache warmth, first
    /// allocations) that would otherwise systematically penalize
    /// whichever candidate happens to run first, then the timed run.
    /// Every candidate computes identical integers (the
    /// bit-transparency the property suite pins), so *which* run's
    /// output is returned is immaterial — calibration costs a handful
    /// of redundant matmuls, never a different answer.
    pub fn calibrate(&self, key: PlanKey, run: &ShapeRun<'_>) -> Result<(ExecPlan, RunOut)> {
        let candidates = ExecPlan::top_candidates(&key, self.pool_slots, CALIBRATION_CANDIDATES);
        let mut best: Option<(f64, ExecPlan, RunOut)> = None;
        for plan in candidates {
            let _warm = run.run(&plan)?;
            let t0 = Instant::now();
            let out = run.run(&plan)?;
            let dt = t0.elapsed().as_secs_f64();
            if best.as_ref().map_or(true, |(b, _, _)| dt < *b) {
                best = Some((dt, plan, out));
            }
        }
        let (_, plan, out) = best.expect("top_candidates is never empty");
        self.insert(key, plan);
        self.calibrations.fetch_add(1, Ordering::Relaxed);
        Ok((plan, out))
    }

    /// Global counters (the per-request view lives in
    /// `ExecutionReport.plan`; this one also counts warm-start and
    /// tune-time work).
    pub fn stats(&self) -> PlanStats {
        PlanStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            calibrations: self.calibrations.load(Ordering::Relaxed),
        }
    }

    /// The cached plans in stable order — the serve table's
    /// plan-per-shape-class rows and the plan file's contents.
    pub fn summary(&self) -> Vec<(PlanKey, ExecPlan)> {
        let mut v: Vec<(PlanKey, ExecPlan)> = self
            .cache
            .lock()
            .expect("plan cache poisoned")
            .iter()
            .map(|(k, c)| (*k, c.plan))
            .collect();
        v.sort_by_key(|(k, _)| k.sort_key());
        v
    }

    /// Install every entry of a plan file after the version/host check
    /// — a stale or foreign file errs here and the planner keeps
    /// resolving from the cost model instead (the fallback the
    /// fingerprint exists for). Returns the entry count installed.
    pub fn load_file(&self, path: &std::path::Path) -> Result<usize> {
        let file = PlanFile::load(path)?;
        file.check_host()?;
        let n = file.entries.len();
        let mut cache = self.cache.lock().expect("plan cache poisoned");
        for (k, p) in file.entries {
            // tuned entries are donors: a loaded plan may seed its
            // neighbouring buckets like a locally calibrated one
            cache.insert(k, Cached { plan: p, donor: true });
        }
        Ok(n)
    }

    /// Persist the cache as a fingerprinted plan file (what `bitsmm
    /// tune` writes). Returns the entry count written.
    pub fn save_file(&self, path: &std::path::Path) -> Result<usize> {
        let entries = self.summary();
        let n = entries.len();
        PlanFile::new(entries).save(path)?;
        Ok(n)
    }

    /// Persist the *tuned* plans back to `path` on graceful server
    /// shutdown. Only donor entries qualify — calibrated winners,
    /// file-loaded plans, deliberate inserts — never cost-model seeds
    /// or nearest-tier copies, which are better re-derived. Merge,
    /// don't clobber: an existing same-host file's entries are kept
    /// and overlaid by this run's donors, so serving sessions
    /// accumulate coverage instead of erasing each other; a foreign or
    /// stale-version file errs and is left untouched (the caller logs
    /// and moves on). The write is atomic — temp file in the same
    /// directory, then `rename` — so a crash mid-write can never
    /// truncate the live plan file. Returns the entry count written.
    pub fn persist_file(&self, path: &std::path::Path) -> Result<usize> {
        let mut merged: HashMap<PlanKey, ExecPlan> = HashMap::new();
        if path.exists() {
            let existing = PlanFile::load(path)?;
            existing.check_host()?;
            merged.extend(existing.entries);
        }
        {
            let cache = self.cache.lock().expect("plan cache poisoned");
            for (k, c) in cache.iter() {
                if c.donor {
                    merged.insert(*k, c.plan);
                }
            }
        }
        let mut entries: Vec<(PlanKey, ExecPlan)> = merged.into_iter().collect();
        entries.sort_by_key(|(k, _)| k.sort_key());
        let n = entries.len();
        let tmp = path.with_extension("json.tmp");
        PlanFile::new(entries).save(&tmp)?;
        std::fs::rename(&tmp, path)?;
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::packed::{PackedPlanes, PackedPool, PopcountKernel, TilePolicy};
    use crate::bits::plane::PlaneKind;
    use crate::bits::twos::{max_value, min_value};
    use crate::plan::exec::{Partition, PlanBackend};
    use crate::prng::Pcg32;
    use crate::sim::driver::ref_matmul_i64;
    use std::sync::Arc;

    fn key(m: usize, k: usize, n: usize, bits: u32) -> PlanKey {
        PlanKey::for_matmul(m, k, n, bits, bits, PlaneKind::Sbmwc)
    }

    #[test]
    fn three_tier_resolution_and_install() {
        let p = Planner::new(PlannerMode::Static, 4);
        // empty cache, no tuned neighbour: cost model
        let (plan1, tier1) = p.resolve(key(64, 512, 64, 4));
        assert_eq!(tier1, PlanTier::CostModel);
        assert_eq!(plan1.backend, PlanBackend::Packed);
        // the resolution was installed: second lookup is an exact hit
        let (plan2, tier2) = p.resolve(key(60, 500, 33, 4));
        assert_eq!(tier2, PlanTier::Exact, "same buckets hit the installed plan");
        assert_eq!(plan2, plan1);
        // cost-model seeds never donate: a nearby class re-derives
        // its own seed instead of inheriting one
        let (_, tier3) = p.resolve(key(64, 512, 128, 4));
        assert_eq!(tier3, PlanTier::CostModel);
        // a deliberately installed (tuned) plan does donate (tier 2)…
        let tuned = ExecPlan::packed(
            PopcountKernel::Unroll4,
            4,
            Partition::Rowslice,
            TilePolicy::AUTO,
        );
        p.insert(key(32, 512, 64, 4), tuned); // bucket (5, 9, 6)
        let (plan4, tier4) = p.resolve(key(16, 512, 64, 4)); // (4,9,6): distance 1
        assert_eq!(tier4, PlanTier::Nearest);
        assert_eq!(plan4, tuned);
        // …but its nearest-tier copy does not re-donate: a key in
        // range of the copy yet out of range of the tuned entry falls
        // to the cost model instead of chaining past the distance cap
        let (plan5, tier5) = p.resolve(key(1, 512, 64, 4)); // d(tuned)=5, d(copy)=4
        assert_eq!(tier5, PlanTier::CostModel);
        assert_ne!(plan5, tuned);
        // precision wall: a tuned 4-bit plan never crosses to 16-bit
        let (_, tier6) = p.resolve(key(32, 512, 64, 16));
        assert_eq!(tier6, PlanTier::CostModel);
        let s = p.stats();
        assert_eq!((s.hits, s.misses, s.calibrations), (1, 5, 0));
        assert_eq!(p.len(), 6);
    }

    #[test]
    fn distant_buckets_fall_to_the_cost_model() {
        let p = Planner::new(PlannerMode::Static, 4);
        p.insert(key(1, 1, 1, 8), ExecPlan::native());
        let (_, tier) = p.resolve(key(4096, 4096, 4096, 8));
        assert_eq!(tier, PlanTier::CostModel, "too far to inherit a plan");
    }

    #[test]
    fn online_calibration_returns_exact_output_and_installs_winner() {
        let pool = Arc::new(PackedPool::new(2).unwrap());
        let planner = Planner::new(PlannerMode::Online, pool.threads() + 1);
        let mut rng = Pcg32::new(0xca1b);
        let (m, k, n, bits) = (7usize, 70usize, 9usize, 5u32);
        let (lo, hi) = (min_value(bits), max_value(bits));
        let a: Vec<i32> = (0..m * k).map(|_| rng.range_i32(lo, hi)).collect();
        let b: Vec<i32> = (0..k * n).map(|_| rng.range_i32(lo, hi)).collect();
        let pb = Arc::new(PackedPlanes::pack_cols(&b, k, n, bits, PlaneKind::Sbmwc).unwrap());
        let run = ShapeRun {
            a: &a,
            b: &b,
            m,
            k,
            n,
            bits,
            stream_kind: PlaneKind::Sbmwc,
            packed_b: Some(&pb),
            pool: Some(&pool),
        };
        let k1 = key(m, k, n, bits);
        let (plan, tier, out) = planner.plan_run(k1, &run).unwrap();
        assert_eq!(tier, PlanTier::Calibrated);
        let out = out.expect("calibration returns the winning run's output");
        assert_eq!(out.0, ref_matmul_i64(&a, &b, m, k, n), "calibrated output exact");
        assert_eq!(planner.peek(&k1), Some(plan), "winner installed");
        // second touch of the class: exact hit, no output (caller runs)
        let (plan2, tier2, out2) = planner.plan_run(k1, &run).unwrap();
        assert_eq!((plan2, tier2), (plan, PlanTier::Exact));
        assert!(out2.is_none());
        let s = planner.stats();
        assert_eq!((s.hits, s.misses, s.calibrations), (1, 1, 1));
    }

    #[test]
    fn online_mode_reuses_a_tuned_neighbour_before_calibrating() {
        // a plan for a nearby bucket (e.g. loaded from the plan file)
        // is reused at tier 2 — no live-request calibration
        let planner = Planner::new(PlannerMode::Online, 1);
        let tuned = ExecPlan::packed(
            PopcountKernel::Unroll4,
            1,
            Partition::Serial,
            TilePolicy::AUTO,
        );
        planner.insert(key(8, 64, 8, 5), tuned);
        let mut rng = Pcg32::new(0xca1c);
        let (m, k, n, bits) = (4usize, 64usize, 8usize, 5u32); // distance 1
        let (lo, hi) = (min_value(bits), max_value(bits));
        let a: Vec<i32> = (0..m * k).map(|_| rng.range_i32(lo, hi)).collect();
        let b: Vec<i32> = (0..k * n).map(|_| rng.range_i32(lo, hi)).collect();
        let run = ShapeRun {
            a: &a,
            b: &b,
            m,
            k,
            n,
            bits,
            stream_kind: PlaneKind::Sbmwc,
            packed_b: None,
            pool: None,
        };
        let (plan, tier, out) = planner.plan_run(key(m, k, n, bits), &run).unwrap();
        assert_eq!(tier, PlanTier::Nearest);
        assert_eq!(plan, tuned);
        assert!(out.is_none(), "nearest reuse never runs the matmul itself");
        assert_eq!(planner.stats().calibrations, 0);
        // a class with no neighbour in range (other precision: the
        // wall blocks reuse) still calibrates
        let a2 = vec![1i32; 4];
        let b2 = vec![1i32; 4];
        let run2 = ShapeRun {
            a: &a2,
            b: &b2,
            m: 2,
            k: 2,
            n: 2,
            bits: 9,
            stream_kind: PlaneKind::Sbmwc,
            packed_b: None,
            pool: None,
        };
        let (_, tier2, _) = planner.plan_run(key(2, 2, 2, 9), &run2).unwrap();
        assert_eq!(tier2, PlanTier::Calibrated);
        assert_eq!(planner.stats().calibrations, 1);
    }

    #[test]
    fn concurrent_calibration_is_claimed_once() {
        let planner = Planner::new(PlannerMode::Online, 1);
        let k1 = key(4, 64, 8, 6);
        let a = vec![1i32; 4 * 64];
        let b = vec![1i32; 64 * 8];
        let run = ShapeRun {
            a: &a,
            b: &b,
            m: 4,
            k: 64,
            n: 8,
            bits: 6,
            stream_kind: PlaneKind::Sbmwc,
            packed_b: None,
            pool: None,
        };
        // simulate another worker mid-calibration on this class: the
        // racer gets the cost-model seed once and installs nothing
        planner.calibrating.lock().unwrap().insert(k1);
        let (plan, tier, out) = planner.plan_run(k1, &run).unwrap();
        assert_eq!(tier, PlanTier::CostModel);
        assert_eq!(plan, crate::plan::cost::seed_plan(&k1, 1));
        assert!(out.is_none());
        assert!(planner.peek(&k1).is_none(), "the racer must not install");
        assert_eq!(planner.stats().calibrations, 0);
        // once the claim clears, the class calibrates normally
        planner.calibrating.lock().unwrap().remove(&k1);
        let (_, tier, _) = planner.plan_run(k1, &run).unwrap();
        assert_eq!(tier, PlanTier::Calibrated);
        assert_eq!(planner.stats().calibrations, 1);
        assert!(
            planner.calibrating.lock().unwrap().is_empty(),
            "the claim is released after calibration"
        );
    }

    #[test]
    fn save_load_roundtrip_preserves_resolutions() {
        let p = Planner::new(PlannerMode::Static, 9);
        let keys = [key(1, 512, 4096, 8), key(256, 256, 256, 16), key(8, 64, 64, 4)];
        for k in keys {
            p.resolve(k);
        }
        // pin one deliberately non-default plan
        let forced = ExecPlan::packed(
            PopcountKernel::Unroll4,
            9,
            Partition::Rowslice,
            TilePolicy { tile_rows: 2, tile_cols: 4, ..TilePolicy::AUTO },
        );
        p.insert(keys[0], forced);
        let dir = std::env::temp_dir().join("bitsmm_planner_roundtrip");
        let path = dir.join("plans.json");
        assert_eq!(p.save_file(&path).unwrap(), 3);

        let q = Planner::new(PlannerMode::Static, 9);
        assert_eq!(q.load_file(&path).unwrap(), 3);
        for k in keys {
            assert_eq!(q.peek(&k), p.peek(&k), "{k}");
        }
        assert_eq!(q.peek(&keys[0]), Some(forced));
        // loaded entries resolve as exact hits
        let (_, tier) = q.resolve(keys[1]);
        assert_eq!(tier, PlanTier::Exact);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn persist_merges_donors_without_clobbering() {
        let dir = std::env::temp_dir().join("bitsmm_planner_persist");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("plans.json");
        let _ = std::fs::remove_file(&path);

        // session 1: one tuned plan, one cost-model resolution
        let p = Planner::new(PlannerMode::Static, 9);
        let tuned1 = ExecPlan::packed(
            PopcountKernel::Unroll8,
            9,
            Partition::Stolen,
            TilePolicy::AUTO,
        );
        p.insert(key(1, 512, 4096, 8), tuned1);
        p.resolve(key(64, 512, 64, 4)); // non-donor: must not persist
        assert_eq!(p.persist_file(&path).unwrap(), 1, "donors only");

        // session 2: a different tuned class merges in; session 1's
        // entry survives, and the shared key is overlaid by the newer
        // donor rather than duplicated
        let q = Planner::new(PlannerMode::Static, 9);
        let tuned2 = ExecPlan::packed(
            PopcountKernel::Unroll4,
            9,
            Partition::Stolen,
            TilePolicy { k_chunks: 4, ..TilePolicy::AUTO },
        );
        q.insert(key(8, 64, 64, 4), tuned2);
        q.insert(key(1, 512, 4096, 8), tuned2); // overlays session 1
        assert_eq!(q.persist_file(&path).unwrap(), 2);

        let r = Planner::new(PlannerMode::Static, 9);
        assert_eq!(r.load_file(&path).unwrap(), 2);
        assert_eq!(r.peek(&key(8, 64, 64, 4)), Some(tuned2));
        assert_eq!(r.peek(&key(1, 512, 4096, 8)), Some(tuned2), "newer donor wins");
        assert!(
            !path.with_extension("json.tmp").exists(),
            "temp file renamed away"
        );
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn persist_refuses_to_touch_a_foreign_file() {
        let dir = std::env::temp_dir().join("bitsmm_planner_persist_foreign");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("plans.json");
        let foreign = PlanFile::new(vec![])
            .render()
            .replace(&crate::plan::host_fingerprint(), "other-box/neon/c2");
        std::fs::write(&path, &foreign).unwrap();

        let p = Planner::new(PlannerMode::Static, 4);
        p.insert(key(8, 64, 64, 4), ExecPlan::native());
        let err = p.persist_file(&path).unwrap_err().to_string();
        assert!(err.contains("foreign"), "{err}");
        assert_eq!(
            std::fs::read_to_string(&path).unwrap(),
            foreign,
            "foreign file left byte-identical"
        );
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn stale_fingerprint_rejected_and_cost_model_takes_over() {
        let p = Planner::new(PlannerMode::Static, 4);
        p.resolve(key(64, 512, 64, 4));
        let dir = std::env::temp_dir().join("bitsmm_planner_stale");
        let path = dir.join("plans.json");
        p.save_file(&path).unwrap();
        // doctor the fingerprint in place
        let doctored = std::fs::read_to_string(&path)
            .unwrap()
            .replace(&crate::plan::host_fingerprint(), "other-box/neon/c2");
        std::fs::write(&path, doctored).unwrap();

        let q = Planner::new(PlannerMode::Static, 4);
        let err = q.load_file(&path).unwrap_err().to_string();
        assert!(err.contains("foreign"), "{err}");
        assert_eq!(q.len(), 0, "nothing foreign installed");
        // the planner still plans — from the cost model
        let (_, tier) = q.resolve(key(64, 512, 64, 4));
        assert_eq!(tier, PlanTier::CostModel);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn mode_parse_and_stats_merge() {
        assert_eq!("off".parse::<PlannerMode>().unwrap(), PlannerMode::Off);
        assert_eq!("static".parse::<PlannerMode>().unwrap(), PlannerMode::Static);
        assert_eq!("online".parse::<PlannerMode>().unwrap(), PlannerMode::Online);
        assert!("turbo".parse::<PlannerMode>().is_err());
        assert!(!Planner::new(PlannerMode::Off, 1).is_on());
        let mut s = PlanStats { hits: 3, misses: 1, calibrations: 1 };
        s.merge(&PlanStats { hits: 1, misses: 3, calibrations: 0 });
        assert_eq!(s, PlanStats { hits: 4, misses: 4, calibrations: 1 });
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
        assert_eq!(PlanStats::default().hit_rate(), 0.0);
    }
}
