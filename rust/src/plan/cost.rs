//! The built-in cost model — tier 3 of plan resolution (DESIGN.md
//! §Planner): when a shape class has no cached or nearby plan, seed
//! one from first principles.
//!
//! The packed engine reduces one output element with
//! `bits_a · bits_b` plane pairs of `ceil(k/64)` word-AND-popcounts
//! each, while the native loop spends `k` multiply-adds — so the
//! crossover is `bits_a · bits_b · ceil(k/64) ≶ k`, which flips with
//! operand precision exactly as `benches/eq_crossover.rs` shows for
//! the hardware equations (eq. 8 vs eq. 6): word packing amortizes 64
//! digits per op, plane pairing costs precision². At 8×8 bits the two
//! sides tie and the tie breaks packed (SIMD popcounts and cached
//! weight planes are not in the formula but always favour packed);
//! at 16×16 native wins, at ≤4 bits packed wins outright.

use super::exec::{ExecPlan, Partition};
use super::key::PlanKey;
use crate::bits::packed::{PopcountKernel, TilePolicy, MIN_TILE_WORK};

/// Word operations the packed engine spends on an `m×k×n` matmul at
/// `ba × bb` bits: one AND+popcount per word per plane pair per output
/// element.
pub fn packed_word_ops(m: usize, k: usize, n: usize, ba: u32, bb: u32) -> u128 {
    let words = k.div_ceil(64) as u128;
    ba as u128 * bb as u128 * words * m as u128 * n as u128
}

/// Element operations of the native i-k-j loop: one multiply-add per
/// `(row, k, col)` triple.
pub fn native_elem_ops(m: usize, k: usize, n: usize) -> u128 {
    m as u128 * k as u128 * n as u128
}

/// Whether the cost model routes this shape class to the packed
/// engine (ties break packed — see module docs).
pub fn prefers_packed(m: usize, k: usize, n: usize, ba: u32, bb: u32) -> bool {
    packed_word_ops(m, k, n, ba, bb) <= native_elem_ops(m, k, n)
}

/// Seed an [`ExecPlan`] for a shape class from the cost model alone:
/// backend by the word-ops crossover, the best runtime-detected
/// popcount reducer, and the pool (work-stolen, auto tiles) whenever
/// the class carries enough word work to amortize dispatch
/// ([`MIN_TILE_WORK`], the same floor the tile planner uses).
pub fn seed_plan(key: &PlanKey, pool_slots: usize) -> ExecPlan {
    let (m, k, n) = key.rep_shape();
    let (ba, bb) = (key.bits_a as u32, key.bits_b as u32);
    if !prefers_packed(m, k, n, ba, bb) {
        return ExecPlan::native();
    }
    let kernel = PopcountKernel::Auto.resolve();
    if pool_slots > 1 && packed_word_ops(m, k, n, ba, bb) >= MIN_TILE_WORK as u128 {
        ExecPlan::packed(kernel, pool_slots as u32, Partition::Stolen, TilePolicy::AUTO)
    } else {
        ExecPlan::packed(kernel, 1, Partition::Serial, TilePolicy::AUTO)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::plane::PlaneKind;
    use crate::plan::exec::PlanBackend;

    #[test]
    fn crossover_flips_with_precision() {
        // ≤ 7×7 bits: 49 plane pairs on 1/64th the words beats native
        assert!(prefers_packed(64, 512, 64, 4, 4));
        assert!(prefers_packed(64, 512, 64, 7, 7));
        // 8×8 on word-aligned k ties, and the tie breaks packed
        assert!(prefers_packed(64, 512, 64, 8, 8));
        // 16×16: 256 plane pairs overwhelm the 64× word amortization
        assert!(!prefers_packed(64, 512, 64, 16, 16));
        // asymmetric widths follow the product
        assert!(prefers_packed(64, 512, 64, 16, 3));
    }

    #[test]
    fn seed_plan_tracks_the_crossover_and_work_floor() {
        let lo = PlanKey::for_matmul(256, 256, 256, 4, 4, PlaneKind::Sbmwc);
        let p = seed_plan(&lo, 9);
        assert_eq!(p.backend, PlanBackend::Packed);
        assert_eq!(p.partition, Partition::Stolen, "big class uses the pool");
        assert!(p.kernel.available());

        let hi = PlanKey::for_matmul(256, 256, 256, 16, 16, PlaneKind::Sbmwc);
        assert_eq!(seed_plan(&hi, 9).backend, PlanBackend::Native);

        // tiny packed class: serial, the pool cannot amortize dispatch
        let tiny = PlanKey::for_matmul(2, 16, 2, 2, 2, PlaneKind::Sbmwc);
        let p = seed_plan(&tiny, 9);
        assert_eq!(p.backend, PlanBackend::Packed);
        assert_eq!(p.partition, Partition::Serial);

        // no pool: never plans a pooled partition
        let p = seed_plan(&lo, 1);
        assert_eq!(p.partition, Partition::Serial);
    }
}
