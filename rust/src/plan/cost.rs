//! The built-in cost model — tier 3 of plan resolution (DESIGN.md
//! §Planner): when a shape class has no cached or nearby plan, seed
//! one from first principles.
//!
//! The packed engine reduces one output element with
//! `bits_a · bits_b` plane pairs of `ceil(k/64)` word-AND-popcounts
//! each, while the native loop spends `k` multiply-adds — so the
//! crossover is `bits_a · bits_b · ceil(k/64) ≶ k`, which flips with
//! operand precision exactly as `benches/eq_crossover.rs` shows for
//! the hardware equations (eq. 8 vs eq. 6): word packing amortizes 64
//! digits per op, plane pairing costs precision². At 8×8 bits the two
//! sides tie and the tie breaks packed (SIMD popcounts and cached
//! weight planes are not in the formula but always favour packed);
//! at 16×16 native wins, at ≤4 bits packed wins outright.

//!
//! Two further regimes ride the same seeding path (DESIGN.md
//! §Sub-popcount-Kernels): 1–2 bit classes whose operands are
//! redundant enough for RSR segment reuse to undercut popcount, and
//! huge-k classes (`k ≥ 4096`) whose output grids cannot feed the pool
//! without splitting the contracted dimension. Both are *assumption*
//! seeds — the online calibrator measures and overrides them, which is
//! why [`RSR_DISTINCT_FRACTION_X16`] may be optimistic without ever
//! serving a slow plan.

use super::exec::{ExecPlan, Partition};
use super::key::PlanKey;
use crate::bits::packed::{PopcountKernel, TilePolicy, MIN_TILE_WORK};

/// Word operations the packed engine spends on an `m×k×n` matmul at
/// `ba × bb` bits: one AND+popcount per word per plane pair per output
/// element.
pub fn packed_word_ops(m: usize, k: usize, n: usize, ba: u32, bb: u32) -> u128 {
    let words = k.div_ceil(64) as u128;
    ba as u128 * bb as u128 * words * m as u128 * n as u128
}

/// Element operations of the native i-k-j loop: one multiply-add per
/// `(row, k, col)` triple.
pub fn native_elem_ops(m: usize, k: usize, n: usize) -> u128 {
    m as u128 * k as u128 * n as u128
}

/// Whether the cost model routes this shape class to the packed
/// engine (ties break packed — see module docs).
pub fn prefers_packed(m: usize, k: usize, n: usize, ba: u32, bb: u32) -> bool {
    packed_word_ops(m, k, n, ba, bb) <= native_elem_ops(m, k, n)
}

/// Assumed distinct-fraction ρ of an RSR segment at 1–2 bits, in
/// sixteenths: real quantized weight columns are drawn from small
/// codebooks, so ~4 of every 16 column patterns per segment are
/// distinct. Uniform random operands have ρ ≈ 1 and RSR loses — the
/// calibrator measures the truth; this constant only decides which
/// side the *seed* starts on.
pub const RSR_DISTINCT_FRACTION_X16: u128 = 4;

/// Cost of one RSR per-column indexed add relative to a word
/// AND+popcount, in sixteenths.
pub const RSR_ADD_COST_X16: u128 = 4;

/// Word-op-equivalents the RSR engine spends on an `m×k×n` matmul:
/// per plane pair and segment, `ρ·n` distinct popcounts plus `n`
/// cheap indexed adds replace the direct kernel's `n` popcounts.
pub fn rsr_word_ops(m: usize, k: usize, n: usize, ba: u32, bb: u32) -> u128 {
    packed_word_ops(m, k, n, ba, bb) * (RSR_DISTINCT_FRACTION_X16 + RSR_ADD_COST_X16) / 16
}

/// Segment-table amortization floor: the table is built once per
/// (plane, tile) and paid back over `m · bits_a` streamed row-plane
/// passes; below this many passes the build dominates.
pub const RSR_MIN_AMORTIZE: usize = 8;

/// Whether the cost model seeds the RSR family for this class: the
/// binary/ternary regime (both operands ≤ 2 bits — where segment
/// patterns can actually collide), with enough streamed passes to
/// amortize the table build.
pub fn prefers_rsr(key: &PlanKey) -> bool {
    let (m, _, _) = key.rep_shape();
    key.bits_a <= 2
        && key.bits_b <= 2
        && m * key.bits_a as usize >= RSR_MIN_AMORTIZE
}

/// k-split threshold: classes whose contracted dimension reaches this
/// size (`kb ≥ 12`) qualify for seeded k-splitting — below it the
/// output grid almost always feeds the pool by itself.
pub const KSPLIT_MIN_K: usize = 4096;

/// Whether the cost model seeds a concrete k-split for this class: a
/// pool to fan out over and a huge contracted dimension. The k-split
/// merge costs `chunks` i64 adds per output cell — noise against the
/// per-chunk word work above [`crate::bits::packed::MIN_KSPLIT_WORK`],
/// so the model charges it nothing and lets calibration arbitrate
/// between the split and unsplit candidates it offers.
pub fn prefers_ksplit(key: &PlanKey, pool_slots: usize) -> bool {
    let (_, k, _) = key.rep_shape();
    pool_slots > 1 && k >= KSPLIT_MIN_K
}

/// Concrete chunk count seeded for a huge-k class: enough chunks to
/// feed every slot, never more than the packed words available,
/// floored at 2 so the split is visible in plan files and sweeps.
pub fn seed_k_chunks(key: &PlanKey, pool_slots: usize) -> usize {
    let (_, k, _) = key.rep_shape();
    k.div_ceil(64).min(pool_slots.max(2)).max(2)
}

/// Seed an [`ExecPlan`] for a shape class from the cost model alone:
/// backend by the word-ops crossover, the best runtime-detected
/// popcount reducer, and the pool (work-stolen, auto tiles) whenever
/// the class carries enough word work to amortize dispatch
/// ([`MIN_TILE_WORK`], the same floor the tile planner uses).
pub fn seed_plan(key: &PlanKey, pool_slots: usize) -> ExecPlan {
    let (m, k, n) = key.rep_shape();
    let (ba, bb) = (key.bits_a as u32, key.bits_b as u32);
    if !prefers_packed(m, k, n, ba, bb) {
        return ExecPlan::native();
    }
    let kernel = PopcountKernel::Auto.resolve();
    let rsr = prefers_rsr(key) && rsr_word_ops(m, k, n, ba, bb) < packed_word_ops(m, k, n, ba, bb);
    let pooled = pool_slots > 1 && packed_word_ops(m, k, n, ba, bb) >= MIN_TILE_WORK as u128;
    let plan = if pooled {
        let tile = if !rsr && prefers_ksplit(key, pool_slots) {
            TilePolicy { k_chunks: seed_k_chunks(key, pool_slots), ..TilePolicy::AUTO }
        } else {
            TilePolicy::AUTO
        };
        ExecPlan::packed(kernel, pool_slots as u32, Partition::Stolen, tile)
    } else {
        ExecPlan::packed(kernel, 1, Partition::Serial, TilePolicy::AUTO)
    };
    if rsr { plan.rsr(0) } else { plan }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::plane::PlaneKind;
    use crate::plan::exec::PlanBackend;

    #[test]
    fn crossover_flips_with_precision() {
        // ≤ 7×7 bits: 49 plane pairs on 1/64th the words beats native
        assert!(prefers_packed(64, 512, 64, 4, 4));
        assert!(prefers_packed(64, 512, 64, 7, 7));
        // 8×8 on word-aligned k ties, and the tie breaks packed
        assert!(prefers_packed(64, 512, 64, 8, 8));
        // 16×16: 256 plane pairs overwhelm the 64× word amortization
        assert!(!prefers_packed(64, 512, 64, 16, 16));
        // asymmetric widths follow the product
        assert!(prefers_packed(64, 512, 64, 16, 3));
    }

    #[test]
    fn seed_plan_tracks_the_crossover_and_work_floor() {
        let lo = PlanKey::for_matmul(256, 256, 256, 4, 4, PlaneKind::Sbmwc);
        let p = seed_plan(&lo, 9);
        assert_eq!(p.backend, PlanBackend::Packed);
        assert_eq!(p.partition, Partition::Stolen, "big class uses the pool");
        assert!(p.kernel.available());

        let hi = PlanKey::for_matmul(256, 256, 256, 16, 16, PlaneKind::Sbmwc);
        assert_eq!(seed_plan(&hi, 9).backend, PlanBackend::Native);

        // tiny packed class: serial, the pool cannot amortize dispatch
        let tiny = PlanKey::for_matmul(2, 16, 2, 2, 2, PlaneKind::Sbmwc);
        let p = seed_plan(&tiny, 9);
        assert_eq!(p.backend, PlanBackend::Packed);
        assert_eq!(p.partition, Partition::Serial);

        // no pool: never plans a pooled partition
        let p = seed_plan(&lo, 1);
        assert_eq!(p.partition, Partition::Serial);
    }

    #[test]
    fn seed_plan_selects_rsr_in_the_low_precision_regime() {
        use crate::bits::packed::KernelFamily;
        for bits in [1u32, 2] {
            let key = PlanKey::for_matmul(64, 512, 64, bits, bits, PlaneKind::Sbmwc);
            assert!(prefers_rsr(&key));
            let p = seed_plan(&key, 9);
            assert_eq!(p.backend, PlanBackend::Packed);
            assert!(
                matches!(p.family, KernelFamily::Rsr { .. }),
                "{bits}b seed must be RSR, got {}",
                p.label()
            );
            assert_eq!(p.tile.k_chunks, 0, "RSR tiles never k-split");
        }
        // too few streamed passes to amortize the table build
        let thin = PlanKey::for_matmul(2, 512, 64, 1, 1, PlaneKind::Sbmwc);
        assert!(!prefers_rsr(&thin));
        // mid precision stays popcount
        let mid = PlanKey::for_matmul(64, 512, 64, 4, 4, PlaneKind::Sbmwc);
        assert_eq!(seed_plan(&mid, 9).family, KernelFamily::Popcount);
    }

    #[test]
    fn seed_plan_ksplits_huge_k_starved_grids() {
        use crate::bits::packed::KernelFamily;
        let hugek = PlanKey::for_matmul(1, 8192, 512, 8, 8, PlaneKind::Sbmwc);
        assert!(prefers_ksplit(&hugek, 8));
        let p = seed_plan(&hugek, 8);
        assert_eq!(p.partition, Partition::Stolen);
        assert_eq!(p.family, KernelFamily::Popcount);
        assert!(
            p.tile.k_chunks >= 2,
            "huge-k starved grid must seed a visible split, got {}",
            p.label()
        );
        assert!(p.tile.k_chunks <= 8192usize.div_ceil(64));

        // small k never qualifies, nor does a poolless host
        let smallk = PlanKey::for_matmul(1, 512, 512, 8, 8, PlaneKind::Sbmwc);
        assert!(!prefers_ksplit(&smallk, 8));
        assert!(!prefers_ksplit(&hugek, 1));
        assert_eq!(seed_plan(&smallk, 8).tile.k_chunks, 0);
    }
}
