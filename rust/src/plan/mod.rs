//! Shape-keyed execution planner — the autotuning subsystem that picks
//! the kernel/thread/tile plan per (shape, precision) and serves it
//! from a persistent plan cache (DESIGN.md §Planner).
//!
//! PRs 1–4 grew a large discrete plan space on the packed hot path —
//! five [`crate::bits::packed::PopcountKernel`] reducers, the pool
//! width, 2-D tile rows/cols, rowslice-vs-stealing partitioning, and a
//! native-vs-packed crossover that flips with operand precision
//! (`benches/eq_crossover.rs`) — but every knob was one static
//! server-wide value, so a deployment tuned for 256³ @ 8 b served
//! 1×512×4096 @ 3 b with the wrong plan. This module turns those knobs
//! into a self-tuning runtime, the BISMO-style "select a configuration
//! from a cost model at runtime" idea (PAPERS.md, Umuroglu et al.)
//! applied to the software stack — which is what bitSMM's
//! runtime-configurable 1–16-bit precision (PAPER.md §III) needs to
//! actually pay off when precision changes:
//!
//! * [`key`] — [`PlanKey`]: geometric shape buckets × exact precision
//!   × plane kind.
//! * [`exec`] — [`ExecPlan`]: one executable configuration, its
//!   candidate space, and [`ShapeRun`], the single plan executor the
//!   scheduler, calibrator, tuner, benches, and tests all share.
//! * [`cost`] — the built-in word-ops cost model
//!   (`bits_a·bits_b·⌈k/64⌉·m·n` vs native `m·k·n`).
//! * [`planner`] — [`Planner`]: the `Arc`-shared three-tier resolver
//!   (exact hit → nearest bucket/cost model → on-line calibration)
//!   with hit/miss/calibration telemetry.
//! * [`store`] — [`PlanFile`]: the versioned, host-fingerprinted
//!   `configs/plans.json` persistence.
//! * [`tune`] — the `bitsmm tune` sweep over the zoo shape census.
//!
//! The planner is **bit-transparent**: every candidate plan computes
//! identical integers (pinned against the serial packed oracle and the
//! native reference by the property suite), so planning changes speed,
//! never results.

pub mod cost;
pub mod exec;
pub mod key;
pub mod planner;
pub mod store;
pub mod tune;

pub use exec::{ExecPlan, Partition, PlanBackend, RunOut, ShapeRun};
pub use key::PlanKey;
pub use planner::{PlanStats, PlanTier, Planner, PlannerMode};
pub use store::{host_fingerprint, PlanFile};
pub use tune::{calibrate_shape, codebook_cols, run_tune, TuneOpts};
