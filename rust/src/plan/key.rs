//! Shape-class keys for the execution planner (DESIGN.md §Planner).
//!
//! A [`PlanKey`] names a *class* of matmuls, not one shape: the three
//! dimensions are bucketed geometrically (one bucket per power of two)
//! while the operand precisions and the stationary operand's plane
//! kind stay exact — precision is what flips the native/packed
//! crossover (`benches/eq_crossover.rs`), so it must never be blurred,
//! whereas a 100-row and a 128-row request want the same plan. The
//! bucket count is tiny (≤ 64 per dimension), so a serving run touches
//! a handful of keys and the plan cache stays small.

use crate::bits::plane::PlaneKind;

/// Geometric bucket of a dimension: the smallest `b` with `dim ≤ 2^b`
/// (`bucket(1) = 0`, `bucket(3) = bucket(4) = 2`, …). Zero-sized
/// dimensions share bucket 0 with `dim = 1`.
pub fn bucket(dim: usize) -> u8 {
    let dim = dim.max(1);
    (usize::BITS - (dim - 1).leading_zeros()) as u8
}

/// Representative (upper-bound) dimension of a bucket: `2^b`. The cost
/// model evaluates keys at this size so every member of the class gets
/// the plan its largest member would.
pub fn bucket_dim(b: u8) -> usize {
    1usize << b.min(usize::BITS as u8 - 2)
}

/// One shape class: bucketed `m × k × n`, exact operand precisions,
/// exact stationary plane kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// Bucket of the output-row dimension m.
    pub mb: u8,
    /// Bucket of the contracted dimension k.
    pub kb: u8,
    /// Bucket of the output-column dimension n.
    pub nb: u8,
    /// Streamed-operand precision (bits of A).
    pub bits_a: u8,
    /// Stationary-operand precision (bits of B).
    pub bits_b: u8,
    /// Plane kind of the stationary operand (the cached one).
    pub kind: PlaneKind,
}

impl PlanKey {
    pub fn for_matmul(
        m: usize,
        k: usize,
        n: usize,
        bits_a: u32,
        bits_b: u32,
        kind: PlaneKind,
    ) -> PlanKey {
        PlanKey {
            mb: bucket(m),
            kb: bucket(k),
            nb: bucket(n),
            bits_a: bits_a.min(255) as u8,
            bits_b: bits_b.min(255) as u8,
            kind,
        }
    }

    /// Representative shape of the class (each bucket's upper bound).
    pub fn rep_shape(&self) -> (usize, usize, usize) {
        (bucket_dim(self.mb), bucket_dim(self.kb), bucket_dim(self.nb))
    }

    /// Bucket distance to another key of the *same* precisions and
    /// plane kind (`None` otherwise — plans never cross precision or
    /// kind, that is exactly the blur the key exists to prevent).
    pub fn distance(&self, o: &PlanKey) -> Option<u32> {
        if self.bits_a != o.bits_a || self.bits_b != o.bits_b || self.kind != o.kind {
            return None;
        }
        let d = |a: u8, b: u8| a.abs_diff(b) as u32;
        Some(d(self.mb, o.mb) + d(self.kb, o.kb) + d(self.nb, o.nb))
    }

    /// Total sort key for stable summaries / plan files (PlaneKind has
    /// no `Ord`, so map it explicitly).
    pub fn sort_key(&self) -> (u8, u8, u8, u8, u8, u8) {
        let kind = match self.kind {
            PlaneKind::Sbmwc => 0u8,
            PlaneKind::Booth => 1,
        };
        (self.bits_a, self.bits_b, kind, self.mb, self.kb, self.nb)
    }
}

impl std::fmt::Display for PlanKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let (m, k, n) = self.rep_shape();
        write!(
            f,
            "{m}x{k}x{n} @{}x{}b {}",
            self.bits_a,
            self.bits_b,
            self.kind.name()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_geometric() {
        assert_eq!(bucket(0), 0);
        assert_eq!(bucket(1), 0);
        assert_eq!(bucket(2), 1);
        assert_eq!(bucket(3), 2);
        assert_eq!(bucket(4), 2);
        assert_eq!(bucket(5), 3);
        assert_eq!(bucket(64), 6);
        assert_eq!(bucket(65), 7);
        assert_eq!(bucket(4096), 12);
        for d in 1..=4096usize {
            let b = bucket(d);
            assert!(bucket_dim(b) >= d, "dim {d} escaped its bucket");
            assert!(b == 0 || bucket_dim(b - 1) < d, "dim {d} over-bucketed");
        }
    }

    #[test]
    fn keys_collapse_shapes_but_not_precision() {
        let a = PlanKey::for_matmul(100, 512, 4096, 8, 8, PlaneKind::Sbmwc);
        let b = PlanKey::for_matmul(128, 400, 3000, 8, 8, PlaneKind::Sbmwc);
        assert_eq!(a, b, "same buckets, same class");
        let c = PlanKey::for_matmul(100, 512, 4096, 3, 8, PlaneKind::Sbmwc);
        assert_ne!(a, c, "precision is exact, never bucketed");
        let d = PlanKey::for_matmul(100, 512, 4096, 8, 8, PlaneKind::Booth);
        assert_ne!(a, d, "plane kind is exact");
    }

    #[test]
    fn distance_is_bucket_manhattan_within_a_precision() {
        let a = PlanKey::for_matmul(1, 512, 4096, 8, 8, PlaneKind::Sbmwc);
        let b = PlanKey::for_matmul(4, 512, 2048, 8, 8, PlaneKind::Sbmwc);
        assert_eq!(a.distance(&b), Some(2 + 0 + 1));
        assert_eq!(a.distance(&a), Some(0));
        let c = PlanKey::for_matmul(1, 512, 4096, 4, 8, PlaneKind::Sbmwc);
        assert_eq!(a.distance(&c), None, "plans never cross precision");
        let d = PlanKey::for_matmul(1, 512, 4096, 8, 8, PlaneKind::Booth);
        assert_eq!(a.distance(&d), None, "plans never cross plane kind");
    }

    #[test]
    fn display_names_the_class() {
        let k = PlanKey::for_matmul(1, 512, 4096, 8, 6, PlaneKind::Sbmwc);
        assert_eq!(format!("{k}"), "1x512x4096 @8x6b sbmwc");
    }
}
