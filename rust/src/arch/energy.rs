//! Event-level energy model: turns the simulator's measured switching
//! activity ([`crate::sim::stats::MacStats`]) into energy/power, per
//! PDK.
//!
//! This is the quantitative back-end of the paper's power argument
//! (§III-A): the value toggle replaces a free-running counter and the
//! Booth adder fires only on multiplier-bit transitions, so *dynamic
//! power depends on the data*. Table II/III report totals for the
//! synthesis corner; this model exposes the per-event decomposition so
//! workload-dependent power (zeros vs random vs worst-case operands)
//! can be studied — the ablation the RTL reports cannot show.
//!
//! Calibration: the per-event energies are chosen so that a *random*
//! 16-bit workload reproduces the Table III power of both MAC variants
//! on each PDK (two equations — Booth and SBMwC — for the two free
//! parameters: adder-event energy and per-MAC clock/idle energy).

use crate::arch::pdk::{Pdk, PdkKind};
use crate::sim::stats::MacStats;

/// Per-event energies (joules) for one PDK at its target frequency.
#[derive(Debug, Clone)]
pub struct EnergyModel {
    pub pdk: PdkKind,
    /// Energy per adder firing (one `acc ± M<<i` at accumulator width).
    pub adder_j: f64,
    /// Energy per clock per MAC (clock tree + idle register load).
    pub clock_j: f64,
    /// Energy per multiplicand-assembly shift cycle.
    pub shift_j: f64,
}

/// Measured activity for a random 16-bit workload, per MAC-cycle:
/// Booth fires the adder on ~50% of multiplier cycles; SBMwC fires two
/// adders on ~50% (set bits). The multiplier-active fraction of eq. 8
/// time is n/(n+1) ≈ 1; the assembly shifts every streaming cycle.
const BOOTH_ADDERS_PER_CYCLE: f64 = 0.5;
const SBMWC_ADDERS_PER_CYCLE: f64 = 1.0;

impl EnergyModel {
    /// Calibrate against the PDK's Table III Booth power figure with a
    /// structure-informed split: the accumulator adder path is ~40% of
    /// per-MAC dynamic power at the 0.5 adders/cycle random-data duty,
    /// the clock tree + idle register load ~45%, and the multiplicand
    /// assembly shift ~15%. (An exact two-variant solve over-attributes
    /// to the adder on asap7, where the SBMwC penalty also includes its
    /// second accumulator bank and wider muxing — cf. the 2.09× power
    /// factor vs its 1.38× area factor.)
    pub fn calibrated(kind: PdkKind) -> EnergyModel {
        let pdk = Pdk::get(kind);
        let f = pdk.target_hz;
        let p_booth = pdk.power_per_mac_w;
        let adder_j = 0.40 * p_booth / (BOOTH_ADDERS_PER_CYCLE * f);
        let clock_j = 0.45 * p_booth / f;
        let shift_j = 0.15 * p_booth / f;
        let _ = SBMWC_ADDERS_PER_CYCLE; // documented duty for reporting
        EnergyModel {
            pdk: kind,
            adder_j,
            clock_j,
            shift_j,
        }
    }

    /// Energy for a run with the given aggregated activity, where
    /// `total_cycles` is the wall cycle count and `macs` the array
    /// size (clock energy is paid by every MAC every cycle).
    pub fn energy_j(&self, stats: &MacStats, total_cycles: u64, macs: u64) -> f64 {
        self.adder_j * stats.adder_ops as f64
            + self.shift_j * stats.mc_shift_cycles as f64
            + self.clock_j * (total_cycles * macs) as f64
    }

    /// Average power at the PDK target frequency.
    pub fn power_w(&self, stats: &MacStats, total_cycles: u64, macs: u64) -> f64 {
        if total_cycles == 0 {
            return 0.0;
        }
        let f = Pdk::get(self.pdk).target_hz;
        self.energy_j(stats, total_cycles, macs) / (total_cycles as f64 / f)
    }

    /// Energy per MAC operation (the efficiency metric GOPS/W inverts).
    pub fn energy_per_mac_j(&self, stats: &MacStats, total_cycles: u64, macs: u64, mac_ops: u64) -> f64 {
        if mac_ops == 0 {
            return 0.0;
        }
        self.energy_j(stats, total_cycles, macs) / mac_ops as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg32;
    use crate::sim::array::{SaConfig, SystolicArray};
    use crate::sim::mac_common::MacVariant;

    fn run_power(variant: MacVariant, data: impl Fn(&mut Pcg32) -> i32) -> f64 {
        let sa = SaConfig::new(4, 16, variant);
        let mut arr = SystolicArray::new(sa);
        let (m, k, n, bits) = (4usize, 64usize, 16usize, 16u32);
        let mut rng = Pcg32::new(0xe6e);
        let a: Vec<i32> = (0..m * k).map(|_| data(&mut rng)).collect();
        let b: Vec<i32> = (0..k * n).map(|_| data(&mut rng)).collect();
        let out = arr.matmul(&a, &b, m, k, n, bits).unwrap();
        let em = EnergyModel::calibrated(PdkKind::Asap7);
        em.power_w(&out.stats.mac, out.stats.total_cycles(), 64)
    }

    #[test]
    fn calibration_reproduces_table3_power_for_random_data() {
        // random 16-bit workload on 16×4 asap7 ≈ 0.102 W (Booth) and
        // ≈ 0.213 W (SBMwC); the streaming schedule has idle slack the
        // synthesis corner doesn't, so allow generous tolerance on the
        // absolute value but require the Booth < SBMwC ordering and the
        // right magnitude.
        let booth = run_power(MacVariant::Booth, |r| r.range_i32(-32768, 32767));
        let sbmwc = run_power(MacVariant::Sbmwc, |r| r.range_i32(-32768, 32767));
        assert!((0.05..0.2).contains(&booth), "booth power {booth}");
        assert!((0.1..0.4).contains(&sbmwc), "sbmwc power {sbmwc}");
        // adder-event doubling alone gives ~1.4×; the remaining SBMwC
        // penalty (second register bank) lives in the arch models
        assert!(sbmwc > booth * 1.25, "{sbmwc} vs {booth}");
    }

    #[test]
    fn data_dependent_power_zeros_cheapest() {
        let zeros = run_power(MacVariant::Booth, |_| 0);
        let random = run_power(MacVariant::Booth, |r| r.range_i32(-32768, 32767));
        // alternating bit pattern 0101… = 0x5555 maximizes Booth adder
        // activity (every pair differs)
        let worst = run_power(MacVariant::Booth, |_| 0x5555);
        assert!(zeros < random, "{zeros} !< {random}");
        assert!(random < worst, "{random} !< {worst}");
    }

    #[test]
    fn energy_per_mac_scales_inverse_with_utilization() {
        let em = EnergyModel::calibrated(PdkKind::Nangate45);
        let stats = MacStats {
            adder_ops: 1000,
            mc_shift_cycles: 2000,
            ..Default::default()
        };
        let busy = em.energy_per_mac_j(&stats, 1000, 64, 4096);
        let idle = em.energy_per_mac_j(&stats, 4000, 64, 4096);
        assert!(idle > busy, "idle cycles burn clock energy per op");
    }

    #[test]
    fn positive_calibrated_constants() {
        for kind in [PdkKind::Asap7, PdkKind::Nangate45] {
            let em = EnergyModel::calibrated(kind);
            assert!(em.adder_j > 0.0 && em.clock_j > 0.0 && em.shift_j > 0.0, "{em:?}");
        }
    }
}
