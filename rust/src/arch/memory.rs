//! Operand-delivery (memory interface) model.
//!
//! The SA consumes one bit per cycle per edge stream: `cols` vertical
//! multiplicand streams and `rows` horizontal multiplier streams
//! (§III-B) — so sustained compute needs only `rows + cols` bits/cycle
//! of operand bandwidth, *independent of precision* (a wider operand
//! takes proportionally more cycles, eq. 8). That is the quantified
//! version of the paper's §V observation: weights can stay big-endian
//! in memory, activations little-endian, and no in-memory data
//! manipulation is needed — the P2S converters do the (de)serialization
//! on the fly.
//!
//! This module sizes the scratchpad for a tile schedule and computes
//! the bandwidth-limited throughput bound (a memory roofline for the
//! accelerator), which the DSE example reports alongside the compute
//! bound of eq. 10.

use crate::sim::array::SaConfig;

/// Memory interface description.
#[derive(Debug, Clone, Copy)]
pub struct MemoryInterface {
    /// Bits deliverable per cycle to the accelerator (bus width ×
    /// utilization).
    pub bits_per_cycle: f64,
    /// Scratchpad capacity in bytes.
    pub scratchpad_bytes: usize,
}

impl Default for MemoryInterface {
    fn default() -> Self {
        // a 64-bit on-chip bus at full rate and a 64 KiB scratchpad —
        // representative of the embedded SoCs the paper targets
        MemoryInterface {
            bits_per_cycle: 64.0,
            scratchpad_bytes: 64 * 1024,
        }
    }
}

/// Operand-delivery requirement of one SA: bits per cycle during
/// streaming (each active edge stream consumes one bit per cycle).
pub fn required_bits_per_cycle(sa: &SaConfig) -> f64 {
    (sa.rows + sa.cols) as f64
}

/// Scratchpad bytes needed to double-buffer one `m×k×n` tile at `bits`
/// precision: A tile + B tile + output accumulators, ×2 for ping-pong.
pub fn tile_scratchpad_bytes(m: usize, k: usize, n: usize, bits: u32, acc_bits: u32) -> usize {
    let a_bits = m * k * bits as usize;
    let b_bits = k * n * bits as usize;
    let o_bits = m * n * acc_bits as usize;
    2 * (a_bits + b_bits + o_bits).div_ceil(8)
}

/// Bandwidth-limited OP/cycle bound: operand streaming for a k-length
/// dot product moves `(rows + cols)·(k+1)·bits` bits (eq. 8 schedule)
/// to produce `rows·cols·k` MACs; if the interface can deliver only
/// `B` bits/cycle the achievable rate caps at
/// `compute_peak × min(1, B / (rows+cols))`.
pub fn bandwidth_bound_op_per_cycle(sa: &SaConfig, bits: u32, iface: &MemoryInterface) -> f64 {
    let compute_peak = crate::arch::throughput::peak_op_per_cycle(sa.cols as u64, sa.rows as u64, bits);
    let supply_ratio = (iface.bits_per_cycle / required_bits_per_cycle(sa)).min(1.0);
    compute_peak * supply_ratio
}

/// Arithmetic intensity: MAC operations per operand byte moved (the
/// roofline x-axis). `m·n/(m+n)` scaled by `8/bits` — it grows with
/// the output-tile extents (each A row is reused across all n columns
/// and vice versa) and is independent of k, which scales operands and
/// MACs alike.
pub fn arithmetic_intensity(m: usize, k: usize, n: usize, bits: u32) -> f64 {
    let macs = (m * k * n) as f64;
    let bytes = ((m * k + k * n) * bits as usize) as f64 / 8.0;
    macs / bytes
}

/// Whether a tile schedule fits the scratchpad with double buffering.
pub fn fits_scratchpad(sa: &SaConfig, k: usize, bits: u32, iface: &MemoryInterface) -> bool {
    tile_scratchpad_bytes(sa.rows, k, sa.cols, bits, sa.acc_bits) <= iface.scratchpad_bytes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::mac_common::MacVariant;

    fn sa() -> SaConfig {
        SaConfig::new(4, 16, MacVariant::Booth)
    }

    #[test]
    fn bandwidth_requirement_is_precision_independent() {
        let s = sa();
        assert_eq!(required_bits_per_cycle(&s), 20.0);
        // same requirement at any operand width — the bit-serial win
        let iface = MemoryInterface::default();
        let b4 = bandwidth_bound_op_per_cycle(&s, 4, &iface);
        let b16 = bandwidth_bound_op_per_cycle(&s, 16, &iface);
        // bound scales with compute peak only (4× more OP/c at 4 bits)
        assert!((b4 / b16 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn narrow_bus_caps_throughput() {
        let s = SaConfig::new(16, 64, MacVariant::Booth); // 80 streams
        let narrow = MemoryInterface {
            bits_per_cycle: 20.0,
            ..Default::default()
        };
        let wide = MemoryInterface {
            bits_per_cycle: 200.0,
            ..Default::default()
        };
        let capped = bandwidth_bound_op_per_cycle(&s, 8, &narrow);
        let full = bandwidth_bound_op_per_cycle(&s, 8, &wide);
        assert!((capped / full - 20.0 / 80.0).abs() < 1e-9);
    }

    #[test]
    fn scratchpad_sizing() {
        // 4×64×16 at 8 bits, 48-bit accumulators:
        // A: 4·64·8 = 2048 b; B: 64·16·8 = 8192 b; O: 4·16·48 = 3072 b
        // total (2048+8192+3072)/8 = 1664 bytes, ×2 = 3328
        assert_eq!(tile_scratchpad_bytes(4, 64, 16, 8, 48), 3328);
        assert!(fits_scratchpad(&sa(), 64, 8, &MemoryInterface::default()));
        // absurdly long dot products eventually exceed 64 KiB
        assert!(!fits_scratchpad(&sa(), 200_000, 16, &MemoryInterface::default()));
    }

    #[test]
    fn intensity_grows_with_tile_area_not_k() {
        // independent of k (operands and MACs both scale with k)
        let i_k16 = arithmetic_intensity(4, 16, 16, 8);
        let i_k1024 = arithmetic_intensity(4, 1024, 16, 8);
        assert!((i_k16 - i_k1024).abs() < 1e-12);
        // larger output tiles reuse operands more
        assert!(arithmetic_intensity(16, 64, 64, 8) > i_k16);
        // narrower operands raise MACs-per-byte
        assert!(arithmetic_intensity(4, 16, 16, 4) > i_k16);
    }
}
