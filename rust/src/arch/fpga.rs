//! ZCU104 FPGA implementation model — regenerates **Table II**.
//!
//! bitSMM uses no BRAMs or DSPs (§IV-B): only LUTs and FFs. The paper
//! observes that resource usage scales *superlinearly* — each 4× step
//! in MAC count costs more than 4× the LUTs/FFs (routing/fan-out
//! pressure). We model that with power laws fitted on the paper's own
//! three Booth design points:
//!
//! ```text
//! LUT(macs) = 61.42 · macs^1.0969        (≤ 8.5% residual; the 32×8
//!                                         point sits above the law —
//!                                         Vivado P&R noise)
//! FF(macs)  = 115.56 · macs^1.0376       (≤ 3% residual)
//! P(W)      = 0.8125 + 2.063e-5 · (LUT + FF) · activity
//! ```
//!
//! The SBMwC variant multiplies LUTs by 2.03 (second full adder plus
//! the difference datapath), FFs by 1.23 (second accumulator register)
//! and dynamic power by an activity factor of 1.84 — both adders fire
//! on every set multiplier bit, versus Booth's single adder firing only
//! on bit transitions (§III-A); the unit tests cross-check this factor
//! against the cycle-accurate simulator's measured adder duty ratio.

use crate::arch::throughput::{gops, peak_op_per_cycle};
use crate::sim::array::SaConfig;
use crate::sim::mac_common::MacVariant;

/// Calibrated ZCU104 model constants (fit: DESIGN.md §Per-experiment,
/// residuals asserted in tests below).
#[derive(Debug, Clone)]
pub struct FpgaModel {
    /// LUT power law: `lut_coeff · macs ^ lut_exp`.
    pub lut_coeff: f64,
    pub lut_exp: f64,
    /// FF power law.
    pub ff_coeff: f64,
    pub ff_exp: f64,
    /// Static + board power floor (W).
    pub static_power_w: f64,
    /// Dynamic power per (LUT+FF) at the 300 MHz target (W/cell).
    pub dyn_w_per_cell: f64,
    /// SBMwC multipliers.
    pub sbmwc_lut_factor: f64,
    pub sbmwc_ff_factor: f64,
    pub sbmwc_activity_factor: f64,
    /// Target clock of the FPGA implementation (Hz).
    pub clock_hz: f64,
}

impl Default for FpgaModel {
    fn default() -> Self {
        FpgaModel {
            lut_coeff: 61.4163,
            lut_exp: 1.0969,
            ff_coeff: 115.5648,
            ff_exp: 1.0376,
            static_power_w: 0.8125,
            dyn_w_per_cell: 2.063e-5,
            sbmwc_lut_factor: 2.0281,
            sbmwc_ff_factor: 1.2334,
            sbmwc_activity_factor: 1.8415,
            clock_hz: 300e6,
        }
    }
}

/// One synthesized design point — a Table II row.
#[derive(Debug, Clone)]
pub struct FpgaImplementation {
    pub config: SaConfig,
    pub luts: u64,
    pub ffs: u64,
    pub power_w: f64,
    /// Peak GOPS at the 300 MHz target and 16-bit operands (the table's
    /// operating point).
    pub gops: f64,
    pub gops_per_w: f64,
}

impl FpgaModel {
    /// Evaluate the model for one SA configuration at `bits`-wide
    /// operands (Table II uses 16).
    pub fn implement(&self, config: SaConfig, bits: u32) -> FpgaImplementation {
        let macs = config.macs() as f64;
        let (lut_f, ff_f, act) = match config.variant {
            MacVariant::Booth => (1.0, 1.0, 1.0),
            MacVariant::Sbmwc => (
                self.sbmwc_lut_factor,
                self.sbmwc_ff_factor,
                self.sbmwc_activity_factor,
            ),
        };
        let luts = self.lut_coeff * macs.powf(self.lut_exp) * lut_f;
        let ffs = self.ff_coeff * macs.powf(self.ff_exp) * ff_f;
        let power = self.static_power_w + self.dyn_w_per_cell * (luts + ffs) * act;
        let g = gops(
            peak_op_per_cycle(config.cols as u64, config.rows as u64, bits),
            self.clock_hz,
        );
        FpgaImplementation {
            config,
            luts: luts.round() as u64,
            ffs: ffs.round() as u64,
            power_w: power,
            gops: g,
            gops_per_w: g / power,
        }
    }

    /// The four Table II rows, in the paper's order.
    pub fn table2_rows(&self) -> Vec<FpgaImplementation> {
        [
            SaConfig::new(4, 16, MacVariant::Booth),
            SaConfig::new(4, 16, MacVariant::Sbmwc),
            SaConfig::new(8, 32, MacVariant::Booth),
            SaConfig::new(16, 64, MacVariant::Booth),
        ]
        .into_iter()
        .map(|c| self.implement(c, 16))
        .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper Table II, for calibration assertions.
    const TABLE2: [(&str, u64, u64, f64, f64, f64); 4] = [
        ("16x4", 5630, 8762, 1.13, 1.2, 1.062),
        ("16x4-sbmwc", 11418, 10807, 1.657, 1.2, 0.724),
        ("32x8", 29355, 35490, 2.125, 4.8, 2.259),
        ("64x16", 117836, 155586, 6.459, 19.2, 2.973),
    ];

    fn rel_err(got: f64, want: f64) -> f64 {
        (got - want).abs() / want
    }

    #[test]
    fn reproduces_table2_within_tolerance() {
        let rows = FpgaModel::default().table2_rows();
        for (row, (label, luts, ffs, p, g, gpw)) in rows.iter().zip(TABLE2) {
            assert!(
                rel_err(row.luts as f64, luts as f64) < 0.09,
                "{label} LUTs: {} vs {}",
                row.luts,
                luts
            );
            assert!(
                rel_err(row.ffs as f64, ffs as f64) < 0.03,
                "{label} FFs: {} vs {}",
                row.ffs,
                ffs
            );
            assert!(rel_err(row.power_w, p) < 0.03, "{label} P: {} vs {}", row.power_w, p);
            assert!(rel_err(row.gops, g) < 1e-9, "{label} GOPS");
            assert!(rel_err(row.gops_per_w, gpw) < 0.04, "{label} GOPS/W");
        }
    }

    #[test]
    fn superlinear_scaling_as_observed_in_section4b() {
        // "resource usage increases by more than 4× between successive
        // configurations"
        let m = FpgaModel::default();
        let s = m.implement(SaConfig::new(4, 16, MacVariant::Booth), 16);
        let med = m.implement(SaConfig::new(8, 32, MacVariant::Booth), 16);
        let l = m.implement(SaConfig::new(16, 64, MacVariant::Booth), 16);
        assert!(med.luts as f64 / s.luts as f64 > 4.0);
        assert!(l.luts as f64 / med.luts as f64 > 4.0);
        assert!(med.ffs as f64 / s.ffs as f64 > 4.0);
        assert!(l.ffs as f64 / med.ffs as f64 > 4.0);
    }

    #[test]
    fn orderings_match_paper_narrative() {
        let m = FpgaModel::default();
        let rows = m.table2_rows();
        // SBMwC consumes more resources and more power than Booth at 16×4
        assert!(rows[1].luts > rows[0].luts);
        assert!(rows[1].ffs > rows[0].ffs);
        assert!(rows[1].power_w > rows[0].power_w);
        // Booth beats SBMwC on GOPS/W
        assert!(rows[0].gops_per_w > rows[1].gops_per_w);
        // 64×16 has the best GOPS/W among the FPGA configurations
        let best = rows
            .iter()
            .max_by(|a, b| a.gops_per_w.total_cmp(&b.gops_per_w))
            .unwrap();
        assert_eq!(best.config.label(), "64x16");
    }

    #[test]
    fn activity_factor_consistent_with_simulator_duty() {
        // The calibrated SBMwC activity factor (1.84) should be of the
        // same order as the measured adder-duty ratio between variants
        // on random data. Booth fires on transitions (~0.5/bit), SBMwC
        // fires two adders on set bits (~1.0/bit) → ratio ≈ 2.
        use crate::prng::Pcg32;
        use crate::sim::driver::mac_dot_with_stats;
        let mut rng = Pcg32::new(0xac7);
        let mc: Vec<i32> = (0..256).map(|_| rng.range_i32(-32768, 32767)).collect();
        let ml: Vec<i32> = (0..256).map(|_| rng.range_i32(-32768, 32767)).collect();
        let booth = mac_dot_with_stats(MacVariant::Booth, &mc, &ml, 16, 48);
        let sbmwc = mac_dot_with_stats(MacVariant::Sbmwc, &mc, &ml, 16, 48);
        let ratio = sbmwc.2.adder_duty() / booth.2.adder_duty();
        assert!(
            (1.5..=2.5).contains(&ratio),
            "measured activity ratio {ratio} inconsistent with calibration"
        );
    }
}
