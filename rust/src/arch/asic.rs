//! ASIC physical-implementation model — regenerates **Table III**.
//!
//! Mirrors the paper's OpenROAD 2.0 flow outputs: maximum frequency,
//! cell area, and estimated power for each SA topology on each PDK.
//! Area and power scale proportionally with SA size (the paper's
//! observation), which yields the near-constant GOPS/W across
//! implementations that Table III shows; frequency declines gently with
//! design size. GOPS/area and GOPS/W use the throughput at the target
//! frequency, peak GOPS uses the maximum frequency — exactly the
//! paper's reporting convention.

use crate::arch::pdk::{Pdk, PdkKind};
use crate::arch::throughput::{gops, peak_op_per_cycle};
use crate::sim::array::SaConfig;
use crate::sim::mac_common::MacVariant;

/// The ASIC model: a PDK plus the evaluation operand width.
#[derive(Debug, Clone)]
pub struct AsicModel {
    pub pdk: Pdk,
}

/// One Table III row.
#[derive(Debug, Clone)]
pub struct AsicImplementation {
    pub config: SaConfig,
    pub pdk_kind: PdkKind,
    pub max_freq_mhz: f64,
    pub area_mm2: f64,
    pub power_w: f64,
    /// Peak GOPS at maximum frequency (16-bit operands).
    pub peak_gops_at_fmax: f64,
    /// GOPS at the PDK target frequency.
    pub gops_at_target: f64,
    /// GOPS/mm² at target frequency.
    pub gops_per_mm2: f64,
    /// GOPS/W at target frequency.
    pub gops_per_w: f64,
}

impl AsicModel {
    pub fn new(kind: PdkKind) -> Self {
        AsicModel { pdk: Pdk::get(kind) }
    }

    /// Evaluate one design point at `bits`-wide operands (Table III
    /// uses 16).
    pub fn implement(&self, config: SaConfig, bits: u32) -> AsicImplementation {
        let macs = config.macs();
        let (area_f, power_f) = match config.variant {
            MacVariant::Booth => (1.0, 1.0),
            MacVariant::Sbmwc => (self.pdk.sbmwc_area_factor, self.pdk.sbmwc_power_factor),
        };
        let area = self.pdk.area_per_mac_mm2 * macs as f64 * area_f;
        let power = self.pdk.power_per_mac_w * macs as f64 * power_f;
        let fmax = self.pdk.fmax_mhz(macs, config.variant);
        let opc = peak_op_per_cycle(config.cols as u64, config.rows as u64, bits);
        let peak = gops(opc, fmax * 1e6);
        let at_target = gops(opc, self.pdk.target_hz);
        AsicImplementation {
            config,
            pdk_kind: self.pdk.kind,
            max_freq_mhz: fmax,
            area_mm2: area,
            power_w: power,
            peak_gops_at_fmax: peak,
            gops_at_target: at_target,
            gops_per_mm2: at_target / area,
            gops_per_w: at_target / power,
        }
    }

    /// The four rows the paper implements per PDK, in Table III order.
    pub fn table3_rows(&self) -> Vec<AsicImplementation> {
        [
            SaConfig::new(4, 16, MacVariant::Booth),
            SaConfig::new(4, 16, MacVariant::Sbmwc),
            SaConfig::new(8, 32, MacVariant::Booth),
            SaConfig::new(16, 64, MacVariant::Booth),
        ]
        .into_iter()
        .map(|c| self.implement(c, 16))
        .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper Table III: (label, fmax MHz, area mm², power W,
    /// peak GOPS, GOPS@target, GOPS/mm², GOPS/W).
    const ASAP7: [(&str, f64, f64, f64, f64, f64, f64, f64); 4] = [
        ("16x4", 1183., 0.008, 0.102, 4.73, 4., 500., 39.2),
        ("16x4-sbmwc", 1311., 0.011, 0.213, 5.24, 4., 364., 18.8),
        ("32x8", 1124., 0.029, 0.403, 17.98, 16., 552., 39.7),
        ("64x16", 1144., 0.118, 1.57, 73.22, 64., 542., 40.8),
    ];
    const NANGATE45: [(&str, f64, f64, f64, f64, f64, f64, f64); 4] = [
        ("16x4", 748., 0.094, 0.214, 2.99, 2., 21.28, 9.35),
        ("16x4-sbmwc", 730., 0.131, 0.305, 2.92, 2., 15.27, 6.56),
        ("32x8", 685., 0.378, 0.809, 10.96, 8., 21.16, 9.89),
        ("64x16", 643., 1.484, 3.28, 41.15, 32., 21.56, 9.76),
    ];

    fn check_rows(kind: PdkKind, expect: &[(&str, f64, f64, f64, f64, f64, f64, f64); 4]) {
        let rows = AsicModel::new(kind).table3_rows();
        for (row, e) in rows.iter().zip(expect) {
            let rel = |got: f64, want: f64| (got - want).abs() / want;
            assert!(rel(row.max_freq_mhz, e.1) < 0.045, "{kind:?} {} fmax {} vs {}", e.0, row.max_freq_mhz, e.1);
            assert!(rel(row.area_mm2, e.2) < 0.07, "{kind:?} {} area {} vs {}", e.0, row.area_mm2, e.2);
            assert!(rel(row.power_w, e.3) < 0.07, "{kind:?} {} power {} vs {}", e.0, row.power_w, e.3);
            assert!(rel(row.gops_at_target, e.5) < 1e-9, "{kind:?} {} gops@target", e.0);
            assert!(rel(row.gops_per_mm2, e.6) < 0.08, "{kind:?} {} gops/mm2 {} vs {}", e.0, row.gops_per_mm2, e.6);
            assert!(rel(row.gops_per_w, e.7) < 0.08, "{kind:?} {} gops/W {} vs {}", e.0, row.gops_per_w, e.7);
        }
    }

    #[test]
    fn reproduces_table3_asap7() {
        check_rows(PdkKind::Asap7, &ASAP7);
    }

    #[test]
    fn reproduces_table3_nangate45() {
        check_rows(PdkKind::Nangate45, &NANGATE45);
    }

    #[test]
    fn consistent_gops_per_watt_across_sizes() {
        // "Notably, this results in a consistent throughput-per-watt
        // across all implementations."
        for kind in [PdkKind::Asap7, PdkKind::Nangate45] {
            let rows = AsicModel::new(kind).table3_rows();
            let booth: Vec<f64> = rows
                .iter()
                .filter(|r| r.config.variant == MacVariant::Booth)
                .map(|r| r.gops_per_w)
                .collect();
            let mean = booth.iter().sum::<f64>() / booth.len() as f64;
            for g in &booth {
                assert!((g - mean).abs() / mean < 0.05, "{kind:?}: {booth:?}");
            }
        }
    }

    #[test]
    fn headline_claims() {
        // "in asap7 it achieves up to 73.22 GOPS, 552 GOPS/mm², and
        // 40.8 GOPS/W"
        let rows = AsicModel::new(PdkKind::Asap7).table3_rows();
        let peak = rows.iter().map(|r| r.peak_gops_at_fmax).fold(0., f64::max);
        let per_mm2 = rows.iter().map(|r| r.gops_per_mm2).fold(0., f64::max);
        let per_w = rows.iter().map(|r| r.gops_per_w).fold(0., f64::max);
        assert!((peak - 73.22).abs() / 73.22 < 0.05, "peak {peak}");
        assert!((per_mm2 - 552.).abs() / 552. < 0.08, "per_mm2 {per_mm2}");
        assert!((per_w - 40.8).abs() / 40.8 < 0.08, "per_w {per_w}");
    }

    #[test]
    fn smaller_arrays_close_timing_faster() {
        // "The maximum achievable frequency is higher for smaller SAs"
        for kind in [PdkKind::Asap7, PdkKind::Nangate45] {
            let pdk = Pdk::get(kind);
            assert!(pdk.fmax_mhz(64, MacVariant::Booth) > pdk.fmax_mhz(1024, MacVariant::Booth));
        }
    }
}
