//! Process design kit (PDK) parameter sets for the ASIC model.
//!
//! The paper implements bitSMM with OpenROAD 2.0 using two open PDKs:
//! **asap7** (7 nm predictive FinFET [12]) targeting 1 GHz and
//! **nangate45** (45 nm [13]) targeting 500 MHz. Constants below are
//! calibrated on the paper's Table III design points (per-MAC area and
//! power are near-constant across sizes — "area and power scale
//! proportionally with SA size"; maximum frequency declines gently with
//! array size, modelled linearly in log2(#MACs)).

use crate::sim::mac_common::MacVariant;

/// Which PDK.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PdkKind {
    Asap7,
    Nangate45,
}

impl PdkKind {
    pub fn name(self) -> &'static str {
        match self {
            PdkKind::Asap7 => "asap7 (7nm)",
            PdkKind::Nangate45 => "nangate45 (45nm)",
        }
    }
}

impl std::str::FromStr for PdkKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "asap7" | "7nm" => Ok(PdkKind::Asap7),
            "nangate45" | "45nm" => Ok(PdkKind::Nangate45),
            other => anyhow::bail!("unknown PDK '{other}' (expected asap7|nangate45)"),
        }
    }
}

/// Calibrated physical parameters of one PDK.
#[derive(Debug, Clone)]
pub struct Pdk {
    pub kind: PdkKind,
    /// Feature size (nm), informational.
    pub node_nm: u32,
    /// Cell area per Booth MAC including its share of P2S/readout
    /// (mm²/MAC).
    pub area_per_mac_mm2: f64,
    /// Power per Booth MAC at the PDK's target frequency (W/MAC).
    pub power_per_mac_w: f64,
    /// Max-frequency model: `fmax = fmax0 − fmax_slope · log2(#MACs)`
    /// (MHz).
    pub fmax0_mhz: f64,
    pub fmax_slope_mhz: f64,
    /// SBMwC multipliers (second adder + difference accumulator).
    pub sbmwc_area_factor: f64,
    pub sbmwc_power_factor: f64,
    pub sbmwc_fmax_factor: f64,
    /// The paper's target implementation frequency (Hz).
    pub target_hz: f64,
}

impl Pdk {
    pub fn get(kind: PdkKind) -> Pdk {
        match kind {
            // Fitted on Table III asap7 rows (see DESIGN.md).
            PdkKind::Asap7 => Pdk {
                kind,
                node_nm: 7,
                area_per_mac_mm2: 1.178e-4, // mean of 1.250/1.133/1.152e-4
                power_per_mac_w: 1.567e-3,  // mean of 1.594/1.574/1.533e-3
                fmax0_mhz: 1228.3,
                fmax_slope_mhz: 9.75,
                sbmwc_area_factor: 1.375,
                sbmwc_power_factor: 2.088,
                sbmwc_fmax_factor: 1.108, // smaller design closed faster
                target_hz: 1e9,
            },
            // Fitted on Table III nangate45 rows.
            PdkKind::Nangate45 => Pdk {
                kind,
                node_nm: 45,
                area_per_mac_mm2: 1.465e-3,
                power_per_mac_w: 3.236e-3,
                fmax0_mhz: 902.0,
                fmax_slope_mhz: 26.25,
                sbmwc_area_factor: 1.394,
                sbmwc_power_factor: 1.425,
                sbmwc_fmax_factor: 0.976,
                target_hz: 500e6,
            },
        }
    }

    /// Maximum frequency (MHz) for a design of `macs` MACs.
    pub fn fmax_mhz(&self, macs: usize, variant: MacVariant) -> f64 {
        let base = self.fmax0_mhz - self.fmax_slope_mhz * (macs as f64).log2();
        match variant {
            MacVariant::Booth => base,
            MacVariant::Sbmwc => base * self.sbmwc_fmax_factor,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_kinds() {
        assert_eq!("asap7".parse::<PdkKind>().unwrap(), PdkKind::Asap7);
        assert_eq!("NANGATE45".parse::<PdkKind>().unwrap(), PdkKind::Nangate45);
        assert!("tsmc5".parse::<PdkKind>().is_err());
    }

    #[test]
    fn fmax_matches_table3_within_tolerance() {
        // Table III Max Freq. column (Booth rows)
        let cases = [
            (PdkKind::Asap7, 64usize, 1183.0f64),
            (PdkKind::Asap7, 256, 1124.0),
            (PdkKind::Asap7, 1024, 1144.0),
            (PdkKind::Nangate45, 64, 748.0),
            (PdkKind::Nangate45, 256, 685.0),
            (PdkKind::Nangate45, 1024, 643.0),
        ];
        for (kind, macs, want) in cases {
            let got = Pdk::get(kind).fmax_mhz(macs, MacVariant::Booth);
            assert!(
                (got - want).abs() / want < 0.035,
                "{kind:?} {macs} MACs: {got} vs {want}"
            );
        }
    }

    #[test]
    fn seven_nm_is_denser_and_cooler() {
        let a7 = Pdk::get(PdkKind::Asap7);
        let n45 = Pdk::get(PdkKind::Nangate45);
        assert!(a7.area_per_mac_mm2 < n45.area_per_mac_mm2 / 5.0);
        assert!(a7.power_per_mac_w < n45.power_per_mac_w);
        assert!(a7.fmax0_mhz > n45.fmax0_mhz);
    }
}
