//! The paper's cycle-count and throughput equations (eqs. 6–10).
//!
//! Conventions (see DESIGN.md "Cycle/time model"): the paper counts
//! **one OP per multiply-accumulate result**, so that e.g. the 64×16
//! array at 16-bit operands and 300 MHz yields
//! `64·16/16 × 300 MHz = 19.2 GOPS` — exactly Table II's headline.

/// Documentation constant: the paper's OPS convention (1 OP = 1 MAC).
pub const PEAK_OPS_CONVENTION: &str = "1 OP = 1 multiply-accumulate";

/// eq. 6 — cycles for a vector dot product in the BISMO/Loom
/// decomposition (no intra-MAC parallelism): every multiplicand bit is
/// paired with every multiplier bit.
pub fn bismo_cycles(b_mc: u64, b_ml: u64, n_values: u64) -> u64 {
    b_mc * b_ml * n_values
}

/// eq. 7 — the common operand width both streams are extended to.
pub fn b_max(b_mc: u32, b_ml: u32) -> u32 {
    b_mc.max(b_ml)
}

/// eq. 8 — cycles for a vector dot product on a bitSMM MAC: the
/// multiplicand leads by `b_max`, then `n` multiplier slots follow.
pub fn bitsmm_cycles(n_values: u64, b_max: u32) -> u64 {
    (n_values + 1) * b_max as u64
}

/// eq. 9 — achieved operations per cycle for a full matrix
/// multiplication on an `sa_height × sa_width` array (rows × cols),
/// contracting dimension `n`: the numerator is the total MAC count,
/// the denominator the compute latency (eq. 8) plus the readout
/// latency (`sa_width × sa_height` cycles).
pub fn op_per_cycle(
    n: u64,
    matrix_a_width: u64,
    matrix_b_height: u64,
    bit_width: u32,
    sa_width: u64,
    sa_height: u64,
) -> f64 {
    let ops = (n * matrix_a_width * matrix_b_height) as f64;
    let cycles = ((1 + n) * bit_width as u64 + sa_width * sa_height) as f64;
    ops / cycles
}

/// eq. 10 — peak operations per cycle (n → ∞, matrices matching the SA
/// dimensions): `SA_width × SA_height / bitWidth`.
pub fn peak_op_per_cycle(sa_width: u64, sa_height: u64, bit_width: u32) -> f64 {
    (sa_width * sa_height) as f64 / bit_width as f64
}

/// OPS at a clock frequency: `OP/cycle × f`.
pub fn gops(op_per_cycle: f64, freq_hz: f64) -> f64 {
    op_per_cycle * freq_hz / 1e9
}

/// §III-A latency comparison: bitSMM (eq. 8, with both operands
/// extended to `b_max`) vs the BISMO-style decomposition (eq. 6).
/// Returns `(bitsmm, bismo)` cycles. The paper's claim: bitSMM is
/// lower for all `b_mc > 1 && b_ml > 1`, and they tie only at
/// `b_mc = b_ml = 2` (asymptotically in n).
pub fn latency_pair(b_mc: u32, b_ml: u32, n_values: u64) -> (u64, u64) {
    (
        bitsmm_cycles(n_values, b_max(b_mc, b_ml)),
        bismo_cycles(b_mc as u64, b_ml as u64, n_values),
    )
}

/// The Fig. 6 series: peak OP/cycle as a function of operand bit width
/// for one SA topology.
pub fn fig6_series(sa_width: u64, sa_height: u64, bit_widths: impl Iterator<Item = u32>) -> Vec<(u32, f64)> {
    bit_widths
        .map(|b| (b, peak_op_per_cycle(sa_width, sa_height, b)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_headline_numbers_from_eq10() {
        // Table II GOPS at 300 MHz, 16-bit operands
        for (cols, rows, expect) in [(16u64, 4u64, 1.2f64), (32, 8, 4.8), (64, 16, 19.2)] {
            let g = gops(peak_op_per_cycle(cols, rows, 16), 300e6);
            assert!((g - expect).abs() < 1e-9, "{cols}x{rows}: {g}");
        }
    }

    #[test]
    fn table3_peak_gops_at_max_freq() {
        // Table III "Peak GOPS (@ Max Freq.)" column, 16-bit operands
        let cases = [
            (16u64, 4u64, 1183e6, 4.73f64),
            (32, 8, 1124e6, 17.98),
            (64, 16, 1144e6, 73.22),
            (16, 4, 748e6, 2.99),
            (32, 8, 685e6, 10.96),
            (64, 16, 643e6, 41.15),
        ];
        for (cols, rows, f, expect) in cases {
            let g = gops(peak_op_per_cycle(cols, rows, 16), f);
            assert!(
                (g - expect).abs() / expect < 0.005,
                "{cols}x{rows}@{f}: got {g} want {expect}"
            );
        }
    }

    #[test]
    fn eq9_approaches_eq10_as_n_grows() {
        let (w, h, b) = (64u64, 16u64, 8u32);
        let peak = peak_op_per_cycle(w, h, b);
        let at_small = op_per_cycle(64, w, h, b, w, h);
        let at_large = op_per_cycle(1_000_000, w, h, b, w, h);
        assert!(at_small < peak);
        assert!((at_large - peak).abs() / peak < 1e-3);
    }

    #[test]
    fn crossover_claim_of_section3a() {
        // lower latency for all b_mc>1 && b_ml>1 (except the 2,2 tie)
        let n = 1_000u64;
        for b_mc in 2..=16u32 {
            for b_ml in 2..=16u32 {
                let (ours, theirs) = latency_pair(b_mc, b_ml, n);
                if b_mc == 2 && b_ml == 2 {
                    // matches prior approaches only at 2×2 (asymptotically)
                    assert!(ours as f64 / theirs as f64 <= 1.0 + 2.0 / n as f64);
                } else {
                    assert!(ours < theirs, "b=({b_mc},{b_ml}): {ours} !< {theirs}");
                }
            }
        }
        // …and loses when an operand is 1-bit wide (the BISMO advantage)
        let (ours, theirs) = latency_pair(1, 16, n);
        assert!(ours > theirs);
    }

    #[test]
    fn fig6_endpoints() {
        let s = fig6_series(64, 16, 1..=16);
        assert_eq!(s.first().unwrap(), &(1, 1024.0));
        assert_eq!(s.last().unwrap(), &(16, 64.0));
        // monotone decreasing in bit width
        assert!(s.windows(2).all(|w| w[0].1 >= w[1].1));
    }
}
