//! Analytical architecture models (paper §III-B, §IV).
//!
//! Three model families, each regenerating one paper artefact:
//!
//! * [`throughput`] — the paper's cycle/throughput equations
//!   (eqs. 6–10); regenerates **Fig. 6** and the eq.6-vs-eq.8 latency
//!   crossover claim of §III-A.
//! * [`fpga`] — ZCU104 resource/power model; regenerates **Table II**.
//! * [`asic`] + [`pdk`] — asap7/nangate45 physical-implementation
//!   models; regenerate **Table III**.
//!
//! The FPGA/ASIC models are *calibrated*: per-MAC costs, superlinear
//! interconnect exponents, and per-PDK constants are fitted on the
//! paper's own reported design points (the calibration residuals are
//! asserted to a few percent by unit tests). They exist so that the
//! bench harness can sweep topologies the paper never synthesized —
//! design-space exploration, the `dse` example — while reproducing the
//! published rows exactly where they overlap. See DESIGN.md's
//! substitution table for why this stands in for Vivado/OpenROAD.

pub mod asic;
pub mod energy;
pub mod memory;
pub mod fpga;
pub mod pdk;
pub mod throughput;

pub use energy::EnergyModel;
pub use asic::{AsicImplementation, AsicModel};
pub use fpga::{FpgaImplementation, FpgaModel};
pub use pdk::{Pdk, PdkKind};
pub use throughput::{
    b_max, bismo_cycles, bitsmm_cycles, gops, op_per_cycle, peak_op_per_cycle, PEAK_OPS_CONVENTION,
};
