//! Serving metrics: latency distributions, throughput counters, and
//! packed-pool scheduling telemetry.

use crate::bits::packed::StealStats;
use crate::coordinator::faults::{FaultStats, ScrubStats};
use crate::coordinator::scheduler::ExecutionReport;
use crate::device::DeviceStats;
use crate::obs::hist::Histogram;
use crate::plan::PlanStats;
use std::sync::Mutex;
use std::time::Duration;

/// Online latency statistics, backed by the bounded log-bucketed
/// histogram (`obs::hist`, DESIGN.md §Observability). Small runs stay
/// exact — up to `obs::hist::EXACT_MAX` samples are kept verbatim and
/// percentiles come from a sort, identical to the old per-sample
/// `Vec<u64>` — and past that memory is constant (~60 KiB) with a
/// documented ≤ 1/128 relative quantile error. Merging worker stats
/// then asking percentiles equals recording every sample into one
/// stats object, in both modes.
#[derive(Debug, Clone, Default)]
pub struct LatencyStats {
    hist: Histogram,
}

impl LatencyStats {
    pub fn record(&mut self, d: Duration) {
        self.hist.record(d.as_micros() as u64);
    }

    pub fn count(&self) -> usize {
        self.hist.count() as usize
    }

    /// Exact mean in both modes (the histogram keeps a full-width sum).
    pub fn mean_us(&self) -> f64 {
        self.hist.mean()
    }

    /// Smallest recorded latency (exact in both modes; 0 when empty).
    pub fn min_us(&self) -> u64 {
        if self.hist.count() == 0 {
            0
        } else {
            self.hist.min()
        }
    }

    /// Largest recorded latency (exact in both modes; 0 when empty).
    pub fn max_us(&self) -> u64 {
        if self.hist.count() == 0 {
            0
        } else {
            self.hist.max()
        }
    }

    /// Nearest-rank percentiles, each `p` in [0, 100]; exact until the
    /// histogram spills, then within ≤ 1/128 relative error. One sort
    /// serves every requested percentile — report tables asking for
    /// p50/p95/p99 pay the sort once, not once per row. Empty stats
    /// answer 0 for every percentile, never a panic.
    pub fn percentiles(&self, ps: &[f64]) -> Vec<u64> {
        self.hist.percentiles(ps)
    }

    /// Single-percentile convenience over [`LatencyStats::percentiles`].
    pub fn percentile_us(&self, p: f64) -> u64 {
        self.percentiles(&[p])[0]
    }

    pub fn merge(&mut self, other: &LatencyStats) {
        self.hist.merge(&other.hist);
    }
}

/// Table-cell rendering of [`Metrics::worker_tile_imbalance`]: a
/// starved worker's infinite ratio renders as `inf` in human tables —
/// the JSONL snapshot layer renders the same value as `null`, because
/// JSON has no infinity (`obs::snapshot` pins both).
pub fn imbalance_label(v: f64) -> String {
    if v.is_infinite() {
        "inf".into()
    } else {
        crate::report::f(v)
    }
}

/// Whole-server metrics.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub latency: LatencyStats,
    /// Requests completed successfully.
    pub requests: u64,
    /// Requests that failed validation or execution (their submitters
    /// received an error response carrying the cause).
    pub errors: u64,
    /// Batches executed.
    pub batches: u64,
    /// MAC operations served.
    pub macs: u64,
    /// Simulated-hardware cycles consumed (timing model).
    pub hw_cycles: u64,
    /// Wall-clock of the serving run.
    pub wall: Duration,
    /// Packed-pool work-stealing telemetry: tile jobs, steals, and the
    /// max/min per-worker tile share (zero unless the packed backend
    /// ran with a pool).
    pub steal: StealStats,
    /// Execution-planner telemetry: plan-cache hits, misses, and
    /// on-line calibrations on the request path (zero unless a planner
    /// is attached — DESIGN.md §Planner).
    pub plan: PlanStats,
    /// Submissions refused at admission (bounded queue full, or the
    /// server already closed). Their submitters got a typed rejection.
    pub rejected: u64,
    /// Queued requests shed for exceeding the `shed_after` age budget
    /// (answered `Overloaded`, never executed).
    pub sheds: u64,
    /// Requests answered `DeadlineExceeded` because their deadline
    /// passed before (or between) forwards.
    pub deadline_misses: u64,
    /// Batch executions that panicked under the worker's supervisor;
    /// every affected request was answered `WorkerFault` and the
    /// worker survived.
    pub panics: u64,
    /// Worker threads that died outside supervision (join failed at
    /// shutdown); surviving workers' metrics still merged.
    pub worker_deaths: u64,
    /// Low-priority requests served at degraded (narrower) operand
    /// precision under overload — bit-exact by the `slice_bits`
    /// clamp argument (DESIGN.md §Resilience).
    pub degraded: u64,
    /// Corruption-fault injections (dropped pool jobs, SEU bit-flips,
    /// memory SEUs) and whether each was masked before reaching a
    /// response.
    pub faults: FaultStats,
    /// Resident-state integrity telemetry: scrubber sweeps plus
    /// corrupt planes detected / repaired / quarantined by either
    /// integrity path — the background scrubber or the on-ABFT-miss
    /// escalation ladder (DESIGN.md §Integrity).
    pub scrub: ScrubStats,
    /// Instruction-driven device telemetry: per-stage fetch/execute/
    /// writeback cycles and the fetch overlap won by double buffering
    /// (zero unless the simulate backend ran — DESIGN.md §Device).
    pub device: DeviceStats,
}

impl Metrics {
    /// Requests per second over the run.
    pub fn throughput_rps(&self) -> f64 {
        if self.wall.is_zero() {
            return 0.0;
        }
        self.requests as f64 / self.wall.as_secs_f64()
    }

    /// Simulated-hardware GOPS (paper convention) at a clock frequency.
    pub fn hw_gops(&self, clock_hz: f64) -> f64 {
        if self.hw_cycles == 0 {
            return 0.0;
        }
        (self.macs as f64 / self.hw_cycles as f64) * clock_hz / 1e9
    }

    /// Mean batch occupancy.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.requests as f64 / self.batches as f64
    }

    /// Fraction of pooled tile jobs that were stolen rather than run
    /// from their seeded deque — how much rebalancing the work-stealing
    /// scheduler actually did.
    pub fn steal_rate(&self) -> f64 {
        if self.steal.tiles == 0 {
            return 0.0;
        }
        self.steal.steals as f64 / self.steal.tiles as f64
    }

    /// Max/min per-worker tile share across pooled runs: 1.0 = perfect
    /// balance, 0.0 when no pooled run happened, and `f64::INFINITY`
    /// when some slot ran nothing while another ran tiles — a fully
    /// starved worker is the *worst* imbalance and must never read as
    /// the 0.0 that looks like "no pooled work" (the serve table
    /// renders it as `inf`).
    pub fn worker_tile_imbalance(&self) -> f64 {
        if self.steal.max_worker_tiles == 0 {
            return 0.0;
        }
        if self.steal.min_worker_tiles == 0 {
            return f64::INFINITY;
        }
        self.steal.max_worker_tiles as f64 / self.steal.min_worker_tiles as f64
    }

    /// Fraction of request-path plan lookups served by an exact
    /// plan-cache hit (0.0 when no planner ran).
    pub fn plan_hit_rate(&self) -> f64 {
        self.plan.hit_rate()
    }

    /// Fold one worker's metrics into this aggregate: latency samples
    /// concatenate, counters add. `wall`, `steal`, `plan`, and
    /// `device` are set by the caller (the run clock and the merged
    /// `ExecutionReport` own those).
    pub fn absorb(&mut self, w: &Metrics) {
        self.latency.merge(&w.latency);
        self.requests += w.requests;
        self.errors += w.errors;
        self.batches += w.batches;
        self.macs += w.macs;
        self.hw_cycles += w.hw_cycles;
        self.rejected += w.rejected;
        self.sheds += w.sheds;
        self.deadline_misses += w.deadline_misses;
        self.panics += w.panics;
        self.worker_deaths += w.worker_deaths;
        self.degraded += w.degraded;
        self.faults.merge(&w.faults);
        self.scrub.merge(&w.scrub);
    }
}

/// Live per-worker metrics mailbox behind the periodic snapshotter
/// (DESIGN.md §Observability). Workers own their `Metrics` /
/// `ExecutionReport` exclusively while serving — the property the whole
/// serving stack is built on — so mid-run visibility comes from each
/// worker *publishing* a clone into its slot after every batch, and the
/// snapshotter folding the slots exactly the way `shutdown` folds the
/// workers' final state: absorb each slot's metrics, merge the reports,
/// then single-source `steal`/`plan`/`device` from the merged report
/// and add its fault/scrub ledgers on top.
#[derive(Debug)]
pub struct MetricsHub {
    slots: Vec<Mutex<(ExecutionReport, Metrics)>>,
}

impl MetricsHub {
    pub fn new(workers: usize) -> MetricsHub {
        MetricsHub {
            slots: (0..workers.max(1)).map(|_| Mutex::new(Default::default())).collect(),
        }
    }

    /// Overwrite worker `w`'s slot with its current state (cheap: the
    /// histogram is constant-size, the reports are plain counters).
    pub fn publish(&self, w: usize, report: &ExecutionReport, metrics: &Metrics) {
        let slot = &self.slots[w % self.slots.len()];
        let mut s = slot.lock().unwrap_or_else(|p| p.into_inner());
        *s = (report.clone(), metrics.clone());
    }

    /// Fold every slot into one `Metrics`, mirroring the shutdown merge.
    /// `wall` and `rejected` stay zero — the caller owns the run clock
    /// and the admission counter.
    pub fn aggregate(&self) -> Metrics {
        let mut report = ExecutionReport::default();
        let mut total = Metrics::default();
        for slot in &self.slots {
            let s = slot.lock().unwrap_or_else(|p| p.into_inner());
            report.merge(&s.0);
            total.absorb(&s.1);
        }
        total.steal = report.steal.clone();
        total.plan = report.plan.clone();
        total.device = report.device.clone();
        total.faults.merge(&report.faults);
        total.scrub.merge(&report.scrub);
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_exact() {
        let mut l = LatencyStats::default();
        for us in [10u64, 20, 30, 40, 50, 60, 70, 80, 90, 100] {
            l.record(Duration::from_micros(us));
        }
        assert_eq!(l.percentile_us(0.0), 10);
        assert_eq!(l.percentile_us(50.0), 60); // nearest-rank on 10 samples
        assert_eq!(l.percentile_us(100.0), 100);
        assert!((l.mean_us() - 55.0).abs() < 1e-9);
    }

    #[test]
    fn batch_percentiles_match_individual() {
        let mut l = LatencyStats::default();
        for us in [5u64, 1, 9, 3, 7] {
            l.record(Duration::from_micros(us));
        }
        assert_eq!(l.percentiles(&[0.0, 50.0, 100.0]), vec![1, 5, 9]);
        for p in [0.0, 25.0, 50.0, 90.0, 100.0] {
            assert_eq!(l.percentile_us(p), l.percentiles(&[p])[0]);
        }
        assert_eq!(
            LatencyStats::default().percentiles(&[50.0, 99.0]),
            vec![0, 0]
        );
    }

    #[test]
    fn empty_stats_are_zero() {
        let l = LatencyStats::default();
        assert_eq!(l.percentile_us(99.0), 0);
        assert_eq!(l.mean_us(), 0.0);
        let m = Metrics::default();
        assert_eq!(m.throughput_rps(), 0.0);
        assert_eq!(m.hw_gops(300e6), 0.0);
    }

    #[test]
    fn hw_gops_accounting() {
        let m = Metrics {
            macs: 1024,
            hw_cycles: 16,
            ..Default::default()
        };
        // 64 OP/cycle × 300 MHz = 19.2 GOPS — the Table II headline
        assert!((m.hw_gops(300e6) - 19.2).abs() < 1e-9);
    }

    #[test]
    fn steal_telemetry_rates() {
        let mut m = Metrics::default();
        assert_eq!(m.steal_rate(), 0.0);
        assert_eq!(m.worker_tile_imbalance(), 0.0);
        m.steal = StealStats {
            tiles: 40,
            steals: 10,
            max_worker_tiles: 6,
            min_worker_tiles: 3,
        };
        assert!((m.steal_rate() - 0.25).abs() < 1e-12);
        assert!((m.worker_tile_imbalance() - 2.0).abs() < 1e-12);
        // a fully starved worker is infinite imbalance, not the 0.0
        // that means "no pooled work ran"
        m.steal = StealStats {
            tiles: 6,
            steals: 0,
            max_worker_tiles: 6,
            min_worker_tiles: 0,
        };
        assert_eq!(m.worker_tile_imbalance(), f64::INFINITY);
    }

    #[test]
    fn absorb_adds_resilience_counters() {
        let mut total = Metrics::default();
        let mut w1 = Metrics::default();
        w1.latency.record(Duration::from_micros(10));
        w1.requests = 3;
        w1.sheds = 2;
        w1.panics = 1;
        w1.faults = FaultStats {
            injected: 2,
            masked_transient: 1,
            masked_persistent: 1,
            ..FaultStats::default()
        };
        w1.scrub = ScrubStats {
            sweeps: 2,
            detected: 1,
            repaired: 1,
            quarantined: 0,
        };
        let mut w2 = Metrics::default();
        w2.errors = 1;
        w2.deadline_misses = 4;
        w2.degraded = 5;
        w2.faults = FaultStats {
            injected: 1,
            mem_seu: 1,
            unmasked: 1,
            ..FaultStats::default()
        };
        w2.scrub = ScrubStats {
            sweeps: 1,
            detected: 1,
            repaired: 0,
            quarantined: 1,
        };
        total.absorb(&w1);
        total.absorb(&w2);
        assert_eq!(total.latency.count(), 1);
        assert_eq!(total.requests, 3);
        assert_eq!(total.errors, 1);
        assert_eq!(total.sheds, 2);
        assert_eq!(total.deadline_misses, 4);
        assert_eq!(total.panics, 1);
        assert_eq!(total.degraded, 5);
        assert_eq!(
            total.faults,
            FaultStats {
                injected: 3,
                mem_seu: 1,
                masked_transient: 1,
                masked_persistent: 1,
                unmasked: 1,
            }
        );
        assert_eq!(total.faults.masked(), 2);
        assert_eq!(
            total.scrub,
            ScrubStats { sweeps: 3, detected: 2, repaired: 1, quarantined: 1 }
        );
    }

    #[test]
    fn hub_aggregate_mirrors_the_shutdown_merge() {
        let hub = MetricsHub::new(2);
        // nothing published yet: an all-zero aggregate, not a panic
        assert_eq!(hub.aggregate().requests, 0);

        let mut m1 = Metrics::default();
        m1.requests = 3;
        m1.latency.record(Duration::from_micros(10));
        let mut r1 = ExecutionReport::default();
        r1.steal = StealStats { tiles: 4, steals: 1, max_worker_tiles: 2, min_worker_tiles: 1 };
        r1.faults.injected = 1;
        r1.faults.masked_transient = 1;
        hub.publish(0, &r1, &m1);

        let mut m2 = Metrics::default();
        m2.requests = 2;
        m2.sheds = 1;
        let mut r2 = ExecutionReport::default();
        r2.steal = StealStats { tiles: 6, steals: 2, max_worker_tiles: 3, min_worker_tiles: 2 };
        r2.scrub.repaired = 1;
        hub.publish(1, &r2, &m2);

        let total = hub.aggregate();
        assert_eq!(total.requests, 5);
        assert_eq!(total.sheds, 1);
        assert_eq!(total.latency.count(), 1);
        assert_eq!(total.steal.tiles, 10, "steal comes from the merged report");
        assert_eq!(total.faults.injected, 1);
        assert_eq!(total.faults.masked(), 1);
        assert_eq!(total.scrub.repaired, 1);
        assert_eq!(total.wall, Duration::ZERO, "the caller owns the run clock");

        // publish overwrites, never accumulates: re-publishing the same
        // worker state must not double-count
        hub.publish(0, &r1, &m1);
        assert_eq!(hub.aggregate().requests, 5);
    }

    #[test]
    fn plan_telemetry_rates() {
        let mut m = Metrics::default();
        assert_eq!(m.plan_hit_rate(), 0.0, "no planner ran");
        m.plan = PlanStats { hits: 6, misses: 2, calibrations: 1 };
        assert!((m.plan_hit_rate() - 0.75).abs() < 1e-12);
        assert_eq!(m.plan.lookups(), 8);
    }
}
