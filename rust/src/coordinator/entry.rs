//! CLI entry points for the launcher binary (kept in the library so
//! integration tests can exercise them).

use crate::cli::Args;
use crate::coordinator::faults::{FaultPlan, FaultState};
use crate::coordinator::scheduler::Backend;
use crate::coordinator::server::{serve_all, shaped_inputs, DegradePolicy, ServerConfig};
use crate::coordinator::BatcherConfig;
use crate::nn::model::zoo_model;
use crate::coordinator::metrics::imbalance_label;
use crate::plan::{Planner, PlannerMode};
use crate::prng::Pcg32;
use crate::report::{f, Table};
use crate::sim::array::SaConfig;
use crate::sim::mac_common::MacVariant;
use crate::Result;
use std::sync::Arc;

/// Parse the paper's `colsxrows` geometry notation ("16x4" = 16
/// columns × 4 rows).
pub struct SaParse;

impl SaParse {
    pub fn parse(s: &str, variant: MacVariant) -> Result<SaConfig> {
        let (cols, rows) = s
            .split_once('x')
            .ok_or_else(|| anyhow::anyhow!("geometry '{s}' should be colsxrows, e.g. 16x4"))?;
        let cols: usize = cols.trim().parse()?;
        let rows: usize = rows.trim().parse()?;
        anyhow::ensure!(rows >= 1 && cols >= 1, "degenerate geometry {s}");
        Ok(SaConfig::new(rows, cols, variant))
    }
}

/// Build the shared execution planner for a serving run: size it to
/// the resolved kernel slots and seed it from the plan file when one
/// exists for *this* host — a stale or foreign file is reported and
/// skipped (the planner falls back to the cost model), never applied.
fn build_planner(mode: PlannerMode, plan_file: &str, cfg: &ServerConfig) -> Option<Arc<Planner>> {
    if mode == PlannerMode::Off {
        return None;
    }
    // only the packed backend consults the planner; building one for
    // native/simulate/pjrt would just print dead all-zero table rows
    if !matches!(cfg.backend, Backend::Packed) {
        println!(
            "planner: '{}' requested but backend '{}' never consults it; planner disabled",
            mode.name(),
            cfg.backend.name()
        );
        return None;
    }
    let planner = Arc::new(Planner::new(mode, cfg.kernel_slots()));
    let path = std::path::Path::new(plan_file);
    if path.exists() {
        match planner.load_file(path) {
            Ok(n) => println!("planner: loaded {n} plans from {plan_file}"),
            Err(e) => println!(
                "planner: ignoring {plan_file} ({e:#}); resolving from the cost model"
            ),
        }
    }
    Some(planner)
}

/// Resilience rows shared by the serve and launch tables: admission/
/// shedding/deadline counters, supervision outcomes, degraded serves,
/// and the fault-injection ledger (DESIGN.md §Resilience). Printed
/// unconditionally — all-zero rows are the "healthy run" statement,
/// and CI greps them.
fn resilience_rows(t: &mut Table, metrics: &crate::coordinator::Metrics) {
    t.row(&[
        "rejected / sheds / deadline misses".into(),
        format!(
            "{} / {} / {}",
            metrics.rejected, metrics.sheds, metrics.deadline_misses
        ),
    ]);
    t.row(&[
        "worker panics / deaths".into(),
        format!("{} / {}", metrics.panics, metrics.worker_deaths),
    ]);
    t.row(&["degraded serves".into(), format!("{}", metrics.degraded)]);
    t.row(&[
        "faults injected / masked / unmasked".into(),
        format!(
            "{} / {} / {}",
            metrics.faults.injected,
            metrics.faults.masked(),
            metrics.faults.unmasked
        ),
    ]);
    t.row(&[
        "faults masked transient / persistent".into(),
        format!(
            "{} / {}",
            metrics.faults.masked_transient, metrics.faults.masked_persistent
        ),
    ]);
    t.row(&[
        "scrub sweeps / detected / repaired / quarantined".into(),
        format!(
            "{} / {} / {} / {}",
            metrics.scrub.sweeps,
            metrics.scrub.detected,
            metrics.scrub.repaired,
            metrics.scrub.quarantined
        ),
    ]);
}

/// Device-backend rows shared by the serve and launch tables: the
/// per-stage cycle split and the fetch/execute overlap the
/// double-buffered driver won (DESIGN.md §Device). Printed
/// unconditionally — all-zero rows state "no simulate backend ran",
/// and CI greps the `fetch_overlap` line.
fn device_rows(t: &mut Table, metrics: &crate::coordinator::Metrics) {
    let d = &metrics.device;
    t.row(&[
        "device fetch / exec / wb cycles".into(),
        format!("{} / {} / {}", d.fetch_cycles, d.exec_cycles, d.wb_cycles),
    ]);
    t.row(&[
        "device fetch_overlap / stall cycles".into(),
        format!(
            "{} / {} (overlap ratio {})",
            d.overlap_cycles,
            d.stall_cycles,
            f(d.fetch_overlap_ratio())
        ),
    ]);
    t.row(&[
        "device pipelined / serial cycles".into(),
        format!(
            "{} / {} (occupancy {})",
            d.pipelined_cycles(),
            d.serial_cycles(),
            f(d.occupancy())
        ),
    ]);
}

/// Resolve the resilience knobs shared by the CLI and config entry
/// points onto a [`ServerConfig`]: bounded admission, age shedding,
/// the optional degrade policy, ABFT verification, and a parsed fault
/// plan (`spec` empty = no injection), plus the background scrub
/// period (`scrub_ms`, 0 = off — DESIGN.md §Integrity).
#[allow(clippy::too_many_arguments)]
fn apply_resilience(
    cfg: &mut ServerConfig,
    max_queue: usize,
    shed_after_ms: f64,
    degrade_high_water: usize,
    degrade_bits: u32,
    abft: bool,
    scrub_ms: u64,
    fault_plan: Option<&str>,
) -> Result<()> {
    cfg.batcher.max_queue = max_queue;
    cfg.batcher.shed_after = if shed_after_ms > 0.0 {
        Some(std::time::Duration::from_secs_f64(shed_after_ms / 1e3))
    } else {
        None
    };
    if degrade_high_water > 0 {
        cfg.degrade = Some(DegradePolicy {
            high_water: degrade_high_water,
            floor_bits: degrade_bits,
        });
    }
    cfg.abft = abft;
    cfg.scrub_ms = scrub_ms;
    if let Some(spec) = fault_plan.filter(|s| !s.trim().is_empty()) {
        cfg.faults = Some(Arc::new(FaultState::new(FaultPlan::parse(spec)?)));
    }
    Ok(())
}

/// Resolve the flight-telemetry knobs shared by the CLI and config
/// entry points onto a [`ServerConfig`]: the JSONL metrics snapshot
/// file + cadence and the per-request trace dump (DESIGN.md
/// §Observability). Empty paths leave both layers disabled (and
/// tracing at its near-zero cost: the hooks short-circuit on a `None`
/// ring).
fn apply_observability(
    cfg: &mut ServerConfig,
    metrics_file: &str,
    metrics_every_ms: u64,
    trace_requests: &str,
) {
    if !metrics_file.trim().is_empty() {
        cfg.metrics_file = Some(std::path::PathBuf::from(metrics_file.trim()));
    }
    if metrics_every_ms > 0 {
        cfg.metrics_every_ms = metrics_every_ms;
    }
    if !trace_requests.trim().is_empty() {
        // the server builds the ring itself when a dump path is set
        cfg.trace_file = Some(std::path::PathBuf::from(trace_requests.trim()));
    }
}

/// Planner rows shared by the serve and launch tables: mode, cache
/// telemetry, and the chosen plan per shape class.
fn planner_rows(t: &mut Table, planner: &Planner, metrics: &crate::coordinator::Metrics) {
    t.row(&[
        "planner".into(),
        format!("{} ({} plans cached)", planner.mode().name(), planner.len()),
    ]);
    t.row(&[
        "plan hits / misses / calibrations".into(),
        format!(
            "{} / {} / {}",
            metrics.plan.hits, metrics.plan.misses, metrics.plan.calibrations
        ),
    ]);
    t.row(&["plan hit rate".into(), f(metrics.plan_hit_rate())]);
    for (key, plan) in planner.summary().into_iter().take(8) {
        t.row(&[format!("plan {key}"), plan.label()]);
    }
}

/// `bitsmm serve` implementation.
pub fn serve_all_entry(args: &Args) -> Result<()> {
    let variant: MacVariant = args.req::<String>("variant")?.parse()?;
    let sa = SaParse::parse(args.get("sa").unwrap(), variant)?;
    let backend = match args.get("backend").unwrap() {
        "native" => Backend::Native,
        "packed" => Backend::Packed,
        "simulate" => Backend::Simulate,
        "pjrt" => {
            let dir = args
                .get("artifacts")
                .map(std::path::PathBuf::from)
                .unwrap_or_else(crate::runtime::default_artifact_dir);
            let (engine, _join) = crate::runtime::EngineHandle::spawn(&dir)?;
            println!("pjrt engine up ({} artifacts warm)", engine.warm_up()?);
            Backend::Pjrt(engine)
        }
        other => anyhow::bail!("unknown backend '{other}'"),
    };
    let model = zoo_model(args.get("model").unwrap(), 1)?;
    let n_requests: usize = args.req("requests")?;
    let mut cfg = ServerConfig::new(sa, backend);
    cfg.workers = args.req("workers")?;
    cfg.batcher = BatcherConfig {
        max_batch: args.req("batch")?,
        linger: std::time::Duration::from_millis(2),
        ..BatcherConfig::default()
    };
    apply_resilience(
        &mut cfg,
        args.req("max-queue")?,
        args.req::<f64>("shed-after-ms")?,
        args.req("degrade-high-water")?,
        args.req("degrade-bits")?,
        args.switch("abft"),
        args.req("scrub-ms")?,
        args.get("fault-plan"),
    )?;
    cfg.packed_threads = args.req("packed-threads")?;
    cfg.packed_unroll = args.req::<String>("packed-unroll")?.parse()?;
    cfg.packed_tile_rows = args.req("packed-tile-rows")?;
    cfg.packed_tile_cols = args.req("packed-tile-cols")?;
    cfg.packed_ksplit = args.req("packed-ksplit")?;
    cfg.packed_rsr = args.switch("packed-rsr");
    apply_observability(
        &mut cfg,
        args.get("metrics-file").unwrap_or(""),
        args.get_parse::<u64>("metrics-every-ms")?.unwrap_or(0),
        args.get("trace-requests").unwrap_or(""),
    );
    let metrics_path = cfg.metrics_file.clone();
    let trace_path = cfg.trace_file.clone();
    let planner_mode: PlannerMode = args.req::<String>("planner")?.parse()?;
    let plan_file = args.get("plan-file").unwrap();
    let planner = build_planner(planner_mode, plan_file, &cfg);
    cfg.planner = planner;
    // only on-line runs learn anything worth writing back: calibrated
    // winners flow to the plan file on graceful shutdown (merge, never
    // clobber — see Planner::persist_file)
    if planner_mode == PlannerMode::Online && cfg.planner.is_some() {
        cfg.plan_persist = Some(std::path::PathBuf::from(plan_file));
    }
    let planner_view = cfg.planner.clone();

    let inputs = shaped_inputs(&model, n_requests, 42);
    let model_name = model.name.clone();
    let input_shape = model.input_shape.clone();
    let census = model.stats(n_requests).macs;

    let backend_name = cfg.backend.name();
    let (responses, report, metrics) = serve_all(Arc::new(model), cfg, inputs)?;

    let mut t = Table::new(
        &format!("serve: {} requests, backend={backend_name}, SA {}", responses.len(), sa.label()),
        &["metric", "value"],
    );
    t.row(&["model".into(), format!("{model_name} (input {input_shape:?})")]);
    t.row(&["requests ok / errors".into(), format!("{} / {}", metrics.requests, metrics.errors)]);
    t.row(&["batches".into(), format!("{}", metrics.batches)]);
    t.row(&["mean batch".into(), f(metrics.mean_batch())]);
    let p = metrics.latency.percentiles(&[50.0, 95.0, 99.0]);
    t.row(&["p50 latency (us)".into(), format!("{}", p[0])]);
    t.row(&["p95 latency (us)".into(), format!("{}", p[1])]);
    t.row(&["p99 latency (us)".into(), format!("{}", p[2])]);
    t.row(&["MAC census (model)".into(), format!("{census}")]);
    t.row(&["wall throughput (req/s)".into(), f(metrics.throughput_rps())]);
    t.row(&["MACs served".into(), format!("{}", report.macs)]);
    t.row(&["hw cycles (model)".into(), format!("{}", report.hw_cycles)]);
    t.row(&["hw GOPS @300MHz".into(), f(report.hw_gops(300e6))]);
    t.row(&[
        "pjrt / native / packed".into(),
        format!(
            "{} / {} / {}",
            report.pjrt_hits, report.native_fallbacks, report.packed_execs
        ),
    ]);
    t.row(&[
        "pool tiles / steals".into(),
        format!("{} / {}", report.steal.tiles, report.steal.steals),
    ]);
    // a starved slot is infinite imbalance — the table renders it as
    // `inf` (never a number that could be confused with "balanced"),
    // while JSONL snapshots emit `null` for the same value
    t.row(&[
        "worker tile share max/min".into(),
        format!(
            "{} / {} (imbalance {}, steal rate {})",
            report.steal.max_worker_tiles,
            report.steal.min_worker_tiles,
            imbalance_label(metrics.worker_tile_imbalance()),
            f(metrics.steal_rate())
        ),
    ]);
    device_rows(&mut t, &metrics);
    resilience_rows(&mut t, &metrics);
    if let Some(pl) = &planner_view {
        planner_rows(&mut t, pl, &metrics);
    }
    print!("{}", t.render());
    if let Some(p) = &metrics_path {
        println!("metrics snapshots appended to {}", p.display());
    }
    if let Some(p) = &trace_path {
        println!("request trace dumped to {}", p.display());
    }
    Ok(())
}

/// `bitsmm launch` implementation: a config-file driven serving run —
/// the deployment-style entry point (see `configs/serve.toml`).
pub fn launch_entry(cfg_path: &std::path::Path) -> Result<()> {
    let cfg = crate::config::Config::load(cfg_path)?;
    launch_from_config(&cfg)
}

/// Serving run from a parsed [`crate::config::Config`] (separated for
/// tests).
pub fn launch_from_config(cfg: &crate::config::Config) -> Result<()> {
    let variant: MacVariant = cfg.str_or("sa.variant", "booth").parse()?;
    let sa = SaConfig::new(
        usize::try_from(cfg.int_or("sa.rows", 4))?,
        usize::try_from(cfg.int_or("sa.cols", 16))?,
        variant,
    );
    anyhow::ensure!(sa.rows >= 1 && sa.cols >= 1, "degenerate SA geometry");
    let backend = match cfg.str_or("server.backend", "native") {
        "native" => Backend::Native,
        "packed" => Backend::Packed,
        "simulate" => Backend::Simulate,
        "pjrt" => {
            let dir = std::path::PathBuf::from(
                cfg.str_or("server.artifacts", "artifacts"),
            );
            let (engine, _join) = crate::runtime::EngineHandle::spawn(&dir)?;
            engine.warm_up()?;
            Backend::Pjrt(engine)
        }
        other => anyhow::bail!("unknown backend '{other}' in config"),
    };
    let model = zoo_model(cfg.str_or("server.model", "mlp"), 1)?;
    let n_requests = usize::try_from(cfg.int_or("server.requests", 64))?;
    let mut server_cfg = ServerConfig::new(sa, backend);
    server_cfg.workers = usize::try_from(cfg.int_or("server.workers", 2))?;
    server_cfg.batcher = BatcherConfig {
        max_batch: usize::try_from(cfg.int_or("server.max_batch", 8))?,
        linger: std::time::Duration::from_secs_f64(
            cfg.float_or("server.linger_ms", 2.0) / 1e3,
        ),
        ..BatcherConfig::default()
    };
    apply_resilience(
        &mut server_cfg,
        usize::try_from(cfg.int_or("server.max_queue", 0))?,
        cfg.float_or("server.shed_after_ms", 0.0),
        usize::try_from(cfg.int_or("server.degrade_high_water", 0))?,
        u32::try_from(cfg.int_or("server.degrade_bits", 4))?,
        cfg.bool_or("server.abft", false),
        u64::try_from(cfg.int_or("server.scrub_ms", 0))?,
        Some(cfg.str_or("server.fault_plan", "")),
    )?;
    server_cfg.clock_hz = cfg.float_or("server.clock_mhz", 300.0) * 1e6;
    server_cfg.packed_threads = usize::try_from(cfg.int_or("server.packed_threads", 0))?;
    server_cfg.packed_unroll = cfg.str_or("server.packed_unroll", "auto").parse()?;
    server_cfg.packed_tile_rows = usize::try_from(cfg.int_or("server.packed_tile_rows", 0))?;
    server_cfg.packed_tile_cols = usize::try_from(cfg.int_or("server.packed_tile_cols", 0))?;
    server_cfg.packed_ksplit = usize::try_from(cfg.int_or("server.packed_ksplit", 0))?;
    server_cfg.packed_rsr = cfg.bool_or("server.packed_rsr", false);
    apply_observability(
        &mut server_cfg,
        cfg.str_or("server.metrics_file", ""),
        u64::try_from(cfg.int_or("server.metrics_every_ms", 0))?,
        cfg.str_or("server.trace_requests", ""),
    );
    let planner_mode: PlannerMode = cfg.str_or("server.planner", "off").parse()?;
    let plan_file = cfg.str_or("server.plan_file", "configs/plans.json");
    let planner = build_planner(planner_mode, plan_file, &server_cfg);
    server_cfg.planner = planner;
    // graceful shutdown writes on-line calibrated winners back to the
    // plan file so the next run starts warm (satellite of the planner:
    // merge-don't-clobber, atomic rename — Planner::persist_file)
    if planner_mode == PlannerMode::Online && server_cfg.planner.is_some() {
        server_cfg.plan_persist = Some(std::path::PathBuf::from(plan_file));
    }
    let planner_view = server_cfg.planner.clone();

    let inputs = shaped_inputs(&model, n_requests, 42);
    let model_name = model.name.clone();
    let input_shape = model.input_shape.clone();
    let clock_hz = server_cfg.clock_hz;
    let (responses, report, metrics) = serve_all(Arc::new(model), server_cfg, inputs)?;
    let mut t = Table::new(
        &format!(
            "launch '{}': {} requests on {} ({})",
            cfg.str_or("name", "unnamed"),
            responses.len(),
            sa.label(),
            variant.name()
        ),
        &["metric", "value"],
    );
    t.row(&["model".into(), format!("{model_name} (input {input_shape:?})")]);
    t.row(&["requests ok / errors".into(), format!("{} / {}", metrics.requests, metrics.errors)]);
    t.row(&["throughput (req/s)".into(), f(metrics.throughput_rps())]);
    let p = metrics.latency.percentiles(&[50.0, 99.0]);
    t.row(&["p50 / p99 latency (us)".into(), format!("{} / {}", p[0], p[1])]);
    t.row(&["hw GOPS @config clock".into(), f(report.hw_gops(clock_hz))]);
    t.row(&["MACs / hw cycles".into(), format!("{} / {}", report.macs, report.hw_cycles)]);
    device_rows(&mut t, &metrics);
    resilience_rows(&mut t, &metrics);
    if let Some(pl) = &planner_view {
        planner_rows(&mut t, pl, &metrics);
    }
    print!("{}", t.render());
    Ok(())
}

/// `bitsmm simulate` implementation. With `trace`, the run re-executes
/// through the device driver with the instruction-queue tracer attached
/// and writes the issue/retire waveform as VCD to that path (the traced
/// rerun is bit-checked against the first pass).
pub fn simulate_entry(
    sa: SaConfig,
    m: usize,
    k: usize,
    n: usize,
    bits: u32,
    seed: u64,
    trace: Option<&std::path::Path>,
) -> Result<()> {
    let mut rng = Pcg32::new(seed);
    let lo = crate::bits::twos::min_value(bits);
    let hi = crate::bits::twos::max_value(bits);
    let a: Vec<i32> = (0..m * k).map(|_| rng.range_i32(lo, hi)).collect();
    let b: Vec<i32> = (0..k * n).map(|_| rng.range_i32(lo, hi)).collect();

    let mut sched = crate::coordinator::scheduler::Scheduler::new(sa, Backend::Simulate);
    let got = sched.matmul(&a, &b, m, k, n, bits)?;
    let want = crate::sim::driver::ref_matmul_i64(&a, &b, m, k, n);
    anyhow::ensure!(got == want, "simulator diverged from integer reference");

    let plan = crate::coordinator::tiler::tile_matmul(m, k, n, &sa);
    let eq9 = crate::arch::throughput::op_per_cycle(
        k as u64,
        m as u64,
        n as u64,
        bits,
        sa.cols as u64,
        sa.rows as u64,
    );
    let dev = sched.report.device;
    let mut t = Table::new(
        &format!("simulate {m}x{k}x{n} @{bits}b on {} ({})", sa.label(), sa.variant.name()),
        &["metric", "value"],
    );
    t.row(&["tiles".into(), format!("{}", plan.jobs.len())]);
    t.row(&["instructions".into(), format!("{}", dev.instrs)]);
    t.row(&["measured cycles".into(), format!("{}", sched.report.hw_cycles)]);
    t.row(&["modelled cycles (eq8+fill+readout)".into(), format!("{}", plan.total_cycles(&sa, bits))]);
    t.row(&[
        "fetch / exec / wb cycles".into(),
        format!("{} / {} / {}", dev.fetch_cycles, dev.exec_cycles, dev.wb_cycles),
    ]);
    t.row(&[
        "fetch_overlap / stall cycles".into(),
        format!(
            "{} / {} (overlap ratio {})",
            dev.overlap_cycles,
            dev.stall_cycles,
            f(dev.fetch_overlap_ratio())
        ),
    ]);
    t.row(&[
        "pipelined / serial cycles".into(),
        format!(
            "{} / {} (occupancy {})",
            dev.pipelined_cycles(),
            dev.serial_cycles(),
            f(dev.occupancy())
        ),
    ]);
    t.row(&["DMA words streamed".into(), format!("{}", dev.dma_words)]);
    t.row(&["achieved OP/cycle".into(), f(sched.report.macs as f64 / sched.report.hw_cycles as f64)]);
    t.row(&["eq. 9 OP/cycle (single tile)".into(), f(eq9)]);
    t.row(&["result".into(), "MATCHES integer reference".into()]);
    print!("{}", t.render());

    if let Some(path) = trace {
        use crate::bits::packed::PackedPlanes;
        use crate::bits::plane::PlaneKind;
        let pa = PackedPlanes::pack_rows(&a, m, k, bits, PlaneKind::Sbmwc)?;
        let pb = PackedPlanes::pack_cols(&b, k, n, bits, PlaneKind::Sbmwc)?;
        let mut dev = crate::sim::array::SystolicArray::new(sa);
        let mut tr = crate::sim::trace::DeviceTrace::new();
        let run = crate::device::run_layer(&mut dev, &plan, &sa, &pa, &pb, bits, Some(&mut tr))?;
        anyhow::ensure!(run.out == want, "traced rerun diverged from reference");
        std::fs::write(path, tr.render_vcd())?;
        println!(
            "wrote {} instruction-queue events to {}",
            tr.events().len(),
            path.display()
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sa_parse_paper_notation() {
        let sa = SaParse::parse("16x4", MacVariant::Booth).unwrap();
        assert_eq!((sa.cols, sa.rows), (16, 4));
        assert!(SaParse::parse("16", MacVariant::Booth).is_err());
        assert!(SaParse::parse("0x4", MacVariant::Booth).is_err());
    }

    #[test]
    fn launch_from_config_runs() {
        let cfg = crate::config::Config::parse(
            "name = \"t\"
[sa]
rows = 2
cols = 4
variant = \"booth\"
             [server]
requests = 4
workers = 1
max_batch = 4
",
        )
        .unwrap();
        launch_from_config(&cfg).unwrap();
    }

    #[test]
    fn launch_runs_on_packed_backend() {
        let cfg = crate::config::Config::parse(
            "name = \"p\"
[sa]
rows = 2
cols = 4
[server]
backend = \"packed\"
requests = 4
workers = 2
max_batch = 4
",
        )
        .unwrap();
        launch_from_config(&cfg).unwrap();
    }

    #[test]
    fn launch_reads_packed_pool_config() {
        // explicit thread count + forced-scalar reducer via dotted paths
        let cfg = crate::config::Config::parse(
            "name = \"pt\"
[sa]
rows = 2
cols = 4
[server]
backend = \"packed\"
requests = 4
workers = 1
max_batch = 4
packed_threads = 2
packed_unroll = \"scalar\"
",
        )
        .unwrap();
        launch_from_config(&cfg).unwrap();
    }

    #[test]
    fn launch_reads_tile_granularity_config() {
        // explicit 2-D tile knobs via dotted paths; a forced 1-row ×
        // 4-col grid exercises the column-parallel path end to end
        let cfg = crate::config::Config::parse(
            "name = \"tiles\"
[sa]
rows = 2
cols = 4
[server]
backend = \"packed\"
requests = 4
workers = 1
max_batch = 4
packed_threads = 2
packed_tile_rows = 1
packed_tile_cols = 4
",
        )
        .unwrap();
        launch_from_config(&cfg).unwrap();
    }

    #[test]
    fn launch_serves_cnn_and_attention_models() {
        // the full zoo through the config-driven entry point — the
        // former "launch currently serves the mlp zoo model" bail
        for (model, backend) in [("cnn", "native"), ("attn", "native"), ("cnn", "packed"), ("attn", "packed")] {
            let cfg = crate::config::Config::parse(&format!(
                "name = \"zoo\"
[sa]
rows = 2
cols = 4
[server]
backend = \"{backend}\"
model = \"{model}\"
requests = 2
workers = 1
max_batch = 2
"
            ))
            .unwrap();
            launch_from_config(&cfg).unwrap_or_else(|e| panic!("{model}/{backend}: {e:#}"));
        }
    }

    #[test]
    fn launch_reads_planner_config() {
        // the planner threads end-to-end through the dotted config
        // path; a missing plan file is fine (cost-model resolution).
        // the plan file lives in a temp dir because online mode now
        // persists calibrated plans back to it on shutdown.
        let dir = std::env::temp_dir().join(format!("bitsmm-launch-plan-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        for mode in ["static", "online"] {
            let plan_file = dir.join(format!("{mode}.json"));
            let cfg = crate::config::Config::parse(&format!(
                "name = \"plan\"
[sa]
rows = 2
cols = 4
[server]
backend = \"packed\"
requests = 4
workers = 1
max_batch = 4
packed_threads = 2
planner = \"{mode}\"
plan_file = \"{}\"
",
                plan_file.display()
            ))
            .unwrap();
            launch_from_config(&cfg).unwrap_or_else(|e| panic!("{mode}: {e:#}"));
            // static never writes; online persists calibrated winners
            match mode {
                "static" => assert!(!plan_file.exists(), "static mode must not persist"),
                _ => assert!(plan_file.exists(), "online mode persists on shutdown"),
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn launch_reads_subpopcount_kernel_config() {
        // the PR-6 knobs thread through dotted config paths: a forced
        // RSR family and a forced k-split chunk count both serve
        // correctly (results stay bit-identical by construction, so a
        // clean run is the assertion)
        for (rsr, ksplit) in [("true", 0), ("false", 2), ("true", 2)] {
            let cfg = crate::config::Config::parse(&format!(
                "name = \"subpop\"
[sa]
rows = 2
cols = 4
[server]
backend = \"packed\"
requests = 4
workers = 1
max_batch = 4
packed_threads = 2
packed_rsr = {rsr}
packed_ksplit = {ksplit}
"
            ))
            .unwrap();
            launch_from_config(&cfg)
                .unwrap_or_else(|e| panic!("rsr={rsr} ksplit={ksplit}: {e:#}"));
        }
    }

    #[test]
    fn launch_reads_resilience_config() {
        // the robustness knobs thread through dotted config paths: a
        // bounded queue, age shedding, ABFT, a degrade policy over the
        // headroom zoo, and a deterministic fault plan — the run must
        // complete (every request gets a terminal answer) even with a
        // panic and an SEU injected
        let cfg = crate::config::Config::parse(
            "name = \"chaos\"
[sa]
rows = 2
cols = 4
[server]
backend = \"packed\"
model = \"mlp-headroom\"
requests = 8
workers = 1
max_batch = 4
packed_threads = 2
max_queue = 64
shed_after_ms = 5000.0
degrade_high_water = 1
degrade_bits = 4
abft = true
fault_plan = \"panic@0,seu@1,seed=7\"
",
        )
        .unwrap();
        launch_from_config(&cfg).unwrap();
    }

    #[test]
    fn launch_reads_integrity_config() {
        // scrub_ms + a memory-SEU fault plan thread through dotted
        // config paths: the scrubber and the ABFT ladder between them
        // must mask the resident-plane upset and the run completes
        let cfg = crate::config::Config::parse(
            "name = \"integrity\"
[sa]
rows = 2
cols = 4
[server]
backend = \"packed\"
model = \"mlp-headroom\"
requests = 8
workers = 1
max_batch = 4
packed_threads = 2
abft = true
scrub_ms = 1
fault_plan = \"mem@1,seed=11\"
",
        )
        .unwrap();
        launch_from_config(&cfg).unwrap();
    }

    #[test]
    fn launch_reads_observability_config() {
        // metrics_file / metrics_every_ms / trace_requests thread
        // through dotted config paths: the run appends parseable JSONL
        // snapshots (≥ 1 periodic + the final) and dumps a trace whose
        // spans cover every request
        let dir = std::env::temp_dir().join(format!("bitsmm-launch-obs-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let metrics_file = dir.join("metrics.jsonl");
        let trace_file = dir.join("trace.jsonl");
        let cfg = crate::config::Config::parse(&format!(
            "name = \"obs\"
[sa]
rows = 2
cols = 4
[server]
backend = \"packed\"
requests = 6
workers = 1
max_batch = 4
packed_threads = 2
metrics_file = \"{}\"
metrics_every_ms = 5
trace_requests = \"{}\"
",
            metrics_file.display(),
            trace_file.display()
        ))
        .unwrap();
        launch_from_config(&cfg).unwrap();
        let text = std::fs::read_to_string(&metrics_file).unwrap();
        let snaps = crate::obs::snapshot::parse_snapshots(&text).unwrap();
        let last = snaps.last().unwrap();
        use crate::obs::snapshot::lookup;
        assert_eq!(
            lookup(last, "final").unwrap(),
            &crate::plan::store::Json::Bool(true)
        );
        assert_eq!(lookup(last, "requests").unwrap().as_int().unwrap(), 6);
        let trace = std::fs::read_to_string(&trace_file).unwrap();
        assert!(trace.lines().count() > 6, "a span per stage per request plus the trailer");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn launch_rejects_bad_fault_plan() {
        let cfg = crate::config::Config::parse(
            "[server]
fault_plan = \"meteor@5\"
",
        )
        .unwrap();
        assert!(launch_from_config(&cfg).is_err());
    }

    #[test]
    fn launch_with_planner_on_non_packed_backend_disables_it() {
        // the planner is a packed-backend concern: requesting it on
        // native serving runs fine with the planner quietly disabled
        let cfg = crate::config::Config::parse(
            "name = \"np\"
[sa]
rows = 2
cols = 4
[server]
backend = \"native\"
requests = 2
workers = 1
max_batch = 2
planner = \"static\"
",
        )
        .unwrap();
        launch_from_config(&cfg).unwrap();
    }

    #[test]
    fn launch_rejects_unknown_planner_mode() {
        let cfg = crate::config::Config::parse(
            "[server]
backend = \"packed\"
planner = \"turbo\"
",
        )
        .unwrap();
        assert!(launch_from_config(&cfg).is_err());
    }

    #[test]
    fn launch_rejects_unknown_model() {
        let cfg = crate::config::Config::parse(
            "[server]
model = \"resnet\"
",
        )
        .unwrap();
        assert!(launch_from_config(&cfg).is_err());
    }

    #[test]
    fn launch_rejects_unknown_popcount_kernel() {
        let cfg = crate::config::Config::parse(
            "[server]
backend = \"packed\"
packed_unroll = \"simd9000\"
",
        )
        .unwrap();
        assert!(launch_from_config(&cfg).is_err());
    }

    #[test]
    fn launch_rejects_bad_config() {
        let cfg = crate::config::Config::parse("[server]
backend = \"gpu\"
").unwrap();
        assert!(launch_from_config(&cfg).is_err());
    }

    #[test]
    fn simulate_entry_runs() {
        let sa = SaConfig::new(2, 4, MacVariant::Booth);
        simulate_entry(sa, 2, 5, 4, 4, 9, None).unwrap();
    }

    #[test]
    fn simulate_entry_writes_a_device_trace() {
        let sa = SaConfig::new(2, 4, MacVariant::Booth);
        let path = std::env::temp_dir().join(format!("bitsmm-devtrace-{}.vcd", std::process::id()));
        // 5×9×6 on a 2×4 array: 3 row bands × 2 col bands = 6 tiles
        simulate_entry(sa, 5, 9, 6, 4, 9, Some(&path)).unwrap();
        let vcd = std::fs::read_to_string(&path).unwrap();
        assert!(vcd.contains("fetch_busy") && vcd.contains("writeback_tile"));
        std::fs::remove_file(&path).unwrap();
    }
}
