//! Deterministic fault injection for the serving stack.
//!
//! The MAC-level simulator (`sim/tmr.rs`) already injects SEUs into
//! individual multipliers; this module pulls the same discipline up to
//! the coordinator so integration tests and `examples/chaos_serving.rs`
//! can prove the resilience pillars end-to-end: a [`FaultPlan`] names
//! *which* global batch index suffers *what* fault (worker panic,
//! batch delay, dropped pool job, SEU bit-flip on a packed partial),
//! and a seeded PRNG makes the SEU placement reproducible run-to-run.
//! Everything is a runtime hook — no `#[cfg]` walls — so the exact
//! binary that serves production traffic is the one under chaos test.

use crate::prng::Pcg32;
use crate::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// One fault to apply while serving a particular batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// Panic inside the worker's supervised execution closure.
    Panic,
    /// Sleep before executing the batch (models a stalled kernel /
    /// GC-style hiccup; drives shedding and deadline machinery).
    Delay(Duration),
    /// Drop the next `PackedPool` slot job instead of running it.
    /// Masked by construction: the caller's inline steal slot drains
    /// every deque, so the tiles seeded to the dead slot are stolen.
    DropPoolJob,
    /// Flip one random bit of one i64 accumulator in the next packed
    /// matmul output (a single-event upset on a partial sum).
    Seu,
}

/// A deterministic schedule of faults, keyed by global batch index
/// (batches are numbered across all workers in dequeue order).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// `(batch_index, action)` pairs; several actions may target the
    /// same batch.
    pub at: Vec<(u64, FaultAction)>,
    /// Seed for the SEU bit-position PRNG.
    pub seed: u64,
}

impl FaultPlan {
    /// Parse a compact spec: comma-separated `kind@batch` items plus an
    /// optional `seed=N`, e.g. `panic@1,delay@0:250ms,drop@2,seu@3,seed=42`.
    /// `delay` takes a `:<millis>ms` (or bare `:<millis>`) argument.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan {
            seed: 0x5eed_fa17,
            ..FaultPlan::default()
        };
        for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            if let Some(v) = part.strip_prefix("seed=") {
                plan.seed = v
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad fault seed {v:?}"))?;
                continue;
            }
            let (kind, rest) = part
                .split_once('@')
                .ok_or_else(|| anyhow::anyhow!("fault item {part:?} is not kind@batch"))?;
            let (batch_s, arg) = match rest.split_once(':') {
                Some((b, a)) => (b, Some(a)),
                None => (rest, None),
            };
            let batch: u64 = batch_s
                .parse()
                .map_err(|_| anyhow::anyhow!("bad batch index in {part:?}"))?;
            let action = match kind {
                "panic" => FaultAction::Panic,
                "drop" => FaultAction::DropPoolJob,
                "seu" => FaultAction::Seu,
                "delay" => {
                    let ms: u64 = arg
                        .unwrap_or("100")
                        .trim_end_matches("ms")
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad delay in {part:?}"))?;
                    FaultAction::Delay(Duration::from_millis(ms))
                }
                other => anyhow::bail!("unknown fault kind {other:?} in {part:?}"),
            };
            plan.at.push((batch, action));
        }
        Ok(plan)
    }

    /// Read a plan from `BITSMM_FAULT_PLAN`; `Ok(None)` when unset.
    pub fn from_env() -> Result<Option<FaultPlan>> {
        match std::env::var("BITSMM_FAULT_PLAN") {
            Ok(spec) if !spec.trim().is_empty() => Ok(Some(FaultPlan::parse(&spec)?)),
            _ => Ok(None),
        }
    }

    /// All actions scheduled for batch `n`.
    pub fn actions_at(&self, n: u64) -> Vec<FaultAction> {
        self.at
            .iter()
            .filter(|(b, _)| *b == n)
            .map(|(_, a)| *a)
            .collect()
    }

    /// Highest batch index any fault targets (for harnesses that must
    /// submit enough work to reach every scheduled fault).
    pub fn last_batch(&self) -> Option<u64> {
        self.at.iter().map(|(b, _)| *b).max()
    }
}

/// Corruption-fault accounting: how many data-corrupting injections
/// ran and whether each was masked (absorbed with bit-identical
/// output) or escaped to a caller-visible value. Availability faults
/// (panics, delays) are counted by `Metrics.panics` / shed machinery
/// instead — they can never corrupt a served result.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    pub injected: u64,
    pub masked: u64,
    pub unmasked: u64,
}

impl FaultStats {
    pub fn merge(&mut self, o: &FaultStats) {
        self.injected += o.injected;
        self.masked += o.masked;
        self.unmasked += o.unmasked;
    }
}

/// Arms SEU injection for the scheduler's packed matmul path: each
/// armed count flips one PRNG-chosen bit of one output accumulator.
#[derive(Debug)]
pub struct SeuInjector {
    armed: AtomicU64,
    rng: Mutex<Pcg32>,
}

impl SeuInjector {
    pub fn new(seed: u64) -> SeuInjector {
        SeuInjector {
            armed: AtomicU64::new(0),
            rng: Mutex::new(Pcg32::new(seed)),
        }
    }

    /// Schedule `n` more single-bit upsets.
    pub fn arm(&self, n: u64) {
        self.armed.fetch_add(n, Ordering::Relaxed);
    }

    /// If armed, flip one bit of one element and consume one charge.
    /// Returns whether a flip happened.
    pub fn maybe_flip(&self, out: &mut [i64]) -> bool {
        if out.is_empty() {
            return false;
        }
        if self
            .armed
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1))
            .is_err()
        {
            return false;
        }
        let mut rng = self.rng.lock().unwrap_or_else(|e| e.into_inner());
        let pos = rng.below_usize(out.len());
        let bit = rng.below(64);
        out[pos] = (out[pos] as u64 ^ (1u64 << bit)) as i64;
        true
    }
}

/// Shared runtime state for a [`FaultPlan`]: the global batch counter
/// (ticked once per dequeued batch, across all workers) and the SEU
/// injector every worker's scheduler points at.
#[derive(Debug)]
pub struct FaultState {
    plan: FaultPlan,
    next_batch: AtomicU64,
    seu: std::sync::Arc<SeuInjector>,
}

impl FaultState {
    pub fn new(plan: FaultPlan) -> FaultState {
        let seu = std::sync::Arc::new(SeuInjector::new(plan.seed));
        FaultState {
            plan,
            next_batch: AtomicU64::new(0),
            seu,
        }
    }

    /// The SEU injector to attach to each worker's scheduler.
    pub fn seu(&self) -> std::sync::Arc<SeuInjector> {
        self.seu.clone()
    }

    /// Claim the next global batch index and return its scheduled
    /// faults. Exactly one call per dequeued batch keeps the numbering
    /// deterministic in *count* (which worker draws which index may
    /// vary, but every scheduled fault fires exactly once).
    pub fn batch_actions(&self) -> Vec<FaultAction> {
        let n = self.next_batch.fetch_add(1, Ordering::Relaxed);
        self.plan.actions_at(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_spec() {
        let p = FaultPlan::parse("panic@1, delay@0:250ms, drop@2, seu@3, seed=42").unwrap();
        assert_eq!(p.seed, 42);
        assert_eq!(p.actions_at(1), vec![FaultAction::Panic]);
        assert_eq!(
            p.actions_at(0),
            vec![FaultAction::Delay(Duration::from_millis(250))]
        );
        assert_eq!(p.actions_at(2), vec![FaultAction::DropPoolJob]);
        assert_eq!(p.actions_at(3), vec![FaultAction::Seu]);
        assert_eq!(p.actions_at(4), vec![]);
        assert_eq!(p.last_batch(), Some(3));
    }

    #[test]
    fn parse_rejects_junk() {
        assert!(FaultPlan::parse("flood@1").is_err());
        assert!(FaultPlan::parse("panic").is_err());
        assert!(FaultPlan::parse("panic@x").is_err());
        assert!(FaultPlan::parse("delay@1:soon").is_err());
        assert!(FaultPlan::parse("seed=abc").is_err());
    }

    #[test]
    fn multiple_actions_same_batch() {
        let p = FaultPlan::parse("delay@2:10ms,seu@2").unwrap();
        let acts = p.actions_at(2);
        assert_eq!(acts.len(), 2);
        assert!(acts.contains(&FaultAction::Seu));
    }

    #[test]
    fn batch_counter_fires_each_fault_once() {
        let st = FaultState::new(FaultPlan::parse("panic@1,seu@2").unwrap());
        assert!(st.batch_actions().is_empty()); // batch 0
        assert_eq!(st.batch_actions(), vec![FaultAction::Panic]); // 1
        assert_eq!(st.batch_actions(), vec![FaultAction::Seu]); // 2
        assert!(st.batch_actions().is_empty()); // 3
    }

    #[test]
    fn seu_flip_is_single_bit_and_deterministic() {
        let run = |seed| {
            let inj = SeuInjector::new(seed);
            inj.arm(1);
            let mut out = vec![7i64; 16];
            assert!(inj.maybe_flip(&mut out));
            assert!(!inj.maybe_flip(&mut out), "charge consumed");
            out
        };
        let a = run(9);
        let b = run(9);
        assert_eq!(a, b, "same seed, same flip");
        let clean = vec![7i64; 16];
        let diffs: Vec<usize> = (0..16).filter(|&i| a[i] != clean[i]).collect();
        assert_eq!(diffs.len(), 1, "exactly one element corrupted");
        let x = (a[diffs[0]] ^ clean[diffs[0]]) as u64;
        assert_eq!(x.count_ones(), 1, "exactly one bit flipped");
    }

    #[test]
    fn unarmed_injector_never_flips() {
        let inj = SeuInjector::new(1);
        let mut out = vec![3i64; 8];
        assert!(!inj.maybe_flip(&mut out));
        assert_eq!(out, vec![3i64; 8]);
    }
}
