//! Deterministic fault injection for the serving stack.
//!
//! The MAC-level simulator (`sim/tmr.rs`) already injects SEUs into
//! individual multipliers; this module pulls the same discipline up to
//! the coordinator so integration tests and `examples/chaos_serving.rs`
//! can prove the resilience pillars end-to-end: a [`FaultPlan`] names
//! *which* global batch index suffers *what* fault (worker panic,
//! batch delay, dropped pool job, SEU bit-flip on a packed partial),
//! and a seeded PRNG makes the SEU placement reproducible run-to-run.
//! Everything is a runtime hook — no `#[cfg]` walls — so the exact
//! binary that serves production traffic is the one under chaos test.

use crate::prng::Pcg32;
use crate::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// One fault to apply while serving a particular batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// Panic inside the worker's supervised execution closure.
    Panic,
    /// Sleep before executing the batch (models a stalled kernel /
    /// GC-style hiccup; drives shedding and deadline machinery).
    Delay(Duration),
    /// Drop the next `PackedPool` slot job instead of running it.
    /// Masked by construction: the caller's inline steal slot drains
    /// every deque, so the tiles seeded to the dead slot are stolen.
    DropPoolJob,
    /// Flip one random bit of one i64 accumulator in the next packed
    /// matmul output (a single-event upset on a partial sum).
    Seu,
    /// Flip one PRNG-chosen live-digit bit of a *resident* packed
    /// weight plane in a model's `PackedCache` — the memory-SEU model
    /// (DESIGN.md §Integrity): unlike [`FaultAction::Seu`], the
    /// corruption persists across batches until the scrubber or the
    /// ABFT escalation ladder repairs it by re-pack.
    MemSeu,
}

/// A deterministic schedule of faults, keyed by global batch index
/// (batches are numbered across all workers in dequeue order).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// `(batch_index, action)` pairs; several actions may target the
    /// same batch.
    pub at: Vec<(u64, FaultAction)>,
    /// Seed for the SEU bit-position PRNG.
    pub seed: u64,
}

impl FaultPlan {
    /// Parse a compact spec: comma-separated `kind@batch` items plus an
    /// optional `seed=N`, e.g. `panic@1,delay@0:250ms,drop@2,seu@3,seed=42`.
    /// `delay` takes a `:<millis>ms` (or bare `:<millis>`) argument.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan {
            seed: 0x5eed_fa17,
            ..FaultPlan::default()
        };
        for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            if let Some(v) = part.strip_prefix("seed=") {
                plan.seed = v
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad fault seed {v:?}"))?;
                continue;
            }
            let (kind, rest) = part
                .split_once('@')
                .ok_or_else(|| anyhow::anyhow!("fault item {part:?} is not kind@batch"))?;
            let (batch_s, arg) = match rest.split_once(':') {
                Some((b, a)) => (b, Some(a)),
                None => (rest, None),
            };
            let batch: u64 = batch_s
                .parse()
                .map_err(|_| anyhow::anyhow!("bad batch index in {part:?}"))?;
            let action = match kind {
                "panic" | "drop" | "seu" | "mem" => {
                    // argless kinds: a stray `:arg` is a spec typo, not
                    // something to silently drop
                    anyhow::ensure!(
                        arg.is_none(),
                        "fault kind {kind:?} takes no argument, got {part:?}"
                    );
                    match kind {
                        "panic" => FaultAction::Panic,
                        "drop" => FaultAction::DropPoolJob,
                        "seu" => FaultAction::Seu,
                        _ => FaultAction::MemSeu,
                    }
                }
                "delay" => {
                    let ms: u64 = arg
                        .unwrap_or("100")
                        .trim_end_matches("ms")
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad delay in {part:?}"))?;
                    FaultAction::Delay(Duration::from_millis(ms))
                }
                other => anyhow::bail!("unknown fault kind {other:?} in {part:?}"),
            };
            plan.at.push((batch, action));
        }
        Ok(plan)
    }

    /// Read a plan from `BITSMM_FAULT_PLAN`; `Ok(None)` when unset.
    pub fn from_env() -> Result<Option<FaultPlan>> {
        match std::env::var("BITSMM_FAULT_PLAN") {
            Ok(spec) if !spec.trim().is_empty() => Ok(Some(FaultPlan::parse(&spec)?)),
            _ => Ok(None),
        }
    }

    /// All actions scheduled for batch `n`.
    pub fn actions_at(&self, n: u64) -> Vec<FaultAction> {
        self.at
            .iter()
            .filter(|(b, _)| *b == n)
            .map(|(_, a)| *a)
            .collect()
    }

    /// Highest batch index any fault targets (for harnesses that must
    /// submit enough work to reach every scheduled fault).
    pub fn last_batch(&self) -> Option<u64> {
        self.at.iter().map(|(b, _)| *b).max()
    }
}

/// Corruption-fault accounting: how many data-corrupting injections
/// ran and whether each was masked (absorbed with bit-identical
/// output) or escaped to a caller-visible value. Availability faults
/// (panics, delays) are counted by `Metrics.panics` / shed machinery
/// instead — they can never corrupt a served result.
///
/// Masked faults classify **transient** (an in-flight upset: the
/// stationary planes verified intact, and the shape had not just
/// ABFT-missed) vs **persistent** (resident corruption: the planes'
/// signatures failed, or the same shape missed ABFT on consecutive
/// executions) — a stuck-at plane must not read as a stream of
/// independent transients in the serve-table ledger.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    pub injected: u64,
    /// Injections via [`FaultAction::MemSeu`] (a subset of `injected`,
    /// broken out so chaos drills can pin the resident-SEU path).
    pub mem_seu: u64,
    pub masked_transient: u64,
    pub masked_persistent: u64,
    pub unmasked: u64,
}

impl FaultStats {
    /// Total masked faults, transient + persistent — the combined
    /// figure the serve table's injected/masked/unmasked row reports.
    pub fn masked(&self) -> u64 {
        self.masked_transient + self.masked_persistent
    }

    pub fn merge(&mut self, o: &FaultStats) {
        self.injected += o.injected;
        self.mem_seu += o.mem_seu;
        self.masked_transient += o.masked_transient;
        self.masked_persistent += o.masked_persistent;
        self.unmasked += o.unmasked;
    }

    /// JSON object for the telemetry snapshot (DESIGN.md
    /// §Observability) — every counter, no derived rates.
    pub fn json(&self) -> String {
        format!(
            "{{\"injected\":{},\"mem_seu\":{},\"masked_transient\":{},\"masked_persistent\":{},\"unmasked\":{}}}",
            self.injected, self.mem_seu, self.masked_transient, self.masked_persistent, self.unmasked
        )
    }
}

/// Resident-state integrity accounting (DESIGN.md §Integrity): sweeps
/// of the background scrubber, plus detections/repairs/quarantines
/// from *either* integrity path — the scrubber's periodic sweep or the
/// scheduler's on-ABFT-miss escalation ladder.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ScrubStats {
    /// Completed scrubber sweeps over every resident cache.
    pub sweeps: u64,
    /// Planes (or golden tensors) found corrupted.
    pub detected: u64,
    /// Corrupted entries restored by evict + re-pack from a
    /// golden-verified source.
    pub repaired: u64,
    /// Slots quarantined because their golden source was itself
    /// corrupt — requests needing them get `ServeError::Quarantined`.
    pub quarantined: u64,
}

impl ScrubStats {
    pub fn merge(&mut self, o: &ScrubStats) {
        self.sweeps += o.sweeps;
        self.detected += o.detected;
        self.repaired += o.repaired;
        self.quarantined += o.quarantined;
    }

    /// JSON object for the telemetry snapshot.
    pub fn json(&self) -> String {
        format!(
            "{{\"sweeps\":{},\"detected\":{},\"repaired\":{},\"quarantined\":{}}}",
            self.sweeps, self.detected, self.repaired, self.quarantined
        )
    }
}

/// Arms SEU injection for the scheduler's packed matmul path: each
/// armed count flips one PRNG-chosen bit of one output accumulator.
#[derive(Debug)]
pub struct SeuInjector {
    armed: AtomicU64,
    rng: Mutex<Pcg32>,
}

impl SeuInjector {
    pub fn new(seed: u64) -> SeuInjector {
        SeuInjector {
            armed: AtomicU64::new(0),
            rng: Mutex::new(Pcg32::new(seed)),
        }
    }

    /// Schedule `n` more single-bit upsets.
    pub fn arm(&self, n: u64) {
        self.armed.fetch_add(n, Ordering::Relaxed);
    }

    /// If armed, flip one bit of one element and consume one charge.
    /// Returns whether a flip happened.
    pub fn maybe_flip(&self, out: &mut [i64]) -> bool {
        if out.is_empty() {
            return false;
        }
        if self
            .armed
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1))
            .is_err()
        {
            return false;
        }
        let mut rng = self.rng.lock().unwrap_or_else(|e| e.into_inner());
        let pos = rng.below_usize(out.len());
        let bit = rng.below(64);
        out[pos] = (out[pos] as u64 ^ (1u64 << bit)) as i64;
        true
    }

    /// Draw a uniform index in `0..n` from the same seeded stream —
    /// the placement oracle for [`FaultAction::MemSeu`] (which cache
    /// entry, which plane, which live digit), so resident upsets are
    /// reproducible run-to-run like everything else in the plan.
    pub fn pick(&self, n: usize) -> usize {
        let mut rng = self.rng.lock().unwrap_or_else(|e| e.into_inner());
        rng.below_usize(n.max(1))
    }
}

/// Shared runtime state for a [`FaultPlan`]: the global batch counter
/// (ticked once per dequeued batch, across all workers) and the SEU
/// injector every worker's scheduler points at.
#[derive(Debug)]
pub struct FaultState {
    plan: FaultPlan,
    next_batch: AtomicU64,
    seu: std::sync::Arc<SeuInjector>,
}

impl FaultState {
    pub fn new(plan: FaultPlan) -> FaultState {
        let seu = std::sync::Arc::new(SeuInjector::new(plan.seed));
        FaultState {
            plan,
            next_batch: AtomicU64::new(0),
            seu,
        }
    }

    /// The SEU injector to attach to each worker's scheduler.
    pub fn seu(&self) -> std::sync::Arc<SeuInjector> {
        self.seu.clone()
    }

    /// Claim the next global batch index and return its scheduled
    /// faults. Exactly one call per dequeued batch keeps the numbering
    /// deterministic in *count* (which worker draws which index may
    /// vary, but every scheduled fault fires exactly once).
    pub fn batch_actions(&self) -> Vec<FaultAction> {
        let n = self.next_batch.fetch_add(1, Ordering::Relaxed);
        self.plan.actions_at(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_spec() {
        let p = FaultPlan::parse("panic@1, delay@0:250ms, drop@2, seu@3, seed=42").unwrap();
        assert_eq!(p.seed, 42);
        assert_eq!(p.actions_at(1), vec![FaultAction::Panic]);
        assert_eq!(
            p.actions_at(0),
            vec![FaultAction::Delay(Duration::from_millis(250))]
        );
        assert_eq!(p.actions_at(2), vec![FaultAction::DropPoolJob]);
        assert_eq!(p.actions_at(3), vec![FaultAction::Seu]);
        assert_eq!(p.actions_at(4), vec![]);
        assert_eq!(p.last_batch(), Some(3));
    }

    #[test]
    fn parse_rejects_junk() {
        assert!(FaultPlan::parse("flood@1").is_err());
        assert!(FaultPlan::parse("panic").is_err());
        assert!(FaultPlan::parse("panic@x").is_err());
        assert!(FaultPlan::parse("delay@1:soon").is_err());
        assert!(FaultPlan::parse("seed=abc").is_err());
    }

    #[test]
    fn parse_mem_seu_and_rejects_args_on_argless_kinds() {
        let p = FaultPlan::parse("mem@2,seed=7").unwrap();
        assert_eq!(p.actions_at(2), vec![FaultAction::MemSeu]);
        // a stray `:arg` on an argless kind is an error, not silently
        // dropped (the old parser accepted `seu@3:5` and ignored the 5)
        for bad in ["seu@3:5", "panic@1:oops", "drop@2:1", "mem@4:9"] {
            let err = FaultPlan::parse(bad).unwrap_err().to_string();
            assert!(
                err.contains("takes no argument"),
                "{bad:?} must be rejected with a clear error, got {err:?}"
            );
        }
        // delay still takes its argument either way
        assert!(FaultPlan::parse("delay@1:50ms").is_ok());
        assert!(FaultPlan::parse("delay@1").is_ok());
    }

    #[test]
    fn fault_stats_split_masked_merge() {
        let mut s = FaultStats {
            injected: 2,
            mem_seu: 1,
            masked_transient: 1,
            masked_persistent: 1,
            unmasked: 0,
        };
        assert_eq!(s.masked(), 2);
        s.merge(&FaultStats {
            injected: 1,
            mem_seu: 0,
            masked_transient: 0,
            masked_persistent: 1,
            unmasked: 0,
        });
        assert_eq!(s.injected, 3);
        assert_eq!(s.mem_seu, 1);
        assert_eq!(s.masked_transient, 1);
        assert_eq!(s.masked_persistent, 2);
        assert_eq!(s.masked(), 3);
    }

    #[test]
    fn scrub_stats_merge_and_injector_pick_determinism() {
        let mut s = ScrubStats { sweeps: 1, detected: 1, repaired: 1, quarantined: 0 };
        s.merge(&ScrubStats { sweeps: 2, detected: 0, repaired: 0, quarantined: 1 });
        assert_eq!(s, ScrubStats { sweeps: 3, detected: 1, repaired: 1, quarantined: 1 });
        let a = SeuInjector::new(11);
        let b = SeuInjector::new(11);
        let da: Vec<usize> = (0..8).map(|_| a.pick(100)).collect();
        let db: Vec<usize> = (0..8).map(|_| b.pick(100)).collect();
        assert_eq!(da, db, "same seed, same placement draws");
        assert!(da.iter().all(|&v| v < 100));
        assert_eq!(SeuInjector::new(1).pick(0), 0, "empty ranges degrade to 0");
    }

    #[test]
    fn multiple_actions_same_batch() {
        let p = FaultPlan::parse("delay@2:10ms,seu@2").unwrap();
        let acts = p.actions_at(2);
        assert_eq!(acts.len(), 2);
        assert!(acts.contains(&FaultAction::Seu));
    }

    #[test]
    fn batch_counter_fires_each_fault_once() {
        let st = FaultState::new(FaultPlan::parse("panic@1,seu@2").unwrap());
        assert!(st.batch_actions().is_empty()); // batch 0
        assert_eq!(st.batch_actions(), vec![FaultAction::Panic]); // 1
        assert_eq!(st.batch_actions(), vec![FaultAction::Seu]); // 2
        assert!(st.batch_actions().is_empty()); // 3
    }

    #[test]
    fn seu_flip_is_single_bit_and_deterministic() {
        let run = |seed| {
            let inj = SeuInjector::new(seed);
            inj.arm(1);
            let mut out = vec![7i64; 16];
            assert!(inj.maybe_flip(&mut out));
            assert!(!inj.maybe_flip(&mut out), "charge consumed");
            out
        };
        let a = run(9);
        let b = run(9);
        assert_eq!(a, b, "same seed, same flip");
        let clean = vec![7i64; 16];
        let diffs: Vec<usize> = (0..16).filter(|&i| a[i] != clean[i]).collect();
        assert_eq!(diffs.len(), 1, "exactly one element corrupted");
        let x = (a[diffs[0]] ^ clean[diffs[0]]) as u64;
        assert_eq!(x.count_ones(), 1, "exactly one bit flipped");
    }

    #[test]
    fn unarmed_injector_never_flips() {
        let inj = SeuInjector::new(1);
        let mut out = vec![3i64; 8];
        assert!(!inj.maybe_flip(&mut out));
        assert_eq!(out, vec![3i64; 8]);
    }
}
