//! Dynamic batcher: groups inference requests into batches to raise SA
//! occupancy (larger effective M per matmul → more MAC rows active),
//! bounded by a maximum batch size and a linger deadline — the standard
//! serving trade between throughput and tail latency.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Batcher tuning.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Maximum requests per batch.
    pub max_batch: usize,
    /// How long an incomplete batch may wait for more requests.
    pub linger: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 8,
            linger: Duration::from_millis(2),
        }
    }
}

/// A batch handed to the execution engine.
#[derive(Debug)]
pub struct Batch<T> {
    pub items: Vec<T>,
    /// When the oldest item entered the queue (for latency accounting).
    pub oldest: Instant,
}

struct Inner<T> {
    queue: VecDeque<(T, Instant)>,
    closed: bool,
}

/// Thread-safe dynamic batcher.
pub struct Batcher<T> {
    cfg: BatcherConfig,
    inner: Mutex<Inner<T>>,
    cv: Condvar,
}

impl<T> Batcher<T> {
    pub fn new(cfg: BatcherConfig) -> Self {
        assert!(cfg.max_batch >= 1);
        Batcher {
            cfg,
            inner: Mutex::new(Inner {
                queue: VecDeque::new(),
                closed: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue one request.
    pub fn push(&self, item: T) {
        let mut g = self.inner.lock().unwrap();
        g.queue.push_back((item, Instant::now()));
        drop(g);
        self.cv.notify_one();
    }

    /// Signal that no more requests will arrive; blocked `next_batch`
    /// callers drain and then observe `None`.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Current queue depth (for backpressure decisions).
    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    /// Block for the next batch: returns as soon as `max_batch` items
    /// are available, or when the linger deadline passes with at least
    /// one item, or `None` once closed and drained.
    pub fn next_batch(&self) -> Option<Batch<T>> {
        let mut g = self.inner.lock().unwrap();
        loop {
            // wait for the first item (or closure)
            while g.queue.is_empty() {
                if g.closed {
                    return None;
                }
                g = self.cv.wait(g).unwrap();
            }
            // have at least one: linger for a full batch. The deadline
            // is anchored to when the *oldest currently-queued* item
            // was enqueued — not to when this consumer woke up — so a
            // request that already waited while the worker ran the
            // previous batch never pays a second full linger. It is
            // re-derived each iteration: if another consumer takes the
            // front item mid-wait, the new front's (younger) enqueue
            // time re-anchors the deadline instead of leaking the old,
            // possibly expired one onto a fresh request.
            while g.queue.len() < self.cfg.max_batch && !g.closed {
                let front_t = match g.queue.front() {
                    Some(&(_, t)) => t,
                    None => break, // raced: re-enter the outer wait
                };
                let deadline = front_t + self.cfg.linger;
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                g = self.cv.wait_timeout(g, deadline - now).unwrap().0;
            }
            if g.queue.is_empty() {
                continue; // raced with another consumer
            }
            let take = g.queue.len().min(self.cfg.max_batch);
            let mut items = Vec::with_capacity(take);
            let mut oldest = Instant::now();
            for _ in 0..take {
                let (item, t) = g.queue.pop_front().unwrap();
                oldest = oldest.min(t);
                items.push(item);
            }
            return Some(Batch { items, oldest });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn full_batch_returns_immediately() {
        let b = Batcher::new(BatcherConfig {
            max_batch: 3,
            linger: Duration::from_secs(10), // would hang if linger waited
        });
        for i in 0..3 {
            b.push(i);
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.items, vec![0, 1, 2]);
    }

    #[test]
    fn linger_flushes_partial_batch() {
        let b = Batcher::new(BatcherConfig {
            max_batch: 100,
            linger: Duration::from_millis(5),
        });
        b.push(42);
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.items, vec![42]);
        assert!(t0.elapsed() >= Duration::from_millis(4));
    }

    #[test]
    fn stale_item_flushes_without_second_linger() {
        let b = Batcher::new(BatcherConfig {
            max_batch: 100,
            linger: Duration::from_millis(300),
        });
        b.push(7);
        // simulate the consumer being busy with a previous batch for
        // longer than the linger: the deadline anchors to the enqueue
        // time, so the already-stale item must flush immediately
        std::thread::sleep(Duration::from_millis(400));
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.items, vec![7]);
        assert!(
            t0.elapsed() < Duration::from_millis(150),
            "stale item paid a second linger: {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn close_drains_then_none() {
        let b = Batcher::new(BatcherConfig {
            max_batch: 2,
            linger: Duration::from_millis(1),
        });
        b.push(1);
        b.close();
        assert_eq!(b.next_batch().unwrap().items, vec![1]);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn concurrent_producers_all_served() {
        let b = Arc::new(Batcher::new(BatcherConfig {
            max_batch: 4,
            linger: Duration::from_millis(1),
        }));
        let n = 64;
        let mut handles = Vec::new();
        for t in 0..4 {
            let b2 = b.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..n / 4 {
                    b2.push(t * 1000 + i);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        b.close();
        let mut seen = 0;
        while let Some(batch) = b.next_batch() {
            assert!(batch.items.len() <= 4);
            seen += batch.items.len();
        }
        assert_eq!(seen, n as usize);
    }
}
