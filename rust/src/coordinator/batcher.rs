//! Dynamic batcher: groups inference requests into batches to raise SA
//! occupancy (larger effective M per matmul → more MAC rows active),
//! bounded by a maximum batch size and a linger deadline — the standard
//! serving trade between throughput and tail latency.
//!
//! The batcher is also the admission-control point of the serving
//! stack: a bounded queue refuses pushes once `max_queue` items are
//! waiting, and a queue-age budget (`shed_after`) sheds items the
//! consumer is too late to serve so a worker never spends a matmul on
//! a request whose client has already given up (DESIGN.md §Resilience).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Batcher tuning.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Maximum requests per batch.
    pub max_batch: usize,
    /// How long an incomplete batch may wait for more requests.
    pub linger: Duration,
    /// Bounded-queue admission limit; `0` means unbounded (the
    /// pre-resilience behaviour). Pushes beyond the limit are refused
    /// with [`PushRefused::Full`].
    pub max_queue: usize,
    /// Queue-age budget: items that have waited longer than this when
    /// a batch is formed are moved to [`Batch::shed`] instead of
    /// [`Batch::items`], for the consumer to answer with an overload
    /// error. `None` disables shedding.
    pub shed_after: Option<Duration>,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 8,
            linger: Duration::from_millis(2),
            max_queue: 0,
            shed_after: None,
        }
    }
}

/// Why [`Batcher::push`] refused an item. The item is handed back so
/// the caller can answer its submitter instead of losing it.
#[derive(Debug)]
pub enum PushRefused<T> {
    /// The bounded queue is at `max_queue`; `depth` is the queue depth
    /// observed at refusal time.
    Full { item: T, depth: usize },
    /// `close()` already ran: no consumer will ever drain this item.
    Closed { item: T },
}

/// A batch handed to the execution engine.
#[derive(Debug)]
pub struct Batch<T> {
    pub items: Vec<T>,
    /// When the oldest item entered the queue (for latency accounting).
    pub oldest: Instant,
    /// How long each entry of `items` waited in the queue before this
    /// batch formed (same order as `items` — the request tracer turns
    /// these into per-request `queue_wait` spans).
    pub waits: Vec<Duration>,
    /// When the batch was assembled (the `assemble` span's endpoint).
    pub assembled: Instant,
    /// Items whose queue age exceeded `shed_after`, paired with how
    /// long each actually waited. The consumer must still answer them
    /// (with an overload error) — they are shed from execution, not
    /// from accounting.
    pub shed: Vec<(T, Duration)>,
}

struct Inner<T> {
    queue: VecDeque<(T, Instant)>,
    closed: bool,
}

/// Thread-safe dynamic batcher.
pub struct Batcher<T> {
    cfg: BatcherConfig,
    inner: Mutex<Inner<T>>,
    cv: Condvar,
}

impl<T> Batcher<T> {
    pub fn new(cfg: BatcherConfig) -> Self {
        assert!(cfg.max_batch >= 1);
        Batcher {
            cfg,
            inner: Mutex::new(Inner {
                queue: VecDeque::new(),
                closed: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue one request. Refuses (returning the item) when the
    /// bounded queue is full or the batcher is closed, so no request
    /// is ever silently stranded in a queue nobody will drain.
    pub fn push(&self, item: T) -> Result<(), PushRefused<T>> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(PushRefused::Closed { item });
        }
        if self.cfg.max_queue > 0 && g.queue.len() >= self.cfg.max_queue {
            let depth = g.queue.len();
            return Err(PushRefused::Full { item, depth });
        }
        g.queue.push_back((item, Instant::now()));
        drop(g);
        self.cv.notify_one();
        Ok(())
    }

    /// Signal that no more requests will arrive; blocked `next_batch`
    /// callers drain and then observe `None`.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Current queue depth (for backpressure decisions).
    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    /// Block for the next batch: returns as soon as `max_batch` items
    /// are available, or when the linger deadline passes with at least
    /// one item, or `None` once closed and drained. Items older than
    /// `shed_after` come back in [`Batch::shed`] rather than
    /// [`Batch::items`]; a batch may be shed-only if everything queued
    /// was overdue.
    pub fn next_batch(&self) -> Option<Batch<T>> {
        let mut g = self.inner.lock().unwrap();
        loop {
            // wait for the first item (or closure)
            while g.queue.is_empty() {
                if g.closed {
                    return None;
                }
                g = self.cv.wait(g).unwrap();
            }
            // have at least one: linger for a full batch. The deadline
            // is anchored to when the *oldest currently-queued* item
            // was enqueued — not to when this consumer woke up — so a
            // request that already waited while the worker ran the
            // previous batch never pays a second full linger. It is
            // re-derived each iteration: if another consumer takes the
            // front item mid-wait, the new front's (younger) enqueue
            // time re-anchors the deadline instead of leaking the old,
            // possibly expired one onto a fresh request. An over-age
            // front (past `shed_after`) also expires the linger, so
            // sheds are answered promptly rather than after a wait
            // they have already lost.
            while g.queue.len() < self.cfg.max_batch && !g.closed {
                let front_t = match g.queue.front() {
                    Some(&(_, t)) => t,
                    None => break, // raced: re-enter the outer wait
                };
                let deadline = front_t + self.cfg.linger;
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                if let Some(budget) = self.cfg.shed_after {
                    if now.duration_since(front_t) > budget {
                        break;
                    }
                }
                g = self.cv.wait_timeout(g, deadline - now).unwrap().0;
            }
            if g.queue.is_empty() {
                continue; // raced with another consumer
            }
            // shed-by-age: enqueue times are monotonic front-to-back
            // (pushes append under the same lock), so over-budget items
            // form a prefix — pop until the front is young enough.
            let mut shed = Vec::new();
            if let Some(budget) = self.cfg.shed_after {
                let now = Instant::now();
                while let Some((_, t)) = g.queue.front() {
                    let waited = now.duration_since(*t);
                    if waited <= budget {
                        break;
                    }
                    let (item, _) = g.queue.pop_front().unwrap();
                    shed.push((item, waited));
                }
            }
            let take = g.queue.len().min(self.cfg.max_batch);
            if take == 0 && shed.is_empty() {
                continue; // raced: everything vanished under the lock
            }
            let mut items = Vec::with_capacity(take);
            let mut waits = Vec::with_capacity(take);
            let assembled = Instant::now();
            let mut oldest = assembled;
            for _ in 0..take {
                let (item, t) = g.queue.pop_front().unwrap();
                oldest = oldest.min(t);
                waits.push(assembled.duration_since(t));
                items.push(item);
            }
            return Some(Batch {
                items,
                oldest,
                waits,
                assembled,
                shed,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn full_batch_returns_immediately() {
        let b = Batcher::new(BatcherConfig {
            max_batch: 3,
            linger: Duration::from_secs(10), // would hang if linger waited
            ..BatcherConfig::default()
        });
        for i in 0..3 {
            b.push(i).unwrap();
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.items, vec![0, 1, 2]);
        assert!(batch.shed.is_empty());
        assert_eq!(batch.waits.len(), 3, "one wait per kept item");
        assert!(batch.assembled >= batch.oldest);
    }

    #[test]
    fn linger_flushes_partial_batch() {
        let b = Batcher::new(BatcherConfig {
            max_batch: 100,
            linger: Duration::from_millis(5),
            ..BatcherConfig::default()
        });
        b.push(42).unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.items, vec![42]);
        assert!(t0.elapsed() >= Duration::from_millis(4));
    }

    #[test]
    fn stale_item_flushes_without_second_linger() {
        let b = Batcher::new(BatcherConfig {
            max_batch: 100,
            linger: Duration::from_millis(300),
            ..BatcherConfig::default()
        });
        b.push(7).unwrap();
        // simulate the consumer being busy with a previous batch for
        // longer than the linger: the deadline anchors to the enqueue
        // time, so the already-stale item must flush immediately
        std::thread::sleep(Duration::from_millis(400));
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.items, vec![7]);
        assert!(
            t0.elapsed() < Duration::from_millis(150),
            "stale item paid a second linger: {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn close_drains_then_none() {
        let b = Batcher::new(BatcherConfig {
            max_batch: 2,
            linger: Duration::from_millis(1),
            ..BatcherConfig::default()
        });
        b.push(1).unwrap();
        b.close();
        assert_eq!(b.next_batch().unwrap().items, vec![1]);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn concurrent_producers_all_served() {
        let b = Arc::new(Batcher::new(BatcherConfig {
            max_batch: 4,
            linger: Duration::from_millis(1),
            ..BatcherConfig::default()
        }));
        let n = 64;
        let mut handles = Vec::new();
        for t in 0..4 {
            let b2 = b.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..n / 4 {
                    b2.push(t * 1000 + i).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        b.close();
        let mut seen = 0;
        while let Some(batch) = b.next_batch() {
            assert!(batch.items.len() <= 4);
            seen += batch.items.len();
        }
        assert_eq!(seen, n as usize);
    }

    #[test]
    fn queue_full_rejects_with_depth() {
        let b = Batcher::new(BatcherConfig {
            max_batch: 8,
            linger: Duration::from_millis(1),
            max_queue: 2,
            ..BatcherConfig::default()
        });
        b.push(1).unwrap();
        b.push(2).unwrap();
        match b.push(3) {
            Err(PushRefused::Full { item, depth }) => {
                assert_eq!(item, 3);
                assert_eq!(depth, 2);
            }
            other => panic!("expected Full refusal, got {other:?}"),
        }
        // draining makes room again
        assert_eq!(b.next_batch().unwrap().items, vec![1, 2]);
        b.push(4).unwrap();
    }

    #[test]
    fn push_after_close_refused() {
        let b = Batcher::new(BatcherConfig::default());
        b.close();
        match b.push(9) {
            Err(PushRefused::Closed { item }) => assert_eq!(item, 9),
            other => panic!("expected Closed refusal, got {other:?}"),
        }
        // nothing silently enqueued
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn shed_by_age_keeps_young_items() {
        let b = Batcher::new(BatcherConfig {
            max_batch: 8,
            linger: Duration::from_millis(1),
            shed_after: Some(Duration::from_millis(50)),
            ..BatcherConfig::default()
        });
        b.push(1).unwrap(); // will exceed the age budget
        std::thread::sleep(Duration::from_millis(120));
        b.push(2).unwrap(); // still fresh
        let batch = b.next_batch().unwrap();
        let shed_items: Vec<i32> = batch.shed.iter().map(|(i, _)| *i).collect();
        assert_eq!(shed_items, vec![1], "older-than-budget item shed");
        assert!(
            batch.shed[0].1 >= Duration::from_millis(100),
            "shed carries the observed wait"
        );
        assert_eq!(batch.items, vec![2], "younger item kept");
    }

    #[test]
    fn shed_only_batch_when_everything_overdue() {
        let b = Batcher::new(BatcherConfig {
            max_batch: 8,
            linger: Duration::from_millis(1),
            shed_after: Some(Duration::from_millis(20)),
            ..BatcherConfig::default()
        });
        b.push(1).unwrap();
        b.push(2).unwrap();
        std::thread::sleep(Duration::from_millis(60));
        let batch = b.next_batch().unwrap();
        assert!(batch.items.is_empty());
        let shed_items: Vec<i32> = batch.shed.iter().map(|(i, _)| *i).collect();
        assert_eq!(shed_items, vec![1, 2], "FIFO order preserved in shed");
    }

    #[test]
    fn shedding_reanchors_linger_to_surviving_front() {
        // An overdue front must not make the batcher linger a full
        // period on its behalf, and after the shed the young survivor
        // flushes with the batch — total wait stays far below the
        // linger that anchored to the dead item.
        let b = Batcher::new(BatcherConfig {
            max_batch: 100,
            linger: Duration::from_millis(400),
            shed_after: Some(Duration::from_millis(60)),
            ..BatcherConfig::default()
        });
        b.push(1).unwrap();
        std::thread::sleep(Duration::from_millis(100));
        b.push(2).unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert!(
            t0.elapsed() < Duration::from_millis(200),
            "over-age front should expire the linger, waited {:?}",
            t0.elapsed()
        );
        let shed_items: Vec<i32> = batch.shed.iter().map(|(i, _)| *i).collect();
        assert_eq!(shed_items, vec![1]);
        assert_eq!(batch.items, vec![2]);
    }
}
