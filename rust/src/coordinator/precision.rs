//! Per-layer precision policy — the runtime-configurable bit-width
//! knob the paper highlights ("different layers (or groups of
//! parameters) can use different bit-widths", §V).

use crate::nn::model::Model;
use crate::nn::quant::{quant_snr_db, quantize_symmetric};
use crate::Result;

/// How operand precision is chosen per layer.
#[derive(Debug, Clone, PartialEq)]
pub enum PrecisionPolicy {
    /// One width for every layer.
    Uniform(u32),
    /// Explicit per-layer widths.
    PerLayer(Vec<u32>),
    /// Choose the smallest width whose weight-quantization SNR meets a
    /// target (the Dynamic-Stripes-style adaptivity of §II-D, applied
    /// per layer at load time).
    Adaptive { snr_target_db: f64 },
}

impl PrecisionPolicy {
    /// Resolve to one width per layer of `model`. For `Adaptive`, the
    /// layer's *weights* are requantized at increasing widths until the
    /// SNR target is met (weights are what we control at load time).
    pub fn resolve(&self, model: &Model) -> Result<Vec<u32>> {
        let n = model.layers.len();
        match self {
            PrecisionPolicy::Uniform(bits) => {
                crate::validate_bits(*bits)?;
                Ok(vec![*bits; n])
            }
            PrecisionPolicy::PerLayer(v) => {
                anyhow::ensure!(v.len() == n, "policy length {} vs {} layers", v.len(), n);
                for &b in v {
                    crate::validate_bits(b)?;
                }
                Ok(v.clone())
            }
            PrecisionPolicy::Adaptive { snr_target_db } => {
                let mut out = Vec::with_capacity(n);
                for layer in &model.layers {
                    let w = match layer {
                        crate::nn::layers::Layer::Linear(l) => &l.w,
                        crate::nn::layers::Layer::Conv2d(l) => &l.w,
                        crate::nn::layers::Layer::Attention(l) => &l.wq,
                        // no weights, no arithmetic: any legal width
                        crate::nn::layers::Layer::Flatten => {
                            out.push(1);
                            continue;
                        }
                    };
                    let real: Vec<f64> = w.data.iter().map(|&q| q as f64 * w.scale).collect();
                    let mut chosen = crate::MAX_BITS;
                    for bits in 2..=crate::MAX_BITS {
                        let t = quantize_symmetric(&real, w.shape.clone(), bits)?;
                        if quant_snr_db(&real, &t) >= *snr_target_db {
                            chosen = bits;
                            break;
                        }
                    }
                    out.push(chosen.max(layer.bits().min(crate::MAX_BITS)).min(crate::MAX_BITS));
                }
                Ok(out)
            }
        }
    }

    /// The matmul shape census of `model` with this policy's resolved
    /// widths substituted for the per-layer precisions — the sweep set
    /// the execution planner (`bitsmm tune`, warm start) seeds its
    /// plan cache from, so a precision re-plan finds its plans already
    /// resolved (DESIGN.md §Planner).
    pub fn shape_census(
        &self,
        model: &Model,
        batch: usize,
    ) -> Result<Vec<(usize, usize, usize, u32)>> {
        let widths = self.resolve(model)?;
        Ok(model.matmul_shapes_with(batch, Some(&widths)))
    }

    /// Relative latency of the policy vs uniform-16-bit on the same
    /// model (eq. 8: cycles scale linearly with width).
    pub fn latency_fraction(&self, model: &Model) -> Result<f64> {
        let widths = self.resolve(model)?;
        let stats = model.stats(1);
        let base: f64 = stats.per_layer.iter().map(|l| l.2 as f64 * 16.0).sum();
        let ours: f64 = stats
            .per_layer
            .iter()
            .zip(&widths)
            .map(|(l, &b)| l.2 as f64 * b as f64)
            .sum();
        Ok(ours / base)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::model::mlp_zoo;

    #[test]
    fn uniform_resolves() {
        let m = mlp_zoo(1);
        assert_eq!(PrecisionPolicy::Uniform(8).resolve(&m).unwrap(), vec![8, 8, 8]);
        assert!(PrecisionPolicy::Uniform(0).resolve(&m).is_err());
    }

    #[test]
    fn per_layer_validates_length() {
        let m = mlp_zoo(1);
        assert!(PrecisionPolicy::PerLayer(vec![8, 4]).resolve(&m).is_err());
        assert_eq!(
            PrecisionPolicy::PerLayer(vec![8, 4, 2]).resolve(&m).unwrap(),
            vec![8, 4, 2]
        );
    }

    #[test]
    fn adaptive_monotone_in_target() {
        let m = mlp_zoo(1);
        let lo = PrecisionPolicy::Adaptive { snr_target_db: 10.0 }
            .resolve(&m)
            .unwrap();
        let hi = PrecisionPolicy::Adaptive { snr_target_db: 45.0 }
            .resolve(&m)
            .unwrap();
        for (a, b) in lo.iter().zip(&hi) {
            assert!(a <= b, "{lo:?} vs {hi:?}");
        }
    }

    #[test]
    fn shape_census_substitutes_policy_widths() {
        let m = mlp_zoo(1);
        let census = PrecisionPolicy::Uniform(6).shape_census(&m, 2).unwrap();
        assert_eq!(
            census,
            vec![(2, 32, 10, 6), (2, 64, 32, 6), (2, 64, 64, 6)]
        );
        // per-layer policies carry their widths through layer order
        let per = PrecisionPolicy::PerLayer(vec![8, 2, 2]).shape_census(&m, 1).unwrap();
        assert!(per.contains(&(1, 64, 64, 8)) && per.contains(&(1, 32, 10, 2)));
    }

    #[test]
    fn latency_fraction_scales_with_width() {
        let m = mlp_zoo(1);
        let f8 = PrecisionPolicy::Uniform(8).latency_fraction(&m).unwrap();
        let f4 = PrecisionPolicy::Uniform(4).latency_fraction(&m).unwrap();
        assert!((f8 - 0.5).abs() < 1e-12);
        assert!((f4 - 0.25).abs() < 1e-12);
    }
}
