//! L3 coordinator: the serving stack around the accelerator.
//!
//! The paper's contribution is the accelerator itself; its conclusion
//! (§V) calls for integration "into a complete NN accelerator to
//! benchmark end-to-end workloads" — this module is that integration:
//!
//! * [`tiler`] — maps arbitrary `M×K×N` matmuls onto SA-sized output
//!   tiles (output-stationary, K unbounded per eq. 8).
//! * [`precision`] — per-layer bit-width policy (uniform, per-layer,
//!   or SNR-adaptive), the paper's headline flexibility.
//! * [`batcher`] — dynamic batching of inference requests.
//! * [`scheduler`] — routes each matmul to an execution backend (PJRT
//!   artifact / cycle-accurate simulator / native planes; all
//!   bit-identical) while accounting cycles on the *hardware* timing
//!   model, i.e. functional–timing co-simulation.
//! * [`server`] — the threaded request loop with latency metrics.
//! * [`faults`] — deterministic fault injection (worker panics, batch
//!   delays, dropped pool jobs, SEU bit-flips) so the resilience layer
//!   is provable end-to-end (DESIGN.md §Resilience).

pub mod batcher;
pub mod faults;
pub mod metrics;
pub mod precision;
pub mod scheduler;
pub mod server;
pub mod tiler;

pub use batcher::{Batch, Batcher, BatcherConfig, PushRefused};
pub use faults::{FaultAction, FaultPlan, FaultState, FaultStats, SeuInjector};
pub use metrics::{imbalance_label, LatencyStats, Metrics, MetricsHub};
pub use precision::PrecisionPolicy;
pub use scheduler::{Backend, ExecutionReport, Scheduler};
pub use server::{
    serve_all, shaped_inputs, DegradePolicy, InferenceServer, Priority, Request, Response,
    ServeError, ServerConfig, TensorInput,
};
pub mod entry;
pub use entry::{serve_all_entry, simulate_entry, SaParse};
pub use tiler::{tile_matmul, TileJob, TilePlan};
