//! The inference server: threaded request loop over the batcher,
//! scheduler and model — the end-to-end serving path of the `e2e`
//! example (and the paper's future-work integration, §V).

use crate::bits::packed::{KernelFamily, PackedPool, PopcountKernel, TilePolicy};
use crate::bits::plane::PlaneKind;
use crate::coordinator::batcher::{Batch, Batcher, BatcherConfig};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::scheduler::{Backend, ExecutionReport, Scheduler};
use crate::nn::model::Model;
use crate::nn::tensor::QTensor;
use crate::plan::{calibrate_shape, PlanKey, Planner, PlannerMode};
use crate::sim::array::SaConfig;
use crate::Result;
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

/// A shaped request payload: quantized values on the model's input
/// grid plus their shape, validated server-side against
/// [`Model::input_shape`] — rank 1 for vector models (MLP rows), rank
/// 2 for token matrices (attention), rank 3 for images (CNN).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorInput {
    pub data: Vec<i32>,
    pub shape: Vec<usize>,
}

impl TensorInput {
    pub fn new(data: Vec<i32>, shape: Vec<usize>) -> TensorInput {
        TensorInput { data, shape }
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Plain vectors keep the historical row-request ergonomics.
impl From<Vec<i32>> for TensorInput {
    fn from(data: Vec<i32>) -> TensorInput {
        let shape = vec![data.len()];
        TensorInput { data, shape }
    }
}

/// Random shaped requests on `model`'s input grid (any rank) — the one
/// generator behind the CLI entries, the e2e example, and the
/// integration tests, so the request contract cannot drift per caller.
pub fn shaped_inputs(model: &Model, n: usize, seed: u64) -> Vec<TensorInput> {
    let numel: usize = model.input_shape.iter().product();
    let lo = crate::bits::twos::min_value(model.input_bits);
    let hi = crate::bits::twos::max_value(model.input_bits);
    let mut rng = crate::prng::Pcg32::new(seed);
    (0..n)
        .map(|_| {
            TensorInput::new(
                (0..numel).map(|_| rng.range_i32(lo, hi)).collect(),
                model.input_shape.clone(),
            )
        })
        .collect()
}

/// One inference request: a quantized, shaped input for the model.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub input: TensorInput,
    pub submitted: Instant,
}

/// One completed inference.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// Output activations (dequantized logits), or the serving error —
    /// validation and execution failures reach the submitter with
    /// their cause instead of a silently dropped channel.
    pub output: std::result::Result<Vec<f64>, String>,
    pub latency: std::time::Duration,
}

/// Server tuning.
#[derive(Clone)]
pub struct ServerConfig {
    pub sa: SaConfig,
    pub backend: Backend,
    pub batcher: BatcherConfig,
    pub workers: usize,
    /// Hardware clock for GOPS accounting (300 MHz = the paper's FPGA
    /// operating point).
    pub clock_hz: f64,
    /// Packed-kernel worker threads, shared by **all** request workers
    /// through one [`PackedPool`] so kernel parallelism composes with
    /// (not multiplies against) request parallelism. `0` = auto:
    /// available cores / `workers`, min 1. `1` = single-thread kernel
    /// (no pool). Ignored by non-packed backends.
    pub packed_threads: usize,
    /// Popcount reducer for the packed kernel (`Auto` = AVX2/NEON when
    /// the CPU has one, else 8-word unrolled chunks).
    pub packed_unroll: PopcountKernel,
    /// Output rows per pooled-kernel tile job (`0` = auto: adapt to the
    /// batch shape and worker count — see DESIGN.md §Packed-Threading).
    pub packed_tile_rows: usize,
    /// Output columns per pooled-kernel tile job (`0` = auto).
    pub packed_tile_cols: usize,
    /// Contracted-dimension chunks per pooled tile job
    /// (`server.packed_ksplit`, `--packed-ksplit`; `0` = auto: split
    /// only when the output grid alone cannot feed the pool, `1` =
    /// never split). Deterministic and bit-identical — see DESIGN.md
    /// §Sub-popcount-Kernels.
    pub packed_ksplit: usize,
    /// Route static-path packed matmuls through the RSR segment-reuse
    /// kernel family (`server.packed_rsr`, `--packed-rsr`) instead of
    /// direct popcount. With a planner attached the family is chosen
    /// per shape class and this knob is ignored.
    pub packed_rsr: bool,
    /// Shape-keyed execution planner shared by every worker's
    /// scheduler (`server.planner = off|static|online`, `--planner`).
    /// `None` (or `Off`): the static knobs above run every matmul —
    /// the pre-planner behavior. See DESIGN.md §Planner.
    pub planner: Option<Arc<Planner>>,
    /// Persist the planner's tuned plans to this file on graceful
    /// shutdown (atomic rename, fingerprint-stamped, merged into any
    /// same-host file already there). `None` = never persist.
    pub plan_persist: Option<std::path::PathBuf>,
}

impl ServerConfig {
    pub fn new(sa: SaConfig, backend: Backend) -> Self {
        ServerConfig {
            sa,
            backend,
            batcher: BatcherConfig::default(),
            workers: 2,
            clock_hz: 300e6,
            packed_threads: 0,
            packed_unroll: PopcountKernel::Auto,
            packed_tile_rows: 0,
            packed_tile_cols: 0,
            packed_ksplit: 0,
            packed_rsr: false,
            planner: None,
            plan_persist: None,
        }
    }

    /// The pooled kernel's tile-granularity knobs as one policy.
    pub fn tile_policy(&self) -> TilePolicy {
        TilePolicy {
            tile_rows: self.packed_tile_rows,
            tile_cols: self.packed_tile_cols,
            k_chunks: self.packed_ksplit,
        }
    }

    /// Resolve `packed_threads = 0` (auto) to a concrete thread count:
    /// the machine's cores divided across the request workers, min 1.
    pub fn resolved_packed_threads(&self) -> usize {
        if self.packed_threads != 0 {
            return self.packed_threads;
        }
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        (cores / self.workers.max(1)).max(1)
    }

    /// Kernel slots a packed matmul under this config can occupy: the
    /// pool's workers plus the caller's inline slot, or 1 when no pool
    /// will be built. The single source for sizing the planner's
    /// candidate plans — must agree with the pool [`InferenceServer`]
    /// constructs and the slot count the scheduler derives from it.
    pub fn kernel_slots(&self) -> usize {
        match self.backend {
            Backend::Packed => {
                let threads = self.resolved_packed_threads();
                if threads > 1 {
                    threads + 1
                } else {
                    1
                }
            }
            _ => 1,
        }
    }
}

/// A running inference server for one model.
pub struct InferenceServer {
    batcher: Arc<Batcher<(Request, mpsc::Sender<Response>)>>,
    workers: Vec<std::thread::JoinHandle<(ExecutionReport, Metrics)>>,
    /// Plan file the planner's tuned entries are persisted to on
    /// graceful shutdown (`ServerConfig::plan_persist` + an active
    /// planner).
    persist: Option<(std::path::PathBuf, Arc<Planner>)>,
}

impl InferenceServer {
    /// Start worker threads serving `model`. Batch-fusable models —
    /// rank-1 vectors and attention-free rank-3 image models — stack
    /// whole batches into one forward pass (convs via batched im2col);
    /// rank-2 token matrices and anything containing attention run per
    /// item so the data-dependent requantization never mixes requests.
    /// Either way responses are bit-identical whether a request is
    /// served alone or inside a batch. On the packed backend, start-up
    /// warm-packs every weight's planes and conv transposes (and
    /// pre-resolves the shape census when a planner is configured), so
    /// the first request pays no pack latency.
    pub fn start(model: Arc<Model>, cfg: ServerConfig) -> Result<InferenceServer> {
        anyhow::ensure!(cfg.workers >= 1, "need at least one worker");
        anyhow::ensure!(
            (1..=3).contains(&model.input_shape.len())
                && model.input_shape.iter().all(|&d| d >= 1),
            "servable models take non-degenerate rank 1-3 inputs (got {:?})",
            model.input_shape
        );
        let batcher = Arc::new(Batcher::new(cfg.batcher));
        // one pool for the whole server: every worker's scheduler rides
        // the same packed_threads kernel lanes (DESIGN.md
        // §Packed-Threading)
        let packed_pool = match cfg.backend {
            Backend::Packed => {
                let threads = cfg.resolved_packed_threads();
                if threads > 1 {
                    Some(Arc::new(PackedPool::new(threads)?))
                } else {
                    None
                }
            }
            _ => None,
        };
        // Warm start (DESIGN.md §Serving): before any request can be
        // submitted, pre-pack every weight's bit planes and conv
        // transpose, and pre-resolve (Online: pre-calibrate, on
        // synthetic operands) the plans of the model's shape census —
        // the first request pays neither pack latency nor a plan miss.
        if matches!(cfg.backend, Backend::Packed) {
            model.warm_packed()?;
            if let Some(pl) = cfg.planner.as_ref().filter(|p| p.is_on()) {
                // powers-of-two batch sizes plus max_batch cover every
                // plan bucket any assembled batch can produce: fused
                // row counts scale linearly with batch and
                // `bucket(2x) = bucket(x) + 1`, so a batch size between
                // 2^i and 2^(i+1) always lands in one of their buckets.
                // Classes already cached skip their (re-)calibration.
                let max_batch = cfg.batcher.max_batch.max(1);
                let mut shapes = Vec::new();
                let mut batch = 1usize;
                while batch < max_batch {
                    shapes.extend(model.matmul_shapes(batch));
                    batch *= 2;
                }
                shapes.extend(model.matmul_shapes(max_batch));
                shapes.sort_unstable();
                shapes.dedup();
                for (m, k, n, bits) in shapes {
                    if pl.mode() == PlannerMode::Online {
                        calibrate_shape(
                            pl,
                            packed_pool.as_ref(),
                            m,
                            k,
                            n,
                            bits,
                            PlaneKind::Sbmwc,
                            0x5eed_ca1b,
                        )?;
                    } else {
                        pl.resolve(PlanKey::for_matmul(m, k, n, bits, bits, PlaneKind::Sbmwc));
                    }
                }
            }
        }
        let mut workers = Vec::new();
        for w in 0..cfg.workers {
            let batcher = batcher.clone();
            let model = model.clone();
            let cfg = cfg.clone();
            let pool = packed_pool.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("bitsmm-worker-{w}"))
                    .spawn(move || worker_loop(&model, &cfg, &batcher, pool))?,
            );
        }
        let persist = match (&cfg.plan_persist, cfg.planner.as_ref().filter(|p| p.is_on())) {
            (Some(path), Some(pl)) => Some((path.clone(), pl.clone())),
            _ => None,
        };
        Ok(InferenceServer { batcher, workers, persist })
    }

    /// Submit a request; the response arrives on the returned channel.
    pub fn submit(&self, req: Request) -> mpsc::Receiver<Response> {
        let (tx, rx) = mpsc::channel();
        self.batcher.push((req, tx));
        rx
    }

    /// Queue depth (for callers implementing backpressure).
    pub fn queue_depth(&self) -> usize {
        self.batcher.depth()
    }

    /// Stop accepting requests, drain, and collect merged metrics.
    pub fn shutdown(self) -> (ExecutionReport, Metrics) {
        self.batcher.close();
        let mut report = ExecutionReport::default();
        let mut metrics = Metrics::default();
        for w in self.workers {
            let (r, m) = w.join().expect("worker panicked");
            report.merge(&r);
            metrics.latency.merge(&m.latency);
            metrics.requests += m.requests;
            metrics.errors += m.errors;
            metrics.batches += m.batches;
            metrics.macs += m.macs;
            metrics.hw_cycles += m.hw_cycles;
            metrics.wall = metrics.wall.max(m.wall);
        }
        // single-sourced from the merged report so the two aggregation
        // paths cannot desynchronize
        metrics.steal = report.steal;
        metrics.plan = report.plan;
        // graceful shutdown persists what this run learned: tuned
        // plans merge into the configured plan file (atomic rename),
        // so the next `--planner static` start serves them as exact
        // hits. Persistence failing (foreign file, unwritable path)
        // is logged, never fatal — metrics still come back.
        if let Some((path, planner)) = &self.persist {
            match planner.persist_file(path) {
                Ok(n) => eprintln!("persisted {n} tuned plans to {}", path.display()),
                Err(e) => eprintln!(
                    "plan persistence to {} skipped: {e:#}",
                    path.display()
                ),
            }
        }
        (report, metrics)
    }
}

fn worker_loop(
    model: &Model,
    cfg: &ServerConfig,
    batcher: &Batcher<(Request, mpsc::Sender<Response>)>,
    packed_pool: Option<Arc<PackedPool>>,
) -> (ExecutionReport, Metrics) {
    let mut sched = Scheduler::new(cfg.sa, cfg.backend.clone());
    sched.set_popcount_kernel(cfg.packed_unroll);
    sched.set_tile_policy(cfg.tile_policy());
    if cfg.packed_rsr {
        sched.set_kernel_family(KernelFamily::Rsr { seg_words: 0 });
    }
    if let Some(pool) = packed_pool {
        sched.set_packed_pool(pool);
    }
    if let Some(planner) = cfg.planner.clone().filter(|p| p.is_on()) {
        sched.set_planner(planner);
    }
    let mut metrics = Metrics::default();
    let t0 = Instant::now();
    // Per-kind batch assembly: batch-fusable models — rank-1 vector
    // rows (stacked into one [rows, d] matmul) and attention-free
    // rank-3 image models (stacked into one (B,C,H,W) forward whose
    // convs run batched im2col) — fuse whole batches into one forward
    // pass. Everything else (attention's data-dependent ctx
    // requantization must never mix requests) runs per item. Either
    // way responses are bit-identical across batch compositions:
    // fused layers treat each request's rows independently
    // (DESIGN.md §Serving).
    let fuse = model.fuses_batches();
    while let Some(batch) = batcher.next_batch() {
        let cycles_before = sched.report.hw_cycles;
        let macs_before = sched.report.macs;
        let served_before = metrics.requests;
        // the scheduler itself is the executor (not an `as_exec`
        // closure) so the packed backend sees layer-cached weight
        // planes and packs each weight once per (layer, precision)
        if fuse {
            serve_fused(model, &mut sched, batch, &mut metrics);
        } else {
            serve_per_item(model, &mut sched, batch, &mut metrics);
        }
        metrics.macs += sched.report.macs - macs_before;
        metrics.hw_cycles += sched.report.hw_cycles - cycles_before;
        // a batch counts as executed if it produced responses or did
        // matmul work (e.g. a forward that failed mid-model) — only
        // all-invalid batches that never reached the scheduler are
        // excluded, so MACs are never attributed to zero batches
        if metrics.requests > served_before || sched.report.macs > macs_before {
            metrics.batches += 1;
        }
    }
    metrics.wall = t0.elapsed();
    (sched.report, metrics)
}

/// Shape + range validation of one request against the model contract.
/// Rejections become per-request error responses, never batch drops.
fn validate_input(model: &Model, req: &Request) -> Result<()> {
    anyhow::ensure!(
        req.input.shape == model.input_shape,
        "request {}: input shape {:?} does not match model input shape {:?}",
        req.id,
        req.input.shape,
        model.input_shape
    );
    anyhow::ensure!(
        req.input.data.len() == req.input.numel(),
        "request {}: {} values for shape {:?}",
        req.id,
        req.input.data.len(),
        req.input.shape
    );
    let lo = crate::bits::twos::min_value(model.input_bits);
    let hi = crate::bits::twos::max_value(model.input_bits);
    anyhow::ensure!(
        req.input.data.iter().all(|v| (lo..=hi).contains(v)),
        "request {}: values exceed the model's {}-bit input range",
        req.id,
        model.input_bits
    );
    Ok(())
}

/// Deliver one response and account it.
fn respond(
    metrics: &mut Metrics,
    id: u64,
    submitted: Instant,
    tx: &mpsc::Sender<Response>,
    output: std::result::Result<Vec<f64>, String>,
) {
    let latency = submitted.elapsed();
    match &output {
        Ok(_) => {
            metrics.latency.record(latency);
            metrics.requests += 1;
        }
        Err(_) => metrics.errors += 1,
    }
    let _ = tx.send(Response {
        id,
        output,
        latency,
    });
}

/// Fused assembly: stack every valid request into one forward pass —
/// `[rows, d]` for rank-1 vector models, `(rows, C, H, W)` for
/// attention-free image models (whose convs then run batched im2col:
/// one matmul per layer per batch instead of per request). Fusing is
/// batch-invariant because every fused layer treats each request's
/// rows independently (DESIGN.md §Serving).
fn serve_fused(
    model: &Model,
    sched: &mut Scheduler,
    batch: Batch<(Request, mpsc::Sender<Response>)>,
    metrics: &mut Metrics,
) {
    let numel: usize = model.input_shape.iter().product();
    let mut stacked = Vec::with_capacity(batch.items.len() * numel);
    let mut valid: Vec<(&Request, &mpsc::Sender<Response>)> =
        Vec::with_capacity(batch.items.len());
    for (req, tx) in &batch.items {
        match validate_input(model, req) {
            Ok(()) => {
                stacked.extend_from_slice(&req.input.data);
                valid.push((req, tx));
            }
            Err(e) => respond(metrics, req.id, req.submitted, tx, Err(format!("{e:#}"))),
        }
    }
    if valid.is_empty() {
        return;
    }
    let rows = valid.len();
    let mut shape = Vec::with_capacity(1 + model.input_shape.len());
    shape.push(rows);
    shape.extend_from_slice(&model.input_shape);
    let run = QTensor::new(stacked, shape, model.input_scale, model.input_bits)
        .and_then(|x| model.forward(&x, sched));
    match run {
        Ok(y) => {
            let out_dim = y.numel() / rows;
            for (i, (req, tx)) in valid.iter().enumerate() {
                let output = y.data[i * out_dim..(i + 1) * out_dim]
                    .iter()
                    .map(|&q| q as f64 * y.scale)
                    .collect();
                respond(metrics, req.id, req.submitted, tx, Ok(output));
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            for (req, tx) in &valid {
                respond(metrics, req.id, req.submitted, tx, Err(msg.clone()));
            }
        }
    }
}

/// Per-item assembly (token matrices and any model containing
/// attention): each request runs its own forward pass, so attention's
/// data-dependent `ctx_scale` requantization never mixes requests, and
/// one request's failure cannot take its batch-mates down. The batch
/// is consumed so each payload *moves* into its forward pass — no
/// per-request copy.
fn serve_per_item(
    model: &Model,
    sched: &mut Scheduler,
    batch: Batch<(Request, mpsc::Sender<Response>)>,
    metrics: &mut Metrics,
) {
    for (req, tx) in batch.items {
        let (id, submitted) = (req.id, req.submitted);
        let run = match validate_input(model, &req) {
            Ok(()) => run_one(model, sched, req.input),
            Err(e) => Err(e),
        };
        respond(metrics, id, submitted, &tx, run.map_err(|e| format!("{e:#}")));
    }
}

/// Execute a single validated shaped request (consumes the payload).
fn run_one(model: &Model, sched: &mut Scheduler, input: TensorInput) -> Result<Vec<f64>> {
    let x = QTensor::new(input.data, input.shape, model.input_scale, model.input_bits)?;
    let y = model.forward(&x, sched)?;
    Ok(y.data.iter().map(|&q| q as f64 * y.scale).collect())
}

/// Convenience: run a closed set of requests through a fresh server and
/// gather everything (used by examples/benches). Accepts anything that
/// converts into a [`TensorInput`] — plain `Vec<i32>` rows for vector
/// models, shaped payloads for images / token matrices.
pub fn serve_all<I: Into<TensorInput>>(
    model: Arc<Model>,
    cfg: ServerConfig,
    inputs: Vec<I>,
) -> Result<(Vec<Response>, ExecutionReport, Metrics)> {
    let server = InferenceServer::start(model, cfg)?;
    let rxs: Vec<_> = inputs
        .into_iter()
        .enumerate()
        .map(|(i, input)| {
            server.submit(Request {
                id: i as u64,
                input: input.into(),
                submitted: Instant::now(),
            })
        })
        .collect();
    let mut responses = Vec::with_capacity(rxs.len());
    for rx in rxs {
        responses.push(rx.recv()?);
    }
    let (report, metrics) = server.shutdown();
    responses.sort_by_key(|r| r.id);
    Ok((responses, report, metrics))
}

/// Shared-state guard used by tests to assert worker counts; kept
/// small and public for the harness.
pub type SharedReport = Arc<Mutex<ExecutionReport>>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg32;
    use crate::sim::mac_common::MacVariant;

    fn inputs(n: usize, d: usize, bits: u32) -> Vec<Vec<i32>> {
        let mut rng = Pcg32::new(0xf00d);
        let lo = crate::bits::twos::min_value(bits);
        let hi = crate::bits::twos::max_value(bits);
        (0..n)
            .map(|_| (0..d).map(|_| rng.range_i32(lo, hi)).collect())
            .collect()
    }

    #[test]
    fn serves_all_requests_in_order() {
        let model = Arc::new(crate::nn::model::mlp_zoo(5));
        let cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        let (resp, report, metrics) = serve_all(model, cfg, inputs(20, 64, 8)).unwrap();
        assert_eq!(resp.len(), 20);
        for (i, r) in resp.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.output.as_ref().unwrap().len(), 10);
        }
        assert_eq!(metrics.requests, 20);
        assert_eq!(metrics.errors, 0);
        assert!(report.macs > 0 && report.hw_cycles > 0);
        assert!(metrics.mean_batch() >= 1.0);
    }

    #[test]
    fn batching_reduces_matmul_count() {
        let model = Arc::new(crate::nn::model::mlp_zoo(5));
        let mut cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        cfg.workers = 1;
        cfg.batcher = BatcherConfig {
            max_batch: 16,
            linger: std::time::Duration::from_millis(20),
        };
        let (_, report, metrics) = serve_all(model, cfg, inputs(16, 64, 8)).unwrap();
        // ideally one batch of 16 → 3 matmuls; allow some fragmentation
        assert!(report.matmuls <= 3 * 4, "matmuls = {}", report.matmuls);
        assert!(metrics.mean_batch() > 1.0);
    }

    #[test]
    fn deterministic_results_across_backends() {
        let model = Arc::new(crate::nn::model::mlp_zoo(5));
        let ins = inputs(4, 64, 8);
        let cfg_n = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        let mut cfg_s = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Simulate);
        cfg_s.workers = 1;
        let cfg_p = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Packed);
        let (r1, _, _) = serve_all(model.clone(), cfg_n, ins.clone()).unwrap();
        let (r2, _, _) = serve_all(model.clone(), cfg_s, ins.clone()).unwrap();
        let (r3, rep_p, _) = serve_all(model, cfg_p, ins).unwrap();
        for ((a, b), c) in r1.iter().zip(&r2).zip(&r3) {
            assert_eq!(a.output, b.output, "native vs simulate diverged");
            assert_eq!(a.output, c.output, "native vs packed diverged");
        }
        assert!(rep_p.packed_execs > 0, "packed backend actually ran");
    }

    #[test]
    fn packed_thread_and_kernel_config_do_not_change_results() {
        let model = Arc::new(crate::nn::model::mlp_zoo(5));
        let ins = inputs(12, 64, 8);
        let cfg_n = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        let (want, _, _) = serve_all(model.clone(), cfg_n, ins.clone()).unwrap();
        for (threads, kernel) in [
            (1usize, PopcountKernel::Scalar),
            (3, PopcountKernel::Unroll4),
            (4, PopcountKernel::Auto),
        ] {
            let mut cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Packed);
            cfg.packed_threads = threads;
            cfg.packed_unroll = kernel;
            let (got, report, _) = serve_all(model.clone(), cfg, ins.clone()).unwrap();
            for (a, b) in want.iter().zip(&got) {
                assert_eq!(a.output, b.output, "t{threads} {} diverged", kernel.name());
            }
            assert!(report.packed_execs > 0);
        }
    }

    #[test]
    fn packed_tile_knobs_do_not_change_results_and_surface_telemetry() {
        let model = Arc::new(crate::nn::model::mlp_zoo(5));
        let ins = inputs(12, 64, 8);
        let cfg_n = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        let (want, _, _) = serve_all(model.clone(), cfg_n, ins.clone()).unwrap();
        for (rows, cols) in [(0usize, 0usize), (1, 0), (0, 4), (2, 8)] {
            let mut cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Packed);
            cfg.packed_threads = 3;
            cfg.packed_tile_rows = rows;
            cfg.packed_tile_cols = cols;
            let (got, report, metrics) = serve_all(model.clone(), cfg, ins.clone()).unwrap();
            for (a, b) in want.iter().zip(&got) {
                assert_eq!(a.output, b.output, "tiles {rows}x{cols} diverged");
            }
            assert!(report.packed_execs > 0);
            // pooled runs happened, so tiling telemetry is populated
            // and mirrored into the serving metrics
            assert!(report.steal.tiles >= 1, "tiles {rows}x{cols}");
            assert_eq!(metrics.steal, report.steal);
            assert!(
                report.steal.max_worker_tiles >= report.steal.min_worker_tiles,
                "tiles {rows}x{cols}"
            );
        }
    }

    #[test]
    fn shaped_requests_serve_image_and_token_models() {
        for (name, model) in [
            ("cnn", crate::nn::model::cnn_zoo(2)),
            ("attn", crate::nn::model::attention_zoo(3)),
        ] {
            let model = Arc::new(model);
            let ins = shaped_inputs(&model, 4, 0xbeef);
            let cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
            let (resp, report, metrics) = serve_all(model.clone(), cfg, ins.clone()).unwrap();
            assert_eq!(resp.len(), 4, "{name}");
            assert_eq!(metrics.requests, 4, "{name}");
            assert_eq!(metrics.errors, 0, "{name}");
            // the serving-path MACs equal the static census for the
            // same request count (per-item batch accounting)
            assert_eq!(report.macs, model.stats(4).macs, "{name}");
            // responses match a direct forward of the same payload
            let mut direct = Scheduler::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
            for (i, r) in resp.iter().enumerate() {
                let x = QTensor::new(
                    ins[i].data.clone(),
                    ins[i].shape.clone(),
                    model.input_scale,
                    model.input_bits,
                )
                .unwrap();
                let y = model.forward(&x, &mut direct).unwrap();
                let want: Vec<f64> = y.data.iter().map(|&q| q as f64 * y.scale).collect();
                assert_eq!(r.output, Ok(want), "{name} request {i}");
            }
        }
    }

    #[test]
    fn invalid_requests_surface_their_cause() {
        let model = Arc::new(crate::nn::model::mlp_zoo(5));
        let cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        let server = InferenceServer::start(model, cfg).unwrap();
        // wrong shape: a 32-vector against the 64-input model
        let rx = server.submit(Request {
            id: 0,
            input: vec![1i32; 32].into(),
            submitted: Instant::now(),
        });
        let r = rx.recv().unwrap();
        let err = r.output.unwrap_err();
        assert!(err.contains("shape"), "cause must name the shape: {err}");
        // out-of-range values against the 8-bit input contract
        let rx = server.submit(Request {
            id: 1,
            input: vec![300i32; 64].into(),
            submitted: Instant::now(),
        });
        let err = rx.recv().unwrap().output.unwrap_err();
        assert!(err.contains("8-bit"), "cause must name the range: {err}");
        let (_, metrics) = server.shutdown();
        assert_eq!((metrics.requests, metrics.errors), (0, 2));
    }

    #[test]
    fn failed_forward_surfaces_error_and_counts_executed_batch() {
        // passes validation but fails mid-forward: layers 1-2 run,
        // layer 3's weight dims mismatch the incoming activation
        let mut model = crate::nn::model::mlp_zoo(5);
        if let crate::nn::Layer::Linear(l) = &mut model.layers[2] {
            l.w = QTensor::zeros(vec![7, 3], 1.0, 4);
        }
        let model = Arc::new(model);
        let cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        let (resp, _, metrics) = serve_all(model, cfg, inputs(3, 64, 8)).unwrap();
        for r in &resp {
            let err = r.output.as_ref().unwrap_err();
            assert!(err.contains("linear dims"), "cause must reach the caller: {err}");
        }
        assert_eq!((metrics.requests, metrics.errors), (0, 3));
        assert!(metrics.macs > 0, "two layers executed before the failure");
        assert!(metrics.batches >= 1, "a batch that did matmul work is an executed batch");
    }

    #[test]
    fn tensor_shaped_models_reject_vector_servers_no_more() {
        // rank-2 and rank-3 input shapes start; rank-0 is rejected
        for model in [crate::nn::model::cnn_zoo(1), crate::nn::model::attention_zoo(1)] {
            let cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
            let server = InferenceServer::start(Arc::new(model), cfg).unwrap();
            server.shutdown();
        }
        let mut degenerate = crate::nn::model::mlp_zoo(1);
        degenerate.input_shape = vec![];
        let cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        assert!(InferenceServer::start(Arc::new(degenerate), cfg).is_err());
    }

    #[test]
    fn fused_image_serving_batches_conv_matmuls() {
        // 6 CNN requests through one single-worker batch: the fused
        // path runs ~3 matmuls (conv1, conv2, head) for the whole
        // batch instead of 3 per request, with identical outputs
        let model = Arc::new(crate::nn::model::cnn_zoo(2));
        let ins = shaped_inputs(&model, 6, 0x1217);
        let mut solo_cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        solo_cfg.workers = 1;
        solo_cfg.batcher = BatcherConfig {
            max_batch: 1,
            linger: std::time::Duration::from_millis(1),
        };
        let (solo, solo_rep, _) = serve_all(model.clone(), solo_cfg, ins.clone()).unwrap();
        let mut fused_cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        fused_cfg.workers = 1;
        fused_cfg.batcher = BatcherConfig {
            max_batch: 6,
            linger: std::time::Duration::from_millis(30),
        };
        let (fused, fused_rep, metrics) = serve_all(model.clone(), fused_cfg, ins).unwrap();
        assert_eq!(metrics.errors, 0);
        for (a, b) in solo.iter().zip(&fused) {
            assert_eq!(a.output, b.output, "fused image serving diverged at id {}", a.id);
        }
        // same MACs (the census), far fewer matmul submissions
        assert_eq!(fused_rep.macs, solo_rep.macs);
        assert_eq!(fused_rep.macs, model.stats(6).macs);
        assert!(
            fused_rep.matmuls <= solo_rep.matmuls / 2,
            "fused {} vs solo {} matmuls",
            fused_rep.matmuls,
            solo_rep.matmuls
        );
    }

    #[test]
    fn planner_modes_do_not_change_served_results() {
        use crate::plan::{Planner, PlannerMode};
        let model = Arc::new(crate::nn::model::mlp_zoo(5));
        let ins = inputs(16, 64, 8);
        let cfg_n = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        let (want, _, _) = serve_all(model.clone(), cfg_n, ins.clone()).unwrap();
        for mode in [PlannerMode::Static, PlannerMode::Online] {
            let mut cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Packed);
            cfg.packed_threads = 2;
            let planner = Arc::new(Planner::new(mode, 3));
            cfg.planner = Some(planner.clone());
            let (got, report, metrics) = serve_all(model.clone(), cfg, ins.clone()).unwrap();
            for (a, b) in want.iter().zip(&got) {
                assert_eq!(a.output, b.output, "{mode:?} diverged at id {}", a.id);
            }
            // warm start pre-resolved the census: the request path
            // planned every matmul, overwhelmingly from cache hits
            assert!(report.plan.lookups() > 0, "{mode:?}: no lookups recorded");
            assert!(report.plan.hits > 0, "{mode:?}: warm start should yield hits");
            assert_eq!(metrics.plan, report.plan, "metrics mirror the report");
            assert!(planner.len() > 0, "{mode:?}: plans cached");
            if mode == PlannerMode::Online {
                assert!(
                    planner.stats().calibrations > 0,
                    "online warm start calibrates the census"
                );
            }
        }
    }

    #[test]
    fn packed_rsr_and_ksplit_knobs_do_not_change_results() {
        let model = Arc::new(crate::nn::model::mlp_zoo(5));
        let ins = inputs(12, 64, 8);
        let cfg_n = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        let (want, _, _) = serve_all(model.clone(), cfg_n, ins.clone()).unwrap();
        for (rsr, ksplit) in [(true, 0usize), (false, 2), (true, 2)] {
            let mut cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Packed);
            cfg.packed_threads = 3;
            cfg.packed_rsr = rsr;
            cfg.packed_ksplit = ksplit;
            assert_eq!(cfg.tile_policy().k_chunks, ksplit);
            let (got, report, _) = serve_all(model.clone(), cfg, ins.clone()).unwrap();
            for (a, b) in want.iter().zip(&got) {
                assert_eq!(a.output, b.output, "rsr={rsr} ksplit={ksplit} diverged");
            }
            assert!(report.packed_execs > 0);
        }
    }

    #[test]
    fn graceful_shutdown_persists_tuned_plans() {
        use crate::plan::{Planner, PlannerMode};
        let dir = std::env::temp_dir().join("bitsmm_server_persist");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("plans.json");
        let _ = std::fs::remove_file(&path);

        let model = Arc::new(crate::nn::model::mlp_zoo(5));
        let mut cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Packed);
        cfg.packed_threads = 2;
        cfg.planner = Some(Arc::new(Planner::new(PlannerMode::Online, 3)));
        cfg.plan_persist = Some(path.clone());
        let (_, _, metrics) = serve_all(model, cfg, inputs(4, 64, 8)).unwrap();
        assert_eq!(metrics.errors, 0);

        // shutdown wrote a same-host file holding the calibrated census
        let q = Planner::new(PlannerMode::Static, 3);
        let n = q.load_file(&path).unwrap();
        assert!(n > 0, "warm-start calibrations were persisted");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn packed_threads_auto_resolution() {
        let mut cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Packed);
        cfg.workers = 1_000_000; // more workers than cores: still >= 1
        assert_eq!(cfg.resolved_packed_threads(), 1);
        assert_eq!(cfg.kernel_slots(), 1, "no pool, no inline slot bonus");
        cfg.packed_threads = 7; // explicit setting wins over auto
        assert_eq!(cfg.resolved_packed_threads(), 7);
        // pool workers + the caller's inline slot — the count the
        // planner sizes candidate plans for
        assert_eq!(cfg.kernel_slots(), 8);
        let non_packed = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        assert_eq!(non_packed.kernel_slots(), 1);
    }
}
