//! The inference server: threaded request loop over the batcher,
//! scheduler and model — the end-to-end serving path of the `e2e`
//! example (and the paper's future-work integration, §V).

use crate::bits::packed::{KernelFamily, PackedPool, PopcountKernel, TilePolicy};
use crate::bits::plane::PlaneKind;
use crate::coordinator::batcher::{Batcher, BatcherConfig, PushRefused};
use crate::coordinator::faults::{FaultAction, FaultState, ScrubStats};
use crate::coordinator::metrics::{Metrics, MetricsHub};
use crate::coordinator::scheduler::{Backend, ExecutionReport, Scheduler};
use crate::nn::model::Model;
use crate::nn::tensor::QTensor;
use crate::obs::snapshot::render_snapshot;
use crate::obs::trace::{SpanKind, TraceRing};
use crate::plan::{calibrate_shape, PlanKey, Planner, PlannerMode};
use crate::sim::array::SaConfig;
use crate::Result;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Span slots in the request-trace ring `start` builds when
/// `--trace-requests` asks for a dump: ~14 spans per request → room for
/// the last ~4½k requests, ~3 MiB resident, and overflow is counted —
/// never silent (DESIGN.md §Observability).
const TRACE_CAPACITY: usize = 65_536;

/// A shaped request payload: quantized values on the model's input
/// grid plus their shape, validated server-side against
/// [`Model::input_shape`] — rank 1 for vector models (MLP rows), rank
/// 2 for token matrices (attention), rank 3 for images (CNN).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorInput {
    pub data: Vec<i32>,
    pub shape: Vec<usize>,
}

impl TensorInput {
    pub fn new(data: Vec<i32>, shape: Vec<usize>) -> TensorInput {
        TensorInput { data, shape }
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Plain vectors keep the historical row-request ergonomics.
impl From<Vec<i32>> for TensorInput {
    fn from(data: Vec<i32>) -> TensorInput {
        let shape = vec![data.len()];
        TensorInput { data, shape }
    }
}

/// Random shaped requests on `model`'s input grid (any rank) — the one
/// generator behind the CLI entries, the e2e example, and the
/// integration tests, so the request contract cannot drift per caller.
pub fn shaped_inputs(model: &Model, n: usize, seed: u64) -> Vec<TensorInput> {
    let numel: usize = model.input_shape.iter().product();
    let lo = crate::bits::twos::min_value(model.input_bits);
    let hi = crate::bits::twos::max_value(model.input_bits);
    let mut rng = crate::prng::Pcg32::new(seed);
    (0..n)
        .map(|_| {
            TensorInput::new(
                (0..numel).map(|_| rng.range_i32(lo, hi)).collect(),
                model.input_shape.clone(),
            )
        })
        .collect()
}

/// SLA class of a request. Under sustained overload an optional
/// [`DegradePolicy`] serves `Low` requests at narrower operand
/// precision (bit-exact by construction — DESIGN.md §Resilience).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Priority {
    #[default]
    Normal,
    Low,
}

/// One inference request: a quantized, shaped input for the model.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub input: TensorInput,
    pub submitted: Instant,
    /// Complete-by deadline. Expired requests are answered
    /// [`ServeError::DeadlineExceeded`] at dequeue, and re-checked
    /// between per-item forwards so one slow batch-mate cannot spend
    /// the budget of the rest. `None` = no deadline.
    pub deadline: Option<Instant>,
    pub priority: Priority,
    /// Trace ID minted at `submit` when request tracing is on
    /// (0 = untraced — the default for every request at construction).
    pub trace: u64,
}

impl Request {
    pub fn new(id: u64, input: impl Into<TensorInput>) -> Request {
        Request {
            id,
            input: input.into(),
            submitted: Instant::now(),
            deadline: None,
            priority: Priority::Normal,
            trace: 0,
        }
    }

    pub fn with_deadline(mut self, deadline: Instant) -> Request {
        self.deadline = Some(deadline);
        self
    }

    pub fn low_priority(mut self) -> Request {
        self.priority = Priority::Low;
        self
    }
}

/// Why a request did not produce an output. Every variant is terminal:
/// a submitter always receives exactly one [`Response`] carrying either
/// the output or one of these causes — never a bare channel disconnect.
#[derive(Debug, Clone, PartialEq)]
pub enum ServeError {
    /// Refused at admission: the bounded queue was at `max_queue`.
    Rejected { depth: usize },
    /// Queued longer than the `shed_after` budget and shed unexecuted.
    Overloaded { waited: Duration },
    /// The request's deadline passed before its forward pass ran.
    DeadlineExceeded,
    /// The worker executing this request's batch panicked; the
    /// supervisor answered on its behalf and the worker survived.
    WorkerFault(String),
    /// The request touched a quarantined weight slot: its packed
    /// planes were corrupt *and* its golden source failed
    /// verification, so the integrity path evicted the slot and
    /// refuses to serve from unverifiable state (DESIGN.md
    /// §Integrity). Recovery requires reloading the weights.
    Quarantined { slot: u32 },
    /// Submitted after the server closed to new requests.
    Closed,
    /// Validation or execution failure (the pre-resilience error path).
    Failed(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Rejected { depth } => {
                write!(f, "rejected at admission: queue full (depth {depth})")
            }
            ServeError::Overloaded { waited } => {
                write!(f, "shed under overload after {}ms in queue", waited.as_millis())
            }
            ServeError::DeadlineExceeded => write!(f, "deadline exceeded before execution"),
            ServeError::WorkerFault(msg) => write!(f, "worker fault: {msg}"),
            ServeError::Quarantined { slot } => write!(
                f,
                "weight slot {slot} quarantined: packed planes corrupt and golden source unverifiable"
            ),
            ServeError::Closed => write!(f, "server is closed to new requests"),
            ServeError::Failed(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// One completed inference.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// Output activations (dequantized logits), or the typed serving
    /// error — admission refusals, sheds, deadline misses, worker
    /// faults, and validation/execution failures all reach the
    /// submitter with their cause instead of a silently dropped
    /// channel.
    pub output: std::result::Result<Vec<f64>, ServeError>,
    pub latency: std::time::Duration,
}

/// Overload-degradation policy: when the queue depth still exceeds
/// `high_water` after a batch is taken, [`Priority::Low`] requests in
/// that batch are served by a precision-degraded clone of the model
/// (operand widths clamped toward `floor_bits`, never below what the
/// weights/activations need exactly — so outputs stay bit-identical
/// while narrower planes cut packed work and modelled hw cycles).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DegradePolicy {
    pub high_water: usize,
    pub floor_bits: u32,
}

/// Server tuning.
#[derive(Clone)]
pub struct ServerConfig {
    pub sa: SaConfig,
    pub backend: Backend,
    pub batcher: BatcherConfig,
    pub workers: usize,
    /// Hardware clock for GOPS accounting (300 MHz = the paper's FPGA
    /// operating point).
    pub clock_hz: f64,
    /// Packed-kernel worker threads, shared by **all** request workers
    /// through one [`PackedPool`] so kernel parallelism composes with
    /// (not multiplies against) request parallelism. `0` = auto:
    /// available cores / `workers`, min 1. `1` = single-thread kernel
    /// (no pool). Ignored by non-packed backends.
    pub packed_threads: usize,
    /// Popcount reducer for the packed kernel (`Auto` = AVX2/NEON when
    /// the CPU has one, else 8-word unrolled chunks).
    pub packed_unroll: PopcountKernel,
    /// Output rows per pooled-kernel tile job (`0` = auto: adapt to the
    /// batch shape and worker count — see DESIGN.md §Packed-Threading).
    pub packed_tile_rows: usize,
    /// Output columns per pooled-kernel tile job (`0` = auto).
    pub packed_tile_cols: usize,
    /// Contracted-dimension chunks per pooled tile job
    /// (`server.packed_ksplit`, `--packed-ksplit`; `0` = auto: split
    /// only when the output grid alone cannot feed the pool, `1` =
    /// never split). Deterministic and bit-identical — see DESIGN.md
    /// §Sub-popcount-Kernels.
    pub packed_ksplit: usize,
    /// Route static-path packed matmuls through the RSR segment-reuse
    /// kernel family (`server.packed_rsr`, `--packed-rsr`) instead of
    /// direct popcount. With a planner attached the family is chosen
    /// per shape class and this knob is ignored.
    pub packed_rsr: bool,
    /// Shape-keyed execution planner shared by every worker's
    /// scheduler (`server.planner = off|static|online`, `--planner`).
    /// `None` (or `Off`): the static knobs above run every matmul —
    /// the pre-planner behavior. See DESIGN.md §Planner.
    pub planner: Option<Arc<Planner>>,
    /// Persist the planner's tuned plans to this file on graceful
    /// shutdown (atomic rename, fingerprint-stamped, merged into any
    /// same-host file already there). `None` = never persist.
    pub plan_persist: Option<std::path::PathBuf>,
    /// Serve low-priority requests at degraded precision under
    /// sustained overload. `None` = never degrade.
    pub degrade: Option<DegradePolicy>,
    /// Verify every packed matmul output against an exact row-checksum
    /// (algorithm-based fault tolerance); on mismatch the result is
    /// recomputed natively, masking SEU-style corruption before it can
    /// reach a response.
    pub abft: bool,
    /// Background scrub period in milliseconds (`server.scrub_ms`,
    /// `--scrub-ms`; `0` = scrubbing off). Every period a dedicated
    /// thread sweeps the model's resident packed state — weight-plane
    /// caches and conv kernel transposes — verifying per-plane
    /// word-fold signatures and repairing corruption by re-packing
    /// from the golden-verified weights (DESIGN.md §Integrity).
    pub scrub_ms: u64,
    /// Deterministic fault schedule shared by all workers (chaos
    /// testing; `None` in production).
    pub faults: Option<Arc<FaultState>>,
    /// Append one JSONL snapshot of the full metrics tree to this file
    /// every `metrics_every_ms` — plus one at start and a
    /// `"final":true` one carrying the fully merged totals at graceful
    /// shutdown (`server.metrics_file`, `--metrics-file`; see DESIGN.md
    /// §Observability for the schema). `None` = snapshotting off.
    pub metrics_file: Option<PathBuf>,
    /// Snapshot period in milliseconds (`server.metrics_every_ms`,
    /// `--metrics-every-ms`; ignored without `metrics_file`).
    pub metrics_every_ms: u64,
    /// Dump the request-trace span ring as JSONL to this file at
    /// graceful shutdown (`server.trace_requests`, `--trace-requests`).
    /// Setting it turns tracing on; `None` with no explicit `trace`
    /// ring means tracing stays off and costs one branch per hook.
    pub trace_file: Option<PathBuf>,
    /// Request-trace ring shared by `submit`, the workers, and their
    /// schedulers. Tests inject one to inspect spans in-process;
    /// `start` builds one of [`TRACE_CAPACITY`] slots when only
    /// `trace_file` is set.
    pub trace: Option<Arc<TraceRing>>,
}

impl ServerConfig {
    pub fn new(sa: SaConfig, backend: Backend) -> Self {
        ServerConfig {
            sa,
            backend,
            batcher: BatcherConfig::default(),
            workers: 2,
            clock_hz: 300e6,
            packed_threads: 0,
            packed_unroll: PopcountKernel::Auto,
            packed_tile_rows: 0,
            packed_tile_cols: 0,
            packed_ksplit: 0,
            packed_rsr: false,
            planner: None,
            plan_persist: None,
            degrade: None,
            abft: false,
            scrub_ms: 0,
            faults: None,
            metrics_file: None,
            metrics_every_ms: 1000,
            trace_file: None,
            trace: None,
        }
    }

    /// The pooled kernel's tile-granularity knobs as one policy.
    pub fn tile_policy(&self) -> TilePolicy {
        TilePolicy {
            tile_rows: self.packed_tile_rows,
            tile_cols: self.packed_tile_cols,
            k_chunks: self.packed_ksplit,
        }
    }

    /// Resolve `packed_threads = 0` (auto) to a concrete thread count:
    /// the machine's cores divided across the request workers, min 1.
    pub fn resolved_packed_threads(&self) -> usize {
        if self.packed_threads != 0 {
            return self.packed_threads;
        }
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        (cores / self.workers.max(1)).max(1)
    }

    /// Kernel slots a packed matmul under this config can occupy: the
    /// pool's workers plus the caller's inline slot, or 1 when no pool
    /// will be built. The single source for sizing the planner's
    /// candidate plans — must agree with the pool [`InferenceServer`]
    /// constructs and the slot count the scheduler derives from it.
    pub fn kernel_slots(&self) -> usize {
        match self.backend {
            Backend::Packed => {
                let threads = self.resolved_packed_threads();
                if threads > 1 {
                    threads + 1
                } else {
                    1
                }
            }
            _ => 1,
        }
    }
}

/// A queued request paired with its response channel.
type Queued = (Request, mpsc::Sender<Response>);

/// A running inference server for one model.
pub struct InferenceServer {
    batcher: Arc<Batcher<Queued>>,
    workers: Vec<std::thread::JoinHandle<(ExecutionReport, Metrics)>>,
    /// Plan file the planner's tuned entries are persisted to on
    /// graceful shutdown (`ServerConfig::plan_persist` + an active
    /// planner).
    persist: Option<(std::path::PathBuf, Arc<Planner>)>,
    /// Submissions refused at admission (answered `Rejected`/`Closed`
    /// on their own channel, folded into `Metrics.rejected`). Shared
    /// with the snapshotter so mid-run snapshots count refusals too.
    rejected: Arc<AtomicU64>,
    /// Background integrity scrubber (`scrub_ms > 0`): its stop flag
    /// and join handle, returning the sweep counters folded into
    /// `Metrics.scrub` at shutdown.
    scrubber: Option<(Arc<AtomicBool>, std::thread::JoinHandle<ScrubStats>)>,
    /// Periodic metrics snapshotter (`metrics_file` set): stop flag and
    /// join handle returning how many snapshots it appended — the
    /// sequence number the shutdown-time `"final":true` snapshot takes.
    snapshotter: Option<(Arc<AtomicBool>, std::thread::JoinHandle<u64>)>,
    /// Snapshot sink, kept for the final shutdown snapshot.
    metrics_file: Option<PathBuf>,
    /// Request-trace ring (tracing on) and its optional shutdown dump.
    trace: Option<Arc<TraceRing>>,
    trace_file: Option<PathBuf>,
    /// Next trace ID minus one — IDs start at 1 so 0 can mean untraced.
    trace_seq: AtomicU64,
}

impl InferenceServer {
    /// Start worker threads serving `model`. Batch-fusable models —
    /// rank-1 vectors and attention-free rank-3 image models — stack
    /// whole batches into one forward pass (convs via batched im2col);
    /// rank-2 token matrices and anything containing attention run per
    /// item so the data-dependent requantization never mixes requests.
    /// Either way responses are bit-identical whether a request is
    /// served alone or inside a batch. On the packed backend, start-up
    /// warm-packs every weight's planes and conv transposes (and
    /// pre-resolves the shape census when a planner is configured), so
    /// the first request pays no pack latency.
    pub fn start(model: Arc<Model>, mut cfg: ServerConfig) -> Result<InferenceServer> {
        anyhow::ensure!(cfg.workers >= 1, "need at least one worker");
        // request tracing: an injected ring (tests) or one built here
        // when a shutdown dump was requested; absent both, every trace
        // hook in the serving path is a single branch on a None
        if cfg.trace.is_none() && cfg.trace_file.is_some() {
            cfg.trace = Some(Arc::new(TraceRing::new(TRACE_CAPACITY)));
        }
        anyhow::ensure!(
            (1..=3).contains(&model.input_shape.len())
                && model.input_shape.iter().all(|&d| d >= 1),
            "servable models take non-degenerate rank 1-3 inputs (got {:?})",
            model.input_shape
        );
        let batcher = Arc::new(Batcher::new(cfg.batcher));
        // one pool for the whole server: every worker's scheduler rides
        // the same packed_threads kernel lanes (DESIGN.md
        // §Packed-Threading)
        let packed_pool = match cfg.backend {
            Backend::Packed => {
                let threads = cfg.resolved_packed_threads();
                if threads > 1 {
                    Some(Arc::new(PackedPool::new(threads)?))
                } else {
                    None
                }
            }
            _ => None,
        };
        // Warm start (DESIGN.md §Serving): before any request can be
        // submitted, pre-pack every weight's bit planes and conv
        // transpose, and pre-resolve (Online: pre-calibrate, on
        // synthetic operands) the plans of the model's shape census —
        // the first request pays neither pack latency nor a plan miss.
        if matches!(cfg.backend, Backend::Packed) {
            model.warm_packed()?;
            if let Some(pl) = cfg.planner.as_ref().filter(|p| p.is_on()) {
                // powers-of-two batch sizes plus max_batch cover every
                // plan bucket any assembled batch can produce: fused
                // row counts scale linearly with batch and
                // `bucket(2x) = bucket(x) + 1`, so a batch size between
                // 2^i and 2^(i+1) always lands in one of their buckets.
                // Classes already cached skip their (re-)calibration.
                let max_batch = cfg.batcher.max_batch.max(1);
                let mut shapes = Vec::new();
                let mut batch = 1usize;
                while batch < max_batch {
                    shapes.extend(model.matmul_shapes(batch));
                    batch *= 2;
                }
                shapes.extend(model.matmul_shapes(max_batch));
                shapes.sort_unstable();
                shapes.dedup();
                for (m, k, n, bits) in shapes {
                    if pl.mode() == PlannerMode::Online {
                        calibrate_shape(
                            pl,
                            packed_pool.as_ref(),
                            m,
                            k,
                            n,
                            bits,
                            PlaneKind::Sbmwc,
                            0x5eed_ca1b,
                        )?;
                    } else {
                        pl.resolve(PlanKey::for_matmul(m, k, n, bits, bits, PlaneKind::Sbmwc));
                    }
                }
            }
        }
        // the degraded clone shares the base model's PackedCaches, so
        // its warm-pack slices the already-packed donors instead of
        // re-packing; built after the base warm so donors exist
        let degraded = match &cfg.degrade {
            Some(d) => {
                let deg = Arc::new(model.degraded(d.floor_bits));
                if matches!(cfg.backend, Backend::Packed) {
                    deg.warm_packed()?;
                }
                Some(deg)
            }
            None => None,
        };
        // live metrics mailbox behind the snapshotter: built only when
        // snapshots were asked for, so the publish in the worker loop
        // is one branch otherwise
        let hub = cfg
            .metrics_file
            .as_ref()
            .map(|_| Arc::new(MetricsHub::new(cfg.workers)));
        let mut workers = Vec::new();
        for w in 0..cfg.workers {
            let batcher = batcher.clone();
            let model = model.clone();
            let degraded = degraded.clone();
            let cfg = cfg.clone();
            let pool = packed_pool.clone();
            let hub = hub.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("bitsmm-worker-{w}"))
                    .spawn(move || {
                        worker_loop(&model, degraded.as_deref(), &cfg, &batcher, pool, w, hub)
                    })?,
            );
        }
        let persist = match (&cfg.plan_persist, cfg.planner.as_ref().filter(|p| p.is_on())) {
            (Some(path), Some(pl)) => Some((path.clone(), pl.clone())),
            _ => None,
        };
        // Background scrubber (DESIGN.md §Integrity): every period,
        // sweep the model's resident packed state — signature-verify
        // every plane and repair corruption by re-packing from the
        // golden-verified weights. Scrubbing the base model covers the
        // degraded clone too: the clone shares the base's packed
        // caches by Arc, so there is exactly one resident state.
        let scrubber = if cfg.scrub_ms > 0 {
            let stop = Arc::new(AtomicBool::new(false));
            let flag = stop.clone();
            let scrub_model = model.clone();
            let period = Duration::from_millis(cfg.scrub_ms);
            let handle = std::thread::Builder::new()
                .name("bitsmm-scrubber".into())
                .spawn(move || {
                    let mut stats = ScrubStats::default();
                    while !flag.load(Ordering::Relaxed) {
                        // sleep in small steps so shutdown never waits
                        // a full period for the scrubber to notice
                        let mut slept = Duration::ZERO;
                        while slept < period && !flag.load(Ordering::Relaxed) {
                            let step = (period - slept).min(Duration::from_millis(5));
                            std::thread::sleep(step);
                            slept += step;
                        }
                        if flag.load(Ordering::Relaxed) {
                            break;
                        }
                        let o = scrub_model.scrub();
                        stats.sweeps += 1;
                        stats.detected += o.detected;
                        stats.repaired += o.repaired;
                        stats.quarantined += o.quarantined;
                    }
                    stats
                })?;
            Some((stop, handle))
        } else {
            None
        };
        let rejected = Arc::new(AtomicU64::new(0));
        // Periodic metrics snapshotter (DESIGN.md §Observability): one
        // snapshot immediately (seq 0), one per period, one more at the
        // stop signal — and shutdown appends the `"final":true` line on
        // top, so a graceful run always yields ≥ 2 parseable snapshots.
        let snapshotter = match (&cfg.metrics_file, &hub) {
            (Some(path), Some(hub)) => {
                // create/truncate up front: a bad path fails the start,
                // not silently in the background thread
                if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
                    std::fs::create_dir_all(dir)?;
                }
                std::fs::write(path, "")?;
                let stop = Arc::new(AtomicBool::new(false));
                let flag = stop.clone();
                let hub = hub.clone();
                let rej = rejected.clone();
                let path = path.clone();
                let period = Duration::from_millis(cfg.metrics_every_ms.max(1));
                let started = Instant::now();
                let handle = std::thread::Builder::new()
                    .name("bitsmm-metrics".into())
                    .spawn(move || {
                        let mut written = 0u64;
                        loop {
                            let mut m = hub.aggregate();
                            m.wall = started.elapsed();
                            m.rejected += rej.load(Ordering::Relaxed);
                            if append_line(&path, &render_snapshot(written, false, &m)).is_ok() {
                                written += 1;
                            }
                            if flag.load(Ordering::Relaxed) {
                                break;
                            }
                            // sleep in small steps so shutdown never
                            // waits a full period for the snapshotter
                            let mut slept = Duration::ZERO;
                            while slept < period && !flag.load(Ordering::Relaxed) {
                                let step = (period - slept).min(Duration::from_millis(5));
                                std::thread::sleep(step);
                                slept += step;
                            }
                        }
                        written
                    })?;
                Some((stop, handle))
            }
            _ => None,
        };
        Ok(InferenceServer {
            batcher,
            workers,
            persist,
            rejected,
            scrubber,
            snapshotter,
            metrics_file: cfg.metrics_file.clone(),
            trace: cfg.trace.clone(),
            trace_file: cfg.trace_file.clone(),
            trace_seq: AtomicU64::new(0),
        })
    }

    /// Submit a request; the response arrives on the returned channel.
    /// Admission refusals (bounded queue full, server closed) are
    /// answered immediately on that same channel with a typed error —
    /// the caller's `recv()` always yields a terminal [`Response`].
    pub fn submit(&self, mut req: Request) -> mpsc::Receiver<Response> {
        // trace IDs are minted at admission — spans recorded anywhere
        // downstream tie back to this moment (IDs start at 1; 0 stays
        // the untraced sentinel)
        if let Some(ring) = &self.trace {
            req.trace = self.trace_seq.fetch_add(1, Ordering::Relaxed) + 1;
            ring.span(
                req.trace,
                SpanKind::Admit,
                req.submitted,
                req.submitted.elapsed(),
                req.id,
            );
        }
        let (tx, rx) = mpsc::channel();
        if let Err(refused) = self.batcher.push((req, tx)) {
            let (err, (req, tx)) = match refused {
                PushRefused::Full { item, depth } => (ServeError::Rejected { depth }, item),
                PushRefused::Closed { item } => (ServeError::Closed, item),
            };
            self.rejected.fetch_add(1, Ordering::Relaxed);
            let _ = tx.send(Response {
                id: req.id,
                output: Err(err),
                latency: req.submitted.elapsed(),
            });
        }
        rx
    }

    /// Queue depth (for callers implementing backpressure).
    pub fn queue_depth(&self) -> usize {
        self.batcher.depth()
    }

    /// Stop accepting requests, drain, and collect merged metrics.
    /// A worker that died outside its batch supervisor is *counted*
    /// (`Metrics.worker_deaths`), never propagated: the surviving
    /// workers' telemetry still merges and plan persistence still runs.
    pub fn shutdown(self) -> (ExecutionReport, Metrics) {
        self.batcher.close();
        let mut report = ExecutionReport::default();
        let mut metrics = Metrics::default();
        for w in self.workers {
            match w.join() {
                Ok((r, m)) => {
                    report.merge(&r);
                    metrics.absorb(&m);
                    metrics.wall = metrics.wall.max(m.wall);
                }
                Err(_) => metrics.worker_deaths += 1,
            }
        }
        metrics.rejected += self.rejected.load(Ordering::Relaxed);
        // single-sourced from the merged report so the two aggregation
        // paths cannot desynchronize
        metrics.steal = report.steal;
        metrics.plan = report.plan;
        metrics.device = report.device;
        // scheduler-observed corruption faults (SEU path) fold into the
        // worker-level ledger (dropped pool jobs) — disjoint sources
        metrics.faults.merge(&report.faults);
        // integrity events the ABFT escalation ladder observed inline
        // join the background scrubber's sweep counters below — one
        // §Integrity ledger whichever path found the corruption
        metrics.scrub.merge(&report.scrub);
        // the scrubber keeps sweeping while workers drain; stop it
        // only after they are gone so late corruption is still caught
        if let Some((stop, handle)) = self.scrubber {
            stop.store(true, Ordering::Relaxed);
            if let Ok(stats) = handle.join() {
                metrics.scrub.merge(&stats);
            }
        }
        // stop the snapshotter and append the terminal snapshot carrying
        // the fully merged totals above — a graceful `--metrics-file`
        // run always ends on a `"final":true` line (never fatal: the
        // metrics still come back to the caller either way)
        if let Some((stop, handle)) = self.snapshotter {
            stop.store(true, Ordering::Relaxed);
            let seq = handle.join().unwrap_or(0);
            if let Some(path) = &self.metrics_file {
                if let Err(e) = append_line(path, &render_snapshot(seq, true, &metrics)) {
                    eprintln!("final metrics snapshot to {} failed: {e}", path.display());
                }
            }
        }
        // request-trace dump (also never fatal)
        if let (Some(path), Some(ring)) = (&self.trace_file, &self.trace) {
            if let Err(e) = ring.write_jsonl(path) {
                eprintln!("trace dump to {} failed: {e:#}", path.display());
            }
        }
        // graceful shutdown persists what this run learned: tuned
        // plans merge into the configured plan file (atomic rename),
        // so the next `--planner static` start serves them as exact
        // hits. Persistence failing (foreign file, unwritable path)
        // is logged, never fatal — metrics still come back.
        if let Some((path, planner)) = &self.persist {
            match planner.persist_file(path) {
                Ok(n) => eprintln!("persisted {n} tuned plans to {}", path.display()),
                Err(e) => eprintln!(
                    "plan persistence to {} skipped: {e:#}",
                    path.display()
                ),
            }
        }
        (report, metrics)
    }
}

/// Append one line to a JSONL sink (snapshotter + final snapshot).
fn append_line(path: &std::path::Path, line: &str) -> std::io::Result<()> {
    use std::io::Write;
    let mut f = std::fs::OpenOptions::new().append(true).create(true).open(path)?;
    writeln!(f, "{line}")
}

/// One admitted request in flight inside a worker: the payload (taken
/// when it moves into a forward pass), its response channel, and
/// whether a terminal response was already sent — the ledger the
/// panic supervisor consults so every submitter gets exactly one
/// answer no matter where execution died.
struct Pending {
    id: u64,
    submitted: Instant,
    deadline: Option<Instant>,
    priority: Priority,
    trace: u64,
    input: Option<TensorInput>,
    tx: mpsc::Sender<Response>,
    answered: bool,
}

impl Pending {
    fn new((req, tx): Queued) -> Pending {
        Pending {
            id: req.id,
            submitted: req.submitted,
            deadline: req.deadline,
            priority: req.priority,
            trace: req.trace,
            input: Some(req.input),
            tx,
            answered: false,
        }
    }

    /// Deliver the terminal response exactly once and account it. The
    /// supervisor calls this again for items a panic left unanswered;
    /// the guard makes that a no-op for items already served.
    fn answer(&mut self, metrics: &mut Metrics, output: std::result::Result<Vec<f64>, ServeError>) {
        if self.answered {
            return;
        }
        self.answered = true;
        let latency = self.submitted.elapsed();
        match &output {
            Ok(_) => {
                metrics.latency.record(latency);
                metrics.requests += 1;
            }
            Err(_) => metrics.errors += 1,
        }
        let _ = self.tx.send(Response {
            id: self.id,
            output,
            latency,
        });
    }

    fn past_deadline(&self, now: Instant) -> bool {
        self.deadline.map_or(false, |d| now >= d)
    }
}

fn worker_loop(
    model: &Model,
    degraded: Option<&Model>,
    cfg: &ServerConfig,
    batcher: &Batcher<Queued>,
    packed_pool: Option<Arc<PackedPool>>,
    w: usize,
    hub: Option<Arc<MetricsHub>>,
) -> (ExecutionReport, Metrics) {
    let mut sched = Scheduler::new(cfg.sa, cfg.backend.clone());
    sched.set_popcount_kernel(cfg.packed_unroll);
    sched.set_tile_policy(cfg.tile_policy());
    if cfg.packed_rsr {
        sched.set_kernel_family(KernelFamily::Rsr { seg_words: 0 });
    }
    let pool_handle = packed_pool.clone();
    if let Some(pool) = packed_pool {
        sched.set_packed_pool(pool);
    }
    if let Some(planner) = cfg.planner.clone().filter(|p| p.is_on()) {
        sched.set_planner(planner);
    }
    if let Some(faults) = &cfg.faults {
        sched.set_seu_injector(faults.seu());
    }
    sched.set_abft(cfg.abft);
    let tracer = cfg.trace.clone();
    if let Some(ring) = tracer.clone() {
        sched.set_tracer(ring);
    }
    let mut metrics = Metrics::default();
    let t0 = Instant::now();
    // Per-kind batch assembly: batch-fusable models — rank-1 vector
    // rows (stacked into one [rows, d] matmul) and attention-free
    // rank-3 image models (stacked into one (B,C,H,W) forward whose
    // convs run batched im2col) — fuse whole batches into one forward
    // pass. Everything else (attention's data-dependent ctx
    // requantization must never mix requests) runs per item. Either
    // way responses are bit-identical across batch compositions:
    // fused layers treat each request's rows independently
    // (DESIGN.md §Serving).
    let fuse = model.fuses_batches();
    while let Some(batch) = batcher.next_batch() {
        // one global batch index per dequeued batch keeps the fault
        // schedule deterministic across workers
        let actions = cfg
            .faults
            .as_ref()
            .map(|f| f.batch_actions())
            .unwrap_or_default();
        // shed items never execute but are always answered
        for (item, waited) in batch.shed {
            metrics.sheds += 1;
            if let Some(ring) = &tracer {
                if item.0.trace != 0 {
                    ring.event(item.0.trace, SpanKind::Shed, waited.as_millis() as u64);
                }
            }
            Pending::new(item).answer(&mut metrics, Err(ServeError::Overloaded { waited }));
        }
        // per-request queue-wait spans plus one assembly span on the
        // batch's lead (first traced) request
        if let Some(ring) = &tracer {
            for ((req, _tx), waited) in batch.items.iter().zip(&batch.waits) {
                if req.trace != 0 {
                    ring.span(req.trace, SpanKind::QueueWait, req.submitted, *waited, req.id);
                }
            }
            let lead = batch.items.iter().map(|(r, _)| r.trace).find(|&t| t != 0);
            if let Some(lead) = lead {
                ring.span(
                    lead,
                    SpanKind::Assemble,
                    batch.oldest,
                    batch.assembled.duration_since(batch.oldest),
                    batch.items.len() as u64,
                );
            }
        }
        let mut pending: Vec<Pending> = batch.items.into_iter().map(Pending::new).collect();
        // deadline check at dequeue: a request whose budget is already
        // spent wastes no matmul
        let now = Instant::now();
        for p in &mut pending {
            if p.past_deadline(now) {
                metrics.deadline_misses += 1;
                if let Some(ring) = &tracer {
                    if p.trace != 0 {
                        ring.event(p.trace, SpanKind::DeadlineMiss, p.id);
                    }
                }
                p.answer(&mut metrics, Err(ServeError::DeadlineExceeded));
            }
        }
        let mut panic_armed = false;
        for a in &actions {
            match a {
                FaultAction::Panic => panic_armed = true,
                FaultAction::Delay(d) => std::thread::sleep(*d),
                FaultAction::DropPoolJob => {
                    if let Some(pool) = &pool_handle {
                        pool.inject_drop_jobs(1);
                        // masked by construction: the caller's inline
                        // steal slot drains every deque, so tiles
                        // seeded to the dropped slot job are stolen
                        // and the merge still sees every tile
                        metrics.faults.injected += 1;
                        metrics.faults.masked_transient += 1;
                    }
                }
                FaultAction::Seu => {
                    if let Some(faults) = &cfg.faults {
                        faults.seu().arm(1);
                    }
                }
                FaultAction::MemSeu => {
                    // memory SEU: flip one bit of a *live* digit in a
                    // resident packed plane (DESIGN.md §Integrity).
                    // Constraining the draw to live digits keeps the
                    // upset output-visible, so ABFT deterministically
                    // observes it; the scrubber and the escalation
                    // ladder then detect via the plane signature and
                    // repair by re-packing from the golden weights.
                    if let Some(faults) = &cfg.faults {
                        let targets = model.resident_planes();
                        if !targets.is_empty() {
                            let seu = faults.seu();
                            let (cache, key, planes) = &targets[seu.pick(targets.len())];
                            let plane = seu.pick(planes.bits as usize);
                            let vec = seu.pick(planes.vectors);
                            let digit = seu.pick(planes.len);
                            let corrupted = planes
                                .with_flipped_bit(plane, vec, digit / 64, (digit % 64) as u32, false)
                                .expect("flip target drawn inside the pack");
                            cache.replace(*key, Arc::new(corrupted));
                            metrics.faults.injected += 1;
                            metrics.faults.mem_seu += 1;
                        }
                    }
                }
            }
        }
        if pending.iter().all(|p| p.answered) && !panic_armed {
            continue; // shed-only or all-expired batch
        }
        // scheduler-level spans (plan/pack/kernel/ABFT/device) are
        // batch-granular: attribute them to the lead traced request
        let lead = pending
            .iter()
            .find(|p| p.trace != 0 && !p.answered)
            .map_or(0, |p| p.trace);
        sched.set_trace_ctx(lead);
        let cycles_before = sched.report.hw_cycles;
        let macs_before = sched.report.macs;
        let served_before = metrics.requests;
        // degrade decision per batch: depth measured after this batch
        // was taken, so only a *sustained* backlog downshifts anyone
        let deg_for_batch = match (&cfg.degrade, degraded) {
            (Some(d), Some(deg)) if batcher.depth() > d.high_water => Some(deg),
            _ => None,
        };
        // supervised execution: a panic anywhere in the batch (model
        // bug, kernel bug, injected fault) is caught here; the ledger
        // then answers every item the panic left hanging and the
        // worker lives on to serve the next batch. The scheduler's
        // internal counters are plain integers — safe to keep using
        // after an unwind.
        let exec = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            if panic_armed {
                panic!("injected fault: worker panic (fault plan)");
            }
            execute_batch(model, deg_for_batch, &mut sched, &mut pending, &mut metrics, fuse);
        }));
        if exec.is_err() {
            metrics.panics += 1;
            for p in &mut pending {
                p.answer(
                    &mut metrics,
                    Err(ServeError::WorkerFault(
                        "worker panicked while executing the batch".into(),
                    )),
                );
            }
        }
        metrics.macs += sched.report.macs - macs_before;
        metrics.hw_cycles += sched.report.hw_cycles - cycles_before;
        // a batch counts as executed if it produced responses or did
        // matmul work (e.g. a forward that failed mid-model) — only
        // all-invalid batches that never reached the scheduler are
        // excluded, so MACs are never attributed to zero batches
        if metrics.requests > served_before || sched.report.macs > macs_before {
            metrics.batches += 1;
        }
        // a respond span closes every trace that received its terminal
        // answer in this batch (dur = the request's end-to-end latency)
        if let Some(ring) = &tracer {
            for p in pending.iter().filter(|p| p.trace != 0 && p.answered) {
                ring.span(p.trace, SpanKind::Respond, p.submitted, p.submitted.elapsed(), p.id);
            }
        }
        // publish this worker's live state for the snapshotter; one
        // branch and two struct clones per batch when snapshots are on
        if let Some(hub) = &hub {
            hub.publish(w, &sched.report, &metrics);
        }
    }
    metrics.wall = t0.elapsed();
    if let Some(hub) = &hub {
        hub.publish(w, &sched.report, &metrics);
    }
    (sched.report, metrics)
}

/// Route one batch's unanswered items through the model — or, when the
/// degrade policy fired, split by SLA class: normal traffic keeps full
/// precision, low-priority traffic runs on the degraded clone (same
/// integers, narrower planes — `Model::degraded` guarantees bit-exact
/// outputs, so the split is invisible in the responses).
fn execute_batch(
    model: &Model,
    degraded: Option<&Model>,
    sched: &mut Scheduler,
    pending: &mut [Pending],
    metrics: &mut Metrics,
    fuse: bool,
) {
    match degraded {
        None => {
            let all: Vec<usize> = (0..pending.len()).collect();
            serve_group(model, sched, pending, &all, metrics, fuse);
        }
        Some(deg) => {
            let (low, normal): (Vec<usize>, Vec<usize>) =
                (0..pending.len()).partition(|&i| pending[i].priority == Priority::Low);
            if !normal.is_empty() {
                serve_group(model, sched, pending, &normal, metrics, fuse);
            }
            if !low.is_empty() {
                metrics.degraded += low.iter().filter(|&&i| !pending[i].answered).count() as u64;
                serve_group(deg, sched, pending, &low, metrics, fuse);
            }
        }
    }
}

fn serve_group(
    model: &Model,
    sched: &mut Scheduler,
    pending: &mut [Pending],
    idxs: &[usize],
    metrics: &mut Metrics,
    fuse: bool,
) {
    if fuse {
        serve_fused(model, sched, pending, idxs, metrics);
    } else {
        serve_per_item(model, sched, pending, idxs, metrics);
    }
}

/// Shape + range validation of one request against the model contract.
/// Rejections become per-request error responses, never batch drops.
fn validate_input(model: &Model, id: u64, input: &TensorInput) -> Result<()> {
    anyhow::ensure!(
        input.shape == model.input_shape,
        "request {}: input shape {:?} does not match model input shape {:?}",
        id,
        input.shape,
        model.input_shape
    );
    anyhow::ensure!(
        input.data.len() == input.numel(),
        "request {}: {} values for shape {:?}",
        id,
        input.data.len(),
        input.shape
    );
    let lo = crate::bits::twos::min_value(model.input_bits);
    let hi = crate::bits::twos::max_value(model.input_bits);
    anyhow::ensure!(
        input.data.iter().all(|v| (lo..=hi).contains(v)),
        "request {}: values exceed the model's {}-bit input range",
        id,
        model.input_bits
    );
    Ok(())
}

/// Fused assembly: stack every valid request into one forward pass —
/// `[rows, d]` for rank-1 vector models, `(rows, C, H, W)` for
/// attention-free image models (whose convs then run batched im2col:
/// one matmul per layer per batch instead of per request). Fusing is
/// batch-invariant because every fused layer treats each request's
/// rows independently (DESIGN.md §Serving).
fn serve_fused(
    model: &Model,
    sched: &mut Scheduler,
    pending: &mut [Pending],
    idxs: &[usize],
    metrics: &mut Metrics,
) {
    let numel: usize = model.input_shape.iter().product();
    let mut stacked = Vec::with_capacity(idxs.len() * numel);
    let mut valid: Vec<usize> = Vec::with_capacity(idxs.len());
    for &i in idxs {
        if pending[i].answered {
            continue;
        }
        let check = {
            let input = pending[i]
                .input
                .as_ref()
                .expect("unanswered pending item retains its payload");
            validate_input(model, pending[i].id, input)
        };
        match check {
            Ok(()) => {
                stacked.extend_from_slice(&pending[i].input.as_ref().unwrap().data);
                valid.push(i);
            }
            Err(e) => pending[i].answer(metrics, Err(ServeError::Failed(format!("{e:#}")))),
        }
    }
    if valid.is_empty() {
        return;
    }
    let rows = valid.len();
    let mut shape = Vec::with_capacity(1 + model.input_shape.len());
    shape.push(rows);
    shape.extend_from_slice(&model.input_shape);
    let run = QTensor::new(stacked, shape, model.input_scale, model.input_bits)
        .and_then(|x| model.forward(&x, sched));
    match run {
        Ok(y) => {
            let out_dim = y.numel() / rows;
            for (pos, &i) in valid.iter().enumerate() {
                let output = y.data[pos * out_dim..(pos + 1) * out_dim]
                    .iter()
                    .map(|&q| q as f64 * y.scale)
                    .collect();
                pending[i].answer(metrics, Ok(output));
            }
        }
        Err(e) => {
            let err = to_serve_error(e);
            for &i in &valid {
                pending[i].answer(metrics, Err(err.clone()));
            }
        }
    }
}

/// Per-item assembly (token matrices and any model containing
/// attention): each request runs its own forward pass, so attention's
/// data-dependent `ctx_scale` requantization never mixes requests, and
/// one request's failure cannot take its batch-mates down. Each
/// payload *moves* into its forward pass — no per-request copy. The
/// deadline is re-checked before every forward: a slow batch-mate
/// earlier in the loop must not silently spend the budget of the rest.
fn serve_per_item(
    model: &Model,
    sched: &mut Scheduler,
    pending: &mut [Pending],
    idxs: &[usize],
    metrics: &mut Metrics,
) {
    for &i in idxs {
        if pending[i].answered {
            continue;
        }
        if pending[i].past_deadline(Instant::now()) {
            metrics.deadline_misses += 1;
            pending[i].answer(metrics, Err(ServeError::DeadlineExceeded));
            continue;
        }
        let id = pending[i].id;
        let input = pending[i]
            .input
            .take()
            .expect("unanswered pending item retains its payload");
        let run =
            validate_input(model, id, &input).and_then(|()| run_one(model, sched, input));
        pending[i].answer(metrics, run.map_err(to_serve_error));
    }
}

/// Map an execution error onto its typed serving cause: a quarantined
/// weight slot keeps its identity through the anyhow chain (the
/// submitter can tell unrecoverable state loss from a transient
/// failure); everything else takes the formatted-cause path.
fn to_serve_error(e: anyhow::Error) -> ServeError {
    match e.downcast_ref::<crate::nn::layers::Quarantined>() {
        Some(q) => ServeError::Quarantined { slot: q.slot },
        None => ServeError::Failed(format!("{e:#}")),
    }
}

/// Execute a single validated shaped request (consumes the payload).
fn run_one(model: &Model, sched: &mut Scheduler, input: TensorInput) -> Result<Vec<f64>> {
    let x = QTensor::new(input.data, input.shape, model.input_scale, model.input_bits)?;
    let y = model.forward(&x, sched)?;
    Ok(y.data.iter().map(|&q| q as f64 * y.scale).collect())
}

/// Convenience: run a closed set of requests through a fresh server and
/// gather everything (used by examples/benches). Accepts anything that
/// converts into a [`TensorInput`] — plain `Vec<i32>` rows for vector
/// models, shaped payloads for images / token matrices.
pub fn serve_all<I: Into<TensorInput>>(
    model: Arc<Model>,
    cfg: ServerConfig,
    inputs: Vec<I>,
) -> Result<(Vec<Response>, ExecutionReport, Metrics)> {
    let server = InferenceServer::start(model, cfg)?;
    let rxs: Vec<_> = inputs
        .into_iter()
        .enumerate()
        .map(|(i, input)| server.submit(Request::new(i as u64, input)))
        .collect();
    let mut responses = Vec::with_capacity(rxs.len());
    for (i, rx) in rxs.into_iter().enumerate() {
        // the resilience contract says this cannot happen — every
        // admitted or refused request gets a terminal Response — so a
        // disconnect here is a bug worth naming, not a bare RecvError
        responses.push(rx.recv().map_err(|_| {
            anyhow::anyhow!("request {i}: response channel closed without a terminal response")
        })?);
    }
    let (report, metrics) = server.shutdown();
    responses.sort_by_key(|r| r.id);
    Ok((responses, report, metrics))
}

/// Shared-state guard used by tests to assert worker counts; kept
/// small and public for the harness.
pub type SharedReport = Arc<Mutex<ExecutionReport>>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg32;
    use crate::sim::mac_common::MacVariant;

    fn inputs(n: usize, d: usize, bits: u32) -> Vec<Vec<i32>> {
        let mut rng = Pcg32::new(0xf00d);
        let lo = crate::bits::twos::min_value(bits);
        let hi = crate::bits::twos::max_value(bits);
        (0..n)
            .map(|_| (0..d).map(|_| rng.range_i32(lo, hi)).collect())
            .collect()
    }

    #[test]
    fn serves_all_requests_in_order() {
        let model = Arc::new(crate::nn::model::mlp_zoo(5));
        let cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        let (resp, report, metrics) = serve_all(model, cfg, inputs(20, 64, 8)).unwrap();
        assert_eq!(resp.len(), 20);
        for (i, r) in resp.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.output.as_ref().unwrap().len(), 10);
        }
        assert_eq!(metrics.requests, 20);
        assert_eq!(metrics.errors, 0);
        assert!(report.macs > 0 && report.hw_cycles > 0);
        assert!(metrics.mean_batch() >= 1.0);
    }

    #[test]
    fn batching_reduces_matmul_count() {
        let model = Arc::new(crate::nn::model::mlp_zoo(5));
        let mut cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        cfg.workers = 1;
        cfg.batcher = BatcherConfig {
            max_batch: 16,
            linger: std::time::Duration::from_millis(20),
            ..BatcherConfig::default()
        };
        let (_, report, metrics) = serve_all(model, cfg, inputs(16, 64, 8)).unwrap();
        // ideally one batch of 16 → 3 matmuls; allow some fragmentation
        assert!(report.matmuls <= 3 * 4, "matmuls = {}", report.matmuls);
        assert!(metrics.mean_batch() > 1.0);
    }

    #[test]
    fn deterministic_results_across_backends() {
        let model = Arc::new(crate::nn::model::mlp_zoo(5));
        let ins = inputs(4, 64, 8);
        let cfg_n = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        let mut cfg_s = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Simulate);
        cfg_s.workers = 1;
        let cfg_p = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Packed);
        let (r1, _, _) = serve_all(model.clone(), cfg_n, ins.clone()).unwrap();
        let (r2, _, _) = serve_all(model.clone(), cfg_s, ins.clone()).unwrap();
        let (r3, rep_p, _) = serve_all(model, cfg_p, ins).unwrap();
        for ((a, b), c) in r1.iter().zip(&r2).zip(&r3) {
            assert_eq!(a.output, b.output, "native vs simulate diverged");
            assert_eq!(a.output, c.output, "native vs packed diverged");
        }
        assert!(rep_p.packed_execs > 0, "packed backend actually ran");
    }

    #[test]
    fn packed_thread_and_kernel_config_do_not_change_results() {
        let model = Arc::new(crate::nn::model::mlp_zoo(5));
        let ins = inputs(12, 64, 8);
        let cfg_n = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        let (want, _, _) = serve_all(model.clone(), cfg_n, ins.clone()).unwrap();
        for (threads, kernel) in [
            (1usize, PopcountKernel::Scalar),
            (3, PopcountKernel::Unroll4),
            (4, PopcountKernel::Auto),
        ] {
            let mut cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Packed);
            cfg.packed_threads = threads;
            cfg.packed_unroll = kernel;
            let (got, report, _) = serve_all(model.clone(), cfg, ins.clone()).unwrap();
            for (a, b) in want.iter().zip(&got) {
                assert_eq!(a.output, b.output, "t{threads} {} diverged", kernel.name());
            }
            assert!(report.packed_execs > 0);
        }
    }

    #[test]
    fn packed_tile_knobs_do_not_change_results_and_surface_telemetry() {
        let model = Arc::new(crate::nn::model::mlp_zoo(5));
        let ins = inputs(12, 64, 8);
        let cfg_n = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        let (want, _, _) = serve_all(model.clone(), cfg_n, ins.clone()).unwrap();
        for (rows, cols) in [(0usize, 0usize), (1, 0), (0, 4), (2, 8)] {
            let mut cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Packed);
            cfg.packed_threads = 3;
            cfg.packed_tile_rows = rows;
            cfg.packed_tile_cols = cols;
            let (got, report, metrics) = serve_all(model.clone(), cfg, ins.clone()).unwrap();
            for (a, b) in want.iter().zip(&got) {
                assert_eq!(a.output, b.output, "tiles {rows}x{cols} diverged");
            }
            assert!(report.packed_execs > 0);
            // pooled runs happened, so tiling telemetry is populated
            // and mirrored into the serving metrics
            assert!(report.steal.tiles >= 1, "tiles {rows}x{cols}");
            assert_eq!(metrics.steal, report.steal);
            assert!(
                report.steal.max_worker_tiles >= report.steal.min_worker_tiles,
                "tiles {rows}x{cols}"
            );
        }
    }

    #[test]
    fn shaped_requests_serve_image_and_token_models() {
        for (name, model) in [
            ("cnn", crate::nn::model::cnn_zoo(2)),
            ("attn", crate::nn::model::attention_zoo(3)),
        ] {
            let model = Arc::new(model);
            let ins = shaped_inputs(&model, 4, 0xbeef);
            let cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
            let (resp, report, metrics) = serve_all(model.clone(), cfg, ins.clone()).unwrap();
            assert_eq!(resp.len(), 4, "{name}");
            assert_eq!(metrics.requests, 4, "{name}");
            assert_eq!(metrics.errors, 0, "{name}");
            // the serving-path MACs equal the static census for the
            // same request count (per-item batch accounting)
            assert_eq!(report.macs, model.stats(4).macs, "{name}");
            // responses match a direct forward of the same payload
            let mut direct = Scheduler::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
            for (i, r) in resp.iter().enumerate() {
                let x = QTensor::new(
                    ins[i].data.clone(),
                    ins[i].shape.clone(),
                    model.input_scale,
                    model.input_bits,
                )
                .unwrap();
                let y = model.forward(&x, &mut direct).unwrap();
                let want: Vec<f64> = y.data.iter().map(|&q| q as f64 * y.scale).collect();
                assert_eq!(r.output, Ok(want), "{name} request {i}");
            }
        }
    }

    #[test]
    fn invalid_requests_surface_their_cause() {
        let model = Arc::new(crate::nn::model::mlp_zoo(5));
        let cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        let server = InferenceServer::start(model, cfg).unwrap();
        // wrong shape: a 32-vector against the 64-input model
        let rx = server.submit(Request::new(0, vec![1i32; 32]));
        let r = rx.recv().unwrap();
        let err = r.output.unwrap_err().to_string();
        assert!(err.contains("shape"), "cause must name the shape: {err}");
        // out-of-range values against the 8-bit input contract
        let rx = server.submit(Request::new(1, vec![300i32; 64]));
        let err = rx.recv().unwrap().output.unwrap_err().to_string();
        assert!(err.contains("8-bit"), "cause must name the range: {err}");
        let (_, metrics) = server.shutdown();
        assert_eq!((metrics.requests, metrics.errors), (0, 2));
    }

    #[test]
    fn failed_forward_surfaces_error_and_counts_executed_batch() {
        // passes validation but fails mid-forward: layers 1-2 run,
        // layer 3's weight dims mismatch the incoming activation
        let mut model = crate::nn::model::mlp_zoo(5);
        if let crate::nn::Layer::Linear(l) = &mut model.layers[2] {
            l.w = QTensor::zeros(vec![7, 3], 1.0, 4);
        }
        let model = Arc::new(model);
        let cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        let (resp, _, metrics) = serve_all(model, cfg, inputs(3, 64, 8)).unwrap();
        for r in &resp {
            let err = r.output.as_ref().unwrap_err().to_string();
            assert!(err.contains("linear dims"), "cause must reach the caller: {err}");
        }
        assert_eq!((metrics.requests, metrics.errors), (0, 3));
        assert!(metrics.macs > 0, "two layers executed before the failure");
        assert!(metrics.batches >= 1, "a batch that did matmul work is an executed batch");
    }

    #[test]
    fn tensor_shaped_models_reject_vector_servers_no_more() {
        // rank-2 and rank-3 input shapes start; rank-0 is rejected
        for model in [crate::nn::model::cnn_zoo(1), crate::nn::model::attention_zoo(1)] {
            let cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
            let server = InferenceServer::start(Arc::new(model), cfg).unwrap();
            server.shutdown();
        }
        let mut degenerate = crate::nn::model::mlp_zoo(1);
        degenerate.input_shape = vec![];
        let cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        assert!(InferenceServer::start(Arc::new(degenerate), cfg).is_err());
    }

    #[test]
    fn fused_image_serving_batches_conv_matmuls() {
        // 6 CNN requests through one single-worker batch: the fused
        // path runs ~3 matmuls (conv1, conv2, head) for the whole
        // batch instead of 3 per request, with identical outputs
        let model = Arc::new(crate::nn::model::cnn_zoo(2));
        let ins = shaped_inputs(&model, 6, 0x1217);
        let mut solo_cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        solo_cfg.workers = 1;
        solo_cfg.batcher = BatcherConfig {
            max_batch: 1,
            linger: std::time::Duration::from_millis(1),
            ..BatcherConfig::default()
        };
        let (solo, solo_rep, _) = serve_all(model.clone(), solo_cfg, ins.clone()).unwrap();
        let mut fused_cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        fused_cfg.workers = 1;
        fused_cfg.batcher = BatcherConfig {
            max_batch: 6,
            linger: std::time::Duration::from_millis(30),
            ..BatcherConfig::default()
        };
        let (fused, fused_rep, metrics) = serve_all(model.clone(), fused_cfg, ins).unwrap();
        assert_eq!(metrics.errors, 0);
        for (a, b) in solo.iter().zip(&fused) {
            assert_eq!(a.output, b.output, "fused image serving diverged at id {}", a.id);
        }
        // same MACs (the census), far fewer matmul submissions
        assert_eq!(fused_rep.macs, solo_rep.macs);
        assert_eq!(fused_rep.macs, model.stats(6).macs);
        assert!(
            fused_rep.matmuls <= solo_rep.matmuls / 2,
            "fused {} vs solo {} matmuls",
            fused_rep.matmuls,
            solo_rep.matmuls
        );
    }

    #[test]
    fn planner_modes_do_not_change_served_results() {
        use crate::plan::{Planner, PlannerMode};
        let model = Arc::new(crate::nn::model::mlp_zoo(5));
        let ins = inputs(16, 64, 8);
        let cfg_n = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        let (want, _, _) = serve_all(model.clone(), cfg_n, ins.clone()).unwrap();
        for mode in [PlannerMode::Static, PlannerMode::Online] {
            let mut cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Packed);
            cfg.packed_threads = 2;
            let planner = Arc::new(Planner::new(mode, 3));
            cfg.planner = Some(planner.clone());
            let (got, report, metrics) = serve_all(model.clone(), cfg, ins.clone()).unwrap();
            for (a, b) in want.iter().zip(&got) {
                assert_eq!(a.output, b.output, "{mode:?} diverged at id {}", a.id);
            }
            // warm start pre-resolved the census: the request path
            // planned every matmul, overwhelmingly from cache hits
            assert!(report.plan.lookups() > 0, "{mode:?}: no lookups recorded");
            assert!(report.plan.hits > 0, "{mode:?}: warm start should yield hits");
            assert_eq!(metrics.plan, report.plan, "metrics mirror the report");
            assert!(planner.len() > 0, "{mode:?}: plans cached");
            if mode == PlannerMode::Online {
                assert!(
                    planner.stats().calibrations > 0,
                    "online warm start calibrates the census"
                );
            }
        }
    }

    #[test]
    fn packed_rsr_and_ksplit_knobs_do_not_change_results() {
        let model = Arc::new(crate::nn::model::mlp_zoo(5));
        let ins = inputs(12, 64, 8);
        let cfg_n = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        let (want, _, _) = serve_all(model.clone(), cfg_n, ins.clone()).unwrap();
        for (rsr, ksplit) in [(true, 0usize), (false, 2), (true, 2)] {
            let mut cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Packed);
            cfg.packed_threads = 3;
            cfg.packed_rsr = rsr;
            cfg.packed_ksplit = ksplit;
            assert_eq!(cfg.tile_policy().k_chunks, ksplit);
            let (got, report, _) = serve_all(model.clone(), cfg, ins.clone()).unwrap();
            for (a, b) in want.iter().zip(&got) {
                assert_eq!(a.output, b.output, "rsr={rsr} ksplit={ksplit} diverged");
            }
            assert!(report.packed_execs > 0);
        }
    }

    #[test]
    fn graceful_shutdown_persists_tuned_plans() {
        use crate::plan::{Planner, PlannerMode};
        let dir = std::env::temp_dir().join("bitsmm_server_persist");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("plans.json");
        let _ = std::fs::remove_file(&path);

        let model = Arc::new(crate::nn::model::mlp_zoo(5));
        let mut cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Packed);
        cfg.packed_threads = 2;
        cfg.planner = Some(Arc::new(Planner::new(PlannerMode::Online, 3)));
        cfg.plan_persist = Some(path.clone());
        let (_, _, metrics) = serve_all(model, cfg, inputs(4, 64, 8)).unwrap();
        assert_eq!(metrics.errors, 0);

        // shutdown wrote a same-host file holding the calibrated census
        let q = Planner::new(PlannerMode::Static, 3);
        let n = q.load_file(&path).unwrap();
        assert!(n > 0, "warm-start calibrations were persisted");
        std::fs::remove_file(&path).unwrap();
    }

    fn fault_cfg(spec: &str, backend: Backend) -> ServerConfig {
        let mut cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), backend);
        cfg.workers = 1;
        cfg.faults = Some(Arc::new(FaultState::new(
            crate::coordinator::faults::FaultPlan::parse(spec).unwrap(),
        )));
        cfg
    }

    #[test]
    fn expired_deadline_answered_at_dequeue() {
        let model = Arc::new(crate::nn::model::mlp_zoo(5));
        let mut cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        cfg.workers = 1;
        let server = InferenceServer::start(model, cfg).unwrap();
        let input: TensorInput = vec![1i32; 64].into();
        let rx = server.submit(Request::new(0, input.clone()).with_deadline(Instant::now()));
        let r = rx.recv().unwrap();
        assert_eq!(r.output, Err(ServeError::DeadlineExceeded));
        // a generous deadline still serves
        let rx = server.submit(
            Request::new(1, input).with_deadline(Instant::now() + Duration::from_secs(30)),
        );
        assert!(rx.recv().unwrap().output.is_ok());
        let (_, metrics) = server.shutdown();
        assert_eq!(metrics.deadline_misses, 1);
        assert_eq!((metrics.requests, metrics.errors), (1, 1));
    }

    #[test]
    fn queue_full_submissions_get_typed_rejection() {
        let model = Arc::new(crate::nn::model::mlp_zoo(5));
        // batch 0 stalls 250ms while 12 instant submissions hit a
        // 2-deep queue: rejections are guaranteed regardless of how
        // the worker races the submitter
        let mut cfg = fault_cfg("delay@0:250ms", Backend::Native);
        cfg.batcher = BatcherConfig {
            max_batch: 2,
            linger: Duration::from_millis(1),
            max_queue: 2,
            ..BatcherConfig::default()
        };
        let server = InferenceServer::start(model, cfg).unwrap();
        let rxs: Vec<_> = (0..12)
            .map(|i| server.submit(Request::new(i, vec![1i32; 64])))
            .collect();
        let responses: Vec<Response> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        assert_eq!(responses.len(), 12, "every submitter got a terminal answer");
        let rejected = responses
            .iter()
            .filter(|r| matches!(r.output, Err(ServeError::Rejected { .. })))
            .count();
        assert!(rejected >= 1, "bounded queue must refuse the flood");
        let (_, metrics) = server.shutdown();
        assert_eq!(metrics.rejected, rejected as u64);
        assert_eq!(metrics.requests + metrics.errors, 12 - metrics.rejected);
    }

    #[test]
    fn overaged_requests_shed_with_overload_error() {
        let model = Arc::new(crate::nn::model::mlp_zoo(5));
        // batch 0 stalls 200ms; the leftover queue ages past the 50ms
        // budget and must be shed, not executed
        let mut cfg = fault_cfg("delay@0:200ms", Backend::Native);
        cfg.batcher = BatcherConfig {
            max_batch: 4,
            linger: Duration::from_millis(1),
            shed_after: Some(Duration::from_millis(50)),
            ..BatcherConfig::default()
        };
        let server = InferenceServer::start(model, cfg).unwrap();
        let rxs: Vec<_> = (0..8)
            .map(|i| server.submit(Request::new(i, vec![1i32; 64])))
            .collect();
        let responses: Vec<Response> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        let shed = responses
            .iter()
            .filter(|r| matches!(r.output, Err(ServeError::Overloaded { .. })))
            .count();
        assert!(shed >= 1, "items older than the budget must shed");
        let (_, metrics) = server.shutdown();
        assert_eq!(metrics.sheds, shed as u64);
        for r in &responses {
            if let Err(ServeError::Overloaded { waited }) = &r.output {
                assert!(*waited >= Duration::from_millis(50), "shed carries real wait");
            }
        }
    }

    #[test]
    fn worker_panic_is_supervised_and_survivors_stay_bit_identical() {
        let model = Arc::new(crate::nn::model::mlp_zoo(5));
        let ins = inputs(8, 64, 8);
        // fault-free baseline
        let cfg_ok = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        let (want, _, _) = serve_all(model.clone(), cfg_ok, ins.clone()).unwrap();
        // batch 0 panics under the supervisor
        let mut cfg = fault_cfg("panic@0", Backend::Native);
        cfg.batcher = BatcherConfig {
            max_batch: 4,
            linger: Duration::from_millis(5),
            ..BatcherConfig::default()
        };
        let (got, _, metrics) = serve_all(model, cfg, ins).unwrap();
        assert_eq!(metrics.panics, 1, "exactly the scheduled panic fired");
        assert_eq!(got.len(), 8, "server survived and answered everyone");
        let mut faulted = 0;
        for r in &got {
            match &r.output {
                Err(ServeError::WorkerFault(_)) => faulted += 1,
                Ok(out) => {
                    let base = want[r.id as usize].output.as_ref().unwrap();
                    assert_eq!(out, base, "non-faulted request {} diverged", r.id);
                }
                other => panic!("unexpected outcome for {}: {other:?}", r.id),
            }
        }
        assert!(faulted >= 1, "the panicked batch answered its requests");
        assert_eq!(metrics.errors, faulted as u64);
    }

    #[test]
    fn degraded_low_priority_serving_is_bit_identical() {
        // headroom model: 4-bit-valued weights declared at 8 bits, so
        // the degrade clamp has real width to reclaim
        let model = Arc::new(crate::nn::model::mlp_headroom_zoo(3));
        let input: TensorInput = shaped_inputs(&model, 1, 0xdead).remove(0);
        // baseline at full precision, no degrade
        let mut base_cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Packed);
        base_cfg.workers = 1;
        base_cfg.packed_threads = 1;
        let base_server = InferenceServer::start(model.clone(), base_cfg).unwrap();
        let want = base_server
            .submit(Request::new(0, input.clone()))
            .recv()
            .unwrap()
            .output
            .unwrap();
        base_server.shutdown();
        // overloaded server with a degrade policy: batch 0 stalls so a
        // backlog builds, and every request is low-priority
        let mut cfg = fault_cfg("delay@0:150ms", Backend::Packed);
        cfg.packed_threads = 1;
        cfg.batcher = BatcherConfig {
            max_batch: 4,
            linger: Duration::from_millis(1),
            ..BatcherConfig::default()
        };
        cfg.degrade = Some(DegradePolicy {
            high_water: 0,
            floor_bits: 4,
        });
        let server = InferenceServer::start(model, cfg).unwrap();
        let rxs: Vec<_> = (0..12)
            .map(|i| server.submit(Request::new(i, input.clone()).low_priority()))
            .collect();
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert_eq!(
                r.output.as_ref().unwrap(),
                &want,
                "degraded serving changed bits for request {}",
                r.id
            );
        }
        let (_, metrics) = server.shutdown();
        assert!(
            metrics.degraded >= 1,
            "backlog above high-water must downshift low-priority traffic"
        );
        assert_eq!(metrics.errors, 0);
    }

    /// All-ones inputs keep every weight digit live, so a flipped
    /// resident plane bit must perturb the matmul and ABFT must
    /// observe it (a random input could zero the faulted column).
    fn ones_inputs(model: &Model, n: usize) -> Vec<TensorInput> {
        let numel: usize = model.input_shape.iter().product();
        (0..n)
            .map(|_| TensorInput::new(vec![1; numel], model.input_shape.clone()))
            .collect()
    }

    #[test]
    fn mem_seu_is_masked_by_the_abft_ladder_and_stays_bit_identical() {
        let model = Arc::new(crate::nn::model::mlp_headroom_zoo(3));
        let ins = ones_inputs(&model, 8);
        let mut base = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Packed);
        base.workers = 1;
        base.packed_threads = 1;
        let (want, _, _) = serve_all(model.clone(), base, ins.clone()).unwrap();
        let mut cfg = fault_cfg("mem@1,seed=9", Backend::Packed);
        cfg.packed_threads = 1;
        cfg.abft = true;
        cfg.batcher = BatcherConfig {
            max_batch: 2,
            linger: Duration::from_millis(2),
            ..BatcherConfig::default()
        };
        let (got, _, metrics) = serve_all(model, cfg, ins).unwrap();
        for (a, b) in want.iter().zip(&got) {
            assert_eq!(a.output, b.output, "memory SEU leaked into request {}", a.id);
        }
        assert!(metrics.faults.mem_seu >= 1, "the scheduled memory SEU fired");
        assert_eq!(metrics.faults.injected, metrics.faults.mem_seu);
        assert_eq!(metrics.faults.unmasked, 0, "no corrupt output reached a response");
        assert!(metrics.faults.masked() >= 1, "the ladder masked the corruption");
        assert!(
            metrics.scrub.detected >= 1 && metrics.scrub.repaired >= 1,
            "repair-by-re-pack ran inline: {:?}",
            metrics.scrub
        );
        assert_eq!(metrics.scrub.quarantined, 0);
    }

    #[test]
    fn background_scrubber_repairs_a_flipped_resident_plane() {
        let model = Arc::new(crate::nn::model::mlp_headroom_zoo(3));
        let mut cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Packed);
        cfg.workers = 1;
        cfg.packed_threads = 1;
        cfg.scrub_ms = 1;
        let server = InferenceServer::start(model.clone(), cfg).unwrap();
        // corrupt one warm-packed plane behind the server's back — the
        // memory-SEU model, minus the fault plan
        let targets = model.resident_planes();
        assert!(!targets.is_empty(), "warm start left the weights resident");
        let (cache, key, clean) = &targets[0];
        cache.replace(
            *key,
            Arc::new(clean.with_flipped_bit(0, 0, 0, 7, false).unwrap()),
        );
        let deadline = Instant::now() + Duration::from_secs(10);
        while !model.resident_planes().iter().all(|(_, _, p)| p.verify()) {
            assert!(Instant::now() < deadline, "scrubber never repaired the plane");
            std::thread::sleep(Duration::from_millis(2));
        }
        let repaired = model
            .resident_planes()
            .into_iter()
            .find(|(_, k, _)| k == key)
            .map(|(_, _, p)| p)
            .unwrap();
        assert_eq!(repaired.as_ref(), clean.as_ref(), "repair re-packs bit-identically");
        let (_, metrics) = server.shutdown();
        assert!(metrics.scrub.sweeps >= 1, "sweep counter advanced");
        assert!(metrics.scrub.detected >= 1 && metrics.scrub.repaired >= 1);
        assert_eq!(metrics.scrub.quarantined, 0);
        assert_eq!(metrics.faults.injected, 0, "no fault plan ran");
    }

    #[test]
    fn quarantined_slots_surface_typed_serve_errors() {
        // poison every weight's dense data *after* construction: the
        // golden stamps no longer match, so when a memory SEU corrupts
        // the packed planes the ladder cannot trust the source and
        // must quarantine instead of re-packing
        let mut model = crate::nn::model::mlp_headroom_zoo(3);
        for layer in &mut model.layers {
            if let crate::nn::Layer::Linear(l) = layer {
                l.w.data[0] ^= 1;
            }
        }
        let model = Arc::new(model);
        let mut cfg = fault_cfg("mem@0,seed=5", Backend::Packed);
        cfg.packed_threads = 1;
        cfg.abft = true;
        cfg.batcher = BatcherConfig {
            max_batch: 2,
            linger: Duration::from_millis(2),
            ..BatcherConfig::default()
        };
        let ins = ones_inputs(&model, 6);
        let (resp, _, metrics) = serve_all(model, cfg, ins).unwrap();
        let quarantined = resp
            .iter()
            .filter(|r| matches!(r.output, Err(ServeError::Quarantined { .. })))
            .count();
        assert!(quarantined >= 1, "the poisoned slot surfaces its typed cause");
        assert!(metrics.faults.mem_seu >= 1);
        assert!(metrics.scrub.quarantined >= 1, "{:?}", metrics.scrub);
        assert_eq!(metrics.faults.unmasked, 0, "no corrupt output was served");
        assert_eq!(metrics.errors, quarantined as u64);
    }

    #[test]
    fn metrics_snapshots_and_trace_dump_round_trip() {
        use crate::obs::snapshot::{lookup, parse_snapshots, REQUIRED_GROUPS};
        use crate::plan::store::Json;
        let dir = std::env::temp_dir().join(format!("bitsmm_obs_server_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let metrics_path = dir.join("metrics.jsonl");
        let trace_path = dir.join("trace.jsonl");

        let model = Arc::new(crate::nn::model::mlp_zoo(5));
        let mut cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Packed);
        cfg.workers = 2;
        cfg.packed_threads = 2;
        cfg.metrics_file = Some(metrics_path.clone());
        cfg.metrics_every_ms = 5;
        cfg.trace_file = Some(trace_path.clone());
        let server = InferenceServer::start(model, cfg).unwrap();
        let rxs: Vec<_> = inputs(12, 64, 8)
            .into_iter()
            .enumerate()
            .map(|(i, x)| server.submit(Request::new(i as u64, x)))
            .collect();
        for rx in rxs {
            assert!(rx.recv().unwrap().output.is_ok());
        }
        // let at least one periodic snapshot land beyond the initial one
        std::thread::sleep(Duration::from_millis(25));
        let (_, metrics) = server.shutdown();
        assert_eq!(metrics.requests, 12);

        // ≥ 2 snapshots round-trip through the in-repo JSON reader,
        // every counter group present, last line = the merged final
        let text = std::fs::read_to_string(&metrics_path).unwrap();
        let snaps = parse_snapshots(&text).unwrap();
        assert!(snaps.len() >= 2, "only {} snapshots", snaps.len());
        let last = snaps.last().unwrap();
        assert_eq!(lookup(last, "final").unwrap(), &Json::Bool(true));
        assert_eq!(lookup(last, "requests").unwrap().as_int().unwrap(), 12);
        assert_eq!(
            lookup(last, "latency.count").unwrap().as_int().unwrap(),
            12,
            "final snapshot carries the merged latency histogram"
        );
        assert!(lookup(last, "throughput_rps").unwrap().as_f64().unwrap() > 0.0);
        for g in REQUIRED_GROUPS {
            assert!(lookup(last, g).is_ok(), "group {g} missing");
        }
        // seq numbers are consecutive from 0
        for (i, s) in snaps.iter().enumerate() {
            assert_eq!(lookup(s, "seq").unwrap().as_int().unwrap(), i as i64);
        }

        // the trace dump parses line by line; every request's trace
        // runs admit → … → respond with strictly increasing seq
        let ttext = std::fs::read_to_string(&trace_path).unwrap();
        let mut per_trace: std::collections::HashMap<i64, Vec<(i64, String)>> =
            std::collections::HashMap::new();
        let mut trailer_seen = false;
        for line in ttext.lines() {
            let v = Json::parse(line).unwrap();
            if v.field("spans").is_ok() {
                trailer_seen = true;
                continue;
            }
            per_trace
                .entry(v.field("trace").unwrap().as_int().unwrap())
                .or_default()
                .push((
                    v.field("seq").unwrap().as_int().unwrap(),
                    v.field("kind").unwrap().as_str().unwrap().to_string(),
                ));
        }
        assert!(trailer_seen, "dump ends with the ring-accounting trailer");
        assert_eq!(per_trace.len(), 12, "one trace per request");
        for (trace, spans) in &per_trace {
            assert!(spans.windows(2).all(|p| p[0].0 < p[1].0), "trace {trace} seq order");
            let kinds: Vec<&str> = spans.iter().map(|(_, k)| k.as_str()).collect();
            assert_eq!(kinds.first().copied(), Some("admit"), "trace {trace}");
            assert_eq!(kinds.last().copied(), Some("respond"), "trace {trace}");
            assert!(kinds.contains(&"queue_wait"), "trace {trace}: {kinds:?}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn packed_threads_auto_resolution() {
        let mut cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Packed);
        cfg.workers = 1_000_000; // more workers than cores: still >= 1
        assert_eq!(cfg.resolved_packed_threads(), 1);
        assert_eq!(cfg.kernel_slots(), 1, "no pool, no inline slot bonus");
        cfg.packed_threads = 7; // explicit setting wins over auto
        assert_eq!(cfg.resolved_packed_threads(), 7);
        // pool workers + the caller's inline slot — the count the
        // planner sizes candidate plans for
        assert_eq!(cfg.kernel_slots(), 8);
        let non_packed = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        assert_eq!(non_packed.kernel_slots(), 1);
    }
}
