//! The inference server: threaded request loop over the batcher,
//! scheduler and model — the end-to-end serving path of the `e2e`
//! example (and the paper's future-work integration, §V).

use crate::bits::packed::{PackedPool, PopcountKernel, TilePolicy};
use crate::coordinator::batcher::{Batcher, BatcherConfig};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::scheduler::{Backend, ExecutionReport, Scheduler};
use crate::nn::model::Model;
use crate::nn::tensor::QTensor;
use crate::sim::array::SaConfig;
use crate::Result;
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

/// One inference request: a quantized input row for the model.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub input: Vec<i32>,
    pub submitted: Instant,
}

/// One completed inference.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// Output activations (dequantized logits).
    pub output: Vec<f64>,
    pub latency: std::time::Duration,
}

/// Server tuning.
#[derive(Clone)]
pub struct ServerConfig {
    pub sa: SaConfig,
    pub backend: Backend,
    pub batcher: BatcherConfig,
    pub workers: usize,
    /// Hardware clock for GOPS accounting (300 MHz = the paper's FPGA
    /// operating point).
    pub clock_hz: f64,
    /// Packed-kernel worker threads, shared by **all** request workers
    /// through one [`PackedPool`] so kernel parallelism composes with
    /// (not multiplies against) request parallelism. `0` = auto:
    /// available cores / `workers`, min 1. `1` = single-thread kernel
    /// (no pool). Ignored by non-packed backends.
    pub packed_threads: usize,
    /// Popcount reducer for the packed kernel (`Auto` = AVX2/NEON when
    /// the CPU has one, else 8-word unrolled chunks).
    pub packed_unroll: PopcountKernel,
    /// Output rows per pooled-kernel tile job (`0` = auto: adapt to the
    /// batch shape and worker count — see DESIGN.md §Packed-Threading).
    pub packed_tile_rows: usize,
    /// Output columns per pooled-kernel tile job (`0` = auto).
    pub packed_tile_cols: usize,
}

impl ServerConfig {
    pub fn new(sa: SaConfig, backend: Backend) -> Self {
        ServerConfig {
            sa,
            backend,
            batcher: BatcherConfig::default(),
            workers: 2,
            clock_hz: 300e6,
            packed_threads: 0,
            packed_unroll: PopcountKernel::Auto,
            packed_tile_rows: 0,
            packed_tile_cols: 0,
        }
    }

    /// The pooled kernel's tile-granularity knobs as one policy.
    pub fn tile_policy(&self) -> TilePolicy {
        TilePolicy {
            tile_rows: self.packed_tile_rows,
            tile_cols: self.packed_tile_cols,
        }
    }

    /// Resolve `packed_threads = 0` (auto) to a concrete thread count:
    /// the machine's cores divided across the request workers, min 1.
    pub fn resolved_packed_threads(&self) -> usize {
        if self.packed_threads != 0 {
            return self.packed_threads;
        }
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        (cores / self.workers.max(1)).max(1)
    }
}

/// A running inference server for one model.
pub struct InferenceServer {
    batcher: Arc<Batcher<(Request, mpsc::Sender<Response>)>>,
    workers: Vec<std::thread::JoinHandle<(ExecutionReport, Metrics)>>,
}

impl InferenceServer {
    /// Start worker threads serving `model` (2-D inputs: each request
    /// is one row; batches stack rows into one matmul pass).
    pub fn start(model: Arc<Model>, cfg: ServerConfig) -> Result<InferenceServer> {
        anyhow::ensure!(cfg.workers >= 1, "need at least one worker");
        anyhow::ensure!(
            model.input_shape.len() == 1,
            "row-serving requires vector inputs (got {:?})",
            model.input_shape
        );
        let batcher = Arc::new(Batcher::new(cfg.batcher));
        // one pool for the whole server: every worker's scheduler rides
        // the same packed_threads kernel lanes (DESIGN.md
        // §Packed-Threading)
        let packed_pool = match cfg.backend {
            Backend::Packed => {
                let threads = cfg.resolved_packed_threads();
                if threads > 1 {
                    Some(Arc::new(PackedPool::new(threads)?))
                } else {
                    None
                }
            }
            _ => None,
        };
        let mut workers = Vec::new();
        for w in 0..cfg.workers {
            let batcher = batcher.clone();
            let model = model.clone();
            let cfg = cfg.clone();
            let pool = packed_pool.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("bitsmm-worker-{w}"))
                    .spawn(move || worker_loop(&model, &cfg, &batcher, pool))?,
            );
        }
        Ok(InferenceServer { batcher, workers })
    }

    /// Submit a request; the response arrives on the returned channel.
    pub fn submit(&self, req: Request) -> mpsc::Receiver<Response> {
        let (tx, rx) = mpsc::channel();
        self.batcher.push((req, tx));
        rx
    }

    /// Queue depth (for callers implementing backpressure).
    pub fn queue_depth(&self) -> usize {
        self.batcher.depth()
    }

    /// Stop accepting requests, drain, and collect merged metrics.
    pub fn shutdown(self) -> (ExecutionReport, Metrics) {
        self.batcher.close();
        let mut report = ExecutionReport::default();
        let mut metrics = Metrics::default();
        for w in self.workers {
            let (r, m) = w.join().expect("worker panicked");
            report.merge(&r);
            metrics.latency.merge(&m.latency);
            metrics.requests += m.requests;
            metrics.batches += m.batches;
            metrics.macs += m.macs;
            metrics.hw_cycles += m.hw_cycles;
            metrics.wall = metrics.wall.max(m.wall);
        }
        // single-sourced from the merged report so the two aggregation
        // paths cannot desynchronize
        metrics.steal = report.steal;
        (report, metrics)
    }
}

fn worker_loop(
    model: &Model,
    cfg: &ServerConfig,
    batcher: &Batcher<(Request, mpsc::Sender<Response>)>,
    packed_pool: Option<Arc<PackedPool>>,
) -> (ExecutionReport, Metrics) {
    let mut sched = Scheduler::new(cfg.sa, cfg.backend.clone());
    sched.set_popcount_kernel(cfg.packed_unroll);
    sched.set_tile_policy(cfg.tile_policy());
    if let Some(pool) = packed_pool {
        sched.set_packed_pool(pool);
    }
    let mut metrics = Metrics::default();
    let t0 = Instant::now();
    let d_in = model.input_shape[0];
    while let Some(batch) = batcher.next_batch() {
        let rows = batch.items.len();
        let mut stacked = Vec::with_capacity(rows * d_in);
        for (req, _) in &batch.items {
            debug_assert_eq!(req.input.len(), d_in);
            stacked.extend_from_slice(&req.input);
        }
        let x = match QTensor::new(stacked, vec![rows, d_in], model.input_scale, model.input_bits)
        {
            Ok(x) => x,
            Err(e) => {
                log_drop(&batch, &e);
                continue;
            }
        };
        let cycles_before = sched.report.hw_cycles;
        let macs_before = sched.report.macs;
        // the scheduler itself is the executor (not an `as_exec`
        // closure) so the packed backend sees layer-cached weight
        // planes and packs each weight once per (layer, precision)
        let result = model.forward(&x, &mut sched);
        match result {
            Ok(y) => {
                let out_dim = y.shape[1];
                for (i, (req, tx)) in batch.items.iter().enumerate() {
                    let output: Vec<f64> = y.data[i * out_dim..(i + 1) * out_dim]
                        .iter()
                        .map(|&q| q as f64 * y.scale)
                        .collect();
                    let latency = req.submitted.elapsed();
                    metrics.latency.record(latency);
                    let _ = tx.send(Response {
                        id: req.id,
                        output,
                        latency,
                    });
                }
                metrics.requests += rows as u64;
                metrics.batches += 1;
                metrics.macs += sched.report.macs - macs_before;
                metrics.hw_cycles += sched.report.hw_cycles - cycles_before;
            }
            Err(e) => log_drop(&batch, &e),
        }
    }
    metrics.wall = t0.elapsed();
    (sched.report, metrics)
}

fn log_drop(batch: &crate::coordinator::batcher::Batch<(Request, mpsc::Sender<Response>)>, e: &anyhow::Error) {
    eprintln!(
        "[bitsmm-server] dropping batch of {}: {e:#}",
        batch.items.len()
    );
}

/// Convenience: run a closed set of requests through a fresh server and
/// gather everything (used by examples/benches).
pub fn serve_all(
    model: Arc<Model>,
    cfg: ServerConfig,
    inputs: Vec<Vec<i32>>,
) -> Result<(Vec<Response>, ExecutionReport, Metrics)> {
    let server = InferenceServer::start(model, cfg)?;
    let rxs: Vec<_> = inputs
        .into_iter()
        .enumerate()
        .map(|(i, input)| {
            server.submit(Request {
                id: i as u64,
                input,
                submitted: Instant::now(),
            })
        })
        .collect();
    let mut responses = Vec::with_capacity(rxs.len());
    for rx in rxs {
        responses.push(rx.recv()?);
    }
    let (report, metrics) = server.shutdown();
    responses.sort_by_key(|r| r.id);
    Ok((responses, report, metrics))
}

/// Shared-state guard used by tests to assert worker counts; kept
/// small and public for the harness.
pub type SharedReport = Arc<Mutex<ExecutionReport>>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg32;
    use crate::sim::mac_common::MacVariant;

    fn inputs(n: usize, d: usize, bits: u32) -> Vec<Vec<i32>> {
        let mut rng = Pcg32::new(0xf00d);
        let lo = crate::bits::twos::min_value(bits);
        let hi = crate::bits::twos::max_value(bits);
        (0..n)
            .map(|_| (0..d).map(|_| rng.range_i32(lo, hi)).collect())
            .collect()
    }

    #[test]
    fn serves_all_requests_in_order() {
        let model = Arc::new(crate::nn::model::mlp_zoo(5));
        let cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        let (resp, report, metrics) = serve_all(model, cfg, inputs(20, 64, 8)).unwrap();
        assert_eq!(resp.len(), 20);
        for (i, r) in resp.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.output.len(), 10);
        }
        assert_eq!(metrics.requests, 20);
        assert!(report.macs > 0 && report.hw_cycles > 0);
        assert!(metrics.mean_batch() >= 1.0);
    }

    #[test]
    fn batching_reduces_matmul_count() {
        let model = Arc::new(crate::nn::model::mlp_zoo(5));
        let mut cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        cfg.workers = 1;
        cfg.batcher = BatcherConfig {
            max_batch: 16,
            linger: std::time::Duration::from_millis(20),
        };
        let (_, report, metrics) = serve_all(model, cfg, inputs(16, 64, 8)).unwrap();
        // ideally one batch of 16 → 3 matmuls; allow some fragmentation
        assert!(report.matmuls <= 3 * 4, "matmuls = {}", report.matmuls);
        assert!(metrics.mean_batch() > 1.0);
    }

    #[test]
    fn deterministic_results_across_backends() {
        let model = Arc::new(crate::nn::model::mlp_zoo(5));
        let ins = inputs(4, 64, 8);
        let cfg_n = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        let mut cfg_s = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Simulate);
        cfg_s.workers = 1;
        let cfg_p = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Packed);
        let (r1, _, _) = serve_all(model.clone(), cfg_n, ins.clone()).unwrap();
        let (r2, _, _) = serve_all(model.clone(), cfg_s, ins.clone()).unwrap();
        let (r3, rep_p, _) = serve_all(model, cfg_p, ins).unwrap();
        for ((a, b), c) in r1.iter().zip(&r2).zip(&r3) {
            assert_eq!(a.output, b.output, "native vs simulate diverged");
            assert_eq!(a.output, c.output, "native vs packed diverged");
        }
        assert!(rep_p.packed_execs > 0, "packed backend actually ran");
    }

    #[test]
    fn packed_thread_and_kernel_config_do_not_change_results() {
        let model = Arc::new(crate::nn::model::mlp_zoo(5));
        let ins = inputs(12, 64, 8);
        let cfg_n = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        let (want, _, _) = serve_all(model.clone(), cfg_n, ins.clone()).unwrap();
        for (threads, kernel) in [
            (1usize, PopcountKernel::Scalar),
            (3, PopcountKernel::Unroll4),
            (4, PopcountKernel::Auto),
        ] {
            let mut cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Packed);
            cfg.packed_threads = threads;
            cfg.packed_unroll = kernel;
            let (got, report, _) = serve_all(model.clone(), cfg, ins.clone()).unwrap();
            for (a, b) in want.iter().zip(&got) {
                assert_eq!(a.output, b.output, "t{threads} {} diverged", kernel.name());
            }
            assert!(report.packed_execs > 0);
        }
    }

    #[test]
    fn packed_tile_knobs_do_not_change_results_and_surface_telemetry() {
        let model = Arc::new(crate::nn::model::mlp_zoo(5));
        let ins = inputs(12, 64, 8);
        let cfg_n = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        let (want, _, _) = serve_all(model.clone(), cfg_n, ins.clone()).unwrap();
        for (rows, cols) in [(0usize, 0usize), (1, 0), (0, 4), (2, 8)] {
            let mut cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Packed);
            cfg.packed_threads = 3;
            cfg.packed_tile_rows = rows;
            cfg.packed_tile_cols = cols;
            let (got, report, metrics) = serve_all(model.clone(), cfg, ins.clone()).unwrap();
            for (a, b) in want.iter().zip(&got) {
                assert_eq!(a.output, b.output, "tiles {rows}x{cols} diverged");
            }
            assert!(report.packed_execs > 0);
            // pooled runs happened, so tiling telemetry is populated
            // and mirrored into the serving metrics
            assert!(report.steal.tiles >= 1, "tiles {rows}x{cols}");
            assert_eq!(metrics.steal, report.steal);
            assert!(
                report.steal.max_worker_tiles >= report.steal.min_worker_tiles,
                "tiles {rows}x{cols}"
            );
        }
    }

    #[test]
    fn packed_threads_auto_resolution() {
        let mut cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Packed);
        cfg.workers = 1_000_000; // more workers than cores: still >= 1
        assert_eq!(cfg.resolved_packed_threads(), 1);
        cfg.packed_threads = 7; // explicit setting wins over auto
        assert_eq!(cfg.resolved_packed_threads(), 7);
    }
}
