//! The inference server: threaded request loop over the batcher,
//! scheduler and model — the end-to-end serving path of the `e2e`
//! example (and the paper's future-work integration, §V).

use crate::bits::packed::{PackedPool, PopcountKernel, TilePolicy};
use crate::coordinator::batcher::{Batch, Batcher, BatcherConfig};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::scheduler::{Backend, ExecutionReport, Scheduler};
use crate::nn::model::Model;
use crate::nn::tensor::QTensor;
use crate::sim::array::SaConfig;
use crate::Result;
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

/// A shaped request payload: quantized values on the model's input
/// grid plus their shape, validated server-side against
/// [`Model::input_shape`] — rank 1 for vector models (MLP rows), rank
/// 2 for token matrices (attention), rank 3 for images (CNN).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorInput {
    pub data: Vec<i32>,
    pub shape: Vec<usize>,
}

impl TensorInput {
    pub fn new(data: Vec<i32>, shape: Vec<usize>) -> TensorInput {
        TensorInput { data, shape }
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Plain vectors keep the historical row-request ergonomics.
impl From<Vec<i32>> for TensorInput {
    fn from(data: Vec<i32>) -> TensorInput {
        let shape = vec![data.len()];
        TensorInput { data, shape }
    }
}

/// Random shaped requests on `model`'s input grid (any rank) — the one
/// generator behind the CLI entries, the e2e example, and the
/// integration tests, so the request contract cannot drift per caller.
pub fn shaped_inputs(model: &Model, n: usize, seed: u64) -> Vec<TensorInput> {
    let numel: usize = model.input_shape.iter().product();
    let lo = crate::bits::twos::min_value(model.input_bits);
    let hi = crate::bits::twos::max_value(model.input_bits);
    let mut rng = crate::prng::Pcg32::new(seed);
    (0..n)
        .map(|_| {
            TensorInput::new(
                (0..numel).map(|_| rng.range_i32(lo, hi)).collect(),
                model.input_shape.clone(),
            )
        })
        .collect()
}

/// One inference request: a quantized, shaped input for the model.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub input: TensorInput,
    pub submitted: Instant,
}

/// One completed inference.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// Output activations (dequantized logits), or the serving error —
    /// validation and execution failures reach the submitter with
    /// their cause instead of a silently dropped channel.
    pub output: std::result::Result<Vec<f64>, String>,
    pub latency: std::time::Duration,
}

/// Server tuning.
#[derive(Clone)]
pub struct ServerConfig {
    pub sa: SaConfig,
    pub backend: Backend,
    pub batcher: BatcherConfig,
    pub workers: usize,
    /// Hardware clock for GOPS accounting (300 MHz = the paper's FPGA
    /// operating point).
    pub clock_hz: f64,
    /// Packed-kernel worker threads, shared by **all** request workers
    /// through one [`PackedPool`] so kernel parallelism composes with
    /// (not multiplies against) request parallelism. `0` = auto:
    /// available cores / `workers`, min 1. `1` = single-thread kernel
    /// (no pool). Ignored by non-packed backends.
    pub packed_threads: usize,
    /// Popcount reducer for the packed kernel (`Auto` = AVX2/NEON when
    /// the CPU has one, else 8-word unrolled chunks).
    pub packed_unroll: PopcountKernel,
    /// Output rows per pooled-kernel tile job (`0` = auto: adapt to the
    /// batch shape and worker count — see DESIGN.md §Packed-Threading).
    pub packed_tile_rows: usize,
    /// Output columns per pooled-kernel tile job (`0` = auto).
    pub packed_tile_cols: usize,
}

impl ServerConfig {
    pub fn new(sa: SaConfig, backend: Backend) -> Self {
        ServerConfig {
            sa,
            backend,
            batcher: BatcherConfig::default(),
            workers: 2,
            clock_hz: 300e6,
            packed_threads: 0,
            packed_unroll: PopcountKernel::Auto,
            packed_tile_rows: 0,
            packed_tile_cols: 0,
        }
    }

    /// The pooled kernel's tile-granularity knobs as one policy.
    pub fn tile_policy(&self) -> TilePolicy {
        TilePolicy {
            tile_rows: self.packed_tile_rows,
            tile_cols: self.packed_tile_cols,
        }
    }

    /// Resolve `packed_threads = 0` (auto) to a concrete thread count:
    /// the machine's cores divided across the request workers, min 1.
    pub fn resolved_packed_threads(&self) -> usize {
        if self.packed_threads != 0 {
            return self.packed_threads;
        }
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        (cores / self.workers.max(1)).max(1)
    }
}

/// A running inference server for one model.
pub struct InferenceServer {
    batcher: Arc<Batcher<(Request, mpsc::Sender<Response>)>>,
    workers: Vec<std::thread::JoinHandle<(ExecutionReport, Metrics)>>,
}

impl InferenceServer {
    /// Start worker threads serving `model`. Rank-1 (vector) models
    /// stack whole batches into one `[rows, d]` matmul pass; rank-2
    /// (token-matrix) and rank-3 (image) models run per item so conv
    /// im2col and attention's data-dependent requantization never mix
    /// requests — responses are bit-identical whether a request is
    /// served alone or inside a batch.
    pub fn start(model: Arc<Model>, cfg: ServerConfig) -> Result<InferenceServer> {
        anyhow::ensure!(cfg.workers >= 1, "need at least one worker");
        anyhow::ensure!(
            (1..=3).contains(&model.input_shape.len())
                && model.input_shape.iter().all(|&d| d >= 1),
            "servable models take non-degenerate rank 1-3 inputs (got {:?})",
            model.input_shape
        );
        let batcher = Arc::new(Batcher::new(cfg.batcher));
        // one pool for the whole server: every worker's scheduler rides
        // the same packed_threads kernel lanes (DESIGN.md
        // §Packed-Threading)
        let packed_pool = match cfg.backend {
            Backend::Packed => {
                let threads = cfg.resolved_packed_threads();
                if threads > 1 {
                    Some(Arc::new(PackedPool::new(threads)?))
                } else {
                    None
                }
            }
            _ => None,
        };
        let mut workers = Vec::new();
        for w in 0..cfg.workers {
            let batcher = batcher.clone();
            let model = model.clone();
            let cfg = cfg.clone();
            let pool = packed_pool.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("bitsmm-worker-{w}"))
                    .spawn(move || worker_loop(&model, &cfg, &batcher, pool))?,
            );
        }
        Ok(InferenceServer { batcher, workers })
    }

    /// Submit a request; the response arrives on the returned channel.
    pub fn submit(&self, req: Request) -> mpsc::Receiver<Response> {
        let (tx, rx) = mpsc::channel();
        self.batcher.push((req, tx));
        rx
    }

    /// Queue depth (for callers implementing backpressure).
    pub fn queue_depth(&self) -> usize {
        self.batcher.depth()
    }

    /// Stop accepting requests, drain, and collect merged metrics.
    pub fn shutdown(self) -> (ExecutionReport, Metrics) {
        self.batcher.close();
        let mut report = ExecutionReport::default();
        let mut metrics = Metrics::default();
        for w in self.workers {
            let (r, m) = w.join().expect("worker panicked");
            report.merge(&r);
            metrics.latency.merge(&m.latency);
            metrics.requests += m.requests;
            metrics.errors += m.errors;
            metrics.batches += m.batches;
            metrics.macs += m.macs;
            metrics.hw_cycles += m.hw_cycles;
            metrics.wall = metrics.wall.max(m.wall);
        }
        // single-sourced from the merged report so the two aggregation
        // paths cannot desynchronize
        metrics.steal = report.steal;
        (report, metrics)
    }
}

fn worker_loop(
    model: &Model,
    cfg: &ServerConfig,
    batcher: &Batcher<(Request, mpsc::Sender<Response>)>,
    packed_pool: Option<Arc<PackedPool>>,
) -> (ExecutionReport, Metrics) {
    let mut sched = Scheduler::new(cfg.sa, cfg.backend.clone());
    sched.set_popcount_kernel(cfg.packed_unroll);
    sched.set_tile_policy(cfg.tile_policy());
    if let Some(pool) = packed_pool {
        sched.set_packed_pool(pool);
    }
    let mut metrics = Metrics::default();
    let t0 = Instant::now();
    // Per-kind batch assembly: rank-1 models are row-independent
    // (linear stacks), so whole batches fuse into one [rows, d]
    // matmul. Higher-rank inputs (images, token matrices) run per
    // item — conv im2col is single-image and attention's
    // data-dependent ctx requantization must never mix requests —
    // which is also what makes responses bit-identical across batch
    // compositions (DESIGN.md §Serving).
    let stack_rows = model.input_shape.len() == 1;
    while let Some(batch) = batcher.next_batch() {
        let cycles_before = sched.report.hw_cycles;
        let macs_before = sched.report.macs;
        let served_before = metrics.requests;
        // the scheduler itself is the executor (not an `as_exec`
        // closure) so the packed backend sees layer-cached weight
        // planes and packs each weight once per (layer, precision)
        if stack_rows {
            serve_stacked(model, &mut sched, batch, &mut metrics);
        } else {
            serve_per_item(model, &mut sched, batch, &mut metrics);
        }
        metrics.macs += sched.report.macs - macs_before;
        metrics.hw_cycles += sched.report.hw_cycles - cycles_before;
        // a batch counts as executed if it produced responses or did
        // matmul work (e.g. a forward that failed mid-model) — only
        // all-invalid batches that never reached the scheduler are
        // excluded, so MACs are never attributed to zero batches
        if metrics.requests > served_before || sched.report.macs > macs_before {
            metrics.batches += 1;
        }
    }
    metrics.wall = t0.elapsed();
    (sched.report, metrics)
}

/// Shape + range validation of one request against the model contract.
/// Rejections become per-request error responses, never batch drops.
fn validate_input(model: &Model, req: &Request) -> Result<()> {
    anyhow::ensure!(
        req.input.shape == model.input_shape,
        "request {}: input shape {:?} does not match model input shape {:?}",
        req.id,
        req.input.shape,
        model.input_shape
    );
    anyhow::ensure!(
        req.input.data.len() == req.input.numel(),
        "request {}: {} values for shape {:?}",
        req.id,
        req.input.data.len(),
        req.input.shape
    );
    let lo = crate::bits::twos::min_value(model.input_bits);
    let hi = crate::bits::twos::max_value(model.input_bits);
    anyhow::ensure!(
        req.input.data.iter().all(|v| (lo..=hi).contains(v)),
        "request {}: values exceed the model's {}-bit input range",
        req.id,
        model.input_bits
    );
    Ok(())
}

/// Deliver one response and account it.
fn respond(
    metrics: &mut Metrics,
    id: u64,
    submitted: Instant,
    tx: &mpsc::Sender<Response>,
    output: std::result::Result<Vec<f64>, String>,
) {
    let latency = submitted.elapsed();
    match &output {
        Ok(_) => {
            metrics.latency.record(latency);
            metrics.requests += 1;
        }
        Err(_) => metrics.errors += 1,
    }
    let _ = tx.send(Response {
        id,
        output,
        latency,
    });
}

/// Rank-1 assembly: stack every valid request into one `[rows, d]`
/// matmul pass. Row-serving is batch-invariant because every layer of
/// a vector model treats rows independently.
fn serve_stacked(
    model: &Model,
    sched: &mut Scheduler,
    batch: Batch<(Request, mpsc::Sender<Response>)>,
    metrics: &mut Metrics,
) {
    let d_in = model.input_shape[0];
    let mut stacked = Vec::with_capacity(batch.items.len() * d_in);
    let mut valid: Vec<(&Request, &mpsc::Sender<Response>)> =
        Vec::with_capacity(batch.items.len());
    for (req, tx) in &batch.items {
        match validate_input(model, req) {
            Ok(()) => {
                stacked.extend_from_slice(&req.input.data);
                valid.push((req, tx));
            }
            Err(e) => respond(metrics, req.id, req.submitted, tx, Err(format!("{e:#}"))),
        }
    }
    if valid.is_empty() {
        return;
    }
    let rows = valid.len();
    let run = QTensor::new(stacked, vec![rows, d_in], model.input_scale, model.input_bits)
        .and_then(|x| model.forward(&x, sched));
    match run {
        Ok(y) => {
            let out_dim = y.numel() / rows;
            for (i, (req, tx)) in valid.iter().enumerate() {
                let output = y.data[i * out_dim..(i + 1) * out_dim]
                    .iter()
                    .map(|&q| q as f64 * y.scale)
                    .collect();
                respond(metrics, req.id, req.submitted, tx, Ok(output));
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            for (req, tx) in &valid {
                respond(metrics, req.id, req.submitted, tx, Err(msg.clone()));
            }
        }
    }
}

/// Rank-2/3 assembly: each request runs its own forward pass, so
/// im2col stays single-image, attention's data-dependent `ctx_scale`
/// requantization never mixes requests, and one request's failure
/// cannot take its batch-mates down. The batch is consumed so each
/// payload *moves* into its forward pass — no per-request copy.
fn serve_per_item(
    model: &Model,
    sched: &mut Scheduler,
    batch: Batch<(Request, mpsc::Sender<Response>)>,
    metrics: &mut Metrics,
) {
    for (req, tx) in batch.items {
        let (id, submitted) = (req.id, req.submitted);
        let run = match validate_input(model, &req) {
            Ok(()) => run_one(model, sched, req.input),
            Err(e) => Err(e),
        };
        respond(metrics, id, submitted, &tx, run.map_err(|e| format!("{e:#}")));
    }
}

/// Execute a single validated shaped request (consumes the payload).
fn run_one(model: &Model, sched: &mut Scheduler, input: TensorInput) -> Result<Vec<f64>> {
    let x = QTensor::new(input.data, input.shape, model.input_scale, model.input_bits)?;
    let y = model.forward(&x, sched)?;
    Ok(y.data.iter().map(|&q| q as f64 * y.scale).collect())
}

/// Convenience: run a closed set of requests through a fresh server and
/// gather everything (used by examples/benches). Accepts anything that
/// converts into a [`TensorInput`] — plain `Vec<i32>` rows for vector
/// models, shaped payloads for images / token matrices.
pub fn serve_all<I: Into<TensorInput>>(
    model: Arc<Model>,
    cfg: ServerConfig,
    inputs: Vec<I>,
) -> Result<(Vec<Response>, ExecutionReport, Metrics)> {
    let server = InferenceServer::start(model, cfg)?;
    let rxs: Vec<_> = inputs
        .into_iter()
        .enumerate()
        .map(|(i, input)| {
            server.submit(Request {
                id: i as u64,
                input: input.into(),
                submitted: Instant::now(),
            })
        })
        .collect();
    let mut responses = Vec::with_capacity(rxs.len());
    for rx in rxs {
        responses.push(rx.recv()?);
    }
    let (report, metrics) = server.shutdown();
    responses.sort_by_key(|r| r.id);
    Ok((responses, report, metrics))
}

/// Shared-state guard used by tests to assert worker counts; kept
/// small and public for the harness.
pub type SharedReport = Arc<Mutex<ExecutionReport>>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg32;
    use crate::sim::mac_common::MacVariant;

    fn inputs(n: usize, d: usize, bits: u32) -> Vec<Vec<i32>> {
        let mut rng = Pcg32::new(0xf00d);
        let lo = crate::bits::twos::min_value(bits);
        let hi = crate::bits::twos::max_value(bits);
        (0..n)
            .map(|_| (0..d).map(|_| rng.range_i32(lo, hi)).collect())
            .collect()
    }

    #[test]
    fn serves_all_requests_in_order() {
        let model = Arc::new(crate::nn::model::mlp_zoo(5));
        let cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        let (resp, report, metrics) = serve_all(model, cfg, inputs(20, 64, 8)).unwrap();
        assert_eq!(resp.len(), 20);
        for (i, r) in resp.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.output.as_ref().unwrap().len(), 10);
        }
        assert_eq!(metrics.requests, 20);
        assert_eq!(metrics.errors, 0);
        assert!(report.macs > 0 && report.hw_cycles > 0);
        assert!(metrics.mean_batch() >= 1.0);
    }

    #[test]
    fn batching_reduces_matmul_count() {
        let model = Arc::new(crate::nn::model::mlp_zoo(5));
        let mut cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        cfg.workers = 1;
        cfg.batcher = BatcherConfig {
            max_batch: 16,
            linger: std::time::Duration::from_millis(20),
        };
        let (_, report, metrics) = serve_all(model, cfg, inputs(16, 64, 8)).unwrap();
        // ideally one batch of 16 → 3 matmuls; allow some fragmentation
        assert!(report.matmuls <= 3 * 4, "matmuls = {}", report.matmuls);
        assert!(metrics.mean_batch() > 1.0);
    }

    #[test]
    fn deterministic_results_across_backends() {
        let model = Arc::new(crate::nn::model::mlp_zoo(5));
        let ins = inputs(4, 64, 8);
        let cfg_n = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        let mut cfg_s = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Simulate);
        cfg_s.workers = 1;
        let cfg_p = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Packed);
        let (r1, _, _) = serve_all(model.clone(), cfg_n, ins.clone()).unwrap();
        let (r2, _, _) = serve_all(model.clone(), cfg_s, ins.clone()).unwrap();
        let (r3, rep_p, _) = serve_all(model, cfg_p, ins).unwrap();
        for ((a, b), c) in r1.iter().zip(&r2).zip(&r3) {
            assert_eq!(a.output, b.output, "native vs simulate diverged");
            assert_eq!(a.output, c.output, "native vs packed diverged");
        }
        assert!(rep_p.packed_execs > 0, "packed backend actually ran");
    }

    #[test]
    fn packed_thread_and_kernel_config_do_not_change_results() {
        let model = Arc::new(crate::nn::model::mlp_zoo(5));
        let ins = inputs(12, 64, 8);
        let cfg_n = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        let (want, _, _) = serve_all(model.clone(), cfg_n, ins.clone()).unwrap();
        for (threads, kernel) in [
            (1usize, PopcountKernel::Scalar),
            (3, PopcountKernel::Unroll4),
            (4, PopcountKernel::Auto),
        ] {
            let mut cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Packed);
            cfg.packed_threads = threads;
            cfg.packed_unroll = kernel;
            let (got, report, _) = serve_all(model.clone(), cfg, ins.clone()).unwrap();
            for (a, b) in want.iter().zip(&got) {
                assert_eq!(a.output, b.output, "t{threads} {} diverged", kernel.name());
            }
            assert!(report.packed_execs > 0);
        }
    }

    #[test]
    fn packed_tile_knobs_do_not_change_results_and_surface_telemetry() {
        let model = Arc::new(crate::nn::model::mlp_zoo(5));
        let ins = inputs(12, 64, 8);
        let cfg_n = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        let (want, _, _) = serve_all(model.clone(), cfg_n, ins.clone()).unwrap();
        for (rows, cols) in [(0usize, 0usize), (1, 0), (0, 4), (2, 8)] {
            let mut cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Packed);
            cfg.packed_threads = 3;
            cfg.packed_tile_rows = rows;
            cfg.packed_tile_cols = cols;
            let (got, report, metrics) = serve_all(model.clone(), cfg, ins.clone()).unwrap();
            for (a, b) in want.iter().zip(&got) {
                assert_eq!(a.output, b.output, "tiles {rows}x{cols} diverged");
            }
            assert!(report.packed_execs > 0);
            // pooled runs happened, so tiling telemetry is populated
            // and mirrored into the serving metrics
            assert!(report.steal.tiles >= 1, "tiles {rows}x{cols}");
            assert_eq!(metrics.steal, report.steal);
            assert!(
                report.steal.max_worker_tiles >= report.steal.min_worker_tiles,
                "tiles {rows}x{cols}"
            );
        }
    }

    #[test]
    fn shaped_requests_serve_image_and_token_models() {
        for (name, model) in [
            ("cnn", crate::nn::model::cnn_zoo(2)),
            ("attn", crate::nn::model::attention_zoo(3)),
        ] {
            let model = Arc::new(model);
            let ins = shaped_inputs(&model, 4, 0xbeef);
            let cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
            let (resp, report, metrics) = serve_all(model.clone(), cfg, ins.clone()).unwrap();
            assert_eq!(resp.len(), 4, "{name}");
            assert_eq!(metrics.requests, 4, "{name}");
            assert_eq!(metrics.errors, 0, "{name}");
            // the serving-path MACs equal the static census for the
            // same request count (per-item batch accounting)
            assert_eq!(report.macs, model.stats(4).macs, "{name}");
            // responses match a direct forward of the same payload
            let mut direct = Scheduler::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
            for (i, r) in resp.iter().enumerate() {
                let x = QTensor::new(
                    ins[i].data.clone(),
                    ins[i].shape.clone(),
                    model.input_scale,
                    model.input_bits,
                )
                .unwrap();
                let y = model.forward(&x, &mut direct).unwrap();
                let want: Vec<f64> = y.data.iter().map(|&q| q as f64 * y.scale).collect();
                assert_eq!(r.output, Ok(want), "{name} request {i}");
            }
        }
    }

    #[test]
    fn invalid_requests_surface_their_cause() {
        let model = Arc::new(crate::nn::model::mlp_zoo(5));
        let cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        let server = InferenceServer::start(model, cfg).unwrap();
        // wrong shape: a 32-vector against the 64-input model
        let rx = server.submit(Request {
            id: 0,
            input: vec![1i32; 32].into(),
            submitted: Instant::now(),
        });
        let r = rx.recv().unwrap();
        let err = r.output.unwrap_err();
        assert!(err.contains("shape"), "cause must name the shape: {err}");
        // out-of-range values against the 8-bit input contract
        let rx = server.submit(Request {
            id: 1,
            input: vec![300i32; 64].into(),
            submitted: Instant::now(),
        });
        let err = rx.recv().unwrap().output.unwrap_err();
        assert!(err.contains("8-bit"), "cause must name the range: {err}");
        let (_, metrics) = server.shutdown();
        assert_eq!((metrics.requests, metrics.errors), (0, 2));
    }

    #[test]
    fn failed_forward_surfaces_error_and_counts_executed_batch() {
        // passes validation but fails mid-forward: layers 1-2 run,
        // layer 3's weight dims mismatch the incoming activation
        let mut model = crate::nn::model::mlp_zoo(5);
        if let crate::nn::Layer::Linear(l) = &mut model.layers[2] {
            l.w = QTensor::zeros(vec![7, 3], 1.0, 4);
        }
        let model = Arc::new(model);
        let cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        let (resp, _, metrics) = serve_all(model, cfg, inputs(3, 64, 8)).unwrap();
        for r in &resp {
            let err = r.output.as_ref().unwrap_err();
            assert!(err.contains("linear dims"), "cause must reach the caller: {err}");
        }
        assert_eq!((metrics.requests, metrics.errors), (0, 3));
        assert!(metrics.macs > 0, "two layers executed before the failure");
        assert!(metrics.batches >= 1, "a batch that did matmul work is an executed batch");
    }

    #[test]
    fn tensor_shaped_models_reject_vector_servers_no_more() {
        // rank-2 and rank-3 input shapes start; rank-0 is rejected
        for model in [crate::nn::model::cnn_zoo(1), crate::nn::model::attention_zoo(1)] {
            let cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
            let server = InferenceServer::start(Arc::new(model), cfg).unwrap();
            server.shutdown();
        }
        let mut degenerate = crate::nn::model::mlp_zoo(1);
        degenerate.input_shape = vec![];
        let cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Native);
        assert!(InferenceServer::start(Arc::new(degenerate), cfg).is_err());
    }

    #[test]
    fn packed_threads_auto_resolution() {
        let mut cfg = ServerConfig::new(SaConfig::new(4, 16, MacVariant::Booth), Backend::Packed);
        cfg.workers = 1_000_000; // more workers than cores: still >= 1
        assert_eq!(cfg.resolved_packed_threads(), 1);
        cfg.packed_threads = 7; // explicit setting wins over auto
        assert_eq!(cfg.resolved_packed_threads(), 7);
    }
}
