//! Matmul tiling onto the systolic array.
//!
//! The SA computes output tiles of at most `rows × cols` elements per
//! pass (one MAC per output element, output-stationary). The contracted
//! dimension K is unbounded — eq. 8 scales linearly in `n_values` — so
//! only M and N are tiled. Edge tiles are smaller (unused rows/columns
//! idle, exactly as in the hardware where their enables stay low).

use crate::arch::throughput::bitsmm_cycles;
use crate::sim::array::SaConfig;

/// One SA pass: computes `C[row0.., col0..][..m, ..n]`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileJob {
    pub row0: usize,
    pub col0: usize,
    pub m: usize,
    pub k: usize,
    pub n: usize,
}

impl TileJob {
    /// Architectural cycles for this pass: compute (eq. 8) + systolic
    /// fill + readout (`rows·cols`, §III-B).
    pub fn cycles(&self, sa: &SaConfig, bits: u32) -> u64 {
        let fill = (sa.rows + sa.cols - 2) as u64;
        bitsmm_cycles(self.k as u64, bits) + fill + (sa.rows * sa.cols) as u64
    }

    /// MAC operations this pass performs.
    pub fn macs(&self) -> u64 {
        (self.m * self.k * self.n) as u64
    }
}

/// A full matmul decomposed into SA passes.
#[derive(Debug, Clone)]
pub struct TilePlan {
    pub jobs: Vec<TileJob>,
    pub m: usize,
    pub k: usize,
    pub n: usize,
}

impl TilePlan {
    /// Total architectural cycles (sequential passes on one SA).
    pub fn total_cycles(&self, sa: &SaConfig, bits: u32) -> u64 {
        self.jobs.iter().map(|j| j.cycles(sa, bits)).sum()
    }

    pub fn total_macs(&self) -> u64 {
        self.jobs.iter().map(|j| j.macs()).sum()
    }

    /// Achieved OP/cycle of the plan (paper convention, 1 OP = 1 MAC).
    pub fn ops_per_cycle(&self, sa: &SaConfig, bits: u32) -> f64 {
        self.total_macs() as f64 / self.total_cycles(sa, bits) as f64
    }
}

/// Decompose `M×K×N` into row-major SA tiles.
pub fn tile_matmul(m: usize, k: usize, n: usize, sa: &SaConfig) -> TilePlan {
    let mut jobs = Vec::new();
    let mut row0 = 0;
    while row0 < m {
        let tm = (m - row0).min(sa.rows);
        let mut col0 = 0;
        while col0 < n {
            let tn = (n - col0).min(sa.cols);
            jobs.push(TileJob {
                row0,
                col0,
                m: tm,
                k,
                n: tn,
            });
            col0 += tn;
        }
        row0 += tm;
    }
    TilePlan { jobs, m, k, n }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::mac_common::MacVariant;

    fn sa() -> SaConfig {
        SaConfig::new(4, 16, MacVariant::Booth)
    }

    #[test]
    fn exact_fit_single_tile() {
        let plan = tile_matmul(4, 100, 16, &sa());
        assert_eq!(plan.jobs.len(), 1);
        assert_eq!(plan.jobs[0], TileJob { row0: 0, col0: 0, m: 4, k: 100, n: 16 });
    }

    #[test]
    fn larger_matrix_tiles_cover_everything() {
        let (m, k, n) = (10, 7, 40);
        let plan = tile_matmul(m, k, n, &sa());
        // coverage: every output element in exactly one tile
        let mut cover = vec![0u8; m * n];
        for j in &plan.jobs {
            for r in j.row0..j.row0 + j.m {
                for c in j.col0..j.col0 + j.n {
                    cover[r * n + c] += 1;
                }
            }
        }
        assert!(cover.iter().all(|&x| x == 1));
        assert_eq!(plan.total_macs(), (m * k * n) as u64);
    }

    #[test]
    fn edge_tiles_are_cropped() {
        let plan = tile_matmul(5, 3, 17, &sa());
        // rows: 4 + 1; cols: 16 + 1 → 4 tiles
        assert_eq!(plan.jobs.len(), 4);
        let last = plan.jobs.last().unwrap();
        assert_eq!((last.m, last.n), (1, 1));
    }

    #[test]
    fn cycles_match_eq8_plus_readout() {
        let plan = tile_matmul(4, 64, 16, &sa());
        let bits = 8;
        let want = (64 + 1) * 8 + (4 + 16 - 2) + 64;
        assert_eq!(plan.total_cycles(&sa(), bits), want as u64);
    }

    #[test]
    fn ops_per_cycle_below_peak() {
        let cfg = sa();
        let plan = tile_matmul(4, 10_000, 16, &cfg);
        let achieved = plan.ops_per_cycle(&cfg, 16);
        let peak = crate::arch::throughput::peak_op_per_cycle(16, 4, 16);
        assert!(achieved <= peak);
        assert!(achieved / peak > 0.98, "long-K should approach peak");
    }
}
