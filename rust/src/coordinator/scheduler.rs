//! Matmul scheduling and functional–timing co-simulation.
//!
//! Every layer matmul is tiled onto the configured SA and accounted on
//! the *hardware* timing model (eq. 8 + systolic fill + readout per
//! tile). Functionally the integers can be produced by any of four
//! bit-identical backends:
//!
//! * [`Backend::Pjrt`] — the AOT-compiled HLO executable (the L1/L2
//!   Pallas/JAX path) through the PJRT engine thread; shapes without a
//!   registered artifact fall back to the native path. f32 artifacts
//!   are exact for ≤ 8-bit operands (every intermediate is an integer
//!   < 2²⁴); wider operands are routed natively.
//! * [`Backend::Native`] — the Rust Booth-plane matmul.
//! * [`Backend::Packed`] — the word-packed plane engine
//!   ([`crate::bits::packed`]): AND+popcount per plane pair through a
//!   configurable unrolled/AVX2 reducer ([`PopcountKernel`]), the
//!   streamed operand packed once per matmul, the stationary operand
//!   taken pre-packed from the layer's [`crate::nn::PackedCache`] when
//!   the call arrives through [`crate::nn::MatmulExec`] (planes cached
//!   at a wider precision are *sliced*, never re-packed). When the
//!   scheduler is handed a shared [`PackedPool`], the kernel is
//!   decomposed into work-stolen 2-D output tiles (sized by the
//!   scheduler's [`TilePolicy`], auto by default) on the pool's
//!   persistent workers (DESIGN.md §Packed-Threading) — bit-identical
//!   to the single-thread path, with steal/imbalance telemetry folded
//!   into the report. With a [`Planner`] attached, reducer / threads /
//!   partition / tiles — and the native-vs-packed crossover itself —
//!   are resolved **per (shape, precision)** through the shared plan
//!   cache instead of the static config (DESIGN.md §Planner); plans
//!   change speed, never integers.
//! * [`Backend::Simulate`] — the cycle-accurate SA through the
//!   instruction-driven device backend ([`crate::device`]): operands
//!   are packed once into [`PackedPlanes`], the tile plan is compiled
//!   to `Fetch`/`Execute`/`Writeback`/`Sync` instructions, and the
//!   double-buffered driver streams plane words into the array over
//!   the `SimIf` transport — slowest, but *measures* cycles instead of
//!   modelling them, and reports per-stage fetch/execute overlap in
//!   [`ExecutionReport::device`]. Operands wider than the declared
//!   precision widen to their true width (≤ 16 bits); beyond that the
//!   native loop serves, exactly like the packed fallback.

use crate::bits::packed::{
    KernelFamily, PackedPlanes, PackedPool, PopcountKernel, StealStats, TilePolicy,
};
use crate::bits::plane::PlaneKind;
use crate::coordinator::faults::{FaultStats, ScrubStats, SeuInjector};
use crate::coordinator::tiler::{tile_matmul, TilePlan};
use crate::device::DeviceStats;
use crate::nn::layers::{MatmulExec, PackedWeight, Quarantined, RepairSource};
use crate::nn::matmul_native;
use crate::obs::trace::{SpanKind, TraceRing};
use crate::plan::{ExecPlan, PlanKey, PlanStats, PlanTier, Planner, ShapeRun};
use crate::runtime::{EngineHandle, IntMat};
use crate::sim::array::{SaConfig, SystolicArray};
use crate::Result;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

/// Functional execution backend.
#[derive(Clone)]
pub enum Backend {
    Native,
    Simulate,
    Pjrt(EngineHandle),
    Packed,
}

impl Backend {
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Native => "native",
            Backend::Simulate => "simulate",
            Backend::Pjrt(_) => "pjrt",
            Backend::Packed => "packed",
        }
    }
}

/// Cycle/operation accounting of one scheduler's lifetime.
#[derive(Debug, Clone, Default)]
pub struct ExecutionReport {
    pub matmuls: u64,
    pub tiles: u64,
    pub macs: u64,
    /// Architectural cycles (modelled for Native/Pjrt, measured for
    /// Simulate).
    pub hw_cycles: u64,
    pub pjrt_hits: u64,
    pub native_fallbacks: u64,
    pub sim_passes: u64,
    /// Matmuls executed by the packed plane engine.
    pub packed_execs: u64,
    /// Cached weight planes reused at a lower precision via a
    /// plane-subset slice (no re-pack).
    pub plane_slices: u64,
    /// Work-stealing telemetry of the pooled packed kernel: tile jobs,
    /// steals, and the max/min per-worker tile share (DESIGN.md
    /// §Packed-Threading).
    pub steal: StealStats,
    /// Plan-cache telemetry of the execution planner: exact hits,
    /// below-tier-1 misses, and on-line calibrations (zero unless a
    /// planner is attached — DESIGN.md §Planner).
    pub plan: PlanStats,
    /// Corruption-fault telemetry: SEU injections on packed-path
    /// outputs and whether the ABFT row-checksum guard masked them
    /// (zero unless an injector is armed — DESIGN.md §Resilience).
    pub faults: FaultStats,
    /// Resident-state integrity telemetry from the on-ABFT-miss
    /// escalation ladder: corrupt stationary planes detected, repaired
    /// by re-pack, or quarantined (DESIGN.md §Integrity). The
    /// background scrubber's sweeps land in the same counters at the
    /// server level.
    pub scrub: ScrubStats,
    /// Per-stage device telemetry of the instruction-driven simulate
    /// backend: fetch/execute/writeback cycles, the fetch cycles hidden
    /// under compute by double buffering, and the exposed stalls (zero
    /// unless `Backend::Simulate` ran — DESIGN.md §Device).
    pub device: DeviceStats,
}

impl ExecutionReport {
    pub fn merge(&mut self, o: &ExecutionReport) {
        self.matmuls += o.matmuls;
        self.tiles += o.tiles;
        self.macs += o.macs;
        self.hw_cycles += o.hw_cycles;
        self.pjrt_hits += o.pjrt_hits;
        self.native_fallbacks += o.native_fallbacks;
        self.sim_passes += o.sim_passes;
        self.packed_execs += o.packed_execs;
        self.plane_slices += o.plane_slices;
        self.steal.merge(&o.steal);
        self.plan.merge(&o.plan);
        self.faults.merge(&o.faults);
        self.scrub.merge(&o.scrub);
        self.device.merge(&o.device);
    }

    /// Simulated-hardware GOPS at a clock (paper convention).
    pub fn hw_gops(&self, clock_hz: f64) -> f64 {
        if self.hw_cycles == 0 {
            return 0.0;
        }
        self.macs as f64 / self.hw_cycles as f64 * clock_hz / 1e9
    }
}

/// One worker's scheduler: owns (or talks to) its backends and keeps
/// its own report; workers merge reports at the end of a run.
pub struct Scheduler {
    pub sa: SaConfig,
    backend: Backend,
    /// Long-lived simulated array (Simulate backend only).
    sim: Option<SystolicArray>,
    /// Shared packed-kernel worker pool (`None` = single-thread
    /// kernel). The server hands every worker's scheduler the *same*
    /// pool, so kernel threads compose with — rather than multiply
    /// against — request-level workers.
    packed_pool: Option<Arc<PackedPool>>,
    /// Popcount reducer for the packed kernel.
    popcount: PopcountKernel,
    /// Tile granularity for the pooled packed kernel (auto by default).
    tile_policy: TilePolicy,
    /// Plane-pair kernel family for the packed backend when no planner
    /// decides per class (`server.packed_rsr` / `--packed-rsr`).
    family: KernelFamily,
    /// Shape-keyed execution planner (`None` / `Off` = the static
    /// `popcount` + `tile_policy` config runs every matmul, the
    /// pre-planner behavior). Shared `Arc` across a server's workers
    /// so every scheduler resolves from one plan cache.
    planner: Option<Arc<Planner>>,
    /// Armed SEU injector (chaos testing): flips one bit of one packed
    /// output accumulator per armed charge. `None` in production.
    seu: Option<Arc<SeuInjector>>,
    /// Verify outputs against the exact ABFT row checksum and recover
    /// on mismatch (masks SEU-style corruption): packed misses climb
    /// the integrity ladder (verify planes → repair + retry → native
    /// recompute); native/simulate misses recompute natively.
    abft: bool,
    /// Per-shape ABFT-miss streak: a shape whose *consecutive*
    /// executions fail the checksum is a persistent fault (stuck-at
    /// state), not an independent transient — the classification the
    /// split `masked_transient`/`masked_persistent` ledger reports.
    abft_streak: HashMap<(usize, usize, usize, u32), bool>,
    /// Request-trace ring (DESIGN.md §Observability): when attached,
    /// plan resolution, pack/slice, kernel execution, ABFT
    /// verify/repair, and the device stage ledger record spans against
    /// the current trace context. `None` = tracing off, and every hook
    /// is a single branch on this Option.
    tracer: Option<Arc<TraceRing>>,
    /// Trace ID spans are attributed to — the worker sets it per batch
    /// to the batch's lead request (0 = untraced context).
    trace_ctx: u64,
    pub report: ExecutionReport,
}

impl Scheduler {
    pub fn new(sa: SaConfig, backend: Backend) -> Scheduler {
        let sim = match backend {
            Backend::Simulate => Some(SystolicArray::new(sa)),
            _ => None,
        };
        Scheduler {
            sa,
            backend,
            sim,
            packed_pool: None,
            popcount: PopcountKernel::Auto,
            tile_policy: TilePolicy::AUTO,
            family: KernelFamily::Popcount,
            planner: None,
            seu: None,
            abft: false,
            abft_streak: HashMap::new(),
            tracer: None,
            trace_ctx: 0,
            report: ExecutionReport::default(),
        }
    }

    /// Attach the request-trace ring: scheduler-level stages (plan
    /// resolution, pack/slice, kernel, ABFT, device) then record spans
    /// against the trace context set by [`Scheduler::set_trace_ctx`].
    pub fn set_tracer(&mut self, ring: Arc<TraceRing>) {
        self.tracer = Some(ring);
    }

    /// Set the trace ID scheduler spans are attributed to (the worker
    /// calls this per batch with the batch's lead request).
    pub fn set_trace_ctx(&mut self, trace: u64) {
        self.trace_ctx = trace;
    }

    /// `Some(now)` when tracing is on — stage timestamps cost nothing
    /// when the ring is absent.
    #[inline]
    fn stamp(&self) -> Option<Instant> {
        self.tracer.as_ref().map(|_| Instant::now())
    }

    #[inline]
    fn span_since(&self, kind: SpanKind, start: Option<Instant>, detail: u64) {
        if let (Some(ring), Some(t0)) = (&self.tracer, start) {
            ring.span(self.trace_ctx, kind, t0, t0.elapsed(), detail);
        }
    }

    /// Attach a shared work-stealing worker pool for the packed kernel.
    pub fn set_packed_pool(&mut self, pool: Arc<PackedPool>) {
        self.packed_pool = Some(pool);
    }

    /// Select the popcount reducer for the packed kernel (defaults to
    /// [`PopcountKernel::Auto`]: AVX2/NEON when the CPU has one).
    pub fn set_popcount_kernel(&mut self, kernel: PopcountKernel) {
        self.popcount = kernel;
    }

    /// Set the pooled packed kernel's 2-D tile granularity
    /// (`server.packed_tile_rows` / `packed_tile_cols`; 0 = auto).
    pub fn set_tile_policy(&mut self, policy: TilePolicy) {
        self.tile_policy = policy;
    }

    /// Select the packed backend's plane-pair kernel family for the
    /// static (planner-off) path — [`KernelFamily::Rsr`] routes every
    /// packed matmul through the segment-reuse kernel
    /// (`server.packed_rsr` / `--packed-rsr`; bit-identical, see
    /// DESIGN.md §Sub-popcount-Kernels).
    pub fn set_kernel_family(&mut self, family: KernelFamily) {
        self.family = family;
    }

    /// Attach the shared execution planner: the packed backend then
    /// resolves kernel/threads/partition/tiles (and the native-vs-
    /// packed crossover) per (shape, precision) through the plan cache
    /// instead of the static config (DESIGN.md §Planner).
    pub fn set_planner(&mut self, planner: Arc<Planner>) {
        self.planner = Some(planner);
    }

    /// Attach a deterministic SEU injector (chaos testing): each armed
    /// charge flips one PRNG-chosen bit of one packed-path output
    /// accumulator, modelling a single-event upset in accumulator
    /// SRAM at the exact point the paper's TMR argument targets.
    pub fn set_seu_injector(&mut self, seu: Arc<SeuInjector>) {
        self.seu = Some(seu);
    }

    /// Enable the ABFT row-checksum guard on packed-path outputs.
    pub fn set_abft(&mut self, on: bool) {
        self.abft = on;
    }

    /// Execute `A (m×k) · B (k×n)` at `bits` precision. Returns exact
    /// i64 accumulators; updates the report.
    pub fn matmul(
        &mut self,
        a: &[i32],
        b: &[i32],
        m: usize,
        k: usize,
        n: usize,
        bits: u32,
    ) -> Result<Vec<i64>> {
        self.matmul_with(a, b, m, k, n, bits, None, None)
    }

    /// [`Scheduler::matmul`] with an optional pre-packed stationary
    /// operand (the packed backend skips re-packing it; other backends
    /// ignore it) and its repair source (the integrity ladder re-packs
    /// corrupt resident planes from it on an ABFT miss).
    #[allow(clippy::too_many_arguments)]
    fn matmul_with(
        &mut self,
        a: &[i32],
        b: &[i32],
        m: usize,
        k: usize,
        n: usize,
        bits: u32,
        packed_b: Option<Arc<PackedPlanes>>,
        repair: Option<RepairSource<'_>>,
    ) -> Result<Vec<i64>> {
        crate::validate_bits(bits)?;
        let plan = tile_matmul(m, k, n, &self.sa);
        self.report.matmuls += 1;
        self.report.tiles += plan.jobs.len() as u64;
        self.report.macs += plan.total_macs();

        let out = match &self.backend {
            Backend::Native => {
                self.report.hw_cycles += plan.total_cycles(&self.sa, bits);
                self.report.native_fallbacks += 1;
                let mut out = matmul_native(a, b, m, k, n, bits)?;
                // the guard wraps every functional backend, not just
                // packed: a flip in the native path's accumulators is
                // just as real (one recompute masks it)
                if self.abft && !abft_row_check(a, b, &out, m, k, n) {
                    out = matmul_native(a, b, m, k, n, bits)?;
                    anyhow::ensure!(
                        abft_row_check(a, b, &out, m, k, n),
                        "matmul corruption persisted across the native recompute"
                    );
                    self.report.faults.masked_transient += 1;
                }
                out
            }
            Backend::Pjrt(engine) => {
                self.report.hw_cycles += plan.total_cycles(&self.sa, bits);
                // f32 artifact exactness holds through 8-bit operands
                let attempt = if bits <= 8 {
                    engine.execute_matmul(
                        IntMat::new(a.to_vec(), m, k)?,
                        IntMat::new(b.to_vec(), k, n)?,
                        bits,
                        self.sa.variant,
                    )?
                } else {
                    None
                };
                match attempt {
                    Some(f) => {
                        self.report.pjrt_hits += 1;
                        f.into_iter().map(|v| v.round() as i64).collect()
                    }
                    None => {
                        self.report.native_fallbacks += 1;
                        matmul_native(a, b, m, k, n, bits)?
                    }
                }
            }
            Backend::Packed => {
                self.report.hw_cycles += plan.total_cycles(&self.sa, bits);
                // Plane decomposition needs operands inside the
                // declared width; layers with looser precision
                // contracts (conv/attention inputs are not
                // range-checked) fall back to the native loop so the
                // packed backend never errs where Native succeeds.
                let lo = crate::bits::twos::min_value(bits);
                let hi = crate::bits::twos::max_value(bits);
                let in_range = |s: &[i32]| s.iter().all(|v| (lo..=hi).contains(v));
                if !in_range(a) || (packed_b.is_none() && !in_range(b)) {
                    self.report.native_fallbacks += 1;
                    return matmul_native(a, b, m, k, n, bits);
                }
                // the stationary operand arrives pre-packed from the
                // layer cache (or is packed inside the run for ad-hoc
                // calls). Planes cached at a *wider* precision are
                // sliced down — cross-precision reuse, never a re-pack.
                let t_slice = self.stamp();
                let pb: Option<Arc<PackedPlanes>> = match packed_b {
                    Some(p) => {
                        anyhow::ensure!(
                            p.len == k && p.vectors == n,
                            "cached planes ({}x{}) do not match the request ({k}x{n})",
                            p.len,
                            p.vectors
                        );
                        if p.bits == bits {
                            Some(p)
                        } else if p.bits > bits && p.min_bits <= bits {
                            self.report.plane_slices += 1;
                            Some(Arc::new(p.slice_bits(bits)?))
                        } else if p.bits < bits {
                            anyhow::bail!(
                                "cached planes @{}b cannot serve a {bits}-bit request (packs only narrow)",
                                p.bits
                            );
                        } else {
                            anyhow::bail!(
                                "cached planes @{}b hold values needing {}b — a {bits}-bit slice would truncate them",
                                p.bits,
                                p.min_bits
                            );
                        }
                    }
                    None => None,
                };
                self.span_since(SpanKind::PackSlice, t_slice, bits as u64);
                // the hardware tiling above is *timing* accounting; the
                // functional product runs through the one shared plan
                // executor: either the plan the shape-keyed planner
                // resolves for this (shape, precision) class, or the
                // static server-wide config when no planner is attached
                // (DESIGN.md §Planner)
                let pool = self.packed_pool.clone();
                let pool_slots = pool.as_ref().map_or(1, |p| p.threads() + 1);
                let run = ShapeRun {
                    a,
                    b,
                    m,
                    k,
                    n,
                    bits,
                    stream_kind: PlaneKind::Sbmwc,
                    packed_b: pb.as_ref(),
                    pool: pool.as_ref(),
                };
                let planner = self.planner.clone().filter(|p| p.is_on());
                let t_plan = self.stamp();
                let (plan, tier, pre_run) = match &planner {
                    Some(pl) => {
                        let kind = pb.as_ref().map_or(PlaneKind::Sbmwc, |p| p.kind);
                        let key = PlanKey::for_matmul(m, k, n, bits, bits, kind);
                        let (plan, tier, pre) = pl.plan_run(key, &run)?;
                        (plan, Some(tier), pre)
                    }
                    None => {
                        let mut plan =
                            ExecPlan::static_default(self.popcount, self.tile_policy, pool_slots);
                        if let KernelFamily::Rsr { seg_words } = self.family {
                            plan = plan.rsr(seg_words);
                        }
                        (plan, None, None)
                    }
                };
                self.span_since(SpanKind::PlanResolve, t_plan, u64::from(tier.is_some()));
                match tier {
                    Some(PlanTier::Exact) => self.report.plan.hits += 1,
                    Some(PlanTier::Nearest) | Some(PlanTier::CostModel) => {
                        self.report.plan.misses += 1
                    }
                    Some(PlanTier::Calibrated) => {
                        self.report.plan.misses += 1;
                        self.report.plan.calibrations += 1;
                    }
                    None => {}
                }
                let t_kernel = self.stamp();
                let (out, stats, ran_packed) = match pre_run {
                    Some(r) => r, // calibration already produced the product
                    None => run.run(&plan)?,
                };
                self.span_since(SpanKind::Kernel, t_kernel, stats.steals);
                if ran_packed {
                    self.report.packed_execs += 1;
                    self.report.steal.merge(&stats);
                } else {
                    // the planner chose the native loop for this class
                    self.report.native_fallbacks += 1;
                }
                let mut out = out;
                // SEU injection hook: an armed charge lands here, on
                // the output accumulators, exactly where a radiation
                // bit-flip in accumulator SRAM would surface
                let flipped = self.seu.as_ref().map_or(false, |inj| inj.maybe_flip(&mut out));
                if flipped {
                    self.report.faults.injected += 1;
                }
                if self.abft {
                    // ABFT row-checksum guard, exact in i64:
                    // `sum_j C[i,j] == dot(A[i,:], colsum(B))` per row.
                    // Any single-bit flip shifts one row sum by ±2^b,
                    // so upsets are always caught, at O(mk+kn+mn)
                    // checksum cost against the O(mkn) product.
                    let shape = (m, k, n, bits);
                    let t_verify = self.stamp();
                    let clean = abft_row_check(a, b, &out, m, k, n);
                    self.span_since(SpanKind::AbftVerify, t_verify, u64::from(!clean));
                    if !clean {
                        let t_repair = self.stamp();
                        // Escalation ladder (DESIGN.md §Integrity).
                        // Rung 1: verify the stationary planes — a
                        // corrupt resident pack is a *persistent*
                        // fault that would fail every later exec of
                        // this weight, so repair it at the source.
                        let planes_corrupt =
                            pb.as_ref().map_or(false, |p| !p.verify());
                        let mut retried: Option<Vec<i64>> = None;
                        if planes_corrupt {
                            self.report.scrub.detected += 1;
                            match repair {
                                // Rung 2: golden-verified dense source
                                // → evict + re-pack, retry packed once
                                Some(r) if r.w.verify_golden() => {
                                    let fix = r.cache.scrub(r.slot, r.w);
                                    self.report.scrub.repaired += fix.repaired;
                                    self.report.scrub.quarantined += fix.quarantined;
                                    if fix.repaired > 0 {
                                        let fresh = r.cache.get_or_pack(r.slot, r.w, bits)?;
                                        let rerun = ShapeRun {
                                            a,
                                            b,
                                            m,
                                            k,
                                            n,
                                            bits,
                                            stream_kind: PlaneKind::Sbmwc,
                                            packed_b: Some(&fresh),
                                            pool: pool.as_ref(),
                                        };
                                        let (again, _, ran) = rerun.run(&plan)?;
                                        if ran && abft_row_check(a, b, &again, m, k, n) {
                                            retried = Some(again);
                                        }
                                    }
                                }
                                // Unrepairable: planes corrupt AND the
                                // dense golden source corrupt — nothing
                                // trustworthy remains for this slot
                                Some(r) => {
                                    r.cache.quarantine(r.slot);
                                    self.report.scrub.quarantined += 1;
                                    return Err(anyhow::Error::new(Quarantined {
                                        slot: r.slot,
                                    }));
                                }
                                None => {}
                            }
                        }
                        match retried {
                            Some(again) => {
                                out = again;
                                self.report.faults.masked_persistent += 1;
                                self.abft_streak.remove(&shape);
                            }
                            None => {
                                // Rung 3 (prior behavior): recompute
                                // natively — bit-identical to fault-free
                                out = matmul_native(a, b, m, k, n, bits)?;
                                anyhow::ensure!(
                                    abft_row_check(a, b, &out, m, k, n),
                                    "matmul corruption persisted across the native recompute"
                                );
                                let persistent = planes_corrupt
                                    || self.abft_streak.get(&shape).copied().unwrap_or(false);
                                if persistent {
                                    self.report.faults.masked_persistent += 1;
                                } else {
                                    self.report.faults.masked_transient += 1;
                                }
                                self.abft_streak.insert(shape, true);
                            }
                        }
                        self.span_since(SpanKind::AbftRepair, t_repair, u64::from(planes_corrupt));
                    } else {
                        // clean exec breaks any miss streak: a later
                        // miss on this shape is an independent transient
                        self.abft_streak.remove(&shape);
                    }
                } else if flipped {
                    self.report.faults.unmasked += 1;
                }
                out
            }
            Backend::Simulate => {
                // the &mut array borrow below outlives the stage stamps,
                // so the ring handle is cloned out of self up front
                let tracer = self.tracer.clone();
                let ctx = self.trace_ctx;
                let sim = self.sim.as_mut().expect("simulate backend has an array");
                // Plane packing needs operands inside the declared
                // width; layers with looser precision contracts
                // (conv/attention inputs are not range-checked) widen
                // to their true width — the device streams whatever the
                // planes hold — and only beyond the hardware's 16-bit
                // ceiling does the native loop serve (mirroring the
                // packed backend's fallback).
                let eff = PackedPlanes::needed_bits(a)
                    .max(PackedPlanes::needed_bits(b))
                    .max(bits);
                if eff > crate::MAX_BITS {
                    self.report.hw_cycles += plan.total_cycles(&self.sa, bits);
                    self.report.native_fallbacks += 1;
                    return matmul_native(a, b, m, k, n, bits);
                }
                // pack once per matmul; every tile streams word slices
                // of the same packs over the SimIf transport
                let t_pack = tracer.as_ref().map(|_| Instant::now());
                let pa = PackedPlanes::pack_rows(a, m, k, eff, PlaneKind::Sbmwc)?;
                let pb = PackedPlanes::pack_cols(b, k, n, eff, PlaneKind::Sbmwc)?;
                if let (Some(ring), Some(t0)) = (&tracer, t_pack) {
                    ring.span(ctx, SpanKind::PackSlice, t0, t0.elapsed(), eff as u64);
                }
                let t_kernel = tracer.as_ref().map(|_| Instant::now());
                let run = crate::device::run_layer(sim, &plan, &self.sa, &pa, &pb, eff, None)?;
                if let (Some(ring), Some(t0)) = (&tracer, t_kernel) {
                    ring.span(ctx, SpanKind::Kernel, t0, t0.elapsed(), run.stats.tiles);
                    // per-stage device ledger as point events: the
                    // driver already measured these in cycles, so the
                    // cycle counts ride in `detail` rather than in span
                    // durations
                    ring.event(ctx, SpanKind::DeviceFetch, run.stats.fetch_cycles);
                    ring.event(ctx, SpanKind::DeviceExec, run.stats.exec_cycles);
                    ring.event(ctx, SpanKind::DeviceWriteback, run.stats.wb_cycles);
                }
                // array-busy cycles (compute + readout) land in the
                // shared hw_cycles ledger exactly as before the
                // streaming refactor; fetch/overlap/stall are the
                // device's own telemetry
                self.report.hw_cycles += run.stats.hw_cycles();
                self.report.sim_passes += run.stats.tiles;
                self.report.device.merge(&run.stats);
                let mut out = run.out;
                // the guard wraps the merged simulator output too: a
                // flip while stitching tiles is recomputed natively
                if self.abft && !abft_row_check(a, b, &out, m, k, n) {
                    out = matmul_native(a, b, m, k, n, bits)?;
                    anyhow::ensure!(
                        abft_row_check(a, b, &out, m, k, n),
                        "matmul corruption persisted across the native recompute"
                    );
                    self.report.faults.masked_transient += 1;
                }
                out
            }
        };
        Ok(out)
    }

    /// Timing-only accounting for a plan executed elsewhere.
    pub fn plan_for(&self, m: usize, k: usize, n: usize) -> TilePlan {
        tile_matmul(m, k, n, &self.sa)
    }

    /// Adapt this scheduler into a plain closure executor. Note the
    /// closure path never advertises packed support — pass `&mut
    /// Scheduler` itself (it implements [`MatmulExec`]) to let the
    /// packed backend reuse layer-cached weight planes.
    pub fn as_exec(&mut self) -> impl FnMut(&[i32], &[i32], usize, usize, usize, u32) -> Result<Vec<i64>> + '_ {
        move |a, b, m, k, n, bits| self.matmul(a, b, m, k, n, bits)
    }
}

/// Algorithm-based fault tolerance check: every output row's sum must
/// equal the dot product of the corresponding `A` row with `B`'s
/// column sums — exact in i64 for ≤16-bit operands at any servable
/// shape (|row dot| ≤ k·n·2³⁰ stays far below i64::MAX).
fn abft_row_check(a: &[i32], b: &[i32], out: &[i64], m: usize, k: usize, n: usize) -> bool {
    let mut bsum = vec![0i64; k];
    for (kk, s) in bsum.iter_mut().enumerate() {
        *s = b[kk * n..(kk + 1) * n].iter().map(|&v| v as i64).sum();
    }
    for i in 0..m {
        let want: i64 = a[i * k..(i + 1) * k]
            .iter()
            .zip(&bsum)
            .map(|(&av, &bs)| av as i64 * bs)
            .sum();
        let got: i64 = out[i * n..(i + 1) * n].iter().sum();
        if want != got {
            return false;
        }
    }
    true
}

impl MatmulExec for Scheduler {
    fn matmul(
        &mut self,
        a: &[i32],
        b: &[i32],
        m: usize,
        k: usize,
        n: usize,
        bits: u32,
    ) -> Result<Vec<i64>> {
        Scheduler::matmul(self, a, b, m, k, n, bits)
    }

    fn wants_packed(&self) -> bool {
        matches!(self.backend, Backend::Packed)
    }

    fn matmul_packed(
        &mut self,
        a: &[i32],
        w: &PackedWeight<'_>,
        m: usize,
        k: usize,
        n: usize,
        bits: u32,
    ) -> Result<Vec<i64>> {
        self.matmul_with(a, w.data, m, k, n, bits, w.planes.clone(), w.repair)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg32;
    use crate::sim::driver::ref_matmul_i64;
    use crate::sim::mac_common::MacVariant;

    fn rand_mat(rng: &mut Pcg32, len: usize, bits: u32) -> Vec<i32> {
        let lo = crate::bits::twos::min_value(bits);
        let hi = crate::bits::twos::max_value(bits);
        (0..len).map(|_| rng.range_i32(lo, hi)).collect()
    }

    #[test]
    fn native_and_simulate_agree_with_reference() {
        let sa = SaConfig::new(4, 16, MacVariant::Booth);
        let (m, k, n, bits) = (6, 9, 20, 5);
        let mut rng = Pcg32::new(0x5eed);
        let a = rand_mat(&mut rng, m * k, bits);
        let b = rand_mat(&mut rng, k * n, bits);
        let want = ref_matmul_i64(&a, &b, m, k, n);

        let mut nat = Scheduler::new(sa, Backend::Native);
        assert_eq!(nat.matmul(&a, &b, m, k, n, bits).unwrap(), want);

        let mut packed = Scheduler::new(sa, Backend::Packed);
        assert_eq!(packed.matmul(&a, &b, m, k, n, bits).unwrap(), want);
        // packed and native share the same modelled cycle accounting
        assert_eq!(packed.report.hw_cycles, nat.report.hw_cycles);
        assert_eq!(packed.report.packed_execs, 1);

        let mut sim = Scheduler::new(sa, Backend::Simulate);
        assert_eq!(sim.matmul(&a, &b, m, k, n, bits).unwrap(), want);
        // measured and modelled cycle counts agree to within the
        // per-tile fill/flush allowance
        let slack = sim.report.tiles * (sa.rows + sa.cols) as u64;
        let (hi, lo) = (
            sim.report.hw_cycles.max(nat.report.hw_cycles),
            sim.report.hw_cycles.min(nat.report.hw_cycles),
        );
        assert!(hi - lo <= slack, "sim {} vs model {}", sim.report.hw_cycles, nat.report.hw_cycles);
        assert_eq!(sim.report.sim_passes, sim.report.tiles);
    }

    #[test]
    fn simulate_backend_reports_device_telemetry() {
        let sa = SaConfig::new(4, 16, MacVariant::Booth);
        let (m, k, n, bits) = (6, 9, 20, 5); // 2 row bands × 2 col bands
        let mut rng = Pcg32::new(0xdec0);
        let a = rand_mat(&mut rng, m * k, bits);
        let b = rand_mat(&mut rng, k * n, bits);
        let mut s = Scheduler::new(sa, Backend::Simulate);
        assert_eq!(s.matmul(&a, &b, m, k, n, bits).unwrap(), ref_matmul_i64(&a, &b, m, k, n));
        let d = &s.report.device;
        assert_eq!(d.tiles, s.report.tiles);
        assert_eq!(d.instrs, d.tiles * 3 + 1);
        assert!(d.overlap_cycles > 0, "multi-tile fetch must hide under compute");
        assert_eq!(d.fetch_cycles, d.overlap_cycles + d.stall_cycles);
        // array-busy accounting is the shared hw_cycles ledger, exactly
        assert_eq!(d.hw_cycles(), s.report.hw_cycles);
    }

    #[test]
    fn simulate_widens_out_of_range_operands_like_packed_falls_back() {
        let sa = SaConfig::new(4, 16, MacVariant::Booth);
        let (m, k, n, bits) = (2usize, 5usize, 3usize, 4u32);
        // 100 does not fit in 4 bits: the device widens and still
        // matches Native (conv/attention layers rely on this)
        let a = vec![100i32; m * k];
        let b = vec![3i32; k * n];
        let mut nat = Scheduler::new(sa, Backend::Native);
        let want = nat.matmul(&a, &b, m, k, n, bits).unwrap();
        let mut s = Scheduler::new(sa, Backend::Simulate);
        assert_eq!(s.matmul(&a, &b, m, k, n, bits).unwrap(), want);
        assert_eq!(s.report.native_fallbacks, 0, "widening, not fallback");
        assert!(s.report.sim_passes > 0);
        // beyond the 16-bit hardware ceiling the native loop serves
        let wide = vec![100_000i32; m * k];
        let want = nat.matmul(&wide, &b, m, k, n, bits).unwrap();
        assert_eq!(s.matmul(&wide, &b, m, k, n, bits).unwrap(), want);
        assert_eq!(s.report.native_fallbacks, 1);
    }

    #[test]
    fn report_accounting() {
        let sa = SaConfig::new(4, 16, MacVariant::Booth);
        let mut s = Scheduler::new(sa, Backend::Native);
        s.matmul(&[1; 8 * 3], &[1; 3 * 20], 8, 3, 20, 4).unwrap();
        assert_eq!(s.report.matmuls, 1);
        assert_eq!(s.report.tiles, 4); // 2 row tiles × 2 col tiles
        assert_eq!(s.report.macs, (8 * 3 * 20) as u64);
        assert!(s.report.hw_cycles > 0);
    }

    #[test]
    fn model_forward_through_scheduler() {
        let sa = SaConfig::new(4, 16, MacVariant::Booth);
        let model = crate::nn::model::mlp_zoo(11);
        let x = crate::nn::tensor::QTensor::zeros(vec![2, 64], 0.05, 8);
        let mut s = Scheduler::new(sa, Backend::Native);
        let y = model.forward(&x, &mut s.as_exec()).unwrap();
        assert_eq!(y.shape, vec![2, 10]);
        assert_eq!(s.report.matmuls, 3);
    }

    #[test]
    fn packed_backend_uses_layer_cached_planes() {
        let sa = SaConfig::new(4, 16, MacVariant::Booth);
        let model = crate::nn::model::mlp_zoo(11);
        let mut rng = Pcg32::new(0xcafe);
        let x = crate::nn::tensor::QTensor::new(
            (0..2 * 64).map(|_| rng.range_i32(-128, 127)).collect(),
            vec![2, 64],
            0.05,
            8,
        )
        .unwrap();

        let mut nat = Scheduler::new(sa, Backend::Native);
        let want = model.forward(&x, &mut nat).unwrap();

        // two forwards through &mut Scheduler (the MatmulExec impl):
        // identical integers, and each layer packs its weights once
        let mut packed = Scheduler::new(sa, Backend::Packed);
        let y1 = model.forward(&x, &mut packed).unwrap();
        let y2 = model.forward(&x, &mut packed).unwrap();
        assert_eq!(y1.data, want.data, "packed vs native diverged");
        assert_eq!(y2.data, want.data);
        assert_eq!(packed.report.packed_execs, 6, "3 layers x 2 forwards");
        for layer in &model.layers {
            if let crate::nn::layers::Layer::Linear(l) = layer {
                assert_eq!(l.packed.packs(), 1, "one pack per (layer, precision)");
            }
        }
    }

    #[test]
    fn packed_falls_back_natively_on_out_of_range_operands() {
        // conv/attention layers may legally hand a packed scheduler
        // operands wider than the layer precision; the backend must
        // match Native, not error
        let sa = SaConfig::new(4, 16, MacVariant::Booth);
        let (m, k, n, bits) = (2usize, 5usize, 3usize, 4u32);
        let a = vec![100i32; m * k]; // 100 does not fit in 4 bits
        let b = vec![3i32; k * n];
        let mut nat = Scheduler::new(sa, Backend::Native);
        let want = nat.matmul(&a, &b, m, k, n, bits).unwrap();
        let mut packed = Scheduler::new(sa, Backend::Packed);
        assert_eq!(packed.matmul(&a, &b, m, k, n, bits).unwrap(), want);
        assert_eq!(packed.report.packed_execs, 0);
        assert_eq!(packed.report.native_fallbacks, 1);
    }

    #[test]
    fn seu_without_abft_escapes_and_is_counted_unmasked() {
        let sa = SaConfig::new(4, 16, MacVariant::Booth);
        let (m, k, n, bits) = (4, 8, 6, 6);
        let mut rng = Pcg32::new(0x5e0);
        let a = rand_mat(&mut rng, m * k, bits);
        let b = rand_mat(&mut rng, k * n, bits);
        let want = ref_matmul_i64(&a, &b, m, k, n);
        let mut s = Scheduler::new(sa, Backend::Packed);
        let inj = Arc::new(SeuInjector::new(7));
        s.set_seu_injector(inj.clone());
        inj.arm(1);
        let got = s.matmul(&a, &b, m, k, n, bits).unwrap();
        let diffs = (0..m * n).filter(|&i| got[i] != want[i]).count();
        assert_eq!(diffs, 1, "one upset corrupts exactly one accumulator");
        assert_eq!(
            s.report.faults,
            FaultStats { injected: 1, unmasked: 1, ..FaultStats::default() }
        );
        // charge consumed: the next matmul is clean
        assert_eq!(s.matmul(&a, &b, m, k, n, bits).unwrap(), want);
        assert_eq!(s.report.faults.injected, 1);
    }

    #[test]
    fn abft_masks_injected_seu_bit_identically() {
        let sa = SaConfig::new(4, 16, MacVariant::Booth);
        let (m, k, n, bits) = (4, 8, 6, 6);
        let mut rng = Pcg32::new(0x5e1);
        let a = rand_mat(&mut rng, m * k, bits);
        let b = rand_mat(&mut rng, k * n, bits);
        let want = ref_matmul_i64(&a, &b, m, k, n);
        let mut s = Scheduler::new(sa, Backend::Packed);
        let inj = Arc::new(SeuInjector::new(7));
        s.set_seu_injector(inj.clone());
        s.set_abft(true);
        inj.arm(1);
        assert_eq!(
            s.matmul(&a, &b, m, k, n, bits).unwrap(),
            want,
            "the checksum guard must recompute the corrupted product"
        );
        assert_eq!(
            s.report.faults,
            FaultStats { injected: 1, masked_transient: 1, ..FaultStats::default() }
        );
        assert_eq!(s.report.faults.masked(), 1);
    }

    #[test]
    fn abft_is_quiet_on_clean_runs() {
        let sa = SaConfig::new(4, 16, MacVariant::Booth);
        let (m, k, n, bits) = (3, 5, 7, 8);
        let mut rng = Pcg32::new(0x5e2);
        let a = rand_mat(&mut rng, m * k, bits);
        let b = rand_mat(&mut rng, k * n, bits);
        let mut s = Scheduler::new(sa, Backend::Packed);
        s.set_abft(true);
        let got = s.matmul(&a, &b, m, k, n, bits).unwrap();
        assert_eq!(got, ref_matmul_i64(&a, &b, m, k, n));
        assert_eq!(s.report.faults, FaultStats::default(), "no false positives");
        assert!(abft_row_check(&a, &b, &got, m, k, n));
    }

    #[test]
    fn abft_guards_native_and_simulate_without_false_positives() {
        let sa = SaConfig::new(4, 16, MacVariant::Booth);
        let (m, k, n, bits) = (3, 9, 5, 6);
        let mut rng = Pcg32::new(0x5e3);
        let a = rand_mat(&mut rng, m * k, bits);
        let b = rand_mat(&mut rng, k * n, bits);
        let want = ref_matmul_i64(&a, &b, m, k, n);
        for backend in [Backend::Native, Backend::Simulate] {
            let name = backend.name();
            let mut s = Scheduler::new(sa, backend);
            s.set_abft(true);
            assert_eq!(s.matmul(&a, &b, m, k, n, bits).unwrap(), want, "{name}");
            assert_eq!(s.report.faults, FaultStats::default(), "{name}: no false positives");
        }
    }

    #[test]
    fn abft_ladder_repairs_corrupt_resident_planes_and_retries_packed() {
        use crate::nn::layers::{PackedCache, RepairSource};
        use crate::nn::tensor::QTensor;
        let sa = SaConfig::new(4, 16, MacVariant::Booth);
        let (m, k, n, bits) = (3usize, 10usize, 4usize, 4u32);
        let mut rng = Pcg32::new(0xab1);
        let a = vec![1i32; m * k]; // all-ones: every weight digit is live in the product
        let wvals = rand_mat(&mut rng, k * n, bits);
        let w = QTensor::new(wvals.clone(), vec![k, n], 1.0, bits).unwrap();
        let want = ref_matmul_i64(&a, &wvals, m, k, n);
        let cache = PackedCache::new();
        let clean = cache.get_or_pack(0, &w, bits).unwrap();
        // memory SEU: flip a live digit bit of the resident pack —
        // every later exec of this weight would fail ABFT (persistent)
        cache.replace(
            (0, bits),
            Arc::new(clean.with_flipped_bit(0, 0, 0, 0, false).unwrap()),
        );
        let mut s = Scheduler::new(sa, Backend::Packed);
        s.set_abft(true);
        let pw = PackedWeight {
            data: &w.data,
            planes: Some(cache.get_or_pack(0, &w, bits).unwrap()),
            repair: Some(RepairSource { cache: &cache, slot: 0, w: &w }),
        };
        let got = s.matmul_packed(&a, &pw, m, k, n, bits).unwrap();
        assert_eq!(got, want, "ladder output must be bit-identical to fault-free");
        assert_eq!(s.report.scrub.detected, 1, "corrupt planes located at the source");
        assert_eq!(s.report.scrub.repaired, 1, "repaired by re-pack");
        assert_eq!(s.report.scrub.quarantined, 0);
        assert_eq!(s.report.faults.masked_persistent, 1, "a stuck-at plane is persistent");
        assert_eq!(s.report.faults.masked_transient, 0);
        // the cache now holds a verified, bit-identical pack
        let repaired = cache.get_or_pack(0, &w, bits).unwrap();
        assert!(repaired.verify());
        assert_eq!(*repaired, *clean);
        // next exec is clean: no new detections
        let pw2 = PackedWeight {
            data: &w.data,
            planes: Some(repaired),
            repair: Some(RepairSource { cache: &cache, slot: 0, w: &w }),
        };
        assert_eq!(s.matmul_packed(&a, &pw2, m, k, n, bits).unwrap(), want);
        assert_eq!(s.report.scrub.detected, 1);
    }

    #[test]
    fn abft_ladder_quarantines_when_golden_source_fails_too() {
        use crate::nn::layers::{PackedCache, Quarantined, RepairSource};
        use crate::nn::tensor::QTensor;
        let sa = SaConfig::new(4, 16, MacVariant::Booth);
        let (m, k, n, bits) = (2usize, 6usize, 3usize, 4u32);
        let a = vec![1i32; m * k];
        let mut rng = Pcg32::new(0xab2);
        let w = QTensor::new(rand_mat(&mut rng, k * n, bits), vec![k, n], 1.0, bits).unwrap();
        let cache = PackedCache::new();
        let clean = cache.get_or_pack(3, &w, bits).unwrap();
        cache.replace(
            (3, bits),
            Arc::new(clean.with_flipped_bit(0, 0, 0, 0, false).unwrap()),
        );
        // the dense source is corrupt too: its golden stamp is stale
        let mut bad = w.clone();
        bad.data[0] ^= 1;
        assert!(!bad.verify_golden());
        let mut s = Scheduler::new(sa, Backend::Packed);
        s.set_abft(true);
        let pw = PackedWeight {
            data: &bad.data,
            planes: Some(cache.get_or_pack(3, &bad, bits).unwrap()),
            repair: Some(RepairSource { cache: &cache, slot: 3, w: &bad }),
        };
        let err = s.matmul_packed(&a, &pw, m, k, n, bits).unwrap_err();
        assert_eq!(err.downcast_ref::<Quarantined>(), Some(&Quarantined { slot: 3 }));
        assert!(cache.is_quarantined(3));
        assert_eq!(s.report.scrub.quarantined, 1);
        assert_eq!(s.report.scrub.repaired, 0);
        // the slot refuses all future packs with the same typed error
        let err = cache.get_or_pack(3, &w, bits).unwrap_err();
        assert!(err.downcast_ref::<Quarantined>().is_some());
    }

    #[test]
    fn consecutive_abft_misses_on_a_shape_classify_as_persistent() {
        let sa = SaConfig::new(4, 16, MacVariant::Booth);
        let (m, k, n, bits) = (4, 8, 6, 6);
        let mut rng = Pcg32::new(0x5e4);
        let a = rand_mat(&mut rng, m * k, bits);
        let b = rand_mat(&mut rng, k * n, bits);
        let want = ref_matmul_i64(&a, &b, m, k, n);
        let mut s = Scheduler::new(sa, Backend::Packed);
        let inj = Arc::new(SeuInjector::new(7));
        s.set_seu_injector(inj.clone());
        s.set_abft(true);
        // two flips on consecutive executions of the same shape: the
        // first reads as an independent transient, the second as a
        // stuck-at (persistent) fault
        inj.arm(2);
        assert_eq!(s.matmul(&a, &b, m, k, n, bits).unwrap(), want);
        assert_eq!(s.matmul(&a, &b, m, k, n, bits).unwrap(), want);
        assert_eq!(s.report.faults.masked_transient, 1);
        assert_eq!(s.report.faults.masked_persistent, 1);
        // a clean exec breaks the streak: the next miss is transient
        assert_eq!(s.matmul(&a, &b, m, k, n, bits).unwrap(), want);
        inj.arm(1);
        assert_eq!(s.matmul(&a, &b, m, k, n, bits).unwrap(), want);
        assert_eq!(s.report.faults.masked_transient, 2);
        assert_eq!(s.report.faults.masked_persistent, 1);
        assert_eq!(s.report.faults.masked(), 3);
        assert_eq!(s.report.faults.unmasked, 0);
    }

    #[test]
    fn tracer_records_scheduler_stage_spans() {
        use crate::obs::trace::TraceRing;
        let sa = SaConfig::new(4, 16, MacVariant::Booth);
        let (m, k, n, bits) = (4, 8, 6, 6);
        let mut rng = Pcg32::new(0x7a7a);
        let a = rand_mat(&mut rng, m * k, bits);
        let b = rand_mat(&mut rng, k * n, bits);
        let want = ref_matmul_i64(&a, &b, m, k, n);

        let ring = Arc::new(TraceRing::new(256));
        let mut s = Scheduler::new(sa, Backend::Packed);
        s.set_tracer(ring.clone());
        s.set_trace_ctx(42);
        assert_eq!(s.matmul(&a, &b, m, k, n, bits).unwrap(), want);
        let kinds: Vec<&str> = ring.dump().iter().map(|sp| sp.kind.name()).collect();
        for need in ["pack_slice", "plan_resolve", "kernel"] {
            assert!(kinds.contains(&need), "{need} missing from {kinds:?}");
        }
        assert!(ring.dump().iter().all(|sp| sp.trace == 42));
        // the ABFT guard adds a verify span (clean → detail 0)
        s.set_abft(true);
        assert_eq!(s.matmul(&a, &b, m, k, n, bits).unwrap(), want);
        let verify: Vec<u64> = ring
            .dump()
            .iter()
            .filter(|sp| sp.kind == SpanKind::AbftVerify)
            .map(|sp| sp.detail)
            .collect();
        assert_eq!(verify, vec![0], "one clean verify span");

        // the simulate arm records pack, kernel, and the device ledger
        let ring2 = Arc::new(TraceRing::new(256));
        let mut sim = Scheduler::new(sa, Backend::Simulate);
        sim.set_tracer(ring2.clone());
        assert_eq!(sim.matmul(&a, &b, m, k, n, bits).unwrap(), want);
        let kinds2: Vec<&str> = ring2.dump().iter().map(|sp| sp.kind.name()).collect();
        for need in ["pack_slice", "kernel", "device_fetch", "device_exec", "device_writeback"] {
            assert!(kinds2.contains(&need), "{need} missing from {kinds2:?}");
        }

        // detached tracer (the default) records nothing and costs one branch
        let mut quiet = Scheduler::new(sa, Backend::Packed);
        assert_eq!(quiet.matmul(&a, &b, m, k, n, bits).unwrap(), want);
    }

    #[test]
    fn packed_rejects_mismatched_cached_planes() {
        let sa = SaConfig::new(4, 16, MacVariant::Booth);
        let mut s = Scheduler::new(sa, Backend::Packed);
        let b = [1i32, 2, 3, 4, 5, 6];
        // planes packed for a 3x2 weight at 4 bits...
        let planes = std::sync::Arc::new(
            crate::bits::packed::PackedPlanes::pack_cols(&b, 3, 2, 4, crate::bits::plane::PlaneKind::Sbmwc).unwrap(),
        );
        let w = PackedWeight { data: &b, planes: Some(planes), repair: None };
        // ...offered for an 8-bit request: planes cannot *widen*, so
        // this is rejected, not silently wrong
        assert!(s.matmul_packed(&[1, 1, 1], &w, 1, 3, 2, 8).is_err());
    }

    #[test]
    fn packed_slices_wider_cached_planes_instead_of_erroring() {
        let sa = SaConfig::new(4, 16, MacVariant::Booth);
        let b = [1i32, 2, 3, 4, 5, 6]; // fits 4 bits
        let a = [1i32, -1, 2];
        let mut nat = Scheduler::new(sa, Backend::Native);
        let want = nat.matmul(&a, &b, 1, 3, 2, 4).unwrap();
        // planes cached at 8 bits serve the 4-bit request via a slice
        let planes = std::sync::Arc::new(
            crate::bits::packed::PackedPlanes::pack_cols(
                &b, 3, 2, 8, crate::bits::plane::PlaneKind::Sbmwc,
            ).unwrap(),
        );
        let w = PackedWeight { data: &b, planes: Some(planes), repair: None };
        let mut s = Scheduler::new(sa, Backend::Packed);
        assert_eq!(s.matmul_packed(&a, &w, 1, 3, 2, 4).unwrap(), want);
        assert_eq!(s.report.plane_slices, 1);
        assert_eq!(s.report.packed_execs, 1);
    }

    #[test]
    fn pooled_scheduler_matches_native_and_serial_packed() {
        let sa = SaConfig::new(4, 16, MacVariant::Booth);
        let (m, k, n, bits) = (23, 70, 9, 7);
        let mut rng = Pcg32::new(0x70_01);
        let a = rand_mat(&mut rng, m * k, bits);
        let b = rand_mat(&mut rng, k * n, bits);
        let mut nat = Scheduler::new(sa, Backend::Native);
        let want = nat.matmul(&a, &b, m, k, n, bits).unwrap();

        let mut serial = Scheduler::new(sa, Backend::Packed);
        serial.set_popcount_kernel(PopcountKernel::Scalar);
        assert_eq!(serial.matmul(&a, &b, m, k, n, bits).unwrap(), want);

        let pool = std::sync::Arc::new(PackedPool::new(4).unwrap());
        let mut pooled = Scheduler::new(sa, Backend::Packed);
        pooled.set_packed_pool(pool);
        assert_eq!(pooled.matmul(&a, &b, m, k, n, bits).unwrap(), want);
        // threading changes host speed, not the modelled hardware cycles
        assert_eq!(pooled.report.hw_cycles, serial.report.hw_cycles);
        // the pooled run surfaced its tiling telemetry
        assert!(pooled.report.steal.tiles >= 1);
        assert!(pooled.report.steal.max_worker_tiles >= pooled.report.steal.min_worker_tiles);
        // the single-thread scheduler has none
        assert_eq!(serial.report.steal.tiles, 0);
    }

    #[test]
    fn planner_modes_resolve_plans_and_stay_bit_identical() {
        use crate::plan::{Planner, PlannerMode};
        let sa = SaConfig::new(4, 16, MacVariant::Booth);
        let (m, k, n, bits) = (6, 70, 9, 5);
        let mut rng = Pcg32::new(0x9147);
        let a = rand_mat(&mut rng, m * k, bits);
        let b = rand_mat(&mut rng, k * n, bits);
        let mut nat = Scheduler::new(sa, Backend::Native);
        let want = nat.matmul(&a, &b, m, k, n, bits).unwrap();
        for mode in [PlannerMode::Static, PlannerMode::Online] {
            let mut s = Scheduler::new(sa, Backend::Packed);
            s.set_planner(std::sync::Arc::new(Planner::new(mode, 1)));
            assert_eq!(s.matmul(&a, &b, m, k, n, bits).unwrap(), want, "{mode:?}");
            assert_eq!(s.matmul(&a, &b, m, k, n, bits).unwrap(), want, "{mode:?}");
            // first touch misses (cost model or calibration), second hits
            assert_eq!(s.report.plan.misses, 1, "{mode:?}");
            assert_eq!(s.report.plan.hits, 1, "{mode:?}");
            let want_cal = if mode == PlannerMode::Online { 1 } else { 0 };
            assert_eq!(s.report.plan.calibrations, want_cal, "{mode:?}");
        }
        // an Off planner leaves the static path untouched
        let mut s = Scheduler::new(sa, Backend::Packed);
        s.set_planner(std::sync::Arc::new(Planner::new(PlannerMode::Off, 1)));
        assert_eq!(s.matmul(&a, &b, m, k, n, bits).unwrap(), want);
        assert_eq!(s.report.plan, crate::plan::PlanStats::default());
        assert_eq!(s.report.packed_execs, 1);
    }

    #[test]
    fn planner_routes_wide_precision_to_native_without_changing_results() {
        use crate::plan::{Planner, PlannerMode};
        // at 16x16 bits the word-ops cost model crosses over to native
        let sa = SaConfig::new(4, 16, MacVariant::Booth);
        let (m, k, n, bits) = (8, 70, 8, 16);
        let mut rng = Pcg32::new(0x9148);
        let a = rand_mat(&mut rng, m * k, bits);
        let b = rand_mat(&mut rng, k * n, bits);
        let mut nat = Scheduler::new(sa, Backend::Native);
        let want = nat.matmul(&a, &b, m, k, n, bits).unwrap();
        let mut s = Scheduler::new(sa, Backend::Packed);
        s.set_planner(std::sync::Arc::new(Planner::new(PlannerMode::Static, 1)));
        assert_eq!(s.matmul(&a, &b, m, k, n, bits).unwrap(), want);
        assert_eq!(s.report.packed_execs, 0, "planner chose the native loop");
        assert_eq!(s.report.native_fallbacks, 1);
        // the narrow-precision class still runs packed
        let (a4, b4) = (rand_mat(&mut rng, m * k, 4), rand_mat(&mut rng, k * n, 4));
        let want4 = nat.matmul(&a4, &b4, m, k, n, 4).unwrap();
        assert_eq!(s.matmul(&a4, &b4, m, k, n, 4).unwrap(), want4);
        assert_eq!(s.report.packed_execs, 1, "precision flip re-plans the backend");
    }

    #[test]
    fn static_rsr_family_stays_bit_identical() {
        let sa = SaConfig::new(4, 16, MacVariant::Booth);
        let (m, k, n, bits) = (6, 70, 9, 2);
        let mut rng = Pcg32::new(0x5151);
        let a = rand_mat(&mut rng, m * k, bits);
        let b = rand_mat(&mut rng, k * n, bits);
        let mut nat = Scheduler::new(sa, Backend::Native);
        let want = nat.matmul(&a, &b, m, k, n, bits).unwrap();

        let mut s = Scheduler::new(sa, Backend::Packed);
        s.set_kernel_family(KernelFamily::Rsr { seg_words: 0 });
        assert_eq!(s.matmul(&a, &b, m, k, n, bits).unwrap(), want);
        assert_eq!(s.report.packed_execs, 1);

        let pool = std::sync::Arc::new(PackedPool::new(2).unwrap());
        let mut p = Scheduler::new(sa, Backend::Packed);
        p.set_packed_pool(pool);
        p.set_kernel_family(KernelFamily::Rsr { seg_words: 1 });
        assert_eq!(p.matmul(&a, &b, m, k, n, bits).unwrap(), want);
        assert_eq!(p.report.packed_execs, 1);
    }

    #[test]
    fn tile_policy_does_not_change_results_and_reports_merge() {
        let sa = SaConfig::new(4, 16, MacVariant::Booth);
        // skewed: one output row — the shape the 2-D scheduler exists for
        let (m, k, n, bits) = (1, 70, 40, 8);
        let mut rng = Pcg32::new(0x71_1e);
        let a = rand_mat(&mut rng, m * k, bits);
        let b = rand_mat(&mut rng, k * n, bits);
        let mut nat = Scheduler::new(sa, Backend::Native);
        let want = nat.matmul(&a, &b, m, k, n, bits).unwrap();

        let pool = std::sync::Arc::new(PackedPool::new(3).unwrap());
        let mut merged = ExecutionReport::default();
        for policy in [
            TilePolicy::AUTO,
            TilePolicy { tile_rows: 1, tile_cols: 1, ..TilePolicy::AUTO },
            TilePolicy { tile_rows: 0, tile_cols: 7, ..TilePolicy::AUTO },
            TilePolicy { k_chunks: 2, ..TilePolicy::AUTO },
        ] {
            let mut s = Scheduler::new(sa, Backend::Packed);
            s.set_packed_pool(pool.clone());
            s.set_tile_policy(policy);
            assert_eq!(s.matmul(&a, &b, m, k, n, bits).unwrap(), want, "{policy:?}");
            merged.merge(&s.report);
        }
        // the forced 1x1 policy decomposed into one tile per output col
        assert!(merged.steal.tiles >= n as u64);
        assert!(merged.steal.max_worker_tiles >= 1);
    }
}
