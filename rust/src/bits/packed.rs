//! Word-packed bit planes and the popcount plane-pair matmul kernel —
//! the software hot path of the bit-serial formulation (see DESIGN.md
//! §Packed-Planes).
//!
//! The per-plane path ([`crate::nn::matmul_planes`]) stores one *byte*
//! per digit, so an `m×k` operand at `b` bits costs `b·m·k` bytes and
//! the plane matmul touches every one of them per output column. This
//! module stores each plane packed 64 digits per `u64` word:
//!
//! * **SBMwC** `{0,1}` planes — one word stream per plane; the MSb
//!   plane's weight is `−2^(b−1)` (eq. 2's sign correction).
//! * **Booth** `{−1,0,+1}` planes — a `(pos, neg)` word-stream pair
//!   per plane (`digit = pos − neg`); every plane weighs `+2^i`.
//!
//! The kernel realises `A·B = Σ_{i,j} w_i·w_j · (D_i(A)·D_j(B))` where
//! each binary plane-pair product is per-word `AND` + `count_ones` —
//! the BISMO-style word-packed formulation (PAPERS.md, Umuroglu et
//! al.), with signed `w` absorbing the SBMwC correction. Both packers
//! derive their digits from the shared [`decompose`] oracle, so the
//! packed engine cannot drift from the per-plane one.

use super::plane::{decompose, plane_weight, PlaneKind};
use crate::Result;

/// A matrix operand decomposed into `bits` digit planes, each packed
/// 64 digits per word along the contracted dimension.
///
/// `vectors` is the number of packed vectors — matrix *rows* for the
/// streamed (left) operand of `A·B` ([`PackedPlanes::pack_rows`]),
/// matrix *columns* for the stationary (right) operand
/// ([`PackedPlanes::pack_cols`]) — and `len` is the contracted
/// dimension k. Packing columns along k is what lets the tiler slice
/// column ranges of a cached weight operand without re-packing
/// ([`matmul_packed_tile`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackedPlanes {
    pub kind: PlaneKind,
    pub bits: u32,
    /// Number of packed vectors (rows or columns of the source matrix).
    pub vectors: usize,
    /// Digits per vector (the contracted dimension k).
    pub len: usize,
    /// Words per vector: `ceil(len / 64)`; trailing bits of the last
    /// word are always zero (tail masking happens at pack time).
    pub words: usize,
    /// Positive-digit words, plane-major:
    /// `pos[(plane · vectors + vec) · words + w]`.
    pos: Vec<u64>,
    /// Negative-digit words (Booth only; empty for SBMwC).
    neg: Vec<u64>,
}

impl PackedPlanes {
    /// Pack the rows of a row-major `rows × cols` matrix: one packed
    /// vector per row, `len = cols`. This is the layout for the
    /// streamed (left) operand of `A·B`.
    pub fn pack_rows(
        data: &[i32],
        rows: usize,
        cols: usize,
        bits: u32,
        kind: PlaneKind,
    ) -> Result<PackedPlanes> {
        Self::check(data, rows, cols, bits)?;
        Ok(Self::pack_vectors(data, rows, cols, bits, kind, |v, e| {
            v * cols + e
        }))
    }

    /// Pack the columns of a row-major `rows × cols` matrix: one packed
    /// vector per column, `len = rows`. This is the layout for the
    /// stationary (right) operand of `A·B`, packed along k so weight
    /// matrices pack once and tiles select column ranges by index.
    pub fn pack_cols(
        data: &[i32],
        rows: usize,
        cols: usize,
        bits: u32,
        kind: PlaneKind,
    ) -> Result<PackedPlanes> {
        Self::check(data, rows, cols, bits)?;
        Ok(Self::pack_vectors(data, cols, rows, bits, kind, |v, e| {
            e * cols + v
        }))
    }

    fn check(data: &[i32], rows: usize, cols: usize, bits: u32) -> Result<()> {
        crate::validate_bits(bits)?;
        anyhow::ensure!(
            data.len() == rows * cols,
            "pack: {} values for a {rows}x{cols} matrix",
            data.len()
        );
        let (lo, hi) = (
            crate::bits::twos::min_value(bits),
            crate::bits::twos::max_value(bits),
        );
        anyhow::ensure!(
            data.iter().all(|v| (lo..=hi).contains(v)),
            "pack: operand exceeds the {bits}-bit two's-complement range"
        );
        Ok(())
    }

    fn pack_vectors(
        data: &[i32],
        vectors: usize,
        len: usize,
        bits: u32,
        kind: PlaneKind,
        index: impl Fn(usize, usize) -> usize,
    ) -> PackedPlanes {
        let planes = decompose(kind, data, bits); // the shared oracle
        let words = (len + 63) / 64;
        let total = bits as usize * vectors * words;
        let mut pos = vec![0u64; total];
        let mut neg = match kind {
            PlaneKind::Booth => vec![0u64; total],
            PlaneKind::Sbmwc => Vec::new(),
        };
        for (p, plane) in planes.iter().enumerate() {
            for v in 0..vectors {
                let base = (p * vectors + v) * words;
                for e in 0..len {
                    let digit = plane[index(v, e)];
                    let bit = 1u64 << (e % 64);
                    if digit > 0 {
                        pos[base + e / 64] |= bit;
                    } else if digit < 0 {
                        debug_assert_eq!(kind, PlaneKind::Booth);
                        neg[base + e / 64] |= bit;
                    }
                }
            }
        }
        PackedPlanes {
            kind,
            bits,
            vectors,
            len,
            words,
            pos,
            neg,
        }
    }

    /// Positive-digit words of one plane of one vector.
    #[inline]
    pub fn plane_pos(&self, plane: usize, vec: usize) -> &[u64] {
        let base = (plane * self.vectors + vec) * self.words;
        &self.pos[base..base + self.words]
    }

    /// Negative-digit words of one plane of one vector (`None` for
    /// SBMwC, whose digits are non-negative).
    #[inline]
    pub fn plane_neg(&self, plane: usize, vec: usize) -> Option<&[u64]> {
        if self.neg.is_empty() {
            return None;
        }
        let base = (plane * self.vectors + vec) * self.words;
        Some(&self.neg[base..base + self.words])
    }

    /// Unpack back to digit planes in packed-vector order. For a
    /// [`PackedPlanes::pack_rows`] of row-major data this reproduces
    /// the [`decompose`] oracle's planes exactly (the round-trip the
    /// property tests pin).
    pub fn unpack(&self) -> Vec<Vec<i8>> {
        (0..self.bits as usize)
            .map(|p| {
                let mut plane = Vec::with_capacity(self.vectors * self.len);
                for v in 0..self.vectors {
                    let pos = self.plane_pos(p, v);
                    let neg = self.plane_neg(p, v);
                    for e in 0..self.len {
                        let bit = 1u64 << (e % 64);
                        let digit = if pos[e / 64] & bit != 0 {
                            1i8
                        } else if neg.map_or(false, |n| n[e / 64] & bit != 0) {
                            -1i8
                        } else {
                            0i8
                        };
                        plane.push(digit);
                    }
                }
                plane
            })
            .collect()
    }

    /// Words of packed storage. The byte-per-digit representation costs
    /// `bits · vectors · len` bytes; this costs `8 · mem_words()` —
    /// a ~8× reduction (~16× for Booth's two streams vs. pos/neg bytes
    /// is the same 8× per stream).
    pub fn mem_words(&self) -> usize {
        self.pos.len() + self.neg.len()
    }
}

/// Packed plane-pair matmul: `a` holds the rows of `A` (m vectors of
/// length k), `b` the columns of `B` (n vectors of length k). Returns
/// the exact `m × n` i64 accumulators, bit-identical to
/// [`crate::nn::matmul_native`].
pub fn matmul_packed_planes(a: &PackedPlanes, b: &PackedPlanes) -> Result<Vec<i64>> {
    matmul_packed_tile(a, b, 0, a.vectors, 0, b.vectors)
}

/// Tile view of [`matmul_packed_planes`]: rows `row0 .. row0+tm` of A
/// against columns `col0 .. col0+tn` of B, selected by index so
/// neither operand is re-packed per tile. Returns a `tm × tn` tile.
///
/// Realises `A·B = Σ_{i,j} w_i w_j (D_i(A)·D_j(B))` with the binary
/// plane-pair products computed as per-word `AND` + `count_ones`; the
/// signed plane weights carry the SBMwC MSb-plane correction.
pub fn matmul_packed_tile(
    a: &PackedPlanes,
    b: &PackedPlanes,
    row0: usize,
    tm: usize,
    col0: usize,
    tn: usize,
) -> Result<Vec<i64>> {
    anyhow::ensure!(
        a.len == b.len,
        "contracted dims differ: {} vs {}",
        a.len,
        b.len
    );
    anyhow::ensure!(
        row0 + tm <= a.vectors && col0 + tn <= b.vectors,
        "tile {row0}+{tm} / {col0}+{tn} exceeds {}x{} packed operands",
        a.vectors,
        b.vectors
    );
    let mut out = vec![0i64; tm * tn];
    for i in 0..a.bits as usize {
        let wa = plane_weight(a.kind, i as u32, a.bits);
        for j in 0..b.bits as usize {
            let w = wa * plane_weight(b.kind, j as u32, b.bits);
            for r in 0..tm {
                let ap = a.plane_pos(i, row0 + r);
                let an = a.plane_neg(i, row0 + r);
                let orow = &mut out[r * tn..(r + 1) * tn];
                for (c, o) in orow.iter_mut().enumerate() {
                    let bp = b.plane_pos(j, col0 + c);
                    let bn = b.plane_neg(j, col0 + c);
                    // Specialised per kind pair: the SBMwC×SBMwC case
                    // (the engine default) is a single AND+popcount.
                    let dot: i64 = match (an, bn) {
                        (None, None) => ap
                            .iter()
                            .zip(bp)
                            .map(|(x, y)| (x & y).count_ones() as i64)
                            .sum(),
                        (Some(an), None) => ap
                            .iter()
                            .zip(an)
                            .zip(bp)
                            .map(|((x, xn), y)| {
                                (x & y).count_ones() as i64 - (xn & y).count_ones() as i64
                            })
                            .sum(),
                        (None, Some(bn)) => ap
                            .iter()
                            .zip(bp)
                            .zip(bn)
                            .map(|((x, y), yn)| {
                                (x & y).count_ones() as i64 - (x & yn).count_ones() as i64
                            })
                            .sum(),
                        (Some(an), Some(bn)) => ap
                            .iter()
                            .zip(an)
                            .zip(bp)
                            .zip(bn)
                            .map(|(((x, xn), y), yn)| {
                                (x & y).count_ones() as i64 - (x & yn).count_ones() as i64
                                    - (xn & y).count_ones() as i64
                                    + (xn & yn).count_ones() as i64
                            })
                            .sum(),
                    };
                    *o += w * dot;
                }
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::twos::{max_value, min_value};
    use crate::prng::Pcg32;
    use crate::sim::driver::ref_matmul_i64 as ref_mm;

    fn rand_mat(rng: &mut Pcg32, len: usize, bits: u32) -> Vec<i32> {
        let (lo, hi) = (min_value(bits), max_value(bits));
        (0..len).map(|_| rng.range_i32(lo, hi)).collect()
    }

    #[test]
    fn pack_unpack_matches_oracle_both_kinds() {
        let mut rng = Pcg32::new(0xbeef);
        for bits in [1u32, 2, 5, 8, 16] {
            // lengths straddling the word boundary exercise tail masking
            for len in [1usize, 7, 63, 64, 65, 130] {
                let data = rand_mat(&mut rng, 3 * len, bits);
                for kind in [PlaneKind::Sbmwc, PlaneKind::Booth] {
                    let p = PackedPlanes::pack_rows(&data, 3, len, bits, kind).unwrap();
                    assert_eq!(p.words, (len + 63) / 64);
                    assert_eq!(p.unpack(), decompose(kind, &data, bits), "{kind:?} {bits}b len={len}");
                }
            }
        }
    }

    #[test]
    fn packed_matmul_exact_all_kind_pairs() {
        let mut rng = Pcg32::new(0x9c0d);
        for bits in [1u32, 3, 8, 11, 16] {
            for (m, k, n) in [(2usize, 7usize, 3usize), (3, 64, 2), (2, 70, 4), (1, 1, 1)] {
                let a = rand_mat(&mut rng, m * k, bits);
                let b = rand_mat(&mut rng, k * n, bits);
                let want = ref_mm(&a, &b, m, k, n);
                for ka in [PlaneKind::Sbmwc, PlaneKind::Booth] {
                    for kb in [PlaneKind::Sbmwc, PlaneKind::Booth] {
                        let pa = PackedPlanes::pack_rows(&a, m, k, bits, ka).unwrap();
                        let pb = PackedPlanes::pack_cols(&b, k, n, bits, kb).unwrap();
                        assert_eq!(
                            matmul_packed_planes(&pa, &pb).unwrap(),
                            want,
                            "{ka:?}x{kb:?} {m}x{k}x{n} @{bits}b"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn sign_plane_saturation_is_exact() {
        // every operand at min_value: the SBMwC MSb (sign) plane is
        // all-ones, maximally exercising the −2^(b−1) correction
        for bits in 1..=16u32 {
            let (m, k, n) = (2usize, 70usize, 2usize);
            let a = vec![min_value(bits); m * k];
            let b = vec![min_value(bits); k * n];
            let pa = PackedPlanes::pack_rows(&a, m, k, bits, PlaneKind::Sbmwc).unwrap();
            let pb = PackedPlanes::pack_cols(&b, k, n, bits, PlaneKind::Sbmwc).unwrap();
            assert_eq!(matmul_packed_planes(&pa, &pb).unwrap(), ref_mm(&a, &b, m, k, n), "bits={bits}");
        }
    }

    #[test]
    fn tile_view_matches_full_product() {
        let mut rng = Pcg32::new(0x711e);
        let (m, k, n, bits) = (5usize, 67usize, 9usize, 6u32);
        let a = rand_mat(&mut rng, m * k, bits);
        let b = rand_mat(&mut rng, k * n, bits);
        let pa = PackedPlanes::pack_rows(&a, m, k, bits, PlaneKind::Sbmwc).unwrap();
        let pb = PackedPlanes::pack_cols(&b, k, n, bits, PlaneKind::Sbmwc).unwrap();
        let full = matmul_packed_planes(&pa, &pb).unwrap();
        // a 2×3 tile at (row0=2, col0=5), sliced purely by index
        let tile = matmul_packed_tile(&pa, &pb, 2, 2, 5, 3).unwrap();
        for r in 0..2 {
            for c in 0..3 {
                assert_eq!(tile[r * 3 + c], full[(2 + r) * n + 5 + c]);
            }
        }
        assert!(matmul_packed_tile(&pa, &pb, 4, 2, 0, 1).is_err(), "row overrun");
    }

    #[test]
    fn packing_validates_range_and_shape() {
        assert!(PackedPlanes::pack_rows(&[1, 2, 3], 2, 2, 4, PlaneKind::Sbmwc).is_err());
        assert!(PackedPlanes::pack_rows(&[8], 1, 1, 4, PlaneKind::Sbmwc).is_err()); // 8 > max 4-bit
        assert!(PackedPlanes::pack_rows(&[7], 1, 1, 4, PlaneKind::Sbmwc).is_ok());
        assert!(PackedPlanes::pack_rows(&[1], 1, 1, 0, PlaneKind::Sbmwc).is_err());
    }

    #[test]
    fn packed_footprint_is_an_order_smaller() {
        let (rows, cols, bits) = (16usize, 256usize, 8u32);
        let data = vec![1i32; rows * cols];
        let p = PackedPlanes::pack_rows(&data, rows, cols, bits, PlaneKind::Sbmwc).unwrap();
        let packed_bytes = p.mem_words() * 8;
        let byte_planes = bits as usize * rows * cols;
        assert_eq!(packed_bytes * 8, byte_planes, "exactly 8x smaller");
    }
}
