//! Word-packed bit planes and the popcount plane-pair matmul kernel —
//! the software hot path of the bit-serial formulation (see DESIGN.md
//! §Packed-Planes and §Packed-Threading).
//!
//! The per-plane path ([`crate::nn::matmul_planes`]) stores one *byte*
//! per digit, so an `m×k` operand at `b` bits costs `b·m·k` bytes and
//! the plane matmul touches every one of them per output column. This
//! module stores each plane packed 64 digits per `u64` word:
//!
//! * **SBMwC** `{0,1}` planes — one word stream per plane; the MSb
//!   plane's weight is `−2^(b−1)` (eq. 2's sign correction).
//! * **Booth** `{−1,0,+1}` planes — a `(pos, neg)` word-stream pair
//!   per plane (`digit = pos − neg`); every plane weighs `+2^i`.
//!
//! The kernel realises `A·B = Σ_{i,j} w_i·w_j · (D_i(A)·D_j(B))` where
//! each binary plane-pair product is per-word `AND` + popcount — the
//! BISMO-style word-packed formulation (PAPERS.md, Umuroglu et al.),
//! with signed `w` absorbing the SBMwC correction. Both packers derive
//! their digits from the shared [`decompose`] oracle, so the packed
//! engine cannot drift from the per-plane one.
//!
//! Three host-throughput levers live here (all bit-identical to the
//! scalar kernel, pinned by tests):
//!
//! * [`PopcountKernel`] — the word reducer behind every plane-pair
//!   product: scalar, 4-/8-word unrolled chunks, an AVX2 nibble-LUT
//!   popcount, or a NEON `vcntq_u8` popcount, selected by *runtime*
//!   feature detection (`Auto`). All kind-pair arms share one
//!   [`plane_pair_dot`] reducer, so unroll variants cannot diverge
//!   from each other.
//! * [`PackedPool`] — a persistent `std::thread` worker pool. A pooled
//!   matmul is decomposed into 2-D row×column output tiles sized
//!   adaptively from the shape and word count ([`plan_tile_shape`],
//!   overridable via [`TilePolicy`]), seeded into per-slot deques, and
//!   executed with steal-on-empty so skewed shapes (tall-thin,
//!   wide-short) keep every worker busy
//!   ([`matmul_packed_tile_stolen`]); one pool is shared by all of a
//!   server's request workers. The PR 2 equal-row-slice partitioner is
//!   kept as [`matmul_packed_tile_rowslice`] for A/B benchmarking.
//! * [`PackedPlanes::slice_bits`] — cross-precision plane reuse: the
//!   plane-major layout makes the planes of every lower precision a
//!   *prefix* of a higher-precision pack, so a `b'`-bit view of a
//!   `b`-bit pack (`b' ≥ min_bits`) is a zero-copy `Arc` share.
//!
//! Two further plan-selectable levers (DESIGN.md
//! §Sub-popcount-Kernels), both bit-identical by construction:
//!
//! * [`KernelFamily::Rsr`] — redundant-segment-reuse kernels
//!   ([`SegmentTable`], [`matmul_packed_rsr`]): dedupe the stationary
//!   operand's column word-patterns per segment and serve each output
//!   as a sum of shared segment dots instead of per-column popcounts —
//!   the sub-popcount path for the 1–2 bit regime where quantized
//!   weight columns repeat (RSR/RSR++, arXiv 2411.06360).
//! * [`TilePolicy::k_chunks`] — deterministic k-split: stolen tiles may
//!   split the contracted dimension into fixed-order word-aligned
//!   chunks ([`plan_k_chunks`]) whose exact i64 partials merge in
//!   chunk-index order, so `1×hugek×n` shapes fan out across slots.

use super::plane::{decompose, plane_weight, PlaneKind};
use crate::Result;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};

/// A matrix operand decomposed into `bits` digit planes, each packed
/// 64 digits per word along the contracted dimension.
///
/// `vectors` is the number of packed vectors — matrix *rows* for the
/// streamed (left) operand of `A·B` ([`PackedPlanes::pack_rows`]),
/// matrix *columns* for the stationary (right) operand
/// ([`PackedPlanes::pack_cols`]) — and `len` is the contracted
/// dimension k. Packing columns along k is what lets the tiler slice
/// column ranges of a cached weight operand without re-packing
/// ([`matmul_packed_tile`]).
///
/// Word storage is plane-major and shared (`Arc`), so a lower-precision
/// view produced by [`PackedPlanes::slice_bits`] costs no copy: planes
/// `0..b'` of a `b`-bit pack are a storage prefix. Equality compares
/// only the visible planes (`0..bits`), so a sliced view equals a fresh
/// pack at the same precision.
#[derive(Debug, Clone)]
pub struct PackedPlanes {
    pub kind: PlaneKind,
    pub bits: u32,
    /// Number of packed vectors (rows or columns of the source matrix).
    pub vectors: usize,
    /// Digits per vector (the contracted dimension k).
    pub len: usize,
    /// Words per vector: `ceil(len / 64)`; trailing bits of the last
    /// word are always zero (tail masking happens at pack time).
    pub words: usize,
    /// Smallest width every packed value fits in — the floor for
    /// [`PackedPlanes::slice_bits`] (truncating two's complement below
    /// this width would change values).
    pub min_bits: u32,
    /// Positive-digit words, plane-major:
    /// `pos[(plane · vectors + vec) · words + w]`. Shared across
    /// precision-sliced views.
    pos: Arc<[u64]>,
    /// Negative-digit words (Booth only; empty for SBMwC).
    neg: Arc<[u64]>,
    /// Per-plane integrity signatures of the `pos` stream, one
    /// word-fold per *stored* plane (donor planes included, so
    /// precision-sliced views stay verifiable). Computed once at pack
    /// time and deliberately never recomputed on mutation — a fault
    /// model flips words, not signatures, exactly like real SRAM.
    sig_pos: Arc<[u64]>,
    /// Per-plane signatures of the `neg` stream (empty for SBMwC).
    sig_neg: Arc<[u64]>,
}

/// Rotate-xor word fold behind the per-plane integrity signatures
/// (DESIGN.md §Integrity). Each word lands at a distinct rotation, so a
/// flipped bit in word `i` flips exactly one position-dependent bit of
/// the fold — every single-bit upset in a plane's words (tail padding
/// included) is guaranteed to change its signature.
pub fn plane_signature(words: &[u64]) -> u64 {
    let mut sig = 0x9e37_79b9_7f4a_7c15u64;
    for &w in words {
        sig = sig.rotate_left(29) ^ w;
    }
    sig
}

impl PartialEq for PackedPlanes {
    /// Visible-plane equality: two packs are equal when their shape and
    /// their planes `0..bits` agree — storage beyond the visible planes
    /// (a higher-precision donor behind a [`PackedPlanes::slice_bits`]
    /// view) does not participate.
    fn eq(&self, other: &PackedPlanes) -> bool {
        let vis = |p: &PackedPlanes| p.bits as usize * p.vectors * p.words;
        self.kind == other.kind
            && self.bits == other.bits
            && self.vectors == other.vectors
            && self.len == other.len
            && self.words == other.words
            && self.pos[..vis(self)] == other.pos[..vis(other)]
            && self.neg.is_empty() == other.neg.is_empty()
            && (self.neg.is_empty() || self.neg[..vis(self)] == other.neg[..vis(other)])
    }
}

impl Eq for PackedPlanes {}

impl PackedPlanes {
    /// Pack the rows of a row-major `rows × cols` matrix: one packed
    /// vector per row, `len = cols`. This is the layout for the
    /// streamed (left) operand of `A·B`.
    pub fn pack_rows(
        data: &[i32],
        rows: usize,
        cols: usize,
        bits: u32,
        kind: PlaneKind,
    ) -> Result<PackedPlanes> {
        Self::check(data, rows, cols, bits)?;
        Ok(Self::pack_vectors(data, rows, cols, bits, kind, |v, e| {
            v * cols + e
        }))
    }

    /// Pack the columns of a row-major `rows × cols` matrix: one packed
    /// vector per column, `len = rows`. This is the layout for the
    /// stationary (right) operand of `A·B`, packed along k so weight
    /// matrices pack once and tiles select column ranges by index.
    pub fn pack_cols(
        data: &[i32],
        rows: usize,
        cols: usize,
        bits: u32,
        kind: PlaneKind,
    ) -> Result<PackedPlanes> {
        Self::check(data, rows, cols, bits)?;
        Ok(Self::pack_vectors(data, cols, rows, bits, kind, |v, e| {
            e * cols + v
        }))
    }

    fn check(data: &[i32], rows: usize, cols: usize, bits: u32) -> Result<()> {
        crate::validate_bits(bits)?;
        anyhow::ensure!(
            data.len() == rows * cols,
            "pack: {} values for a {rows}x{cols} matrix",
            data.len()
        );
        let (lo, hi) = (
            crate::bits::twos::min_value(bits),
            crate::bits::twos::max_value(bits),
        );
        anyhow::ensure!(
            data.iter().all(|v| (lo..=hi).contains(v)),
            "pack: operand exceeds the {bits}-bit two's-complement range"
        );
        Ok(())
    }

    /// Smallest width every value of `data` fits in (1..=16; `check`
    /// guarantees it does not exceed the declared pack width). Public
    /// because the degrade policy clamps its precision floor to this —
    /// a downshift below it would truncate live weight values.
    pub fn needed_bits(data: &[i32]) -> u32 {
        let mut bits = 1u32;
        for &v in data {
            while v < crate::bits::twos::min_value(bits)
                || v > crate::bits::twos::max_value(bits)
            {
                bits += 1;
            }
        }
        bits
    }

    fn pack_vectors(
        data: &[i32],
        vectors: usize,
        len: usize,
        bits: u32,
        kind: PlaneKind,
        index: impl Fn(usize, usize) -> usize,
    ) -> PackedPlanes {
        let planes = decompose(kind, data, bits); // the shared oracle
        let words = (len + 63) / 64;
        let total = bits as usize * vectors * words;
        let mut pos = vec![0u64; total];
        let mut neg = match kind {
            PlaneKind::Booth => vec![0u64; total],
            PlaneKind::Sbmwc => Vec::new(),
        };
        for (p, plane) in planes.iter().enumerate() {
            for v in 0..vectors {
                let base = (p * vectors + v) * words;
                for e in 0..len {
                    let digit = plane[index(v, e)];
                    let bit = 1u64 << (e % 64);
                    if digit > 0 {
                        pos[base + e / 64] |= bit;
                    } else if digit < 0 {
                        debug_assert_eq!(kind, PlaneKind::Booth);
                        neg[base + e / 64] |= bit;
                    }
                }
            }
        }
        let region = vectors * words;
        let sig_pos: Vec<u64> = (0..bits as usize)
            .map(|p| plane_signature(&pos[p * region..(p + 1) * region]))
            .collect();
        let sig_neg: Vec<u64> = if neg.is_empty() {
            Vec::new()
        } else {
            (0..bits as usize)
                .map(|p| plane_signature(&neg[p * region..(p + 1) * region]))
                .collect()
        };
        PackedPlanes {
            kind,
            bits,
            vectors,
            len,
            words,
            min_bits: Self::needed_bits(data),
            pos: pos.into(),
            neg: neg.into(),
            sig_pos: sig_pos.into(),
            sig_neg: sig_neg.into(),
        }
    }

    /// A `bits`-precision view of this pack, sharing the word storage
    /// (zero copy, zero re-decomposition).
    ///
    /// Sound because two's-complement truncation preserves values that
    /// fit in the narrower width, both plane kinds derive digit `i`
    /// only from pattern bits `≤ i`, and the plane-major layout makes
    /// planes `0..bits` a storage prefix; the top plane's sign weight
    /// is reapplied by [`plane_weight`] at the new width. Requires
    /// `min_bits ≤ bits ≤ self.bits` — below `min_bits` the narrower
    /// encoding would change values, exactly when a fresh re-pack at
    /// `bits` would also be rejected.
    pub fn slice_bits(&self, bits: u32) -> Result<PackedPlanes> {
        crate::validate_bits(bits)?;
        anyhow::ensure!(
            bits <= self.bits,
            "cannot slice {bits} planes out of a {}-bit pack (packs only narrow)",
            self.bits
        );
        anyhow::ensure!(
            self.min_bits <= bits,
            "packed values need {} bits; a {bits}-bit slice would truncate them",
            self.min_bits
        );
        let mut view = self.clone(); // Arc clones — no word copy
        view.bits = bits;
        Ok(view)
    }

    /// Positive-digit words of one plane of one vector.
    #[inline]
    pub fn plane_pos(&self, plane: usize, vec: usize) -> &[u64] {
        let base = (plane * self.vectors + vec) * self.words;
        &self.pos[base..base + self.words]
    }

    /// Append the DMA word stream for one vector into `buf`:
    /// plane-major, `bits × words_per_vec` u64 words, verbatim from the
    /// packed storage. This is exactly what the device driver streams
    /// over the `SimIf` boundary for one edge lane (DESIGN.md §Device)
    /// — the plane words *are* the serialized bit streams, so no
    /// re-encoding happens between memory and the array's P2S units.
    pub fn dma_words(&self, vec: usize, buf: &mut Vec<u64>) {
        buf.reserve(self.bits as usize * self.words);
        for p in 0..self.bits as usize {
            buf.extend_from_slice(self.plane_pos(p, vec));
        }
    }

    /// Negative-digit words of one plane of one vector (`None` for
    /// SBMwC, whose digits are non-negative).
    #[inline]
    pub fn plane_neg(&self, plane: usize, vec: usize) -> Option<&[u64]> {
        if self.neg.is_empty() {
            return None;
        }
        let base = (plane * self.vectors + vec) * self.words;
        Some(&self.neg[base..base + self.words])
    }

    /// Unpack back to digit planes in packed-vector order. For a
    /// [`PackedPlanes::pack_rows`] of row-major data this reproduces
    /// the [`decompose`] oracle's planes exactly (the round-trip the
    /// property tests pin).
    pub fn unpack(&self) -> Vec<Vec<i8>> {
        (0..self.bits as usize)
            .map(|p| {
                let mut plane = Vec::with_capacity(self.vectors * self.len);
                for v in 0..self.vectors {
                    let pos = self.plane_pos(p, v);
                    let neg = self.plane_neg(p, v);
                    for e in 0..self.len {
                        let bit = 1u64 << (e % 64);
                        let digit = if pos[e / 64] & bit != 0 {
                            1i8
                        } else if neg.map_or(false, |n| n[e / 64] & bit != 0) {
                            -1i8
                        } else {
                            0i8
                        };
                        plane.push(digit);
                    }
                }
                plane
            })
            .collect()
    }

    /// Words of packed storage visible at this precision (a sliced view
    /// reports its own planes, not the donor's). The byte-per-digit
    /// representation costs `bits · vectors · len` bytes; this costs
    /// `8 · mem_words()` — a ~8× reduction per stream.
    pub fn mem_words(&self) -> usize {
        let streams = if self.neg.is_empty() { 1 } else { 2 };
        self.bits as usize * self.vectors * self.words * streams
    }

    /// Whether this pack carries a negative-digit stream (Booth).
    pub fn has_neg(&self) -> bool {
        !self.neg.is_empty()
    }

    /// Integrity check: recompute every *visible* plane's signature and
    /// compare with the pack-time fold. `true` = intact. A sliced view
    /// checks exactly the planes it can serve; the donor's extra planes
    /// stay covered through the donor handle (signatures are per-plane,
    /// so narrowing never invalidates them).
    pub fn verify(&self) -> bool {
        self.locate().is_empty()
    }

    /// Indices of visible planes whose current words no longer match
    /// their pack-time signature — empty when the pack is intact, the
    /// scrubber's repair worklist otherwise.
    pub fn locate(&self) -> Vec<u32> {
        let region = self.vectors * self.words;
        (0..self.bits as usize)
            .filter(|&p| {
                plane_signature(&self.pos[p * region..(p + 1) * region]) != self.sig_pos[p]
                    || (!self.neg.is_empty()
                        && plane_signature(&self.neg[p * region..(p + 1) * region])
                            != self.sig_neg[p])
            })
            .map(|p| p as u32)
            .collect()
    }

    /// A deep copy with one storage bit flipped — the memory-SEU fault
    /// model behind `FaultAction::MemSeu`: the words change but the
    /// pack-time signatures are carried over unchanged, so
    /// [`PackedPlanes::verify`]/[`PackedPlanes::locate`] see the upset
    /// exactly as a scrubber reading corrupted SRAM would. `bit`
    /// indexes within the word (`0..64`); flips past `len` land in tail
    /// padding (signature-visible but output-invisible, which is why
    /// the injector constrains its draws to live digits).
    pub fn with_flipped_bit(
        &self,
        plane: usize,
        vec: usize,
        word: usize,
        bit: u32,
        neg_stream: bool,
    ) -> Result<PackedPlanes> {
        anyhow::ensure!(
            plane < self.bits as usize && vec < self.vectors && word < self.words && bit < 64,
            "flip target plane {plane} vec {vec} word {word} bit {bit} outside a \
             {}-plane {}x{}-word pack",
            self.bits,
            self.vectors,
            self.words
        );
        anyhow::ensure!(
            !neg_stream || !self.neg.is_empty(),
            "SBMwC packs have no negative stream to flip"
        );
        let mut flipped = self.clone();
        let idx = (plane * self.vectors + vec) * self.words + word;
        let stream = if neg_stream { &self.neg } else { &self.pos };
        let mut words_copy: Vec<u64> = stream.to_vec();
        words_copy[idx] ^= 1u64 << bit;
        if neg_stream {
            flipped.neg = words_copy.into();
        } else {
            flipped.pos = words_copy.into();
        }
        Ok(flipped)
    }
}

// ---------------------------------------------------------------------------
// Popcount reducers
// ---------------------------------------------------------------------------

/// Word-level `AND`+popcount reducer used for every binary plane-pair
/// product — the innermost loop of the packed engine (DESIGN.md
/// §Packed-Threading). All variants are bit-identical; they differ only
/// in how many words they reduce per step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PopcountKernel {
    /// Best available at runtime: AVX2 when the CPU has it, else the
    /// 8-word unrolled chunks.
    Auto,
    /// One `u64::count_ones` per word — the PR 1 baseline, kept as the
    /// forced-scalar reference for tests and benches.
    Scalar,
    /// 4-word chunked `count_ones`.
    Unroll4,
    /// 8-word chunked `count_ones`.
    Unroll8,
    /// `std::arch` AVX2 nibble-LUT popcount (4 words per 256-bit step).
    /// Falls back to [`PopcountKernel::Unroll8`] where AVX2 is absent.
    Avx2,
    /// `std::arch` aarch64 NEON popcount (`vcntq_u8` per-byte counts +
    /// pairwise widening adds, 2 words per 128-bit step). Falls back to
    /// [`PopcountKernel::Unroll8`] off aarch64.
    Neon,
}

impl PopcountKernel {
    /// Every concrete (non-`Auto`) kernel, for sweeps.
    pub const CONCRETE: [PopcountKernel; 5] = [
        PopcountKernel::Scalar,
        PopcountKernel::Unroll4,
        PopcountKernel::Unroll8,
        PopcountKernel::Avx2,
        PopcountKernel::Neon,
    ];

    /// The concrete kernels that run natively on this CPU — the
    /// reducer axis of the execution planner's candidate space
    /// ([`crate::plan::ExecPlan::candidates`]) and of bench sweeps.
    pub fn available_concrete() -> Vec<PopcountKernel> {
        Self::CONCRETE.iter().copied().filter(|k| k.available()).collect()
    }

    pub fn name(self) -> &'static str {
        match self {
            PopcountKernel::Auto => "auto",
            PopcountKernel::Scalar => "scalar",
            PopcountKernel::Unroll4 => "unroll4",
            PopcountKernel::Unroll8 => "unroll8",
            PopcountKernel::Avx2 => "avx2",
            PopcountKernel::Neon => "neon",
        }
    }

    /// Whether this kernel runs natively on the current CPU (`Avx2` and
    /// `Neon` are the conditional ones; everything else always does).
    pub fn available(self) -> bool {
        match self {
            PopcountKernel::Avx2 => avx2_available(),
            PopcountKernel::Neon => neon_available(),
            _ => true,
        }
    }

    /// Map `Auto` (and an unavailable `Avx2`/`Neon`) to a concrete
    /// kernel via runtime feature detection.
    pub fn resolve(self) -> PopcountKernel {
        match self {
            PopcountKernel::Auto => {
                if avx2_available() {
                    PopcountKernel::Avx2
                } else if neon_available() {
                    PopcountKernel::Neon
                } else {
                    PopcountKernel::Unroll8
                }
            }
            PopcountKernel::Avx2 if !avx2_available() => PopcountKernel::Unroll8,
            PopcountKernel::Neon if !neon_available() => PopcountKernel::Unroll8,
            k => k,
        }
    }

    /// The reducer function: `Σ_w popcount(x_w & y_w)`.
    fn and_pop(self) -> AndPop {
        match self.resolve() {
            PopcountKernel::Scalar => and_pop_scalar,
            PopcountKernel::Unroll4 => and_pop_unrolled::<4>,
            PopcountKernel::Unroll8 => and_pop_unrolled::<8>,
            PopcountKernel::Avx2 => and_pop_avx2,
            PopcountKernel::Neon => and_pop_neon,
            PopcountKernel::Auto => unreachable!("resolve() never returns Auto"),
        }
    }
}

impl std::str::FromStr for PopcountKernel {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<PopcountKernel> {
        match s {
            "auto" => Ok(PopcountKernel::Auto),
            "scalar" => Ok(PopcountKernel::Scalar),
            "unroll4" => Ok(PopcountKernel::Unroll4),
            "unroll8" => Ok(PopcountKernel::Unroll8),
            "avx2" => Ok(PopcountKernel::Avx2),
            "neon" => Ok(PopcountKernel::Neon),
            other => anyhow::bail!(
                "unknown popcount kernel '{other}' (auto|scalar|unroll4|unroll8|avx2|neon)"
            ),
        }
    }
}

type AndPop = fn(&[u64], &[u64]) -> u64;

fn and_pop_scalar(x: &[u64], y: &[u64]) -> u64 {
    x.iter().zip(y).map(|(a, b)| (a & b).count_ones() as u64).sum()
}

/// Chunked reducer: `W` words per step so the compiler can keep `W`
/// independent `popcnt` chains in flight, plus a scalar tail.
fn and_pop_unrolled<const W: usize>(x: &[u64], y: &[u64]) -> u64 {
    let n = x.len().min(y.len());
    let steps = n / W;
    let mut sum = 0u64;
    for s in 0..steps {
        let base = s * W;
        let mut chunk = 0u64;
        for l in 0..W {
            chunk += (x[base + l] & y[base + l]).count_ones() as u64;
        }
        sum += chunk;
    }
    for i in steps * W..n {
        sum += (x[i] & y[i]).count_ones() as u64;
    }
    sum
}

#[cfg(target_arch = "x86_64")]
fn avx2_available() -> bool {
    std::is_x86_feature_detected!("avx2")
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_available() -> bool {
    false
}

#[cfg(target_arch = "x86_64")]
fn and_pop_avx2(x: &[u64], y: &[u64]) -> u64 {
    // Safety: this entry is only installed by `PopcountKernel::resolve`
    // after `is_x86_feature_detected!("avx2")` returned true.
    unsafe { avx2::and_popcount(x, y) }
}

#[cfg(not(target_arch = "x86_64"))]
fn and_pop_avx2(x: &[u64], y: &[u64]) -> u64 {
    and_pop_unrolled::<8>(x, y)
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    //! Mula-style nibble-LUT popcount: per 256-bit step, AND the
    //! operands, table-look-up each nibble's popcount with `vpshufb`,
    //! and horizontally add bytes into 64-bit lanes with `vpsadbw`.
    use std::arch::x86_64::*;

    /// `Σ_w popcount(x_w & y_w)` over 4 `u64` words per vector step.
    ///
    /// # Safety
    /// Caller must have verified AVX2 support at runtime.
    #[target_feature(enable = "avx2")]
    pub unsafe fn and_popcount(x: &[u64], y: &[u64]) -> u64 {
        let n = x.len().min(y.len());
        let lut = _mm256_setr_epi8(
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, //
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
        );
        let low_nibble = _mm256_set1_epi8(0x0f);
        let zero = _mm256_setzero_si256();
        let mut acc = zero;
        let steps = n / 4;
        for s in 0..steps {
            let xv = _mm256_loadu_si256(x.as_ptr().add(4 * s) as *const __m256i);
            let yv = _mm256_loadu_si256(y.as_ptr().add(4 * s) as *const __m256i);
            let v = _mm256_and_si256(xv, yv);
            let lo = _mm256_and_si256(v, low_nibble);
            let hi = _mm256_and_si256(_mm256_srli_epi16(v, 4), low_nibble);
            let counts = _mm256_add_epi8(
                _mm256_shuffle_epi8(lut, lo),
                _mm256_shuffle_epi8(lut, hi),
            );
            acc = _mm256_add_epi64(acc, _mm256_sad_epu8(counts, zero));
        }
        let mut lanes = [0u64; 4];
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
        let mut sum: u64 = lanes.iter().sum();
        for i in 4 * steps..n {
            sum += (x[i] & y[i]).count_ones() as u64;
        }
        sum
    }
}

#[cfg(target_arch = "aarch64")]
fn neon_available() -> bool {
    std::arch::is_aarch64_feature_detected!("neon")
}

#[cfg(not(target_arch = "aarch64"))]
fn neon_available() -> bool {
    false
}

#[cfg(target_arch = "aarch64")]
fn and_pop_neon(x: &[u64], y: &[u64]) -> u64 {
    // Safety: this entry is only installed by `PopcountKernel::resolve`
    // after `is_aarch64_feature_detected!("neon")` returned true.
    unsafe { neon::and_popcount(x, y) }
}

#[cfg(not(target_arch = "aarch64"))]
fn and_pop_neon(x: &[u64], y: &[u64]) -> u64 {
    and_pop_unrolled::<8>(x, y)
}

#[cfg(target_arch = "aarch64")]
mod neon {
    //! NEON popcount: per 128-bit step, AND the operands, count bits
    //! per byte with `vcntq_u8`, and widen the byte counts into 64-bit
    //! lanes with the pairwise-add ladder (`vpaddlq_u8/u16/u32`). Lane
    //! accumulation cannot overflow: each step adds ≤ 128 to a u64.
    use std::arch::aarch64::*;

    /// `Σ_w popcount(x_w & y_w)` over 2 `u64` words per vector step.
    ///
    /// # Safety
    /// Caller must have verified NEON support at runtime.
    #[target_feature(enable = "neon")]
    pub unsafe fn and_popcount(x: &[u64], y: &[u64]) -> u64 {
        let n = x.len().min(y.len());
        let mut acc = vdupq_n_u64(0);
        let steps = n / 2;
        for s in 0..steps {
            let xv = vld1q_u64(x.as_ptr().add(2 * s));
            let yv = vld1q_u64(y.as_ptr().add(2 * s));
            let v = vandq_u64(xv, yv);
            let counts = vcntq_u8(vreinterpretq_u8_u64(v));
            acc = vaddq_u64(acc, vpaddlq_u32(vpaddlq_u16(vpaddlq_u8(counts))));
        }
        let mut sum = vgetq_lane_u64::<0>(acc) + vgetq_lane_u64::<1>(acc);
        for i in 2 * steps..n {
            sum += (x[i] & y[i]).count_ones() as u64;
        }
        sum
    }
}

/// The one statement of the packed-operand tile contract, shared by
/// the serial and pooled kernels so they cannot drift.
fn check_tile(
    a: &PackedPlanes,
    b: &PackedPlanes,
    row0: usize,
    tm: usize,
    col0: usize,
    tn: usize,
) -> Result<()> {
    anyhow::ensure!(
        a.len == b.len,
        "contracted dims differ: {} vs {}",
        a.len,
        b.len
    );
    anyhow::ensure!(
        row0 + tm <= a.vectors && col0 + tn <= b.vectors,
        "tile {row0}+{tm} / {col0}+{tn} exceeds {}x{} packed operands",
        a.vectors,
        b.vectors
    );
    Ok(())
}

/// The single shared plane-pair reducer behind every kind pair:
/// with `digit = pos − neg` on both sides, the signed binary dot is
/// `pp − pn − np + nn`, each term one word-`AND` popcount. SBMwC
/// operands have no negative stream, so their terms vanish — the
/// SBMwC×SBMwC engine default stays a single `AND`+popcount pass.
#[inline]
fn plane_pair_dot(
    and_pop: AndPop,
    ap: &[u64],
    an: Option<&[u64]>,
    bp: &[u64],
    bn: Option<&[u64]>,
) -> i64 {
    let mut dot = and_pop(ap, bp) as i64;
    if let Some(bn) = bn {
        dot -= and_pop(ap, bn) as i64;
    }
    if let Some(an) = an {
        dot -= and_pop(an, bp) as i64;
        if let Some(bn) = bn {
            dot += and_pop(an, bn) as i64;
        }
    }
    dot
}

// ---------------------------------------------------------------------------
// Kernels
// ---------------------------------------------------------------------------

/// Packed plane-pair matmul: `a` holds the rows of `A` (m vectors of
/// length k), `b` the columns of `B` (n vectors of length k). Returns
/// the exact `m × n` i64 accumulators, bit-identical to
/// [`crate::nn::matmul_native`].
pub fn matmul_packed_planes(a: &PackedPlanes, b: &PackedPlanes) -> Result<Vec<i64>> {
    matmul_packed_tile(a, b, 0, a.vectors, 0, b.vectors)
}

/// Tile view of [`matmul_packed_planes`] with the default
/// ([`PopcountKernel::Auto`]) reducer: rows `row0 .. row0+tm` of A
/// against columns `col0 .. col0+tn` of B, selected by index so
/// neither operand is re-packed per tile. Returns a `tm × tn` tile.
pub fn matmul_packed_tile(
    a: &PackedPlanes,
    b: &PackedPlanes,
    row0: usize,
    tm: usize,
    col0: usize,
    tn: usize,
) -> Result<Vec<i64>> {
    matmul_packed_tile_with(a, b, row0, tm, col0, tn, PopcountKernel::Auto)
}

/// [`matmul_packed_tile`] with an explicit popcount reducer (benches
/// sweep these; tests force [`PopcountKernel::Scalar`]).
///
/// Realises `A·B = Σ_{i,j} w_i w_j (D_i(A)·D_j(B))` with every binary
/// plane-pair product going through the one shared [`plane_pair_dot`]
/// reducer; the signed plane weights carry the SBMwC MSb-plane
/// correction.
pub fn matmul_packed_tile_with(
    a: &PackedPlanes,
    b: &PackedPlanes,
    row0: usize,
    tm: usize,
    col0: usize,
    tn: usize,
    kernel: PopcountKernel,
) -> Result<Vec<i64>> {
    let nw = a.words;
    matmul_packed_tile_words(a, b, row0, tm, col0, tn, kernel, 0, nw)
}

/// [`matmul_packed_tile_with`] restricted to packed words
/// `w0 .. w0+nw` of the contracted dimension — the per-chunk kernel of
/// the deterministic k-split. Tail bits are masked at pack time, so
/// word-aligned chunks partition every dot product exactly: summing the
/// chunk tiles (in any fixed order — i64 adds are exact) reproduces the
/// full-range kernel bit for bit. The full range `(0, words)` *is* the
/// classic kernel.
fn matmul_packed_tile_words(
    a: &PackedPlanes,
    b: &PackedPlanes,
    row0: usize,
    tm: usize,
    col0: usize,
    tn: usize,
    kernel: PopcountKernel,
    w0: usize,
    nw: usize,
) -> Result<Vec<i64>> {
    check_tile(a, b, row0, tm, col0, tn)?;
    anyhow::ensure!(
        w0 + nw <= a.words,
        "k-chunk words {w0}+{nw} exceed the {}-word pack",
        a.words
    );
    let and_pop = kernel.and_pop();
    let mut out = vec![0i64; tm * tn];
    for i in 0..a.bits as usize {
        let wa = plane_weight(a.kind, i as u32, a.bits);
        for j in 0..b.bits as usize {
            let w = wa * plane_weight(b.kind, j as u32, b.bits);
            for r in 0..tm {
                let ap = &a.plane_pos(i, row0 + r)[w0..w0 + nw];
                let an = a.plane_neg(i, row0 + r).map(|s| &s[w0..w0 + nw]);
                let orow = &mut out[r * tn..(r + 1) * tn];
                for (c, o) in orow.iter_mut().enumerate() {
                    let bp = &b.plane_pos(j, col0 + c)[w0..w0 + nw];
                    let bn = b.plane_neg(j, col0 + c).map(|s| &s[w0..w0 + nw]);
                    *o += w * plane_pair_dot(and_pop, ap, an, bp, bn);
                }
            }
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// RSR segment kernels (redundant-segment reuse)
// ---------------------------------------------------------------------------

/// The plane-pair kernel family a plan runs — the family axis of
/// [`crate::plan::ExecPlan`] (DESIGN.md §Sub-popcount-Kernels).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelFamily {
    /// The direct AND+popcount engine: one [`plane_pair_dot`] per
    /// (row, column, plane pair).
    Popcount,
    /// Redundant-segment reuse: dedupe the stationary operand's column
    /// word-patterns per segment ([`SegmentTable`]) and serve each
    /// output column from shared segment dots — sub-popcount exactly
    /// when columns repeat (the 1–2 bit quantized-weight regime).
    Rsr {
        /// Packed words per shared segment (0 = auto via
        /// [`SegmentTable::auto_seg_words`]).
        seg_words: u32,
    },
}

impl Default for KernelFamily {
    fn default() -> KernelFamily {
        KernelFamily::Popcount
    }
}

impl KernelFamily {
    pub fn name(self) -> &'static str {
        match self {
            KernelFamily::Popcount => "popcount",
            KernelFamily::Rsr { .. } => "rsr",
        }
    }
}

/// Deduplicated column word-patterns of a stationary-operand tile,
/// per (plane, stream, segment) — built **once per (plane, tile)** and
/// amortized over every streamed row and plane of the left operand.
///
/// The contracted dimension's `words` packed words split into segments
/// of `seg_words` words. Within one segment, two columns whose word
/// patterns agree need only one AND+popcount against any left-operand
/// row: the kernel computes each segment's `D` distinct dots, then
/// serves all `tn` columns by indexed add. Against the direct kernel's
/// `tn` popcounts per segment this wins exactly when `D` (plus the
/// per-column add) undercuts `tn` — real 1–2 bit quantized weights are
/// heavily redundant, uniform random operands are not, which is why the
/// planner calibrates rather than assumes (see `plan/cost.rs`).
pub struct SegmentTable {
    /// Words per segment actually used (auto resolved at build time).
    pub seg_words: usize,
    bits: u32,
    kind: PlaneKind,
    len: usize,
    tn: usize,
    nstreams: usize,
    /// Per (plane, stream) segment lists, plane-major:
    /// `streams[plane * nstreams + stream]`; stream 0 = pos, 1 = neg.
    streams: Vec<Vec<SegPatterns>>,
}

/// One segment's deduplicated patterns: `patterns` holds `D` distinct
/// `nw`-word patterns flattened, `ids[c]` names column `c`'s pattern.
struct SegPatterns {
    w0: usize,
    nw: usize,
    patterns: Vec<u64>,
    ids: Vec<u32>,
}

impl SegmentTable {
    /// Auto segment length in words: short segments maximise pattern
    /// collisions (`D` is capped by the distinct patterns that *can*
    /// occur), longer ones amortise the per-column indexed adds; two
    /// words only pays once there are enough words to share and few
    /// enough columns that collisions survive the doubled pattern
    /// space.
    pub fn auto_seg_words(words: usize, tn: usize) -> usize {
        if tn <= 64 && words >= 4 {
            2
        } else {
            1
        }
    }

    /// Dedupe columns `col0 .. col0+tn` of the stationary pack `b`
    /// (`seg_words = 0` → auto).
    pub fn build(b: &PackedPlanes, col0: usize, tn: usize, seg_words: usize) -> Result<SegmentTable> {
        anyhow::ensure!(
            col0 + tn <= b.vectors,
            "segment table {col0}+{tn} exceeds {} packed columns",
            b.vectors
        );
        let seg_words = match seg_words {
            0 => Self::auto_seg_words(b.words, tn),
            s => s,
        }
        .min(b.words.max(1));
        let nstreams = if b.neg.is_empty() { 1 } else { 2 };
        let mut streams = Vec::with_capacity(b.bits as usize * nstreams);
        for plane in 0..b.bits as usize {
            for stream in 0..nstreams {
                let mut segs = Vec::new();
                let mut w0 = 0usize;
                while w0 < b.words {
                    let nw = seg_words.min(b.words - w0);
                    let mut patterns: Vec<u64> = Vec::new();
                    let mut ids = Vec::with_capacity(tn);
                    let mut index: HashMap<Vec<u64>, u32> = HashMap::new();
                    for c in 0..tn {
                        let col = if stream == 0 {
                            b.plane_pos(plane, col0 + c)
                        } else {
                            b.plane_neg(plane, col0 + c).expect("stream 1 only for Booth")
                        };
                        let id = *index.entry(col[w0..w0 + nw].to_vec()).or_insert_with_key(|k| {
                            let id = (patterns.len() / nw) as u32;
                            patterns.extend_from_slice(k);
                            id
                        });
                        ids.push(id);
                    }
                    segs.push(SegPatterns { w0, nw, patterns, ids });
                    w0 += nw;
                }
                streams.push(segs);
            }
        }
        Ok(SegmentTable {
            seg_words,
            bits: b.bits,
            kind: b.kind,
            len: b.len,
            tn,
            nstreams,
            streams,
        })
    }

    /// Total distinct patterns across every (plane, stream, segment) —
    /// `≪ tn × segments × planes` exactly when RSR pays off.
    pub fn distinct(&self) -> usize {
        self.streams
            .iter()
            .flatten()
            .map(|s| s.patterns.len() / s.nw.max(1))
            .sum()
    }

    /// Column patterns the table replaced (`tn` per segment per plane
    /// per stream) — `distinct() / replaced()` is the measured
    /// redundancy ratio ρ the cost model assumes for 1–2 bit operands.
    pub fn replaced(&self) -> usize {
        self.streams.iter().map(|s| s.len() * self.tn).sum()
    }
}

/// The RSR matmul tile: [`matmul_packed_tile_with`]'s contract, served
/// from a [`SegmentTable`] built once for the whole tile.
///
/// **Bit-identity.** Per (row, plane pair) the direct kernel computes
/// `pp − pn − np + nn` over the full word range; the word-wise AND
/// popcount distributes over word-aligned segments, so summing each
/// column's (shared) segment dots — all exact i64 integers — is a pure
/// re-association of the same sum and yields the identical value, for
/// both plane kinds and any segment length.
pub fn matmul_packed_rsr(
    a: &PackedPlanes,
    b: &PackedPlanes,
    row0: usize,
    tm: usize,
    col0: usize,
    tn: usize,
    kernel: PopcountKernel,
    seg_words: usize,
) -> Result<Vec<i64>> {
    check_tile(a, b, row0, tm, col0, tn)?;
    let table = SegmentTable::build(b, col0, tn, seg_words)?;
    matmul_packed_rsr_with_table(a, &table, row0, tm, kernel)
}

/// [`matmul_packed_rsr`] against a pre-built [`SegmentTable`] (the
/// serving steady state: the stationary operand's table outlives many
/// streamed rows).
pub fn matmul_packed_rsr_with_table(
    a: &PackedPlanes,
    t: &SegmentTable,
    row0: usize,
    tm: usize,
    kernel: PopcountKernel,
) -> Result<Vec<i64>> {
    anyhow::ensure!(
        a.len == t.len,
        "contracted dims differ: {} vs {}",
        a.len,
        t.len
    );
    anyhow::ensure!(
        row0 + tm <= a.vectors,
        "rows {row0}+{tm} exceed {} packed rows",
        a.vectors
    );
    let and_pop = kernel.and_pop();
    let tn = t.tn;
    let a_streams = if a.neg.is_empty() { 1 } else { 2 };
    let mut out = vec![0i64; tm * tn];
    let mut dots: Vec<i64> = Vec::new();
    let mut col_acc = vec![0i64; tn];
    for i in 0..a.bits as usize {
        let wa = plane_weight(a.kind, i as u32, a.bits);
        for j in 0..t.bits as usize {
            let w = wa * plane_weight(t.kind, j as u32, t.bits);
            for r in 0..tm {
                for v in col_acc.iter_mut() {
                    *v = 0;
                }
                // signed plane-pair dot per column (pp − pn − np + nn),
                // each term served from this stream pair's segment sums
                for sa in 0..a_streams {
                    let aw = if sa == 0 {
                        a.plane_pos(i, row0 + r)
                    } else {
                        a.plane_neg(i, row0 + r).expect("stream 1 only for Booth")
                    };
                    for sb in 0..t.nstreams {
                        let sign: i64 = if sa == sb { 1 } else { -1 };
                        for seg in &t.streams[j * t.nstreams + sb] {
                            let d = seg.patterns.len() / seg.nw;
                            dots.clear();
                            for p in 0..d {
                                let pat = &seg.patterns[p * seg.nw..(p + 1) * seg.nw];
                                dots.push(and_pop(&aw[seg.w0..seg.w0 + seg.nw], pat) as i64);
                            }
                            for (acc, &id) in col_acc.iter_mut().zip(&seg.ids) {
                                *acc += sign * dots[id as usize];
                            }
                        }
                    }
                }
                let orow = &mut out[r * tn..(r + 1) * tn];
                for (o, &acc) in orow.iter_mut().zip(&col_acc) {
                    *o += w * acc;
                }
            }
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Worker pool + row-block threading
// ---------------------------------------------------------------------------

type PoolJob = Box<dyn FnOnce() + Send + 'static>;

/// A persistent `std::thread` worker pool for packed-kernel row blocks
/// (DESIGN.md §Packed-Threading). The inference server builds **one**
/// pool sized by `server.packed_threads` and shares it (`Arc`) across
/// every request worker's scheduler, so kernel parallelism *composes
/// with* — rather than multiplies against — request-level parallelism.
/// Dropping the pool closes the job channel and joins the workers.
pub struct PackedPool {
    tx: Mutex<Option<mpsc::Sender<PoolJob>>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// Fault injection: how many upcoming slot jobs to drop instead of
    /// enqueueing (chaos testing). Dropping is masked by construction:
    /// the caller's inline steal slot drains every deque, so the tiles
    /// seeded to a dropped job are stolen and the merge still sees all
    /// of them.
    drop_next: std::sync::atomic::AtomicUsize,
}

impl PackedPool {
    /// Spawn `threads ≥ 1` persistent workers pulling from one shared
    /// job queue.
    pub fn new(threads: usize) -> Result<PackedPool> {
        anyhow::ensure!(threads >= 1, "packed pool needs at least one thread");
        let (tx, rx) = mpsc::channel::<PoolJob>();
        let rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::with_capacity(threads);
        for i in 0..threads {
            let rx = rx.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("bitsmm-packed-{i}"))
                    .spawn(move || loop {
                        // hold the lock only while dequeueing; recover
                        // the guard if a sibling panicked mid-dequeue —
                        // the channel itself is never left inconsistent
                        let job = rx.lock().unwrap_or_else(|e| e.into_inner()).recv();
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // channel closed: pool dropped
                        }
                    })?,
            );
        }
        Ok(PackedPool {
            tx: Mutex::new(Some(tx)),
            workers,
            drop_next: std::sync::atomic::AtomicUsize::new(0),
        })
    }

    /// Worker count (= concurrent row blocks a matmul is split into).
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Fault injection: silently drop the next `n` submitted jobs (as
    /// if their worker died before running them). Work-stealing masks
    /// the loss — see the field doc on `drop_next`.
    pub fn inject_drop_jobs(&self, n: usize) {
        self.drop_next
            .fetch_add(n, std::sync::atomic::Ordering::Relaxed);
    }

    fn execute(&self, job: PoolJob) -> Result<()> {
        if self
            .drop_next
            .fetch_update(
                std::sync::atomic::Ordering::Relaxed,
                std::sync::atomic::Ordering::Relaxed,
                |v| v.checked_sub(1),
            )
            .is_ok()
        {
            drop(job); // injected fault: the job never reaches a worker
            return Ok(());
        }
        let guard = self.tx.lock().unwrap_or_else(|e| e.into_inner());
        let tx = guard
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("packed pool already closed"))?;
        // send fails only when every worker has exited (e.g. all
        // panicked): surface an error the caller can handle instead of
        // taking its thread down
        tx.send(job)
            .map_err(|_| anyhow::anyhow!("packed pool workers exited early"))?;
        Ok(())
    }
}

impl Drop for PackedPool {
    fn drop(&mut self) {
        // close the queue, then join: workers drain remaining jobs
        *self.tx.lock().unwrap_or_else(|e| e.into_inner()) = None;
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Work-stealing 2-D tile scheduler
// ---------------------------------------------------------------------------

/// Tile-granularity knobs for the work-stealing 2-D scheduler
/// (`server.packed_tile_rows` / `server.packed_tile_cols` /
/// `server.packed_ksplit` in configs, `--packed-tile-rows` /
/// `--packed-tile-cols` / `--packed-ksplit` on `serve`). `0` means
/// *auto*: adapt the dimension to the shape, word count, and worker
/// count via [`plan_tile_shape`] (and [`plan_k_chunks`] for the
/// contracted dimension).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct TilePolicy {
    /// Output rows per tile job (0 = auto).
    pub tile_rows: usize,
    /// Output columns per tile job (0 = auto).
    pub tile_cols: usize,
    /// Contracted-dimension chunks per tile (0 = auto: split only when
    /// the output grid alone cannot feed every slot; 1 = never split —
    /// the pre-k-split scheduler; n ≥ 2 = force n word-aligned chunks,
    /// clamped to the word count).
    pub k_chunks: usize,
}

impl TilePolicy {
    /// Adapt every dimension (the server default).
    pub const AUTO: TilePolicy = TilePolicy {
        tile_rows: 0,
        tile_cols: 0,
        k_chunks: 0,
    };

    /// Auto output tiles with k-splitting disabled — the exact PR 4
    /// scheduler, kept as the forced baseline for A/B sweeps.
    pub const NO_KSPLIT: TilePolicy = TilePolicy {
        tile_rows: 0,
        tile_cols: 0,
        k_chunks: 1,
    };
}

/// Telemetry of one work-stealing run, surfaced through
/// `ExecutionReport` and the server metrics.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StealStats {
    /// Tile jobs the matmul was decomposed into.
    pub tiles: u64,
    /// Tiles a slot took from another slot's deque (0 on a perfectly
    /// pre-balanced run).
    pub steals: u64,
    /// Largest per-slot executed-tile count (the caller's inline slot
    /// included) — with `min_worker_tiles`, the imbalance measure the
    /// stealing exists to fix.
    pub max_worker_tiles: u64,
    /// Smallest per-slot executed-tile count (may be 0 when a shared
    /// pool's workers were busy elsewhere and the caller drained the
    /// run itself).
    pub min_worker_tiles: u64,
}

impl StealStats {
    pub fn merge(&mut self, o: &StealStats) {
        // `tiles` discriminates "recorded a run" from the zero default,
        // so a genuine 0 minimum share (a starved slot) survives the
        // merge instead of being mistaken for "no data"
        self.min_worker_tiles = if self.tiles == 0 {
            o.min_worker_tiles
        } else if o.tiles == 0 {
            self.min_worker_tiles
        } else {
            self.min_worker_tiles.min(o.min_worker_tiles)
        };
        self.tiles += o.tiles;
        self.steals += o.steals;
        self.max_worker_tiles = self.max_worker_tiles.max(o.max_worker_tiles);
    }

    /// JSON object for the telemetry snapshot (DESIGN.md
    /// §Observability). Raw counters only — the derived imbalance
    /// ratio (which can be non-finite) is the snapshot layer's job.
    pub fn json(&self) -> String {
        format!(
            "{{\"tiles\":{},\"steals\":{},\"max_worker_tiles\":{},\"min_worker_tiles\":{}}}",
            self.tiles, self.steals, self.max_worker_tiles, self.min_worker_tiles
        )
    }
}

/// Per-slot oversubscription: enough tile jobs per worker that
/// steal-on-empty can rebalance stragglers, few enough that dispatch
/// overhead stays negligible against tile work.
const TILE_OVERSUBSCRIBE: usize = 4;

/// Smallest tile worth its dispatch, in word-AND-popcount operations
/// (auto-planned tiles grow until they clear this floor or parallelism
/// would drop below the slot count). Public because the execution
/// planner's cost model ([`crate::plan`]) uses the same floor to
/// decide when a matmul is worth pooling at all.
pub const MIN_TILE_WORK: u64 = 1 << 15;

/// Smallest k-chunk of a split tile worth its dispatch, in word
/// operations — an eighth of [`MIN_TILE_WORK`]: chunk jobs reuse the
/// tile's packed operands and merge at one i64 add per output cell, so
/// they stay profitable well below the tile floor.
pub const MIN_KSPLIT_WORK: u64 = MIN_TILE_WORK / 8;

/// Plan the contracted-dimension chunk count for a stolen run whose
/// output grid came out as `ntiles` tiles of `tile_work` word
/// operations each, over `words` packed words.
///
/// Auto (`k_chunks = 0`) splits only when the output grid alone cannot
/// feed every slot — the huge-k regime (`1×hugek×n`) where tiles would
/// otherwise serialize — and grows the chunk count toward the
/// oversubscription target while every chunk still clears
/// [`MIN_KSPLIT_WORK`]. Forced counts are clamped to the word count:
/// chunks are always word-aligned, so pack-time tail masking keeps
/// every chunk's dot products exact.
pub fn plan_k_chunks(
    words: usize,
    ntiles: usize,
    slots: usize,
    tile_work: u64,
    policy: TilePolicy,
) -> usize {
    match policy.k_chunks {
        0 => {
            if words <= 1 || ntiles >= slots.max(1) {
                return 1;
            }
            let target = (slots.max(1) * TILE_OVERSUBSCRIBE).div_ceil(ntiles.max(1));
            let by_work = (tile_work / MIN_KSPLIT_WORK).max(1) as usize;
            words.min(target).min(by_work).max(1)
        }
        c => c.min(words.max(1)),
    }
}

/// Plan the `(tile_rows, tile_cols)` job granularity for a `tm × tn`
/// output executed by `slots` workers, where one output element costs
/// `cell_work` word operations (`bits_a · bits_b · words`).
///
/// Rows are split first (each row job streams contiguous plane words of
/// the packed left operand); columns supply the parallelism rows cannot
/// — a `1×k×4096` request still yields `slots`-way parallelism via
/// column blocks. Auto-planned dimensions then grow (columns first)
/// until every tile clears [`MIN_TILE_WORK`] or tiles would drop below
/// the slot count; explicit [`TilePolicy`] dimensions are respected as
/// given (clamped to the shape).
pub fn plan_tile_shape(
    tm: usize,
    tn: usize,
    cell_work: u64,
    slots: usize,
    policy: TilePolicy,
) -> (usize, usize) {
    if tm == 0 || tn == 0 {
        return (tm.max(1), tn.max(1));
    }
    let slots = slots.max(1);
    let target = slots * TILE_OVERSUBSCRIBE;
    let row_splits = tm.min(target);
    let col_splits = tn.min(target.div_ceil(row_splits));
    let mut tr = match policy.tile_rows {
        0 => tm.div_ceil(row_splits),
        r => r.min(tm),
    };
    let mut tc = match policy.tile_cols {
        0 => tn.div_ceil(col_splits),
        c => c.min(tn),
    };
    loop {
        let tiles = tm.div_ceil(tr) * tn.div_ceil(tc);
        if tiles <= slots || tr as u64 * tc as u64 * cell_work.max(1) >= MIN_TILE_WORK {
            break;
        }
        if policy.tile_cols == 0 && tc < tn {
            tc = (tc * 2).min(tn);
        } else if policy.tile_rows == 0 && tr < tm {
            tr = (tr * 2).min(tm);
        } else {
            break;
        }
    }
    (tr, tc)
}

/// One job of a stolen matmul: a 2-D output tile restricted to the
/// packed words `w0 .. w0+nwords` of the contracted dimension (the full
/// range when the tile is not k-split); coordinates are relative to the
/// requested tile view. `idx` is the row-major (tile, chunk) position
/// and doubles as the deterministic merge order.
#[derive(Debug, Clone, Copy)]
struct TileJob2d {
    idx: usize,
    r0: usize,
    rows: usize,
    c0: usize,
    cols: usize,
    w0: usize,
    nwords: usize,
}

/// Shared state of one work-stealing run: per-slot deques seeded with
/// contiguous chunks of the tile list, plus the telemetry counters.
/// Counter loads in the caller are ordered after every increment by the
/// result channel (each increment is sequenced before that slot's send,
/// and the caller receives all sends before reading).
struct StealSet {
    deques: Vec<Mutex<VecDeque<TileJob2d>>>,
    steals: AtomicU64,
    executed: Vec<AtomicU64>,
}

impl StealSet {
    fn new(slots: usize, tiles: &[TileJob2d]) -> StealSet {
        let n = tiles.len();
        StealSet {
            // balanced contiguous chunks, like the row-slice partition
            deques: (0..slots)
                .map(|s| Mutex::new(tiles[s * n / slots..(s + 1) * n / slots].iter().copied().collect()))
                .collect(),
            steals: AtomicU64::new(0),
            executed: (0..slots).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Own chunk first (front of the own deque, preserving locality),
    /// then steal from the *back* of the other slots' deques, scanning
    /// from the next slot so concurrent thieves spread over victims.
    /// Poisoned deques are recovered, not propagated: a tile job that
    /// panicked mid-run must not cascade panics into every other
    /// kernel worker — the collector's lost-job count already surfaces
    /// the real failure as an `Err` (tiles are popped *before* they
    /// run, so a recovered deque is always structurally sound).
    fn next(&self, slot: usize) -> Option<TileJob2d> {
        if let Some(t) = self.deques[slot]
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .pop_front()
        {
            return Some(t);
        }
        let slots = self.deques.len();
        for off in 1..slots {
            let victim = (slot + off) % slots;
            if let Some(t) = self.deques[victim]
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .pop_back()
            {
                self.steals.fetch_add(1, Ordering::Relaxed);
                return Some(t);
            }
        }
        None
    }
}

/// One slot's drain loop: run tiles (own, then stolen) until every
/// deque is empty, sending each tile's result to the collector.
fn run_steal_slot(
    set: &StealSet,
    slot: usize,
    a: &PackedPlanes,
    b: &PackedPlanes,
    row0: usize,
    col0: usize,
    kernel: PopcountKernel,
    family: KernelFamily,
    tx: &mpsc::Sender<(usize, Result<Vec<i64>>)>,
) {
    while let Some(t) = set.next(slot) {
        let part = match family {
            KernelFamily::Popcount => matmul_packed_tile_words(
                a, b, row0 + t.r0, t.rows, col0 + t.c0, t.cols, kernel, t.w0, t.nwords,
            ),
            // RSR jobs always carry the full word range (the scheduler
            // never k-splits them); the segment table is built once per
            // tile, amortized over the tile's rows × plane pairs
            KernelFamily::Rsr { seg_words } => matmul_packed_rsr(
                a, b, row0 + t.r0, t.rows, col0 + t.c0, t.cols, kernel, seg_words as usize,
            ),
        };
        set.executed[slot].fetch_add(1, Ordering::Relaxed);
        if tx.send((t.idx, part)).is_err() {
            break; // collector bailed on an earlier tile error
        }
    }
}

/// [`matmul_packed_tile_with`], decomposed into work-stolen 2-D output
/// tiles across the pool's workers *and* the calling thread (the
/// caller drains tiles too, so a shared pool busy with other requests
/// delays but never starves a run).
///
/// **Determinism.** Output tiles partition the output: every element
/// is produced by the tile(s) covering it. An unsplit tile accumulates
/// its elements in the exact plane-pair order of the single-thread
/// path; a k-split tile's word-aligned chunk partials are exact i64
/// sums that merge by addition in fixed chunk-index order — a pure
/// re-association of the same integer sum, so either way the pooled
/// output is bit-identical to [`matmul_packed_tile_with`] by
/// construction, regardless of which slot ran which job when. Operands
/// travel as `Arc` clones — no packing, no copying.
pub fn matmul_packed_tile_stolen(
    pool: &PackedPool,
    a: &Arc<PackedPlanes>,
    b: &Arc<PackedPlanes>,
    row0: usize,
    tm: usize,
    col0: usize,
    tn: usize,
    kernel: PopcountKernel,
    policy: TilePolicy,
) -> Result<(Vec<i64>, StealStats)> {
    matmul_packed_tile_stolen_with(
        pool,
        a,
        b,
        row0,
        tm,
        col0,
        tn,
        kernel,
        policy,
        KernelFamily::Popcount,
    )
}

/// [`matmul_packed_tile_stolen`] with an explicit [`KernelFamily`] —
/// the executor entry the plan layer drives. RSR tiles are never
/// k-split (their segment tables span the full contracted dimension);
/// popcount tiles split per [`plan_k_chunks`].
pub fn matmul_packed_tile_stolen_with(
    pool: &PackedPool,
    a: &Arc<PackedPlanes>,
    b: &Arc<PackedPlanes>,
    row0: usize,
    tm: usize,
    col0: usize,
    tn: usize,
    kernel: PopcountKernel,
    policy: TilePolicy,
    family: KernelFamily,
) -> Result<(Vec<i64>, StealStats)> {
    // fail fast on a bad tile before dispatching any work
    check_tile(a, b, row0, tm, col0, tn)?;
    let slots = pool.threads() + 1; // + the caller's inline slot
    let cell_work = a.bits as u64 * b.bits as u64 * a.words as u64;
    let (tr, tc) = plan_tile_shape(tm, tn, cell_work, slots, policy);
    let grid_r = if tm == 0 { 0 } else { tm.div_ceil(tr) };
    let grid_c = if tn == 0 { 0 } else { tn.div_ceil(tc) };
    let ntiles = grid_r * grid_c;
    let chunks = match family {
        KernelFamily::Rsr { .. } => 1,
        KernelFamily::Popcount => {
            plan_k_chunks(a.words, ntiles, slots, tr as u64 * tc as u64 * cell_work, policy)
        }
    };
    let njobs = ntiles * chunks;
    if njobs <= 1 {
        let out = match family {
            KernelFamily::Popcount => matmul_packed_tile_with(a, b, row0, tm, col0, tn, kernel)?,
            KernelFamily::Rsr { seg_words } => {
                matmul_packed_rsr(a, b, row0, tm, col0, tn, kernel, seg_words as usize)?
            }
        };
        let tiles = njobs as u64;
        return Ok((
            out,
            StealStats {
                tiles,
                steals: 0,
                max_worker_tiles: tiles,
                min_worker_tiles: tiles,
            },
        ));
    }
    let words = a.words;
    let mut jobs = Vec::with_capacity(njobs);
    for gr in 0..grid_r {
        for gc in 0..grid_c {
            let (r0, c0) = (gr * tr, gc * tc);
            for ch in 0..chunks {
                // balanced word-aligned chunk ranges (tail chunks may
                // be one word shorter)
                let w0 = ch * words / chunks;
                let w1 = (ch + 1) * words / chunks;
                jobs.push(TileJob2d {
                    idx: jobs.len(),
                    r0,
                    rows: tr.min(tm - r0),
                    c0,
                    cols: tc.min(tn - c0),
                    w0,
                    nwords: w1 - w0,
                });
            }
        }
    }
    let set = Arc::new(StealSet::new(slots, &jobs));
    let (tx, rx) = mpsc::channel();
    for slot in 0..pool.threads() {
        let (set, a, b, tx) = (set.clone(), a.clone(), b.clone(), tx.clone());
        pool.execute(Box::new(move || {
            run_steal_slot(&set, slot, &a, &b, row0, col0, kernel, family, &tx)
        }))?;
    }
    run_steal_slot(&set, slots - 1, a, b, row0, col0, kernel, family, &tx);
    drop(tx);
    let mut parts: Vec<Option<Vec<i64>>> = (0..njobs).map(|_| None).collect();
    let mut seen = 0usize;
    while let Ok((idx, part)) = rx.recv() {
        parts[idx] = Some(part?);
        seen += 1;
    }
    anyhow::ensure!(
        seen == njobs,
        "packed pool lost {} of {njobs} tile jobs (worker panicked?)",
        njobs - seen
    );
    // deterministic merge: fixed job-index order — distinct tiles cover
    // disjoint regions, and one tile's k-chunk partials add in
    // chunk-index order (exact i64 adds: any fixed order is
    // bit-identical; fixing it makes determinism syntactic)
    let mut out = vec![0i64; tm * tn];
    for j in &jobs {
        let part = parts[j.idx].take().expect("every job counted above");
        for r in 0..j.rows {
            let dst = (j.r0 + r) * tn + j.c0;
            for (o, p) in out[dst..dst + j.cols]
                .iter_mut()
                .zip(&part[r * j.cols..(r + 1) * j.cols])
            {
                *o += p;
            }
        }
    }
    let executed: Vec<u64> = set.executed.iter().map(|c| c.load(Ordering::Relaxed)).collect();
    Ok((
        out,
        StealStats {
            tiles: njobs as u64,
            steals: set.steals.load(Ordering::Relaxed),
            max_worker_tiles: executed.iter().copied().max().unwrap_or(0),
            min_worker_tiles: executed.iter().copied().min().unwrap_or(0),
        },
    ))
}

/// [`matmul_packed_tile_stolen`] with auto tile planning, discarding
/// the telemetry — the drop-in pooled entry point used by benches and
/// callers that predate the 2-D scheduler.
pub fn matmul_packed_tile_pooled(
    pool: &PackedPool,
    a: &Arc<PackedPlanes>,
    b: &Arc<PackedPlanes>,
    row0: usize,
    tm: usize,
    col0: usize,
    tn: usize,
    kernel: PopcountKernel,
) -> Result<Vec<i64>> {
    Ok(matmul_packed_tile_stolen(pool, a, b, row0, tm, col0, tn, kernel, TilePolicy::AUTO)?.0)
}

/// The PR 2 equal-row-slice partitioner, kept as the A/B baseline for
/// `perf_hotpath`'s skewed-shape sweep (and as a differential oracle in
/// tests): `min(threads, tm)` balanced contiguous row blocks, one job
/// each, no column parallelism, no stealing. Bit-identical to the
/// serial kernel for the same reason the stolen scheduler is.
pub fn matmul_packed_tile_rowslice(
    pool: &PackedPool,
    a: &Arc<PackedPlanes>,
    b: &Arc<PackedPlanes>,
    row0: usize,
    tm: usize,
    col0: usize,
    tn: usize,
    kernel: PopcountKernel,
) -> Result<Vec<i64>> {
    let blocks = pool.threads().min(tm);
    if blocks <= 1 {
        return matmul_packed_tile_with(a, b, row0, tm, col0, tn, kernel);
    }
    // fail fast on a bad tile before dispatching any work
    check_tile(a, b, row0, tm, col0, tn)?;
    let (tx, rx) = mpsc::channel();
    for bidx in 0..blocks {
        // balanced partition: every block gets tm/blocks or +1 rows
        let r0 = row0 + bidx * tm / blocks;
        let r1 = row0 + (bidx + 1) * tm / blocks;
        let (a, b, tx) = (a.clone(), b.clone(), tx.clone());
        pool.execute(Box::new(move || {
            let block = matmul_packed_tile_with(&a, &b, r0, r1 - r0, col0, tn, kernel);
            let _ = tx.send((r0 - row0, block));
        }))?;
    }
    drop(tx);
    let mut out = vec![0i64; tm * tn];
    let mut seen = 0usize;
    while let Ok((row_off, block)) = rx.recv() {
        let block = block?;
        out[row_off * tn..row_off * tn + block.len()].copy_from_slice(&block);
        seen += 1;
    }
    anyhow::ensure!(
        seen == blocks,
        "packed pool lost {} of {blocks} row blocks (worker panicked?)",
        blocks - seen
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::twos::{max_value, min_value};
    use crate::prng::Pcg32;
    use crate::sim::driver::ref_matmul_i64 as ref_mm;

    fn rand_mat(rng: &mut Pcg32, len: usize, bits: u32) -> Vec<i32> {
        let (lo, hi) = (min_value(bits), max_value(bits));
        (0..len).map(|_| rng.range_i32(lo, hi)).collect()
    }

    #[test]
    fn pack_unpack_matches_oracle_both_kinds() {
        let mut rng = Pcg32::new(0xbeef);
        for bits in [1u32, 2, 5, 8, 16] {
            // lengths straddling the word boundary exercise tail masking
            for len in [1usize, 7, 63, 64, 65, 130] {
                let data = rand_mat(&mut rng, 3 * len, bits);
                for kind in [PlaneKind::Sbmwc, PlaneKind::Booth] {
                    let p = PackedPlanes::pack_rows(&data, 3, len, bits, kind).unwrap();
                    assert_eq!(p.words, (len + 63) / 64);
                    assert!(p.min_bits <= bits);
                    assert_eq!(p.unpack(), decompose(kind, &data, bits), "{kind:?} {bits}b len={len}");
                }
            }
        }
    }

    #[test]
    fn packed_matmul_exact_all_kind_pairs() {
        let mut rng = Pcg32::new(0x9c0d);
        for bits in [1u32, 3, 8, 11, 16] {
            for (m, k, n) in [(2usize, 7usize, 3usize), (3, 64, 2), (2, 70, 4), (1, 1, 1)] {
                let a = rand_mat(&mut rng, m * k, bits);
                let b = rand_mat(&mut rng, k * n, bits);
                let want = ref_mm(&a, &b, m, k, n);
                for ka in [PlaneKind::Sbmwc, PlaneKind::Booth] {
                    for kb in [PlaneKind::Sbmwc, PlaneKind::Booth] {
                        let pa = PackedPlanes::pack_rows(&a, m, k, bits, ka).unwrap();
                        let pb = PackedPlanes::pack_cols(&b, k, n, bits, kb).unwrap();
                        assert_eq!(
                            matmul_packed_planes(&pa, &pb).unwrap(),
                            want,
                            "{ka:?}x{kb:?} {m}x{k}x{n} @{bits}b"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn every_popcount_kernel_is_bit_identical() {
        let mut rng = Pcg32::new(0x4e11);
        // k values straddle the 4- and 8-word chunk boundaries so every
        // kernel exercises both its wide loop and its scalar tail
        for (m, k, n, bits) in [(3usize, 70usize, 4usize, 8u32), (2, 520, 3, 5), (1, 64, 1, 16)] {
            let a = rand_mat(&mut rng, m * k, bits);
            let b = rand_mat(&mut rng, k * n, bits);
            for ka in [PlaneKind::Sbmwc, PlaneKind::Booth] {
                for kb in [PlaneKind::Sbmwc, PlaneKind::Booth] {
                    let pa = PackedPlanes::pack_rows(&a, m, k, bits, ka).unwrap();
                    let pb = PackedPlanes::pack_cols(&b, k, n, bits, kb).unwrap();
                    let want =
                        matmul_packed_tile_with(&pa, &pb, 0, m, 0, n, PopcountKernel::Scalar)
                            .unwrap();
                    assert_eq!(want, ref_mm(&a, &b, m, k, n));
                    for kernel in PopcountKernel::CONCRETE {
                        assert_eq!(
                            matmul_packed_tile_with(&pa, &pb, 0, m, 0, n, kernel).unwrap(),
                            want,
                            "{} diverged ({ka:?}x{kb:?} {m}x{k}x{n} @{bits}b)",
                            kernel.name()
                        );
                    }
                    assert_eq!(
                        matmul_packed_tile_with(&pa, &pb, 0, m, 0, n, PopcountKernel::Auto)
                            .unwrap(),
                        want
                    );
                }
            }
        }
    }

    #[test]
    fn popcount_kernel_parse_and_resolve() {
        assert_eq!("auto".parse::<PopcountKernel>().unwrap(), PopcountKernel::Auto);
        assert_eq!("scalar".parse::<PopcountKernel>().unwrap(), PopcountKernel::Scalar);
        assert_eq!("unroll4".parse::<PopcountKernel>().unwrap(), PopcountKernel::Unroll4);
        assert_eq!("unroll8".parse::<PopcountKernel>().unwrap(), PopcountKernel::Unroll8);
        assert_eq!("avx2".parse::<PopcountKernel>().unwrap(), PopcountKernel::Avx2);
        assert_eq!("neon".parse::<PopcountKernel>().unwrap(), PopcountKernel::Neon);
        assert!("simd9000".parse::<PopcountKernel>().is_err());
        // Auto always resolves to something concrete and available
        let r = PopcountKernel::Auto.resolve();
        assert_ne!(r, PopcountKernel::Auto);
        assert!(r.available());
        // unavailable SIMD requests degrade instead of erroring
        assert!(PopcountKernel::Avx2.resolve().available());
        assert!(PopcountKernel::Neon.resolve().available());
        // exactly one of the SIMD reducers can be native per arch
        assert!(!(PopcountKernel::Avx2.available() && PopcountKernel::Neon.available()));
    }

    #[test]
    fn sign_plane_saturation_is_exact() {
        // every operand at min_value: the SBMwC MSb (sign) plane is
        // all-ones, maximally exercising the −2^(b−1) correction
        for bits in 1..=16u32 {
            let (m, k, n) = (2usize, 70usize, 2usize);
            let a = vec![min_value(bits); m * k];
            let b = vec![min_value(bits); k * n];
            let pa = PackedPlanes::pack_rows(&a, m, k, bits, PlaneKind::Sbmwc).unwrap();
            let pb = PackedPlanes::pack_cols(&b, k, n, bits, PlaneKind::Sbmwc).unwrap();
            assert_eq!(pa.min_bits, bits, "min_value({bits}) needs every plane");
            assert_eq!(matmul_packed_planes(&pa, &pb).unwrap(), ref_mm(&a, &b, m, k, n), "bits={bits}");
        }
    }

    #[test]
    fn tile_view_matches_full_product() {
        let mut rng = Pcg32::new(0x711e);
        let (m, k, n, bits) = (5usize, 67usize, 9usize, 6u32);
        let a = rand_mat(&mut rng, m * k, bits);
        let b = rand_mat(&mut rng, k * n, bits);
        let pa = PackedPlanes::pack_rows(&a, m, k, bits, PlaneKind::Sbmwc).unwrap();
        let pb = PackedPlanes::pack_cols(&b, k, n, bits, PlaneKind::Sbmwc).unwrap();
        let full = matmul_packed_planes(&pa, &pb).unwrap();
        // a 2×3 tile at (row0=2, col0=5), sliced purely by index
        let tile = matmul_packed_tile(&pa, &pb, 2, 2, 5, 3).unwrap();
        for r in 0..2 {
            for c in 0..3 {
                assert_eq!(tile[r * 3 + c], full[(2 + r) * n + 5 + c]);
            }
        }
        assert!(matmul_packed_tile(&pa, &pb, 4, 2, 0, 1).is_err(), "row overrun");
    }

    #[test]
    fn pooled_matmul_matches_serial_and_reports_errors() {
        let mut rng = Pcg32::new(0x9001);
        let pool = PackedPool::new(3).unwrap();
        assert_eq!(pool.threads(), 3);
        for (m, k, n, bits) in [(1usize, 70usize, 4usize, 8u32), (2, 64, 3, 4), (13, 67, 9, 6)] {
            let a = rand_mat(&mut rng, m * k, bits);
            let b = rand_mat(&mut rng, k * n, bits);
            let pa = Arc::new(PackedPlanes::pack_rows(&a, m, k, bits, PlaneKind::Sbmwc).unwrap());
            let pb = Arc::new(PackedPlanes::pack_cols(&b, k, n, bits, PlaneKind::Booth).unwrap());
            let serial = matmul_packed_tile_with(&pa, &pb, 0, m, 0, n, PopcountKernel::Scalar).unwrap();
            let pooled =
                matmul_packed_tile_pooled(&pool, &pa, &pb, 0, m, 0, n, PopcountKernel::Auto)
                    .unwrap();
            assert_eq!(pooled, serial, "{m}x{k}x{n} @{bits}b");
            // interior tile views thread identically
            if m >= 3 && n >= 4 {
                let t_serial = matmul_packed_tile(&pa, &pb, 1, m - 2, 1, n - 2).unwrap();
                let t_pooled = matmul_packed_tile_pooled(
                    &pool, &pa, &pb, 1, m - 2, 1, n - 2, PopcountKernel::Auto,
                )
                .unwrap();
                assert_eq!(t_pooled, t_serial);
            }
        }
        // oversize tiles are rejected before dispatch
        let a = rand_mat(&mut rng, 4 * 10, 4);
        let pa = Arc::new(PackedPlanes::pack_rows(&a, 4, 10, 4, PlaneKind::Sbmwc).unwrap());
        assert!(matmul_packed_tile_pooled(&pool, &pa, &pa, 0, 5, 0, 1, PopcountKernel::Auto).is_err());
    }

    #[test]
    fn plan_tile_shape_adapts_to_skew() {
        // tall-thin / wide-short: the starved dimension is recovered
        // from the other axis — at least `slots` tiles in every case
        for (tm, tn) in [(1usize, 4096usize), (4096, 1), (1, 9), (64, 4096), (256, 256)] {
            let (tr, tc) = plan_tile_shape(tm, tn, 256, 9, TilePolicy::AUTO);
            assert!(tr >= 1 && tr <= tm && tc >= 1 && tc <= tn, "{tm}x{tn} -> {tr}x{tc}");
            let tiles = tm.div_ceil(tr) * tn.div_ceil(tc);
            assert!(tiles >= 9, "{tm}x{tn} planned only {tiles} tiles");
        }
        // tiny problems stay serial rather than shattering into
        // sub-dispatch-cost fragments
        let (tr, tc) = plan_tile_shape(2, 2, 4, 9, TilePolicy::AUTO);
        assert!(tr * tc >= 1);
        // explicit knobs are respected (clamped to the shape)
        let p = TilePolicy { tile_rows: 7, tile_cols: 1000, ..TilePolicy::AUTO };
        assert_eq!(plan_tile_shape(20, 30, 256, 4, p), (7, 30));
        // degenerate shapes do not divide by zero
        assert_eq!(plan_tile_shape(0, 5, 1, 4, TilePolicy::AUTO), (1, 5));
    }

    #[test]
    fn stolen_matches_rowslice_and_serial_with_stats() {
        let mut rng = Pcg32::new(0x57ea1);
        let pool = PackedPool::new(3).unwrap();
        // skewed shapes (single row, single column) + a square one,
        // k straddling word boundaries
        for (m, k, n, bits) in [
            (1usize, 70usize, 37usize, 8u32),
            (37, 65, 1, 6),
            (13, 64, 9, 4),
            (1, 1, 1, 3),
        ] {
            let a = rand_mat(&mut rng, m * k, bits);
            let b = rand_mat(&mut rng, k * n, bits);
            let pa = Arc::new(PackedPlanes::pack_rows(&a, m, k, bits, PlaneKind::Sbmwc).unwrap());
            let pb = Arc::new(PackedPlanes::pack_cols(&b, k, n, bits, PlaneKind::Booth).unwrap());
            let serial =
                matmul_packed_tile_with(&pa, &pb, 0, m, 0, n, PopcountKernel::Scalar).unwrap();
            assert_eq!(serial, ref_mm(&a, &b, m, k, n));
            let rowslice =
                matmul_packed_tile_rowslice(&pool, &pa, &pb, 0, m, 0, n, PopcountKernel::Auto)
                    .unwrap();
            assert_eq!(rowslice, serial, "{m}x{k}x{n}");
            // every tile policy yields the same integers; forced-small
            // tiles maximise job count and steal traffic, forced
            // k-chunks exercise the split-merge path (clamped to the
            // word count on short-k shapes)
            for policy in [
                TilePolicy::AUTO,
                TilePolicy::NO_KSPLIT,
                TilePolicy { tile_rows: 1, tile_cols: 0, ..TilePolicy::AUTO },
                TilePolicy { tile_rows: 0, tile_cols: 1, ..TilePolicy::AUTO },
                TilePolicy { tile_rows: 1, tile_cols: 1, ..TilePolicy::AUTO },
                TilePolicy { tile_rows: 5, tile_cols: 4, ..TilePolicy::AUTO },
                TilePolicy { tile_rows: 0, tile_cols: 0, k_chunks: 2 },
                TilePolicy { tile_rows: 5, tile_cols: 4, k_chunks: 3 },
            ] {
                let (out, stats) = matmul_packed_tile_stolen(
                    &pool, &pa, &pb, 0, m, 0, n, PopcountKernel::Auto, policy,
                )
                .unwrap();
                assert_eq!(out, serial, "{m}x{k}x{n} {policy:?}");
                assert!(stats.tiles >= 1);
                assert!(stats.max_worker_tiles >= stats.min_worker_tiles);
                assert!(stats.max_worker_tiles <= stats.tiles);
            }
        }
    }

    #[test]
    fn dropped_pool_jobs_are_masked_by_the_inline_slot() {
        // Fault injection: `inject_drop_jobs` makes the pool silently
        // swallow the next N submitted slot-jobs. The caller's inline
        // slot drains *every* deque, so the tiles seeded for a dropped
        // slot are still executed (stolen) and the merge sees all
        // `njobs` parts — the fault is masked by construction.
        let mut rng = Pcg32::new(0xd09);
        let pool = PackedPool::new(3).unwrap();
        let (m, k, n, bits) = (13usize, 70usize, 9usize, 6u32);
        let a = rand_mat(&mut rng, m * k, bits);
        let b = rand_mat(&mut rng, k * n, bits);
        let pa = Arc::new(PackedPlanes::pack_rows(&a, m, k, bits, PlaneKind::Sbmwc).unwrap());
        let pb = Arc::new(PackedPlanes::pack_cols(&b, k, n, bits, PlaneKind::Booth).unwrap());
        let serial = matmul_packed_tile_with(&pa, &pb, 0, m, 0, n, PopcountKernel::Scalar).unwrap();
        // tiny forced tiles maximise the job count so the surviving
        // slots have real stealing to do
        let policy = TilePolicy { tile_rows: 2, tile_cols: 2, ..TilePolicy::AUTO };
        for drops in [1usize, 3] {
            pool.inject_drop_jobs(drops);
            let (out, stats) =
                matmul_packed_tile_stolen(&pool, &pa, &pb, 0, m, 0, n, PopcountKernel::Auto, policy)
                    .unwrap();
            assert_eq!(out, serial, "drops={drops}");
            assert!(stats.tiles > 1);
        }
        // dropping every slot-job degrades to caller-only execution,
        // still bit-identical
        pool.inject_drop_jobs(usize::MAX);
        let (out, _) =
            matmul_packed_tile_stolen(&pool, &pa, &pb, 0, m, 0, n, PopcountKernel::Auto, policy)
                .unwrap();
        assert_eq!(out, serial);
    }

    #[test]
    fn stolen_interior_tile_views_match_serial() {
        let mut rng = Pcg32::new(0x57ea2);
        let pool = PackedPool::new(2).unwrap();
        let (m, k, n, bits) = (9usize, 67usize, 11usize, 5u32);
        let a = rand_mat(&mut rng, m * k, bits);
        let b = rand_mat(&mut rng, k * n, bits);
        let pa = Arc::new(PackedPlanes::pack_rows(&a, m, k, bits, PlaneKind::Booth).unwrap());
        let pb = Arc::new(PackedPlanes::pack_cols(&b, k, n, bits, PlaneKind::Sbmwc).unwrap());
        let t_serial = matmul_packed_tile(&pa, &pb, 2, m - 3, 1, n - 2).unwrap();
        let (t_stolen, _) = matmul_packed_tile_stolen(
            &pool,
            &pa,
            &pb,
            2,
            m - 3,
            1,
            n - 2,
            PopcountKernel::Auto,
            TilePolicy { tile_rows: 2, tile_cols: 3, ..TilePolicy::AUTO },
        )
        .unwrap();
        assert_eq!(t_stolen, t_serial);
        // oversize views rejected before dispatch
        assert!(matmul_packed_tile_stolen(
            &pool, &pa, &pb, 0, m + 1, 0, n, PopcountKernel::Auto, TilePolicy::AUTO
        )
        .is_err());
    }

    #[test]
    fn steal_stats_merge_semantics() {
        let mut a = StealStats { tiles: 4, steals: 1, max_worker_tiles: 3, min_worker_tiles: 1 };
        // merging the zero default does not fake a 0 minimum share
        a.merge(&StealStats::default());
        assert_eq!(a.min_worker_tiles, 1);
        a.merge(&StealStats { tiles: 6, steals: 2, max_worker_tiles: 5, min_worker_tiles: 2 });
        assert_eq!(a.tiles, 10);
        assert_eq!(a.steals, 3);
        assert_eq!(a.max_worker_tiles, 5);
        assert_eq!(a.min_worker_tiles, 1);
        let mut z = StealStats::default();
        z.merge(&StealStats { tiles: 2, steals: 0, max_worker_tiles: 2, min_worker_tiles: 2 });
        assert_eq!(z.min_worker_tiles, 2);
        // a recorded run whose minimum share is genuinely 0 (caller
        // drained everything, a pool slot ran nothing) survives merges
        z.merge(&StealStats { tiles: 3, steals: 3, max_worker_tiles: 3, min_worker_tiles: 0 });
        assert_eq!(z.min_worker_tiles, 0);
        z.merge(&StealStats { tiles: 2, steals: 0, max_worker_tiles: 2, min_worker_tiles: 1 });
        assert_eq!(z.min_worker_tiles, 0, "starved-slot telemetry must not be masked");
    }

    #[test]
    fn slice_bits_equals_fresh_repack() {
        let mut rng = Pcg32::new(0x51ce);
        for (hi, lo) in [(12u32, 8u32), (8, 4), (16, 1), (5, 3), (2, 1)] {
            for k in [1usize, 63, 64, 65, 130] {
                let data = rand_mat(&mut rng, 3 * k, lo); // fits the narrow width
                for kind in [PlaneKind::Sbmwc, PlaneKind::Booth] {
                    let wide = PackedPlanes::pack_rows(&data, 3, k, hi, kind).unwrap();
                    let fresh = PackedPlanes::pack_rows(&data, 3, k, lo, kind).unwrap();
                    let sliced = wide.slice_bits(lo).unwrap();
                    assert_eq!(sliced, fresh, "{kind:?} {hi}->{lo} k={k}");
                    assert_eq!(sliced.mem_words(), fresh.mem_words());
                    assert_eq!(sliced.unpack(), decompose(kind, &data, lo));
                }
            }
        }
        // identity slice, floor guard, and widening rejection
        let data = vec![-8i32, 7, 3, -1]; // needs exactly 4 bits
        let p = PackedPlanes::pack_rows(&data, 2, 2, 8, PlaneKind::Sbmwc).unwrap();
        assert_eq!(p.min_bits, 4);
        assert_eq!(p.slice_bits(8).unwrap(), p);
        assert!(p.slice_bits(3).is_err(), "below min_bits would truncate");
        let q = PackedPlanes::pack_rows(&data, 2, 2, 4, PlaneKind::Sbmwc).unwrap();
        assert!(q.slice_bits(8).is_err(), "packs only narrow");
    }

    #[test]
    fn sliced_operands_compute_exact_matmuls() {
        let mut rng = Pcg32::new(0x51cf);
        let (m, k, n, hi, lo) = (4usize, 70usize, 3usize, 12u32, 6u32);
        let a = rand_mat(&mut rng, m * k, lo);
        let b = rand_mat(&mut rng, k * n, lo);
        let want = ref_mm(&a, &b, m, k, n);
        let pa = PackedPlanes::pack_rows(&a, m, k, lo, PlaneKind::Sbmwc).unwrap();
        let pb_wide = PackedPlanes::pack_cols(&b, k, n, hi, PlaneKind::Sbmwc).unwrap();
        let pb = pb_wide.slice_bits(lo).unwrap();
        assert_eq!(matmul_packed_planes(&pa, &pb).unwrap(), want);
        // saturated negative fill: the sliced view's top plane becomes
        // the sign plane at the new width
        let b_sat = vec![min_value(lo); k * n];
        let want_sat = ref_mm(&a, &b_sat, m, k, n);
        let pb_sat = PackedPlanes::pack_cols(&b_sat, k, n, hi, PlaneKind::Sbmwc)
            .unwrap()
            .slice_bits(lo)
            .unwrap();
        assert_eq!(matmul_packed_planes(&pa, &pb_sat).unwrap(), want_sat);
    }

    #[test]
    fn packing_validates_range_and_shape() {
        assert!(PackedPlanes::pack_rows(&[1, 2, 3], 2, 2, 4, PlaneKind::Sbmwc).is_err());
        assert!(PackedPlanes::pack_rows(&[8], 1, 1, 4, PlaneKind::Sbmwc).is_err()); // 8 > max 4-bit
        assert!(PackedPlanes::pack_rows(&[7], 1, 1, 4, PlaneKind::Sbmwc).is_ok());
        assert!(PackedPlanes::pack_rows(&[1], 1, 1, 0, PlaneKind::Sbmwc).is_err());
    }

    #[test]
    fn packed_footprint_is_an_order_smaller() {
        let (rows, cols, bits) = (16usize, 256usize, 8u32);
        let data = vec![1i32; rows * cols];
        let p = PackedPlanes::pack_rows(&data, rows, cols, bits, PlaneKind::Sbmwc).unwrap();
        let packed_bytes = p.mem_words() * 8;
        let byte_planes = bits as usize * rows * cols;
        assert_eq!(packed_bytes * 8, byte_planes, "exactly 8x smaller");
        // a 4-bit view of the same pack advertises half the footprint
        // while sharing the same storage
        assert_eq!(p.slice_bits(4).unwrap().mem_words() * 2, p.mem_words());
    }

    /// A `k × n` matrix whose columns are drawn from a small codebook —
    /// the redundancy profile of real low-precision quantized weights,
    /// which is what makes RSR sub-popcount.
    fn codebook_mat(rng: &mut Pcg32, k: usize, n: usize, bits: u32, distinct: usize) -> Vec<i32> {
        let (lo, hi) = (min_value(bits), max_value(bits));
        let code: Vec<Vec<i32>> = (0..distinct.max(1))
            .map(|_| (0..k).map(|_| rng.range_i32(lo, hi)).collect())
            .collect();
        let mut b = vec![0i32; k * n];
        for c in 0..n {
            let pick = rng.range_i32(0, distinct.max(1) as i32 - 1) as usize;
            for r in 0..k {
                b[r * n + c] = code[pick][r];
            }
        }
        b
    }

    #[test]
    fn rsr_matches_serial_all_kind_pairs_and_seg_lengths() {
        let mut rng = Pcg32::new(0x4542);
        for bits in [1u32, 2, 3, 8] {
            // k straddles word boundaries so segment tails are exercised
            for (m, k, n) in [(3usize, 70usize, 5usize), (1, 64, 9), (4, 257, 3), (1, 1, 1)] {
                let a = rand_mat(&mut rng, m * k, bits);
                let b = codebook_mat(&mut rng, k, n, bits, 3);
                let want = ref_mm(&a, &b, m, k, n);
                for ka in [PlaneKind::Sbmwc, PlaneKind::Booth] {
                    for kb in [PlaneKind::Sbmwc, PlaneKind::Booth] {
                        let pa = PackedPlanes::pack_rows(&a, m, k, bits, ka).unwrap();
                        let pb = PackedPlanes::pack_cols(&b, k, n, bits, kb).unwrap();
                        for seg_words in [0usize, 1, 2, 5] {
                            assert_eq!(
                                matmul_packed_rsr(
                                    &pa, &pb, 0, m, 0, n, PopcountKernel::Scalar, seg_words
                                )
                                .unwrap(),
                                want,
                                "{ka:?}x{kb:?} {m}x{k}x{n} @{bits}b seg={seg_words}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn rsr_interior_tile_and_sign_saturation() {
        let mut rng = Pcg32::new(0x4543);
        // saturated operands: the sign plane is all-ones, the worst case
        // for the −2^(b−1) correction — and maximally redundant columns
        for bits in [1u32, 2, 16] {
            let (m, k, n) = (2usize, 70usize, 4usize);
            let a = vec![min_value(bits); m * k];
            let b = vec![min_value(bits); k * n];
            let pa = PackedPlanes::pack_rows(&a, m, k, bits, PlaneKind::Sbmwc).unwrap();
            let pb = PackedPlanes::pack_cols(&b, k, n, bits, PlaneKind::Sbmwc).unwrap();
            assert_eq!(
                matmul_packed_rsr(&pa, &pb, 0, m, 0, n, PopcountKernel::Auto, 0).unwrap(),
                ref_mm(&a, &b, m, k, n),
                "saturated @{bits}b"
            );
        }
        // interior tile views match the serial tile kernel
        let (m, k, n, bits) = (7usize, 130usize, 9usize, 2u32);
        let a = rand_mat(&mut rng, m * k, bits);
        let b = codebook_mat(&mut rng, k, n, bits, 4);
        let pa = PackedPlanes::pack_rows(&a, m, k, bits, PlaneKind::Booth).unwrap();
        let pb = PackedPlanes::pack_cols(&b, k, n, bits, PlaneKind::Sbmwc).unwrap();
        let want = matmul_packed_tile(&pa, &pb, 2, 3, 4, 5).unwrap();
        assert_eq!(
            matmul_packed_rsr(&pa, &pb, 2, 3, 4, 5, PopcountKernel::Scalar, 1).unwrap(),
            want
        );
        // oversize views rejected before any table is built
        assert!(matmul_packed_rsr(&pa, &pb, 0, m + 1, 0, n, PopcountKernel::Auto, 0).is_err());
    }

    #[test]
    fn segment_table_dedupes_redundant_columns() {
        let mut rng = Pcg32::new(0x4544);
        let (k, n, bits, distinct) = (128usize, 64usize, 1u32, 4usize);
        let b = codebook_mat(&mut rng, k, n, bits, distinct);
        let pb = PackedPlanes::pack_cols(&b, k, n, bits, PlaneKind::Sbmwc).unwrap();
        let t = SegmentTable::build(&pb, 0, n, 1).unwrap();
        assert_eq!(t.seg_words, 1);
        // identical columns collapse: at most `distinct` patterns per
        // segment, against `n` replaced popcounts per segment
        assert!(
            t.distinct() <= distinct * pb.words,
            "{} distinct patterns for a {distinct}-column codebook",
            t.distinct()
        );
        assert_eq!(t.replaced(), n * pb.words);
        // uniform random columns barely dedupe — the case the planner's
        // measured calibration exists to catch
        let r = rand_mat(&mut rng, k * n, 8);
        let pr = PackedPlanes::pack_cols(&r, k, n, 8, PlaneKind::Sbmwc).unwrap();
        let tr = SegmentTable::build(&pr, 0, n, 1).unwrap();
        assert!(tr.distinct() > t.distinct());
        // auto segment length stays within the pack
        assert!(SegmentTable::build(&pb, 0, n, 0).unwrap().seg_words >= 1);
        assert!(SegmentTable::build(&pb, 0, n, 99).unwrap().seg_words <= pb.words);
        assert!(SegmentTable::build(&pb, 60, 10, 1).is_err(), "column overrun");
    }

    #[test]
    fn plan_k_chunks_auto_and_forced() {
        // single output tile over many words: auto fans k out
        let chunks = plan_k_chunks(128, 1, 9, 1 << 20, TilePolicy::AUTO);
        assert!(chunks >= 2, "huge-k single tile must split, got {chunks}");
        assert!(chunks <= 128);
        // a grid that already feeds every slot never splits
        assert_eq!(plan_k_chunks(128, 9, 9, 1 << 20, TilePolicy::AUTO), 1);
        assert_eq!(plan_k_chunks(128, 36, 9, 1 << 20, TilePolicy::AUTO), 1);
        // single-word and tiny-work tiles stay whole
        assert_eq!(plan_k_chunks(1, 1, 9, 1 << 20, TilePolicy::AUTO), 1);
        assert_eq!(plan_k_chunks(128, 1, 9, MIN_KSPLIT_WORK, TilePolicy::AUTO), 1);
        // forced counts are clamped to the word count
        let forced = |c| TilePolicy { tile_rows: 0, tile_cols: 0, k_chunks: c };
        assert_eq!(plan_k_chunks(128, 9, 9, 1 << 20, forced(4)), 4);
        assert_eq!(plan_k_chunks(2, 1, 9, 1 << 20, forced(7)), 2);
        assert_eq!(plan_k_chunks(128, 1, 9, 1 << 20, forced(1)), 1);
    }

    #[test]
    fn ksplit_stolen_matches_serial_including_tail_words() {
        let mut rng = Pcg32::new(0x4545);
        let pool = PackedPool::new(3).unwrap();
        // k = 257 → 5 words: 2- and 3-chunk splits leave unequal
        // word-aligned chunks, and the last word is tail-masked
        for (m, k, n, bits) in [(1usize, 257usize, 37usize, 8u32), (4, 700, 3, 2), (2, 64, 2, 16)] {
            let a = rand_mat(&mut rng, m * k, bits);
            let b = rand_mat(&mut rng, k * n, bits);
            let pa = Arc::new(PackedPlanes::pack_rows(&a, m, k, bits, PlaneKind::Booth).unwrap());
            let pb = Arc::new(PackedPlanes::pack_cols(&b, k, n, bits, PlaneKind::Sbmwc).unwrap());
            let serial =
                matmul_packed_tile_with(&pa, &pb, 0, m, 0, n, PopcountKernel::Scalar).unwrap();
            assert_eq!(serial, ref_mm(&a, &b, m, k, n));
            for chunks in [0usize, 1, 2, 3, 64] {
                let policy = TilePolicy { tile_rows: 0, tile_cols: 0, k_chunks: chunks };
                let (out, stats) = matmul_packed_tile_stolen(
                    &pool, &pa, &pb, 0, m, 0, n, PopcountKernel::Auto, policy,
                )
                .unwrap();
                assert_eq!(out, serial, "{m}x{k}x{n} @{bits}b k_chunks={chunks}");
                assert!(stats.tiles >= 1);
            }
        }
        // auto k-split: a 1×hugek×2 run has only 2 output tiles for 4
        // slots, so the planner must fan the contracted dimension out
        let a = rand_mat(&mut rng, 8192, 8);
        let b = rand_mat(&mut rng, 8192 * 2, 8);
        let pa = Arc::new(PackedPlanes::pack_rows(&a, 1, 8192, 8, PlaneKind::Sbmwc).unwrap());
        let pb = Arc::new(PackedPlanes::pack_cols(&b, 8192, 2, 8, PlaneKind::Sbmwc).unwrap());
        let serial = matmul_packed_tile_with(&pa, &pb, 0, 1, 0, 2, PopcountKernel::Scalar).unwrap();
        let (out, stats) = matmul_packed_tile_stolen(
            &pool, &pa, &pb, 0, 1, 0, 2, PopcountKernel::Auto, TilePolicy::AUTO,
        )
        .unwrap();
        assert_eq!(out, serial, "auto-k-split 1x8192x2");
        assert!(stats.tiles > 2, "auto k-split must fan out the huge-k run, got {} jobs", stats.tiles);
        let (out, stats) = matmul_packed_tile_stolen(
            &pool, &pa, &pb, 0, 1, 0, 2, PopcountKernel::Auto, TilePolicy::NO_KSPLIT,
        )
        .unwrap();
        assert_eq!(out, serial);
        assert!(stats.tiles <= 2, "NO_KSPLIT must keep tiles whole");

        // stolen RSR: per-tile segment tables under the same scheduler
        let (m, k, n, bits) = (9usize, 130usize, 33usize, 2u32);
        let a = rand_mat(&mut rng, m * k, bits);
        let b = codebook_mat(&mut rng, k, n, bits, 4);
        let pa = Arc::new(PackedPlanes::pack_rows(&a, m, k, bits, PlaneKind::Sbmwc).unwrap());
        let pb = Arc::new(PackedPlanes::pack_cols(&b, k, n, bits, PlaneKind::Sbmwc).unwrap());
        let serial = matmul_packed_tile_with(&pa, &pb, 0, m, 0, n, PopcountKernel::Scalar).unwrap();
        for seg_words in [0u32, 1, 2] {
            let (out, _) = matmul_packed_tile_stolen_with(
                &pool,
                &pa,
                &pb,
                0,
                m,
                0,
                n,
                PopcountKernel::Auto,
                TilePolicy { tile_rows: 2, tile_cols: 8, ..TilePolicy::AUTO },
                KernelFamily::Rsr { seg_words },
            )
            .unwrap();
            assert_eq!(out, serial, "stolen rsr seg_words={seg_words}");
        }
    }

    #[test]
    fn plane_signature_detects_every_single_bit_flip() {
        // The integrity property the scrubber stands on: for both plane
        // kinds and every width, flipping ANY single storage bit — any
        // plane, any vector, any word including the tail-masked last
        // word, either stream — fails `verify()` and `locate()` names
        // exactly the upset plane.
        let mut rng = Pcg32::new(0x519);
        let (vectors, len) = (2usize, 70usize); // 2 words: one full, one tail
        for bits in 1..=16u32 {
            let data = rand_mat(&mut rng, vectors * len, bits);
            for kind in [PlaneKind::Sbmwc, PlaneKind::Booth] {
                let p = PackedPlanes::pack_rows(&data, vectors, len, bits, kind).unwrap();
                assert!(p.verify(), "{kind:?} @{bits}b intact pack must verify");
                assert!(p.locate().is_empty());
                let streams: &[bool] =
                    if p.has_neg() { &[false, true] } else { &[false] };
                for plane in 0..bits as usize {
                    for vec in 0..vectors {
                        for word in 0..p.words {
                            for bit in 0..64u32 {
                                for &neg in streams {
                                    let f = p
                                        .with_flipped_bit(plane, vec, word, bit, neg)
                                        .unwrap();
                                    assert!(
                                        !f.verify(),
                                        "{kind:?} @{bits}b flip p{plane} v{vec} w{word} b{bit} neg={neg} escaped"
                                    );
                                    assert_eq!(
                                        f.locate(),
                                        vec![plane as u32],
                                        "{kind:?} @{bits}b flip must localise to its plane"
                                    );
                                }
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn with_flipped_bit_rejects_out_of_range_targets() {
        let data = vec![1i32, 2, 3, 4];
        let p = PackedPlanes::pack_rows(&data, 2, 2, 4, PlaneKind::Sbmwc).unwrap();
        assert!(p.with_flipped_bit(4, 0, 0, 0, false).is_err(), "plane overrun");
        assert!(p.with_flipped_bit(0, 2, 0, 0, false).is_err(), "vector overrun");
        assert!(p.with_flipped_bit(0, 0, 1, 0, false).is_err(), "word overrun");
        assert!(p.with_flipped_bit(0, 0, 0, 64, false).is_err(), "bit overrun");
        assert!(p.with_flipped_bit(0, 0, 0, 0, true).is_err(), "SBMwC has no neg stream");
        let b = PackedPlanes::pack_rows(&data, 2, 2, 4, PlaneKind::Booth).unwrap();
        assert!(b.with_flipped_bit(0, 0, 0, 0, true).is_ok());
    }

    #[test]
    fn sliced_views_remain_verifiable_per_plane() {
        let mut rng = Pcg32::new(0x51a);
        let (vectors, len, hi, lo) = (3usize, 130usize, 12u32, 5u32);
        let data = rand_mat(&mut rng, vectors * len, lo);
        for kind in [PlaneKind::Sbmwc, PlaneKind::Booth] {
            let wide = PackedPlanes::pack_rows(&data, vectors, len, hi, kind).unwrap();
            let view = wide.slice_bits(lo).unwrap();
            assert!(view.verify(), "zero-copy view of an intact pack verifies");
            // a flip in a plane the view serves fails BOTH handles
            let hit = wide.with_flipped_bit(2, 1, 1, 17, false).unwrap();
            assert!(!hit.verify());
            let hit_view = hit.slice_bits(lo).unwrap();
            assert!(!hit_view.verify(), "visible-plane corruption must fail the view");
            assert_eq!(hit_view.locate(), vec![2]);
            // a flip in a donor-only plane (>= lo) is invisible to the
            // view — per-plane signatures keep the narrow check exact —
            // while the donor handle still catches it
            let donor_only = wide.with_flipped_bit(lo as usize + 1, 0, 0, 3, false).unwrap();
            assert_eq!(donor_only.locate(), vec![lo + 1]);
            let clean_view = donor_only.slice_bits(lo).unwrap();
            assert!(clean_view.verify(), "donor-plane corruption is outside the view");
            assert!(clean_view.locate().is_empty());
        }
    }

    #[test]
    fn flipped_live_digit_changes_the_matmul_and_repack_restores_it() {
        // end-to-end repair contract at the kernel level: a live-digit
        // flip is both signature-visible and output-visible, and a
        // fresh re-pack from the intact source is bit-identical to the
        // pre-fault pack
        let mut rng = Pcg32::new(0x51b);
        let (m, k, n, bits) = (3usize, 70usize, 4usize, 6u32);
        let a = rand_mat(&mut rng, m * k, bits);
        let b = rand_mat(&mut rng, k * n, bits);
        let pa = PackedPlanes::pack_rows(&a, m, k, bits, PlaneKind::Sbmwc).unwrap();
        let pb = PackedPlanes::pack_cols(&b, k, n, bits, PlaneKind::Sbmwc).unwrap();
        let clean = matmul_packed_planes(&pa, &pb).unwrap();
        // digit 65 of column 2: word 1, bit 1 — a live (non-tail) digit
        let corrupt = pb.with_flipped_bit(1, 2, 1, 1, false).unwrap();
        assert!(!corrupt.verify());
        let wrong = matmul_packed_planes(&pa, &corrupt).unwrap();
        assert_ne!(wrong, clean, "a live-digit flip must perturb the product");
        let repacked = PackedPlanes::pack_cols(&b, k, n, bits, PlaneKind::Sbmwc).unwrap();
        assert_eq!(repacked, pb, "re-pack from the intact source is bit-identical");
        assert!(repacked.verify());
        assert_eq!(matmul_packed_planes(&pa, &repacked).unwrap(), clean);
    }
}
