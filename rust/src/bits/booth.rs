//! Radix-2 Booth recoding (paper §II-A, Table I, eq. 5).
//!
//! Booth's algorithm scans the multiplier LSb-first and, at each bit
//! position `i`, inspects the pair `(ml[i], ml[i-1])` (with
//! `ml[-1] = 0`). The pair selects one of three actions (Table I):
//!
//! | pair (cur, prev) | action            | signed digit |
//! |------------------|-------------------|--------------|
//! | 00               | shift only        |  0           |
//! | 01               | +M, shift         | +1           |
//! | 10               | −M, shift         | −1           |
//! | 11               | shift only        |  0           |
//!
//! so the multiplier decomposes into signed digits
//! `d_i = ml[i-1] − ml[i]` with `ML = Σ d_i · 2^i`, which handles the
//! two's-complement sign bit with no correction step — the property the
//! Booth-based MAC exploits to need only a single adder (§III-A).

use super::twos::{encode, Bits};

/// The action Booth recoding selects for one multiplier bit pair
/// (paper Table I).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BoothAction {
    /// Pair 00 or 11: accumulate nothing, just shift.
    Shift,
    /// Pair 01: add the (shifted) multiplicand.
    AddM,
    /// Pair 10: subtract the (shifted) multiplicand.
    SubM,
}

impl BoothAction {
    /// Classify a (current, previous) multiplier bit pair.
    pub fn from_pair(cur: bool, prev: bool) -> Self {
        match (cur, prev) {
            (false, true) => BoothAction::AddM,
            (true, false) => BoothAction::SubM,
            _ => BoothAction::Shift,
        }
    }

    /// The signed digit {−1, 0, +1} this action contributes.
    pub fn digit(self) -> i32 {
        match self {
            BoothAction::Shift => 0,
            BoothAction::AddM => 1,
            BoothAction::SubM => -1,
        }
    }
}

/// Booth signed digits of `ml` (LSb-first): `d_i = ml[i-1] − ml[i]`.
///
/// Invariant (checked by tests): `Σ d_i · 2^i == ml.value`.
pub fn booth_digits(ml: Bits) -> Vec<i32> {
    let pat = encode(ml.value, ml.width);
    let mut prev = false; // ml[-1] = 0 ("we assume the previous bit is 0")
    let mut digits = Vec::with_capacity(ml.width as usize);
    for i in 0..ml.width {
        let cur = (pat >> i) & 1 == 1;
        digits.push(BoothAction::from_pair(cur, prev).digit());
        prev = cur;
    }
    digits
}

/// Reference Booth multiplication: `mc × ml` via the digit expansion.
/// This is the oracle the Booth MAC simulator is tested against.
pub fn booth_mul(mc: Bits, ml: Bits) -> i64 {
    booth_digits(ml)
        .iter()
        .enumerate()
        .map(|(i, &d)| (d as i64) * (mc.value as i64) << i)
        .sum()
}

/// Number of add/sub operations Booth recoding performs for `ml` —
/// the switching-activity proxy used by the power model: a Booth MAC
/// only fires its adder when consecutive multiplier bits differ.
pub fn booth_addsub_count(ml: Bits) -> u32 {
    booth_digits(ml).iter().filter(|&&d| d != 0).count() as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::twos::{max_value, min_value};

    #[test]
    fn table1_pairs() {
        assert_eq!(BoothAction::from_pair(false, false), BoothAction::Shift);
        assert_eq!(BoothAction::from_pair(false, true), BoothAction::AddM);
        assert_eq!(BoothAction::from_pair(true, false), BoothAction::SubM);
        assert_eq!(BoothAction::from_pair(true, true), BoothAction::Shift);
    }

    #[test]
    fn paper_eq4_run_decompositions() {
        // 0110₂ = 2³ − 2¹ = 6 (paper eq. 4)
        let d = booth_digits(Bits::new(6, 4).unwrap());
        assert_eq!(d, vec![0, -1, 0, 1]);
        // 1110₂ = −2¹ = −2 (paper eq. 4)
        let d = booth_digits(Bits::new(-2, 4).unwrap());
        assert_eq!(d, vec![0, -1, 0, 0]);
    }

    #[test]
    fn paper_eq5_example() {
        // 0110 × 1110 = 6 × −2 = −12 (paper eq. 5)
        let mc = Bits::new(6, 4).unwrap();
        let ml = Bits::new(-2, 4).unwrap();
        assert_eq!(booth_mul(mc, ml), -12);
    }

    #[test]
    fn digits_reconstruct_value_exhaustive() {
        for width in 1..=10u32 {
            for v in min_value(width)..=max_value(width) {
                let ml = Bits::new(v, width).unwrap();
                let sum: i64 = booth_digits(ml)
                    .iter()
                    .enumerate()
                    .map(|(i, &d)| (d as i64) << i)
                    .sum();
                assert_eq!(sum, v as i64, "w={width} v={v}");
            }
        }
    }

    #[test]
    fn booth_mul_exhaustive_4bit() {
        for a in -8..=7 {
            for b in -8..=7 {
                let mc = Bits::new(a, 4).unwrap();
                let ml = Bits::new(b, 4).unwrap();
                assert_eq!(booth_mul(mc, ml), (a as i64) * (b as i64));
            }
        }
    }

    #[test]
    fn one_bit_operands() {
        // 1-bit two's complement: bit pattern 1 = −1, 0 = 0.
        let m1 = Bits::new(-1, 1).unwrap();
        let z = Bits::new(0, 1).unwrap();
        assert_eq!(booth_mul(m1, m1), 1);
        assert_eq!(booth_mul(m1, z), 0);
        assert_eq!(booth_mul(z, m1), 0);
    }

    #[test]
    fn addsub_activity_bounds() {
        // alternating bits maximize adder activity; 0 and −1 minimize it
        assert_eq!(booth_addsub_count(Bits::new(0, 8).unwrap()), 0);
        assert_eq!(booth_addsub_count(Bits::new(-1, 8).unwrap()), 1);
        // 0b01010101 = 85: every pair differs → 8 add/subs
        assert_eq!(booth_addsub_count(Bits::new(85, 8).unwrap()), 8);
    }
}
