//! Bit-level arithmetic ground truth.
//!
//! Everything the paper's hardware does reduces to two's-complement
//! arithmetic over 1..=16-bit operands (§II-A). This module is the
//! *software* definition of that arithmetic: the cycle-accurate
//! simulator ([`crate::sim`]) is tested against it, the analytical
//! models use its widths, and the quantizer clamps to its ranges.
//!
//! Submodules:
//! * [`twos`] — two's-complement encode/decode, ranges, wrapping.
//! * [`booth`] — radix-2 Booth recoding (paper Table I / eq. 5).
//! * [`plane`] — bit-plane decomposition of integer matrices (the
//!   TPU-side re-expression of bit-serial streaming, see
//!   DESIGN.md §Hardware-Adaptation) and the decomposition oracle
//!   shared by every plane-based execution path.
//! * [`packed`] — word-packed planes (`u64` words, 64 digits/word),
//!   the AND+popcount plane-pair matmul kernel behind
//!   `Backend::Packed`, its unrolled/AVX2/NEON popcount reducers, the
//!   persistent worker pool with its work-stealing 2-D tile scheduler,
//!   and cross-precision plane slicing (see DESIGN.md §Packed-Planes
//!   and §Packed-Threading).

pub mod booth;
pub mod packed;
pub mod plane;
pub mod twos;

pub use booth::{booth_digits, booth_mul, BoothAction};
pub use packed::{
    matmul_packed_planes, matmul_packed_tile, matmul_packed_tile_pooled,
    matmul_packed_tile_rowslice, matmul_packed_tile_stolen, matmul_packed_tile_with,
    plan_tile_shape, PackedPlanes, PackedPool, PopcountKernel, StealStats, TilePolicy,
};
pub use plane::{
    bit_planes_sbmwc, booth_planes, decompose, plane_weight, reconstruct_sbmwc, PlaneKind,
};
pub use twos::{decode, encode, max_value, min_value, wrap_to, Bits};
