//! Bit-plane decomposition of integer matrices.
//!
//! This is the TPU-side re-expression of bit-serial streaming (see
//! DESIGN.md §Hardware-Adaptation): instead of feeding one bit per
//! *cycle* into a tiny MAC, we feed one bit-*plane* per grid step into
//! a dense matmul. The two decompositions here mirror the paper's two
//! MAC variants:
//!
//! * **SBMwC planes** — raw `{0,1}` bit planes; the sign (MSb) plane
//!   carries weight `−2^(b−1)` (the "correction" of §II-A eq. 2).
//! * **Booth planes** — `{−1,0,+1}` signed-digit planes
//!   (`d_i = ml[i-1] − ml[i]`, Table I); every plane carries weight
//!   `+2^i`.
//!
//! The Pallas kernel (`python/compile/kernels/bitserial_matmul.py`)
//! performs the same decompositions; these functions are its Rust-side
//! oracle and are used by the coordinator's functional fallback path.

use super::twos::encode;

/// Which plane decomposition a plane set uses. Mirrors the paper's two
/// MAC variants; [`decompose`] is the single oracle both the per-plane
/// matmul ([`crate::nn::matmul_planes`]) and the packed engine
/// ([`crate::bits::packed`]) derive their planes from.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PlaneKind {
    /// Raw `{0,1}` bit planes; the MSb plane carries weight `−2^(b−1)`
    /// (the sign correction of §II-A eq. 2).
    Sbmwc,
    /// Booth signed-digit `{−1,0,+1}` planes; plane `i` carries `+2^i`.
    Booth,
}

impl PlaneKind {
    pub fn name(self) -> &'static str {
        match self {
            PlaneKind::Sbmwc => "sbmwc",
            PlaneKind::Booth => "booth",
        }
    }
}

/// The decomposition oracle: one definition of "the planes of `data`
/// at `bits` width" shared by every plane-based execution path.
pub fn decompose(kind: PlaneKind, data: &[i32], bits: u32) -> Vec<Vec<i8>> {
    match kind {
        PlaneKind::Sbmwc => bit_planes_sbmwc(data, bits),
        PlaneKind::Booth => booth_planes(data, bits),
    }
}

/// Signed weight of plane `i` under `kind` at `bits` width:
/// `x = Σ_i plane_weight(kind, i, bits) · digit_i(x)`.
pub fn plane_weight(kind: PlaneKind, i: u32, bits: u32) -> i64 {
    match kind {
        PlaneKind::Sbmwc if i == bits - 1 => -(1i64 << i),
        _ => 1i64 << i,
    }
}

/// SBMwC bit planes of an integer matrix (row-major `data`, values must
/// fit in `bits` two's complement). Returns `bits` planes of `{0,1}`,
/// plane `i` = bit `i` (LSb = plane 0).
///
/// Reconstruction: `x = Σ_{i<b-1} plane_i·2^i − plane_{b-1}·2^{b-1}`.
pub fn bit_planes_sbmwc(data: &[i32], bits: u32) -> Vec<Vec<i8>> {
    (0..bits)
        .map(|i| {
            data.iter()
                .map(|&v| ((encode(v, bits) >> i) & 1) as i8)
                .collect()
        })
        .collect()
}

/// Booth signed-digit planes: `bits` planes with entries in `{−1,0,+1}`.
///
/// Reconstruction: `x = Σ_i plane_i · 2^i` (no sign correction needed).
pub fn booth_planes(data: &[i32], bits: u32) -> Vec<Vec<i8>> {
    (0..bits)
        .map(|i| {
            data.iter()
                .map(|&v| {
                    let pat = encode(v, bits);
                    let cur = ((pat >> i) & 1) as i8;
                    let prev = if i == 0 { 0 } else { ((pat >> (i - 1)) & 1) as i8 };
                    prev - cur // d_i = ml[i-1] − ml[i]
                })
                .collect()
        })
        .collect()
}

/// Reconstruct values from SBMwC planes (test helper / functional path).
pub fn reconstruct_sbmwc(planes: &[Vec<i8>], bits: u32) -> Vec<i32> {
    let n = planes[0].len();
    (0..n)
        .map(|j| {
            let mut v: i32 = 0;
            for (i, p) in planes.iter().enumerate() {
                let w = 1i32 << i;
                let w = if i as u32 == bits - 1 { -w } else { w };
                v += (p[j] as i32) * w;
            }
            v
        })
        .collect()
}

/// Reconstruct values from Booth planes.
pub fn reconstruct_booth(planes: &[Vec<i8>]) -> Vec<i32> {
    let n = planes[0].len();
    (0..n)
        .map(|j| {
            planes
                .iter()
                .enumerate()
                .map(|(i, p)| (p[j] as i32) << i)
                .sum()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::twos::{max_value, min_value};

    #[test]
    fn sbmwc_roundtrip_exhaustive() {
        for bits in 1..=10u32 {
            let vals: Vec<i32> = (min_value(bits)..=max_value(bits)).collect();
            let planes = bit_planes_sbmwc(&vals, bits);
            assert_eq!(planes.len(), bits as usize);
            assert_eq!(reconstruct_sbmwc(&planes, bits), vals);
        }
    }

    #[test]
    fn booth_roundtrip_exhaustive() {
        for bits in 1..=10u32 {
            let vals: Vec<i32> = (min_value(bits)..=max_value(bits)).collect();
            let planes = booth_planes(&vals, bits);
            assert_eq!(planes.len(), bits as usize);
            assert_eq!(reconstruct_booth(&planes), vals);
        }
    }

    #[test]
    fn plane_entries_in_range() {
        let vals: Vec<i32> = (-128..=127).collect();
        for p in bit_planes_sbmwc(&vals, 8) {
            assert!(p.iter().all(|&x| x == 0 || x == 1));
        }
        for p in booth_planes(&vals, 8) {
            assert!(p.iter().all(|&x| (-1..=1).contains(&x)));
        }
    }

    #[test]
    fn oracle_weights_reconstruct_both_kinds() {
        // x = Σ_i plane_weight(kind, i, bits) · digit_i(x) for every
        // representable value under both decompositions.
        for bits in 1..=10u32 {
            let vals: Vec<i32> = (min_value(bits)..=max_value(bits)).collect();
            for kind in [PlaneKind::Sbmwc, PlaneKind::Booth] {
                let planes = decompose(kind, &vals, bits);
                for (j, &v) in vals.iter().enumerate() {
                    let got: i64 = planes
                        .iter()
                        .enumerate()
                        .map(|(i, p)| plane_weight(kind, i as u32, bits) * p[j] as i64)
                        .sum();
                    assert_eq!(got, v as i64, "{} bits={bits} v={v}", kind.name());
                }
            }
        }
    }

    #[test]
    fn plane_matmul_equals_int_matmul() {
        // 2×3 · 3×2 at 4 bits through Booth planes of the B operand:
        // A·B = Σ_i 2^i (A · D_i)  — the identity the Pallas kernel uses.
        let a = [1i32, -2, 3, 4, -5, 6]; // 2×3
        let b = [7i32, -8, 5, -4, 3, 2]; // 3×2, all fit in 4 bits
        let bits = 4;
        let planes = booth_planes(&b, bits);
        let mut acc = [0i64; 4]; // 2×2
        for (i, plane) in planes.iter().enumerate() {
            for r in 0..2 {
                for c in 0..2 {
                    let mut dot = 0i64;
                    for k in 0..3 {
                        dot += (a[r * 3 + k] as i64) * (plane[k * 2 + c] as i64);
                    }
                    acc[r * 2 + c] += dot << i;
                }
            }
        }
        // plain integer matmul reference
        let mut expect = [0i64; 4];
        for r in 0..2 {
            for c in 0..2 {
                for k in 0..3 {
                    expect[r * 2 + c] += (a[r * 3 + k] as i64) * (b[k * 2 + c] as i64);
                }
            }
        }
        assert_eq!(acc, expect);
    }
}
