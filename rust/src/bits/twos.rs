//! Two's-complement encode/decode helpers for 1..=16-bit operands.
//!
//! The hardware fixes the *maximum* operand width at compile time
//! (16 bits in the paper) but the *effective* precision is a runtime
//! knob (§III-A). All conversions here are explicit about the width so
//! tests can sweep every width the hardware supports.

/// A value annotated with its operand width — the unit the P2S
/// converters serialize and the MACs consume.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Bits {
    /// The signed value. Invariant: fits in `width` bits two's complement.
    pub value: i32,
    /// Operand width in bits, 1..=16.
    pub width: u32,
}

impl Bits {
    /// Construct, checking the value fits in `width` bits.
    pub fn new(value: i32, width: u32) -> Option<Self> {
        if (1..=16).contains(&width) && value >= min_value(width) && value <= max_value(width) {
            Some(Bits { value, width })
        } else {
            None
        }
    }

    /// Bit `i` (0 = LSb) of the two's-complement encoding.
    pub fn bit(&self, i: u32) -> bool {
        debug_assert!(i < self.width);
        (encode(self.value, self.width) >> i) & 1 == 1
    }

    /// Bits MSb-first — the order the vertical (multiplicand) P2S
    /// converters emit (§III-B).
    pub fn bits_msb_first(&self) -> Vec<bool> {
        (0..self.width).rev().map(|i| self.bit(i)).collect()
    }

    /// Bits LSb-first — the order the horizontal (multiplier) P2S
    /// converters emit (§III-B).
    pub fn bits_lsb_first(&self) -> Vec<bool> {
        (0..self.width).map(|i| self.bit(i)).collect()
    }
}

/// Smallest representable value at `width` bits (two's complement).
pub const fn min_value(width: u32) -> i32 {
    -(1 << (width - 1))
}

/// Largest representable value at `width` bits (two's complement).
pub const fn max_value(width: u32) -> i32 {
    (1 << (width - 1)) - 1
}

/// Encode a signed value into its `width`-bit two's-complement pattern
/// (returned in the low `width` bits; upper bits zero).
pub fn encode(value: i32, width: u32) -> u32 {
    debug_assert!((1..=31).contains(&width));
    debug_assert!(
        value >= min_value(width) && value <= max_value(width),
        "{value} does not fit in {width} bits"
    );
    (value as u32) & low_mask(width)
}

/// Decode a `width`-bit two's-complement pattern into a signed value.
pub fn decode(pattern: u32, width: u32) -> i32 {
    debug_assert!((1..=31).contains(&width));
    let pattern = pattern & low_mask(width);
    let sign = 1u32 << (width - 1);
    if pattern & sign != 0 {
        (pattern as i32) - (1i32 << width)
    } else {
        pattern as i32
    }
}

/// Wrap an arbitrarily wide signed value into `width`-bit two's
/// complement (what a hardware register of that width would hold).
///
/// Hot path: called on every accumulator write in the simulator, so
/// this is mask arithmetic (power-of-two modulus), not `rem_euclid` —
/// the latter emits a hardware divide (§Perf change 1).
#[inline(always)]
pub fn wrap_to(value: i64, width: u32) -> i64 {
    debug_assert!((1..=63).contains(&width));
    let shift = 64 - width;
    // keep the low `width` bits and sign-extend them
    (value << shift) >> shift
}

/// Mask with the low `width` bits set.
pub const fn low_mask(width: u32) -> u32 {
    if width >= 32 {
        u32::MAX
    } else {
        (1u32 << width) - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges() {
        assert_eq!(min_value(1), -1);
        assert_eq!(max_value(1), 0);
        assert_eq!(min_value(8), -128);
        assert_eq!(max_value(8), 127);
        assert_eq!(min_value(16), -32768);
        assert_eq!(max_value(16), 32767);
    }

    #[test]
    fn encode_decode_roundtrip_exhaustive() {
        for width in 1..=12u32 {
            for v in min_value(width)..=max_value(width) {
                assert_eq!(decode(encode(v, width), width), v, "w={width} v={v}");
            }
        }
    }

    #[test]
    fn paper_example_eq2_operands() {
        // 0110₂ = 6, 1110₂ = −2 at 4 bits (paper eq. 2).
        assert_eq!(decode(0b0110, 4), 6);
        assert_eq!(decode(0b1110, 4), -2);
        assert_eq!(encode(-2, 4), 0b1110);
    }

    #[test]
    fn bit_orders() {
        let b = Bits::new(-2, 4).unwrap(); // 1110
        assert_eq!(b.bits_msb_first(), vec![true, true, true, false]);
        assert_eq!(b.bits_lsb_first(), vec![false, true, true, true]);
    }

    #[test]
    fn wrapping() {
        assert_eq!(wrap_to(128, 8), -128);
        assert_eq!(wrap_to(-129, 8), 127);
        assert_eq!(wrap_to(255, 8), -1);
        assert_eq!(wrap_to(42, 8), 42);
        // wide accumulator never wraps in the tested regimes
        assert_eq!(wrap_to(1 << 40, 48), 1 << 40);
    }

    #[test]
    fn new_rejects_out_of_range() {
        assert!(Bits::new(8, 4).is_none());
        assert!(Bits::new(-9, 4).is_none());
        assert!(Bits::new(7, 4).is_some());
        assert!(Bits::new(0, 0).is_none());
        assert!(Bits::new(0, 17).is_none());
    }
}
