//! Argument-parsing substrate (offline environment — no `clap`; see
//! DESIGN.md substitutions). Supports subcommands, `--flag value`,
//! `--flag=value`, boolean switches, defaults, and generated help.

use crate::Result;
use std::collections::BTreeMap;

/// Declarative specification of one option.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_switch: bool,
}

/// A parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    switches: Vec<String>,
    /// Positional arguments after options.
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("--{name}={s}: {e}")),
        }
    }

    /// Required-with-default convenience.
    pub fn req<T: std::str::FromStr>(&self, name: &str) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        self.get_parse(name)?
            .ok_or_else(|| anyhow::anyhow!("missing required option --{name}"))
    }

    pub fn switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

/// A subcommand definition.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command {
            name,
            about,
            opts: Vec::new(),
        }
    }

    pub fn opt(mut self, name: &'static str, help: &'static str, default: Option<&'static str>) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default,
            is_switch: false,
        });
        self
    }

    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: None,
            is_switch: true,
        });
        self
    }

    /// Parse `argv` (without the program/subcommand names).
    pub fn parse(&self, argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        // seed defaults
        for o in &self.opts {
            if let Some(d) = o.default {
                args.values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(body) = tok.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (body, None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| anyhow::anyhow!("unknown option --{name}\n{}", self.help()))?;
                if spec.is_switch {
                    anyhow::ensure!(inline.is_none(), "--{name} takes no value");
                    args.switches.push(name.to_string());
                } else {
                    let value = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .ok_or_else(|| anyhow::anyhow!("--{name} needs a value"))?
                                .clone()
                        }
                    };
                    args.values.insert(name.to_string(), value);
                }
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    /// Usage text.
    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for o in &self.opts {
            let kind = if o.is_switch { "" } else { " <value>" };
            let default = o
                .default
                .map(|d| format!(" (default: {d})"))
                .unwrap_or_default();
            s.push_str(&format!("  --{}{kind}\t{}{default}\n", o.name, o.help));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("demo", "test command")
            .opt("bits", "operand width", Some("8"))
            .opt("sa", "array geometry", Some("16x4"))
            .switch("verbose", "chatty output")
    }

    fn v(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = cmd().parse(&v(&[])).unwrap();
        assert_eq!(a.get("bits"), Some("8"));
        assert!(!a.switch("verbose"));
    }

    #[test]
    fn space_and_equals_forms() {
        let a = cmd().parse(&v(&["--bits", "4", "--sa=32x8", "--verbose"])).unwrap();
        assert_eq!(a.req::<u32>("bits").unwrap(), 4);
        assert_eq!(a.get("sa"), Some("32x8"));
        assert!(a.switch("verbose"));
    }

    #[test]
    fn unknown_option_rejected_with_help() {
        let e = cmd().parse(&v(&["--nope"])).unwrap_err().to_string();
        assert!(e.contains("unknown option"));
        assert!(e.contains("--bits"));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(cmd().parse(&v(&["--bits"])).is_err());
    }

    #[test]
    fn positional_collected() {
        let a = cmd().parse(&v(&["run", "--bits", "2", "fast"])).unwrap();
        assert_eq!(a.positional, vec!["run", "fast"]);
    }

    #[test]
    fn parse_errors_carry_context() {
        let e = cmd().parse(&v(&["--bits", "abc"])).unwrap().req::<u32>("bits");
        assert!(e.unwrap_err().to_string().contains("--bits=abc"));
    }
}
