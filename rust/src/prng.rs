//! Deterministic PRNGs for tests, property testing, and workload
//! generation. Built in-repo because the environment is offline (no
//! `rand` crate); implements SplitMix64 and PCG32, both well-known,
//! tiny, and statistically solid for simulation workloads.

/// SplitMix64 — used for seeding and simple streams.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// PCG32 (XSH-RR) — the main PRNG.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Seed from a single u64 via SplitMix64 (stream constant 1442695040888963407).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut rng = Pcg32 {
            state: sm.next_u64(),
            inc: sm.next_u64() | 1,
        };
        rng.next_u32(); // advance past the seed-correlated first output
        rng
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift with rejection.
    pub fn below(&mut self, bound: u32) -> u32 {
        assert!(bound > 0);
        loop {
            let x = self.next_u32();
            let m = (x as u64) * (bound as u64);
            let lo = m as u32;
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform signed integer in the inclusive range `[lo, hi]`.
    pub fn range_i32(&mut self, lo: i32, hi: i32) -> i32 {
        assert!(lo <= hi);
        let span = (hi as i64 - lo as i64 + 1) as u32;
        lo.wrapping_add(self.below(span) as i32)
    }

    /// Uniform usize in `[0, bound)`.
    pub fn below_usize(&mut self, bound: usize) -> usize {
        assert!(bound > 0 && bound <= u32::MAX as usize);
        self.below(bound as u32) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple on purpose).
    pub fn normal(&mut self) -> f64 {
        let u1 = (self.f64()).max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_in_bounds_and_covers() {
        let mut r = Pcg32::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn range_i32_inclusive() {
        let mut r = Pcg32::new(9);
        let (mut saw_lo, mut saw_hi) = (false, false);
        for _ in 0..2000 {
            let v = r.range_i32(-8, 7);
            assert!((-8..=7).contains(&v));
            saw_lo |= v == -8;
            saw_hi |= v == 7;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Pcg32::new(3);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_roughly_standard() {
        let mut r = Pcg32::new(5);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.08, "var={var}");
    }
}
