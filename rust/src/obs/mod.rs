//! Flight-telemetry observability layer (DESIGN.md §Observability).
//!
//! Spacecraft operators see an accelerator only through a bounded-rate
//! telemetry downlink, so every structure here is constant-memory by
//! construction:
//!
//! - [`hist`]: the HDR-style log-bucketed [`hist::Histogram`] behind
//!   `LatencyStats` — exact for small runs, ≤ 1/128 relative quantile
//!   error and ~60 KiB flat once a serve goes past 4096 samples.
//! - [`trace`]: per-request [`trace::Span`]s in a fixed-capacity
//!   lock-striped [`trace::TraceRing`] with an exact `dropped`
//!   counter; JSONL dump via `bitsmm serve --trace-requests <path>`.
//! - [`snapshot`]: the periodic JSONL snapshotter of the full
//!   `Metrics` tree (`--metrics-file` / `--metrics-every-ms`) plus the
//!   parse/assert helpers behind `bitsmm obs` that CI uses instead of
//!   grepping table text.

pub mod hist;
pub mod snapshot;
pub mod trace;

pub use hist::{Histogram, EXACT_MAX, NUM_BUCKETS, REL_ERROR_BOUND};
pub use snapshot::{check_snapshot_file, lookup, parse_snapshots, render_snapshot, REQUIRED_GROUPS};
pub use trace::{Span, SpanKind, TraceRing};
