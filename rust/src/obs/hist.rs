//! Bounded log-bucketed histogram (HDR-style) — the storage behind
//! `LatencyStats` (DESIGN.md §Observability).
//!
//! Small runs stay exact: up to [`EXACT_MAX`] samples are kept verbatim
//! and percentiles come from a sort, byte-identical to the pre-PR-10
//! `Vec<u64>` behaviour. Past that the histogram spills every sample
//! into log-spaced buckets and memory stays constant no matter how many
//! samples arrive — a million-request serve costs the same ~60 KiB as
//! a thousand-request one.
//!
//! Bucket layout (values are u64 microseconds, but the structure is
//! unit-agnostic):
//!   - `v < 64`: one bucket per value (exact).
//!   - `v >= 64`: let `exp = 63 - v.leading_zeros()` (so `2^exp <= v <
//!     2^(exp+1)`); the octave `[2^exp, 2^(exp+1))` is split into 64
//!     sub-buckets of width `2^(exp-6)`. Bucket index:
//!     `64 + (exp - 6) * 64 + ((v >> (exp - 6)) & 63)`.
//!
//! Total buckets: `64 + 58 * 64 = 3776` (`exp` runs 6..=63), ~30 KiB of
//! `u64` counters. A bucket's representative is its integer midpoint,
//! clamped to the observed `[min, max]`, so the relative quantile error
//! is bounded by half a bucket width over the bucket's lower bound:
//! `(2^(exp-6) / 2) / 2^exp = 1/128` (< 0.79%). `min`, `max`, `count`,
//! and the mean (exact `u128` sum) are always exact in both modes.

/// Samples kept verbatim before spilling to buckets. Both `record` and
/// `merge` switch modes on the same rule — "total count exceeds
/// `EXACT_MAX`" — so merging worker histograms lands in the *same*
/// state as recording every sample into one histogram (the
/// merge==record-all property test relies on this).
pub const EXACT_MAX: usize = 4096;

/// Values below this are their own bucket (exact even in bucket mode).
const LINEAR_MAX: u64 = 64;

/// Sub-buckets per octave; 64 sub-buckets → rel. error ≤ 1/128.
const SUBS: u64 = 64;

/// `exp` runs 6..=63 → 58 octaves of 64 sub-buckets after the linear range.
pub const NUM_BUCKETS: usize = 64 + 58 * 64;

/// Documented relative error bound of bucketed percentiles.
pub const REL_ERROR_BOUND: f64 = 1.0 / 128.0;

#[derive(Debug, Clone, Default)]
pub struct Histogram {
    /// Raw samples while in exact mode; drained on spill.
    exact: Vec<u64>,
    /// Log-spaced counters; empty until the first spill.
    buckets: Vec<u64>,
    count: u64,
    sum: u128,
    min: u64,
    max: u64,
}

fn bucket_index(v: u64) -> usize {
    if v < LINEAR_MAX {
        return v as usize;
    }
    let exp = 63 - v.leading_zeros() as u64; // 6..=63
    let sub = (v >> (exp - 6)) & (SUBS - 1);
    (64 + (exp - 6) * SUBS + sub) as usize
}

/// Integer midpoint of bucket `idx` — the value bucketed percentiles
/// report for samples that landed there.
fn bucket_mid(idx: usize) -> u64 {
    if idx < LINEAR_MAX as usize {
        return idx as u64;
    }
    let off = (idx - 64) as u64;
    let exp = off / SUBS + 6;
    let sub = off % SUBS;
    let width = 1u64 << (exp - 6);
    let lo = (1u64 << exp) + sub * width;
    lo + (width - 1) / 2
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    pub fn record(&mut self, v: u64) {
        self.count += 1;
        self.sum += v as u128;
        if self.count == 1 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        if self.buckets.is_empty() {
            self.exact.push(v);
            if self.exact.len() > EXACT_MAX {
                self.spill();
            }
        } else {
            self.buckets[bucket_index(v)] += 1;
        }
    }

    /// Convert the exact samples into bucket counters (one-way).
    fn spill(&mut self) {
        self.buckets = vec![0u64; NUM_BUCKETS];
        for &v in &self.exact {
            self.buckets[bucket_index(v)] += 1;
        }
        self.exact = Vec::new();
    }

    /// True while percentiles are exact (no sample has been bucketed).
    pub fn is_exact(&self) -> bool {
        self.buckets.is_empty()
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn min(&self) -> u64 {
        self.min
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Exact mean (the sum is kept in full width in both modes).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum as f64 / self.count as f64
    }

    /// Merge `other` into `self`. If the combined count still fits the
    /// exact budget both sides must be exact (each count ≤ combined)
    /// and the samples concatenate; otherwise both sides land in
    /// buckets and the counters add. Either way the resulting state is
    /// identical to having recorded every sample into one histogram.
    pub fn merge(&mut self, other: &Histogram) {
        if other.count == 0 {
            return;
        }
        if self.count == 0 {
            *self = other.clone();
            return;
        }
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        self.sum += other.sum;
        self.count += other.count;
        if self.count <= EXACT_MAX as u64 {
            // both must still be exact: each side's count is bounded
            // by the combined count, which fits the exact budget
            self.exact.extend_from_slice(&other.exact);
            return;
        }
        if self.buckets.is_empty() {
            self.spill();
        }
        if other.buckets.is_empty() {
            for &v in &other.exact {
                self.buckets[bucket_index(v)] += 1;
            }
        } else {
            for (b, o) in self.buckets.iter_mut().zip(other.buckets.iter()) {
                *b += o;
            }
        }
    }

    /// Nearest-rank percentiles, each `p` in [0, 100]. Empty → 0 for
    /// every requested percentile, never a panic. Exact mode sorts
    /// once and serves every `p` from the sorted copy; bucket mode
    /// walks cumulative counts and reports the target bucket's
    /// midpoint clamped to the observed [min, max] (p0/p100 exact).
    pub fn percentiles(&self, ps: &[f64]) -> Vec<u64> {
        if self.count == 0 {
            return vec![0; ps.len()];
        }
        let n = self.count;
        let rank_of = |p: f64| -> u64 {
            let r = ((p / 100.0) * (n as f64 - 1.0)).round();
            (r.max(0.0) as u64).min(n - 1)
        };
        if self.buckets.is_empty() {
            let mut s = self.exact.clone();
            s.sort_unstable();
            return ps.iter().map(|&p| s[rank_of(p) as usize]).collect();
        }
        ps.iter()
            .map(|&p| {
                let rank = rank_of(p);
                let mut seen = 0u64;
                for (idx, &c) in self.buckets.iter().enumerate() {
                    seen += c;
                    if seen > rank {
                        return bucket_mid(idx).clamp(self.min, self.max);
                    }
                }
                self.max // unreachable while counters stay consistent
            })
            .collect()
    }

    pub fn percentile(&self, p: f64) -> u64 {
        self.percentiles(&[p])[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg32;

    #[test]
    fn bucket_index_and_mid_are_consistent() {
        // every representative lands back in its own bucket, and the
        // relative error of the representative is within the bound
        for v in [0u64, 1, 63, 64, 65, 127, 128, 1000, 4096, 1 << 20, u64::MAX] {
            let idx = bucket_index(v);
            let mid = bucket_mid(idx);
            assert_eq!(bucket_index(mid), idx, "mid of bucket {idx} stays inside");
            let err = (mid as f64 - v as f64).abs() / (v.max(1) as f64);
            assert!(err <= REL_ERROR_BOUND, "v={v} mid={mid} err={err}");
        }
        assert!(bucket_index(u64::MAX) < NUM_BUCKETS);
    }

    #[test]
    fn exact_mode_matches_sort() {
        let mut h = Histogram::new();
        for v in [10u64, 20, 30, 40, 50, 60, 70, 80, 90, 100] {
            h.record(v);
        }
        assert!(h.is_exact());
        assert_eq!(h.percentile(0.0), 10);
        assert_eq!(h.percentile(50.0), 60);
        assert_eq!(h.percentile(100.0), 100);
        assert_eq!(h.min(), 10);
        assert_eq!(h.max(), 100);
        assert!((h.mean() - 55.0).abs() < 1e-9);
    }

    #[test]
    fn empty_percentiles_are_zero() {
        let h = Histogram::new();
        assert_eq!(h.percentiles(&[0.0, 50.0, 99.9, 100.0]), vec![0, 0, 0, 0]);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.count(), 0);
    }

    /// Bits-skewed latencies: most requests are fast 1-bit plans, a
    /// heavy tail re-packs at 16 bits — the shape that breaks
    /// fixed-width buckets. The bucketed percentiles must stay within
    /// the documented 1/128 relative error of an exact sort.
    #[test]
    fn bucketed_percentiles_within_error_bound() {
        let mut rng = Pcg32::new(0xb175);
        let mut h = Histogram::new();
        let mut all: Vec<u64> = Vec::new();
        for i in 0..20_000u64 {
            let v = match i % 16 {
                0..=10 => 80 + rng.next_u64() % 60,         // fast mode ~100us
                11..=14 => 1_500 + rng.next_u64() % 900,    // mid tail
                _ => 40_000 + rng.next_u64() % 30_000,      // 16-bit re-pack tail
            };
            h.record(v);
            all.push(v);
        }
        assert!(!h.is_exact(), "20k samples must have spilled");
        all.sort_unstable();
        for p in [0.0, 10.0, 50.0, 90.0, 95.0, 99.0, 99.9, 100.0] {
            let rank = ((p / 100.0) * (all.len() as f64 - 1.0)).round() as usize;
            let exact = all[rank.min(all.len() - 1)];
            let approx = h.percentile(p);
            let err = (approx as f64 - exact as f64).abs() / exact as f64;
            assert!(
                err <= REL_ERROR_BOUND,
                "p{p}: exact={exact} approx={approx} err={err}"
            );
        }
        assert_eq!(h.min(), all[0]);
        assert_eq!(h.max(), *all.last().unwrap());
        let mean = all.iter().sum::<u64>() as f64 / all.len() as f64;
        assert!((h.mean() - mean).abs() < 1e-6, "mean stays exact after spill");
    }

    /// Merging any split of a sample stream equals recording it all
    /// into one histogram — across exact/exact, exact/bucketed, and
    /// bucketed/bucketed merges.
    #[test]
    fn merge_equals_record_all_over_random_splits() {
        let mut rng = Pcg32::new(0x5eed);
        for &total in &[10usize, 100, EXACT_MAX - 1, EXACT_MAX + 5, 9_000] {
            let samples: Vec<u64> = (0..total)
                .map(|_| rng.next_u64() % 1_000_000)
                .collect();
            let mut whole = Histogram::new();
            for &v in &samples {
                whole.record(v);
            }
            for _ in 0..4 {
                let cut = (rng.next_u64() as usize) % (total + 1);
                let (a, b) = samples.split_at(cut);
                let mut left = Histogram::new();
                let mut right = Histogram::new();
                for &v in a {
                    left.record(v);
                }
                for &v in b {
                    right.record(v);
                }
                left.merge(&right);
                assert_eq!(left.count(), whole.count());
                assert_eq!(left.min(), whole.min());
                assert_eq!(left.max(), whole.max());
                assert_eq!(
                    left.percentiles(&[0.0, 25.0, 50.0, 75.0, 95.0, 99.0, 100.0]),
                    whole.percentiles(&[0.0, 25.0, 50.0, 75.0, 95.0, 99.0, 100.0]),
                    "split at {cut}/{total}"
                );
            }
        }
    }

    #[test]
    fn memory_stays_bounded_after_spill() {
        let mut h = Histogram::new();
        for i in 0..(EXACT_MAX as u64 * 4) {
            h.record(i);
        }
        assert!(!h.is_exact());
        assert_eq!(h.exact.len(), 0, "exact samples drained on spill");
        assert_eq!(h.buckets.len(), NUM_BUCKETS, "constant bucket storage");
        assert_eq!(h.count(), EXACT_MAX as u64 * 4);
    }
}
