//! Per-request tracing: fixed-capacity, lock-striped span ring buffer
//! (DESIGN.md §Observability).
//!
//! A trace ID is minted when a request is admitted at `submit`; every
//! stage it passes after that — queue wait, batch assembly, plan
//! resolution, pack/slice, kernel execution, ABFT verify/repair, the
//! device fetch/execute/writeback ledger, respond — records a [`Span`]
//! against that ID. Batch-granular stages (assembly, kernel, device)
//! are attributed to the batch's *lead* trace ID, the oldest request in
//! the batch.
//!
//! Storage is a ring: `stripes × per_stripe` span slots, a span's
//! stripe chosen by `trace % stripes` so one request's spans stay in
//! one stripe (and contention spreads across workers serving different
//! requests). When a stripe is full the oldest span in it is
//! overwritten and the global `dropped` counter increments by exactly
//! one per overwrite — telemetry is bounded-rate by construction, and
//! the consumer can see precisely how much history it lost.
//!
//! Cost when disabled: the server carries `Option<Arc<TraceRing>>`;
//! `None` means every call site is one branch on an Option.

use crate::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Lifecycle stage a span measures. `name()` is the JSONL identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SpanKind {
    /// Request accepted at `submit` (dur 0; start = submission).
    Admit,
    /// Time between submission and leaving the queue in a batch.
    QueueWait,
    /// Batch formed: the `next_batch` call that produced it (lead ID;
    /// detail = batch size).
    Assemble,
    /// Queued request shed for age (detail = waited ms).
    Shed,
    /// Request answered `DeadlineExceeded` without executing.
    DeadlineMiss,
    /// Execution-plan resolution (cache → cost model → calibration).
    PlanResolve,
    /// Operand packing / zero-copy plane slicing ahead of the kernel.
    PackSlice,
    /// Packed/native/simulated kernel execution (lead ID; detail =
    /// tiles stolen during the run — k-split merges ride the same
    /// pooled run this span times).
    Kernel,
    /// ABFT row-checksum verification (detail = 1 on mismatch).
    AbftVerify,
    /// ABFT escalation: plane verify + repair + retry after a miss.
    AbftRepair,
    /// Device instruction-stream fetch stage (detail = cycles).
    DeviceFetch,
    /// Device execute stage (detail = cycles).
    DeviceExec,
    /// Device writeback stage (detail = cycles).
    DeviceWriteback,
    /// Response delivered (dur = request latency).
    Respond,
}

impl SpanKind {
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Admit => "admit",
            SpanKind::QueueWait => "queue_wait",
            SpanKind::Assemble => "assemble",
            SpanKind::Shed => "shed",
            SpanKind::DeadlineMiss => "deadline_miss",
            SpanKind::PlanResolve => "plan_resolve",
            SpanKind::PackSlice => "pack_slice",
            SpanKind::Kernel => "kernel",
            SpanKind::AbftVerify => "abft_verify",
            SpanKind::AbftRepair => "abft_repair",
            SpanKind::DeviceFetch => "device_fetch",
            SpanKind::DeviceExec => "device_exec",
            SpanKind::DeviceWriteback => "device_writeback",
            SpanKind::Respond => "respond",
        }
    }
}

/// One recorded stage of one trace.
#[derive(Debug, Clone, Copy)]
pub struct Span {
    /// Trace ID minted at admission (0 = untraced/batch context).
    pub trace: u64,
    /// Global record order — monotone within a trace by construction
    /// (a trace's spans are recorded in lifecycle order).
    pub seq: u64,
    pub kind: SpanKind,
    /// Microseconds since the ring's epoch (server start).
    pub start_us: u64,
    /// Stage duration in microseconds (0 for point events).
    pub dur_us: u64,
    /// Stage-specific payload (batch size, steals, cycles, …).
    pub detail: u64,
}

impl Span {
    fn jsonl(&self) -> String {
        format!(
            "{{\"trace\":{},\"seq\":{},\"kind\":\"{}\",\"start_us\":{},\"dur_us\":{},\"detail\":{}}}",
            self.trace,
            self.seq,
            self.kind.name(),
            self.start_us,
            self.dur_us,
            self.detail
        )
    }
}

struct Stripe {
    slots: Vec<Span>,
    /// Next write position once `slots` reached capacity.
    head: usize,
}

/// Fixed-capacity lock-striped span ring. See module docs.
pub struct TraceRing {
    stripes: Vec<Mutex<Stripe>>,
    per_stripe: usize,
    seq: AtomicU64,
    dropped: AtomicU64,
    epoch: Instant,
}

/// Stripe count for `TraceRing::new` (capacity is split across these).
pub const DEFAULT_STRIPES: usize = 8;

impl TraceRing {
    /// Ring with `capacity` total span slots split over
    /// [`DEFAULT_STRIPES`] stripes (rounded up to a whole number of
    /// slots per stripe).
    pub fn new(capacity: usize) -> TraceRing {
        TraceRing::with_stripes(DEFAULT_STRIPES, capacity.div_ceil(DEFAULT_STRIPES))
    }

    pub fn with_stripes(stripes: usize, per_stripe: usize) -> TraceRing {
        let stripes = stripes.max(1);
        let per_stripe = per_stripe.max(1);
        TraceRing {
            stripes: (0..stripes)
                .map(|_| {
                    Mutex::new(Stripe {
                        slots: Vec::with_capacity(per_stripe),
                        head: 0,
                    })
                })
                .collect(),
            per_stripe,
            seq: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            epoch: Instant::now(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.stripes.len() * self.per_stripe
    }

    /// Spans currently resident (≤ capacity).
    pub fn len(&self) -> usize {
        self.stripes
            .iter()
            .map(|s| s.lock().unwrap_or_else(|p| p.into_inner()).slots.len())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Spans overwritten because their stripe was full — exact: one
    /// increment per lost span.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Record a span whose stage started at `start` and ran for `dur`.
    pub fn span(&self, trace: u64, kind: SpanKind, start: Instant, dur: Duration, detail: u64) {
        let start_us = start.saturating_duration_since(self.epoch).as_micros() as u64;
        self.push(Span {
            trace,
            seq: self.seq.fetch_add(1, Ordering::Relaxed),
            kind,
            start_us,
            dur_us: dur.as_micros() as u64,
            detail,
        });
    }

    /// Point-event convenience: zero duration, starting now.
    pub fn event(&self, trace: u64, kind: SpanKind, detail: u64) {
        self.span(trace, kind, Instant::now(), Duration::ZERO, detail);
    }

    fn push(&self, span: Span) {
        let stripe = &self.stripes[(span.trace % self.stripes.len() as u64) as usize];
        let mut s = stripe.lock().unwrap_or_else(|p| p.into_inner());
        if s.slots.len() < self.per_stripe {
            s.slots.push(span);
        } else {
            let head = s.head;
            s.slots[head] = span;
            s.head = (head + 1) % self.per_stripe;
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Snapshot every resident span, ordered by (trace, seq).
    pub fn dump(&self) -> Vec<Span> {
        let mut all: Vec<Span> = Vec::new();
        for stripe in &self.stripes {
            let s = stripe.lock().unwrap_or_else(|p| p.into_inner());
            all.extend_from_slice(&s.slots);
        }
        all.sort_by_key(|s| (s.trace, s.seq));
        all
    }

    /// JSONL dump: one span object per line, then a trailer object
    /// with the ring accounting (`{"spans":…,"dropped":…,…}`).
    pub fn dump_jsonl(&self) -> String {
        let spans = self.dump();
        let mut out = String::new();
        for s in &spans {
            out.push_str(&s.jsonl());
            out.push('\n');
        }
        out.push_str(&format!(
            "{{\"spans\":{},\"dropped\":{},\"capacity\":{}}}\n",
            spans.len(),
            self.dropped(),
            self.capacity()
        ));
        out
    }

    pub fn write_jsonl(&self, path: &std::path::Path) -> Result<()> {
        if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.dump_jsonl())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::store::Json;

    fn ring1(cap: usize) -> TraceRing {
        TraceRing::with_stripes(1, cap)
    }

    #[test]
    fn span_order_is_monotone_per_trace() {
        let ring = TraceRing::new(256);
        let t0 = Instant::now();
        // interleave two traces the way two workers would
        for _ in 0..10 {
            ring.span(1, SpanKind::QueueWait, t0, Duration::from_micros(5), 0);
            ring.span(2, SpanKind::QueueWait, t0, Duration::from_micros(5), 0);
            ring.span(1, SpanKind::Kernel, t0, Duration::from_micros(9), 0);
            ring.span(2, SpanKind::Respond, t0, Duration::from_micros(1), 0);
        }
        let spans = ring.dump();
        for pair in spans.windows(2) {
            if pair[0].trace == pair[1].trace {
                assert!(pair[0].seq < pair[1].seq, "dump sorts by seq within a trace");
            }
        }
        // per-trace record order is preserved: for trace 1 every
        // QueueWait..Kernel pair alternates
        let t1: Vec<_> = spans.iter().filter(|s| s.trace == 1).collect();
        assert_eq!(t1.len(), 20);
        for (i, s) in t1.iter().enumerate() {
            let want = if i % 2 == 0 { SpanKind::QueueWait } else { SpanKind::Kernel };
            assert_eq!(s.kind, want, "slot {i}");
        }
    }

    #[test]
    fn dropped_is_exact_under_overflow() {
        let ring = ring1(16);
        let t0 = Instant::now();
        for i in 0..100u64 {
            ring.span(7, SpanKind::Kernel, t0, Duration::ZERO, i);
        }
        assert_eq!(ring.len(), 16, "ring holds exactly its capacity");
        assert_eq!(ring.dropped(), 100 - 16, "one drop per overwrite");
        // the survivors are the newest 16 spans, still in seq order
        let spans = ring.dump();
        let details: Vec<u64> = spans.iter().map(|s| s.detail).collect();
        assert_eq!(details, (84..100).collect::<Vec<u64>>());
        // no overflow → no drops
        let calm = ring1(64);
        for _ in 0..64 {
            calm.event(1, SpanKind::Admit, 0);
        }
        assert_eq!(calm.dropped(), 0);
        assert_eq!(calm.len(), 64);
    }

    #[test]
    fn jsonl_dump_parses_line_by_line() {
        let ring = TraceRing::new(64);
        ring.event(3, SpanKind::Admit, 0);
        ring.event(3, SpanKind::Respond, 0);
        let text = ring.dump_jsonl();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3, "two spans + trailer");
        for line in &lines[..2] {
            let v = Json::parse(line).unwrap();
            assert_eq!(v.field("trace").unwrap().as_int().unwrap(), 3);
            assert!(v.field("kind").unwrap().as_str().is_ok());
        }
        let trailer = Json::parse(lines[2]).unwrap();
        assert_eq!(trailer.field("spans").unwrap().as_int().unwrap(), 2);
        assert_eq!(trailer.field("dropped").unwrap().as_int().unwrap(), 0);
    }

    #[test]
    fn stripes_partition_by_trace_id() {
        let ring = TraceRing::with_stripes(4, 4);
        assert_eq!(ring.capacity(), 16);
        // 8 spans on one trace overflow only that trace's stripe
        for _ in 0..8 {
            ring.event(5, SpanKind::Kernel, 0);
        }
        assert_eq!(ring.dropped(), 4);
        // a different trace's stripe is untouched
        ring.event(6, SpanKind::Kernel, 0);
        assert_eq!(ring.dropped(), 4);
    }
}
