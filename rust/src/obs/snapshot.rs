//! Metrics snapshot export: the full `Metrics` tree rendered as one
//! JSONL object per snapshot, plus the parse/assert helpers CI uses
//! instead of grepping tables (DESIGN.md §Observability).
//!
//! Schema (one line per snapshot; `seq` counts snapshots within a run,
//! the last line of a file is the final at-shutdown aggregate):
//!
//! ```json
//! {"seq":0,"wall_ms":0,"final":false,
//!  "latency":{"count":0,"mean_us":0.0,"min_us":0,"p50_us":0,"p95_us":0,"p99_us":0,"max_us":0},
//!  "requests":0,"errors":0,"batches":0,"macs":0,"hw_cycles":0,
//!  "throughput_rps":0.0,"rejected":0,"sheds":0,"deadline_misses":0,
//!  "panics":0,"worker_deaths":0,"degraded":0,
//!  "steal":{"tiles":0,"steals":0,"max_worker_tiles":0,"min_worker_tiles":0,"imbalance":0.0},
//!  "plan":{"hits":0,"misses":0,"calibrations":0},
//!  "faults":{"injected":0,"mem_seu":0,"masked_transient":0,"masked_persistent":0,"unmasked":0},
//!  "scrub":{"sweeps":0,"detected":0,"repaired":0,"quarantined":0},
//!  "device":{"tiles":0,"instrs":0,"fetch_cycles":0,"exec_cycles":0,"wb_cycles":0,"overlap_cycles":0,"stall_cycles":0,"dma_words":0}}
//! ```
//!
//! Every value is finite or `null`: derived ratios that can be
//! non-finite (`steal.imbalance` is `inf` for a starved worker) render
//! as `null`, because JSON has no infinity — the human tables keep
//! printing `inf` (see `Metrics::worker_tile_imbalance`).

use crate::coordinator::Metrics;
use crate::plan::store::Json;
use crate::Result;

/// Render one snapshot line (no trailing newline).
pub fn render_snapshot(seq: u64, is_final: bool, m: &Metrics) -> String {
    let pcts = m.latency.percentiles(&[50.0, 95.0, 99.0]);
    format!(
        "{{\"seq\":{seq},\"wall_ms\":{wall},\"final\":{is_final},\
         \"latency\":{{\"count\":{lc},\"mean_us\":{lmean},\"min_us\":{lmin},\"p50_us\":{p50},\"p95_us\":{p95},\"p99_us\":{p99},\"max_us\":{lmax}}},\
         \"requests\":{req},\"errors\":{err},\"batches\":{bat},\"macs\":{macs},\"hw_cycles\":{hw},\
         \"throughput_rps\":{rps},\"rejected\":{rej},\"sheds\":{sheds},\"deadline_misses\":{dl},\
         \"panics\":{panics},\"worker_deaths\":{deaths},\"degraded\":{deg},\
         \"steal\":{{\"tiles\":{st},\"steals\":{ss},\"max_worker_tiles\":{smax},\"min_worker_tiles\":{smin},\"imbalance\":{imb}}},\
         \"plan\":{plan},\"faults\":{faults},\"scrub\":{scrub},\"device\":{device}}}",
        wall = m.wall.as_millis(),
        lc = m.latency.count(),
        lmean = Json::render_f64(m.latency.mean_us()),
        lmin = m.latency.min_us(),
        p50 = pcts[0],
        p95 = pcts[1],
        p99 = pcts[2],
        lmax = m.latency.max_us(),
        req = m.requests,
        err = m.errors,
        bat = m.batches,
        macs = m.macs,
        hw = m.hw_cycles,
        rps = Json::render_f64(m.throughput_rps()),
        rej = m.rejected,
        sheds = m.sheds,
        dl = m.deadline_misses,
        panics = m.panics,
        deaths = m.worker_deaths,
        deg = m.degraded,
        st = m.steal.tiles,
        ss = m.steal.steals,
        smax = m.steal.max_worker_tiles,
        smin = m.steal.min_worker_tiles,
        imb = Json::render_f64(m.worker_tile_imbalance()),
        plan = m.plan.json(),
        faults = m.faults.json(),
        scrub = m.scrub.json(),
        device = m.device.json(),
    )
}

/// The counter groups every snapshot must carry (acceptance contract).
pub const REQUIRED_GROUPS: [&str; 5] = ["latency", "faults", "scrub", "plan", "device"];

/// Parse a JSONL snapshot file's text into one `Json` per line,
/// verifying each line carries every required group and that every
/// leaf value is finite or null (`Json` cannot even represent a
/// non-finite float, so parsing alone proves finiteness — this walk
/// additionally rejects missing groups).
pub fn parse_snapshots(text: &str) -> Result<Vec<Json>> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = Json::parse(line).map_err(|e| anyhow::anyhow!("snapshot line {}: {e}", i + 1))?;
        for g in REQUIRED_GROUPS {
            let f = v
                .field(g)
                .map_err(|e| anyhow::anyhow!("snapshot line {}: {e}", i + 1))?;
            anyhow::ensure!(
                matches!(f, Json::Obj(_)),
                "snapshot line {}: group '{g}' is not an object",
                i + 1
            );
        }
        out.push(v);
    }
    anyhow::ensure!(!out.is_empty(), "no snapshots in file");
    Ok(out)
}

/// Navigate a dotted path (`faults.unmasked`) into a snapshot object.
pub fn lookup<'a>(v: &'a Json, path: &str) -> Result<&'a Json> {
    let mut cur = v;
    for part in path.split('.') {
        cur = cur
            .field(part)
            .map_err(|e| anyhow::anyhow!("path '{path}': {e}"))?;
    }
    Ok(cur)
}

/// One `--require` clause: `faults.unmasked=0`, `scrub.repaired>=1`,
/// `steal.imbalance=null`, `latency.count>0`, …
fn check_requirement(snap: &Json, req: &str) -> Result<()> {
    let (path, op, want) = split_requirement(req)?;
    let got = lookup(snap, path)?;
    if want == "null" {
        let ok = match op {
            "=" | "==" => got.is_null(),
            "!=" => !got.is_null(),
            other => anyhow::bail!("requirement '{req}': op '{other}' does not apply to null"),
        };
        anyhow::ensure!(ok, "requirement '{req}' failed: {path} is {got:?}");
        return Ok(());
    }
    let want_num: f64 = want
        .parse()
        .map_err(|e| anyhow::anyhow!("requirement '{req}': bad number '{want}': {e}"))?;
    anyhow::ensure!(
        !got.is_null(),
        "requirement '{req}' failed: {path} is null"
    );
    let got_num = got.as_f64().map_err(|e| anyhow::anyhow!("requirement '{req}': {e}"))?;
    let ok = match op {
        "=" | "==" => got_num == want_num,
        "!=" => got_num != want_num,
        ">=" => got_num >= want_num,
        "<=" => got_num <= want_num,
        ">" => got_num > want_num,
        "<" => got_num < want_num,
        other => anyhow::bail!("requirement '{req}': unknown op '{other}'"),
    };
    anyhow::ensure!(
        ok,
        "requirement '{req}' failed: {path} = {got_num}"
    );
    Ok(())
}

/// Split `path<op>value` on the first comparison operator. Two-char
/// ops first so `>=` does not parse as `>` + `=value`.
fn split_requirement(req: &str) -> Result<(&str, &str, &str)> {
    for op in ["==", ">=", "<=", "!=", "=", ">", "<"] {
        if let Some(pos) = req.find(op) {
            let path = req[..pos].trim();
            let want = req[pos + op.len()..].trim();
            anyhow::ensure!(
                !path.is_empty() && !want.is_empty(),
                "malformed requirement '{req}'"
            );
            return Ok((path, op, want));
        }
    }
    anyhow::bail!("requirement '{req}' has no comparison operator")
}

/// CI entry (`bitsmm obs`): parse a snapshot file, validate the schema
/// on every line, and assert each comma-separated requirement against
/// the **final** (last) snapshot. Returns a human summary line.
pub fn check_snapshot_file(path: &std::path::Path, requires: &str) -> Result<String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    let snaps = parse_snapshots(&text)?;
    let last = snaps.last().unwrap();
    let mut checked = 0usize;
    for req in requires.split(',').map(str::trim).filter(|r| !r.is_empty()) {
        check_requirement(last, req)?;
        checked += 1;
    }
    Ok(format!(
        "{}: {} snapshots, {} requirements hold on the final snapshot",
        path.display(),
        snaps.len(),
        checked
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::packed::StealStats;
    use std::time::Duration;

    fn sample_metrics() -> Metrics {
        let mut m = Metrics::default();
        m.latency.record(Duration::from_micros(120));
        m.latency.record(Duration::from_micros(480));
        m.requests = 2;
        m.batches = 1;
        m.macs = 4096;
        m.wall = Duration::from_millis(10);
        m.faults.injected = 1;
        m.faults.masked_transient = 1;
        m.scrub.sweeps = 3;
        m.scrub.repaired = 1;
        m.plan.hits = 2;
        m.device.tiles = 4;
        m
    }

    #[test]
    fn snapshot_round_trips_through_the_reader() {
        let m = sample_metrics();
        let line = render_snapshot(0, true, &m);
        let snaps = parse_snapshots(&line).unwrap();
        assert_eq!(snaps.len(), 1);
        let v = &snaps[0];
        assert_eq!(lookup(v, "latency.count").unwrap().as_int().unwrap(), 2);
        assert_eq!(lookup(v, "latency.p50_us").unwrap().as_int().unwrap(), 480);
        assert_eq!(lookup(v, "latency.mean_us").unwrap().as_f64().unwrap(), 300.0);
        assert_eq!(lookup(v, "faults.masked_transient").unwrap().as_int().unwrap(), 1);
        assert_eq!(lookup(v, "scrub.repaired").unwrap().as_int().unwrap(), 1);
        assert_eq!(lookup(v, "plan.hits").unwrap().as_int().unwrap(), 2);
        assert_eq!(lookup(v, "device.tiles").unwrap().as_int().unwrap(), 4);
        assert_eq!(lookup(v, "requests").unwrap().as_int().unwrap(), 2);
        assert!(lookup(v, "throughput_rps").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(lookup(v, "final").unwrap(), &Json::Bool(true));
    }

    /// Satellite: the starved-worker imbalance is `inf` in the table
    /// rendering but must be `null` in the snapshot — both pinned.
    #[test]
    fn non_finite_imbalance_renders_null_in_json_inf_in_tables() {
        let mut m = Metrics::default();
        m.steal = StealStats {
            tiles: 6,
            steals: 0,
            max_worker_tiles: 6,
            min_worker_tiles: 0,
        };
        assert_eq!(m.worker_tile_imbalance(), f64::INFINITY);
        // table rendering keeps `inf`
        assert_eq!(crate::coordinator::metrics::imbalance_label(m.worker_tile_imbalance()), "inf");
        // snapshot renders null, and the whole line still parses
        let line = render_snapshot(0, true, &m);
        let v = &parse_snapshots(&line).unwrap()[0];
        assert!(lookup(v, "steal.imbalance").unwrap().is_null());
        // finite imbalance stays a number in both renderings
        m.steal.min_worker_tiles = 3;
        assert_eq!(crate::coordinator::metrics::imbalance_label(m.worker_tile_imbalance()), "2.00");
        let line = render_snapshot(1, true, &m);
        let v = &parse_snapshots(&line).unwrap()[0];
        assert_eq!(lookup(v, "steal.imbalance").unwrap().as_f64().unwrap(), 2.0);
    }

    #[test]
    fn requirements_check_against_the_final_snapshot() {
        let m = sample_metrics();
        let text = format!(
            "{}\n{}\n",
            render_snapshot(0, false, &Metrics::default()),
            render_snapshot(1, true, &m)
        );
        let dir = std::env::temp_dir().join("bitsmm_obs_snapshot_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("metrics.jsonl");
        std::fs::write(&path, &text).unwrap();
        let summary = check_snapshot_file(
            &path,
            "faults.unmasked=0, scrub.repaired>=1, latency.count>1, steal.imbalance=null, plan.hits==2",
        )
        .unwrap();
        assert!(summary.contains("2 snapshots"));
        assert!(summary.contains("5 requirements"));
        // a failing requirement reports path and value
        let err = check_snapshot_file(&path, "faults.unmasked>=1").unwrap_err();
        assert!(err.to_string().contains("faults.unmasked"), "{err}");
        // schema damage is caught on every line, not just the last
        std::fs::write(&path, "{\"seq\":0}\n").unwrap();
        assert!(check_snapshot_file(&path, "").is_err(), "missing groups rejected");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn requirement_grammar() {
        assert_eq!(split_requirement("a.b>=1").unwrap(), ("a.b", ">=", "1"));
        assert_eq!(split_requirement("a=null").unwrap(), ("a", "=", "null"));
        assert_eq!(split_requirement("a.b.c<2.5").unwrap(), ("a.b.c", "<", "2.5"));
        assert!(split_requirement("nonsense").is_err());
        assert!(split_requirement("=1").is_err());
    }
}
