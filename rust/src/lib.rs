//! # bitSMM — bit-Serial Matrix Multiplication Accelerator
//!
//! Reproduction of *"bitSMM: A bit-Serial Matrix Multiplication
//! Accelerator"* (Antunes & Podobas, CS.AR 2026) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! The crate contains:
//!
//! * [`bits`] — two's-complement / Booth-recoding / bit-plane arithmetic
//!   (the shared ground truth for the simulator and all tests), plus
//!   the word-packed plane engine (`bits::packed`) behind the serving
//!   stack's `Backend::Packed` hot path.
//! * [`sim`] — a **bit-true, cycle-accurate** simulator of the paper's
//!   hardware: both bit-serial MAC variants (Booth, SBMwC), the
//!   parallel-to-serial converters, the systolic array with its skewed
//!   streaming network, and the snake-traversal readout network.
//! * [`arch`] — analytical models: the paper's throughput equations
//!   (eqs. 6–10), the FPGA resource/power model behind Table II, and the
//!   ASIC area/power models behind Table III.
//! * [`baselines`] — cycle/throughput models of the comparator designs
//!   (BISMO, Loom, Stripes, FSSA) used for Table IV.
//! * [`nn`] — the NN substrate: integer tensors, symmetric quantization,
//!   linear / conv2d / attention layers, and a tiny model zoo.
//! * [`coordinator`] — the serving stack: matmul tiler, per-layer
//!   precision policy, dynamic batcher, scheduler and threaded server.
//! * [`device`] — the instruction-driven device backend: a four-op
//!   ISA (`Fetch`/`Execute`/`Writeback`/`Sync`), the narrow `SimIf`
//!   register/DMA transport the simulator implements, and the
//!   double-buffered driver that streams packed plane words into the
//!   array and reports fetch/execute overlap (see DESIGN.md §Device).
//! * [`plan`] — the shape-keyed execution planner: per-(shape,
//!   precision) kernel/thread/tile plans resolved through a persistent
//!   cache, a cost model, and on-line calibration (`bitsmm tune`).
//! * [`obs`] — the flight-telemetry layer: per-request trace spans in
//!   a fixed-capacity ring, the bounded log-bucketed histogram behind
//!   `LatencyStats`, and JSONL metrics snapshots that CI parses
//!   instead of grepping tables (see DESIGN.md §Observability).
//! * [`runtime`] — PJRT client wrapper that loads the AOT-compiled HLO
//!   artifacts produced by `python/compile/aot.py` and executes them on
//!   the request path (Python is never on the request path).
//! * Substrates built in-repo because the environment is offline:
//!   [`cli`] (argument parsing), [`config`] (TOML-subset parser),
//!   [`report`] (paper-style tables), [`proptest_lite`] (property
//!   testing with shrinking), [`bench_harness`] (timing statistics),
//!   [`prng`] (SplitMix64/PCG32).
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index
//! mapping every paper table/figure to a bench target.

pub mod arch;
pub mod baselines;
pub mod bench_harness;
pub mod bits;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod device;
pub mod nn;
pub mod obs;
pub mod plan;
pub mod prng;
pub mod proptest_lite;
pub mod report;
pub mod runtime;
pub mod sim;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Maximum operand bit width supported by the hardware (compile-time
/// constant in the paper; all MACs are synthesized for up to 16-bit
/// operands, §III-A).
pub const MAX_BITS: u32 = 16;

/// Check that a runtime-configured operand width is legal (1..=16).
pub fn validate_bits(bits: u32) -> Result<u32> {
    if (1..=MAX_BITS).contains(&bits) {
        Ok(bits)
    } else {
        anyhow::bail!("operand bit width must be in 1..={MAX_BITS}, got {bits}")
    }
}
