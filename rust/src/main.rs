//! bitSMM launcher: the L3 coordinator binary.
//!
//! Subcommands:
//!   serve      run the inference server on a zoo model
//!   tune       sweep the zoo shape census and write the plan cache
//!   simulate   run one matmul on the cycle-accurate SA simulator
//!   tables     reproduce paper Tables II / III / IV
//!   fig6       reproduce paper Fig. 6 (peak OP/cycle vs bit width)
//!   artifacts  list the AOT artifact registry
//!   help       this text

use bitsmm::cli::Command;
use bitsmm::coordinator::{serve_all_entry, SaParse};
use bitsmm::Result;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(argv: &[String]) -> Result<()> {
    let (sub, rest) = match argv.first().map(|s| s.as_str()) {
        Some(s) if !s.starts_with("--") => (s, &argv[1..]),
        _ => ("help", argv),
    };
    match sub {
        "serve" => cmd_serve(rest),
        "launch" => cmd_launch(rest),
        "tune" => cmd_tune(rest),
        "simulate" => cmd_simulate(rest),
        "tables" => cmd_tables(rest),
        "fig6" => cmd_fig6(rest),
        "artifacts" => cmd_artifacts(rest),
        "verilog" => cmd_verilog(rest),
        "obs" => cmd_obs(rest),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => anyhow::bail!("unknown subcommand '{other}'\n{HELP}"),
    }
}

const HELP: &str = "\
bitsmm — bit-serial matrix multiplication accelerator (paper reproduction)

usage: bitsmm <subcommand> [options]

subcommands:
  serve      run the inference server on a zoo model
  launch     config-file driven serving run (see configs/serve.toml)
  tune       sweep the zoo shape census, write the plan cache (configs/plans.json)
  simulate   run one matmul on the cycle-accurate SA simulator
  tables     reproduce paper Tables II / III / IV
  fig6       reproduce paper Fig. 6 (peak OP/cycle vs bit width)
  artifacts  list the AOT artifact registry
  verilog    emit the SystemVerilog for an SA configuration
  obs        check a JSONL metrics snapshot file against requirements
  help       this text

run `bitsmm <subcommand> --help` for options.
";

fn cmd_verilog(argv: &[String]) -> Result<()> {
    let cmd = Command::new("verilog", "emit SystemVerilog for an SA configuration")
        .opt("sa", "SA geometry colsxrows", Some("16x4"))
        .opt("variant", "booth|sbmwc", Some("booth"))
        .opt("out", "output file (stdout if omitted)", None)
        .switch("help", "show help");
    let args = cmd.parse(argv)?;
    if args.switch("help") {
        print!("{}", cmd.help());
        return Ok(());
    }
    let sa = SaParse::parse(
        args.get("sa").unwrap(),
        args.req::<String>("variant")?.parse()?,
    )?;
    let text = bitsmm::sim::verilog_gen::full_design(&sa, &Default::default());
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &text)?;
            println!("wrote {} bytes to {path}", text.len());
        }
        None => print!("{text}"),
    }
    Ok(())
}

/// `bitsmm obs`: validate a metrics-snapshot JSONL file (every line
/// parses, every counter group present) and assert requirements on the
/// final snapshot — CI's replacement for grepping report tables.
fn cmd_obs(argv: &[String]) -> Result<()> {
    let cmd = Command::new("obs", "check a JSONL metrics snapshot file")
        .opt("metrics", "snapshot file written by --metrics-file", None)
        .opt(
            "require",
            "comma-separated assertions on the final snapshot, e.g. 'faults.unmasked=0,scrub.repaired>=1,steal.imbalance=null'",
            Some(""),
        )
        .switch("help", "show help");
    let args = cmd.parse(argv)?;
    if args.switch("help") {
        print!("{}", cmd.help());
        return Ok(());
    }
    let path = args
        .get("metrics")
        .filter(|s| !s.trim().is_empty())
        .ok_or_else(|| anyhow::anyhow!("--metrics <path> is required"))?;
    let summary = bitsmm::obs::snapshot::check_snapshot_file(
        std::path::Path::new(path),
        args.get("require").unwrap_or(""),
    )?;
    println!("{summary}");
    Ok(())
}

fn cmd_launch(argv: &[String]) -> Result<()> {
    let cmd = Command::new("launch", "config-file driven serving run")
        .opt("config", "TOML config path", Some("configs/serve.toml"))
        .switch("help", "show help");
    let args = cmd.parse(argv)?;
    if args.switch("help") {
        print!("{}", cmd.help());
        return Ok(());
    }
    bitsmm::coordinator::entry::launch_entry(std::path::Path::new(args.get("config").unwrap()))
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    let cmd = Command::new("serve", "run the inference server on a zoo model")
        .opt("model", "zoo model: mlp|mlp-headroom|cnn|attn", Some("mlp"))
        .opt("backend", "native|packed|simulate|pjrt", Some("native"))
        .opt("sa", "SA geometry colsxrows (paper order)", Some("16x4"))
        .opt("variant", "MAC variant booth|sbmwc", Some("booth"))
        .opt("requests", "number of requests to serve", Some("64"))
        .opt("workers", "worker threads", Some("2"))
        .opt("batch", "max batch size", Some("8"))
        .opt(
            "max-queue",
            "admission control: refuse submissions beyond this queue depth (0 = unbounded)",
            Some("0"),
        )
        .opt(
            "shed-after-ms",
            "shed queued requests older than this before executing a batch (0 = never)",
            Some("0"),
        )
        .opt(
            "degrade-high-water",
            "queue depth beyond which low-priority requests serve at degraded precision (0 = off)",
            Some("0"),
        )
        .opt(
            "degrade-bits",
            "precision floor for degraded serving (clamped so outputs stay bit-identical)",
            Some("4"),
        )
        .switch(
            "abft",
            "verify packed matmuls with an exact row-checksum; recompute on mismatch",
        )
        .opt(
            "fault-plan",
            "deterministic fault schedule, e.g. 'panic@1,drop@2,seu@3,mem@4,delay@0:50ms,seed=42'",
            None,
        )
        .opt(
            "scrub-ms",
            "background integrity scrub period in ms: verify resident packed planes and repair by re-pack (0 = off)",
            Some("0"),
        )
        .opt(
            "packed-threads",
            "packed-kernel threads shared across workers (0 = auto: cores/workers)",
            Some("0"),
        )
        .opt(
            "packed-unroll",
            "packed popcount reducer: auto|scalar|unroll4|unroll8|avx2|neon",
            Some("auto"),
        )
        .opt(
            "packed-tile-rows",
            "output rows per packed-pool tile job (0 = auto)",
            Some("0"),
        )
        .opt(
            "packed-tile-cols",
            "output cols per packed-pool tile job (0 = auto)",
            Some("0"),
        )
        .opt(
            "packed-ksplit",
            "k-split chunks per packed tile (0 = auto, 1 = never split)",
            Some("0"),
        )
        .switch(
            "packed-rsr",
            "force the RSR segment kernel for statically-planned packed matmuls",
        )
        .opt(
            "planner",
            "shape-keyed execution planner: off|static|online",
            Some("off"),
        )
        .opt(
            "plan-file",
            "persistent plan cache to load (written by `bitsmm tune`)",
            Some("configs/plans.json"),
        )
        .opt(
            "metrics-file",
            "append periodic JSONL metrics snapshots to this path (empty = off)",
            Some(""),
        )
        .opt(
            "metrics-every-ms",
            "snapshot cadence in ms (0 = keep the server default of 1000)",
            Some("0"),
        )
        .opt(
            "trace-requests",
            "dump per-request trace spans as JSONL to this path at shutdown (empty = off)",
            Some(""),
        )
        .opt("artifacts", "artifact directory", None)
        .switch("help", "show help");
    let args = cmd.parse(argv)?;
    if args.switch("help") {
        print!("{}", cmd.help());
        return Ok(());
    }
    serve_all_entry(&args)
}

fn cmd_tune(argv: &[String]) -> Result<()> {
    let cmd = Command::new(
        "tune",
        "calibrate execution plans over the zoo shape census and write the plan cache",
    )
    .opt("out", "plan file to write", Some("configs/plans.json"))
    .opt(
        "threads",
        "packed-kernel pool threads for tuning (0 = all cores)",
        Some("0"),
    )
    .opt("models", "comma-separated zoo models to census", Some("mlp,cnn,attn"))
    .opt("seed", "synthetic operand seed", Some("42"))
    .switch("smoke", "CI budget: smaller shapes, no precision-override sweep")
    .switch("help", "show help");
    let args = cmd.parse(argv)?;
    if args.switch("help") {
        print!("{}", cmd.help());
        return Ok(());
    }
    let models: Vec<String> = args
        .get("models")
        .unwrap()
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    anyhow::ensure!(!models.is_empty(), "--models must name at least one zoo model");
    let opts = bitsmm::plan::TuneOpts {
        out: args.get("out").unwrap().into(),
        threads: args.req("threads")?,
        smoke: args.switch("smoke"),
        models,
        seed: args.req("seed")?,
    };
    bitsmm::plan::run_tune(&opts)?;
    Ok(())
}

fn cmd_simulate(argv: &[String]) -> Result<()> {
    let cmd = Command::new("simulate", "run one matmul on the cycle-accurate simulator")
        .opt("sa", "SA geometry colsxrows", Some("16x4"))
        .opt("variant", "booth|sbmwc", Some("booth"))
        .opt("m", "output rows", Some("4"))
        .opt("k", "contracted dim", Some("64"))
        .opt("n", "output cols", Some("16"))
        .opt("bits", "operand precision 1..16", Some("8"))
        .opt("seed", "operand seed", Some("1"))
        .opt(
            "trace",
            "write the device instruction-queue waveform (VCD) to this path",
            None,
        )
        .switch("help", "show help");
    let args = cmd.parse(argv)?;
    if args.switch("help") {
        print!("{}", cmd.help());
        return Ok(());
    }
    let sa = SaParse::parse(
        args.get("sa").unwrap(),
        args.req::<String>("variant")?.parse()?,
    )?;
    let (m, k, n) = (args.req("m")?, args.req("k")?, args.req("n")?);
    let bits: u32 = args.req("bits")?;
    let seed: u64 = args.req("seed")?;
    let trace = args.get("trace").map(std::path::Path::new);
    bitsmm::coordinator::simulate_entry(sa, m, k, n, bits, seed, trace)
}

fn cmd_tables(argv: &[String]) -> Result<()> {
    let cmd = Command::new("tables", "reproduce paper Tables II/III/IV").switch("help", "show help");
    let args = cmd.parse(argv)?;
    if args.switch("help") {
        print!("{}", cmd.help());
        return Ok(());
    }
    print!("{}", bitsmm::report::paper::render_table2());
    print!("{}", bitsmm::report::paper::render_table3());
    print!("{}", bitsmm::report::paper::render_table4());
    Ok(())
}

fn cmd_fig6(argv: &[String]) -> Result<()> {
    let cmd = Command::new("fig6", "reproduce paper Fig. 6").switch("help", "show help");
    let args = cmd.parse(argv)?;
    if args.switch("help") {
        print!("{}", cmd.help());
        return Ok(());
    }
    print!("{}", bitsmm::report::paper::render_fig6());
    Ok(())
}

fn cmd_artifacts(argv: &[String]) -> Result<()> {
    let cmd = Command::new("artifacts", "list the AOT artifact registry")
        .opt("dir", "artifact directory", None)
        .switch("help", "show help");
    let args = cmd.parse(argv)?;
    if args.switch("help") {
        print!("{}", cmd.help());
        return Ok(());
    }
    let dir = args
        .get("dir")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(bitsmm::runtime::default_artifact_dir);
    let reg = bitsmm::runtime::Registry::load(&dir)?;
    println!("{} artifacts in {}", reg.len(), dir.display());
    let mut metas: Vec<_> = reg.iter().collect();
    metas.sort_by(|a, b| a.name.cmp(&b.name));
    for m in metas {
        println!(
            "  {:<32} {:?} {} bits={} {}x{}x{} {:?}",
            m.name,
            m.kind,
            m.variant.name(),
            m.bits,
            m.m,
            m.k,
            m.n,
            m.dtype
        );
    }
    Ok(())
}
