//! Bit-true, cycle-accurate simulator of the bitSMM hardware (§III).
//!
//! This is the Rust re-implementation of the paper's [System]Verilog
//! RTL at register-transfer granularity: every architectural register
//! named in the paper (value-toggle register, multiplicand mask /
//! shift-mask, assembly shift register, Booth accumulator, the SBMwC
//! sum/difference accumulator pair, P2S shift registers, the SA's
//! skewing pipeline registers, and the readout enable chain) is
//! modelled, and the per-cycle observable behaviour (which bit enters
//! which unit on which clock edge, when accumulators update, when
//! outputs emerge) matches the paper's description and its latency
//! equations (eq. 7/8 and the readout latency of §III-B).
//!
//! Module map (paper figure → module):
//! * Fig. 2 (Booth MAC)        → [`mac_booth`]
//! * Fig. 3 (SBMwC MAC)        → [`mac_sbmwc`]
//! * Fig. 4 (SA + P2S + regs)  → [`array`], [`p2s`]
//! * Fig. 5 (snake readout)    → [`readout`]
//! * §I TMR motivation         → [`tmr`]
//!
//! Since the §Device refactor the array is *instruction-driven*: it
//! implements the [`crate::device::SimIf`] transport (register pokes +
//! per-lane DMA of [`crate::bits::PackedPlanes`] words), and the P2S
//! units in [`p2s`] consume pre-gathered bit patterns from those
//! streamed words instead of re-deriving them from integer values.
//! [`SystolicArray::matmul`] survives as a pack-then-stream convenience
//! wrapper over [`crate::device::run_tile`].
//!
//! The simulator is validated against [`crate::bits`] exactly as the
//! paper validates its RTL against testbenches (§IV-A): exhaustively
//! for ≤8-bit operand pairs, randomly for 8–16-bit, random dot products
//! for vector lengths 1–1000, and matrix products up to the SA
//! dimensions — see `rust/tests/`.

pub mod array;
pub mod driver;
pub mod mac_booth;
pub mod mac_common;
pub mod mac_sbmwc;
pub mod p2s;
pub mod readout;
pub mod stats;
pub mod tmr;
pub mod trace;
pub mod verilog_gen;

pub use array::{SaConfig, SystolicArray};
pub use driver::{mac_dot, sa_matmul, MatmulRun};
pub use mac_booth::BoothMac;
pub use mac_common::{MacInput, MacVariant};
pub use mac_sbmwc::SbmwcMac;
pub use stats::{MacStats, SimStats};

/// Default accumulator width in bits. 16×16-bit products summed over
/// vectors of length ≤ 2¹⁶ need 32 + 16 = 48 bits; the compile-time
/// default leaves headroom, mirroring the paper's fixed-at-synthesis
/// accumulator sizing.
pub const DEFAULT_ACC_BITS: u32 = 48;

/// Object-safe interface shared by both MAC variants — the SA is
/// generic over it, matching the paper's drop-in exchange of the two
/// MAC architectures inside the same array (§IV-A).
pub trait BitSerialMac {
    /// Advance one clock edge with the given input bits.
    fn step(&mut self, input: MacInput);
    /// Current dot-product accumulator value (what the readout network
    /// forwards when this MAC's enable is asserted).
    fn accumulator(&self) -> i64;
    /// Synchronous reset (the SA's global reset, §III-B).
    fn reset(&mut self);
    /// Switching-activity counters for the power model.
    fn stats(&self) -> &MacStats;
    /// Which variant this is (for reporting).
    fn variant(&self) -> MacVariant;
    /// Inject a single-event upset: flip bit `bit` of the accumulator
    /// (radiation-fault model used by the TMR harness; §I).
    fn inject_accumulator_fault(&mut self, bit: u32);
}

/// Statically dispatched MAC — the SA's grid element. `Box<dyn>` costs
/// a vtable call per MAC per cycle in the simulator's innermost loop;
/// the enum lets the compiler inline both step functions
/// (§Perf change 2).
#[derive(Debug, Clone)]
pub enum MacUnit {
    Booth(BoothMac),
    Sbmwc(SbmwcMac),
}

impl MacUnit {
    pub fn new(variant: MacVariant, acc_bits: u32) -> MacUnit {
        match variant {
            MacVariant::Booth => MacUnit::Booth(BoothMac::new(acc_bits)),
            MacVariant::Sbmwc => MacUnit::Sbmwc(SbmwcMac::new(acc_bits)),
        }
    }

    #[inline(always)]
    pub fn step(&mut self, input: MacInput) {
        match self {
            MacUnit::Booth(m) => m.step(input),
            MacUnit::Sbmwc(m) => m.step(input),
        }
    }

    #[inline]
    pub fn accumulator(&self) -> i64 {
        match self {
            MacUnit::Booth(m) => m.accumulator(),
            MacUnit::Sbmwc(m) => m.accumulator(),
        }
    }

    pub fn reset(&mut self) {
        match self {
            MacUnit::Booth(m) => m.reset(),
            MacUnit::Sbmwc(m) => m.reset(),
        }
    }

    pub fn stats(&self) -> &MacStats {
        match self {
            MacUnit::Booth(m) => m.stats(),
            MacUnit::Sbmwc(m) => m.stats(),
        }
    }

    pub fn inject_accumulator_fault(&mut self, bit: u32) {
        match self {
            MacUnit::Booth(m) => m.inject_accumulator_fault(bit),
            MacUnit::Sbmwc(m) => m.inject_accumulator_fault(bit),
        }
    }
}
