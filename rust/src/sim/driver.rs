//! High-level drivers for the cycle-accurate simulator — the Rust
//! equivalent of the paper's testbench harnesses (§IV-A), also used by
//! the coordinator's timing path.

use crate::bits::twos::Bits;
use crate::sim::array::{MatmulOutput, SaConfig, SystolicArray};
use crate::sim::mac_common::{MacInput, MacVariant};
use crate::sim::stats::MacStats;
use crate::sim::MacUnit;
use crate::Result;

/// Drive a single MAC through a full vector dot product following the
/// §III-A protocol: the multiplicand streams `b_max` cycles ahead of
/// the multiplier (eq. 7); each MAC receives the multiplier bits of the
/// current multiplication concurrently with the multiplicand bits of
/// the next. Returns `(accumulator, cycles)`; the cycle count realises
/// eq. 8: `(n_values + 1) × b_max`.
pub fn mac_dot(variant: MacVariant, mc: &[i32], ml: &[i32], bits: u32, acc_bits: u32) -> (i64, u64) {
    let (acc, cycles, _) = mac_dot_with_stats(variant, mc, ml, bits, acc_bits);
    (acc, cycles)
}

/// As [`mac_dot`] but also returns the MAC's activity counters.
pub fn mac_dot_with_stats(
    variant: MacVariant,
    mc: &[i32],
    ml: &[i32],
    bits: u32,
    acc_bits: u32,
) -> (i64, u64, MacStats) {
    assert_eq!(mc.len(), ml.len(), "dot product operand length mismatch");
    assert!(!mc.is_empty());
    let n = mc.len();
    let b = bits as usize;
    let mut mac = MacUnit::new(variant, acc_bits); // static dispatch (§Perf change 9)

    // Validate ranges once, then extract stream bits arithmetically —
    // materialising Vec<Vec<bool>> per operand dominated the driver
    // (§Perf change 8).
    let check = |v: i32, side: &str| {
        Bits::new(v, bits).unwrap_or_else(|| panic!("{side} operand {v} out of {bits}-bit range"))
    };
    let mc_pat: Vec<u32> = mc
        .iter()
        .map(|&v| crate::bits::twos::encode(check(v, "mc").value, bits))
        .collect();
    let ml_pat: Vec<u32> = ml
        .iter()
        .map(|&v| crate::bits::twos::encode(check(v, "ml").value, bits))
        .collect();

    let total = (n + 1) * b; // eq. 8
    let mut v_t = false;
    for slot in 0..=n {
        v_t = !v_t; // a new multiplicand (or the flush slot) begins
        for j in 0..b {
            let (mc_bit, mc_en) = if slot < n {
                // MSb first: bit (b−1−j)
                ((mc_pat[slot] >> (b - 1 - j)) & 1 == 1, true)
            } else {
                (false, false) // flush slot: toggle only
            };
            let (ml_bit, ml_en) = if slot >= 1 {
                // LSb first: bit j, lagging by b_max cycles
                ((ml_pat[slot - 1] >> j) & 1 == 1, true)
            } else {
                (false, false)
            };
            mac.step(MacInput {
                mc_bit,
                mc_en,
                ml_bit,
                ml_en,
                v_t,
            });
        }
    }
    (mac.accumulator(), total as u64, *mac.stats())
}

/// Result of one simulated SA matrix multiplication.
pub type MatmulRun = MatmulOutput;

/// Simulate `A (m×k) · B (k×n)` on a freshly instantiated SA of the
/// given configuration (convenience wrapper used by tests and benches;
/// the coordinator keeps long-lived arrays instead).
pub fn sa_matmul(
    cfg: SaConfig,
    a: &[i32],
    b: &[i32],
    m: usize,
    k: usize,
    n: usize,
    bits: u32,
) -> Result<MatmulRun> {
    let mut sa = SystolicArray::new(cfg);
    sa.matmul(a, b, m, k, n, bits)
}

/// Plain integer matmul reference (the simulator's oracle).
pub fn ref_matmul_i64(a: &[i32], b: &[i32], m: usize, k: usize, n: usize) -> Vec<i64> {
    let mut out = vec![0i64; m * n];
    for r in 0..m {
        for c in 0..n {
            let mut acc = 0i64;
            for kk in 0..k {
                acc += (a[r * k + kk] as i64) * (b[kk * n + c] as i64);
            }
            out[r * n + c] = acc;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::twos::{max_value, min_value};
    use crate::prng::Pcg32;
    use crate::sim::DEFAULT_ACC_BITS;

    /// §IV-A: "we exhaustively tested all multiplicand–multiplier pairs
    /// for bit widths up to 8 bits" — kept to 6 bits in the unit suite
    /// for runtime; the full 8-bit sweep lives in `rust/tests/`.
    #[test]
    fn exhaustive_pairs_small_widths() {
        for bits in 1..=6u32 {
            for a in min_value(bits)..=max_value(bits) {
                for b in min_value(bits)..=max_value(bits) {
                    for variant in [MacVariant::Booth, MacVariant::Sbmwc] {
                        let (acc, cycles) = mac_dot(variant, &[a], &[b], bits, DEFAULT_ACC_BITS);
                        assert_eq!(
                            acc,
                            (a as i64) * (b as i64),
                            "{variant:?} {a}×{b} @{bits}b"
                        );
                        assert_eq!(cycles, 2 * bits as u64);
                    }
                }
            }
        }
    }

    /// §IV-A: random operand pairs for widths between 8 and 16 bits.
    #[test]
    fn random_pairs_wide_widths() {
        let mut rng = Pcg32::new(0xb175);
        for bits in 8..=16u32 {
            for _ in 0..40 {
                let a = rng.range_i32(min_value(bits), max_value(bits));
                let b = rng.range_i32(min_value(bits), max_value(bits));
                for variant in [MacVariant::Booth, MacVariant::Sbmwc] {
                    let (acc, _) = mac_dot(variant, &[a], &[b], bits, DEFAULT_ACC_BITS);
                    assert_eq!(acc, (a as i64) * (b as i64), "{variant:?} {a}×{b} @{bits}b");
                }
            }
        }
    }

    /// §IV-A: random vector dot products, widths 1–16, lengths 1–1000.
    #[test]
    fn random_dot_products() {
        let mut rng = Pcg32::new(0xd07);
        for &len in &[1usize, 2, 3, 17, 100, 1000] {
            let bits = 1 + rng.below(16);
            let mc: Vec<i32> = (0..len)
                .map(|_| rng.range_i32(min_value(bits), max_value(bits)))
                .collect();
            let ml: Vec<i32> = (0..len)
                .map(|_| rng.range_i32(min_value(bits), max_value(bits)))
                .collect();
            let expect: i64 = mc
                .iter()
                .zip(&ml)
                .map(|(&a, &b)| (a as i64) * (b as i64))
                .sum();
            for variant in [MacVariant::Booth, MacVariant::Sbmwc] {
                let (acc, cycles) = mac_dot(variant, &mc, &ml, bits, DEFAULT_ACC_BITS);
                assert_eq!(acc, expect, "{variant:?} len={len} bits={bits}");
                assert_eq!(cycles, (len as u64 + 1) * bits as u64); // eq. 8
            }
        }
    }

    #[test]
    fn ref_matmul_sanity() {
        // [[1,2],[3,4]]·[[5],[6]] = [[17],[39]]
        assert_eq!(ref_matmul_i64(&[1, 2, 3, 4], &[5, 6], 2, 2, 1), vec![17, 39]);
    }
}
