//! Parallel-to-serial (P2S) converters (paper §III-B, Fig. 4).
//!
//! P2S units turn parallel values fetched from memory into serial bit
//! streams. Once `valid` is asserted each unit stores the value in an
//! internal shift register and shifts every cycle:
//!
//! * **Vertical** P2S (multiplicand inputs): emits **MSb first**, the
//!   internal register shifts *left* each cycle. It also drives the
//!   value toggle `v_t` that flips at each operand boundary.
//! * **Horizontal** P2S (multiplier inputs): emits **LSb first**, the
//!   register shifts *right*.
//!
//! A practical consequence the paper highlights in §V: weights can be
//! stored big-endian and activations little-endian — no in-memory data
//! manipulation before multiplication.
//!
//! Since the streamed-device refactor (DESIGN.md §Device) the P2S no
//! longer derives the bit pattern from an integer value itself: the
//! operand arrives as a ready-made two's-complement **bit pattern**
//! gathered from `PackedPlanes` words on the far side of the DMA
//! boundary ([`P2s::load_pattern`]). The packed planes are the only
//! operand source — what shifts out here is, bit for bit, what the
//! plane words store.

/// Bit emission order (which end of the register leaves first).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BitOrder {
    /// MSb first — vertical / multiplicand side (shift left).
    MsbFirst,
    /// LSb first — horizontal / multiplier side (shift right).
    LsbFirst,
}

/// One parallel-to-serial converter.
#[derive(Debug, Clone)]
pub struct P2s {
    order: BitOrder,
    /// Internal shift register (holds the two's-complement pattern).
    reg: u32,
    /// Bits remaining in the current value.
    remaining: u32,
    /// Operand width of the current value.
    width: u32,
    /// Value toggle output (vertical units drive the MACs' `v_t`).
    v_t: bool,
}

/// One emitted bit plus stream metadata.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct P2sOut {
    pub bit: bool,
    pub valid: bool,
    pub v_t: bool,
}

impl P2s {
    pub fn new(order: BitOrder) -> Self {
        P2s {
            order,
            reg: 0,
            remaining: 0,
            width: 0,
            v_t: false,
        }
    }

    /// True when the current value has fully shifted out.
    pub fn empty(&self) -> bool {
        self.remaining == 0
    }

    /// Load a new parallel bit pattern (asserting `valid` in hardware):
    /// the low `width` bits of `pattern` are the operand's
    /// two's-complement encoding exactly as stored in the packed bit
    /// planes. Flips the value toggle — this is what signals the
    /// operand boundary to the MACs downstream.
    pub fn load_pattern(&mut self, pattern: u32, width: u32) {
        debug_assert!(self.empty(), "P2S loaded while still shifting");
        debug_assert!(width >= 1 && width <= 32, "bad P2S width {width}");
        self.reg = pattern & crate::bits::twos::low_mask(width);
        self.width = width;
        self.remaining = width;
        self.v_t = !self.v_t;
    }

    /// Flip the toggle without loading data — the flush slot that lets
    /// the final operand latch once the stream ends.
    pub fn flush_toggle(&mut self) {
        self.v_t = !self.v_t;
    }

    /// Shift one bit out. When empty, emits `valid = false` and holds
    /// the toggle.
    #[inline(always)]
    pub fn shift(&mut self) -> P2sOut {
        if self.remaining == 0 {
            return P2sOut {
                bit: false,
                valid: false,
                v_t: self.v_t,
            };
        }
        let bit = match self.order {
            BitOrder::MsbFirst => {
                let b = (self.reg >> (self.width - 1)) & 1 == 1;
                self.reg = (self.reg << 1) & crate::bits::twos::low_mask(self.width);
                b
            }
            BitOrder::LsbFirst => {
                let b = self.reg & 1 == 1;
                self.reg >>= 1;
                b
            }
        };
        self.remaining -= 1;
        P2sOut {
            bit,
            valid: true,
            v_t: self.v_t,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::twos::{encode, Bits};

    fn drain(p: &mut P2s, n: u32) -> Vec<bool> {
        (0..n).map(|_| p.shift().bit).collect()
    }

    #[test]
    fn vertical_emits_msb_first() {
        let mut p = P2s::new(BitOrder::MsbFirst);
        p.load_pattern(encode(-2, 4), 4); // 1110
        assert_eq!(drain(&mut p, 4), Bits::new(-2, 4).unwrap().bits_msb_first());
        assert!(p.empty());
    }

    #[test]
    fn horizontal_emits_lsb_first() {
        let mut p = P2s::new(BitOrder::LsbFirst);
        p.load_pattern(encode(6, 4), 4); // 0110
        assert_eq!(drain(&mut p, 4), Bits::new(6, 4).unwrap().bits_lsb_first());
    }

    /// The pattern path is pinned bit-identical to the pre-refactor
    /// value path (`reg = encode(value, width)`): for every width and
    /// every representable value, loading `encode(v, w)` emits exactly
    /// the `Bits` reference sequence in both orders.
    #[test]
    fn pattern_load_matches_the_old_value_derivation() {
        for width in 1..=8u32 {
            let lo = crate::bits::twos::min_value(width);
            let hi = crate::bits::twos::max_value(width);
            for v in lo..=hi {
                let mut p = P2s::new(BitOrder::MsbFirst);
                p.load_pattern(encode(v, width), width);
                assert_eq!(
                    drain(&mut p, width),
                    Bits::new(v, width).unwrap().bits_msb_first(),
                    "msb {v}@{width}"
                );
                let mut p = P2s::new(BitOrder::LsbFirst);
                p.load_pattern(encode(v, width), width);
                assert_eq!(
                    drain(&mut p, width),
                    Bits::new(v, width).unwrap().bits_lsb_first(),
                    "lsb {v}@{width}"
                );
            }
        }
    }

    #[test]
    fn toggle_flips_per_load() {
        let mut p = P2s::new(BitOrder::MsbFirst);
        let t0 = p.shift().v_t;
        p.load_pattern(encode(3, 4), 4);
        let t1 = p.shift().v_t;
        assert_ne!(t0, t1);
        drain(&mut p, 3);
        p.load_pattern(encode(5, 4), 4);
        let t2 = p.shift().v_t;
        assert_ne!(t1, t2);
    }

    #[test]
    fn empty_stream_is_invalid_and_holds_toggle() {
        let mut p = P2s::new(BitOrder::LsbFirst);
        let o1 = p.shift();
        let o2 = p.shift();
        assert!(!o1.valid && !o2.valid);
        assert_eq!(o1.v_t, o2.v_t);
    }

    #[test]
    fn variable_width_values_in_one_stream() {
        // runtime-configurable precision: stream a 3-bit then a 5-bit value
        let mut p = P2s::new(BitOrder::MsbFirst);
        p.load_pattern(encode(-4, 3), 3); // 100
        assert_eq!(drain(&mut p, 3), vec![true, false, false]);
        p.load_pattern(encode(9, 5), 5); // 01001
        assert_eq!(drain(&mut p, 5), vec![false, true, false, false, true]);
    }

    #[test]
    fn pattern_is_masked_to_width() {
        // upper bits beyond `width` must not leak into the stream
        let mut p = P2s::new(BitOrder::LsbFirst);
        p.load_pattern(0xFFFF_FFF6, 4); // low nibble 0110
        assert_eq!(drain(&mut p, 4), Bits::new(6, 4).unwrap().bits_lsb_first());
    }
}
