//! Circuits shared by both MAC variants (§III-A): the value-toggle
//! edge detector, the multiplicand mask circuit, and the
//! multiplication-enable gating.

use crate::bits::twos::decode;
use crate::sim::stats::MacStats;

/// Which MAC architecture (paper §III-A).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MacVariant {
    /// Booth-recoded MAC (Fig. 2): single adder, add/sub selected by
    /// the two most recent multiplier bits.
    Booth,
    /// Standard-binary-multiplication-with-correction MAC (Fig. 3):
    /// two adders, sum and difference accumulators, final-bit
    /// correction.
    Sbmwc,
}

impl MacVariant {
    pub fn name(self) -> &'static str {
        match self {
            MacVariant::Booth => "booth",
            MacVariant::Sbmwc => "sbmwc",
        }
    }
}

impl std::str::FromStr for MacVariant {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "booth" => Ok(MacVariant::Booth),
            "sbmwc" => Ok(MacVariant::Sbmwc),
            other => anyhow::bail!("unknown MAC variant '{other}' (expected booth|sbmwc)"),
        }
    }
}

/// Per-cycle input bundle of one MAC — the signals of Figs. 2/3.
///
/// Signal naming follows the paper: `_i` suffixed inputs, the value
/// toggle `v_t_i`, bit-serial multiplicand `mc_i` (MSb first) and
/// multiplier `ml_i` (LSb first). The `*_en` flags model the per-row /
/// per-column enable signals of the SA (§III-B): when deasserted the
/// corresponding registers hold their state.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct MacInput {
    /// Bit-serial multiplicand bit (vertical stream, MSb first).
    pub mc_bit: bool,
    /// Multiplicand stream valid (vertical enable).
    pub mc_en: bool,
    /// Bit-serial multiplier bit (horizontal stream, LSb first).
    pub ml_bit: bool,
    /// Multiplier stream valid (horizontal enable).
    pub ml_en: bool,
    /// Value toggle `v_t_i` — flips when a new operand begins. Used
    /// instead of a cycle counter to cut switching activity (§III-A).
    pub v_t: bool,
}

impl MacInput {
    /// An idle cycle (both streams invalid, toggle unchanged).
    pub fn idle(v_t: bool) -> Self {
        MacInput {
            v_t,
            ..Default::default()
        }
    }
}

/// Multiplicand mask circuit + assembly register + toggle detector
/// (common to Figs. 2 and 3).
///
/// Between value toggles the circuit appends a leading one to the mask
/// register each cycle while the multiplicand bits shift MSb-first into
/// the assembly register. On a toggle edge it copies the mask into the
/// shift mask `s_m`, isolating the bits of the just-completed operand
/// so the *next* multiplicand can stream into the same register without
/// corrupting the ongoing multiplication (§III-A).
#[derive(Debug, Clone)]
pub struct MultiplicandCircuit {
    /// Registered copy of the value toggle (the XOR partner).
    v_t_reg: bool,
    /// Assembly shift register: multiplicand bits, MSb first.
    mc_shift: u32,
    /// Growing mask: one leading 1 appended per valid cycle.
    mask_reg: u32,
    /// Shift mask latched at the toggle — isolates the active operand.
    s_m: u32,
    /// Sign-extended value of the operand isolated by `s_m`.
    cur_mc: i64,
    /// Effective width of `cur_mc` in bits.
    cur_width: u32,
    /// Multiplication-enable: set once the first complete multiplicand
    /// has been latched (the "multiplication enable circuit").
    mul_en: bool,
}

impl Default for MultiplicandCircuit {
    fn default() -> Self {
        Self::new()
    }
}

impl MultiplicandCircuit {
    pub fn new() -> Self {
        MultiplicandCircuit {
            v_t_reg: false,
            mc_shift: 0,
            mask_reg: 0,
            s_m: 0,
            cur_mc: 0,
            cur_width: 0,
            mul_en: false,
        }
    }

    pub fn reset(&mut self) {
        *self = MultiplicandCircuit::new();
    }

    /// True when a `step` with these inputs would change no register —
    /// the fully-idle fast path the SA uses during systolic fill/drain
    /// (§Perf change 5). Idle means: no valid bit on either stream and
    /// no pending toggle edge.
    #[inline(always)]
    pub fn is_idle(&self, mc_en: bool, v_t: bool) -> bool {
        !mc_en && v_t == self.v_t_reg
    }

    /// One clock edge. Returns `true` when a toggle edge latched a new
    /// operand (i.e. the multiply datapath should reload its working
    /// multiplicand this cycle).
    #[inline(always)]
    pub fn step(&mut self, mc_bit: bool, mc_en: bool, v_t: bool, stats: &mut MacStats) -> bool {
        let toggled = v_t != self.v_t_reg;
        let mut latched = false;
        if toggled {
            stats.toggle_edges += 1;
            if self.mask_reg != 0 {
                // A complete operand sits in the assembly register:
                // copy the mask to s_m and decode the operand.
                self.s_m = self.mask_reg;
                self.cur_width = self.mask_reg.count_ones();
                self.cur_mc = decode(self.mc_shift & self.mask_reg, self.cur_width) as i64;
                self.mul_en = true;
                latched = true;
            }
            self.mask_reg = 0;
        }
        if mc_en {
            self.mc_shift = (self.mc_shift << 1) | mc_bit as u32;
            self.mask_reg = (self.mask_reg << 1) | 1;
            stats.mc_shift_cycles += 1;
        }
        self.v_t_reg = v_t;
        latched
    }

    /// The operand most recently latched (sign-extended).
    pub fn current_mc(&self) -> i64 {
        self.cur_mc
    }

    /// Width of the current operand.
    pub fn current_width(&self) -> u32 {
        self.cur_width
    }

    /// Whether the first multiplicand has arrived.
    pub fn mul_enabled(&self) -> bool {
        self.mul_en
    }

    /// The latched shift mask (exposed for inspection/tests).
    pub fn shift_mask(&self) -> u32 {
        self.s_m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::twos::Bits;

    /// Stream one operand MSb-first and confirm it latches on the next
    /// toggle edge.
    fn stream_and_latch(value: i32, width: u32) -> (i64, u32) {
        let mut c = MultiplicandCircuit::new();
        let mut stats = MacStats::default();
        let b = Bits::new(value, width).unwrap();
        let mut v_t = false;
        // first operand: toggle flips at its first bit
        v_t = !v_t;
        let msb = b.bits_msb_first();
        for (i, &bit) in msb.iter().enumerate() {
            let latched = c.step(bit, true, v_t, &mut stats);
            assert!(!latched, "latched too early at bit {i}");
        }
        // next operand begins: toggle flips, operand latches
        v_t = !v_t;
        let latched = c.step(false, true, v_t, &mut stats);
        assert!(latched);
        (c.current_mc(), c.current_width())
    }

    #[test]
    fn latches_positive_and_negative() {
        assert_eq!(stream_and_latch(6, 4), (6, 4));
        assert_eq!(stream_and_latch(-2, 4), (-2, 4));
        assert_eq!(stream_and_latch(-128, 8), (-128, 8));
        assert_eq!(stream_and_latch(0, 1), (0, 1));
        assert_eq!(stream_and_latch(-1, 1), (-1, 1));
        assert_eq!(stream_and_latch(-32768, 16), (-32768, 16));
        assert_eq!(stream_and_latch(32767, 16), (32767, 16));
    }

    #[test]
    fn mul_en_stays_false_without_data() {
        let mut c = MultiplicandCircuit::new();
        let mut stats = MacStats::default();
        for _ in 0..10 {
            c.step(false, false, false, &mut stats);
        }
        assert!(!c.mul_enabled());
        // a toggle with an empty mask register must not enable
        c.step(false, false, true, &mut stats);
        assert!(!c.mul_enabled());
    }

    #[test]
    fn back_to_back_operands_use_same_register() {
        // Stream 5 then -3 at 4 bits with no gap; both must latch
        // correctly even though they share the assembly register.
        let mut c = MultiplicandCircuit::new();
        let mut stats = MacStats::default();
        let mut v_t = false;
        let mut latched_values = Vec::new();
        for &val in &[5i32, -3] {
            v_t = !v_t;
            for (i, &bit) in Bits::new(val, 4).unwrap().bits_msb_first().iter().enumerate() {
                let latched = c.step(bit, true, v_t, &mut stats);
                if i == 0 && latched {
                    latched_values.push(c.current_mc());
                }
            }
        }
        // flush toggle to latch the second operand
        v_t = !v_t;
        if c.step(false, true, v_t, &mut stats) {
            latched_values.push(c.current_mc());
        }
        assert_eq!(latched_values, vec![5, -3]);
    }

    #[test]
    fn disabled_cycles_hold_state() {
        let mut c = MultiplicandCircuit::new();
        let mut stats = MacStats::default();
        let mut v_t = true;
        for &bit in &Bits::new(6, 4).unwrap().bits_msb_first() {
            c.step(bit, true, v_t, &mut stats);
        }
        // idle cycles: enable low, toggle unchanged — nothing shifts
        for _ in 0..5 {
            c.step(true, false, v_t, &mut stats);
        }
        v_t = !v_t;
        c.step(false, true, v_t, &mut stats);
        assert_eq!(c.current_mc(), 6);
        assert_eq!(c.current_width(), 4);
    }
}
