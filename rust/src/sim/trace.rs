//! VCD (Value Change Dump) waveform tracing for the simulator — what a
//! hardware team expects from an RTL-level model: inspect the value
//! toggle, stream bits, and accumulators of selected MACs in GTKWave.
//!
//! The writer implements the IEEE 1364 VCD subset (header, scopes,
//! `$var` declarations, timestamped value changes, change-only
//! emission).

use std::fmt::Write as _;

/// Signal width kinds supported by the tracer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VarKind {
    /// 1-bit wire.
    Wire,
    /// Multi-bit register (emitted as binary vector).
    Reg(u32),
}

/// One declared signal.
struct Var {
    id: String,
    name: String,
    kind: VarKind,
    last: Option<u64>,
}

/// A VCD writer accumulating into a string buffer.
pub struct VcdTrace {
    vars: Vec<Var>,
    body: String,
    header_done: bool,
    current_time: u64,
    time_emitted: bool,
    module: String,
}

impl VcdTrace {
    pub fn new(module: &str) -> Self {
        VcdTrace {
            vars: Vec::new(),
            body: String::new(),
            header_done: false,
            current_time: 0,
            time_emitted: false,
            module: module.to_string(),
        }
    }

    /// Declare a signal before the first `tick`. Returns its handle.
    pub fn declare(&mut self, name: &str, kind: VarKind) -> usize {
        assert!(!self.header_done, "declare before first tick");
        let idx = self.vars.len();
        // VCD id code: printable ASCII 33..=126, multi-char base-94
        let mut n = idx;
        let mut id = String::new();
        loop {
            id.push((33 + (n % 94)) as u8 as char);
            n /= 94;
            if n == 0 {
                break;
            }
        }
        self.vars.push(Var {
            id,
            name: name.to_string(),
            kind,
            last: None,
        });
        idx
    }

    /// Advance simulation time (emits `#t` lazily on the next change).
    pub fn tick(&mut self, t: u64) {
        self.header_done = true;
        assert!(t >= self.current_time, "time must be monotone");
        if t != self.current_time {
            self.current_time = t;
            self.time_emitted = false;
        }
    }

    /// Record a value; emits only on change.
    pub fn change(&mut self, handle: usize, value: u64) {
        self.header_done = true;
        let var = &mut self.vars[handle];
        if var.last == Some(value) {
            return;
        }
        var.last = Some(value);
        if !self.time_emitted {
            let _ = writeln!(self.body, "#{}", self.current_time);
            self.time_emitted = true;
        }
        match var.kind {
            VarKind::Wire => {
                let _ = writeln!(self.body, "{}{}", if value & 1 == 1 { '1' } else { '0' }, var.id);
            }
            VarKind::Reg(w) => {
                let mut bits = String::with_capacity(w as usize);
                for i in (0..w).rev() {
                    bits.push(if (value >> i) & 1 == 1 { '1' } else { '0' });
                }
                let _ = writeln!(self.body, "b{} {}", bits, var.id);
            }
        }
    }

    /// Render the complete VCD document.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("$date bitsmm simulator $end\n$version bitsmm 0.1 $end\n$timescale 1ns $end\n");
        let _ = writeln!(out, "$scope module {} $end", self.module);
        for v in &self.vars {
            let (ty, w) = match v.kind {
                VarKind::Wire => ("wire", 1),
                VarKind::Reg(w) => ("reg", w),
            };
            let _ = writeln!(out, "$var {ty} {w} {} {} $end", v.id, v.name);
        }
        out.push_str("$upscope $end\n$enddefinitions $end\n");
        out.push_str(&self.body);
        out
    }
}

/// Trace one single-MAC dot product: returns the VCD text with the
/// input bits, toggle, and accumulator of the MAC across the full
/// eq. 8 schedule — the repo's equivalent of the paper's testbench
/// waveforms.
pub fn trace_mac_dot(
    variant: crate::sim::mac_common::MacVariant,
    mc: &[i32],
    ml: &[i32],
    bits: u32,
    acc_bits: u32,
) -> String {
    use crate::bits::twos::encode;
    use crate::sim::mac_common::MacInput;
    use crate::sim::MacUnit;
    assert_eq!(mc.len(), ml.len());
    let n = mc.len();
    let b = bits as usize;
    let mut mac = MacUnit::new(variant, acc_bits);
    let mut vcd = VcdTrace::new(&format!("mac_{}", variant.name()));
    let h_clk = vcd.declare("clk", VarKind::Wire);
    let h_mc = vcd.declare("mc_i", VarKind::Wire);
    let h_mcen = vcd.declare("mc_en_i", VarKind::Wire);
    let h_ml = vcd.declare("ml_i", VarKind::Wire);
    let h_mlen = vcd.declare("ml_en_i", VarKind::Wire);
    let h_vt = vcd.declare("v_t_i", VarKind::Wire);
    let h_acc = vcd.declare("acc", VarKind::Reg(acc_bits));

    let mut v_t = false;
    let mut t = 0u64;
    for slot in 0..=n {
        v_t = !v_t;
        for j in 0..b {
            let (mc_bit, mc_en) = if slot < n {
                ((encode(mc[slot], bits) >> (b - 1 - j)) & 1 == 1, true)
            } else {
                (false, false)
            };
            let (ml_bit, ml_en) = if slot >= 1 {
                ((encode(ml[slot - 1], bits) >> j) & 1 == 1, true)
            } else {
                (false, false)
            };
            vcd.tick(t);
            vcd.change(h_clk, 1);
            vcd.change(h_mc, mc_bit as u64);
            vcd.change(h_mcen, mc_en as u64);
            vcd.change(h_ml, ml_bit as u64);
            vcd.change(h_mlen, ml_en as u64);
            vcd.change(h_vt, v_t as u64);
            mac.step(MacInput {
                mc_bit,
                mc_en,
                ml_bit,
                ml_en,
                v_t,
            });
            vcd.change(h_acc, mac.accumulator() as u64);
            vcd.tick(t + 1);
            vcd.change(h_clk, 0);
            t += 2;
        }
    }
    vcd.render()
}

/// One instruction-queue event the device driver recorded: a stage
/// (`fetch`/`execute`/`writeback`/`sync`) issuing at one scoreboard
/// cycle and retiring at another.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DeviceEvent {
    pub stage: &'static str,
    /// Tile index; `u32::MAX` marks the tile-less `Sync` barrier.
    pub tile: u32,
    pub issue: u64,
    pub retire: u64,
}

/// Instruction-queue trace for the device driver (DESIGN.md §Device):
/// collects issue/retire events per stage while the driver interprets
/// the compiled program, entirely off the hot path — the driver takes
/// `Option<&mut DeviceTrace>` and serving passes `None`.
///
/// Events arrive in *program* order but carry scoreboard times that
/// are not monotone (tile t+1's fetch issues before tile t's
/// writeback retires — that is the double buffering), so rendering
/// sorts the change list before feeding the monotone VCD writer.
#[derive(Debug, Default)]
pub struct DeviceTrace {
    events: Vec<DeviceEvent>,
}

impl DeviceTrace {
    pub fn new() -> Self {
        DeviceTrace::default()
    }

    /// Record one stage's issue/retire pair (called by the driver).
    pub fn stage(&mut self, stage: &'static str, tile: u32, issue: u64, retire: u64) {
        self.events.push(DeviceEvent { stage, tile, issue, retire });
    }

    pub fn events(&self) -> &[DeviceEvent] {
        &self.events
    }

    /// Human-readable event list, sorted by issue cycle.
    pub fn summary(&self) -> Vec<String> {
        let mut ev = self.events.clone();
        ev.sort_by_key(|e| (e.issue, e.retire));
        ev.iter()
            .map(|e| {
                let tile = if e.tile == u32::MAX { "-".to_string() } else { format!("t{}", e.tile) };
                format!("{:>9} {tile:<4} [{:>6}, {:>6})", e.stage, e.issue, e.retire)
            })
            .collect()
    }

    /// Render the queue occupancy as a VCD waveform: per stage, a
    /// `busy` wire and the resident `tile` register. Back-to-back
    /// intervals of one stage stay asserted across the shared edge.
    pub fn render_vcd(&self) -> String {
        const STAGES: [&str; 3] = ["fetch", "execute", "writeback"];
        let mut vcd = VcdTrace::new("device_queue");
        let handles: Vec<(usize, usize)> = STAGES
            .iter()
            .map(|s| {
                (
                    vcd.declare(&format!("{s}_busy"), VarKind::Wire),
                    vcd.declare(&format!("{s}_tile"), VarKind::Reg(16)),
                )
            })
            .collect();
        // (time, order, handle, value) — asserts (order 1) after
        // deasserts (order 0) at equal timestamps
        let mut changes: Vec<(u64, u8, usize, u64)> = Vec::new();
        for (si, stage) in STAGES.iter().enumerate() {
            let mut iv: Vec<(u64, u64, u32)> = self
                .events
                .iter()
                .filter(|e| e.stage == *stage)
                .map(|e| (e.issue, e.retire, e.tile))
                .collect();
            iv.sort_unstable();
            let (busy, tile_h) = handles[si];
            for (i, &(is, re, tile)) in iv.iter().enumerate() {
                changes.push((is, 1, busy, 1));
                changes.push((is, 1, tile_h, tile as u64));
                // suppress the deassert when the next interval abuts
                let back_to_back = iv.get(i + 1).is_some_and(|nx| nx.0 <= re);
                if !back_to_back {
                    changes.push((re, 0, busy, 0));
                }
            }
        }
        changes.sort_by_key(|&(t, o, h, _)| (t, o, h));
        for (t, _, h, v) in changes {
            vcd.tick(t);
            vcd.change(h, v);
        }
        vcd.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::mac_common::MacVariant;

    #[test]
    fn header_and_declarations() {
        let mut v = VcdTrace::new("top");
        v.declare("clk", VarKind::Wire);
        v.declare("acc", VarKind::Reg(8));
        let s = v.render();
        assert!(s.contains("$scope module top $end"));
        assert!(s.contains("$var wire 1 ! clk $end"));
        assert!(s.contains("$var reg 8 \" acc $end"));
        assert!(s.contains("$enddefinitions"));
    }

    #[test]
    fn change_only_emission() {
        let mut v = VcdTrace::new("t");
        let h = v.declare("x", VarKind::Wire);
        v.tick(0);
        v.change(h, 1);
        v.tick(1);
        v.change(h, 1); // no change — no emission
        v.tick(2);
        v.change(h, 0);
        let s = v.render();
        assert!(s.contains("#0\n1!"));
        assert!(!s.contains("#1"));
        assert!(s.contains("#2\n0!"));
    }

    #[test]
    fn vector_values_binary() {
        let mut v = VcdTrace::new("t");
        let h = v.declare("acc", VarKind::Reg(4));
        v.tick(0);
        v.change(h, 0b1010);
        assert!(v.render().contains("b1010 !"));
    }

    #[test]
    #[should_panic(expected = "monotone")]
    fn time_must_be_monotone() {
        let mut v = VcdTrace::new("t");
        v.tick(5);
        v.tick(3);
    }

    #[test]
    fn mac_trace_ends_at_correct_product() {
        // trace 6 × −2 at 4 bits and check the final acc value appears
        let s = trace_mac_dot(MacVariant::Booth, &[6], &[-2], 4, 16);
        // −12 in 16-bit two's complement = 1111111111110100
        assert!(s.contains("b1111111111110100"), "{s}");
        // clock toggles present, one per half-cycle of 2·b·(n+1)
        assert!(s.matches("\n1!").count() >= 8);
    }

    #[test]
    fn device_trace_renders_out_of_order_events() {
        // double-buffered schedule: tile 1's fetch issues (cycle 12)
        // before tile 0's writeback retires (cycle 40) — the driver
        // records them in program order; rendering must not panic the
        // monotone VCD writer
        let mut d = DeviceTrace::new();
        d.stage("fetch", 0, 0, 12);
        d.stage("execute", 0, 12, 36);
        d.stage("writeback", 0, 36, 40);
        d.stage("fetch", 1, 12, 24);
        d.stage("execute", 1, 40, 64);
        d.stage("writeback", 1, 64, 68);
        d.stage("sync", u32::MAX, 68, 68);
        let vcd = d.render_vcd();
        assert!(vcd.contains("$var wire 1"));
        assert!(vcd.contains("fetch_busy"));
        assert!(vcd.contains("execute_tile"));
        // fetch is back-to-back across tiles 0→1 (12 ≤ 12): busy stays
        // asserted, so exactly one deassert line lands at cycle 24
        assert_eq!(vcd.matches("#24").count(), 1);
        let sum = d.summary();
        assert_eq!(sum.len(), 7);
        assert!(sum[0].contains("fetch") && sum[0].contains("t0"));
        assert!(sum[1].contains("fetch") && sum[1].contains("t1"), "{sum:?}");
        assert!(sum.last().unwrap().contains("sync"));
    }

    #[test]
    fn device_trace_events_accumulate() {
        let mut d = DeviceTrace::new();
        assert!(d.events().is_empty());
        d.stage("fetch", 3, 5, 9);
        assert_eq!(
            d.events(),
            &[DeviceEvent { stage: "fetch", tile: 3, issue: 5, retire: 9 }]
        );
    }

    #[test]
    fn many_signals_get_distinct_ids() {
        let mut v = VcdTrace::new("t");
        let mut ids = std::collections::HashSet::new();
        for i in 0..200 {
            v.declare(&format!("s{i}"), VarKind::Wire);
        }
        let s = v.render();
        for line in s.lines().filter(|l| l.starts_with("$var")) {
            let id = line.split_whitespace().nth(3).unwrap();
            assert!(ids.insert(id.to_string()), "duplicate id {id}");
        }
        assert_eq!(ids.len(), 200);
    }
}
