//! Booth-based bit-serial MAC (paper Fig. 2, §III-A).
//!
//! Datapath per the paper: the latched multiplicand is sign-extended
//! into a working register that **shifts left one bit each cycle**;
//! add/subtract is decided by the two most recent multiplier bits
//! (Table I) and the Booth enable asserts only when they differ — so
//! the design needs a **single adder** and its adder activity tracks
//! the number of bit transitions in the multiplier, the paper's power
//! advantage over SBMwC.

use crate::bits::twos::wrap_to;
use crate::sim::mac_common::{MacInput, MacVariant, MultiplicandCircuit};
use crate::sim::stats::MacStats;
use crate::sim::BitSerialMac;

/// Cycle-accurate Booth bit-serial MAC.
#[derive(Debug, Clone)]
pub struct BoothMac {
    /// Shared multiplicand mask / assembly / toggle circuitry.
    mc_circuit: MultiplicandCircuit,
    /// Working multiplicand: sign-extended, shifted left each cycle so
    /// cycle `i` holds `M << i`.
    work_mc: i64,
    /// Previous multiplier bit (`ml[i-1]`; reset to 0 per operand —
    /// "for the first multiplier bit, we assume the previous bit is 0").
    ml_prev: bool,
    /// Dot-product accumulator (the Booth accumulator of Fig. 2).
    acc: i64,
    /// Accumulator width in bits (wrapping semantics of a hardware
    /// register).
    acc_bits: u32,
    stats: MacStats,
}

impl BoothMac {
    pub fn new(acc_bits: u32) -> Self {
        assert!((8..=63).contains(&acc_bits), "acc_bits out of range");
        BoothMac {
            mc_circuit: MultiplicandCircuit::new(),
            work_mc: 0,
            ml_prev: false,
            acc: 0,
            acc_bits,
            stats: MacStats::default(),
        }
    }
}

impl BitSerialMac for BoothMac {
    #[inline(always)]
    fn step(&mut self, input: MacInput) {
        // fully idle cycle (systolic fill/drain): nothing changes
        if !input.ml_en && self.mc_circuit.is_idle(input.mc_en, input.v_t) {
            return;
        }
        // Multiplicand side: assemble the *next* operand; on a toggle
        // edge the just-completed operand is latched and loaded into
        // the working register (reset to shift position 0).
        let latched = self
            .mc_circuit
            .step(input.mc_bit, input.mc_en, input.v_t, &mut self.stats);
        if latched {
            self.work_mc = self.mc_circuit.current_mc();
            self.ml_prev = false;
        }

        // Multiplier side: one Booth step per valid multiplier bit.
        if input.ml_en && self.mc_circuit.mul_enabled() {
            self.stats.ml_active_cycles += 1;
            // pair (cur,prev) = (0,1) → +M ; (1,0) → −M ; else 0
            // (Table I). Branch-free: the Booth digit d = prev − cur is
            // data-dependent and random multiplier bits mispredict a
            // conditional ~50% of the time (§Perf change 6).
            let d = (self.ml_prev as i64) - (input.ml_bit as i64);
            let booth_en = (d != 0) as u64;
            self.acc = wrap_to(self.acc + d * self.work_mc, self.acc_bits);
            self.stats.adder_ops += booth_en;
            self.stats.acc_writes += booth_en;
            self.ml_prev = input.ml_bit;
            // arithmetic-left-shift of the working multiplicand
            self.work_mc <<= 1;
        }
    }

    fn accumulator(&self) -> i64 {
        self.acc
    }

    fn reset(&mut self) {
        let acc_bits = self.acc_bits;
        *self = BoothMac::new(acc_bits);
    }

    fn stats(&self) -> &MacStats {
        &self.stats
    }

    fn variant(&self) -> MacVariant {
        MacVariant::Booth
    }

    fn inject_accumulator_fault(&mut self, bit: u32) {
        let bit = bit % self.acc_bits;
        self.acc = wrap_to(self.acc ^ (1i64 << bit), self.acc_bits);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::driver::mac_dot;
    use crate::sim::mac_common::MacVariant;

    #[test]
    fn paper_eq5_single_multiply() {
        // 6 × (−2) at 4 bits = −12 (paper eq. 5)
        let (acc, cycles) = mac_dot(MacVariant::Booth, &[6], &[-2], 4, 48);
        assert_eq!(acc, -12);
        assert_eq!(cycles, (1 + 1) * 4); // eq. 8: (n+1)·b_max
    }

    #[test]
    fn accumulates_dot_product() {
        // [1,2,3]·[4,5,6] = 32 at 8 bits
        let (acc, cycles) = mac_dot(MacVariant::Booth, &[1, 2, 3], &[4, 5, 6], 8, 48);
        assert_eq!(acc, 32);
        assert_eq!(cycles, (3 + 1) * 8);
    }

    #[test]
    fn adder_fires_only_on_transitions() {
        // multiplier 0 at any width fires the adder zero times
        let run = crate::sim::driver::mac_dot_with_stats(MacVariant::Booth, &[7], &[0], 8, 48);
        assert_eq!(run.2.adder_ops, 0);
        // multiplier −1 (all ones) has exactly one 0→1 transition
        let run = crate::sim::driver::mac_dot_with_stats(MacVariant::Booth, &[7], &[-1], 8, 48);
        assert_eq!(run.2.adder_ops, 1);
        assert_eq!(run.0, -7);
    }

    #[test]
    fn fault_injection_flips_bit() {
        let mut mac = BoothMac::new(16);
        assert_eq!(mac.accumulator(), 0);
        mac.inject_accumulator_fault(3);
        assert_eq!(mac.accumulator(), 8);
        mac.inject_accumulator_fault(3);
        assert_eq!(mac.accumulator(), 0);
        // flipping the top bit goes negative (two's complement register)
        mac.inject_accumulator_fault(15);
        assert!(mac.accumulator() < 0);
    }

    #[test]
    fn accumulator_wraps_like_hardware_register() {
        // 8-bit accumulator: 100 + 100 wraps
        let (acc, _) = mac_dot(MacVariant::Booth, &[100, 100], &[1, 1], 8, 8);
        assert_eq!(acc, crate::bits::twos::wrap_to(200, 8));
    }
}
