//! Snake-traversal output readout network (paper §III-B, Fig. 5).
//!
//! After a matrix multiplication completes, `read_output_enable` is
//! asserted for one cycle. The enable propagates through the array in a
//! snake-like traversal — beginning at MAC (0,0), sweeping row 0 left
//! to right, row 1 right to left, … terminating at
//! (#rows−1, #columns−1) — sequentially enabling each MAC to forward
//! its accumulator onto the multiplexed output chain. One accumulator
//! value is read per cycle, starting one cycle after the enable, for a
//! total readout latency of `rows × cols` cycles.
//!
//! Structure per the paper: `(rows−1)(cols−1)+1` pipeline registers
//! (one at the final output) and `rows·cols − 1` two-input muxes, each
//! controlled by the propagated enable of its MAC: when asserted it
//! forwards that MAC's output, otherwise it passes the previous value
//! along the chain.

/// Snake traversal order: index `p` → (row, col).
pub fn snake_position(p: usize, cols: usize) -> (usize, usize) {
    let r = p / cols;
    let c = p % cols;
    if r % 2 == 0 {
        (r, c)
    } else {
        (r, cols - 1 - c)
    }
}

/// Inverse mapping: (row, col) → snake index.
pub fn snake_index(r: usize, c: usize, cols: usize) -> usize {
    if r % 2 == 0 {
        r * cols + c
    } else {
        r * cols + (cols - 1 - c)
    }
}

/// Cycle-level model of the readout network.
///
/// Driven one `step` per clock: the enable shift register advances one
/// snake position per cycle; the selected MAC's accumulator is latched
/// into the final output register and presented the *next* cycle —
/// matching "one value per cycle starting one cycle after asserting the
/// enable" and the total latency of `rows·cols`.
#[derive(Debug, Clone)]
pub struct ReadoutNetwork {
    rows: usize,
    cols: usize,
    /// Position of the travelling enable (None = idle).
    en_pos: Option<usize>,
    /// The final output register ("one register resides at the final
    /// output").
    out_reg: Option<i64>,
    /// Cycles consumed since the enable was asserted.
    cycles: u64,
}

impl ReadoutNetwork {
    pub fn new(rows: usize, cols: usize) -> Self {
        ReadoutNetwork {
            rows,
            cols,
            en_pos: None,
            out_reg: None,
            cycles: 0,
        }
    }

    /// Number of pipeline registers the hardware instantiates
    /// (paper formula).
    pub fn pipeline_registers(&self) -> usize {
        (self.rows - 1) * (self.cols - 1) + 1
    }

    /// Number of two-input multiplexers (paper formula).
    pub fn mux_count(&self) -> usize {
        self.rows * self.cols - 1
    }

    /// Assert `read_output_enable` for one cycle. The mux chain routes
    /// MAC (0,0)'s accumulator to the final register combinationally in
    /// this same cycle, so the first value is presented one cycle
    /// later (paper: "starting one cycle after asserting the enable").
    pub fn assert_enable(&mut self, accs: &[i64]) {
        self.out_reg = Some(accs[0]); // snake position 0 = (0,0)
        self.en_pos = if self.rows * self.cols > 1 {
            Some(1)
        } else {
            None
        };
        self.cycles = 0;
    }

    /// One clock edge after the enable cycle. `accs` is the accumulator
    /// plane, row-major. Returns the value presented at the output port
    /// this cycle (if any).
    pub fn step(&mut self, accs: &[i64]) -> Option<i64> {
        let presented = self.out_reg.take();
        if let Some(pos) = self.en_pos {
            let (r, c) = snake_position(pos, self.cols);
            // the enable has travelled to snake position `pos`; its mux
            // forwards that MAC's accumulator into the output register
            self.out_reg = Some(accs[r * self.cols + c]);
            self.en_pos = if pos + 1 < self.rows * self.cols {
                Some(pos + 1)
            } else {
                None
            };
        }
        if presented.is_some() {
            self.cycles += 1;
        }
        presented
    }

    /// Drain the full array: returns the values in snake order and the
    /// number of cycles consumed after the enable cycle (= rows × cols,
    /// the paper's total readout latency).
    pub fn drain(&mut self, accs: &[i64]) -> (Vec<i64>, u64) {
        assert_eq!(accs.len(), self.rows * self.cols);
        self.assert_enable(accs);
        let mut out = Vec::with_capacity(accs.len());
        let total = self.rows * self.cols;
        let mut cycle = 0u64;
        while out.len() < total {
            cycle += 1;
            if let Some(v) = self.step(accs) {
                out.push(v);
            }
            assert!(cycle <= total as u64, "readout overran");
        }
        (out, cycle)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snake_order_4x4() {
        let cols = 4;
        let order: Vec<(usize, usize)> = (0..8).map(|p| snake_position(p, cols)).collect();
        assert_eq!(
            order,
            vec![
                (0, 0),
                (0, 1),
                (0, 2),
                (0, 3),
                (1, 3),
                (1, 2),
                (1, 1),
                (1, 0)
            ]
        );
    }

    #[test]
    fn snake_index_inverts_position() {
        for cols in [1usize, 3, 16] {
            for rows in [1usize, 4, 7] {
                for p in 0..rows * cols {
                    let (r, c) = snake_position(p, cols);
                    assert_eq!(snake_index(r, c, cols), p);
                }
            }
        }
    }

    #[test]
    fn starts_and_ends_per_paper() {
        // begins at (0,0), terminates at (rows−1, cols−1)
        let (rows, cols) = (4, 16);
        assert_eq!(snake_position(0, cols), (0, 0));
        let last = snake_position(rows * cols - 1, cols);
        assert_eq!(last.0, rows - 1);
        // odd final row would end at col 0; 4 rows → row 3 is odd →
        // terminates at (3, 0)? The paper says (#rows−1, #cols−1); with
        // even row count the snake must flip so it lands there — row 3
        // sweeps right-to-left ending at col 0. We therefore check the
        // documented endpoints for an odd row count:
        let (rows, cols) = (5, 16);
        assert_eq!(
            snake_position(rows * cols - 1, cols),
            (rows - 1, cols - 1)
        );
    }

    #[test]
    fn structural_counts_match_paper_formulas() {
        let net = ReadoutNetwork::new(4, 16);
        assert_eq!(net.pipeline_registers(), 3 * 15 + 1);
        assert_eq!(net.mux_count(), 64 - 1);
    }

    #[test]
    fn one_value_per_cycle_latency_rows_times_cols() {
        let (rows, cols) = (4, 16);
        let accs: Vec<i64> = (0..(rows * cols) as i64).collect();
        let mut net = ReadoutNetwork::new(rows, cols);
        let (vals, cycles) = net.drain(&accs);
        assert_eq!(cycles, (rows * cols) as u64);
        // values in snake order
        for (p, v) in vals.iter().enumerate() {
            let (r, c) = snake_position(p, cols);
            assert_eq!(*v, (r * cols + c) as i64);
        }
    }

    #[test]
    fn first_value_one_cycle_after_enable() {
        let mut net = ReadoutNetwork::new(2, 2);
        let accs = [10i64, 20, 30, 40];
        net.assert_enable(&accs); // enable cycle: (0,0) latched
        assert_eq!(net.step(&accs), Some(10)); // one cycle later: presented
        assert_eq!(net.step(&accs), Some(20));
        // row 1 sweeps right-to-left: (1,1)=40 then (1,0)=30
        assert_eq!(net.step(&accs), Some(40));
        assert_eq!(net.step(&accs), Some(30));
        assert_eq!(net.step(&accs), None);
    }
}
