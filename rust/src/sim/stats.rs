//! Switching-activity and cycle counters.
//!
//! The paper's power argument (§III-A) is structural: the Booth MAC
//! fires its single adder only when consecutive multiplier bits differ,
//! the value toggle replaces a free-running cycle counter, and the
//! SBMwC MAC pays for two adders every set multiplier bit. These
//! counters capture exactly those events so the FPGA/ASIC power models
//! ([`crate::arch`]) can scale dynamic power with measured activity
//! instead of assuming a constant toggle rate.

/// Per-MAC activity counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MacStats {
    /// Clock cycles in which the multiplicand assembly register shifted.
    pub mc_shift_cycles: u64,
    /// Clock cycles in which the multiplier datapath was active.
    pub ml_active_cycles: u64,
    /// Value-toggle edges observed.
    pub toggle_edges: u64,
    /// Adder firings (adds + subtracts). For SBMwC each set multiplier
    /// bit fires *two* adders (sum and difference paths).
    pub adder_ops: u64,
    /// Accumulator register writes.
    pub acc_writes: u64,
}

impl MacStats {
    pub fn merge(&mut self, other: &MacStats) {
        self.mc_shift_cycles += other.mc_shift_cycles;
        self.ml_active_cycles += other.ml_active_cycles;
        self.toggle_edges += other.toggle_edges;
        self.adder_ops += other.adder_ops;
        self.acc_writes += other.acc_writes;
    }

    /// Adder duty cycle: fraction of multiplier-active cycles that
    /// fired an adder — the headline Booth-vs-SBMwC activity metric.
    pub fn adder_duty(&self) -> f64 {
        if self.ml_active_cycles == 0 {
            0.0
        } else {
            self.adder_ops as f64 / self.ml_active_cycles as f64
        }
    }
}

/// Whole-array simulation statistics for one matrix multiplication.
#[derive(Debug, Clone, Default)]
pub struct SimStats {
    /// Cycles spent streaming/computing (eq. 8 plus systolic skew).
    pub compute_cycles: u64,
    /// Cycles spent draining the readout network (= rows × cols).
    pub readout_cycles: u64,
    /// Aggregated MAC activity across the whole grid.
    pub mac: MacStats,
    /// Number of MAC units in the array.
    pub num_macs: u64,
    /// MAC results produced (one per output element).
    pub mac_results: u64,
}

impl SimStats {
    /// Total cycles for the operation.
    pub fn total_cycles(&self) -> u64 {
        self.compute_cycles + self.readout_cycles
    }

    /// Achieved operations per cycle (paper convention: one OP per
    /// completed multiply-accumulate result element contribution,
    /// i.e. n MAC-ops per output element — see DESIGN.md eq-9 note).
    pub fn ops_per_cycle(&self, n: u64) -> f64 {
        if self.total_cycles() == 0 {
            return 0.0;
        }
        (self.mac_results * n) as f64 / self.total_cycles() as f64
    }

    /// Throughput in OPS at a clock frequency `hz`.
    pub fn ops_per_second(&self, n: u64, hz: f64) -> f64 {
        self.ops_per_cycle(n) * hz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_accumulates() {
        let mut a = MacStats {
            mc_shift_cycles: 1,
            ml_active_cycles: 2,
            toggle_edges: 3,
            adder_ops: 4,
            acc_writes: 5,
        };
        a.merge(&a.clone());
        assert_eq!(a.adder_ops, 8);
        assert_eq!(a.toggle_edges, 6);
    }

    #[test]
    fn duty_cycle() {
        let s = MacStats {
            ml_active_cycles: 10,
            adder_ops: 4,
            ..Default::default()
        };
        assert!((s.adder_duty() - 0.4).abs() < 1e-12);
        assert_eq!(MacStats::default().adder_duty(), 0.0);
    }

    #[test]
    fn throughput_math() {
        let s = SimStats {
            compute_cycles: 90,
            readout_cycles: 10,
            mac_results: 50,
            ..Default::default()
        };
        // 50 results × n=4 MAC-ops each over 100 cycles = 2 OP/cycle
        assert!((s.ops_per_cycle(4) - 2.0).abs() < 1e-12);
        assert!((s.ops_per_second(4, 300e6) - 600e6).abs() < 1.0);
    }
}
