//! SBMwC-based bit-serial MAC (paper Fig. 3, §III-A).
//!
//! Standard binary multiplication with correction: partial products are
//! *added* for every set multiplier bit except the sign bit, whose
//! partial product is *subtracted* (eq. 2). Streaming LSb-first, the
//! MAC cannot know whether the current bit is the final (sign) bit, so
//! it maintains **two accumulators** — one holding the running sum as
//! if the latest set bit were an ordinary add, the other holding the
//! value as if that bit were the sign (a subtract) — and selects
//! between them when the value toggle reveals the operand boundary.
//! That costs a second full adder, the resource/power penalty Table II
//! and Table III quantify against the Booth variant.

use crate::bits::twos::wrap_to;
use crate::sim::mac_common::{MacInput, MacVariant, MultiplicandCircuit};
use crate::sim::stats::MacStats;
use crate::sim::BitSerialMac;

/// Cycle-accurate SBMwC bit-serial MAC.
#[derive(Debug, Clone)]
pub struct SbmwcMac {
    /// Shared multiplicand mask / assembly / toggle circuitry. `m_mc`
    /// in Fig. 3 is `mc_circuit.current_mc()`.
    mc_circuit: MultiplicandCircuit,
    /// Working multiplicand, shifted left each cycle (`M << i`).
    work_mc: i64,
    /// Sum-path accumulator: all set bits treated as adds.
    acc_sum: i64,
    /// Difference-path accumulator: value if the most recent set bit
    /// is the sign bit (i.e. that partial product subtracted).
    acc_diff: i64,
    /// The most recent multiplier bit of the current operand — at the
    /// operand boundary this *was* the sign bit and selects between
    /// `acc_sum` and `acc_diff`.
    last_ml_bit: bool,
    /// Accumulator register width.
    acc_bits: u32,
    stats: MacStats,
}

impl SbmwcMac {
    pub fn new(acc_bits: u32) -> Self {
        assert!((8..=63).contains(&acc_bits), "acc_bits out of range");
        SbmwcMac {
            mc_circuit: MultiplicandCircuit::new(),
            work_mc: 0,
            acc_sum: 0,
            acc_diff: 0,
            last_ml_bit: false,
            acc_bits,
            stats: MacStats::default(),
        }
    }

    /// The correction mux of Fig. 3: if the last consumed bit of the
    /// finished operand was 1 it was the sign bit, so the difference
    /// path holds the corrected value.
    fn selected(&self) -> i64 {
        if self.last_ml_bit {
            self.acc_diff
        } else {
            self.acc_sum
        }
    }
}

impl BitSerialMac for SbmwcMac {
    #[inline(always)]
    fn step(&mut self, input: MacInput) {
        // fully idle cycle (systolic fill/drain): nothing changes
        if !input.ml_en && self.mc_circuit.is_idle(input.mc_en, input.v_t) {
            return;
        }
        let latched = self
            .mc_circuit
            .step(input.mc_bit, input.mc_en, input.v_t, &mut self.stats);
        if latched {
            // Operand boundary: commit the correction-mux selection as
            // the new base for the next value's partial products.
            let base = self.selected();
            self.acc_sum = base;
            self.acc_diff = base;
            self.last_ml_bit = false;
            self.work_mc = self.mc_circuit.current_mc();
        }

        if input.ml_en && self.mc_circuit.mul_enabled() {
            self.stats.ml_active_cycles += 1;
            // Both adders fire on a set bit: sum path adds M<<i, the
            // difference path computes (running sum) − M<<i in case
            // this is the sign bit. Branch-free on the data-dependent
            // multiplier bit (§Perf change 7): a zero bit writes
            // `base` back, which is architecturally invisible — the
            // correction mux only reads `acc_diff` when the *last* bit
            // was 1, and a set bit always rewrites it first.
            let bit = input.ml_bit as i64;
            let base = self.acc_sum;
            self.acc_sum = wrap_to(base + bit * self.work_mc, self.acc_bits);
            self.acc_diff = wrap_to(base - bit * self.work_mc, self.acc_bits);
            self.stats.adder_ops += 2 * bit as u64;
            self.stats.acc_writes += 2 * bit as u64;
            self.last_ml_bit = input.ml_bit;
            self.work_mc <<= 1;
        }
    }

    fn accumulator(&self) -> i64 {
        self.selected()
    }

    fn reset(&mut self) {
        let acc_bits = self.acc_bits;
        *self = SbmwcMac::new(acc_bits);
    }

    fn stats(&self) -> &MacStats {
        &self.stats
    }

    fn variant(&self) -> MacVariant {
        MacVariant::Sbmwc
    }

    fn inject_accumulator_fault(&mut self, bit: u32) {
        let bit = bit % self.acc_bits;
        // Upset the selected (architecturally visible) accumulator.
        if self.last_ml_bit {
            self.acc_diff = wrap_to(self.acc_diff ^ (1i64 << bit), self.acc_bits);
        } else {
            self.acc_sum = wrap_to(self.acc_sum ^ (1i64 << bit), self.acc_bits);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::driver::{mac_dot, mac_dot_with_stats};
    use crate::sim::mac_common::MacVariant;

    #[test]
    fn paper_eq2_single_multiply() {
        // 6 × (−2) at 4 bits = −12 (paper eq. 2)
        let (acc, cycles) = mac_dot(MacVariant::Sbmwc, &[6], &[-2], 4, 48);
        assert_eq!(acc, -12);
        assert_eq!(cycles, (1 + 1) * 4);
    }

    #[test]
    fn dot_product_with_negative_weights() {
        // [−8,7]·[−8,−1] = 64 − 7 = 57 at 4 bits
        let (acc, _) = mac_dot(MacVariant::Sbmwc, &[-8, 7], &[-8, -1], 4, 48);
        assert_eq!(acc, 57);
    }

    #[test]
    fn two_adders_fire_per_set_bit() {
        // multiplier 0b0101 = 5 has two set bits → 4 adder ops
        let run = mac_dot_with_stats(MacVariant::Sbmwc, &[3], &[5], 4, 48);
        assert_eq!(run.2.adder_ops, 4);
        assert_eq!(run.0, 15);
        // Booth on the same operands fires fewer adders (alternating
        // bits are Booth's worst case, but 0101 → digits (±1)×4 = 4 too;
        // use −1 where Booth clearly wins: 1 vs 2·bits)
        let booth = mac_dot_with_stats(MacVariant::Booth, &[3], &[-1], 8, 48);
        let sbmwc = mac_dot_with_stats(MacVariant::Sbmwc, &[3], &[-1], 8, 48);
        assert_eq!(booth.2.adder_ops, 1);
        assert_eq!(sbmwc.2.adder_ops, 16);
        assert_eq!(booth.0, sbmwc.0);
    }

    #[test]
    fn one_bit_operands_are_sign_only() {
        // 1-bit: pattern 1 ≡ −1, so (−1)×(−1) = 1
        let (acc, cycles) = mac_dot(MacVariant::Sbmwc, &[-1], &[-1], 1, 48);
        assert_eq!(acc, 1);
        assert_eq!(cycles, 2);
    }

    #[test]
    fn fault_injection_visible() {
        let mut mac = SbmwcMac::new(16);
        mac.inject_accumulator_fault(2);
        assert_eq!(mac.accumulator(), 4);
    }
}
