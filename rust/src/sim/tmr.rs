//! Triple modular redundancy (TMR) over bit-serial MACs.
//!
//! The paper motivates bit-serial design for space partly because "the
//! sequential nature of bit-serial arithmetic provides a unique, yet
//! unexamined, opportunity to integrate hardware redundancy and
//! resiliency schemes, such as TMR, more efficiently than traditional
//! parallel counterparts" (§I). This module realises that extension:
//! a TMR'd MAC triplicates a bit-serial MAC (cheap — each replica is an
//! AND gate plus adder(s), not a full parallel multiplier) and
//! majority-votes the accumulators. The fault-injection harness flips
//! accumulator bits mid-computation to emulate single-event upsets
//! (SEUs) and the `tmr_faults` example measures masked-fault rates.

use crate::sim::mac_common::{MacInput, MacVariant};
use crate::sim::stats::MacStats;
use crate::sim::MacUnit;

/// Bitwise 2-of-3 majority vote — the TMR voter.
pub fn majority3(a: i64, b: i64, c: i64) -> i64 {
    (a & b) | (a & c) | (b & c)
}

/// A triple-modular-redundant bit-serial MAC: three replicas stepped in
/// lockstep, accumulator read through a bitwise majority voter.
pub struct TmrMac {
    replicas: [MacUnit; 3],
    variant: MacVariant,
    /// Faults injected so far (for reporting).
    pub injected_faults: u64,
}

impl TmrMac {
    pub fn new(variant: MacVariant, acc_bits: u32) -> Self {
        TmrMac {
            replicas: [
                MacUnit::new(variant, acc_bits),
                MacUnit::new(variant, acc_bits),
                MacUnit::new(variant, acc_bits),
            ],
            variant,
            injected_faults: 0,
        }
    }

    /// Step all replicas in lockstep.
    pub fn step(&mut self, input: MacInput) {
        for r in &mut self.replicas {
            r.step(input);
        }
    }

    /// Voted accumulator value.
    pub fn voted(&self) -> i64 {
        majority3(
            self.replicas[0].accumulator(),
            self.replicas[1].accumulator(),
            self.replicas[2].accumulator(),
        )
    }

    /// Raw replica accumulators (for divergence detection/scrubbing).
    pub fn raw(&self) -> [i64; 3] {
        [
            self.replicas[0].accumulator(),
            self.replicas[1].accumulator(),
            self.replicas[2].accumulator(),
        ]
    }

    /// True when at least one replica disagrees — the scrub trigger a
    /// flight system would use to re-synchronise.
    pub fn divergent(&self) -> bool {
        let [a, b, c] = self.raw();
        !(a == b && b == c)
    }

    /// Inject an SEU into replica `which`'s accumulator bit `bit`.
    pub fn inject_fault(&mut self, which: usize, bit: u32) {
        self.replicas[which % 3].inject_accumulator_fault(bit);
        self.injected_faults += 1;
    }

    pub fn reset(&mut self) {
        for r in &mut self.replicas {
            r.reset();
        }
        self.injected_faults = 0;
    }

    pub fn variant(&self) -> MacVariant {
        self.variant
    }

    /// Activity of one replica (all replicas see identical inputs, so
    /// TMR dynamic power ≈ 3 × replica power + voter).
    pub fn replica_stats(&self) -> &MacStats {
        self.replicas[0].stats()
    }
}

/// Run a dot product on a TMR MAC while injecting `faults` random SEUs
/// at random cycles/replicas/bits; returns `(voted, reference, any
/// divergence observed)`. Used by the fault-injection example and the
/// integration tests.
pub fn tmr_dot_with_faults(
    variant: MacVariant,
    mc: &[i32],
    ml: &[i32],
    bits: u32,
    acc_bits: u32,
    faults: &[(u64, usize, u32)], // (cycle, replica, bit)
) -> (i64, i64, bool) {
    use crate::bits::twos::Bits;
    assert_eq!(mc.len(), ml.len());
    let n = mc.len();
    let b = bits as usize;
    let mut mac = TmrMac::new(variant, acc_bits);
    let mc_bits: Vec<Vec<bool>> = mc
        .iter()
        .map(|&v| Bits::new(v, bits).unwrap().bits_msb_first())
        .collect();
    let ml_bits: Vec<Vec<bool>> = ml
        .iter()
        .map(|&v| Bits::new(v, bits).unwrap().bits_lsb_first())
        .collect();
    let total = (n + 1) * b;
    let mut v_t = false;
    let mut divergence = false;
    for t in 0..total {
        let slot = t / b;
        let j = t % b;
        if j == 0 {
            v_t = !v_t;
        }
        let (mc_bit, mc_en) = if slot < n {
            (mc_bits[slot][j], true)
        } else {
            (false, false)
        };
        let (ml_bit, ml_en) = if slot >= 1 {
            (ml_bits[slot - 1][j], true)
        } else {
            (false, false)
        };
        mac.step(MacInput {
            mc_bit,
            mc_en,
            ml_bit,
            ml_en,
            v_t,
        });
        for &(fc, replica, bit) in faults {
            if fc == t as u64 {
                mac.inject_fault(replica, bit);
            }
        }
        divergence |= mac.divergent();
    }
    let reference: i64 = mc
        .iter()
        .zip(ml)
        .map(|(&a, &b2)| (a as i64) * (b2 as i64))
        .sum();
    (mac.voted(), reference, divergence)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn majority_votes_bitwise() {
        assert_eq!(majority3(0b1100, 0b1010, 0b1001), 0b1000);
        assert_eq!(majority3(7, 7, 0), 7);
        assert_eq!(majority3(-1, -1, 0), -1);
        assert_eq!(majority3(5, 5, 5), 5);
    }

    #[test]
    fn single_fault_is_masked() {
        // one SEU in one replica mid-computation: voted result correct
        let faults = [(9u64, 1usize, 5u32)];
        let (voted, reference, divergent) =
            tmr_dot_with_faults(MacVariant::Booth, &[3, -4, 5], &[6, 7, -8], 8, 48, &faults);
        assert_eq!(voted, reference);
        assert!(divergent, "fault should be observable before voting");
    }

    #[test]
    fn no_fault_no_divergence() {
        let (voted, reference, divergent) =
            tmr_dot_with_faults(MacVariant::Sbmwc, &[1, 2], &[3, 4], 6, 48, &[]);
        assert_eq!(voted, reference);
        assert!(!divergent);
    }

    #[test]
    fn double_fault_same_bit_defeats_tmr() {
        // two replicas hit at the same bit+cycle: the voter is fooled —
        // exactly the TMR limitation the literature documents
        let faults = [(11u64, 0usize, 3u32), (11u64, 1usize, 3u32)];
        let (voted, reference, _) =
            tmr_dot_with_faults(MacVariant::Booth, &[3, -4, 5], &[6, 7, -8], 8, 48, &faults);
        assert_ne!(voted, reference);
    }
}
