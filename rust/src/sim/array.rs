//! The bit-serial systolic array (paper §III-B, Fig. 4).
//!
//! A compile-time-configurable grid of `rows × cols` bit-serial MACs
//! with parallel-to-serial converters on both edges and pipeline
//! registers that propagate data across the array:
//!
//! * **Vertical** streams (top edge, one per column): multiplicands
//!   (the B operand), MSb first, propagating **downward** one row per
//!   cycle through pipeline registers, together with the value toggle
//!   and the column enable.
//! * **Horizontal** streams (left edge, one per row): multipliers (the
//!   A operand), LSb first, propagating **rightward** one column per
//!   cycle, with the row enable.
//!
//! Streams are diagonally skewed at the edges (column `c` delayed by
//! `c` cycles, row `r` by `r` cycles) so that after propagation every
//! MAC `(r,c)` sees its multiplicand and multiplier streams with the
//! exact `b_max`-cycle lead of §III-A, uniformly across the array.
//! MAC `(r,c)` therefore accumulates `Σ_k A[r,k]·B[k,c]` — the
//! output-stationary dataflow of Fig. 1.
//!
//! The paper's eq. 8/9 cycle counts ignore the systolic fill
//! (`rows + cols − 2` skew cycles); the simulator measures the true
//! count and the `sim_cycle_accuracy` bench quantifies the delta.
//!
//! Since the streamed-device refactor (DESIGN.md §Device) the array is
//! programmed like a memory-mapped device: geometry registers are
//! poked, operand **plane words** (`PackedPlanes` storage, verbatim)
//! are DMA'd into per-lane edge FIFOs, and `exec`/`readback` run the
//! compute and drain phases. [`SystolicArray`] implements the
//! [`crate::device::SimIf`] transport trait; the edge P2S units consume
//! bit patterns gathered from the DMA'd words — there is no dense
//! operand path into the array any more ([`SystolicArray::matmul`] is a
//! pack-then-stream convenience wrapper).

use crate::bits::packed::PackedPlanes;
use crate::bits::plane::PlaneKind;
use crate::bits::twos::{max_value, min_value};
use crate::device::{DevReg, DmaChannel, SimIf};
use crate::sim::mac_common::{MacInput, MacVariant};
use crate::sim::p2s::{BitOrder, P2s, P2sOut};
use crate::sim::readout::ReadoutNetwork;
use crate::sim::stats::SimStats;
use crate::sim::{MacUnit, DEFAULT_ACC_BITS};
use crate::Result;
use std::collections::VecDeque;

/// Compile-time configuration of one SA instance. The paper's evaluated
/// topologies are 16×4, 32×8 and 64×16 (#columns × #rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SaConfig {
    /// #rows — the M (output-row) extent of one tile.
    pub rows: usize,
    /// #columns — the N (output-column) extent of one tile.
    pub cols: usize,
    /// MAC variant instantiated across the grid.
    pub variant: MacVariant,
    /// Accumulator register width.
    pub acc_bits: u32,
}

impl SaConfig {
    pub fn new(rows: usize, cols: usize, variant: MacVariant) -> Self {
        SaConfig {
            rows,
            cols,
            variant,
            acc_bits: DEFAULT_ACC_BITS,
        }
    }

    /// The paper's three evaluated topologies, written `cols × rows`
    /// as in the paper ("16×4, 32×8, 64×16 (#columns and #rows)").
    pub fn paper_topologies(variant: MacVariant) -> Vec<SaConfig> {
        vec![
            SaConfig::new(4, 16, variant),
            SaConfig::new(8, 32, variant),
            SaConfig::new(16, 64, variant),
        ]
    }

    /// Number of MAC units.
    pub fn macs(&self) -> usize {
        self.rows * self.cols
    }

    /// Display as the paper writes it: `cols × rows`.
    pub fn label(&self) -> String {
        format!("{}x{}", self.cols, self.rows)
    }
}

/// Per-hop vertical pipeline register contents.
#[derive(Debug, Clone, Copy, Default)]
struct VSig {
    bit: bool,
    en: bool,
    v_t: bool,
}

/// Per-hop horizontal pipeline register contents.
#[derive(Debug, Clone, Copy, Default)]
struct HSig {
    bit: bool,
    en: bool,
}

/// Edge stream source: a P2S plus its operand-pattern queue and
/// emission skew. The queue holds two's-complement bit patterns
/// gathered from the DMA'd plane words — the P2S never sees an integer
/// value.
struct EdgeSource {
    p2s: P2s,
    /// Bit patterns yet to stream (in order), with their widths.
    queue: VecDeque<(u32, u32)>,
    /// Idle cycles before the first bit (diagonal skew + lead).
    delay: u64,
    /// Emit one zero flush operand after the queue drains (vertical
    /// side only — provides the toggle that latches the final operand).
    flush_ops_left: u32,
    flush_width: u32,
}

impl EdgeSource {
    fn new(order: BitOrder, delay: u64, flush_ops: u32, flush_width: u32) -> Self {
        EdgeSource {
            p2s: P2s::new(order),
            queue: VecDeque::new(),
            delay,
            flush_ops_left: flush_ops,
            flush_width,
        }
    }

    /// Advance one cycle, producing the edge signal.
    fn emit(&mut self) -> P2sOut {
        if self.delay > 0 {
            self.delay -= 1;
            return P2sOut {
                bit: false,
                valid: false,
                v_t: self.p2s.shift().v_t, // idle shift: holds toggle
            };
        }
        if self.p2s.empty() {
            if let Some((pat, w)) = self.queue.pop_front() {
                self.p2s.load_pattern(pat, w);
            } else if self.flush_ops_left > 0 {
                self.flush_ops_left -= 1;
                self.p2s.load_pattern(0, self.flush_width);
            }
        }
        self.p2s.shift()
    }

    fn exhausted(&self) -> bool {
        self.delay == 0 && self.p2s.empty() && self.queue.is_empty() && self.flush_ops_left == 0
    }
}

/// Gather per-value bit patterns out of one lane's DMA'd plane words
/// (plane-major, `bits × wpv` u64 words for a `k`-long vector): value
/// `kk`'s pattern is bit `kk` of every plane, reassembled LSb-plane
/// first. This is the device-side unpacker sitting between the DMA
/// FIFO and the P2S front end.
fn gather_patterns(words: &[u64], k: usize, wpv: usize, bits: u32) -> VecDeque<(u32, u32)> {
    (0..k)
        .map(|kk| {
            let (w, sh) = (kk >> 6, (kk & 63) as u32);
            let mut pat = 0u32;
            for p in 0..bits as usize {
                pat |= (((words[p * wpv + w] >> sh) & 1) as u32) << p;
            }
            (pat, bits)
        })
        .collect()
}

/// Device-visible streaming state: the geometry registers the driver
/// pokes plus the per-lane packed-word FIFOs it DMAs into.
#[derive(Debug, Default)]
struct StreamState {
    m: usize,
    n: usize,
    k: usize,
    bits: u32,
    /// Per-column vertical FIFOs (multiplicand plane words).
    v_fifos: Vec<Vec<u64>>,
    /// Per-row horizontal FIFOs (multiplier plane words).
    h_fifos: Vec<Vec<u64>>,
    /// Cumulative words received over the DMA boundary.
    dma_words: u64,
    /// Set by `exec`, cleared by `readback`.
    executed: bool,
}

impl StreamState {
    fn new(rows: usize, cols: usize) -> Self {
        StreamState {
            v_fifos: vec![Vec::new(); cols],
            h_fifos: vec![Vec::new(); rows],
            ..Default::default()
        }
    }
}

/// A simulated systolic array instance.
pub struct SystolicArray {
    cfg: SaConfig,
    macs: Vec<MacUnit>,
    /// Input register planes: the signal each MAC sees *this* cycle.
    v_regs: Vec<VSig>,
    h_regs: Vec<HSig>,
    readout: ReadoutNetwork,
    cycle: u64,
    /// Transport-facing registers and DMA FIFOs (`crate::device::SimIf`).
    stream: StreamState,
}

impl SystolicArray {
    pub fn new(cfg: SaConfig) -> Self {
        let macs = (0..cfg.macs())
            .map(|_| MacUnit::new(cfg.variant, cfg.acc_bits))
            .collect();
        SystolicArray {
            cfg,
            macs,
            v_regs: vec![VSig::default(); cfg.macs()],
            h_regs: vec![HSig::default(); cfg.macs()],
            readout: ReadoutNetwork::new(cfg.rows, cfg.cols),
            cycle: 0,
            stream: StreamState::new(cfg.rows, cfg.cols),
        }
    }

    pub fn config(&self) -> SaConfig {
        self.cfg
    }

    /// Global synchronous reset (§III-B input).
    pub fn reset(&mut self) {
        for m in &mut self.macs {
            m.reset();
        }
        self.v_regs.fill(VSig::default());
        self.h_regs.fill(HSig::default());
        self.readout = ReadoutNetwork::new(self.cfg.rows, self.cfg.cols);
        self.cycle = 0;
    }

    /// Direct accumulator plane access (row-major) — used by the TMR
    /// harness and tests; hardware exposes this only via the readout
    /// network.
    pub fn accumulators(&self) -> Vec<i64> {
        self.macs.iter().map(|m| m.accumulator()).collect()
    }

    /// Inject a single-event upset into MAC (r,c)'s accumulator.
    pub fn inject_fault(&mut self, r: usize, c: usize, bit: u32) {
        self.macs[r * self.cfg.cols + c].inject_accumulator_fault(bit);
    }

    /// Execute one matrix multiplication `A (m×k) · B (k×n)` at operand
    /// width `bits`, where `m ≤ rows` and `n ≤ cols`. Returns the m×n
    /// result (row-major) and the cycle statistics, including the
    /// snake-order readout drain.
    ///
    /// This is a convenience wrapper over the streamed transport: the
    /// operands are packed into raw two's-complement bit planes (always
    /// `Sbmwc`-kind — the MAC variant is the unit's internal
    /// architecture, not a stream encoding) and DMA'd through the
    /// [`crate::device::SimIf`] boundary exactly as the device driver
    /// would.
    pub fn matmul(&mut self, a: &[i32], b: &[i32], m: usize, k: usize, n: usize, bits: u32) -> Result<MatmulOutput> {
        let (rows, cols) = (self.cfg.rows, self.cfg.cols);
        anyhow::ensure!(m >= 1 && k >= 1 && n >= 1, "empty matmul {m}x{k}x{n}");
        anyhow::ensure!(m <= rows, "tile rows {m} exceed SA rows {rows}");
        anyhow::ensure!(n <= cols, "tile cols {n} exceed SA cols {cols}");
        anyhow::ensure!(a.len() == m * k, "A shape mismatch");
        anyhow::ensure!(b.len() == k * n, "B shape mismatch");
        crate::validate_bits(bits)?;
        let (lo, hi) = (min_value(bits), max_value(bits));
        anyhow::ensure!(
            a.iter().chain(b.iter()).all(|&v| (lo..=hi).contains(&v)),
            "operand out of {bits}-bit two's-complement range"
        );
        let pa = PackedPlanes::pack_rows(a, m, k, bits, PlaneKind::Sbmwc)?;
        let pb = PackedPlanes::pack_cols(b, k, n, bits, PlaneKind::Sbmwc)?;
        let run = crate::device::run_tile(self, &pa, 0, &pb, 0, m, n, bits)?;
        let mut stats = SimStats {
            compute_cycles: run.exec_cycles,
            readout_cycles: run.readout_cycles,
            num_macs: self.cfg.macs() as u64,
            mac_results: (m * n) as u64,
            ..Default::default()
        };
        for mac in &self.macs {
            stats.mac.merge(mac.stats());
        }
        Ok(MatmulOutput { result: run.out, stats })
    }

    /// The compute phase: run until every edge source is exhausted and
    /// every in-flight bit has propagated through the deepest pipeline.
    /// Returns the architectural cycle count (the paper's accounting
    /// stops when the last MAC has consumed its final multiplier bit;
    /// the drain allowance is a simulator artefact and is subtracted).
    fn run_compute(&mut self, v_srcs: &mut [EdgeSource], h_srcs: &mut [EdgeSource]) -> Result<u64> {
        let drain_after = (self.cfg.rows + self.cfg.cols) as u64; // conservative pipeline drain
        let mut idle_cycles = 0u64;
        let mut compute_cycles = 0u64;
        while idle_cycles < drain_after {
            let all_done = v_srcs.iter().all(|s| s.exhausted()) && h_srcs.iter().all(|s| s.exhausted());
            self.step_compute(v_srcs, h_srcs);
            compute_cycles += 1;
            if all_done {
                idle_cycles += 1;
            }
            anyhow::ensure!(
                compute_cycles < 10_000_000,
                "simulation runaway: {compute_cycles} cycles"
            );
        }
        Ok(compute_cycles - drain_after)
    }

    /// The `SimIf::exec` engine: validate the poked geometry, unpack
    /// the DMA'd plane words into per-lane pattern queues, and run the
    /// compute phase. Consumes the FIFOs; accumulators hold the tile
    /// until `readback`.
    fn exec_streamed(&mut self) -> Result<u64> {
        let (rows, cols) = (self.cfg.rows, self.cfg.cols);
        let (m, n, k, bits) = (self.stream.m, self.stream.n, self.stream.k, self.stream.bits);
        anyhow::ensure!(m >= 1 && k >= 1 && n >= 1, "device exec with unprogrammed geometry {m}x{k}x{n}");
        anyhow::ensure!(m <= rows, "tile rows {m} exceed SA rows {rows}");
        anyhow::ensure!(n <= cols, "tile cols {n} exceed SA cols {cols}");
        crate::validate_bits(bits)?;
        let wpv = k.div_ceil(64);
        let expect = bits as usize * wpv;
        for (lane, fifo) in self.stream.v_fifos.iter().enumerate() {
            let want = if lane < n { expect } else { 0 };
            anyhow::ensure!(
                fifo.len() == want,
                "vertical lane {lane}: {} plane words DMA'd, {want} expected",
                fifo.len()
            );
        }
        for (lane, fifo) in self.stream.h_fifos.iter().enumerate() {
            let want = if lane < m { expect } else { 0 };
            anyhow::ensure!(
                fifo.len() == want,
                "horizontal lane {lane}: {} plane words DMA'd, {want} expected",
                fifo.len()
            );
        }

        // Edge sources with diagonal skew. The multiplicand (vertical)
        // leads the multiplier (horizontal) by b_max cycles (eq. 7);
        // unused lanes idle through their skew with enables low,
        // exactly as before the streamed transport existed.
        let bits_u64 = bits as u64;
        let mut v_srcs: Vec<EdgeSource> = (0..cols)
            .map(|c| {
                let mut s = EdgeSource::new(BitOrder::MsbFirst, c as u64, 1, bits);
                if c < n {
                    s.queue = gather_patterns(&self.stream.v_fifos[c], k, wpv, bits);
                } else {
                    s.flush_ops_left = 0; // unused column: stays idle
                }
                s
            })
            .collect();
        let mut h_srcs: Vec<EdgeSource> = (0..rows)
            .map(|r| {
                let mut s = EdgeSource::new(BitOrder::LsbFirst, r as u64 + bits_u64, 0, bits);
                if r < m {
                    s.queue = gather_patterns(&self.stream.h_fifos[r], k, wpv, bits);
                }
                s
            })
            .collect();
        for fifo in self.stream.v_fifos.iter_mut().chain(self.stream.h_fifos.iter_mut()) {
            fifo.clear();
        }

        self.reset();
        let cycles = self.run_compute(&mut v_srcs, &mut h_srcs)?;
        self.stream.executed = true;
        Ok(cycles)
    }

    /// The `SimIf::readback` engine: snake-drain the accumulator plane
    /// through the readout network, de-snake, and crop to the
    /// programmed m×n tile.
    fn readback_streamed(&mut self) -> Result<(Vec<i64>, u64)> {
        anyhow::ensure!(self.stream.executed, "device readback before exec");
        self.stream.executed = false;
        let (rows, cols) = (self.cfg.rows, self.cfg.cols);
        let (m, n) = (self.stream.m, self.stream.n);
        let accs = self.accumulators();
        let (snake_vals, readout_cycles) = self.readout.drain(&accs);
        let mut full = vec![0i64; rows * cols];
        for (p, v) in snake_vals.iter().enumerate() {
            let (r, c) = crate::sim::readout::snake_position(p, cols);
            full[r * cols + c] = *v;
        }
        let mut result = vec![0i64; m * n];
        for r in 0..m {
            for c in 0..n {
                result[r * n + c] = full[r * cols + c];
            }
        }
        Ok((result, readout_cycles))
    }

    /// One compute-phase clock edge: emit at the edges, step every MAC
    /// with its current input registers, then shift the pipeline
    /// registers (bottom-up / right-to-left so the move is in-place).
    fn step_compute(&mut self, v_srcs: &mut [EdgeSource], h_srcs: &mut [EdgeSource]) {
        let (rows, cols) = (self.cfg.rows, self.cfg.cols);

        // 1. every MAC consumes the register plane of this cycle
        //    (zipped iterators: no per-element bounds checks in the
        //    innermost loop — §Perf change 4)
        for ((mac, v), h) in self
            .macs
            .iter_mut()
            .zip(self.v_regs.iter())
            .zip(self.h_regs.iter())
        {
            mac.step(MacInput {
                mc_bit: v.bit,
                mc_en: v.en,
                ml_bit: h.bit,
                ml_en: h.en,
                v_t: v.v_t,
            });
        }

        // 2. pipeline shift: vertical signals move down one row — a
        //    single overlapping memmove of the first rows−1 rows
        self.v_regs.copy_within(0..(rows - 1) * cols, cols);
        for (c, src) in v_srcs.iter_mut().enumerate() {
            let out = src.emit();
            self.v_regs[c] = VSig {
                bit: out.bit,
                en: out.valid,
                v_t: out.v_t,
            };
        }

        // 3. horizontal signals move right one column (one memmove per
        //    row)
        for r in 0..rows {
            let base = r * cols;
            self.h_regs.copy_within(base..base + cols - 1, base + 1);
        }
        for (r, src) in h_srcs.iter_mut().enumerate() {
            let out = src.emit();
            self.h_regs[r * cols] = HSig {
                bit: out.bit,
                en: out.valid,
            };
        }

        self.cycle += 1;
    }
}

/// The transport boundary (DESIGN.md §Device): the cycle-accurate
/// array *is* a device behind register pokes and packed-word DMA. This
/// is the seam where real hardware (or a PJRT-backed engine) attaches
/// by providing its own `SimIf` implementation.
impl SimIf for SystolicArray {
    fn poke(&mut self, reg: DevReg, val: u64) -> Result<()> {
        match reg {
            DevReg::Reset => {
                if val != 0 {
                    self.reset();
                    self.stream = StreamState::new(self.cfg.rows, self.cfg.cols);
                }
            }
            DevReg::M => self.stream.m = val as usize,
            DevReg::N => self.stream.n = val as usize,
            DevReg::K => self.stream.k = val as usize,
            DevReg::Bits => self.stream.bits = val as u32,
            DevReg::Cycle | DevReg::DmaWords => {
                anyhow::bail!("device register {reg:?} is read-only")
            }
        }
        Ok(())
    }

    fn peek(&self, reg: DevReg) -> u64 {
        match reg {
            DevReg::Reset => 0,
            DevReg::M => self.stream.m as u64,
            DevReg::N => self.stream.n as u64,
            DevReg::K => self.stream.k as u64,
            DevReg::Bits => self.stream.bits as u64,
            DevReg::Cycle => self.cycle,
            DevReg::DmaWords => self.stream.dma_words,
        }
    }

    fn dma_push(&mut self, ch: DmaChannel, lane: usize, words: &[u64]) -> Result<()> {
        let fifos = match ch {
            DmaChannel::Vertical => &mut self.stream.v_fifos,
            DmaChannel::Horizontal => &mut self.stream.h_fifos,
        };
        anyhow::ensure!(
            lane < fifos.len(),
            "DMA lane {lane} out of range for {ch:?} ({} lanes)",
            fifos.len()
        );
        fifos[lane].extend_from_slice(words);
        self.stream.dma_words += words.len() as u64;
        Ok(())
    }

    fn exec(&mut self) -> Result<u64> {
        self.exec_streamed()
    }

    fn readback(&mut self) -> Result<(Vec<i64>, u64)> {
        self.readback_streamed()
    }
}

/// Result bundle of one simulated matmul.
#[derive(Debug, Clone)]
pub struct MatmulOutput {
    /// Row-major m×n product.
    pub result: Vec<i64>,
    /// Cycle and activity statistics.
    pub stats: SimStats,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::mac_common::MacVariant;

    fn ref_matmul(a: &[i32], b: &[i32], m: usize, k: usize, n: usize) -> Vec<i64> {
        let mut out = vec![0i64; m * n];
        for r in 0..m {
            for c in 0..n {
                for kk in 0..k {
                    out[r * n + c] += (a[r * k + kk] as i64) * (b[kk * n + c] as i64);
                }
            }
        }
        out
    }

    #[test]
    fn tiny_2x2_both_variants() {
        let a = [1, 2, 3, 4]; // 2×2
        let b = [5, 6, 7, -8]; // 2×2
        for variant in [MacVariant::Booth, MacVariant::Sbmwc] {
            let mut sa = SystolicArray::new(SaConfig::new(2, 2, variant));
            let out = sa.matmul(&a, &b, 2, 2, 2, 5).unwrap();
            assert_eq!(out.result, ref_matmul(&a, &b, 2, 2, 2), "{variant:?}");
        }
    }

    #[test]
    fn rectangular_tile_smaller_than_array() {
        // 3×5 · 5×7 inside a 4-row × 16-col array at 6 bits
        let (m, k, n) = (3usize, 5usize, 7usize);
        let a: Vec<i32> = (0..m * k).map(|i| (i as i32 % 31) - 15).collect();
        let b: Vec<i32> = (0..k * n).map(|i| ((i as i32 * 7) % 31) - 15).collect();
        let mut sa = SystolicArray::new(SaConfig::new(4, 16, MacVariant::Booth));
        let out = sa.matmul(&a, &b, m, k, n, 6).unwrap();
        assert_eq!(out.result, ref_matmul(&a, &b, m, k, n));
    }

    #[test]
    fn compute_cycles_close_to_eq8() {
        // eq. 8: (n_values+1)·b_max; simulator adds the systolic fill
        let (m, k, n, bits) = (4usize, 32usize, 16usize, 8u32);
        let a = vec![1i32; m * k];
        let b = vec![1i32; k * n];
        let mut sa = SystolicArray::new(SaConfig::new(4, 16, MacVariant::Booth));
        let out = sa.matmul(&a, &b, m, k, n, bits).unwrap();
        let eq8 = ((k as u64) + 1) * bits as u64;
        let measured = out.stats.compute_cycles;
        assert!(
            measured >= eq8 && measured <= eq8 + (4 + 16) as u64,
            "measured {measured} vs eq8 {eq8}"
        );
        assert_eq!(out.stats.readout_cycles, 4 * 16);
    }

    #[test]
    fn rejects_bad_shapes_and_ranges() {
        let mut sa = SystolicArray::new(SaConfig::new(2, 2, MacVariant::Booth));
        assert!(sa.matmul(&[1, 2, 3, 4, 5, 6], &[1, 1], 3, 2, 1, 4).is_err()); // m > rows
        assert!(sa.matmul(&[100], &[1], 1, 1, 1, 4).is_err()); // out of 4-bit range
        assert!(sa.matmul(&[1], &[1], 1, 1, 1, 0).is_err()); // bad width
        assert!(sa.matmul(&[1], &[1], 1, 1, 1, 17).is_err());
    }

    #[test]
    fn one_bit_matmul_binary_weights() {
        // 1-bit two's complement values are {0,−1}: the BNN-style corner
        let a = [0, -1, -1, 0]; // 2×2
        let b = [-1, -1, 0, -1]; // 2×2
        let mut sa = SystolicArray::new(SaConfig::new(2, 2, MacVariant::Booth));
        let out = sa.matmul(&a, &b, 2, 2, 2, 1).unwrap();
        assert_eq!(out.result, ref_matmul(&a, &b, 2, 2, 2));
    }

    /// Drive the transport trait by hand — poke geometry, DMA the
    /// plane words verbatim, exec, readback — and pin it to the dense
    /// wrapper path.
    #[test]
    fn raw_simif_streaming_matches_the_wrapper() {
        let (m, k, n, bits) = (3usize, 70usize, 5usize, 7u32); // k > 64: tail word
        let a: Vec<i32> = (0..m * k).map(|i| (i as i32 % 127) - 63).collect();
        let b: Vec<i32> = (0..k * n).map(|i| ((i as i32 * 11) % 127) - 63).collect();
        let pa = PackedPlanes::pack_rows(&a, m, k, bits, PlaneKind::Sbmwc).unwrap();
        let pb = PackedPlanes::pack_cols(&b, k, n, bits, PlaneKind::Sbmwc).unwrap();

        let mut dev = SystolicArray::new(SaConfig::new(4, 16, MacVariant::Booth));
        dev.poke(DevReg::Reset, 1).unwrap();
        dev.poke(DevReg::M, m as u64).unwrap();
        dev.poke(DevReg::N, n as u64).unwrap();
        dev.poke(DevReg::K, k as u64).unwrap();
        dev.poke(DevReg::Bits, bits as u64).unwrap();
        let mut buf = Vec::new();
        for c in 0..n {
            buf.clear();
            pb.dma_words(c, &mut buf);
            dev.dma_push(DmaChannel::Vertical, c, &buf).unwrap();
        }
        for r in 0..m {
            buf.clear();
            pa.dma_words(r, &mut buf);
            dev.dma_push(DmaChannel::Horizontal, r, &buf).unwrap();
        }
        let exec_cycles = dev.exec().unwrap();
        let (out, readout_cycles) = dev.readback().unwrap();

        let mut sa = SystolicArray::new(SaConfig::new(4, 16, MacVariant::Booth));
        let want = sa.matmul(&a, &b, m, k, n, bits).unwrap();
        assert_eq!(out, want.result);
        assert_eq!(exec_cycles, want.stats.compute_cycles);
        assert_eq!(readout_cycles, want.stats.readout_cycles);
        assert_eq!(dev.peek(DevReg::DmaWords), ((m + n) * 2 * bits as usize) as u64);
    }

    #[test]
    fn sixteen_bit_extremes() {
        let a = [32767, -32768, -1, 0]; // 2×2
        let b = [-32768, 32767, 32767, -32768]; // 2×2
        for variant in [MacVariant::Booth, MacVariant::Sbmwc] {
            let mut sa = SystolicArray::new(SaConfig::new(2, 2, variant));
            let out = sa.matmul(&a, &b, 2, 2, 2, 16).unwrap();
            assert_eq!(out.result, ref_matmul(&a, &b, 2, 2, 2), "{variant:?}");
        }
    }
}
