//! TOML-subset configuration parser (offline environment — no `serde`
//! / `toml`; see DESIGN.md substitutions).
//!
//! Supports the subset the launcher needs: `[section]` and
//! `[section.sub]` headers, `key = value` with strings, integers,
//! floats, booleans, and flat arrays, plus `#` comments. Values are
//! addressed by dotted path (`"server.workers"`).

use crate::Result;
use std::collections::BTreeMap;

/// A parsed configuration value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// Flat dotted-path configuration map.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    entries: BTreeMap<String, Value>,
}

impl Config {
    /// Parse TOML-subset text.
    pub fn parse(text: &str) -> Result<Config> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(h) = line.strip_prefix('[') {
                let h = h
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow::anyhow!("line {}: unterminated section", lineno + 1))?
                    .trim();
                anyhow::ensure!(!h.is_empty(), "line {}: empty section", lineno + 1);
                section = h.to_string();
            } else {
                let (k, v) = line
                    .split_once('=')
                    .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value", lineno + 1))?;
                let key = if section.is_empty() {
                    k.trim().to_string()
                } else {
                    format!("{section}.{}", k.trim())
                };
                let value = parse_value(v.trim())
                    .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
                anyhow::ensure!(
                    cfg.entries.insert(key.clone(), value).is_none(),
                    "line {}: duplicate key {key}",
                    lineno + 1
                );
            }
        }
        Ok(cfg)
    }

    pub fn load(path: &std::path::Path) -> Result<Config> {
        Config::parse(&std::fs::read_to_string(path)?)
    }

    pub fn get(&self, path: &str) -> Option<&Value> {
        self.entries.get(path)
    }

    pub fn str_or<'a>(&'a self, path: &str, default: &'a str) -> &'a str {
        self.get(path).and_then(Value::as_str).unwrap_or(default)
    }

    pub fn int_or(&self, path: &str, default: i64) -> i64 {
        self.get(path).and_then(Value::as_int).unwrap_or(default)
    }

    pub fn float_or(&self, path: &str, default: f64) -> f64 {
        self.get(path).and_then(Value::as_float).unwrap_or(default)
    }

    pub fn bool_or(&self, path: &str, default: bool) -> bool {
        self.get(path).and_then(Value::as_bool).unwrap_or(default)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // no escape handling needed for the subset: '#' inside strings is
    // not supported; keep the launcher configs simple
    let mut in_str = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    anyhow::ensure!(!s.is_empty(), "empty value");
    if let Some(body) = s.strip_prefix('"') {
        let body = body
            .strip_suffix('"')
            .ok_or_else(|| anyhow::anyhow!("unterminated string"))?;
        return Ok(Value::Str(body.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body
            .strip_suffix(']')
            .ok_or_else(|| anyhow::anyhow!("unterminated array"))?
            .trim();
        if body.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let items: Result<Vec<Value>> = body.split(',').map(|i| parse_value(i.trim())).collect();
        return Ok(Value::Array(items?));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    anyhow::bail!("cannot parse value '{s}'")
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# bitSMM launcher config
name = "demo"

[sa]
rows = 4
cols = 16
variant = "booth"

[server]
workers = 2
linger_ms = 2.5
pjrt = true
layer_bits = [8, 4, 4]
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str_or("name", "?"), "demo");
        assert_eq!(c.int_or("sa.rows", 0), 4);
        assert_eq!(c.str_or("sa.variant", "?"), "booth");
        assert_eq!(c.float_or("server.linger_ms", 0.0), 2.5);
        assert!(c.bool_or("server.pjrt", false));
        let arr = c.get("server.layer_bits").unwrap().as_array().unwrap();
        assert_eq!(
            arr.iter().map(|v| v.as_int().unwrap()).collect::<Vec<_>>(),
            vec![8, 4, 4]
        );
    }

    #[test]
    fn defaults_for_missing_keys() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.int_or("nope", 7), 7);
        assert_eq!(c.str_or("nope", "x"), "x");
    }

    #[test]
    fn comments_and_hash_in_string() {
        let c = Config::parse("a = \"has # inside\" # trailing\n").unwrap();
        assert_eq!(c.str_or("a", "?"), "has # inside");
    }

    #[test]
    fn rejects_malformed() {
        assert!(Config::parse("[unterminated\n").is_err());
        assert!(Config::parse("novalue\n").is_err());
        assert!(Config::parse("a = \n").is_err());
        assert!(Config::parse("a = 1\na = 2\n").is_err());
        assert!(Config::parse("a = \"oops\n").is_err());
    }

    #[test]
    fn int_promotes_to_float() {
        let c = Config::parse("x = 3\n").unwrap();
        assert_eq!(c.float_or("x", 0.0), 3.0);
    }
}
