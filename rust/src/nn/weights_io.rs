//! Loader for the trained quantized model exported by
//! `python/compile/train.py` (`artifacts/trained_mlp.txt`) — weights,
//! per-layer precisions/scales, and the held-out eval set, so the Rust
//! serving stack can run a *genuinely trained* workload and measure
//! the accuracy the accelerator delivers.

use crate::nn::layers::{Layer, LinearLayer, PackedCache};
use crate::nn::model::Model;
use crate::nn::tensor::QTensor;
use crate::Result;
use std::path::Path;

/// The trained bundle: the model plus its evaluation split.
#[derive(Debug, Clone)]
pub struct TrainedBundle {
    pub model: Model,
    /// Eval inputs, quantized on the model's input grid (row-major
    /// `n × d`).
    pub eval_x: Vec<i32>,
    pub eval_n: usize,
    pub eval_d: usize,
    /// Eval labels.
    pub eval_y: Vec<usize>,
    /// Accuracies measured at export time (float / bit-serial python).
    pub float_acc: f64,
    pub python_quant_acc: f64,
}

/// Parse `trained_mlp.txt`.
pub fn load_trained(path: &Path) -> Result<TrainedBundle> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("cannot read {} ({e}); run `make artifacts`", path.display()))?;
    parse_trained(&text)
}

/// Parse the export text (separated for tests).
pub fn parse_trained(text: &str) -> Result<TrainedBundle> {
    let mut lines = text.lines().filter(|l| !l.trim_start().starts_with('#'));
    let mut kv = |expect: &str| -> Result<Vec<String>> {
        let line = lines
            .next()
            .ok_or_else(|| anyhow::anyhow!("unexpected EOF expecting '{expect}'"))?;
        let f: Vec<String> = line.split_whitespace().map(str::to_string).collect();
        anyhow::ensure!(
            f.first().map(String::as_str) == Some(expect),
            "expected '{expect}', got '{line}'"
        );
        Ok(f)
    };

    let n_layers: usize = kv("layers")?[1].parse()?;
    let input_bits: u32 = kv("input_bits")?[1].parse()?;
    let input_scale: f64 = kv("input_scale")?[1].parse()?;
    let float_acc: f64 = kv("float_acc")?[1].parse()?;
    let python_quant_acc: f64 = kv("quant_acc")?[1].parse()?;

    let mut layers = Vec::with_capacity(n_layers);
    let mut d_in0 = None;
    for i in 0..n_layers {
        let hdr = kv("layer")?;
        anyhow::ensure!(hdr[1].parse::<usize>()? == i, "layer index mismatch");
        let field = |name: &str| -> Result<f64> {
            let pos = hdr
                .iter()
                .position(|t| t == name)
                .ok_or_else(|| anyhow::anyhow!("layer line missing '{name}'"))?;
            Ok(hdr[pos + 1].parse()?)
        };
        let d_in = field("in")? as usize;
        let d_out = field("out")? as usize;
        let bits = field("bits")? as u32;
        let w_scale = field("w_scale")?;
        let relu = field("relu")? != 0.0;
        let out_bits = field("out_bits")? as u32;
        let out_scale = field("out_scale")?;
        d_in0.get_or_insert(d_in);

        let wline = kv("w")?;
        let w: Vec<i32> = wline[1..]
            .iter()
            .map(|t| t.parse::<i32>().map_err(anyhow::Error::from))
            .collect::<Result<_>>()?;
        anyhow::ensure!(w.len() == d_in * d_out, "layer {i} weight blob size");
        let bline = kv("b")?;
        let bias: Vec<i64> = bline[1..]
            .iter()
            .map(|t| t.parse::<i64>().map_err(anyhow::Error::from))
            .collect::<Result<_>>()?;
        anyhow::ensure!(bias.len() == d_out, "layer {i} bias blob size");

        layers.push(Layer::Linear(LinearLayer {
            w: QTensor::new(w, vec![d_in, d_out], w_scale, bits)?,
            bias,
            bits,
            relu,
            out_scale,
            out_bits,
            packed: PackedCache::new(),
        }));
    }

    let eval_hdr = kv("eval")?;
    let eval_n: usize = eval_hdr[1].parse()?;
    let eval_d: usize = eval_hdr[2].parse()?;
    let xline = kv("x")?;
    let eval_x: Vec<i32> = xline[1..]
        .iter()
        .map(|t| t.parse::<i32>().map_err(anyhow::Error::from))
        .collect::<Result<_>>()?;
    anyhow::ensure!(eval_x.len() == eval_n * eval_d, "eval x blob size");
    let yline = kv("y")?;
    let eval_y: Vec<usize> = yline[1..]
        .iter()
        .map(|t| t.parse::<usize>().map_err(anyhow::Error::from))
        .collect::<Result<_>>()?;
    anyhow::ensure!(eval_y.len() == eval_n, "eval y blob size");

    Ok(TrainedBundle {
        model: Model {
            name: "trained-mlp".into(),
            layers,
            input_shape: vec![d_in0.unwrap_or(eval_d)],
            input_bits,
            input_scale,
        },
        eval_x,
        eval_n,
        eval_d,
        eval_y,
        float_acc,
        python_quant_acc,
    })
}

/// Run the bundle's eval split through a matmul executor and return
/// the classification accuracy — the accelerator-delivered accuracy.
pub fn evaluate(bundle: &TrainedBundle, exec: &mut dyn crate::nn::layers::MatmulExec) -> Result<f64> {
    let x = QTensor::new(
        bundle.eval_x.clone(),
        vec![bundle.eval_n, bundle.eval_d],
        bundle.model.input_scale,
        bundle.model.input_bits,
    )?;
    let logits = bundle.model.forward(&x, exec)?;
    let classes = logits.shape[1];
    let mut correct = 0usize;
    for i in 0..bundle.eval_n {
        let row = &logits.data[i * classes..(i + 1) * classes];
        let pred = row
            .iter()
            .enumerate()
            .max_by_key(|(_, &v)| v)
            .map(|(j, _)| j)
            .unwrap();
        if pred == bundle.eval_y[i] {
            correct += 1;
        }
    }
    Ok(correct as f64 / bundle.eval_n as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment
layers 1
input_bits 4
input_scale 0.5
float_acc 0.95
quant_acc 0.9
layer 0 in 2 out 2 bits 4 w_scale 1.0 relu 0 out_bits 8 out_scale 1.0
w 1 0 0 1
b 0 0
eval 2 2
x 3 -4 5 6
y 0 1
";

    #[test]
    fn parses_sample() {
        let b = parse_trained(SAMPLE).unwrap();
        assert_eq!(b.model.layers.len(), 1);
        assert_eq!(b.eval_n, 2);
        assert_eq!(b.eval_y, vec![0, 1]);
        assert!((b.float_acc - 0.95).abs() < 1e-12);
    }

    #[test]
    fn identity_model_evaluates() {
        let b = parse_trained(SAMPLE).unwrap();
        let mut exec = |a: &[i32], w: &[i32], m: usize, k: usize, n: usize, bits: u32| {
            crate::nn::matmul_native(a, w, m, k, n, bits)
        };
        // identity weights: logits = inputs; labels picked accordingly:
        // row0 = [3,-4] -> argmax 0 (correct), row1 = [5,6] -> argmax 1
        let acc = evaluate(&b, &mut exec).unwrap();
        assert_eq!(acc, 1.0);
    }

    #[test]
    fn loaded_weights_carry_golden_stamps_and_scrub_end_to_end() {
        let b = parse_trained(SAMPLE).unwrap();
        for layer in &b.model.layers {
            let Layer::Linear(l) = layer else {
                panic!("trained bundle is all-linear");
            };
            // every loaded weight goes through QTensor::new, so the
            // golden content hash is stamped at load time
            assert!(l.w.verify_golden());
            assert_eq!(l.w.golden(), crate::nn::tensor::content_hash(&l.w.data));
        }
        // the loaded model is scrubbable: corrupt a resident pack and
        // the sweep repairs it from the golden-verified loaded weights
        b.model.warm_packed().unwrap();
        let targets = b.model.resident_planes();
        assert_eq!(targets.len(), 1);
        let (cache, key, planes) = &targets[0];
        cache.replace(
            *key,
            std::sync::Arc::new(planes.with_flipped_bit(0, 0, 0, 0, false).unwrap()),
        );
        let out = b.model.scrub();
        assert_eq!((out.detected, out.repaired, out.quarantined), (1, 1, 0));
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_trained("layers 1\n").is_err());
        let bad = SAMPLE.replace("w 1 0 0 1", "w 1 0 0");
        assert!(parse_trained(&bad).is_err());
        let bad = SAMPLE.replace("y 0 1", "y 0");
        assert!(parse_trained(&bad).is_err());
    }
}
