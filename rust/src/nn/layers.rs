//! NN layer types, each reduced to matmul work-items for the
//! accelerator (§II-C: fully-connected and convolutional layers
//! dominate NN compute and both reduce to matrix multiplication).
//!
//! Layers are *executor-parameterised*: `forward` takes a
//! [`MatmulExec`] so the coordinator decides where each matmul runs —
//! the PJRT artifact, the cycle-accurate simulator, the native Booth
//! plane path, or the word-packed plane engine. All four produce
//! identical integers, so routing is a pure performance/fidelity
//! decision. Weight matrices carry a [`PackedCache`] so the packed
//! backend packs each weight once per (layer, precision), not once per
//! request.

use crate::bits::packed::PackedPlanes;
use crate::bits::plane::PlaneKind;
use crate::nn::quant::quantize_with_scale;
use crate::nn::tensor::{im2col, im2col_batch, QTensor};
use crate::Result;
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// A matmul executor. `a` is the multiplier operand (activations,
/// LSb-first in hardware), `b` the multiplicand (weights, MSb-first).
///
/// Executors that can exploit pre-packed weight planes (the packed
/// backend) advertise it via [`MatmulExec::wants_packed`]; layers then
/// hand over a [`PackedWeight`] whose planes come from the per-layer
/// [`PackedCache`], so each weight matrix is packed once per
/// precision instead of once per request.
pub trait MatmulExec {
    /// `(a, b, m, k, n, bits) → i64 accumulators`.
    fn matmul(
        &mut self,
        a: &[i32],
        b: &[i32],
        m: usize,
        k: usize,
        n: usize,
        bits: u32,
    ) -> Result<Vec<i64>>;

    /// Whether this executor uses pre-packed weight planes. Layers only
    /// pay the (cached) packing cost when it does.
    fn wants_packed(&self) -> bool {
        false
    }

    /// Matmul whose weight operand carries cached packed planes.
    /// Executors that cannot use them fall back to the dense path.
    fn matmul_packed(
        &mut self,
        a: &[i32],
        w: &PackedWeight<'_>,
        m: usize,
        k: usize,
        n: usize,
        bits: u32,
    ) -> Result<Vec<i64>> {
        self.matmul(a, w.data, m, k, n, bits)
    }
}

/// Every plain closure of the historical `(a, b, m, k, n, bits)` shape
/// is an executor, so tests/benches keep passing closures unchanged.
impl<F> MatmulExec for F
where
    F: FnMut(&[i32], &[i32], usize, usize, usize, u32) -> Result<Vec<i64>>,
{
    fn matmul(
        &mut self,
        a: &[i32],
        b: &[i32],
        m: usize,
        k: usize,
        n: usize,
        bits: u32,
    ) -> Result<Vec<i64>> {
        self(a, b, m, k, n, bits)
    }
}

/// A weight operand: dense data plus (optionally) its packed planes,
/// and — when the planes came from a [`PackedCache`] — the repair
/// source the scheduler's integrity ladder needs to evict and re-pack
/// a corrupted resident plane from golden-verified dense weights.
pub struct PackedWeight<'w> {
    pub data: &'w [i32],
    pub planes: Option<Arc<PackedPlanes>>,
    pub repair: Option<RepairSource<'w>>,
}

/// Where a packed weight's planes live and what to rebuild them from:
/// the owning cache + slot, and the dense source tensor whose golden
/// content hash (stamped at construction) proves it trustworthy. The
/// ladder re-packs from `w` only when `w.verify_golden()` holds;
/// otherwise the slot is quarantined (DESIGN.md §Integrity).
#[derive(Clone, Copy)]
pub struct RepairSource<'w> {
    pub cache: &'w PackedCache,
    pub slot: u32,
    pub w: &'w QTensor,
}

/// Typed unserviceable-weight error: both the resident packed planes
/// and their dense golden source failed verification, so no correct
/// answer can be produced from this slot. Surfaced to clients as
/// `ServeError::Quarantined` instead of a wrong or silently-slow
/// result.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Quarantined {
    pub slot: u32,
}

impl std::fmt::Display for Quarantined {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "weight slot {} quarantined: packed planes corrupt and golden source unverifiable", self.slot)
    }
}

impl std::error::Error for Quarantined {}

/// Outcome of one integrity sweep over a cache (the nn-side sibling of
/// the coordinator's `ScrubStats`; the server folds these into
/// `Metrics.scrub`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ScrubOutcome {
    /// Resident entries whose plane signatures failed verification.
    pub detected: u64,
    /// Corrupt entries replaced by a fresh pack from golden-verified
    /// dense weights.
    pub repaired: u64,
    /// Slots retired because the dense golden source itself failed
    /// verification (or the repair re-pack failed).
    pub quarantined: u64,
}

impl ScrubOutcome {
    pub fn merge(&mut self, o: &ScrubOutcome) {
        self.detected += o.detected;
        self.repaired += o.repaired;
        self.quarantined += o.quarantined;
    }
}

/// Lazily-built, shared cache of packed weight planes, keyed by
/// `(weight slot, precision)`. Cloning shares the cache (it is an
/// `Arc` inside), so server workers sharing an `Arc<Model>` pack each
/// weight matrix **once** per precision, not once per request — the
/// pack happens under the lock, so concurrent workers cannot
/// double-pack. The pack counter makes that invariant testable.
///
/// **Cross-precision reuse** (DESIGN.md §Packed-Threading): a weight
/// packed at `b` bits contains every plane needed for `b' < b`, so a
/// lower-precision request is served by slicing a plane-subset view of
/// an existing higher-precision pack ([`PackedPlanes::slice_bits`],
/// zero copy) instead of re-decomposing the weights. Precision-lowered
/// serving therefore triggers **zero** re-packs; the reuse counter
/// makes that testable too.
///
/// Invariant: weights are immutable once a model serves. The cache is
/// never invalidated, so code that mutates a layer's `w` in place
/// (e.g. requantisation sweeps) must rebuild the layer — or serve on a
/// non-packed backend — to avoid stale planes.
#[derive(Debug, Default, Clone)]
pub struct PackedCache {
    planes: Arc<Mutex<HashMap<(u32, u32), Arc<PackedPlanes>>>>,
    pack_count: Arc<AtomicU64>,
    reuse_count: Arc<AtomicU64>,
    /// Slots retired by the integrity subsystem: resident planes were
    /// corrupt AND the dense golden source failed verification, so
    /// nothing trustworthy is left to pack from. Serving a quarantined
    /// slot is a typed [`Quarantined`] error, never a wrong answer.
    quarantined: Arc<Mutex<HashSet<u32>>>,
}

impl PackedCache {
    pub fn new() -> PackedCache {
        PackedCache::default()
    }

    /// The packed columns of the 2-D weight `w` at `bits` precision:
    /// a cache hit, a plane-subset slice of a wider cached pack, or —
    /// only when neither exists — a fresh pack (at most once per
    /// `(slot, bits)`).
    pub fn get_or_pack(&self, slot: u32, w: &QTensor, bits: u32) -> Result<Arc<PackedPlanes>> {
        if self.is_quarantined(slot) {
            return Err(anyhow::Error::new(Quarantined { slot }));
        }
        // recover a poisoned lock: a supervised worker panic cannot
        // leave a half-inserted entry (insertion is the last step), so
        // the map is always consistent — refusing to serve every later
        // request over a dead worker's poison flag would turn one
        // masked fault into a total outage
        let mut cache = self.planes.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(p) = cache.get(&(slot, bits)) {
            return Ok(p.clone());
        }
        anyhow::ensure!(w.rank() == 2, "packed weights must be 2-D, got {:?}", w.shape);
        // cross-precision reuse: the narrowest wider pack of this slot
        // whose values fit in `bits` planes donates a zero-copy slice
        let donor = cache
            .iter()
            .filter(|&(&(s, b), p)| s == slot && b > bits && p.min_bits <= bits)
            .min_by_key(|&(&(_, b), _)| b)
            .map(|(_, p)| p.clone());
        if let Some(donor) = donor {
            let sliced = Arc::new(donor.slice_bits(bits)?);
            self.reuse_count.fetch_add(1, Ordering::Relaxed);
            cache.insert((slot, bits), sliced.clone());
            return Ok(sliced);
        }
        let p = Arc::new(PackedPlanes::pack_cols(
            &w.data,
            w.shape[0],
            w.shape[1],
            bits,
            PlaneKind::Sbmwc,
        )?);
        self.pack_count.fetch_add(1, Ordering::Relaxed);
        cache.insert((slot, bits), p.clone());
        Ok(p)
    }

    /// How many times a weight matrix was actually packed — the
    /// once-per-(layer, precision) serving invariant. Plane-subset
    /// slices do **not** count: lowering precision re-packs nothing.
    pub fn packs(&self) -> u64 {
        self.pack_count.load(Ordering::Relaxed)
    }

    /// How many requests were served by slicing a plane subset of a
    /// wider cached pack instead of re-packing.
    pub fn plane_reuses(&self) -> u64 {
        self.reuse_count.load(Ordering::Relaxed)
    }

    /// Snapshot of every resident `(slot, bits) → planes` entry — the
    /// scrubber's sweep list and the memory-SEU injector's target set.
    pub fn entries(&self) -> Vec<((u32, u32), Arc<PackedPlanes>)> {
        let cache = self.planes.lock().unwrap_or_else(|e| e.into_inner());
        cache.iter().map(|(&k, p)| (k, p.clone())).collect()
    }

    /// Swap the resident planes at `key` (fault injection and ladder
    /// repair both land here). A no-op for keys never packed: a SEU in
    /// unoccupied SRAM hits nothing.
    pub fn replace(&self, key: (u32, u32), planes: Arc<PackedPlanes>) {
        let mut cache = self.planes.lock().unwrap_or_else(|e| e.into_inner());
        if let std::collections::hash_map::Entry::Occupied(mut e) = cache.entry(key) {
            e.insert(planes);
        }
    }

    /// Drop every resident pack of `slot` (all precisions), returning
    /// how many entries were evicted. Sliced views of an evicted donor
    /// are evicted with it — they share the donor's (possibly corrupt)
    /// storage.
    pub fn evict_slot(&self, slot: u32) -> usize {
        let mut cache = self.planes.lock().unwrap_or_else(|e| e.into_inner());
        let victims: Vec<(u32, u32)> =
            cache.keys().filter(|&&(s, _)| s == slot).copied().collect();
        for k in &victims {
            cache.remove(k);
        }
        victims.len()
    }

    /// Retire `slot`: drop its resident packs and refuse all future
    /// `get_or_pack` calls with a typed [`Quarantined`] error.
    pub fn quarantine(&self, slot: u32) {
        self.evict_slot(slot);
        let mut q = self.quarantined.lock().unwrap_or_else(|e| e.into_inner());
        q.insert(slot);
    }

    pub fn is_quarantined(&self, slot: u32) -> bool {
        let q = self.quarantined.lock().unwrap_or_else(|e| e.into_inner());
        q.contains(&slot)
    }

    /// One integrity pass over the resident packs of `slot`, with `w`
    /// as the dense golden source: verify every entry's per-plane
    /// signatures; re-pack corrupt entries from `w` when `w` itself
    /// passes its golden content hash, else quarantine the slot.
    /// Repair is per-`(slot, bits)` key, so a repaired narrow entry is
    /// a fresh pack (sharing with a corrupt donor would re-import the
    /// flipped bit).
    pub fn scrub(&self, slot: u32, w: &QTensor) -> ScrubOutcome {
        let mut out = ScrubOutcome::default();
        let corrupt: Vec<(u32, u32)> = {
            let cache = self.planes.lock().unwrap_or_else(|e| e.into_inner());
            cache
                .iter()
                .filter(|&(&(s, _), p)| s == slot && !p.verify())
                .map(|(&k, _)| k)
                .collect()
        };
        if corrupt.is_empty() {
            return out;
        }
        out.detected = corrupt.len() as u64;
        if w.rank() != 2 || !w.verify_golden() {
            self.quarantine(slot);
            out.quarantined = 1;
            return out;
        }
        for key in corrupt {
            let fresh = PackedPlanes::pack_cols(
                &w.data,
                w.shape[0],
                w.shape[1],
                key.1,
                PlaneKind::Sbmwc,
            );
            match fresh {
                Ok(p) => {
                    self.pack_count.fetch_add(1, Ordering::Relaxed);
                    self.replace(key, Arc::new(p));
                    out.repaired += 1;
                }
                Err(_) => {
                    self.quarantine(slot);
                    out.quarantined += 1;
                    break;
                }
            }
        }
        out
    }
}

/// Layer-side executor routing shared by every layer type: take the
/// packed path (with `w`'s cached planes) when the executor wants it
/// and both operands fit the layer precision, else the dense path.
fn exec_layer_matmul(
    exec: &mut dyn MatmulExec,
    cache: &PackedCache,
    slot: u32,
    a: &QTensor,
    w: &QTensor,
    m: usize,
    k: usize,
    n: usize,
    bits: u32,
) -> Result<Vec<i64>> {
    if exec.wants_packed() && a.bits <= bits && w.bits <= bits {
        let planes = cache.get_or_pack(slot, w, bits)?;
        let pw = PackedWeight {
            data: &w.data,
            planes: Some(planes),
            repair: Some(RepairSource { cache, slot, w }),
        };
        exec.matmul_packed(&a.data, &pw, m, k, n, bits)
    } else {
        exec.matmul(&a.data, &w.data, m, k, n, bits)
    }
}

/// Fully-connected layer.
#[derive(Debug, Clone)]
pub struct LinearLayer {
    /// Weights, shape `[in, out]`.
    pub w: QTensor,
    /// Bias in accumulator units (i.e. units of `in_scale · w_scale`).
    pub bias: Vec<i64>,
    /// Operand precision for this layer — the per-layer knob.
    pub bits: u32,
    /// Apply ReLU before requantizing.
    pub relu: bool,
    /// Activation scale for the layer output.
    pub out_scale: f64,
    /// Output precision (bits of the produced activations).
    pub out_bits: u32,
    /// Lazily-built packed weight planes (shared across clones).
    pub packed: PackedCache,
}

impl LinearLayer {
    /// `x`: `[batch, in]`. Produces `[batch, out]` activations on the
    /// output grid.
    pub fn forward(&self, x: &QTensor, exec: &mut dyn MatmulExec) -> Result<QTensor> {
        anyhow::ensure!(x.rank() == 2, "linear expects 2-D input");
        let (batch, d_in) = (x.shape[0], x.shape[1]);
        let (w_in, d_out) = (self.w.shape[0], self.w.shape[1]);
        anyhow::ensure!(d_in == w_in, "linear dims: input {d_in} vs weights {w_in}");
        anyhow::ensure!(x.bits <= self.bits, "input precision exceeds layer precision");
        let acc =
            exec_layer_matmul(exec, &self.packed, 0, x, &self.w, batch, d_in, d_out, self.bits)?;
        // accumulator units: in_scale · w_scale
        let acc_scale = x.scale * self.w.scale;
        let mut real: Vec<f64> = acc
            .iter()
            .zip(self.bias.iter().cycle())
            .map(|(&a, &b)| (a + b) as f64 * acc_scale)
            .collect();
        if self.relu {
            for v in &mut real {
                *v = v.max(0.0);
            }
        }
        quantize_with_scale(&real, vec![batch, d_out], self.out_scale, self.out_bits)
    }

    /// The matmul work-items this layer contributes for a batch.
    pub fn matmul_shape(&self, batch: usize) -> (usize, usize, usize, u32) {
        (batch, self.w.shape[0], self.w.shape[1], self.bits)
    }

    /// MAC operations for a batch (the OPS numerator).
    pub fn macs(&self, batch: usize) -> u64 {
        (batch * self.w.shape[0] * self.w.shape[1]) as u64
    }
}

/// Lazily-built cache of a conv kernel's im2col transpose
/// `[oc, c, kh, kw] → [c·kh·kw, oc]`. Shared across clones (an `Arc`
/// inside) like [`PackedCache`], and under the same invariant: weights
/// are immutable once a model serves, so the transpose is derived at
/// most once and never invalidated — packed conv serving re-derives
/// nothing per request.
#[derive(Debug, Clone, Default)]
pub struct TransposedKernelCache(Arc<Mutex<Option<Arc<QTensor>>>>);

impl TransposedKernelCache {
    pub fn new() -> TransposedKernelCache {
        TransposedKernelCache::default()
    }

    /// The cached `[c·kh·kw, oc]` transpose of `w`, built on first use.
    /// Returned by `Arc` (not borrow) so the scrubber can swap in a
    /// rebuilt replacement without invalidating in-flight readers.
    pub fn get_or_build(&self, w: &QTensor) -> Result<Arc<QTensor>> {
        let mut slot = self.0.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(t) = slot.as_ref() {
            debug_assert!(
                w.rank() == 4
                    && t.shape == [w.shape[1] * w.shape[2] * w.shape[3], w.shape[0]],
                "cached transpose does not match the kernel — conv weights \
                 mutated after serving started? (rebuild the layer instead)"
            );
            return Ok(t.clone());
        }
        anyhow::ensure!(w.rank() == 4, "conv kernel must be [oc,c,kh,kw], got {:?}", w.shape);
        let (oc, ckk) = (w.shape[0], w.shape[1] * w.shape[2] * w.shape[3]);
        let t = Arc::new(w.reshape(vec![oc, ckk])?.transpose2()?);
        *slot = Some(t.clone());
        Ok(t)
    }

    /// Whether the transpose has been derived yet (for tests).
    pub fn is_built(&self) -> bool {
        self.0.lock().unwrap_or_else(|e| e.into_inner()).is_some()
    }

    /// The cached transpose without building it — scrubbers only sweep
    /// state that is actually resident.
    pub fn peek(&self) -> Option<Arc<QTensor>> {
        self.0.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// Fault-injection hook: swap the resident transpose (the
    /// memory-SEU model for derived dense state, mirroring
    /// [`PackedCache::replace`] for packed state). No-op when nothing
    /// is resident yet.
    pub fn replace(&self, t: Arc<QTensor>) {
        let mut slot = self.0.lock().unwrap_or_else(|e| e.into_inner());
        if slot.is_some() {
            *slot = Some(t);
        }
    }

    /// One integrity pass over the resident transpose: golden-verify
    /// it, and on corruption rebuild from the golden-verified kernel
    /// `w` — or drop it and report `quarantined` when `w` itself fails
    /// verification (the caller then quarantines the packed slot too).
    pub fn scrub(&self, w: &QTensor) -> ScrubOutcome {
        let mut out = ScrubOutcome::default();
        let mut slot = self.0.lock().unwrap_or_else(|e| e.into_inner());
        let Some(t) = slot.as_ref() else { return out };
        if t.verify_golden() {
            return out;
        }
        out.detected = 1;
        if w.rank() != 4 || !w.verify_golden() {
            *slot = None;
            out.quarantined = 1;
            return out;
        }
        let (oc, ckk) = (w.shape[0], w.shape[1] * w.shape[2] * w.shape[3]);
        match w.reshape(vec![oc, ckk]).and_then(|r| r.transpose2()) {
            Ok(fresh) => {
                *slot = Some(Arc::new(fresh));
                out.repaired = 1;
            }
            Err(_) => {
                *slot = None;
                out.quarantined = 1;
            }
        }
        out
    }
}

/// Convolution layer, served through im2col.
#[derive(Debug, Clone)]
pub struct Conv2dLayer {
    /// Kernel, shape `[oc, c, kh, kw]`.
    pub w: QTensor,
    pub bias: Vec<i64>,
    pub stride: usize,
    pub pad: usize,
    pub bits: u32,
    pub relu: bool,
    pub out_scale: f64,
    pub out_bits: u32,
    /// Lazily-built packed planes of the im2col-transposed kernel.
    pub packed: PackedCache,
    /// Lazily-cached `[c·kh·kw, oc]` transpose of `w` (shared across
    /// clones next to `packed`), so serving never re-derives it.
    pub wt: TransposedKernelCache,
}

impl Conv2dLayer {
    /// `x`: `(c, h, w)` single image → `(oc, oh, ow)`, or a
    /// `(b, c, h, w)` stacked batch → `(b, oc, oh, ow)`. The batched
    /// path stacks every image's im2col matrix into **one**
    /// `[b·oh·ow, c·kh·kw]` matmul (ROADMAP batched-im2col item); rows
    /// stay per-image and the bias/ReLU/requant pipeline is
    /// elementwise, so the batch is bit-identical to `b` solo
    /// forwards — batch invariance holds (DESIGN.md §Serving).
    pub fn forward(&self, x: &QTensor, exec: &mut dyn MatmulExec) -> Result<QTensor> {
        let (oc, c, kh, kw) = (
            self.w.shape[0],
            self.w.shape[1],
            self.w.shape[2],
            self.w.shape[3],
        );
        let (batch, chan) = match x.rank() {
            3 => (1, x.shape[0]),
            4 => (x.shape[0], x.shape[1]),
            r => anyhow::bail!("conv expects (C,H,W) or (B,C,H,W), got rank {r}"),
        };
        anyhow::ensure!(c == chan, "channel mismatch");
        let (a, oh, ow) = if x.rank() == 4 {
            im2col_batch(x, kh, kw, self.stride, self.pad)?
        } else {
            im2col(x, kh, kw, self.stride, self.pad)?
        };
        // cached [c·kh·kw, oc] transpose of the kernel (built once)
        let wt = self.wt.get_or_build(&self.w)?;
        let per = oh * ow;
        let m = batch * per;
        let kdim = c * kh * kw;
        let acc = exec_layer_matmul(exec, &self.packed, 0, &a, &wt, m, kdim, oc, self.bits)?;
        let acc_scale = x.scale * self.w.scale;
        // output layout (…, oc, oh, ow): transpose each image's
        // (per, oc) block independently
        let mut real = vec![0f64; batch * oc * per];
        for img in 0..batch {
            for r in 0..per {
                for o in 0..oc {
                    let v = (acc[(img * per + r) * oc + o] + self.bias[o]) as f64 * acc_scale;
                    real[(img * oc + o) * per + r] = if self.relu { v.max(0.0) } else { v };
                }
            }
        }
        let shape = if x.rank() == 4 {
            vec![batch, oc, oh, ow]
        } else {
            vec![oc, oh, ow]
        };
        quantize_with_scale(&real, shape, self.out_scale, self.out_bits)
    }

    /// Output spatial dims for an `(h, w)` input, or `None` when the
    /// kernel exceeds the padded input — the degenerate geometry
    /// `im2col` rejects; callers must not underflow on it.
    pub fn out_dims(&self, h: usize, w: usize) -> Option<(usize, usize)> {
        let (kh, kw) = (self.w.shape[2], self.w.shape[3]);
        let oh = (h + 2 * self.pad).checked_sub(kh)? / self.stride + 1;
        let ow = (w + 2 * self.pad).checked_sub(kw)? / self.stride + 1;
        Some((oh, ow))
    }

    /// MAC census for an `(h, w)` input; saturates to 0 on degenerate
    /// geometry instead of underflow-panicking.
    pub fn macs(&self, h: usize, w: usize) -> u64 {
        let (oc, c, kh, kw) = (
            self.w.shape[0],
            self.w.shape[1],
            self.w.shape[2],
            self.w.shape[3],
        );
        match self.out_dims(h, w) {
            Some((oh, ow)) => (oh * ow * c * kh * kw * oc) as u64,
            None => 0,
        }
    }
}

/// Single-head self-attention block: four bit-serial projections plus
/// an f64 softmax (matmuls dominate; the paper targets the GEMM core).
#[derive(Debug, Clone)]
pub struct AttentionLayer {
    pub wq: QTensor,
    pub wk: QTensor,
    pub wv: QTensor,
    pub wo: QTensor,
    pub bits: u32,
    pub out_scale: f64,
    pub out_bits: u32,
    /// Lazily-built packed planes of the four projections (slots
    /// 0..=3 = q/k/v/o).
    pub packed: PackedCache,
}

impl AttentionLayer {
    /// Route one projection through the executor, using the packed
    /// cache slot when the executor exploits packed weight planes.
    fn proj_acc(
        &self,
        exec: &mut dyn MatmulExec,
        slot: u32,
        a: &QTensor,
        w: &QTensor,
        s: usize,
        d: usize,
    ) -> Result<Vec<i64>> {
        exec_layer_matmul(exec, &self.packed, slot, a, w, s, d, d, self.bits)
    }

    /// `x`: `[seq, dim]` quantized tokens → `[seq, dim]` on the output
    /// grid.
    pub fn forward(&self, x: &QTensor, exec: &mut dyn MatmulExec) -> Result<QTensor> {
        anyhow::ensure!(x.rank() == 2, "attention expects [seq, dim]");
        let (s, d) = (x.shape[0], x.shape[1]);
        anyhow::ensure!(self.wq.shape == vec![d, d], "wq shape");
        let proj = |exec: &mut dyn MatmulExec, slot: u32, w: &QTensor| -> Result<Vec<f64>> {
            let acc = self.proj_acc(exec, slot, x, w, s, d)?;
            let sc = x.scale * w.scale;
            Ok(acc.iter().map(|&v| v as f64 * sc).collect())
        };
        let q = proj(exec, 0, &self.wq)?;
        let k = proj(exec, 1, &self.wk)?;
        let v = proj(exec, 2, &self.wv)?;
        // softmax(q kᵀ / sqrt(d)) v — float side, matching model.py
        let mut ctx = vec![0f64; s * d];
        let scale = 1.0 / (d as f64).sqrt();
        for i in 0..s {
            let mut logits = vec![0f64; s];
            for j in 0..s {
                let mut dot = 0.0;
                for t in 0..d {
                    dot += q[i * d + t] * k[j * d + t];
                }
                logits[j] = dot * scale;
            }
            let m = logits.iter().fold(f64::NEG_INFINITY, |a, &b| a.max(b));
            let exps: Vec<f64> = logits.iter().map(|&l| (l - m).exp()).collect();
            let z: f64 = exps.iter().sum();
            for j in 0..s {
                let a = exps[j] / z;
                for t in 0..d {
                    ctx[i * d + t] += a * v[j * d + t];
                }
            }
        }
        // requantize context, then output projection
        let amax = ctx.iter().fold(1e-6f64, |m, v| m.max(v.abs()));
        let ctx_scale = amax / crate::bits::twos::max_value(self.bits) as f64;
        let ctx_q = quantize_with_scale(&ctx, vec![s, d], ctx_scale, self.bits)?;
        let acc = self.proj_acc(exec, 3, &ctx_q, &self.wo, s, d)?;
        let sc = ctx_scale * self.wo.scale;
        let real: Vec<f64> = acc.iter().map(|&a| a as f64 * sc).collect();
        quantize_with_scale(&real, vec![s, d], self.out_scale, self.out_bits)
    }

    pub fn macs(&self, seq: usize) -> u64 {
        let d = self.wq.shape[0];
        4 * (seq * d * d) as u64
    }
}

/// A heterogeneous layer.
#[derive(Debug, Clone)]
pub enum Layer {
    Linear(LinearLayer),
    Conv2d(Conv2dLayer),
    Attention(AttentionLayer),
    /// Collapse a higher-rank activation to one `[1, numel]` row — the
    /// explicit conv→linear bridge. Rank-2 activations pass through
    /// **unchanged**: stacked row-serving delivers `[rows, d]` batches
    /// where each row must stay a separate sample, so collapsing
    /// matrices would destroy batch invariance; a matrix that really
    /// needs flattening (e.g. attention→linear head) must be reshaped
    /// by its own explicit layer, not this one. Rank-4 batched-conv
    /// activations `(b, oc, oh, ow)` flatten **per image** to
    /// `[b, oc·oh·ow]` for the same reason — each row is one sample.
    Flatten,
}

impl Layer {
    pub fn forward(&self, x: &QTensor, exec: &mut dyn MatmulExec) -> Result<QTensor> {
        match self {
            Layer::Linear(l) => l.forward(x, exec),
            Layer::Conv2d(l) => l.forward(x, exec),
            Layer::Attention(l) => l.forward(x, exec),
            Layer::Flatten => match x.rank() {
                2 => Ok(x.clone()),
                // batched conv activations: one row per image (the
                // per-image block is contiguous in row-major NCHW)
                4 => x.reshape(vec![x.shape[0], x.numel() / x.shape[0].max(1)]),
                _ => Ok(x.flatten_row()),
            },
        }
    }

    /// This layer's operand precision — the per-layer bit-width knob
    /// (0 for layers that do no arithmetic).
    pub fn bits(&self) -> u32 {
        match self {
            Layer::Linear(l) => l.bits,
            Layer::Conv2d(l) => l.bits,
            Layer::Attention(l) => l.bits,
            Layer::Flatten => 0,
        }
    }

    pub fn kind(&self) -> &'static str {
        match self {
            Layer::Linear(_) => "linear",
            Layer::Conv2d(_) => "conv2d",
            Layer::Attention(_) => "attention",
            Layer::Flatten => "flatten",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::matmul_native;

    fn native_exec() -> impl FnMut(&[i32], &[i32], usize, usize, usize, u32) -> Result<Vec<i64>> {
        |a, b, m, k, n, bits| matmul_native(a, b, m, k, n, bits)
    }

    #[test]
    fn linear_identity_weights() {
        let d = 4;
        let mut w = vec![0i32; d * d];
        for i in 0..d {
            w[i * d + i] = 1;
        }
        let layer = LinearLayer {
            w: QTensor::new(w, vec![d, d], 1.0, 8).unwrap(),
            bias: vec![0; d],
            bits: 8,
            relu: false,
            out_scale: 1.0,
            out_bits: 8,
            packed: PackedCache::new(),
        };
        let x = QTensor::new(vec![1, -2, 3, -4, 5, -6, 7, -8], vec![2, d], 1.0, 8).unwrap();
        let y = layer.forward(&x, &mut native_exec()).unwrap();
        assert_eq!(y.data, x.data);
    }

    #[test]
    fn linear_relu_clamps_negatives() {
        let layer = LinearLayer {
            w: QTensor::new(vec![1], vec![1, 1], 1.0, 8).unwrap(),
            bias: vec![0],
            bits: 8,
            relu: true,
            out_scale: 1.0,
            out_bits: 8,
            packed: PackedCache::new(),
        };
        let x = QTensor::new(vec![-5], vec![1, 1], 1.0, 8).unwrap();
        let y = layer.forward(&x, &mut native_exec()).unwrap();
        assert_eq!(y.data, vec![0]);
    }

    #[test]
    fn linear_bias_applied_in_accumulator_units() {
        let layer = LinearLayer {
            w: QTensor::new(vec![2], vec![1, 1], 0.5, 8).unwrap(),
            bias: vec![10],
            bits: 8,
            relu: false,
            out_scale: 0.25,
            out_bits: 8,
            packed: PackedCache::new(),
        };
        let x = QTensor::new(vec![3], vec![1, 1], 0.5, 8).unwrap();
        // acc = 3·2 + 10 = 16, real = 16·0.25 = 4.0, q = 4/0.25 = 16
        let y = layer.forward(&x, &mut native_exec()).unwrap();
        assert_eq!(y.data, vec![16]);
    }

    #[test]
    fn conv_1x1_is_channel_mix() {
        // 2 channels → 1 output channel, 1×1 kernel w = [1, 1]
        let w = QTensor::new(vec![1, 1], vec![1, 2, 1, 1], 1.0, 8).unwrap();
        let layer = Conv2dLayer {
            w,
            bias: vec![0],
            stride: 1,
            pad: 0,
            bits: 8,
            relu: false,
            out_scale: 1.0,
            out_bits: 8,
            packed: PackedCache::new(),
            wt: TransposedKernelCache::new(),
        };
        let x = QTensor::new(vec![1, 2, 3, 4, 10, 20, 30, 40], vec![2, 2, 2], 1.0, 8).unwrap();
        let y = layer.forward(&x, &mut native_exec()).unwrap();
        assert_eq!(y.shape, vec![1, 2, 2]);
        assert_eq!(y.data, vec![11, 22, 33, 44]);
    }

    #[test]
    fn batched_conv_forward_is_bit_identical_to_solo_forwards() {
        let mut rng = crate::prng::Pcg32::new(0xba7c);
        let w = QTensor::new(
            (0..2 * 3 * 3 * 3).map(|_| rng.range_i32(-8, 7)).collect(),
            vec![2, 3, 3, 3],
            0.1,
            4,
        )
        .unwrap();
        let layer = Conv2dLayer {
            w,
            bias: vec![3, -2],
            stride: 1,
            pad: 1,
            bits: 8,
            relu: true,
            out_scale: 0.05,
            out_bits: 8,
            packed: PackedCache::new(),
            wt: TransposedKernelCache::new(),
        };
        let (b, c, h, wd) = (4usize, 3usize, 5usize, 5usize);
        let data: Vec<i32> = (0..b * c * h * wd).map(|_| rng.range_i32(-100, 100)).collect();
        let batch = QTensor::new(data.clone(), vec![b, c, h, wd], 0.02, 8).unwrap();
        let fused = layer.forward(&batch, &mut native_exec()).unwrap();
        assert_eq!(fused.shape, vec![b, 2, 5, 5]);
        let per = fused.numel() / b;
        for img in 0..b {
            let solo = QTensor::new(
                data[img * c * h * wd..(img + 1) * c * h * wd].to_vec(),
                vec![c, h, wd],
                0.02,
                8,
            )
            .unwrap();
            let y = layer.forward(&solo, &mut native_exec()).unwrap();
            assert_eq!(y.shape, vec![2, 5, 5]);
            assert_eq!(
                &fused.data[img * per..(img + 1) * per],
                &y.data[..],
                "image {img} diverged under batching"
            );
        }
        // rank-4 flatten keeps one row per image
        let flat = Layer::Flatten.forward(&fused, &mut native_exec()).unwrap();
        assert_eq!(flat.shape, vec![b, per]);
        assert_eq!(flat.data, fused.data);
        // rank-2 and rank-5 conv inputs are rejected
        let bad = QTensor::zeros(vec![3, 5], 1.0, 8);
        assert!(layer.forward(&bad, &mut native_exec()).is_err());
    }

    #[test]
    fn conv_macs_formula() {
        let w = QTensor::zeros(vec![4, 2, 3, 3], 1.0, 8);
        let layer = Conv2dLayer {
            w,
            bias: vec![0; 4],
            stride: 1,
            pad: 1,
            bits: 8,
            relu: true,
            out_scale: 1.0,
            out_bits: 8,
            packed: PackedCache::new(),
            wt: TransposedKernelCache::new(),
        };
        // 8×8 input, same-padded: 8·8 positions × 2·3·3 × 4
        assert_eq!(layer.macs(8, 8), 64 * 18 * 4);
    }

    #[test]
    fn conv_macs_saturate_on_degenerate_geometry() {
        // 5×5 kernel over an unpadded 2×2 input: im2col rejects this,
        // and the stats path must saturate instead of underflowing
        let layer = Conv2dLayer {
            w: QTensor::zeros(vec![2, 1, 5, 5], 1.0, 8),
            bias: vec![0; 2],
            stride: 1,
            pad: 0,
            bits: 8,
            relu: false,
            out_scale: 1.0,
            out_bits: 8,
            packed: PackedCache::new(),
            wt: TransposedKernelCache::new(),
        };
        assert_eq!(layer.out_dims(2, 2), None);
        assert_eq!(layer.macs(2, 2), 0);
        // the exact-fit geometry is still counted normally
        assert_eq!(layer.out_dims(5, 5), Some((1, 1)));
        assert_eq!(layer.macs(5, 5), (5 * 5 * 2) as u64);
    }

    #[test]
    fn conv_kernel_transpose_built_once_and_shared_across_clones() {
        let w = QTensor::new(vec![1, 2, 3, -4], vec![2, 2, 1, 1], 1.0, 8).unwrap();
        let cache = TransposedKernelCache::new();
        assert!(!cache.is_built());
        assert!(cache.peek().is_none());
        let p1 = cache.get_or_build(&w).unwrap();
        let p2 = cache.get_or_build(&w).unwrap();
        assert!(Arc::ptr_eq(&p1, &p2), "transpose derived once, then cached");
        assert!(cache.is_built());
        // the cached tensor is exactly the on-the-fly derivation
        let want = w.reshape(vec![2, 2]).unwrap().transpose2().unwrap();
        assert_eq!(*cache.get_or_build(&w).unwrap(), want);
        // clones share the same cached transpose
        let clone = cache.clone();
        assert!(Arc::ptr_eq(&clone.get_or_build(&w).unwrap(), &p1));
    }

    #[test]
    fn transposed_kernel_scrub_detects_and_rebuilds() {
        let w = QTensor::new(vec![1, 2, 3, -4], vec![2, 2, 1, 1], 1.0, 8).unwrap();
        let cache = TransposedKernelCache::new();
        // nothing resident: scrub sweeps nothing
        assert_eq!(cache.scrub(&w), ScrubOutcome::default());
        let clean = cache.get_or_build(&w).unwrap();
        assert_eq!(cache.scrub(&w), ScrubOutcome::default());
        // flip one resident value; the golden stamp goes stale with it
        let mut bad = (*clean).clone();
        bad.data[0] ^= 1;
        cache.replace(Arc::new(bad));
        assert!(!cache.peek().unwrap().verify_golden());
        let out = cache.scrub(&w);
        assert_eq!((out.detected, out.repaired, out.quarantined), (1, 1, 0));
        // rebuilt transpose is bit-identical to the clean derivation
        assert_eq!(cache.peek().unwrap().data, clean.data);
        assert!(cache.peek().unwrap().verify_golden());
    }

    #[test]
    fn packed_cache_scrub_repairs_by_repack_bit_identical() {
        let w = QTensor::new(vec![5, -8, 7, -3, 0, 2], vec![3, 2], 1.0, 4).unwrap();
        let cache = PackedCache::new();
        let clean = cache.get_or_pack(0, &w, 8).unwrap();
        // clean sweep: nothing detected
        assert_eq!(cache.scrub(0, &w), ScrubOutcome::default());
        // flip one live bit of the resident pack (digit 1 of column 0)
        let corrupt = Arc::new(clean.with_flipped_bit(0, 0, 0, 1, false).unwrap());
        assert!(!corrupt.verify());
        cache.replace((0, 8), corrupt);
        assert!(!cache.entries()[0].1.verify());
        let out = cache.scrub(0, &w);
        assert_eq!((out.detected, out.repaired, out.quarantined), (1, 1, 0));
        // repaired pack is bit-identical to the original clean pack
        let repaired = cache.get_or_pack(0, &w, 8).unwrap();
        assert_eq!(*repaired, *clean);
        assert!(repaired.verify());
        assert!(!cache.is_quarantined(0));
    }

    #[test]
    fn packed_cache_quarantines_when_golden_source_is_corrupt() {
        let w = QTensor::new(vec![5, -8, 7, -3, 0, 2], vec![3, 2], 1.0, 4).unwrap();
        let cache = PackedCache::new();
        let clean = cache.get_or_pack(7, &w, 8).unwrap();
        cache.replace(
            (7, 8),
            Arc::new(clean.with_flipped_bit(0, 0, 0, 1, false).unwrap()),
        );
        // corrupt the dense source too: its golden stamp goes stale
        let mut bad = w.clone();
        bad.data[2] ^= 4;
        assert!(!bad.verify_golden());
        let out = cache.scrub(7, &bad);
        assert_eq!((out.detected, out.repaired, out.quarantined), (1, 0, 1));
        assert!(cache.is_quarantined(7));
        assert!(cache.entries().is_empty(), "quarantine evicts the slot");
        // the slot now refuses to serve with the typed error
        let err = cache.get_or_pack(7, &w, 8).unwrap_err();
        assert_eq!(err.downcast_ref::<Quarantined>(), Some(&Quarantined { slot: 7 }));
        // other slots are unaffected
        assert!(cache.get_or_pack(0, &w, 8).is_ok());
    }

    #[test]
    fn evict_and_replace_touch_only_resident_entries() {
        let w = QTensor::new(vec![1, 2, 3, -4], vec![2, 2], 1.0, 4).unwrap();
        let cache = PackedCache::new();
        let p = cache.get_or_pack(0, &w, 4).unwrap();
        cache.get_or_pack(0, &w, 8).unwrap();
        cache.get_or_pack(1, &w, 4).unwrap();
        assert_eq!(cache.entries().len(), 3);
        // replacing a never-packed key is a no-op (SEU in empty SRAM)
        cache.replace((9, 4), p.clone());
        assert_eq!(cache.entries().len(), 3);
        assert!(!cache.entries().iter().any(|(k, _)| *k == (9, 4)));
        assert_eq!(cache.evict_slot(0), 2);
        assert_eq!(cache.entries().len(), 1);
        assert_eq!(cache.entries()[0].0, (1, 4));
    }

    #[test]
    fn flatten_layer_bridges_conv_to_linear() {
        let mut exec = native_exec();
        let img = QTensor::new((0..8).collect(), vec![2, 2, 2], 0.5, 8).unwrap();
        let y = Layer::Flatten.forward(&img, &mut exec).unwrap();
        assert_eq!(y.shape, vec![1, 8]);
        assert_eq!(y.data, img.data);
        // rank-2 activations pass through untouched
        let mat = QTensor::new((0..6).collect(), vec![2, 3], 1.0, 8).unwrap();
        let same = Layer::Flatten.forward(&mat, &mut exec).unwrap();
        assert_eq!(same.shape, vec![2, 3]);
        assert_eq!(Layer::Flatten.kind(), "flatten");
        assert_eq!(Layer::Flatten.bits(), 0);
    }

    /// Executor that insists on packed weights and computes through the
    /// packed kernel — exercises the layer-side caching contract.
    struct PackedExec {
        packed_calls: u64,
        planes_seen: u64,
    }

    impl MatmulExec for PackedExec {
        fn matmul(
            &mut self,
            a: &[i32],
            b: &[i32],
            m: usize,
            k: usize,
            n: usize,
            bits: u32,
        ) -> Result<Vec<i64>> {
            matmul_native(a, b, m, k, n, bits)
        }

        fn wants_packed(&self) -> bool {
            true
        }

        fn matmul_packed(
            &mut self,
            a: &[i32],
            w: &PackedWeight<'_>,
            m: usize,
            k: usize,
            n: usize,
            bits: u32,
        ) -> Result<Vec<i64>> {
            self.packed_calls += 1;
            match &w.planes {
                Some(p) => {
                    self.planes_seen += 1;
                    let pa = PackedPlanes::pack_rows(a, m, k, bits, PlaneKind::Sbmwc)?;
                    crate::bits::packed::matmul_packed_planes(&pa, p)
                }
                None => self.matmul(a, w.data, m, k, n, bits),
            }
        }
    }

    #[test]
    fn packed_executor_gets_cached_planes_and_identical_outputs() {
        let layer = LinearLayer {
            w: QTensor::new(vec![2, -3, 1, 4, 0, -7], vec![3, 2], 0.5, 8).unwrap(),
            bias: vec![5, -5],
            bits: 8,
            relu: false,
            out_scale: 0.25,
            out_bits: 8,
            packed: PackedCache::new(),
        };
        let x = QTensor::new(vec![1, -2, 3, 4, -5, 6], vec![2, 3], 0.5, 8).unwrap();
        let dense = layer.forward(&x, &mut native_exec()).unwrap();
        let mut pe = PackedExec {
            packed_calls: 0,
            planes_seen: 0,
        };
        let p1 = layer.forward(&x, &mut pe).unwrap();
        let p2 = layer.forward(&x, &mut pe).unwrap();
        assert_eq!(p1.data, dense.data, "packed path must be bit-identical");
        assert_eq!(p2.data, dense.data);
        assert_eq!(pe.packed_calls, 2);
        assert_eq!(pe.planes_seen, 2);
        // two forwards, one pack: the cache held the planes
        assert_eq!(layer.packed.packs(), 1);
    }

    #[test]
    fn packed_cache_is_shared_across_clones_and_keyed_by_precision() {
        let w = QTensor::new(vec![1, 2, 3, -4], vec![2, 2], 1.0, 4).unwrap();
        let cache = PackedCache::new();
        let clone = cache.clone();
        let a = cache.get_or_pack(0, &w, 4).unwrap();
        let b = clone.get_or_pack(0, &w, 4).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "clones share one cache");
        assert_eq!(cache.packs(), 1);
        // a *wider* precision cannot reuse a narrow pack: fresh entry
        let c = cache.get_or_pack(0, &w, 8).unwrap();
        assert_eq!(c.bits, 8);
        assert_eq!(cache.packs(), 2);
        assert_eq!(clone.packs(), 2);
    }

    #[test]
    fn packed_cache_slices_lower_precisions_without_repacking() {
        // values fit in 4 bits, packed first at 8: every narrower
        // request must be served by a plane-subset slice, zero re-packs
        let w = QTensor::new(vec![5, -8, 7, -3, 0, 2], vec![3, 2], 1.0, 4).unwrap();
        let cache = PackedCache::new();
        let wide = cache.get_or_pack(0, &w, 8).unwrap();
        assert_eq!((cache.packs(), cache.plane_reuses()), (1, 0));
        let sliced = cache.get_or_pack(0, &w, 4).unwrap();
        assert_eq!((cache.packs(), cache.plane_reuses()), (1, 1));
        assert_eq!(sliced.bits, 4);
        // the slice is exactly what a fresh pack would have produced
        let fresh = PackedPlanes::pack_cols(&w.data, 3, 2, 4, PlaneKind::Sbmwc).unwrap();
        assert_eq!(*sliced, fresh);
        // repeat hits are plain cache hits (no new slice, no new pack)
        cache.get_or_pack(0, &w, 4).unwrap();
        assert_eq!((cache.packs(), cache.plane_reuses()), (1, 1));
        // a second slice at another width reuses the same 8-bit donor
        cache.get_or_pack(0, &w, 6).unwrap();
        assert_eq!((cache.packs(), cache.plane_reuses()), (1, 2));
        // a different slot cannot donate
        cache.get_or_pack(1, &w, 4).unwrap();
        assert_eq!((cache.packs(), cache.plane_reuses()), (2, 2));
        drop(wide);
    }

    #[test]
    fn attention_identity_projections_bounded() {
        let d = 4;
        let mut eye = vec![0i32; d * d];
        for i in 0..d {
            eye[i * d + i] = 1;
        }
        let q = QTensor::new(eye, vec![d, d], 1.0, 8).unwrap();
        let layer = AttentionLayer {
            wq: q.clone(),
            wk: q.clone(),
            wv: q.clone(),
            wo: q,
            bits: 8,
            out_scale: 0.1,
            out_bits: 8,
            packed: PackedCache::new(),
        };
        let x = QTensor::new(vec![4, -4, 2, -2, 1, 3, -3, -1], vec![2, 4], 1.0, 8).unwrap();
        let y = layer.forward(&x, &mut native_exec()).unwrap();
        assert_eq!(y.shape, vec![2, 4]);
        // convex combination of rows of x (identity V): bounded by x range
        let lo = *x.data.iter().min().unwrap() as f64;
        let hi = *x.data.iter().max().unwrap() as f64;
        for &v in &y.data {
            let real = v as f64 * 0.1;
            assert!(real >= lo - 0.2 && real <= hi + 0.2, "{real}");
        }
    }
}
