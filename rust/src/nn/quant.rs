//! Symmetric quantization — the bridge between float model weights /
//! sensor data and the integer operands the accelerator consumes.
//!
//! The paper's flexibility argument (§I, §V): bit-serial hardware lets
//! each layer pick its own precision, trading accuracy against
//! latency/power, where binarized networks over-commit. This module is
//! where the per-layer bit-width decision lands numerically.

use crate::bits::twos::max_value;
use crate::nn::tensor::QTensor;
use crate::Result;

/// Quantization parameters of one tensor.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantParams {
    pub scale: f64,
    pub bits: u32,
}

/// Symmetric per-tensor quantization: `q = clamp(round(x / scale))`
/// with `scale = max|x| / max_value(bits)`.
pub fn quantize_symmetric(x: &[f64], shape: Vec<usize>, bits: u32) -> Result<QTensor> {
    crate::validate_bits(bits)?;
    let amax = x.iter().fold(0f64, |m, v| m.max(v.abs()));
    // 1-bit two's complement has max_value = 0 (range {−1, 0}); anchor
    // the scale to the magnitude of the *negative* end instead so the
    // binarized-network corner stays well-defined.
    let denom = max_value(bits).max(-(crate::bits::twos::min_value(bits) + 1)).max(1) as f64;
    let scale = if amax == 0.0 { 1.0 } else { amax / denom };
    quantize_with_scale(x, shape, scale, bits)
}

/// Quantize with an externally chosen scale (e.g. a calibration pass).
pub fn quantize_with_scale(x: &[f64], shape: Vec<usize>, scale: f64, bits: u32) -> Result<QTensor> {
    anyhow::ensure!(scale > 0.0, "scale must be positive");
    let hi = max_value(bits);
    let lo = crate::bits::twos::min_value(bits);
    let data: Vec<i32> = x
        .iter()
        .map(|&v| ((v / scale).round() as i64).clamp(lo as i64, hi as i64) as i32)
        .collect();
    QTensor::new(data, shape, scale, bits)
}

/// Dequantize back to reals.
pub fn dequantize(t: &QTensor) -> Vec<f64> {
    t.data.iter().map(|&q| q as f64 * t.scale).collect()
}

/// Quantization SNR in dB (signal power over error power) — used by
/// the precision-sweep example to show the accuracy/precision trade.
pub fn quant_snr_db(x: &[f64], t: &QTensor) -> f64 {
    let xr = dequantize(t);
    let sig: f64 = x.iter().map(|v| v * v).sum();
    let err: f64 = x.iter().zip(&xr).map(|(a, b)| (a - b) * (a - b)).sum();
    if err == 0.0 {
        f64::INFINITY
    } else {
        10.0 * (sig / err).log10()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_error_bounded_by_half_step() {
        let x: Vec<f64> = (-50..=50).map(|i| i as f64 / 37.0).collect();
        let t = quantize_symmetric(&x, vec![101], 8).unwrap();
        let xr = dequantize(&t);
        for (a, b) in x.iter().zip(&xr) {
            assert!((a - b).abs() <= t.scale / 2.0 + 1e-12);
        }
    }

    #[test]
    fn all_zero_input() {
        let t = quantize_symmetric(&[0.0; 4], vec![4], 8).unwrap();
        assert!(t.data.iter().all(|&v| v == 0));
    }

    #[test]
    fn snr_improves_with_bits() {
        let x: Vec<f64> = (0..256).map(|i| ((i as f64) * 0.37).sin()).collect();
        let mut prev = f64::NEG_INFINITY;
        for bits in [2u32, 4, 6, 8, 12] {
            let t = quantize_symmetric(&x, vec![256], bits).unwrap();
            let snr = quant_snr_db(&x, &t);
            assert!(snr > prev, "{bits}-bit SNR {snr} !> {prev}");
            prev = snr;
        }
        // ~6 dB/bit rule of thumb: 8-bit should exceed 40 dB
        let t8 = quantize_symmetric(&x, vec![256], 8).unwrap();
        assert!(quant_snr_db(&x, &t8) > 40.0);
    }

    #[test]
    fn one_bit_is_sign_only() {
        // 1-bit two's complement holds {−1, 0}: positives clamp to 0
        let t = quantize_symmetric(&[-1.0, 1.0, -0.2], vec![3], 1).unwrap();
        assert!(t.data.iter().all(|&v| v == 0 || v == -1));
    }

    #[test]
    fn external_scale_clamps() {
        let t = quantize_with_scale(&[100.0, -100.0], vec![2], 0.5, 4).unwrap();
        assert_eq!(t.data, vec![7, -8]);
    }
}
