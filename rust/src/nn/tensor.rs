//! Quantized integer tensors.

use crate::bits::twos::{max_value, min_value};
use crate::Result;

/// FNV-1a fold over quantized values — the golden-source content hash
/// stamped on every [`QTensor`] at construction (DESIGN.md §Integrity).
/// Repair-by-re-pack re-verifies the source against this before
/// trusting it as the donor for a corrupted packed plane.
pub fn content_hash(data: &[i32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &v in data {
        h ^= v as u32 as u64;
        h = h.wrapping_mul(0x1_0000_01b3);
    }
    h
}

/// A quantized tensor: `real ≈ data · scale`, with `data` in the
/// `bits`-bit two's-complement range. Row-major.
#[derive(Debug, Clone, PartialEq)]
pub struct QTensor {
    pub data: Vec<i32>,
    pub shape: Vec<usize>,
    pub scale: f64,
    pub bits: u32,
    /// Golden-source content hash of `data`, stamped at construction
    /// (private so every tensor goes through [`QTensor::new`] /
    /// [`QTensor::zeros`] and carries a valid hash; `bits`/`shape`
    /// re-stamps never touch `data`, so the hash survives them).
    golden: u64,
}

impl QTensor {
    pub fn new(data: Vec<i32>, shape: Vec<usize>, scale: f64, bits: u32) -> Result<Self> {
        crate::validate_bits(bits)?;
        let numel: usize = shape.iter().product();
        anyhow::ensure!(numel == data.len(), "shape {shape:?} vs {} elems", data.len());
        let (lo, hi) = (min_value(bits), max_value(bits));
        anyhow::ensure!(
            data.iter().all(|v| (lo..=hi).contains(v)),
            "values exceed {bits}-bit range"
        );
        let golden = content_hash(&data);
        Ok(QTensor {
            data,
            shape,
            scale,
            bits,
            golden,
        })
    }

    pub fn zeros(shape: Vec<usize>, scale: f64, bits: u32) -> Self {
        let numel = shape.iter().product();
        let data = vec![0; numel];
        let golden = content_hash(&data);
        QTensor {
            data,
            shape,
            scale,
            bits,
            golden,
        }
    }

    /// The pack-time golden hash of `data`.
    pub fn golden(&self) -> u64 {
        self.golden
    }

    /// Whether `data` still matches the hash stamped at construction —
    /// the gate repair-by-re-pack passes before trusting this tensor
    /// as the donor for a corrupted packed plane.
    pub fn verify_golden(&self) -> bool {
        content_hash(&self.data) == self.golden
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// 2-D accessor (row-major); panics on rank ≠ 2 in debug.
    pub fn at2(&self, r: usize, c: usize) -> i32 {
        debug_assert_eq!(self.rank(), 2);
        self.data[r * self.shape[1] + c]
    }

    /// Reinterpret with a new shape of identical element count.
    pub fn reshape(&self, shape: Vec<usize>) -> Result<QTensor> {
        anyhow::ensure!(
            shape.iter().product::<usize>() == self.numel(),
            "reshape {:?} -> {shape:?}",
            self.shape
        );
        let mut t = self.clone();
        t.shape = shape;
        Ok(t)
    }

    /// Collapse to a single row `[1, numel]` — the conv→linear bridge
    /// used by the explicit `Layer::Flatten` in the CNN zoo graph.
    pub fn flatten_row(&self) -> QTensor {
        let mut t = self.clone();
        t.shape = vec![1, t.data.len()];
        t
    }

    /// Transposed copy of a 2-D tensor.
    pub fn transpose2(&self) -> Result<QTensor> {
        anyhow::ensure!(self.rank() == 2, "transpose2 on rank {}", self.rank());
        let (r, c) = (self.shape[0], self.shape[1]);
        let mut data = vec![0i32; r * c];
        for i in 0..r {
            for j in 0..c {
                data[j * r + i] = self.data[i * c + j];
            }
        }
        QTensor::new(data, vec![c, r], self.scale, self.bits)
    }
}

/// im2col for NCHW single-image input: turn a convolution
/// `(C,H,W) * (OC,C,KH,KW)` into a matmul
/// `A[OH·OW, C·KH·KW] × Wᵀ[C·KH·KW, OC]` — the reduction that lets the
/// SA serve convolutional layers (§II-C).
pub fn im2col(
    input: &QTensor,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
) -> Result<(QTensor, usize, usize)> {
    anyhow::ensure!(input.rank() == 3, "im2col expects (C,H,W)");
    let (c, h, w) = (input.shape[0], input.shape[1], input.shape[2]);
    let (oh, ow) = im2col_dims(h, w, kh, kw, stride, pad)?;
    let cols = c * kh * kw;
    let mut out = Vec::with_capacity(oh * ow * cols);
    im2col_fill(&input.data, c, h, w, kh, kw, stride, pad, oh, ow, &mut out);
    Ok((
        QTensor::new(out, vec![oh * ow, cols], input.scale, input.bits)?,
        oh,
        ow,
    ))
}

/// Batched im2col for NCHW rank-4 input: the im2col matrices of every
/// image in a `(B,C,H,W)` batch stacked into one
/// `[B·OH·OW, C·KH·KW]` operand, so a whole batch of convolutions is
/// **one** matmul. Rows stay per-image (image `i` owns rows
/// `i·OH·OW .. (i+1)·OH·OW`, filled by the exact per-image loop), so
/// batch invariance holds: fusing changes the matmul count, never the
/// integers.
pub fn im2col_batch(
    input: &QTensor,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
) -> Result<(QTensor, usize, usize)> {
    anyhow::ensure!(input.rank() == 4, "im2col_batch expects (B,C,H,W)");
    let (b, c, h, w) = (
        input.shape[0],
        input.shape[1],
        input.shape[2],
        input.shape[3],
    );
    let (oh, ow) = im2col_dims(h, w, kh, kw, stride, pad)?;
    let cols = c * kh * kw;
    let mut out = Vec::with_capacity(b * oh * ow * cols);
    for img in 0..b {
        let image = &input.data[img * c * h * w..(img + 1) * c * h * w];
        im2col_fill(image, c, h, w, kh, kw, stride, pad, oh, ow, &mut out);
    }
    Ok((
        QTensor::new(out, vec![b * oh * ow, cols], input.scale, input.bits)?,
        oh,
        ow,
    ))
}

/// Output spatial dims of a convolution, validating the geometry.
fn im2col_dims(
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
) -> Result<(usize, usize)> {
    anyhow::ensure!(kh >= 1 && kw >= 1 && stride >= 1, "bad conv params");
    anyhow::ensure!(h + 2 * pad >= kh && w + 2 * pad >= kw, "kernel larger than input");
    Ok(((h + 2 * pad - kh) / stride + 1, (w + 2 * pad - kw) / stride + 1))
}

/// The per-image im2col inner loop, appending `oh·ow` rows of
/// `c·kh·kw` patch values to `out` (push order equals row-major index
/// order, shared by the single-image and batched entry points so the
/// two cannot drift).
#[allow(clippy::too_many_arguments)]
fn im2col_fill(
    data: &[i32],
    c: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    oh: usize,
    ow: usize,
    out: &mut Vec<i32>,
) {
    for oy in 0..oh {
        for ox in 0..ow {
            for ch in 0..c {
                for ky in 0..kh {
                    for kx in 0..kw {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        let v = if iy >= 0 && ix >= 0 && (iy as usize) < h && (ix as usize) < w {
                            data[ch * h * w + iy as usize * w + ix as usize]
                        } else {
                            0
                        };
                        out.push(v);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_hash_survives_reshape_and_detects_corruption() {
        let t = QTensor::new((0..8).collect(), vec![2, 4], 0.5, 8).unwrap();
        assert!(t.verify_golden());
        // shape/bits re-stamps never touch data, so the hash holds
        assert!(t.reshape(vec![4, 2]).unwrap().verify_golden());
        assert!(t.flatten_row().verify_golden());
        let mut corrupt = t.clone();
        corrupt.data[3] ^= 1;
        assert!(!corrupt.verify_golden(), "a flipped value must fail the golden check");
        assert_eq!(corrupt.golden(), t.golden(), "the stamp itself is immutable");
        // distinct contents hash apart (the collision case repair cares about)
        let u = QTensor::new(vec![1, 2, 3], vec![3], 1.0, 8).unwrap();
        let v = QTensor::new(vec![1, 2, 4], vec![3], 1.0, 8).unwrap();
        assert_ne!(u.golden(), v.golden());
    }

    #[test]
    fn new_validates_range_and_shape() {
        assert!(QTensor::new(vec![127, -128], vec![2], 1.0, 8).is_ok());
        assert!(QTensor::new(vec![128], vec![1], 1.0, 8).is_err());
        assert!(QTensor::new(vec![1, 2, 3], vec![2], 1.0, 8).is_err());
        assert!(QTensor::new(vec![1], vec![1], 1.0, 0).is_err());
    }

    #[test]
    fn flatten_row_collapses_rank() {
        let t = QTensor::new((0..8).collect(), vec![2, 2, 2], 0.5, 8).unwrap();
        let flat = t.flatten_row();
        assert_eq!(flat.shape, vec![1, 8]);
        assert_eq!(flat.data, t.data);
        assert_eq!((flat.scale, flat.bits), (t.scale, t.bits));
    }

    #[test]
    fn transpose_roundtrip() {
        let t = QTensor::new((0..6).collect(), vec![2, 3], 1.0, 8).unwrap();
        let tt = t.transpose2().unwrap().transpose2().unwrap();
        assert_eq!(t, tt);
        let tr = t.transpose2().unwrap();
        assert_eq!(tr.at2(0, 1), t.at2(1, 0));
    }

    #[test]
    fn im2col_identity_kernel() {
        // 1×1 kernel, stride 1: im2col is the flattened image
        let img = QTensor::new((0..9).collect(), vec![1, 3, 3], 1.0, 8).unwrap();
        let (a, oh, ow) = im2col(&img, 1, 1, 1, 0).unwrap();
        assert_eq!((oh, ow), (3, 3));
        assert_eq!(a.shape, vec![9, 1]);
        assert_eq!(a.data, (0..9).collect::<Vec<i32>>());
    }

    #[test]
    fn im2col_3x3_known_patch() {
        let img = QTensor::new((0..16).collect(), vec![1, 4, 4], 1.0, 8).unwrap();
        let (a, oh, ow) = im2col(&img, 3, 3, 1, 0).unwrap();
        assert_eq!((oh, ow), (2, 2));
        assert_eq!(a.shape, vec![4, 9]);
        // first patch = rows 0..3 × cols 0..3
        assert_eq!(&a.data[0..9], &[0, 1, 2, 4, 5, 6, 8, 9, 10]);
    }

    #[test]
    fn im2col_padding_zero_fills() {
        let img = QTensor::new(vec![5; 4], vec![1, 2, 2], 1.0, 8).unwrap();
        let (a, oh, ow) = im2col(&img, 3, 3, 1, 1).unwrap();
        assert_eq!((oh, ow), (2, 2));
        // top-left patch has its first row and column zero-padded
        assert_eq!(&a.data[0..9], &[0, 0, 0, 0, 5, 5, 0, 5, 5]);
    }

    #[test]
    fn im2col_stride_2() {
        let img = QTensor::new((0..16).collect(), vec![1, 4, 4], 1.0, 8).unwrap();
        let (_, oh, ow) = im2col(&img, 2, 2, 2, 0).unwrap();
        assert_eq!((oh, ow), (2, 2));
    }

    #[test]
    fn im2col_batch_stacks_per_image_matrices_exactly() {
        // 3 images of (2, 3, 3): the batched matrix is the per-image
        // im2col matrices concatenated row-block by row-block
        let (b, c, h, w) = (3usize, 2usize, 3usize, 3usize);
        let data: Vec<i32> = (0..(b * c * h * w) as i32).map(|v| v % 50).collect();
        let batch = QTensor::new(data.clone(), vec![b, c, h, w], 0.5, 8).unwrap();
        let (stacked, oh, ow) = im2col_batch(&batch, 2, 2, 1, 1).unwrap();
        assert_eq!((oh, ow), (4, 4));
        assert_eq!(stacked.shape, vec![b * oh * ow, c * 2 * 2]);
        for img in 0..b {
            let solo = QTensor::new(
                data[img * c * h * w..(img + 1) * c * h * w].to_vec(),
                vec![c, h, w],
                0.5,
                8,
            )
            .unwrap();
            let (a, soh, sow) = im2col(&solo, 2, 2, 1, 1).unwrap();
            assert_eq!((soh, sow), (oh, ow));
            let rows = oh * ow * c * 2 * 2;
            assert_eq!(
                &stacked.data[img * rows..(img + 1) * rows],
                &a.data[..],
                "image {img} block diverged"
            );
        }
        // rank and geometry validation
        let solo = QTensor::zeros(vec![1, 2, 2], 1.0, 8);
        assert!(im2col_batch(&solo, 2, 2, 1, 0).is_err(), "rank-3 rejected");
        let tiny = QTensor::zeros(vec![1, 1, 2, 2], 1.0, 8);
        assert!(im2col_batch(&tiny, 5, 5, 1, 0).is_err(), "kernel exceeds input");
    }
}
