//! NN substrate: integer tensors, symmetric quantization, and the
//! layer types whose matmuls the accelerator serves.
//!
//! The paper positions bitSMM as the GEMM core of space-oriented NN
//! inference (§I, §II-C): fully-connected and convolutional layers
//! dominate compute and both reduce to matrix multiplication (conv via
//! im2col), and transformer attention is matmul-dominated. This module
//! provides exactly that reduction so the coordinator can serve whole
//! models: every layer exposes its matmul work-items and a forward
//! function parameterised over a matmul executor (PJRT artifact,
//! cycle-accurate simulator, native loop, or the word-packed plane
//! engine — all four compute identical integers).

pub mod layers;
pub mod model;
pub mod quant;
pub mod tensor;
pub mod weights_io;

pub use layers::{
    AttentionLayer, Conv2dLayer, Layer, LinearLayer, MatmulExec, PackedCache, PackedWeight,
    TransposedKernelCache,
};
pub use model::{Model, ModelStats};
pub use quant::{dequantize, quantize_symmetric, QuantParams};
pub use tensor::QTensor;

use crate::bits::packed::{matmul_packed_planes, PackedPlanes};
use crate::bits::plane::{decompose, plane_weight, PlaneKind};
use crate::Result;

/// Exact integer matmul — the native functional fallback when no PJRT
/// artifact matches a shape.
///
/// The Booth plane decomposition telescopes: `Σ_i 2^i · D_i(A) = A`
/// (digits `d_i = ml[i-1] − ml[i]`, Table I), so
/// `Σ_i 2^i · (D_i(A)·B) = A·B` *exactly* — the per-plane realisation
/// ([`matmul_planes`]) and this direct product are algebraically
/// identical, and a property test pins them together. The serving path
/// therefore uses the direct form with an i-k-j loop order
/// (row-contiguous accumulation — §Perf change 3); `matmul_planes`
/// remains the decomposition oracle used by tests and by callers that
/// want per-plane observability.
pub fn matmul_native(a: &[i32], b: &[i32], m: usize, k: usize, n: usize, bits: u32) -> Result<Vec<i64>> {
    crate::validate_bits(bits)?;
    anyhow::ensure!(a.len() == m * k && b.len() == k * n, "shape mismatch");
    let mut acc = vec![0i64; m * n];
    for r in 0..m {
        let arow = &a[r * k..(r + 1) * k];
        let out = &mut acc[r * n..(r + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0 {
                continue;
            }
            let av = av as i64;
            let brow = &b[kk * n..(kk + 1) * n];
            for (o, &bv) in out.iter_mut().zip(brow) {
                *o += av * bv as i64;
            }
        }
    }
    Ok(acc)
}

/// Per-plane Booth realisation of the same product (`Σ_i 2^i ·
/// (D_i(A)·B)`), mirroring the hardware decomposition cycle-for-plane.
/// Used as the oracle for [`matmul_native`] and by observability paths.
/// Derives its planes from the same [`decompose`] oracle as
/// [`matmul_packed`], so the two realisations cannot drift.
pub fn matmul_planes(a: &[i32], b: &[i32], m: usize, k: usize, n: usize, bits: u32) -> Result<Vec<i64>> {
    crate::validate_bits(bits)?;
    anyhow::ensure!(a.len() == m * k && b.len() == k * n, "shape mismatch");
    let planes = decompose(PlaneKind::Booth, a, bits);
    let mut acc = vec![0i64; m * n];
    for (i, plane) in planes.iter().enumerate() {
        let w = plane_weight(PlaneKind::Booth, i as u32, bits);
        for r in 0..m {
            for c in 0..n {
                let mut dot = 0i64;
                for kk in 0..k {
                    dot += (plane[r * k + kk] as i64) * (b[kk * n + c] as i64);
                }
                acc[r * n + c] += dot * w;
            }
        }
    }
    Ok(acc)
}

/// Word-packed realisation of the same product: both operands are
/// decomposed (via the shared [`decompose`] oracle) into SBMwC planes
/// packed 64 digits per `u64` word, and every plane pair is reduced
/// with per-word `AND` + popcount through the runtime-selected
/// unrolled/AVX2 reducer (`A·B = Σ_{i,j} w_i w_j (D_i(A)·D_j(B))`,
/// see [`crate::bits::packed`]). Bit-identical to [`matmul_native`]
/// and [`matmul_planes`]; ~8× less memory traffic than the
/// byte-per-digit plane path. Serving callers should pre-pack the
/// stationary operand once via [`PackedCache`] instead of calling this
/// per request — the cache also serves lower precisions by slicing
/// plane subsets of wider packs (zero re-packs).
pub fn matmul_packed(a: &[i32], b: &[i32], m: usize, k: usize, n: usize, bits: u32) -> Result<Vec<i64>> {
    crate::validate_bits(bits)?;
    anyhow::ensure!(a.len() == m * k && b.len() == k * n, "shape mismatch");
    let pa = PackedPlanes::pack_rows(a, m, k, bits, PlaneKind::Sbmwc)?;
    let pb = PackedPlanes::pack_cols(b, k, n, bits, PlaneKind::Sbmwc)?;
    matmul_packed_planes(&pa, &pb)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::driver::ref_matmul_i64;

    #[test]
    fn native_matmul_matches_reference() {
        let a = [3i32, -4, 5, 6, -7, 0]; // 2×3
        let b = [1i32, 2, -3, 4, 5, -6]; // 3×2
        let got = matmul_native(&a, &b, 2, 3, 2, 4).unwrap();
        assert_eq!(got, ref_matmul_i64(&a, &b, 2, 3, 2));
    }

    #[test]
    fn plane_realisation_identical_to_direct() {
        // the telescoping identity behind §Perf change 3
        let mut rng = crate::prng::Pcg32::new(0x9a7e);
        for bits in [1u32, 3, 8, 16] {
            let (lo, hi) = (
                crate::bits::twos::min_value(bits),
                crate::bits::twos::max_value(bits),
            );
            let (m, k, n) = (3usize, 11usize, 5usize);
            let a: Vec<i32> = (0..m * k).map(|_| rng.range_i32(lo, hi)).collect();
            let b: Vec<i32> = (0..k * n).map(|_| rng.range_i32(lo, hi)).collect();
            assert_eq!(
                matmul_native(&a, &b, m, k, n, bits).unwrap(),
                matmul_planes(&a, &b, m, k, n, bits).unwrap(),
                "bits={bits}"
            );
        }
    }

    #[test]
    fn native_matmul_validates() {
        assert!(matmul_native(&[1], &[1], 1, 1, 1, 0).is_err());
        assert!(matmul_native(&[1, 2], &[1], 1, 1, 1, 4).is_err());
    }

    #[test]
    fn packed_realisation_identical_to_direct() {
        let mut rng = crate::prng::Pcg32::new(0x9a7f);
        for bits in [1u32, 3, 8, 16] {
            let (lo, hi) = (
                crate::bits::twos::min_value(bits),
                crate::bits::twos::max_value(bits),
            );
            // k = 70 straddles the 64-digit word boundary
            let (m, k, n) = (3usize, 70usize, 5usize);
            let a: Vec<i32> = (0..m * k).map(|_| rng.range_i32(lo, hi)).collect();
            let b: Vec<i32> = (0..k * n).map(|_| rng.range_i32(lo, hi)).collect();
            assert_eq!(
                matmul_packed(&a, &b, m, k, n, bits).unwrap(),
                matmul_native(&a, &b, m, k, n, bits).unwrap(),
                "bits={bits}"
            );
        }
    }

    #[test]
    fn packed_matmul_validates() {
        assert!(matmul_packed(&[1], &[1], 1, 1, 1, 0).is_err());
        assert!(matmul_packed(&[1, 2], &[1], 1, 1, 1, 4).is_err());
    }
}
