//! Model graphs and the tiny-model zoo used by examples and benches.
//!
//! Models mirror the space workloads the paper's introduction motivates
//! (§I): in-situ data analysis (MLP classifier over instrument
//! vectors), on-board payload processing (small CNN over image tiles —
//! the cloud-screening use case of [9]), and transformer workloads
//! (§II-C).

use crate::nn::layers::{
    AttentionLayer, Conv2dLayer, Layer, LinearLayer, MatmulExec, PackedCache,
    TransposedKernelCache,
};
use crate::nn::tensor::QTensor;
use crate::prng::Pcg32;
use crate::Result;

/// A sequential quantized model.
#[derive(Debug, Clone)]
pub struct Model {
    pub name: String,
    pub layers: Vec<Layer>,
    /// Expected input shape (excluding batch for 2-D inputs).
    pub input_shape: Vec<usize>,
    pub input_bits: u32,
    pub input_scale: f64,
}

/// Aggregate statistics of one forward pass.
#[derive(Debug, Clone, Default)]
pub struct ModelStats {
    /// Total MAC operations executed.
    pub macs: u64,
    /// Per-layer (kind, bits, macs).
    pub per_layer: Vec<(&'static str, u32, u64)>,
}

impl Model {
    /// Run the model on one input through the given matmul executor.
    pub fn forward(&self, x: &QTensor, exec: &mut dyn MatmulExec) -> Result<QTensor> {
        let mut h = x.clone();
        for layer in &self.layers {
            h = layer.forward(&h, exec)?;
        }
        Ok(h)
    }

    /// Whether the server may fuse a whole batch of this model's
    /// requests into one stacked forward pass: rank-1 inputs always
    /// (linear stacks are row-independent), rank-3 image models when no
    /// layer is attention (conv/flatten/linear treat each image's rows
    /// independently, so batched im2col is batch-invariant; attention's
    /// data-dependent `ctx_scale` must never mix requests — DESIGN.md
    /// §Serving).
    pub fn fuses_batches(&self) -> bool {
        match self.input_shape.len() {
            1 => true,
            3 => !self
                .layers
                .iter()
                .any(|l| matches!(l, Layer::Attention(_))),
            _ => false,
        }
    }

    /// The matmul shapes `(m, k, n, bits)` a `batch`-request serve
    /// submits, deduplicated — the shape census the execution planner
    /// pre-resolves at warm start and `bitsmm tune` sweeps offline.
    /// Batch-fusing models scale their row dimension by `batch`
    /// (stacked rows / batched im2col); per-item models repeat the
    /// same per-item shapes, so `batch` does not change their census.
    pub fn matmul_shapes(&self, batch: usize) -> Vec<(usize, usize, usize, u32)> {
        self.matmul_shapes_with(batch, None)
    }

    /// [`Model::matmul_shapes`] with per-layer precision overrides
    /// (`widths[i]` replaces layer `i`'s operand width) — how a
    /// [`crate::coordinator::PrecisionPolicy`] projects its resolved
    /// widths onto the census.
    pub fn matmul_shapes_with(
        &self,
        batch: usize,
        widths: Option<&[u32]>,
    ) -> Vec<(usize, usize, usize, u32)> {
        let batch = batch.max(1);
        let bm = if self.fuses_batches() { batch } else { 1 };
        let mut out = Vec::new();
        let mut spatial = self.input_shape.clone();
        for (i, layer) in self.layers.iter().enumerate() {
            let bits = widths.and_then(|w| w.get(i).copied()).unwrap_or(layer.bits());
            match layer {
                Layer::Linear(l) => {
                    let (w_in, w_out) = (l.w.shape[0], l.w.shape[1]);
                    match spatial.as_slice() {
                        // a per-item row; fused serving stacks `batch` of them
                        &[d] if d == w_in => {
                            out.push((bm, w_in, w_out, bits));
                            spatial = vec![w_out];
                        }
                        // an already-matrix activation (e.g. after flatten)
                        &[r, d] if d == w_in => {
                            out.push((r * bm, w_in, w_out, bits));
                            spatial = vec![r, w_out];
                        }
                        _ => {} // the executor would reject this forward
                    }
                }
                Layer::Conv2d(l) if spatial.len() == 3 => {
                    let (oh, ow) = l.out_dims(spatial[1], spatial[2]).unwrap_or((0, 0));
                    let kdim = l.w.shape[1] * l.w.shape[2] * l.w.shape[3];
                    if oh * ow > 0 {
                        out.push((bm * oh * ow, kdim, l.w.shape[0], bits));
                    }
                    spatial = vec![l.w.shape[0], oh, ow];
                }
                // per item, shape-preserving; all five projections
                // (q/k/v/o + ctx) share one [seq, d] × [d, d] shape
                Layer::Attention(l) if spatial.len() == 2 => {
                    let d = l.wq.shape[0];
                    if spatial[1] == d {
                        out.push((spatial[0], d, d, bits));
                    }
                }
                Layer::Flatten => {
                    // mirror Layer::forward: rank-2 activations pass
                    // through unchanged (each row is one sample)
                    if spatial.len() != 2 {
                        spatial = vec![1, spatial.iter().product()];
                    }
                }
                Layer::Conv2d(_) | Layer::Attention(_) => {}
            }
        }
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Warm-start packing: derive every stationary-weight artifact the
    /// packed backend will need — conv im2col transposes
    /// ([`TransposedKernelCache`]) and packed weight planes
    /// ([`PackedCache`], at each layer's declared precision) — so the
    /// first request pays no pack latency. Mirrors the serving-path
    /// condition (`w.bits ≤ layer bits`): weights the executor would
    /// route densely are left unpacked. Returns the number of weight
    /// slots ensured. Idempotent: the caches make repeats free.
    pub fn warm_packed(&self) -> Result<u64> {
        let mut warmed = 0u64;
        for layer in &self.layers {
            match layer {
                Layer::Linear(l) => {
                    if l.w.bits <= l.bits {
                        l.packed.get_or_pack(0, &l.w, l.bits)?;
                        warmed += 1;
                    }
                }
                Layer::Conv2d(l) => {
                    let wt = l.wt.get_or_build(&l.w)?;
                    if wt.bits <= l.bits {
                        l.packed.get_or_pack(0, &wt, l.bits)?;
                        warmed += 1;
                    }
                }
                Layer::Attention(l) => {
                    for (slot, w) in
                        [(0u32, &l.wq), (1, &l.wk), (2, &l.wv), (3, &l.wo)]
                    {
                        if w.bits <= l.bits {
                            l.packed.get_or_pack(slot, w, l.bits)?;
                            warmed += 1;
                        }
                    }
                }
                Layer::Flatten => {}
            }
        }
        Ok(warmed)
    }

    /// One integrity sweep over every resident stationary artifact of
    /// this model: each layer's [`PackedCache`] entries (all slots, all
    /// precisions — warm-start packs, sliced views, and the ad-hoc
    /// packs a request populated on demand all live there) and each
    /// conv layer's [`TransposedKernelCache`]. Corrupt state is
    /// repaired by re-pack/re-derive from its golden-verified dense
    /// source; unrepairable slots are quarantined. Activation packs are
    /// per-execution transients and never resident, so they are the
    /// ABFT row-check's job, not the scrubber's (DESIGN.md §Integrity).
    pub fn scrub(&self) -> crate::nn::layers::ScrubOutcome {
        use crate::nn::layers::ScrubOutcome;
        let mut out = ScrubOutcome::default();
        for layer in &self.layers {
            match layer {
                Layer::Linear(l) => out.merge(&l.packed.scrub(0, &l.w)),
                Layer::Conv2d(l) => {
                    // the derived transpose first: it is both resident
                    // state to protect and the packed cache's golden
                    // source, so repair it before judging the packs
                    let wts = l.wt.scrub(&l.w);
                    out.merge(&wts);
                    if wts.quarantined > 0 {
                        l.packed.quarantine(0);
                        out.quarantined += 1;
                        continue;
                    }
                    if let Some(wt) = l.wt.peek() {
                        out.merge(&l.packed.scrub(0, &wt));
                    }
                }
                Layer::Attention(l) => {
                    for (slot, w) in
                        [(0u32, &l.wq), (1, &l.wk), (2, &l.wv), (3, &l.wo)]
                    {
                        out.merge(&l.packed.scrub(slot, w));
                    }
                }
                Layer::Flatten => {}
            }
        }
        out
    }

    /// Every resident packed-plane entry across the model, paired with
    /// its owning cache handle — the memory-SEU injector's target set
    /// (flip a bit in one of these and the scrubber/ladder must catch
    /// it). Deterministic order (layer, then sorted cache key) so a
    /// seeded injector picks the same victim every run.
    pub fn resident_planes(
        &self,
    ) -> Vec<(PackedCache, (u32, u32), std::sync::Arc<crate::bits::packed::PackedPlanes>)> {
        let mut out = Vec::new();
        for layer in &self.layers {
            let cache = match layer {
                Layer::Linear(l) => &l.packed,
                Layer::Conv2d(l) => &l.packed,
                Layer::Attention(l) => &l.packed,
                Layer::Flatten => continue,
            };
            let mut entries = cache.entries();
            entries.sort_by_key(|(k, _)| *k);
            for (key, planes) in entries {
                out.push((cache.clone(), key, planes));
            }
        }
        out
    }

    /// A precision-degraded clone for overload shedding-by-quality
    /// (DESIGN.md §Resilience): every linear layer's operand precision
    /// is narrowed toward `floor_bits`, clamped so the downshift is
    /// **bit-exact** — never below the incoming activation width (the
    /// forward would assert), never below the width the weight values
    /// actually need ([`crate::bits::packed::PackedPlanes::needed_bits`];
    /// truncating live values would change results), and never above
    /// the layer's declared width (degrading must not widen). Within
    /// those clamps the integer matmul is exact at any width and
    /// `out_scale`/`out_bits` are untouched, so outputs are
    /// bit-identical to the base model — only the plane count (and so
    /// the bit-serial cycle cost) drops. Conv/attention layers pass
    /// through un-degraded (their transposed-kernel caches are keyed to
    /// the declared width); the clone shares all packed caches, so its
    /// narrower planes are zero-copy slices of already-warm packs.
    pub fn degraded(&self, floor_bits: u32) -> Model {
        use crate::bits::packed::PackedPlanes;
        let mut m = self.clone();
        let mut act_bits = self.input_bits;
        for layer in &mut m.layers {
            match layer {
                Layer::Linear(l) => {
                    let need = PackedPlanes::needed_bits(&l.w.data);
                    let nb = floor_bits.max(act_bits).max(need).min(l.bits);
                    l.bits = nb;
                    l.w.bits = nb;
                    act_bits = l.out_bits;
                }
                Layer::Conv2d(l) => act_bits = l.out_bits,
                Layer::Attention(l) => act_bits = l.out_bits,
                Layer::Flatten => {}
            }
        }
        m
    }

    /// Static MAC census (per-layer precision included) for `batch`
    /// inputs. `batch` means stacked rows for rank-1 (vector) models
    /// and independent items for image/token models, matching how the
    /// server assembles batches — so the census always equals what the
    /// scheduler reports for the same request count. Degenerate conv
    /// geometry saturates to zero MACs instead of underflow-panicking
    /// (the case `im2col` rejects at execution time).
    pub fn stats(&self, batch: usize) -> ModelStats {
        let mut s = ModelStats::default();
        // the per-item activation shape, tracked through every layer so
        // chained/composed graphs (conv→conv, conv→flatten→linear,
        // …→attention) are counted from the shape that layer actually
        // sees; compositions the executor would reject (wrong rank for
        // the layer kind) saturate to 0 MACs like degenerate conv
        // geometry does
        let mut spatial = self.input_shape.clone();
        for layer in &self.layers {
            let macs = match layer {
                Layer::Linear(l) => {
                    let (w_in, w_out) = (l.w.shape[0], l.w.shape[1]);
                    match spatial.as_slice() {
                        // a vector model's per-item row (stacked at serve time)
                        &[d] if d == w_in => {
                            spatial = vec![w_out];
                            l.macs(batch)
                        }
                        // an already-matrix activation: every row of each item
                        &[r, d] if d == w_in => {
                            spatial = vec![r, w_out];
                            l.macs(r * batch)
                        }
                        // shape mismatch: the executor would reject this forward
                        _ => 0,
                    }
                }
                Layer::Conv2d(l) if spatial.len() == 3 => {
                    let m = l.macs(spatial[1], spatial[2]) * batch as u64;
                    // 0×0 once the geometry degenerates
                    let (oh, ow) = l.out_dims(spatial[1], spatial[2]).unwrap_or((0, 0));
                    spatial = vec![l.w.shape[0], oh, ow];
                    m
                }
                // per item: one [seq, dim] token matrix, shape-preserving
                Layer::Attention(l) if spatial.len() == 2 => {
                    l.macs(spatial[0]) * batch as u64
                }
                Layer::Flatten => {
                    // mirror Layer::forward: rank-2 activations pass
                    // through unchanged (each row is one sample), so
                    // an attention→flatten→linear head is counted
                    // from the [seq, d] shape the head actually sees
                    if spatial.len() != 2 {
                        spatial = vec![1, spatial.iter().product()];
                    }
                    0
                }
                // rank mismatch: the executor would reject this forward
                Layer::Conv2d(_) | Layer::Attention(_) => 0,
            };
            s.macs += macs;
            s.per_layer.push((layer.kind(), layer.bits(), macs));
        }
        s
    }
}

fn rand_q(rng: &mut Pcg32, shape: Vec<usize>, bits: u32, scale: f64) -> QTensor {
    let lo = crate::bits::twos::min_value(bits) / 2;
    let hi = crate::bits::twos::max_value(bits) / 2;
    let numel = shape.iter().product();
    let data: Vec<i32> = (0..numel).map(|_| rng.range_i32(lo, hi)).collect();
    QTensor::new(data, shape, scale, bits).expect("rand_q in range")
}

/// MLP classifier 64→64→32→10 with per-layer precisions 8/4/4 — the
/// same architecture `python/compile/aot.py` exports, so PJRT-served
/// and rust-native paths cover the same model.
pub fn mlp_zoo(seed: u64) -> Model {
    let mut rng = Pcg32::new(seed);
    let mk = |rng: &mut Pcg32, d_in, d_out, bits, out_scale, out_bits, relu| {
        Layer::Linear(LinearLayer {
            w: rand_q(rng, vec![d_in, d_out], bits, 0.02),
            bias: (0..d_out).map(|_| rng.range_i32(-64, 64) as i64).collect(),
            bits,
            relu,
            out_scale,
            out_bits,
            packed: PackedCache::new(),
        })
    };
    Model {
        name: "mlp-64-64-32-10".into(),
        layers: vec![
            mk(&mut rng, 64, 64, 8, 0.05, 4, true),
            mk(&mut rng, 64, 32, 4, 0.1, 4, true),
            mk(&mut rng, 32, 10, 4, 0.2, 8, false),
        ],
        input_shape: vec![64],
        input_bits: 8,
        input_scale: 0.05,
    }
}

/// MLP 64→32→10 with deliberate precision *headroom*: every weight
/// value fits in 4 bits but the layers declare 8 — so a degrade policy
/// ([`Model::degraded`]) can legally narrow them to 4-bit planes while
/// staying bit-identical. The activations are 4-bit end to end
/// (`input_bits` 4, `out_bits` 4) so the activation clamp never blocks
/// the downshift. This is the chaos/degrade demo workload.
pub fn mlp_headroom_zoo(seed: u64) -> Model {
    let mut rng = Pcg32::new(seed);
    let mk = |rng: &mut Pcg32, d_in, d_out, out_scale, relu| {
        // values drawn from the 4-bit grid, declared at 8 bits
        let mut w = rand_q(rng, vec![d_in, d_out], 4, 0.02);
        w.bits = 8;
        Layer::Linear(LinearLayer {
            w,
            bias: (0..d_out).map(|_| rng.range_i32(-16, 16) as i64).collect(),
            bits: 8,
            relu,
            out_scale,
            out_bits: 4,
            packed: PackedCache::new(),
        })
    };
    Model {
        name: "mlp-headroom-64-32-10".into(),
        layers: vec![
            mk(&mut rng, 64, 32, 0.1, true),
            mk(&mut rng, 32, 10, 0.2, false),
        ],
        input_shape: vec![64],
        input_bits: 4,
        input_scale: 0.05,
    }
}

/// Small CNN over 1×16×16 tiles: conv3x3(8) → conv3x3(16, stride 2) →
/// flatten → linear(10). The cloud-screening-style payload workload.
/// Each layer's `out_bits` matches the next layer's operand precision,
/// so every matmul is servable on the packed bit-plane path (no
/// precision-mismatch fallbacks).
pub fn cnn_zoo(seed: u64) -> Model {
    let mut rng = Pcg32::new(seed);
    let conv = |rng: &mut Pcg32, oc, c, bits, stride, out_scale, out_bits| {
        Layer::Conv2d(Conv2dLayer {
            w: rand_q(rng, vec![oc, c, 3, 3], bits, 0.05),
            bias: (0..oc).map(|_| rng.range_i32(-16, 16) as i64).collect(),
            stride,
            pad: 1,
            bits,
            relu: true,
            out_scale,
            out_bits,
            packed: PackedCache::new(),
            wt: TransposedKernelCache::new(),
        })
    };
    let mut rng2 = Pcg32::new(seed ^ 0xc0ffee);
    Model {
        name: "cnn-16x16".into(),
        layers: vec![
            conv(&mut rng, 8, 1, 8, 1, 0.1, 6),
            conv(&mut rng, 16, 8, 6, 2, 0.2, 6),
            Layer::Flatten,
            Layer::Linear(LinearLayer {
                w: rand_q(&mut rng2, vec![16 * 8 * 8, 10], 6, 0.05),
                bias: vec![0; 10],
                bits: 6,
                relu: false,
                out_scale: 0.5,
                out_bits: 8,
                packed: PackedCache::new(),
            }),
        ],
        input_shape: vec![1, 16, 16],
        input_bits: 8,
        input_scale: 0.02,
    }
}

/// One transformer attention block over `[seq=16, dim=32]` tokens.
pub fn attention_zoo(seed: u64) -> Model {
    let mut rng = Pcg32::new(seed);
    let d = 32;
    Model {
        name: "attn-16x32".into(),
        layers: vec![Layer::Attention(AttentionLayer {
            wq: rand_q(&mut rng, vec![d, d], 8, 0.03),
            wk: rand_q(&mut rng, vec![d, d], 8, 0.03),
            wv: rand_q(&mut rng, vec![d, d], 8, 0.03),
            wo: rand_q(&mut rng, vec![d, d], 8, 0.03),
            bits: 8,
            out_scale: 0.1,
            out_bits: 8,
            packed: PackedCache::new(),
        })],
        input_shape: vec![16, d],
        input_bits: 8,
        input_scale: 0.05,
    }
}

/// Look up a zoo model by its CLI/config name.
pub fn zoo_model(name: &str, seed: u64) -> Result<Model> {
    Ok(match name {
        "mlp" => mlp_zoo(seed),
        "mlp-headroom" => mlp_headroom_zoo(seed),
        "cnn" => cnn_zoo(seed),
        "attn" | "attention" => attention_zoo(seed),
        other => {
            anyhow::bail!("unknown zoo model '{other}' (expected mlp|mlp-headroom|cnn|attn)")
        }
    })
}

/// Historical alias from when `Model::forward` could not flatten: the
/// CNN zoo now carries an explicit [`Layer::Flatten`], so the server
/// path and this wrapper are the same code.
pub fn forward_cnn(model: &Model, x: &QTensor, exec: &mut dyn MatmulExec) -> Result<QTensor> {
    model.forward(x, exec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::matmul_native;

    fn exec() -> impl FnMut(&[i32], &[i32], usize, usize, usize, u32) -> Result<Vec<i64>> {
        |a, b, m, k, n, bits| matmul_native(a, b, m, k, n, bits)
    }

    #[test]
    fn mlp_forward_shape() {
        let m = mlp_zoo(1);
        let x = QTensor::zeros(vec![4, 64], 0.05, 8);
        let y = m.forward(&x, &mut exec()).unwrap();
        assert_eq!(y.shape, vec![4, 10]);
    }

    #[test]
    fn mlp_deterministic_per_seed() {
        let m1 = mlp_zoo(7);
        let m2 = mlp_zoo(7);
        let mut rng = Pcg32::new(99);
        let x = QTensor::new(
            (0..64).map(|_| rng.range_i32(-100, 100)).collect(),
            vec![1, 64],
            0.05,
            8,
        )
        .unwrap();
        let y1 = m1.forward(&x, &mut exec()).unwrap();
        let y2 = m2.forward(&x, &mut exec()).unwrap();
        assert_eq!(y1.data, y2.data);
    }

    #[test]
    fn cnn_forward_shape() {
        let m = cnn_zoo(2);
        // the flatten is an explicit layer now, so plain Model::forward
        // serves the CNN — the server path and the wrapper are one code
        assert!(m.layers.iter().any(|l| matches!(l, Layer::Flatten)));
        let x = QTensor::zeros(vec![1, 16, 16], 0.02, 8);
        let y = m.forward(&x, &mut exec()).unwrap();
        assert_eq!(y.shape, vec![1, 10]);
        let via_wrapper = forward_cnn(&m, &x, &mut exec()).unwrap();
        assert_eq!(y.data, via_wrapper.data);
    }

    #[test]
    fn zoo_model_lookup() {
        assert_eq!(zoo_model("mlp", 1).unwrap().name, "mlp-64-64-32-10");
        assert_eq!(zoo_model("cnn", 1).unwrap().name, "cnn-16x16");
        assert_eq!(zoo_model("attn", 1).unwrap().name, "attn-16x32");
        assert!(zoo_model("resnet", 1).is_err());
    }

    #[test]
    fn attention_forward_shape() {
        let m = attention_zoo(3);
        let x = QTensor::zeros(vec![16, 32], 0.05, 8);
        let y = m.forward(&x, &mut exec()).unwrap();
        assert_eq!(y.shape, vec![16, 32]);
    }

    #[test]
    fn stats_census() {
        let m = mlp_zoo(1);
        let s = m.stats(8);
        assert_eq!(s.per_layer.len(), 3);
        assert_eq!(s.macs, 8 * (64 * 64 + 64 * 32 + 32 * 10) as u64);
        // per-layer precisions recorded
        assert_eq!(
            s.per_layer.iter().map(|p| p.1).collect::<Vec<_>>(),
            vec![8, 4, 4]
        );
    }

    #[test]
    fn cnn_stats_spatial_tracking() {
        let m = cnn_zoo(2);
        let s = m.stats(1);
        // conv1: 16·16 × 1·3·3 × 8; conv2 (stride 2): 8·8 × 8·3·3 × 16
        assert_eq!(s.per_layer[0].2, 256 * 9 * 8);
        assert_eq!(s.per_layer[1].2, 64 * 72 * 16);
        // the explicit flatten contributes no arithmetic
        assert_eq!(s.per_layer[2], ("flatten", 0, 0));
        // per-item batches scale every layer linearly
        let s4 = m.stats(4);
        assert_eq!(s4.macs, 4 * s.macs);
    }

    #[test]
    fn attention_stats_census_counts_tokens() {
        let m = attention_zoo(3);
        // one item = one [16, 32] token matrix: 4 projections of
        // seq·d·d each; items scale linearly (per-item batching)
        assert_eq!(m.stats(1).macs, 4 * 16 * 32 * 32);
        assert_eq!(m.stats(3).macs, 3 * 4 * 16 * 32 * 32);
    }

    #[test]
    fn stats_saturate_on_rank_mismatched_composition() {
        // attention grafted after conv sees a rank-3 activation the
        // executor would reject: its census saturates to 0 instead of
        // silently counting the channel count as a sequence length
        let attn_layer = attention_zoo(1).layers.remove(0);
        let mut m = cnn_zoo(2);
        m.layers.truncate(2); // conv, conv → rank-3 activation
        m.layers.push(attn_layer);
        let s = m.stats(1);
        assert_eq!(s.per_layer[2], ("attention", 8, 0));
        // the conv layers are still counted normally
        assert_eq!(s.per_layer[0].2, 256 * 9 * 8);
    }

    #[test]
    fn batch_fusing_predicate() {
        assert!(mlp_zoo(1).fuses_batches(), "vector rows always stack");
        assert!(cnn_zoo(1).fuses_batches(), "conv/flatten/linear is row-independent");
        assert!(!attention_zoo(1).fuses_batches(), "ctx_scale must never mix requests");
    }

    #[test]
    fn matmul_shapes_census_tracks_serving_assembly() {
        // mlp: stacked rows scale m with batch
        let mlp = mlp_zoo(1);
        assert_eq!(
            mlp.matmul_shapes(1),
            vec![(1, 32, 10, 4), (1, 64, 32, 4), (1, 64, 64, 8)]
        );
        assert_eq!(
            mlp.matmul_shapes(8),
            vec![(8, 32, 10, 4), (8, 64, 32, 4), (8, 64, 64, 8)]
        );
        // cnn fused at batch 4: batched-im2col rows, then a stacked head
        let cnn = cnn_zoo(2);
        let shapes = cnn.matmul_shapes(4);
        assert!(shapes.contains(&(4 * 256, 9, 8, 8)), "{shapes:?}"); // conv1
        assert!(shapes.contains(&(4 * 64, 72, 16, 6)), "{shapes:?}"); // conv2, stride 2
        assert!(shapes.contains(&(4, 16 * 8 * 8, 10, 6)), "{shapes:?}"); // head
        assert_eq!(shapes.len(), 3);
        // attention serves per item: batch never changes its census
        let attn = attention_zoo(3);
        assert_eq!(attn.matmul_shapes(1), vec![(16, 32, 32, 8)]);
        assert_eq!(attn.matmul_shapes(8), attn.matmul_shapes(1));
        // precision overrides replace the per-layer widths
        let over = mlp.matmul_shapes_with(1, Some(&[6, 6, 6]));
        assert_eq!(over, vec![(1, 32, 10, 6), (1, 64, 32, 6), (1, 64, 64, 6)]);
    }

    #[test]
    fn flatten_census_passes_rank2_through_like_forward_does() {
        // attention → flatten → linear head: forward feeds the head
        // the [seq, d] matrix (flatten is a rank-2 passthrough), so
        // the censuses must count it from that shape, not [1, seq·d]
        let attn_layer = attention_zoo(1).layers.remove(0);
        let head = Layer::Linear(LinearLayer {
            w: QTensor::zeros(vec![32, 10], 0.05, 8),
            bias: vec![0; 10],
            bits: 8,
            relu: false,
            out_scale: 0.5,
            out_bits: 8,
            packed: PackedCache::new(),
        });
        let m = Model {
            name: "attn-head".into(),
            layers: vec![attn_layer, Layer::Flatten, head],
            input_shape: vec![16, 32],
            input_bits: 8,
            input_scale: 0.05,
        };
        // the model actually forwards (the composition is legal) …
        let x = QTensor::zeros(vec![16, 32], 0.05, 8);
        let y = m.forward(&x, &mut exec()).unwrap();
        assert_eq!(y.shape, vec![16, 10]);
        // … and both censuses see the head's real [16, 32]×[32, 10]
        let s = m.stats(1);
        assert_eq!(s.per_layer[2], ("linear", 8, 16 * 32 * 10));
        assert!(
            m.matmul_shapes(1).contains(&(16, 32, 10, 8)),
            "{:?}",
            m.matmul_shapes(1)
        );
    }

    #[test]
    fn warm_packed_precomputes_every_weight_slot() {
        let cnn = cnn_zoo(2);
        assert_eq!(cnn.warm_packed().unwrap(), 3, "conv1 + conv2 + head");
        for layer in &cnn.layers {
            match layer {
                Layer::Conv2d(l) => {
                    assert!(l.wt.is_built(), "transpose derived at warm start");
                    assert_eq!(l.packed.packs(), 1);
                }
                Layer::Linear(l) => assert_eq!(l.packed.packs(), 1),
                _ => {}
            }
        }
        // idempotent: a second warm start packs nothing new
        assert_eq!(cnn.warm_packed().unwrap(), 3);
        for layer in &cnn.layers {
            if let Layer::Conv2d(l) = layer {
                assert_eq!(l.packed.packs(), 1);
            }
        }
        let attn = attention_zoo(3);
        assert_eq!(attn.warm_packed().unwrap(), 4, "q/k/v/o projections");
        if let Layer::Attention(l) = &attn.layers[0] {
            assert_eq!(l.packed.packs(), 4);
        }
    }

    #[test]
    fn model_scrub_repairs_a_flipped_resident_plane_bit() {
        use std::sync::Arc;
        let m = cnn_zoo(2);
        m.warm_packed().unwrap();
        // clean model: a sweep finds nothing
        assert_eq!(m.scrub(), crate::nn::layers::ScrubOutcome::default());
        let targets = m.resident_planes();
        assert_eq!(targets.len(), 3, "conv1 + conv2 + head packs resident");
        // flip one live bit in the second resident pack (a conv slot)
        let (cache, key, planes) = &targets[1];
        let clean = planes.clone();
        cache.replace(
            *key,
            Arc::new(clean.with_flipped_bit(0, 0, 0, 0, false).unwrap()),
        );
        let out = m.scrub();
        assert_eq!((out.detected, out.repaired, out.quarantined), (1, 1, 0));
        // the repaired pack is bit-identical to the pre-fault one
        let repaired = m
            .resident_planes()
            .into_iter()
            .find(|(_, k, _)| k == key)
            .unwrap()
            .2;
        assert_eq!(*repaired, *clean);
        // a second sweep is clean again
        assert_eq!(m.scrub(), crate::nn::layers::ScrubOutcome::default());
    }

    #[test]
    fn degraded_headroom_model_narrows_and_stays_bit_identical() {
        let base = mlp_headroom_zoo(5);
        let deg = base.degraded(4);
        for (b, d) in base.layers.iter().zip(&deg.layers) {
            let (Layer::Linear(b), Layer::Linear(d)) = (b, d) else {
                panic!("headroom zoo is all-linear");
            };
            assert_eq!(b.bits, 8, "base declares headroom");
            assert_eq!(d.bits, 4, "degrade takes it");
            assert_eq!(d.w.bits, 4, "weight declaration follows the layer");
            assert_eq!(b.w.data, d.w.data, "values untouched");
        }
        // bit-identical forwards at the narrowed precision
        let mut rng = Pcg32::new(41);
        let x = QTensor::new(
            (0..64).map(|_| rng.range_i32(-8, 7)).collect(),
            vec![1, 64],
            0.05,
            4,
        )
        .unwrap();
        let y_base = base.forward(&x, &mut exec()).unwrap();
        let y_deg = deg.forward(&x, &mut exec()).unwrap();
        assert_eq!(y_base.data, y_deg.data);
        assert_eq!(y_base.bits, y_deg.bits);
        // the degraded clone shares the packed caches: warming the base
        // then the clone slices planes instead of re-packing
        assert_eq!(base.warm_packed().unwrap(), 2);
        assert_eq!(deg.warm_packed().unwrap(), 2);
        for (b, d) in base.layers.iter().zip(&deg.layers) {
            let (Layer::Linear(b), Layer::Linear(d)) = (b, d) else {
                unreachable!()
            };
            assert_eq!(b.packed.packs(), 1, "one real pack per weight");
            assert_eq!(d.packed.packs(), 1, "clone shares it");
            assert_eq!(d.packed.plane_reuses(), 1, "4-bit view sliced, not packed");
        }
    }

    #[test]
    fn degraded_clamps_never_truncate_or_widen() {
        // mlp_zoo has zero headroom: layer widths already equal what the
        // activations and weight values need, so degrading is a no-op
        let base = mlp_zoo(1);
        let deg = base.degraded(1);
        let widths = |m: &Model| {
            m.layers
                .iter()
                .map(|l| l.bits())
                .collect::<Vec<_>>()
        };
        assert_eq!(widths(&deg), widths(&base), "no headroom → no change");
        // a floor above the declared width must not widen the layer
        let wide = mlp_headroom_zoo(5).degraded(12);
        assert!(wide.layers.iter().all(|l| l.bits() == 8));
        // the activation clamp: layer 1 of mlp_zoo consumes 8-bit input,
        // so even with headroom its floor could never drop below 8
        let deg8 = mlp_zoo(1).degraded(2);
        assert_eq!(deg8.layers[0].bits(), 8);
    }

    #[test]
    fn stats_survive_degenerate_conv_geometry() {
        // a 5×5 kernel over an unpadded 1×2×2 input: im2col would
        // reject it; the census must saturate, not underflow-panic
        let m = Model {
            name: "degenerate".into(),
            layers: vec![Layer::Conv2d(Conv2dLayer {
                w: QTensor::zeros(vec![2, 1, 5, 5], 1.0, 8),
                bias: vec![0; 2],
                stride: 1,
                pad: 0,
                bits: 8,
                relu: false,
                out_scale: 1.0,
                out_bits: 8,
                packed: PackedCache::new(),
                wt: TransposedKernelCache::new(),
            })],
            input_shape: vec![1, 2, 2],
            input_bits: 8,
            input_scale: 1.0,
        };
        let s = m.stats(1);
        assert_eq!(s.macs, 0);
        assert_eq!(s.per_layer[0], ("conv2d", 8, 0));
    }
}
