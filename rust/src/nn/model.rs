//! Model graphs and the tiny-model zoo used by examples and benches.
//!
//! Models mirror the space workloads the paper's introduction motivates
//! (§I): in-situ data analysis (MLP classifier over instrument
//! vectors), on-board payload processing (small CNN over image tiles —
//! the cloud-screening use case of [9]), and transformer workloads
//! (§II-C).

use crate::nn::layers::{AttentionLayer, Conv2dLayer, Layer, LinearLayer, MatmulExec, PackedCache};
use crate::nn::tensor::QTensor;
use crate::prng::Pcg32;
use crate::Result;

/// A sequential quantized model.
#[derive(Debug, Clone)]
pub struct Model {
    pub name: String,
    pub layers: Vec<Layer>,
    /// Expected input shape (excluding batch for 2-D inputs).
    pub input_shape: Vec<usize>,
    pub input_bits: u32,
    pub input_scale: f64,
}

/// Aggregate statistics of one forward pass.
#[derive(Debug, Clone, Default)]
pub struct ModelStats {
    /// Total MAC operations executed.
    pub macs: u64,
    /// Per-layer (kind, bits, macs).
    pub per_layer: Vec<(&'static str, u32, u64)>,
}

impl Model {
    /// Run the model on one input through the given matmul executor.
    pub fn forward(&self, x: &QTensor, exec: &mut dyn MatmulExec) -> Result<QTensor> {
        let mut h = x.clone();
        for layer in &self.layers {
            h = layer.forward(&h, exec)?;
        }
        Ok(h)
    }

    /// Static MAC census (per-layer precision included) for a batch of
    /// one 2-D input row set / one image.
    pub fn stats(&self, batch: usize) -> ModelStats {
        let mut s = ModelStats::default();
        let mut spatial = self.input_shape.clone();
        for layer in &self.layers {
            let macs = match layer {
                Layer::Linear(l) => l.macs(batch),
                Layer::Conv2d(l) => {
                    let m = l.macs(spatial[1], spatial[2]);
                    // update spatial dims for chained convs
                    let (kh, kw) = (l.w.shape[2], l.w.shape[3]);
                    spatial = vec![
                        l.w.shape[0],
                        (spatial[1] + 2 * l.pad - kh) / l.stride + 1,
                        (spatial[2] + 2 * l.pad - kw) / l.stride + 1,
                    ];
                    m
                }
                Layer::Attention(l) => l.macs(batch),
            };
            s.macs += macs;
            s.per_layer.push((layer.kind(), layer.bits(), macs));
        }
        s
    }
}

fn rand_q(rng: &mut Pcg32, shape: Vec<usize>, bits: u32, scale: f64) -> QTensor {
    let lo = crate::bits::twos::min_value(bits) / 2;
    let hi = crate::bits::twos::max_value(bits) / 2;
    let numel = shape.iter().product();
    let data: Vec<i32> = (0..numel).map(|_| rng.range_i32(lo, hi)).collect();
    QTensor::new(data, shape, scale, bits).expect("rand_q in range")
}

/// MLP classifier 64→64→32→10 with per-layer precisions 8/4/4 — the
/// same architecture `python/compile/aot.py` exports, so PJRT-served
/// and rust-native paths cover the same model.
pub fn mlp_zoo(seed: u64) -> Model {
    let mut rng = Pcg32::new(seed);
    let mk = |rng: &mut Pcg32, d_in, d_out, bits, out_scale, out_bits, relu| {
        Layer::Linear(LinearLayer {
            w: rand_q(rng, vec![d_in, d_out], bits, 0.02),
            bias: (0..d_out).map(|_| rng.range_i32(-64, 64) as i64).collect(),
            bits,
            relu,
            out_scale,
            out_bits,
            packed: PackedCache::new(),
        })
    };
    Model {
        name: "mlp-64-64-32-10".into(),
        layers: vec![
            mk(&mut rng, 64, 64, 8, 0.05, 4, true),
            mk(&mut rng, 64, 32, 4, 0.1, 4, true),
            mk(&mut rng, 32, 10, 4, 0.2, 8, false),
        ],
        input_shape: vec![64],
        input_bits: 8,
        input_scale: 0.05,
    }
}

/// Small CNN over 1×16×16 tiles: conv3x3(8) → conv3x3(16, stride 2) →
/// flatten-linear(10). The cloud-screening-style payload workload.
pub fn cnn_zoo(seed: u64) -> Model {
    let mut rng = Pcg32::new(seed);
    let conv = |rng: &mut Pcg32, oc, c, bits, stride, out_scale| {
        Layer::Conv2d(Conv2dLayer {
            w: rand_q(rng, vec![oc, c, 3, 3], bits, 0.05),
            bias: (0..oc).map(|_| rng.range_i32(-16, 16) as i64).collect(),
            stride,
            pad: 1,
            bits,
            relu: true,
            out_scale,
            out_bits: bits,
            packed: PackedCache::new(),
        })
    };
    let mut rng2 = Pcg32::new(seed ^ 0xc0ffee);
    Model {
        name: "cnn-16x16".into(),
        layers: vec![
            conv(&mut rng, 8, 1, 8, 1, 0.1),
            conv(&mut rng, 16, 8, 6, 2, 0.2),
            // flatten happens implicitly via reshape in forward_cnn
            Layer::Linear(LinearLayer {
                w: rand_q(&mut rng2, vec![16 * 8 * 8, 10], 6, 0.05),
                bias: vec![0; 10],
                bits: 6,
                relu: false,
                out_scale: 0.5,
                out_bits: 8,
                packed: PackedCache::new(),
            }),
        ],
        input_shape: vec![1, 16, 16],
        input_bits: 8,
        input_scale: 0.02,
    }
}

/// One transformer attention block over `[seq=16, dim=32]` tokens.
pub fn attention_zoo(seed: u64) -> Model {
    let mut rng = Pcg32::new(seed);
    let d = 32;
    Model {
        name: "attn-16x32".into(),
        layers: vec![Layer::Attention(AttentionLayer {
            wq: rand_q(&mut rng, vec![d, d], 8, 0.03),
            wk: rand_q(&mut rng, vec![d, d], 8, 0.03),
            wv: rand_q(&mut rng, vec![d, d], 8, 0.03),
            wo: rand_q(&mut rng, vec![d, d], 8, 0.03),
            bits: 8,
            out_scale: 0.1,
            out_bits: 8,
            packed: PackedCache::new(),
        })],
        input_shape: vec![16, d],
        input_bits: 8,
        input_scale: 0.05,
    }
}

/// CNN forward needs a flatten between conv and linear stages; this
/// wrapper inserts it (kept out of `Model::forward` to keep layer
/// composition explicit).
pub fn forward_cnn(model: &Model, x: &QTensor, exec: &mut dyn MatmulExec) -> Result<QTensor> {
    let mut h = x.clone();
    for layer in &model.layers {
        if let (Layer::Linear(_), 3) = (layer, h.rank()) {
            h = h.reshape(vec![1, h.numel()])?;
        }
        h = layer.forward(&h, exec)?;
    }
    Ok(h)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::matmul_native;

    fn exec() -> impl FnMut(&[i32], &[i32], usize, usize, usize, u32) -> Result<Vec<i64>> {
        |a, b, m, k, n, bits| matmul_native(a, b, m, k, n, bits)
    }

    #[test]
    fn mlp_forward_shape() {
        let m = mlp_zoo(1);
        let x = QTensor::zeros(vec![4, 64], 0.05, 8);
        let y = m.forward(&x, &mut exec()).unwrap();
        assert_eq!(y.shape, vec![4, 10]);
    }

    #[test]
    fn mlp_deterministic_per_seed() {
        let m1 = mlp_zoo(7);
        let m2 = mlp_zoo(7);
        let mut rng = Pcg32::new(99);
        let x = QTensor::new(
            (0..64).map(|_| rng.range_i32(-100, 100)).collect(),
            vec![1, 64],
            0.05,
            8,
        )
        .unwrap();
        let y1 = m1.forward(&x, &mut exec()).unwrap();
        let y2 = m2.forward(&x, &mut exec()).unwrap();
        assert_eq!(y1.data, y2.data);
    }

    #[test]
    fn cnn_forward_shape() {
        let m = cnn_zoo(2);
        let x = QTensor::zeros(vec![1, 16, 16], 0.02, 8);
        let y = forward_cnn(&m, &x, &mut exec()).unwrap();
        assert_eq!(y.shape, vec![1, 10]);
    }

    #[test]
    fn attention_forward_shape() {
        let m = attention_zoo(3);
        let x = QTensor::zeros(vec![16, 32], 0.05, 8);
        let y = m.forward(&x, &mut exec()).unwrap();
        assert_eq!(y.shape, vec![16, 32]);
    }

    #[test]
    fn stats_census() {
        let m = mlp_zoo(1);
        let s = m.stats(8);
        assert_eq!(s.per_layer.len(), 3);
        assert_eq!(s.macs, 8 * (64 * 64 + 64 * 32 + 32 * 10) as u64);
        // per-layer precisions recorded
        assert_eq!(
            s.per_layer.iter().map(|p| p.1).collect::<Vec<_>>(),
            vec![8, 4, 4]
        );
    }

    #[test]
    fn cnn_stats_spatial_tracking() {
        let m = cnn_zoo(2);
        let s = m.stats(1);
        // conv1: 16·16 × 1·3·3 × 8; conv2 (stride 2): 8·8 × 8·3·3 × 16
        assert_eq!(s.per_layer[0].2, 256 * 9 * 8);
        assert_eq!(s.per_layer[1].2, 64 * 72 * 16);
    }
}
