//! Micro-benchmark harness (offline environment — no `criterion`; see
//! DESIGN.md substitutions). Provides warm-up, repeated timed runs,
//! and robust summary statistics for the `rust/benches/` targets.

use std::time::{Duration, Instant};

/// Summary statistics of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub median: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl BenchResult {
    /// Throughput in "units"/second given units-per-iteration.
    pub fn per_second(&self, units_per_iter: f64) -> f64 {
        units_per_iter / self.mean.as_secs_f64()
    }

    pub fn format(&self) -> String {
        format!(
            "{:<44} {:>10} iters  mean {:>12?}  median {:>12?}  p95 {:>12?}  min {:>12?}",
            self.name, self.iters, self.mean, self.median, self.p95, self.min
        )
    }

    /// One JSON object for the machine-readable bench log (names are
    /// bench-controlled ASCII, so no escaping is needed).
    pub fn json(&self) -> String {
        format!(
            "{{\"name\":\"{}\",\"iters\":{},\"mean_ns\":{},\"median_ns\":{},\"p95_ns\":{},\"min_ns\":{}}}",
            self.name,
            self.iters,
            self.mean.as_nanos(),
            self.median.as_nanos(),
            self.p95.as_nanos(),
            self.min.as_nanos()
        )
    }
}

/// Write `BENCH_<target>.json` at the repository root (the crate's
/// `CARGO_MANIFEST_DIR`, *not* the invoker's working directory) so the
/// perf trajectory lands in a fixed, CI-checkable location across PRs.
/// Returns the path written.
pub fn write_json(target: &str, results: &[BenchResult]) -> std::io::Result<String> {
    let path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(format!("BENCH_{target}.json"));
    let body: Vec<String> = results.iter().map(|r| format!("  {}", r.json())).collect();
    let text = format!(
        "{{\"target\":\"{target}\",\"results\":[\n{}\n]}}\n",
        body.join(",\n")
    );
    std::fs::write(&path, text)?;
    Ok(path.display().to_string())
}

/// Benchmark configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    pub warmup_iters: u32,
    pub min_iters: u32,
    /// Stop adding iterations once this much time has been measured.
    pub target_time: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 3,
            min_iters: 10,
            target_time: Duration::from_millis(500),
        }
    }
}

/// Time `f` under `cfg`, returning summary statistics. The closure's
/// return value is passed through `std::hint::black_box` to prevent
/// dead-code elimination.
pub fn bench<T>(name: &str, cfg: BenchConfig, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        std::hint::black_box(f());
    }
    let mut samples: Vec<Duration> = Vec::new();
    let start = Instant::now();
    while samples.len() < cfg.min_iters as usize
        || (start.elapsed() < cfg.target_time && samples.len() < 100_000)
    {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
        if samples.len() >= cfg.min_iters as usize && start.elapsed() >= cfg.target_time {
            break;
        }
    }
    summarize(name, &mut samples)
}

fn summarize(name: &str, samples: &mut [Duration]) -> BenchResult {
    samples.sort_unstable();
    let n = samples.len();
    let sum: Duration = samples.iter().sum();
    let pick = |p: f64| samples[((p * (n as f64 - 1.0)).round() as usize).min(n - 1)];
    BenchResult {
        name: name.to_string(),
        iters: n as u64,
        mean: sum / n as u32,
        median: pick(0.5),
        p95: pick(0.95),
        min: samples[0],
    }
}

/// Print a standard bench header so all targets look uniform.
pub fn header(target: &str, what: &str) {
    println!("\n### bench target: {target}");
    println!("### {what}\n");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_minimum_iterations() {
        let cfg = BenchConfig {
            warmup_iters: 1,
            min_iters: 25,
            target_time: Duration::ZERO,
        };
        let mut count = 0u64;
        let r = bench("count", cfg, || {
            count += 1;
            count
        });
        assert!(r.iters >= 25);
        assert!(count >= 26); // warmup + iters
        assert!(r.min <= r.median && r.median <= r.p95);
    }

    #[test]
    fn per_second_math() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean: Duration::from_millis(100),
            median: Duration::from_millis(100),
            p95: Duration::from_millis(100),
            min: Duration::from_millis(100),
        };
        assert!((r.per_second(50.0) - 500.0).abs() < 1e-9);
    }

    #[test]
    fn json_is_well_formed() {
        let r = BenchResult {
            name: "kernel x".into(),
            iters: 2,
            mean: Duration::from_nanos(1500),
            median: Duration::from_nanos(1400),
            p95: Duration::from_nanos(2000),
            min: Duration::from_nanos(1000),
        };
        let j = r.json();
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert!(j.contains("\"name\":\"kernel x\""));
        assert!(j.contains("\"mean_ns\":1500"));
        assert!(j.contains("\"min_ns\":1000"));
    }

    #[test]
    fn write_json_lands_at_the_repo_root() {
        let r = BenchResult {
            name: "probe".into(),
            iters: 1,
            mean: Duration::from_nanos(10),
            median: Duration::from_nanos(10),
            p95: Duration::from_nanos(10),
            min: Duration::from_nanos(10),
        };
        let path = write_json("harness_selftest", &[r]).unwrap();
        // anchored to the manifest dir, regardless of the test's cwd
        assert!(
            path.starts_with(env!("CARGO_MANIFEST_DIR")),
            "bench json escaped the repo root: {path}"
        );
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"target\":\"harness_selftest\""));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn format_contains_name() {
        let cfg = BenchConfig {
            warmup_iters: 0,
            min_iters: 3,
            target_time: Duration::ZERO,
        };
        let r = bench("fmt-check", cfg, || 1 + 1);
        assert!(r.format().contains("fmt-check"));
    }
}
