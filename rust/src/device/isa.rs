//! The instruction layer (DESIGN.md §Device): a four-op ISA and the
//! compiler that lowers a tiled matmul onto it.
//!
//! Modelled on BISMO's fetch/execute/result instruction queues: every
//! SA pass becomes a `Fetch` (DMA the tile's operand plane words into
//! the back FIFO bank), an `Execute` (run the bit-serial compute
//! phase), and a `Writeback` (snake-drain the accumulators), with a
//! trailing `Sync` barrier. The driver interprets the list in order for
//! *function* and scoreboards it for *timing* — `Fetch` of tile N+1
//! issues while tile N executes (double buffering), which is where the
//! fetch/execute overlap the telemetry reports comes from.

use crate::arch::throughput::bitsmm_cycles;
use crate::coordinator::tiler::{TileJob, TilePlan};
use crate::sim::array::SaConfig;

/// Modelled DMA bandwidth: packed u64 words transferred per device
/// cycle (a 256-bit bus). Only the *timing* of `Fetch` depends on this;
/// function never does.
pub const DMA_WORDS_PER_CYCLE: u64 = 4;

/// One device instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Instr {
    /// Stream one tile's operand plane words into the (back) edge
    /// FIFOs: `(job.m + job.n) · ceil(k/64) · planes` u64 words.
    Fetch {
        tile: u32,
        job: TileJob,
        /// Bit planes per operand (the effective precision).
        planes: u32,
        /// Total u64 words this fetch transfers.
        words: u64,
    },
    /// Run the compute phase. `cycles` is the closed-form estimate
    /// (eq. 8 + systolic fill) the compiler schedules with; the driver
    /// replaces it with the measured count.
    Execute { tile: u32, cycles: u64 },
    /// Drain the tile through the readout network (`rows × cols`
    /// cycles — the full-array snake, §III-B).
    Writeback { tile: u32, job: TileJob, cycles: u64 },
    /// Barrier: all prior instructions retire before anything after.
    Sync,
}

impl Instr {
    /// Display mnemonic for traces and tables.
    pub fn mnemonic(&self) -> &'static str {
        match self {
            Instr::Fetch { .. } => "fetch",
            Instr::Execute { .. } => "execute",
            Instr::Writeback { .. } => "writeback",
            Instr::Sync => "sync",
        }
    }
}

/// DMA words one tile's fetch transfers: every active lane (the tile's
/// `m` rows plus `n` columns) receives `planes × ceil(k/64)` packed
/// words.
pub fn fetch_words(job: &TileJob, bits: u32) -> u64 {
    let wpv = job.k.div_ceil(64) as u64;
    (job.m + job.n) as u64 * wpv * bits as u64
}

/// Fetch cycles at the modelled DMA width.
pub fn fetch_cycles(words: u64) -> u64 {
    words.div_ceil(DMA_WORDS_PER_CYCLE)
}

/// Lower a tiled matmul at precision `bits` into the instruction list
/// the driver interprets: `Fetch, Execute, Writeback` per SA pass, in
/// tile order, then one `Sync`.
pub fn compile(plan: &TilePlan, sa: &SaConfig, bits: u32) -> Vec<Instr> {
    let fill = (sa.rows + sa.cols).saturating_sub(2) as u64;
    let wb = (sa.rows * sa.cols) as u64;
    let mut prog = Vec::with_capacity(plan.jobs.len() * 3 + 1);
    for (t, job) in plan.jobs.iter().enumerate() {
        let tile = t as u32;
        prog.push(Instr::Fetch {
            tile,
            job: *job,
            planes: bits,
            words: fetch_words(job, bits),
        });
        prog.push(Instr::Execute {
            tile,
            cycles: bitsmm_cycles(job.k as u64, bits) + fill,
        });
        prog.push(Instr::Writeback { tile, job: *job, cycles: wb });
    }
    prog.push(Instr::Sync);
    prog
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::tiler::tile_matmul;
    use crate::sim::mac_common::MacVariant;

    #[test]
    fn compile_emits_three_ops_per_tile_plus_sync() {
        let sa = SaConfig::new(4, 16, MacVariant::Booth);
        let plan = tile_matmul(10, 70, 40, &sa); // 3 row bands × 3 col bands
        let prog = compile(&plan, &sa, 8);
        assert_eq!(prog.len(), plan.jobs.len() * 3 + 1);
        assert_eq!(prog.last(), Some(&Instr::Sync));
        for (t, chunk) in prog.chunks_exact(3).enumerate() {
            assert!(matches!(chunk[0], Instr::Fetch { tile, .. } if tile == t as u32));
            assert!(matches!(chunk[1], Instr::Execute { tile, .. } if tile == t as u32));
            assert!(matches!(chunk[2], Instr::Writeback { tile, .. } if tile == t as u32));
        }
    }

    #[test]
    fn fetch_words_count_every_active_lane() {
        let job = TileJob { row0: 0, col0: 0, m: 3, k: 70, n: 5 };
        // ceil(70/64) = 2 words per plane per lane, 8 lanes, 7 planes
        assert_eq!(fetch_words(&job, 7), (3 + 5) * 2 * 7);
        assert_eq!(fetch_cycles(fetch_words(&job, 7)), (8 * 2 * 7u64).div_ceil(4));
    }

    #[test]
    fn execute_estimate_is_eq8_plus_fill() {
        let sa = SaConfig::new(4, 16, MacVariant::Booth);
        let plan = tile_matmul(4, 64, 16, &sa);
        let prog = compile(&plan, &sa, 8);
        let Instr::Execute { cycles, .. } = prog[1] else {
            panic!("expected execute at slot 1, got {:?}", prog[1])
        };
        assert_eq!(cycles, (64 + 1) * 8 + (4 + 16 - 2));
    }
}
