//! Instruction-driven device backend (DESIGN.md §Device).
//!
//! Three layers between the coordinator and the cycle-accurate array:
//!
//! - [`isa`] — a four-op instruction set (`Fetch`/`Execute`/
//!   `Writeback`/`Sync`) and the compiler that lowers a
//!   [`crate::coordinator::tiler::TilePlan`] onto it.
//! - [`simif`] — the narrow transport trait ([`SimIf`]: register
//!   poke/peek + per-lane packed-word DMA) that
//!   [`crate::sim::SystolicArray`] implements and real hardware or a
//!   PJRT device could implement instead.
//! - [`driver`] — the interpreter: strictly in-order function,
//!   double-buffered timing scoreboard, per-stage telemetry in
//!   [`DeviceStats`].
//!
//! The packed bit-plane representation ([`crate::bits::PackedPlanes`])
//! is the only operand format that crosses the transport: the array's
//! P2S front end consumes streamed plane words directly instead of
//! re-deriving bit patterns from integer values each cycle.

pub mod driver;
pub mod isa;
pub mod simif;

pub use driver::{device_matmul, run_layer, run_tile, DeviceStats, LayerRun, TileRun};
pub use isa::{compile, fetch_cycles, fetch_words, Instr, DMA_WORDS_PER_CYCLE};
pub use simif::{DevReg, DmaChannel, SimIf};
