//! The transport layer (DESIGN.md §Device): a narrow register-poke /
//! packed-word-DMA boundary between the driver and whatever executes
//! the tile.
//!
//! Modelled on BISMO's register-file + DMA front end and the
//! simif/dmaif split of FPGA emulation harnesses: the driver side only
//! ever (1) writes geometry registers, (2) streams `PackedPlanes` words
//! into per-lane edge FIFOs, (3) kicks `exec`, and (4) drains results
//! with `readback`. The cycle-accurate [`crate::sim::SystolicArray`]
//! implements this trait today; real hardware (MMIO + DMA engine) or a
//! PJRT-backed device can attach later by implementing the same five
//! methods — nothing above this trait knows which one it is driving.
//!
//! Determinism: the trait is strictly blocking (`exec` runs a tile to
//! completion, `readback` drains it), so a driver issuing the same
//! instruction stream always observes the same outputs and the same
//! per-stage cycle counts. The fetch/execute overlap the driver reports
//! is a *scoreboard* over these measured durations, not a concurrent
//! execution — which is why the double-buffered schedule is
//! reproducible bit-for-bit and cycle-for-cycle.

use crate::Result;

/// Device register map. Geometry registers are write-only from the
/// driver's perspective between `Reset` and `exec`; `Cycle` and
/// `DmaWords` are read-only status counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DevReg {
    /// Write non-zero: full device reset (array state + FIFOs + regs).
    Reset,
    /// Tile output rows (`≤ SA rows`).
    M,
    /// Tile output cols (`≤ SA cols`).
    N,
    /// Contracted dimension (unbounded — eq. 8 scales linearly).
    K,
    /// Operand precision, 1..=16.
    Bits,
    /// Read-only: device cycle counter.
    Cycle,
    /// Read-only: cumulative u64 words received over DMA.
    DmaWords,
}

/// The two edge-FIFO banks of the array.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DmaChannel {
    /// Top edge, one lane per column: multiplicand (B) plane words,
    /// streamed MSb-first by the device's vertical P2S units.
    Vertical,
    /// Left edge, one lane per row: multiplier (A) plane words,
    /// streamed LSb-first by the horizontal P2S units.
    Horizontal,
}

/// The device transport: everything the driver can do to a device.
pub trait SimIf {
    /// Write a device register.
    fn poke(&mut self, reg: DevReg, val: u64) -> Result<()>;

    /// Read a device register.
    fn peek(&self, reg: DevReg) -> u64;

    /// Stream one lane's packed operand words (plane-major,
    /// `bits × ceil(k/64)` u64 words per full lane) into an edge FIFO.
    /// Words are `PackedPlanes` storage verbatim.
    fn dma_push(&mut self, ch: DmaChannel, lane: usize, words: &[u64]) -> Result<()>;

    /// Run the programmed tile's compute phase to completion. Returns
    /// the architectural compute cycles consumed. Consumes the FIFOs.
    fn exec(&mut self) -> Result<u64>;

    /// Drain the result through the readout network: the m×n tile
    /// (row-major, cropped to the programmed geometry) and the drain
    /// cycles.
    fn readback(&mut self) -> Result<(Vec<i64>, u64)>;
}
