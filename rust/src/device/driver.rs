//! The driver layer (DESIGN.md §Device): interpret a compiled
//! instruction list over the [`SimIf`] transport, double-buffering the
//! next tile's fetch under the current tile's execute, and report
//! per-stage cycle/occupancy telemetry.
//!
//! Function and timing are deliberately split. *Function* is strictly
//! in-order: fetch tile t (poke geometry + DMA plane words), execute
//! it, read it back — the blocking transport makes the outputs
//! deterministic. *Timing* is a scoreboard over the measured stage
//! durations that models the double-buffered edge FIFOs of the real
//! device: tile t+1's fetch issues the moment tile t's execute starts
//! (its FIFO bank is free from then on), so fetch cycles hide under
//! compute and only the exposed remainder stalls the array. Because
//! the scoreboard consumes *measured* durations in a fixed order, the
//! reported overlap is as reproducible as the outputs themselves.

use super::isa::{self, Instr};
use super::simif::{DevReg, DmaChannel, SimIf};
use crate::bits::packed::PackedPlanes;
use crate::bits::plane::PlaneKind;
use crate::coordinator::tiler::{tile_matmul, TilePlan};
use crate::sim::array::{SaConfig, SystolicArray};
use crate::sim::trace::DeviceTrace;
use crate::Result;

/// Per-stage device telemetry, accumulated across tiles (and across
/// matmuls when merged into `ExecutionReport`/`Metrics`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DeviceStats {
    /// SA passes executed.
    pub tiles: u64,
    /// Instructions interpreted (fetch + execute + writeback + sync).
    pub instrs: u64,
    /// Total fetch (DMA) cycles at the modelled bus width.
    pub fetch_cycles: u64,
    /// Total measured compute cycles.
    pub exec_cycles: u64,
    /// Total readout drain cycles.
    pub wb_cycles: u64,
    /// Fetch cycles hidden under the previous tile's execute/writeback
    /// (the double-buffering win; 0 on a single-tile shape).
    pub overlap_cycles: u64,
    /// Exposed fetch cycles (the first tile's lead-in plus any fetch
    /// longer than the compute it hides under).
    pub stall_cycles: u64,
    /// u64 words streamed over the DMA boundary.
    pub dma_words: u64,
}

impl DeviceStats {
    /// JSON object for the telemetry snapshot (DESIGN.md
    /// §Observability) — the per-stage cycle ledger verbatim.
    pub fn json(&self) -> String {
        format!(
            "{{\"tiles\":{},\"instrs\":{},\"fetch_cycles\":{},\"exec_cycles\":{},\"wb_cycles\":{},\"overlap_cycles\":{},\"stall_cycles\":{},\"dma_words\":{}}}",
            self.tiles,
            self.instrs,
            self.fetch_cycles,
            self.exec_cycles,
            self.wb_cycles,
            self.overlap_cycles,
            self.stall_cycles,
            self.dma_words
        )
    }

    pub fn merge(&mut self, o: &DeviceStats) {
        self.tiles += o.tiles;
        self.instrs += o.instrs;
        self.fetch_cycles += o.fetch_cycles;
        self.exec_cycles += o.exec_cycles;
        self.wb_cycles += o.wb_cycles;
        self.overlap_cycles += o.overlap_cycles;
        self.stall_cycles += o.stall_cycles;
        self.dma_words += o.dma_words;
    }

    /// Array-busy cycles: compute + readout. This is the cycle count
    /// every backend has always reported as `hw_cycles` — streaming the
    /// operands adds nothing to it (fetch is accounted separately), so
    /// the pre-refactor totals are preserved exactly.
    pub fn hw_cycles(&self) -> u64 {
        self.exec_cycles + self.wb_cycles
    }

    /// End-to-end cycles had every stage run back-to-back (no
    /// double buffering).
    pub fn serial_cycles(&self) -> u64 {
        self.fetch_cycles + self.exec_cycles + self.wb_cycles
    }

    /// End-to-end cycles of the double-buffered schedule: only the
    /// exposed fetch remainder extends the array-busy time.
    pub fn pipelined_cycles(&self) -> u64 {
        self.stall_cycles + self.exec_cycles + self.wb_cycles
    }

    /// Fraction of fetch traffic hidden under compute (0 when nothing
    /// was fetched).
    pub fn fetch_overlap_ratio(&self) -> f64 {
        if self.fetch_cycles == 0 {
            0.0
        } else {
            self.overlap_cycles as f64 / self.fetch_cycles as f64
        }
    }

    /// Fraction of the pipelined schedule the array spent computing or
    /// draining (vs stalled on exposed fetch).
    pub fn occupancy(&self) -> f64 {
        let total = self.pipelined_cycles();
        if total == 0 {
            0.0
        } else {
            self.hw_cycles() as f64 / total as f64
        }
    }
}

/// One streamed SA pass: the cropped m×n tile and its measured stage
/// durations.
pub struct TileRun {
    pub out: Vec<i64>,
    pub exec_cycles: u64,
    pub readout_cycles: u64,
}

/// Stream one tile through the transport: poke geometry, DMA lane
/// words (A row vectors `a_vec0..a_vec0+m`, B column vectors
/// `b_vec0..b_vec0+n`), execute, read back. The packs must be raw
/// two's-complement (`Sbmwc`) planes at exactly `bits` — both MAC
/// variants consume the same raw bit streams (the variant is the MAC's
/// internal architecture, not a stream encoding).
pub fn run_tile<D: SimIf>(
    dev: &mut D,
    pa: &PackedPlanes,
    a_vec0: usize,
    pb: &PackedPlanes,
    b_vec0: usize,
    m: usize,
    n: usize,
    bits: u32,
) -> Result<TileRun> {
    check_planes(pa, pb, bits)?;
    anyhow::ensure!(
        a_vec0 + m <= pa.vectors && b_vec0 + n <= pb.vectors,
        "tile [{a_vec0}+{m}, {b_vec0}+{n}] outside packed operands ({} × {} vectors)",
        pa.vectors,
        pb.vectors
    );
    let k = pa.len;
    dev.poke(DevReg::Reset, 1)?;
    program_and_fetch(dev, pa, a_vec0, pb, b_vec0, m, n, k, bits)?;
    let exec_cycles = dev.exec()?;
    let (out, readout_cycles) = dev.readback()?;
    Ok(TileRun { out, exec_cycles, readout_cycles })
}

fn check_planes(pa: &PackedPlanes, pb: &PackedPlanes, bits: u32) -> Result<()> {
    anyhow::ensure!(
        pa.kind == PlaneKind::Sbmwc && pb.kind == PlaneKind::Sbmwc,
        "device streaming consumes raw two's-complement (sbmwc) planes, got {:?}/{:?}",
        pa.kind,
        pb.kind
    );
    anyhow::ensure!(
        pa.bits == bits && pb.bits == bits,
        "packed planes carry {}/{} bit planes, device programmed for {bits}",
        pa.bits,
        pb.bits
    );
    anyhow::ensure!(
        pa.len == pb.len,
        "contracted dimension mismatch: A k={} vs B k={}",
        pa.len,
        pb.len
    );
    Ok(())
}

/// Poke one tile's geometry and DMA its lane words (the `Fetch`
/// instruction's function).
#[allow(clippy::too_many_arguments)]
fn program_and_fetch<D: SimIf>(
    dev: &mut D,
    pa: &PackedPlanes,
    a_vec0: usize,
    pb: &PackedPlanes,
    b_vec0: usize,
    m: usize,
    n: usize,
    k: usize,
    bits: u32,
) -> Result<()> {
    dev.poke(DevReg::M, m as u64)?;
    dev.poke(DevReg::N, n as u64)?;
    dev.poke(DevReg::K, k as u64)?;
    dev.poke(DevReg::Bits, bits as u64)?;
    let mut buf = Vec::new();
    for c in 0..n {
        buf.clear();
        pb.dma_words(b_vec0 + c, &mut buf);
        dev.dma_push(DmaChannel::Vertical, c, &buf)?;
    }
    for r in 0..m {
        buf.clear();
        pa.dma_words(a_vec0 + r, &mut buf);
        dev.dma_push(DmaChannel::Horizontal, r, &buf)?;
    }
    Ok(())
}

/// One matmul's worth of device execution: the stitched `plan.m ×
/// plan.n` result and the accumulated per-stage telemetry.
pub struct LayerRun {
    pub out: Vec<i64>,
    pub stats: DeviceStats,
}

/// Compile `plan` to the device ISA and interpret it over `dev`,
/// double-buffering fetches. `pa` packs all of A's rows, `pb` all of
/// B's columns (`Sbmwc`-kind, exactly `bits` planes); tiles address
/// them by vector offset, so nothing is re-packed per tile.
pub fn run_layer<D: SimIf>(
    dev: &mut D,
    plan: &TilePlan,
    sa: &SaConfig,
    pa: &PackedPlanes,
    pb: &PackedPlanes,
    bits: u32,
    mut trace: Option<&mut DeviceTrace>,
) -> Result<LayerRun> {
    check_planes(pa, pb, bits)?;
    anyhow::ensure!(
        pa.vectors == plan.m && pb.vectors == plan.n && pa.len == plan.k,
        "packed operands ({}×{} @k={}) do not cover the tile plan ({}×{} @k={})",
        pa.vectors,
        pb.vectors,
        pa.len,
        plan.m,
        plan.n,
        plan.k
    );
    let prog = isa::compile(plan, sa, bits);
    let mut out = vec![0i64; plan.m * plan.n];
    let mut stats = DeviceStats { instrs: prog.len() as u64, ..Default::default() };

    dev.poke(DevReg::Reset, 1)?;

    // Scoreboard state (cycles on the device clock). `Fetch` of tile t
    // issues at `exec_start` of tile t−1 — that is when t's FIFO bank
    // frees up under double buffering; tile 0's fetch is the exposed
    // lead-in.
    let mut last_exec_start = 0u64;
    let mut last_wb_end = 0u64;
    // carried from a tile's Fetch to its Execute: (fetch_end, job).
    let mut pending_fetch_end = 0u64;
    let mut pending_exec_end = 0u64;

    for instr in &prog {
        match *instr {
            Instr::Fetch { tile, job, words, .. } => {
                program_and_fetch(dev, pa, job.row0, pb, job.col0, job.m, job.n, job.k, bits)?;
                let fc = isa::fetch_cycles(words);
                let start = if tile == 0 { 0 } else { last_exec_start };
                let end = start + fc;
                let hidden = if tile == 0 {
                    0
                } else {
                    end.min(last_wb_end).saturating_sub(start)
                };
                stats.fetch_cycles += fc;
                stats.overlap_cycles += hidden;
                stats.stall_cycles += fc - hidden;
                stats.dma_words += words;
                pending_fetch_end = end;
                if let Some(t) = trace.as_deref_mut() {
                    t.stage(instr.mnemonic(), tile, start, end);
                }
            }
            Instr::Execute { tile, .. } => {
                let measured = dev.exec()?;
                let start = pending_fetch_end.max(last_wb_end);
                let end = start + measured;
                stats.exec_cycles += measured;
                last_exec_start = start;
                pending_exec_end = end;
                if let Some(t) = trace.as_deref_mut() {
                    t.stage(instr.mnemonic(), tile, start, end);
                }
            }
            Instr::Writeback { tile, job, .. } => {
                let (tile_out, wb) = dev.readback()?;
                for r in 0..job.m {
                    for c in 0..job.n {
                        out[(job.row0 + r) * plan.n + job.col0 + c] = tile_out[r * job.n + c];
                    }
                }
                let end = pending_exec_end + wb;
                stats.wb_cycles += wb;
                stats.tiles += 1;
                last_wb_end = end;
                if let Some(t) = trace.as_deref_mut() {
                    t.stage(instr.mnemonic(), tile, pending_exec_end, end);
                }
            }
            Instr::Sync => {
                if let Some(t) = trace.as_deref_mut() {
                    t.stage(instr.mnemonic(), u32::MAX, last_wb_end, last_wb_end);
                }
            }
        }
    }
    Ok(LayerRun { out, stats })
}

/// Pack, tile, and run one full matmul on a freshly built device —
/// the standalone entry used by `ExecPlan`'s device backend and tests.
/// Operands wider than the declared precision widen to their true bit
/// width (the device streams whatever the planes hold).
pub fn device_matmul(
    sa: SaConfig,
    a: &[i32],
    b: &[i32],
    m: usize,
    k: usize,
    n: usize,
    bits: u32,
) -> Result<(Vec<i64>, DeviceStats)> {
    crate::validate_bits(bits)?;
    anyhow::ensure!(a.len() == m * k, "A shape mismatch");
    anyhow::ensure!(b.len() == k * n, "B shape mismatch");
    let need = PackedPlanes::needed_bits(a)
        .max(PackedPlanes::needed_bits(b))
        .max(bits);
    crate::validate_bits(need)?;
    let pa = PackedPlanes::pack_rows(a, m, k, need, PlaneKind::Sbmwc)?;
    let pb = PackedPlanes::pack_cols(b, k, n, need, PlaneKind::Sbmwc)?;
    let plan = tile_matmul(m, k, n, &sa);
    let mut dev = SystolicArray::new(sa);
    let run = run_layer(&mut dev, &plan, &sa, &pa, &pb, need, None)?;
    Ok((run.out, run.stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::driver::ref_matmul_i64;
    use crate::sim::mac_common::MacVariant;

    fn mats(m: usize, k: usize, n: usize, bits: u32) -> (Vec<i32>, Vec<i32>) {
        let hi = crate::bits::twos::max_value(bits);
        let a = (0..m * k).map(|i| (i as i32 * 7 % (2 * hi + 1)) - hi).collect();
        let b = (0..k * n).map(|i| (i as i32 * 13 % (2 * hi + 1)) - hi).collect();
        (a, b)
    }

    #[test]
    fn multi_tile_layer_is_bit_identical_and_overlaps() {
        let sa = SaConfig::new(4, 16, MacVariant::Booth);
        let (m, k, n, bits) = (10usize, 130usize, 40usize, 6u32); // 9 tiles, tail word
        let (a, b) = mats(m, k, n, bits);
        let (out, stats) = device_matmul(sa, &a, &b, m, k, n, bits).unwrap();
        assert_eq!(out, ref_matmul_i64(&a, &b, m, k, n));
        assert_eq!(stats.tiles, 9);
        assert_eq!(stats.instrs, 9 * 3 + 1);
        assert!(stats.overlap_cycles > 0, "multi-tile fetch must hide under execute");
        assert_eq!(stats.fetch_cycles, stats.overlap_cycles + stats.stall_cycles);
        assert!(stats.pipelined_cycles() <= stats.serial_cycles());
        assert!(stats.occupancy() > 0.0 && stats.occupancy() <= 1.0);
    }

    #[test]
    fn single_tile_has_no_overlap() {
        let sa = SaConfig::new(4, 16, MacVariant::Sbmwc);
        let (m, k, n, bits) = (4usize, 32usize, 16usize, 8u32);
        let (a, b) = mats(m, k, n, bits);
        let (out, stats) = device_matmul(sa, &a, &b, m, k, n, bits).unwrap();
        assert_eq!(out, ref_matmul_i64(&a, &b, m, k, n));
        assert_eq!(stats.tiles, 1);
        assert_eq!(stats.overlap_cycles, 0);
        assert_eq!(stats.stall_cycles, stats.fetch_cycles);
    }

    #[test]
    fn hot_operands_widen_to_their_true_precision() {
        // declared 4-bit, but the data needs 9 bits — the device widens
        let sa = SaConfig::new(2, 2, MacVariant::Booth);
        let a = [200i32, -7, 3, 1];
        let b = [1i32, -200, 5, 2];
        let (out, _) = device_matmul(sa, &a, &b, 2, 2, 2, 4).unwrap();
        assert_eq!(out, ref_matmul_i64(&a, &b, 2, 2, 2));
    }

    #[test]
    fn booth_planes_are_rejected() {
        let a = [1i32, 2];
        let pa = PackedPlanes::pack_rows(&a, 1, 2, 4, PlaneKind::Booth).unwrap();
        let pb = PackedPlanes::pack_cols(&a, 2, 1, 4, PlaneKind::Sbmwc).unwrap();
        let mut dev = SystolicArray::new(SaConfig::new(2, 2, MacVariant::Booth));
        assert!(run_tile(&mut dev, &pa, 0, &pb, 0, 1, 1, 4).is_err());
    }
}
