//! Offline stand-in for the `anyhow` crate (see DESIGN.md
//! substitutions). The build environment has no crates.io access, so
//! this vendors the subset the workspace uses: a string-backed dynamic
//! [`Error`], the [`anyhow!`] / [`bail!`] / [`ensure!`] macros, the
//! `Result<T>` alias, and `From<E: std::error::Error>` so `?` converts
//! any standard error (the source chain is flattened into the message,
//! which is what `{e:#}` formatting prints in real anyhow).

use std::fmt;

/// A dynamic error: a rendered message (source chain included).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable (what `anyhow!` uses).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{e}` and `{e:#}` both print the full flattened chain.
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`;
// that is what keeps this blanket conversion coherent (same trick as
// real anyhow).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// `anyhow::Result<T>` — the crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    #[test]
    fn macros_and_conversions() {
        fn inner(s: &str) -> crate::Result<i32> {
            crate::ensure!(!s.is_empty(), "empty input");
            let v: i32 = s.parse()?; // ParseIntError -> Error via From
            if v < 0 {
                crate::bail!("negative: {v}");
            }
            Ok(v)
        }
        assert_eq!(inner("42").unwrap(), 42);
        assert!(inner("").unwrap_err().to_string().contains("empty"));
        assert!(inner("x").unwrap_err().to_string().contains("invalid"));
        assert!(inner("-1").unwrap_err().to_string().contains("negative: -1"));
        let e = crate::anyhow!("ctx {}", 7);
        assert_eq!(format!("{e:#}"), "ctx 7");
        assert_eq!(format!("{e:?}"), "ctx 7");
    }
}
